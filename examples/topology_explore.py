"""Design-space exploration: cost/availability/perf of UB-Mesh vs baselines
(the paper's §6 in one script).

    PYTHONPATH=src python examples/topology_explore.py
"""

from repro.core import availability, capex

print("=== CapEx (8K NPUs, relative units) ===")
for row in capex.compare_architectures(8192):
    print(f"{row.name:22s} capex={row.capex:12.0f} opex={row.opex:12.0f} "
          f"perf={row.performance:.3f} cost-eff={row.cost_efficiency*1e6:.2f}")

print("\n=== Availability (Table 6) ===")
for afr in (availability.PAPER_UB_MESH, availability.PAPER_CLOS):
    print(f"{afr.name:8s} AFR={afr.total:6.1f}/yr MTBF={afr.mtbf_hours:6.1f}h "
          f"avail={afr.availability(availability.PAPER_MTTR_HOURS):.4f}")
ub = availability.PAPER_UB_MESH
print(f"with fast fault location+migration (13 min MTTR): "
      f"{ub.availability(availability.FAST_MTTR_HOURS):.4f}")
