"""Batched serving example: prefill + autoregressive decode with KV cache.

    PYTHONPATH=src python examples/serve_decode.py
"""

import subprocess
import sys

for arch in ("granite-8b", "rwkv6-1.6b", "mixtral-8x22b"):
    print(f"=== {arch} ===")
    rc = subprocess.call([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", arch, "--smoke",
        "--batch", "2", "--prompt-len", "24", "--gen", "8",
    ])
    if rc:
        sys.exit(rc)
