"""Batched serving example: prefill + autoregressive decode with KV cache,
then SLO-driven decode planning on the latency-calibrated rack model.

    PYTHONPATH=src python examples/serve_decode.py
"""

import subprocess
import sys

for arch in ("granite-8b", "rwkv6-1.6b", "mixtral-8x22b"):
    print(f"=== {arch} ===")
    rc = subprocess.call([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", arch, "--smoke",
        "--batch", "2", "--prompt-len", "32", "--gen", "8",
    ])
    if rc:
        sys.exit(rc)

# --- SLO-driven decode planning (no accelerator needed) -------------------
# Price a dense-70B decode across one 64-chip rack two ways: the
# bandwidth-calibrated objective (training-era pricing) and the
# message-level latency profile, then pick the sharding that meets a p99
# token-latency SLO at the target request rate.
print("=== SLO-driven decode planning (dense-70B, one rack) ===")
from repro.core.traffic import WorkloadSpec                    # noqa: E402
from repro.launch.serve import plan_decode, rack_perf_model    # noqa: E402

w = WorkloadSpec(
    "dense-70B-serve", 80, 8192, 64, 128, 8,
    seq_len=8192, global_batch=512, params_total=7e10,
)
res = plan_decode(
    w, 64, rack_perf_model(), qps=30.0, slo_s=0.012, batch=8,
    duration_s=10.0,
)
for c in res["candidates"]:
    print(
        f"  tp={c['tp']:3d} dp={c['dp']:3d} "
        f"step(bw)={c['step_bandwidth_s']*1e3:7.3f}ms "
        f"step(lat)={c['step_latency_s']*1e3:7.3f}ms "
        f"p99={c['p99_s']*1e3:9.3f}ms meets_slo={c['meets_slo']}"
    )
bw, slo = res["bandwidth_choice"], res["slo_choice"]
print(
    f"bandwidth-optimal: tp={bw['tp']} x dp={bw['dp']} | "
    f"SLO choice: tp={slo['tp']} x dp={slo['dp']} "
    f"({slo['tokens_per_s']:.0f} tok/s at p99 {slo['p99_s']*1e3:.1f}ms)"
)
assert res["diverged"], "bandwidth and SLO objectives should disagree here"
