"""End-to-end training example: a reduced granite-8b for a few hundred
steps with checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py
"""

import subprocess
import sys

cmd = [
    sys.executable, "-m", "repro.launch.train",
    "--arch", "granite-8b", "--smoke",
    "--steps", "200", "--batch", "16", "--seq", "256",
    "--ckpt-dir", "/tmp/ubmesh_example_ckpt", "--ckpt-every", "100",
    "--compression", "bf16",
]
sys.exit(subprocess.call(cmd))
