"""Fault-tolerance walkthrough: 64+1 backup activation, APR link recovery
with direct notification, checkpoint/restart + elastic DP rescale.

    PYTHONPATH=src python examples/fault_tolerance.py
"""

import numpy as np

from repro.core import apr
from repro.core.topology import ub_mesh_pod
from repro.runtime.fault_tolerance import (
    RackFailover,
    TrainingSupervisor,
    recover_link_failure,
)
from repro.runtime.elastic import ElasticPlan

# --- 64+1 backup NPU (paper Fig. 9) -----------------------------------------
fo = RackFailover()
rec = fo.fail(logical=3)
print(f"NPU-3 failed -> backup NPU {rec['backup_physical']} activated, "
      f"{rec['redirected_links']} links redirected via LRS "
      f"(+{rec['extra_hops']} hop)")

# --- link failure -> APR direct notification --------------------------------
pod = ub_mesh_pod()
plan = apr.RoutePlan(pod)
rng = np.random.default_rng(0)
for _ in range(128):
    s, d = rng.integers(0, pod.num_nodes, 2)
    if s != d:
        plan.install(int(s), int(d), apr.shortest_paths(pod, int(s), int(d))[0])
link = next(iter(plan._by_link))
stats = recover_link_failure(plan, link)
print(f"\nlink {link} failed: {stats['affected_flows']} flows rerouted, "
      f"{stats['control_messages_direct']} direct notifications "
      f"(vs {stats['control_messages_flood']} flood messages), "
      f"recovered in {stats['recovery_wall_s']*1e3:.1f} ms (control plane)")

# --- spare-pool exhaustion: structured outcome, restock ----------------------
rec2 = fo.fail(logical=7)                 # the +1 spare is already in use
print(f"NPU-7 failed with the pool empty -> kind={rec2['kind']}, "
      f"failed_count={rec2['failed_count']} (policy engine decides: wait "
      f"for restock, checkpoint-restore, or elastic shrink)")
fo.restock(rec["failed_physical"])        # field service swapped NPU-3's board
rec3 = fo.fail(logical=9)
print(f"after restock, NPU-9 failed -> kind={rec3['kind']} "
      f"(backup NPU {rec3['backup_physical']})")

# --- supervisor: heartbeat -> recovery plan ---------------------------------
sup = TrainingSupervisor(n_workers=8, heartbeat_timeout_s=0.0)
dead = sup.dead_workers()
plan_ = sup.plan_recovery(RackFailover(), dead[:2])
print(f"\nsupervisor: {len(dead)} silent workers, actions = "
      f"{[a['kind'] for a in plan_['actions']]}, "
      f"restart_from_checkpoint = {plan_['restart_from_checkpoint']}")

# --- elastic rescale ---------------------------------------------------------
ep = ElasticPlan(old_dp=16, new_dp=8, old_global_batch=256)
print(f"\nelastic: dp 16 -> 8, global batch stays {ep.new_global_batch}, "
      f"lr scale {ep.effective_lr_scale}")

# --- netsim: link failure under a LIVE multi-ring AllReduce ------------------
# The RoutePlan recovery above is control-plane only; the flow-level
# simulator executes the data plane: a board-level X link dies mid-
# collective, direct notification fires, and the stranded flows re-split
# over surviving APR paths — the collective still completes.
from repro.core.cost_model import Routing
from repro.core.topology import ub_mesh_rack
from repro.netsim import NetSim, ring_allreduce
from repro.netsim.collectives import clique_nodes

rack = ub_mesh_rack()
nodes = clique_nodes(rack, 0)
dag = ring_allreduce(rack, nodes, 64e6)
sim = NetSim(rack, routing=Routing.DETOUR)
healthy = sim.run_dag(dag)
failed = sim.run_dag(
    dag, fail_link=(nodes[0], nodes[1]), fail_at_s=healthy.makespan_s / 4
)
print(f"\nnetsim: X-clique AllReduce of 64 MB = {healthy.makespan_s*1e3:.2f} ms; "
      f"link {nodes[0]}-{nodes[1]} fails at t={healthy.makespan_s/4*1e3:.2f} ms -> "
      f"completes in {failed.makespan_s*1e3:.2f} ms "
      f"({failed.makespan_s/healthy.makespan_s - 1:+.1%}), "
      f"{failed.incomplete} flows lost, "
      f"peak link utilization {failed.max_link_utilization:.0%}")
