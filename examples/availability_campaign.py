"""Monte-Carlo availability campaign walkthrough (§3.3.2, §6.6, Table 6):
seeded failure sampling, netsim degraded-mesh repricing, the recovery
policy engine, and the UB-Mesh vs Clos head-to-head.

    PYTHONPATH=src python examples/availability_campaign.py
"""

from repro.core.codesign import GeometryCandidate
from repro.runtime.campaign import (
    CampaignConfig,
    campaign_trace,
    head_to_head,
    linearity_under_failures,
    run_campaign,
)

# --- one architecture, netsim-repriced, small pod ---------------------------
cand = GeometryCandidate(board=4, boards_per_rack=4)    # (4,4,4,4) = 256
cfg = CampaignConfig(candidate=cand, chips=256, seeds=(0, 1, 2),
                     size_bytes=4e6)
res = run_campaign(cfg)
s = res.summary()
print(f"{s['seeds']} seeds x {s['horizon_weeks']:.0f} weeks @ {s['chips']} chips:")
print(f"  network availability {s['availability']:.5f}, "
      f"goodput {s['goodput']:.5f}, {s['events']} events, "
      f"policies {s['policies']}")
print(f"  healthy step {s['healthy_step_s']:.3f}s; degraded deltas "
      f"{s['step_delta_s_by_class']} (netsim APR reroute on the failed mesh)")

# --- one seed's timeline -> Perfetto ----------------------------------------
run = max(res.runs, key=lambda r: r.n_events)
campaign_trace(run, path="campaign_trace.json")
print(f"\nseed {run.seed}: {run.n_events} events -> campaign_trace.json "
      f"(open at https://ui.perfetto.dev; 1 trace second = 1 simulated hour)")

# --- Table 6 head-to-head ----------------------------------------------------
h = head_to_head(chips=8192, seeds=tuple(range(16)), netsim_reprice=False)
print(f"\nUB-Mesh  availability {h['ub'].availability:.5f}  "
      f"goodput {h['ub'].goodput:.5f}")
print(f"Clos     availability {h['clos'].availability:.5f}  "
      f"goodput {h['clos'].goodput:.5f}")
print(f"gap {h['availability_gap']:.4f} (paper: ~0.072, closed-form "
      f"{h['analytic_gap']:.4f})")

# --- linearity under failures ------------------------------------------------
lin = linearity_under_failures(1024, 8192, seeds=tuple(range(8)),
                               netsim_reprice=False, perf_backend="analytic")
clos = linearity_under_failures(1024, 8192, seeds=tuple(range(8)),
                                arch="clos", netsim_reprice=False)
print(f"\nlinearity 1K -> 8K under failures: UB-Mesh {lin['linearity']:.4f} "
      f"(>= 0.95 claim), Clos {clos['linearity']:.4f} "
      f"(checkpoint-restore per NPU failure, no 64+1 spare)")
