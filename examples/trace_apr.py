"""Telemetry walkthrough: watch §4.1 All-Path Routing relieve a congested
trunk, then export Perfetto traces of all three strategies.

Fig. 19 in miniature: rack (0,0) sends three transfers to (1,1)..(1,3)
on the (Z, A) inter-rack mesh.  Every dimension-ordered shortest path
funnels through the single trunk (0,0)->(1,0); DETOUR and BORROW split
each transfer over ~4 APR paths so the receiver-egress cap binds instead
and the trunk never saturates.  Telemetry makes the difference visible:
per-link utilization timelines, bottleneck attribution straight from the
max-min solver's freeze step, and a Perfetto trace per strategy.

    PYTHONPATH=src python examples/trace_apr.py [out_dir]

Open the written ``trace_*.json`` files at https://ui.perfetto.dev —
links are counter tracks, ring steps span lanes, transfers async spans.
"""

import os
import sys

from repro.core.cost_model import Routing
from repro.netsim import NetSim, trunk_congestion

out_dir = sys.argv[1] if len(sys.argv) > 1 else "traces"
os.makedirs(out_dir, exist_ok=True)

sc = trunk_congestion()
hot = sc.hot_link
hot_name = f"{hot[0]}->{hot[1]}"
print(f"trunk-congestion on {sc.topo.shape} mesh: "
      f"{len(sc.dag.tasks)} transfers, hot trunk {hot_name}, "
      f"rx cap {sc.rx_gbs:.2f} GB/s\n")

peaks = {}
summaries = {}
for pol in (Routing.SHORTEST, Routing.DETOUR, Routing.BORROW):
    sim = NetSim(sc.topo, routing=pol, rx_gbs=sc.rx_gbs, telemetry=True)
    res = sim.run_dag(sc.dag)
    tel = res.telemetry
    peaks[pol] = tel.peak_utilization(hot)
    summaries[pol] = tel.summary()
    path = os.path.join(out_dir, f"trace_{pol.value}.json")
    tel.to_perfetto(path)
    s = summaries[pol]
    top_bn = s["bottlenecks"]["top"][0][0] if s["bottlenecks"]["top"] else "-"
    print(f"{pol.value:>8}: makespan {res.makespan_s*1e3:6.3f} ms | "
          f"trunk peak util {peaks[pol]:.2f} | "
          f"top bottleneck {top_bn} | "
          f"borrow launches {s['router']['borrow_path_launches']}"
          f"  -> {path}")

# --- the claims the trace should show --------------------------------------
shortest_bn = {
    name for name, _ in summaries[Routing.SHORTEST]["bottlenecks"]["top"]
}
assert peaks[Routing.SHORTEST] > 0.99, (
    f"shortest should saturate the trunk, peak={peaks[Routing.SHORTEST]}"
)
assert hot_name in shortest_bn, (
    f"attribution should name the congested trunk {hot_name}, got {shortest_bn}"
)
assert peaks[Routing.BORROW] < peaks[Routing.SHORTEST] - 0.2, (
    f"borrow should relieve the trunk: {peaks[Routing.BORROW]} "
    f"vs {peaks[Routing.SHORTEST]}"
)
print(f"\nAPR relief confirmed: trunk peak {peaks[Routing.SHORTEST]:.2f} "
      f"(shortest) -> {peaks[Routing.DETOUR]:.2f} (detour) -> "
      f"{peaks[Routing.BORROW]:.2f} (borrow); under shortest the solver "
      f"attributes the stall to {hot_name}, under detour/borrow to the "
      f"receiver-egress caps.")
