"""Quickstart: UB-Mesh topology, APR routing, and the parallelization
planner in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import apr, cost_model, multiring, planner, topology
from repro.core.cost_model import Routing
from repro.core.traffic import WorkloadSpec

# --- 1. build the paper's 4D-FullMesh pod (8x8 NPUs/rack, 4x4 racks) -------
pod = topology.ub_mesh_pod()
print(f"UB-Mesh-Pod: {pod.num_nodes} NPUs, shape {pod.shape}")
print(f"per-NPU bandwidth: {pod.node_bandwidth_gbs():.0f} GB/s")
print(f"cables: {pod.cables_by_link_type()}")

# --- 2. All-Path Routing between two NPUs ----------------------------------
src, dst = 0, pod.node_id((3, 5, 2, 1))
paths = apr.all_paths(pod, src, dst)
admissible = apr.tfc_admissible(pod, paths)
print(f"\nAPR {src}->{dst}: {len(paths)} paths, "
      f"{len(admissible)} TFC-admissible with 2 VLs, "
      f"shortest = {pod.hop_distance(src, dst)} hops")
hdr = apr.encode_path(pod, paths[0])
print(f"source-routing header: {hdr.pack().hex()} (8 bytes)")

# --- 3. Multi-Ring AllReduce planning ---------------------------------------
plan = multiring.plan_multiring(pod, dim=0)
print(f"\nMulti-Ring on the X clique: {len(plan.rings)} rings, "
      f"{plan.utilization:.0%} of links used, "
      f"{plan.effective_bandwidth_gbs():.0f} GB/s effective "
      f"(single ring: {multiring.single_ring_bandwidth_gbs(pod, 0):.0f})")

# --- 4. topology-aware parallelization (paper Fig. 15) ----------------------
w = WorkloadSpec("LLAMA-70B", 80, 8192, 64, 128, 8,
                 seq_len=8192, global_batch=1024, params_total=7e10)
comm = cost_model.build_comm_model(multi_pod=True, routing=Routing.BORROW)
for r in planner.plan(w, 8192, comm, top_k=3):
    s = r.spec
    print(f"planner: tp={s.tp} sp={s.sp} pp={s.pp} dp={s.dp} "
          f"m={s.microbatches}  iter={r.iteration_s:.2f}s "
          f"(comm {r.comm_s:.2f}s, bubble {r.bubble_s:.2f}s)")
