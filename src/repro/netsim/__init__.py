"""repro.netsim — flow-level discrete-event simulator of the UB-Mesh fabric.

Where the analytic engine (``core/simulator.py``) prices collectives with
closed-form alpha-beta costs per axis, netsim *executes* them: flows are
mapped onto the concrete ``NDFullMesh`` links, share bandwidth max-min
fairly, contend, detour, borrow switch capacity, and survive link failures
— the phenomena §4 (All-Path Routing) and §5 (Multi-Ring) exist to handle.

Module map (paper section -> module):

* ``events``      — deterministic heapq event engine, virtual time
                    (simulation substrate; no paper section)
* ``flows``       — max-min fair-share fluid flows on the §3.1 nD-FullMesh
                    links, per-dim ``gbs_per_peer`` capacities (Table 3),
                    plus receiver-egress (incast) caps that serialize
                    many-to-one bursts instead of resolving them instantly,
                    per-dim IO caps for switched tiers, and aggregate flows
                    carrying N symmetric ring-step members at once
* ``messages``    — message-level store-and-forward latency mode
                    (``NetSim(message_level=True)``): per-hop
                    serialization, propagation and FIFO queueing under the
                    same collective DAG compiler — the decode-serving
                    regime where small-message latency, not bandwidth,
                    dominates; feeds ``NetSim.measure_latency_profile``
* ``solver``      — the max-min rate allocators: vectorized numpy
                    water-filling over an incremental group CSR (default)
                    and the pure-Python reference oracle
* ``coarsen``     — rack/pod-coarsened SuperPod meshes (§3.3.4): racks
                    become super-nodes with trunk-aggregated capacities and
                    an IO-capped HRS dimension, so 4096-8192-chip multi-pod
                    scenarios stay tractable; ``detail_racks`` embeds
                    chip-level racks inside the coarse mesh (MixedMesh) so
                    model-axis collectives can be calibrated against
                    cross-pod background traffic
* ``routing``     — APR adapter (§4.1): shortest / detour / borrow path
                    sets from ``core/apr.py`` as per-flow multi-path
                    splits; direct-notification fast recovery (§4.2)
* ``collectives`` — Multi-Ring AllReduce (§5.1, Fig. 13) and Multi-Path
                    All2All (Fig. 14) schedules compiled into flow DAGs;
                    Table-1 traffic entries mapped onto node groups
* ``api``         — ``NetSim.run(workload, parallel_spec)`` facade,
                    ``NetSimResult``, and the per-(axis, collective-shape)
                    ``calibrated_profile`` behind
                    ``core.perf_model.NetsimPerfModel`` (§6 evaluation loop)
* ``scenarios``   — canonical traffic patterns (cross-rack hotspot,
                    inter-rack mesh, trunk congestion) shared by
                    benchmarks, examples and tests
* ``telemetry``   — opt-in recorder threaded through engine, solvers
                    and router: per-link utilization timelines,
                    solver-level bottleneck attribution, flow lifecycle
                    traces, router counters; exports a structured
                    summary dict and Perfetto trace JSON
                    (observability layer; no paper section)

Quick start::

    from repro.core.cost_model import Routing
    from repro.core.topology import ub_mesh_rack
    from repro.netsim import NetSim

    sim = NetSim(ub_mesh_rack(), routing=Routing.DETOUR)
    t = sim.allreduce_time(dim=0, size_bytes=64e6)   # one X clique
"""

from .api import NetSim, NetSimResult                      # noqa: F401
from .coarsen import (                                     # noqa: F401
    CoarseMesh,
    MixedMesh,
    coarse_calibrated_profile,
    coarse_netsim,
    coarsen_superpod,
    cross_pod_background_dag,
    mixed_calibrated_profile,
    mixed_netsim,
)
from .collectives import (                                 # noqa: F401
    FlowDAG,
    FlowTask,
    all_to_all,
    clique_nodes,
    compile_workload,
    grid_all_gather,
    grid_allreduce,
    grid_plane_nodes,
    hierarchical_all_gather,
    hierarchical_allreduce,
    model_group,
    moe_dispatch,
    multipath_all_to_all,
    remap_dag,
    ring_all_gather,
    ring_allreduce,
    ring_reduce_scatter,
    splice_dag,
)
from .events import EventEngine                            # noqa: F401
from .flows import FluidNetwork, default_rx_gbs            # noqa: F401
from .messages import (                                    # noqa: F401
    Message,
    MessageDagRun,
    MessageNetwork,
)
from .routing import Router, Transfer                      # noqa: F401
from .scenarios import (                                   # noqa: F401
    TrunkCongestion,
    hotspot_dag,
    inter_rack_mesh,
    trunk_congestion,
)
from .telemetry import FlowTrace, Telemetry                # noqa: F401
