"""Collective -> flow-DAG compiler (netsim layer 4).

Compiles the planner-side collective schedules into executable DAGs of
``FlowTask``s with explicit dependencies:

* **Multi-Ring AllReduce** (§5.1, Fig. 13) — ``core/multiring.py``'s clique
  decomposition (Walecki cycles for odd n, zig-zag chains for even n) is
  unrolled into 2(n-1) steps per ring, one task per (ring, step, position).
  Each task carries two deps: the data dep (the chunk a node forwards at
  step s is the one it received at step s-1) and the port dep (a node
  serializes its own sends).  Chains are modeled as rings minus the
  wrap-around edge — per-link load matches the schedule exactly, including
  the paper's observation that even-n chains lose endpoint bandwidth.
* **ReduceScatter / AllGather** — the (n-1)-step halves of the same rings.
* **Cross-dim 2D multi-ring** (Fig. 13's joint (X, Y) schedule) —
  ``core/multiring.grid_ring_decomposition``'s Hamiltonian cycles over the
  whole (X, Y) plane, driving both dimensions' links in every step; this is
  what closes the gap between the measured and analytic "model"-axis
  bandwidth that the per-dimension hierarchical schedule leaves open.
* **Hierarchical AllReduce / AllGather** — the cost model's schedule
  (reduce-scatter up the dimension list, allreduce at the top, all-gather
  back down) with phase barriers.
* **All-to-All** (§5.1, Fig. 14) — one independent task per ordered pair;
  the Router's multi-path split supplies the XY/YX partitioning.
* **traffic-table compilation** — maps ``core/traffic.py`` entries
  (TP/SP/EP/PP/DP) onto representative node groups of the concrete
  topology, so a (workload, parallel spec) prices directly on the network.

Ring steps are adjacent-pair transfers and are pinned ``single_path``: the
multi-ring schedule already IS the multipath structure, so re-splitting
them would double-count links.  A2A/P2P tasks use the router's policy.
"""

from __future__ import annotations

import itertools
import logging
import math
from dataclasses import dataclass, field

from ..core.multiring import (
    UnsupportedGridError,
    clique_decomposition,
    grid_ring_decomposition,
)
from ..core.topology import NDFullMesh
from ..core.traffic import ParallelSpec, TrafficTable, WorkloadSpec, analyze_traffic

log = logging.getLogger(__name__)

Ring = tuple[int, ...]


@dataclass(frozen=True)
class FlowTask:
    """One point-to-point message inside a collective schedule — or, when
    ``pairs`` is set, one *aggregate* of symmetric adjacent-pair sends
    (the parallel positions of one multi-ring step): ``size`` is then the
    per-pair byte count and ``src``/``dst`` name the representative first
    pair.  Aggregates execute as a single weighted flow
    (``FluidNetwork.add_aggregate_flow``) unless the run expands them
    (failure injection / parity checks)."""

    tid: int
    src: int
    dst: int
    size: float                       # bytes (per pair, for aggregates)
    deps: tuple[int, ...] = ()
    single_path: bool = False         # ring steps pin their direct link
    tag: str = ""
    pairs: tuple[tuple[int, int], ...] = ()   # () = plain point-to-point

    @property
    def n_flows(self) -> int:
        return len(self.pairs) or 1

    @property
    def total_bytes(self) -> float:
        return self.size * self.n_flows

    def endpoints(self) -> set[int]:
        """All nodes this task touches (aggregate-aware)."""
        if self.pairs:
            return {n for p in self.pairs for n in p}
        return {self.src, self.dst}


@dataclass
class FlowDAG:
    """A dependency DAG of transfers; completion = all tasks done."""

    name: str
    tasks: list[FlowTask] = field(default_factory=list)

    def _add(self, **kw) -> FlowTask:
        t = FlowTask(tid=len(self.tasks), **kw)
        self.tasks.append(t)
        return t

    @property
    def total_bytes(self) -> float:
        return sum(t.total_bytes for t in self.tasks)

    def frontier(self) -> tuple[int, ...]:
        """Tasks no other task depends on (the DAG's exit set)."""
        dep_of = {d for t in self.tasks for d in t.deps}
        return tuple(t.tid for t in self.tasks if t.tid not in dep_of)


# ---------------------------------------------------------------------------
# DAG transforms (mixed-granularity support)
# ---------------------------------------------------------------------------


def remap_dag(dag: FlowDAG, mapping) -> FlowDAG:
    """A copy of ``dag`` with every node id passed through ``mapping`` (a
    dict or a callable); sizes, deps, tags and single-path flags are
    preserved.  Lets a collective compile on a small standalone topology
    (e.g. the chip-level 2D rack mesh, where all the multi-ring / relay-A2A
    conventions already exist) and execute inside a larger one (the
    mixed-granularity coarse mesh, where that rack's chips sit at offset
    node ids)."""
    f = mapping.__getitem__ if isinstance(mapping, dict) else mapping
    out = FlowDAG(name=dag.name)
    for t in dag.tasks:
        out._add(
            src=f(t.src),
            dst=f(t.dst),
            size=t.size,
            deps=t.deps,
            single_path=t.single_path,
            tag=t.tag,
            pairs=tuple((f(u), f(v)) for u, v in t.pairs),
        )
    return out


def splice_dag(dag: FlowDAG, expand) -> FlowDAG:
    """Rewrite a super-node-granularity DAG onto a mixed-granularity mesh.

    ``expand(node)`` returns the member chip ids of a detail super-node
    (or ``None`` for nodes that exist as-is in the target mesh).  Every
    task pair with a detail endpoint is split across the members, each
    carrying ``1/len(members)`` of the pair's bytes — a rack-level send
    becomes its chips' trunk/uplink shares, the same unit conversion
    ``coarse_calibrated_profile`` applies to payloads.  A pair whose BOTH
    endpoints are detail racks pairs members index-to-index (the trunk's
    chip-to-chip lanes, paper Fig. 8-(d)).

    Aggregate ``FlowTask``s require symmetric members (one per-member
    size and one shared rate), so a spliced task splits into one task per
    SYMMETRY CLASS — (member count, src-side detail?, dst-side detail?) —
    all sharing the original task's deps; downstream tasks depend on
    every piece, preserving the ring-step barrier.  The class split
    matters for fidelity: a detail rack's inbound trunk shares (bounded
    by its chips' ejection ports) and outbound shares (bounded by their
    injection caps) can drain at different rates, and lumping them into
    one aggregate would pin the faster class at the slower class's rate
    for the whole step instead of letting it finish early.  The step
    barrier still completes at the slowest class, so under symmetric
    capacities spliced coarse runs stay aligned with pure-coarse ones
    (the pure aggregate also completes at its slowest member)."""
    out = FlowDAG(name=dag.name)
    tid_map: dict[int, tuple[int, ...]] = {}
    for t in dag.tasks:
        deps = tuple(nt for d in t.deps for nt in tid_map[d])
        groups: dict[tuple, list[tuple[int, int]]] = {}
        for (u, v) in (t.pairs or ((t.src, t.dst),)):
            eu, ev = expand(u), expand(v)
            if eu is None and ev is None:
                groups.setdefault((1, False, False), []).append((u, v))
            elif eu is not None and ev is not None:
                if len(eu) != len(ev):
                    raise ValueError(
                        f"detail super-nodes {u} and {v} have mismatched "
                        f"member counts ({len(eu)} vs {len(ev)})"
                    )
                groups.setdefault((len(eu), True, True), []).extend(
                    zip(eu, ev)
                )
            elif eu is not None:
                groups.setdefault((len(eu), True, False), []).extend(
                    (m, v) for m in eu
                )
            else:
                groups.setdefault((len(ev), False, True), []).extend(
                    (u, m) for m in ev
                )
        new_tids = []
        for (div, _su, _sv), pairs in sorted(groups.items()):
            nt = out._add(
                src=pairs[0][0],
                dst=pairs[0][1],
                size=t.size / div,
                deps=deps,
                single_path=t.single_path,
                tag=t.tag,
                pairs=tuple(pairs) if (len(pairs) > 1 or t.pairs) else (),
            )
            new_tids.append(nt.tid)
        tid_map[t.tid] = tuple(new_tids)
    return out


# ---------------------------------------------------------------------------
# clique helpers
# ---------------------------------------------------------------------------


def clique_nodes(
    topo: NDFullMesh, dim: int, fixed: dict[int, int] | None = None
) -> list[int]:
    """Node ids of one clique along ``dim`` (other coords from ``fixed``,
    defaulting to 0)."""
    fixed = dict(fixed or {})
    for i in range(topo.ndim):
        if i != dim:
            fixed.setdefault(i, 0)
    fixed.pop(dim, None)
    return topo.subgroup_nodes(fixed)


# ---------------------------------------------------------------------------
# ring-schedule compilers
# ---------------------------------------------------------------------------


def _ring_steps(
    dag: FlowDAG,
    nodes: list[int],
    rings: list[Ring],
    closed: bool,
    n_steps: int,
    chunk: float,
    deps0: tuple[int, ...],
    tag: str,
) -> None:
    """Unroll ``n_steps`` pipeline steps of every ring, ONE aggregate task
    per (ring, step).

    All positions of one ring step are symmetric — same chunk size, one
    flow per edge-disjoint ring link — so they start together, drain at
    the same max-min rate, and finish together.  The aggregate task
    carries every position's (sender, receiver) pair and depends only on
    the previous step's aggregate, which subsumes the per-position data
    dep (the chunk forwarded at step s arrived at step s-1) and port dep
    (each node serializes its own injections).  This collapses a clique
    collective from O(rings * steps * positions) tasks to
    O(rings * steps) while reproducing the per-position schedule's
    completion times exactly (the parity suite pins aggregate vs expanded
    runs against each other).

    Known coarsening of the DAG itself: under failure injection the run
    expands aggregates into per-pair sends but keeps the per-step barrier
    dep, so a slow rerouted pair stalls its whole ring step instead of
    propagating diagonally as the PR-3 per-position deps did — a slightly
    pessimistic recovery model (the ``netsim_failure`` benchmark guards
    it stays within sane bounds).
    """
    for r, ring in enumerate(rings):
        m = len(ring)
        prev: tuple[int, ...] = ()      # previous step's aggregate tid
        for s in range(n_steps):
            senders = range(m) if closed else range(m - 1)
            pairs = tuple(
                (nodes[ring[i]], nodes[ring[(i + 1) % m]]) for i in senders
            )
            t = dag._add(
                src=pairs[0][0],
                dst=pairs[0][1],
                size=chunk,
                deps=deps0 if s == 0 else prev,
                single_path=True,
                tag=f"{tag}/r{r}s{s}",
                pairs=pairs,
            )
            prev = (t.tid,)


def _ring_collective(
    topo: NDFullMesh,
    nodes: list[int],
    size_bytes: float,
    n_steps_fn,
    deps0: tuple[int, ...],
    dag: FlowDAG | None,
    tag: str,
) -> FlowDAG:
    dag = dag or FlowDAG(name=tag)
    n = len(nodes)
    if n < 2 or size_bytes <= 0:
        return dag
    rings, closed = clique_decomposition(n, verify=False)
    chunk = size_bytes / (max(1, len(rings)) * n)
    _ring_steps(dag, nodes, rings, closed, n_steps_fn(n), chunk, deps0, tag)
    return dag


def ring_allreduce(
    topo: NDFullMesh,
    nodes: list[int],
    size_bytes: float,
    *,
    deps0: tuple[int, ...] = (),
    dag: FlowDAG | None = None,
    tag: str = "allreduce",
) -> FlowDAG:
    """Multi-ring AllReduce over one clique's ``nodes``: 2(n-1) steps,
    per-ring chunk = size / (rings * n) — wire bytes per chip equal the
    analytic 2(n-1)/n * size."""
    return _ring_collective(
        topo, nodes, size_bytes, lambda n: 2 * (n - 1), deps0, dag, tag
    )


def ring_reduce_scatter(
    topo: NDFullMesh,
    nodes: list[int],
    size_bytes: float,
    *,
    deps0: tuple[int, ...] = (),
    dag: FlowDAG | None = None,
    tag: str = "rs",
) -> FlowDAG:
    """(n-1)-step half of the ring schedule; ``size_bytes`` is the per-node
    input (RS) or gathered output (AG) size, matching the cost model."""
    return _ring_collective(
        topo, nodes, size_bytes, lambda n: n - 1, deps0, dag, tag
    )


ring_all_gather = ring_reduce_scatter      # same wire schedule, reversed data


# ---------------------------------------------------------------------------
# cross-dim 2D multi-ring (rings spanning the (X, Y) plane jointly)
# ---------------------------------------------------------------------------


def grid_plane_nodes(
    topo: NDFullMesh, dims: tuple[int, int], *, base_node: int = 0
) -> list[int]:
    """Node ids of the 2D plane spanned by ``dims`` through ``base_node``,
    ordered so index ``i * shape[dims[1]] + j`` is the grid-local id."""
    base = list(topo.coords(base_node))
    nodes = []
    for i in range(topo.shape[dims[0]]):
        for j in range(topo.shape[dims[1]]):
            c = list(base)
            c[dims[0]] = i
            c[dims[1]] = j
            nodes.append(topo.node_id(c))
    return nodes


def _grid_collective(
    topo: NDFullMesh,
    dims: tuple[int, int],
    size_bytes: float,
    n_steps_fn,
    base_node: int,
    deps0: tuple[int, ...],
    dag: FlowDAG | None,
    tag: str,
) -> FlowDAG | None:
    try:
        rings = grid_ring_decomposition(
            topo.shape[dims[0]], topo.shape[dims[1]]
        )
    except UnsupportedGridError as e:
        log.info(
            "%s: no cross-dim grid rings for dims %s (%s); falling back to "
            "the per-dimension hierarchical schedule",
            tag, dims, e.reason,
        )
        return None
    dag = dag or FlowDAG(name=tag)
    if size_bytes <= 0:
        return dag
    nodes = grid_plane_nodes(topo, dims, base_node=base_node)
    n = len(nodes)
    chunk = size_bytes / (len(rings) * n)
    _ring_steps(dag, nodes, list(rings), True, n_steps_fn(n), chunk, deps0, tag)
    return dag


def grid_allreduce(
    topo: NDFullMesh,
    dims: tuple[int, int],
    size_bytes: float,
    *,
    base_node: int = 0,
    deps0: tuple[int, ...] = (),
    dag: FlowDAG | None = None,
    tag: str = "grid-ar",
) -> FlowDAG | None:
    """Single-phase AllReduce over the WHOLE (dims[0], dims[1]) plane on the
    cross-dim Hamiltonian rings: 2(n-1) steps over n = x*y nodes, every ring
    driving one X or Y link per node per step — both dimensions' links stay
    busy simultaneously, unlike the phase-per-dimension hierarchical
    schedule.  Returns ``None`` when no grid decomposition exists for this
    plane (callers fall back to ``hierarchical_allreduce``)."""
    return _grid_collective(
        topo, dims, size_bytes, lambda n: 2 * (n - 1), base_node, deps0, dag, tag
    )


def grid_all_gather(
    topo: NDFullMesh,
    dims: tuple[int, int],
    size_bytes: float,
    *,
    base_node: int = 0,
    deps0: tuple[int, ...] = (),
    dag: FlowDAG | None = None,
    tag: str = "grid-ag",
) -> FlowDAG | None:
    """(n-1)-step AllGather half of the cross-dim grid ring schedule."""
    return _grid_collective(
        topo, dims, size_bytes, lambda n: n - 1, base_node, deps0, dag, tag
    )


def all_to_all(
    topo: NDFullMesh,
    nodes: list[int],
    per_pair_bytes: float,
    *,
    deps0: tuple[int, ...] = (),
    dag: FlowDAG | None = None,
    tag: str = "a2a",
) -> FlowDAG:
    """Uniform A2A: one independent task per ordered pair; the router's
    policy supplies the Fig. 14 multi-path splitting."""
    dag = dag or FlowDAG(name=tag)
    for src, dst in itertools.permutations(nodes, 2):
        dag._add(src=src, dst=dst, size=per_pair_bytes, deps=deps0, tag=tag)
    return dag


def multipath_all_to_all(
    topo: NDFullMesh,
    nodes: list[int],
    per_pair_bytes: float,
    *,
    deps0: tuple[int, ...] = (),
    dag: FlowDAG | None = None,
    tag: str = "mp-a2a",
) -> FlowDAG:
    """Multi-Path A2A (§5.1, Fig. 14-(a)) with EXPLICIT relay hops.

    Each (src, dst) message whose coordinates differ in k ≥ 2 dimensions is
    split in half over the first and last dimension orders (X-then-Y /
    Y-then-X on a 2D plane), exactly the partitioning
    ``core/alltoall.multipath_a2a_loads`` prices analytically; same-clique
    pairs go direct.  Unlike :func:`all_to_all` — which hands whole
    messages to the router's path policy — every hop here is its own
    ``FlowTask`` chained by a data dep, so relays store-and-forward and the
    many-to-one bursts at relay and destination nodes are visible to the
    fluid model's receiver-egress (incast) caps.  Hops pin ``single_path``:
    the XY/YX split IS the multipath structure, re-splitting would
    double-count links.
    """
    dag = dag or FlowDAG(name=tag)
    for src, dst in itertools.permutations(nodes, 2):
        cs, cd = topo.coords(src), topo.coords(dst)
        diff = [i for i in range(topo.ndim) if cs[i] != cd[i]]
        orders = list(itertools.permutations(diff))
        chosen = [orders[0], orders[-1]] if len(orders) > 1 else orders[:1]
        share = per_pair_bytes / len(chosen)
        for o, order in enumerate(chosen):
            cur = list(cs)
            prev = src
            deps = deps0
            for d in order:
                cur[d] = cd[d]
                nxt = topo.node_id(cur)
                t = dag._add(
                    src=prev,
                    dst=nxt,
                    size=share,
                    deps=deps,
                    single_path=True,
                    tag=f"{tag}/o{o}",
                )
                deps = (t.tid,)
                prev = nxt
    return dag


def moe_dispatch(
    topo: NDFullMesh,
    senders: list[int],
    experts: list[int],
    bytes_per_sender: float,
    *,
    deps0: tuple[int, ...] = (),
    dag: FlowDAG | None = None,
    tag: str = "moe-dispatch",
) -> FlowDAG:
    """MoE token dispatch (Fig. 14-(b)): every sender ships its routed
    token tile, split uniformly, to the expert-owning nodes.  With more
    senders than experts this is a many-to-one burst — the pattern whose
    completion time the fluid model understates unless receiver-egress
    (incast) caps are enabled; combine is the same DAG with the roles
    swapped.  Tasks use the router's multi-path policy like
    :func:`all_to_all`."""
    dag = dag or FlowDAG(name=tag)
    remote = [e for e in experts]
    if not remote:
        return dag
    per_expert = bytes_per_sender / len(remote)
    for src in senders:
        for dst in remote:
            if src == dst:
                continue
            dag._add(src=src, dst=dst, size=per_expert, deps=deps0, tag=tag)
    return dag


def _cliques_of(
    topo: NDFullMesh,
    dim: int,
    dims: tuple[int, ...],
    sub_fixed: dict[int, int],
    dim_coords: dict[int, tuple[int, ...]] | None = None,
) -> list[list[int]]:
    """Every clique of ``dim`` inside the subgroup spanned by ``dims``.

    ``dim_coords`` restricts a dimension to a coordinate subset (a subset
    of a clique is still a clique), so a 16-chip TP group can span the
    full X clique but only 2 of the 8 Y boards.
    """

    def coords_for(d: int) -> tuple[int, ...]:
        if dim_coords and d in dim_coords:
            return tuple(dim_coords[d])
        return tuple(range(topo.shape[d]))

    others = [d for d in dims if d != dim]
    out = []
    for combo in itertools.product(*(coords_for(d) for d in others)):
        fixed = dict(sub_fixed)
        fixed.update(dict(zip(others, combo)))
        clique = clique_nodes(topo, dim, fixed)
        keep = set(coords_for(dim))
        out.append([n for n in clique if topo.coords(n)[dim] in keep])
    return out


def hierarchical_allreduce(
    topo: NDFullMesh,
    dims: tuple[int, ...],
    size_bytes: float,
    *,
    base_node: int = 0,
    dim_coords: dict[int, tuple[int, ...]] | None = None,
    dag: FlowDAG | None = None,
    tag: str = "hier-ar",
) -> FlowDAG:
    """RS up ``dims[:-1]``, AllReduce on ``dims[-1]``, AG back down — the
    cost model's hierarchical schedule on the subgroup of ``dims`` that
    contains ``base_node``, with phase barriers between dims.
    ``dim_coords`` narrows a dimension to a coordinate subset (partial-
    width groups like a 16-chip TP domain inside the 64-chip rack)."""
    dag = dag or FlowDAG(name=tag)
    base = topo.coords(base_node)
    sub_fixed = {i: base[i] for i in range(topo.ndim) if i not in dims}

    def width(d: int) -> int:
        return len(dim_coords[d]) if dim_coords and d in dim_coords else topo.shape[d]

    frontier: tuple[int, ...] = ()
    frac = size_bytes
    for phase, dim in enumerate(dims[:-1]):
        start = len(dag.tasks)
        for nodes in _cliques_of(topo, dim, dims, sub_fixed, dim_coords):
            ring_reduce_scatter(
                topo, nodes, frac, deps0=frontier, dag=dag,
                tag=f"{tag}/rs{phase}",
            )
        frontier = tuple(range(start, len(dag.tasks)))
        frac /= width(dim)
    start = len(dag.tasks)
    for nodes in _cliques_of(topo, dims[-1], dims, sub_fixed, dim_coords):
        ring_allreduce(topo, nodes, frac, deps0=frontier, dag=dag, tag=f"{tag}/ar")
    frontier = tuple(range(start, len(dag.tasks)))
    for phase, dim in enumerate(reversed(dims[:-1])):
        frac *= width(dim)
        start = len(dag.tasks)
        for nodes in _cliques_of(topo, dim, dims, sub_fixed, dim_coords):
            ring_all_gather(
                topo, nodes, frac, deps0=frontier, dag=dag,
                tag=f"{tag}/ag{phase}",
            )
        frontier = tuple(range(start, len(dag.tasks)))
    return dag


def hierarchical_all_gather(
    topo: NDFullMesh,
    dims: tuple[int, ...],
    size_bytes: float,
    *,
    base_node: int = 0,
    dim_coords: dict[int, tuple[int, ...]] | None = None,
    dag: FlowDAG | None = None,
    tag: str = "hier-ag",
) -> FlowDAG:
    """AG fast dim first, growing the gathered tile each phase;
    ``size_bytes`` is the final gathered size per node."""
    dag = dag or FlowDAG(name=tag)
    base = topo.coords(base_node)
    sub_fixed = {i: base[i] for i in range(topo.ndim) if i not in dims}

    def width(d: int) -> int:
        return len(dim_coords[d]) if dim_coords and d in dim_coords else topo.shape[d]

    span = math.prod(width(d) for d in dims)
    frac = size_bytes / span
    frontier: tuple[int, ...] = ()
    for phase, dim in enumerate(dims):
        frac *= width(dim)
        start = len(dag.tasks)
        for nodes in _cliques_of(topo, dim, dims, sub_fixed, dim_coords):
            ring_all_gather(
                topo, nodes, frac, deps0=frontier, dag=dag,
                tag=f"{tag}/ag{phase}",
            )
        frontier = tuple(range(start, len(dag.tasks)))
    return dag


# ---------------------------------------------------------------------------
# traffic-table compilation (core/traffic.py -> DAGs)
# ---------------------------------------------------------------------------


def model_group(topo: NDFullMesh, width: int) -> list[int]:
    """A representative TP/SP group: one X clique widened across Y boards
    until ``width`` chips (the intra-rack high-bandwidth domain)."""
    x = topo.shape[0]
    boards = max(1, min(topo.shape[1] if topo.ndim > 1 else 1, -(-width // x)))
    nodes: list[int] = []
    for y in range(boards):
        nodes.extend(clique_nodes(topo, 0, {1: y} if topo.ndim > 1 else None))
    return nodes[:width]


def compile_traffic_entry(
    topo: NDFullMesh,
    technique: str,
    per_transfer_bytes: float,
    p: ParallelSpec,
) -> FlowDAG:
    """One transfer of one Table-1 technique as a flow DAG on ``topo``."""
    x = topo.shape[0]
    if technique in ("TP", "SP"):
        group = model_group(topo, p.tp * p.sp)
        if len(group) <= x:
            fn = ring_allreduce if technique == "TP" else ring_all_gather
            return fn(topo, group, per_transfer_bytes, tag=technique)
        boards = -(-len(group) // x)
        if topo.ndim > 1 and boards == topo.shape[1]:
            # full (X, Y) plane: cross-dim 2D multi-ring when available
            grid_fn = grid_allreduce if technique == "TP" else grid_all_gather
            dag = grid_fn(topo, (0, 1), per_transfer_bytes, tag=technique)
            if dag is not None:
                return dag
        # partial-width group: full X clique x only the Y boards in use
        coords = {0: tuple(range(x)), 1: tuple(range(boards))}
        fn = (
            hierarchical_allreduce if technique == "TP"
            else hierarchical_all_gather
        )
        return fn(
            topo, (0, 1), per_transfer_bytes, dim_coords=coords, tag=technique
        )
    if technique == "EP":
        group = model_group(topo, min(p.ep * 2, 2 * x))
        per_pair = per_transfer_bytes / max(1, len(group) - 1)
        return all_to_all(topo, group, per_pair, tag="EP")
    if technique == "PP":
        # boundary activations hop to the next rack (first inter-rack dim)
        dag = FlowDAG(name="PP")
        inter = 2 if topo.ndim > 2 else topo.ndim - 1
        peers = clique_nodes(topo, inter)
        peer = peers[1] if len(peers) > 1 else 0
        dag._add(src=0, dst=peer, size=per_transfer_bytes, tag="PP")
        return dag
    if technique == "DP":
        dims = tuple(range(2, topo.ndim)) if topo.ndim > 2 else (topo.ndim - 1,)
        if len(dims) == 2:
            dag = grid_allreduce(topo, dims, per_transfer_bytes, tag="DP")
            if dag is not None:
                return dag
        return hierarchical_allreduce(topo, dims, per_transfer_bytes, tag="DP")
    raise ValueError(f"unknown technique {technique}")


def compile_workload(
    topo: NDFullMesh, w: WorkloadSpec, p: ParallelSpec
) -> dict[str, tuple[FlowDAG, float]]:
    """technique -> (one-transfer DAG, effective transfer count).

    Each technique is compiled once at its largest per-transfer volume; the
    effective count scales the simulated single-transfer time back to the
    technique's total bytes (SP's two size classes fold into one)."""
    table: TrafficTable = analyze_traffic(w, p)
    vols: dict[str, float] = {}
    totals: dict[str, float] = {}
    for e in table.entries:
        vol = e.volume_per_transfer
        if e.technique == "EP":
            vol *= p.ep                # ledger stores the per-peer chunk
        vols[e.technique] = max(vols.get(e.technique, 0.0), vol)
        totals[e.technique] = totals.get(e.technique, 0.0) + (
            e.total_bytes * (p.ep if e.technique == "EP" else 1)
        )
    out: dict[str, tuple[FlowDAG, float]] = {}
    for tech, vol in vols.items():
        out[tech] = (
            compile_traffic_entry(topo, tech, vol, p),
            totals[tech] / vol,
        )
    return out
