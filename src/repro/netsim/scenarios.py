"""Canonical netsim scenarios shared by benchmarks and tests.

Keeping these in the package (rather than duplicated in
``benchmarks/netsim_bench.py`` and ``tests/test_netsim.py``) means the
benchmark and the regression test always validate the *same* traffic
pattern.
"""

from __future__ import annotations

from typing import NamedTuple

from ..core.topology import ACTIVE_ELECTRICAL, DimSpec, NDFullMesh, OPTICAL_100M
from .collectives import FlowDAG


def inter_rack_mesh(z: int = 4, a: int = 4) -> NDFullMesh:
    """Rack-level 2D-FullMesh: the (Z, A) inter-rack fabric of one pod."""
    return NDFullMesh(
        dims=(
            DimSpec("Z", z, ACTIVE_ELECTRICAL, 2),
            DimSpec("A", a, OPTICAL_100M, 2),
        )
    )


def hotspot_dag(topo: NDFullMesh, size: float = 8e6) -> FlowDAG:
    """Cross-rack hotspot: in every row a, rack (0,a) sends to (1, a+k) for
    k=0..2 — the three dimension-ordered paths collide on link
    (0,a)->(1,a) while other links idle.  Multipath routes around it, which
    is what separates the §6.3 strategies (Fig. 19 ordering)."""
    dag = FlowDAG(name="hotspot")
    for a in range(topo.shape[1]):
        for k in range(3):
            dag._add(
                src=topo.node_id((0, a)),
                dst=topo.node_id((1, (a + k) % topo.shape[1])),
                size=size,
                tag=f"h{a}.{k}",
            )
    return dag


class TrunkCongestion(NamedTuple):
    """One trunk-congestion scenario: run ``dag`` on ``topo`` with
    ``rx_gbs`` and watch ``hot_link``."""

    topo: NDFullMesh
    dag: FlowDAG
    hot_link: tuple[int, int]            # the trunk every shortest path shares
    rx_gbs: float                        # receiver-egress cap to run with


def trunk_congestion(
    z: int = 4, a: int = 4, size: float = 32e6, fan: int = 3
) -> TrunkCongestion:
    """Fig. 19 in miniature, built to make the congested trunk *visible*
    in telemetry: rack (0,0) sends ``fan`` transfers to (1,1)..(1,fan) —
    never to (1,0) directly — so every dimension-ordered shortest path
    funnels through the single Z-trunk (0,0)->(1,0) while the A-dim
    links sit idle.

    The returned ``rx_gbs`` (half the trunk's per-peer bandwidth) makes
    the strategies separate cleanly in *peak trunk utilization*, not just
    throughput: under SHORTEST the trunk carries all ``fan`` transfers
    and saturates (peak 1.0; per-flow share trunk/fan < rx, so bottleneck
    attribution names the trunk); under DETOUR/BORROW each transfer
    splits over ~4 APR paths and the rx cap binds every subflow at
    rx/4 — the trunk then carries only ~fan * rx/4, measurably below
    capacity.
    """
    if z < 2 or fan < 1 or fan > a - 1:
        raise ValueError(
            f"need z >= 2 and 1 <= fan <= a-1 (got z={z}, a={a}, fan={fan})"
        )
    topo = inter_rack_mesh(z, a)
    dag = FlowDAG(name="trunk-congestion")
    src = topo.node_id((0, 0))
    for k in range(1, fan + 1):
        dag._add(
            src=src, dst=topo.node_id((1, k)), size=size, tag=f"tc{k}"
        )
    return TrunkCongestion(
        topo=topo,
        dag=dag,
        hot_link=(src, topo.node_id((1, 0))),
        rx_gbs=topo.dims[0].gbs_per_peer / 2,
    )
