"""Canonical netsim scenarios shared by benchmarks and tests.

Keeping these in the package (rather than duplicated in
``benchmarks/netsim_bench.py`` and ``tests/test_netsim.py``) means the
benchmark and the regression test always validate the *same* traffic
pattern.
"""

from __future__ import annotations

from ..core.topology import ACTIVE_ELECTRICAL, DimSpec, NDFullMesh, OPTICAL_100M
from .collectives import FlowDAG


def inter_rack_mesh(z: int = 4, a: int = 4) -> NDFullMesh:
    """Rack-level 2D-FullMesh: the (Z, A) inter-rack fabric of one pod."""
    return NDFullMesh(
        dims=(
            DimSpec("Z", z, ACTIVE_ELECTRICAL, 2),
            DimSpec("A", a, OPTICAL_100M, 2),
        )
    )


def hotspot_dag(topo: NDFullMesh, size: float = 8e6) -> FlowDAG:
    """Cross-rack hotspot: in every row a, rack (0,a) sends to (1, a+k) for
    k=0..2 — the three dimension-ordered paths collide on link
    (0,a)->(1,a) while other links idle.  Multipath routes around it, which
    is what separates the §6.3 strategies (Fig. 19 ordering)."""
    dag = FlowDAG(name="hotspot")
    for a in range(topo.shape[1]):
        for k in range(3):
            dag._add(
                src=topo.node_id((0, a)),
                dst=topo.node_id((1, (a + k) % topo.shape[1])),
                size=size,
                tag=f"h{a}.{k}",
            )
    return dag
