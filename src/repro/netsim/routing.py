"""APR adapter: core/apr path sets -> per-flow multi-path splits (layer 3).

Converts the planner-side APR machinery (``core/apr.py``) into executable
routing for the fluid network:

* **Shortest** — the single dimension-ordered shortest path (baseline
  Fig. 10-(a)); on failure, falls back to any surviving APR path.
* **Detour** — a link-disjoint subset of the TFC-admissible all-path set
  (shortest permutations + single-relay detours, §4.1); a transfer's bytes
  are split across the paths with congestion-aware weights.
* **Borrow** — Detour plus one switch-assisted path through a virtual
  LRS/HRS node attached to every NPU at ``borrow_gbs`` per uplink (§6.3).

Congestion awareness: the split weight of a path is its estimated residual
bottleneck bandwidth (capacity divided by one more than the flows already
on each link).  When one subflow finishes while its siblings lag, the
transfer *re-splits* the remaining bytes over all its paths — the fluid
analogue of APR's congestion-aware path (re)selection.

Failure handling is the paper's direct-notification fast recovery (§4.2):
``fail_link`` stalls the crossing flows immediately, and after a
notification delay proportional to the endpoint->source hop distance the
affected transfers re-split their remaining bytes over surviving paths.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from ..core.apr import Path, all_paths, shortest_paths, tfc_admissible
from ..core.cost_model import Routing
from ..core.topology import NDFullMesh
from .flows import Flow, FluidNetwork

_EPS = 1e-6


@dataclass
class Transfer:
    """One logical src->dst message, possibly split over several paths."""

    tid: int
    src: int
    dst: int
    size: float
    on_complete: Callable[["Transfer"], None] | None = None
    meta: object = None
    single_path: bool = False       # collective ring steps pin one path
    subflows: dict[int, Flow] = field(default_factory=dict)
    delivered: float = 0.0
    resplits: int = 0
    done: bool = False
    start_s: float = 0.0
    end_s: float | None = None

    @property
    def remaining(self) -> float:
        return max(0.0, self.size - self.delivered)


class Router:
    """Maps transfers onto APR paths over a FluidNetwork."""

    MAX_PATHS = 4           # split fan-out cap (Fig. 14 uses 2; APR allows more)
    MAX_RESPLITS = 8        # per transfer, guards event inflation

    def __init__(
        self,
        net: FluidNetwork,
        policy: Routing = Routing.DETOUR,
        *,
        borrow_gbs: float = 50.0,
        notify_latency_s: float = 1e-6,
        adaptive: bool = True,
    ) -> None:
        self.net = net
        self.topo: NDFullMesh = net.topo
        self.policy = policy
        self.notify_latency_s = notify_latency_s
        self.adaptive = adaptive
        self.transfers: dict[int, Transfer] = {}
        self._next_tid = 0
        # APR path sets are pure functions of (src, dst, policy) while the
        # failed-link set is unchanged; memoizing them removes the dominant
        # per-send cost of large collective DAG runs (invalidated by
        # ``fail_link``)
        self._path_cache: dict[tuple[int, int, bool], list[Path]] = {}
        self.switch_node: int | None = None
        if policy == Routing.BORROW:
            # virtual switch plane: one hop up, one hop down, per-NPU uplink
            self.switch_node = self.topo.num_nodes
            for u in range(self.topo.num_nodes):
                net.add_link(u, self.switch_node, borrow_gbs)

    # -- path sets ---------------------------------------------------------
    # An NDFullMesh gets core/apr's coordinate-based enumeration plus TFC
    # admission.  A topology carrying its own ``apr_shortest_paths`` /
    # ``apr_all_paths`` (the mixed-granularity coarse meshes, which are
    # NOT Hamming graphs) supplies graph-generic BFS path sets instead;
    # those are simple loop-free paths by construction, and TFC's VL
    # rules need dimension-ordered hops, so they are used as-is.
    def _shortest_set(self, src: int, dst: int) -> list[Path]:
        fn = getattr(self.topo, "apr_shortest_paths", None)
        if fn is not None:
            return fn(src, dst)
        return shortest_paths(self.topo, src, dst)

    def _all_path_set(self, src: int, dst: int) -> list[Path]:
        fn = getattr(self.topo, "apr_all_paths", None)
        if fn is not None:
            return fn(src, dst)
        return all_paths(self.topo, src, dst)

    def _admissible_set(self, src: int, dst: int) -> list[Path]:
        fn = getattr(self.topo, "apr_all_paths", None)
        if fn is not None:
            return fn(src, dst)
        return [
            p
            for p, _ in tfc_admissible(
                self.topo, all_paths(self.topo, src, dst)
            )
        ]

    def _alive(self, p: Path) -> bool:
        return all(self.net.link_ok(u, v) for u, v in zip(p, p[1:]))

    def candidate_paths(self, src: int, dst: int, *, single: bool = False) -> list[Path]:
        """APR path set for (src, dst) under the active policy, skipping
        failed links.  ``single`` pins one path (ring-schedule steps).
        Memoized per (src, dst, single) until a link fails."""
        key = (src, dst, single)
        cached = self._path_cache.get(key)
        if cached is not None:
            return cached
        paths = self._candidate_paths(src, dst, single)
        self._path_cache[key] = paths
        return paths

    def _candidate_paths(self, src: int, dst: int, single: bool) -> list[Path]:
        if src == dst:
            return [(src,)]
        sp = [p for p in self._shortest_set(src, dst) if self._alive(p)]
        if single or self.policy == Routing.SHORTEST:
            if sp:
                return [sp[0]]      # first permutation == dimension-ordered
            # fast recovery: any surviving APR path
            for p in self._all_path_set(src, dst):
                if self._alive(p):
                    return [p]
            raise RuntimeError(f"no surviving path {src}->{dst}")
        adm = [p for p in self._admissible_set(src, dst) if self._alive(p)]
        # greedy link-disjoint subset, shortest first (path_diversity's rule)
        chosen: list[Path] = []
        used: set[tuple[int, int]] = set()
        for p in sorted(adm, key=len):
            edges = {tuple(sorted(e)) for e in zip(p, p[1:])}
            if edges & used:
                continue
            chosen.append(p)
            used |= edges
            if len(chosen) >= self.MAX_PATHS:
                break
        if not chosen and adm:
            chosen = [adm[0]]
        if self.policy == Routing.BORROW and self.switch_node is not None:
            chosen = chosen[: self.MAX_PATHS - 1] + [
                (src, self.switch_node, dst)
            ]
        if not chosen:
            raise RuntimeError(f"no surviving path {src}->{dst}")
        return chosen

    def _weights(self, paths: list[Path]) -> list[float]:
        """Congestion-aware split: residual bottleneck bandwidth per path."""
        counts: dict[tuple[int, int], int] = {}
        for f in self.net.flows.values():
            for l in f.links:
                counts[l] = counts.get(l, 0) + 1
        ws = []
        for p in paths:
            bn = min(
                self.net.effective_capacity(l) / (counts.get(l, 0) + 1)
                for l in zip(p, p[1:])
            )
            ws.append(max(bn, 0.0))
        if sum(ws) <= 0:
            ws = [1.0] * len(paths)
        return ws

    # -- transfers ---------------------------------------------------------
    def send(
        self,
        src: int,
        dst: int,
        size: float,
        on_complete: Callable[[Transfer], None] | None = None,
        *,
        single_path: bool = False,
        meta: object = None,
    ) -> Transfer:
        t = Transfer(
            tid=self._next_tid,
            src=src,
            dst=dst,
            size=float(size),
            on_complete=on_complete,
            meta=meta,
            single_path=single_path,
            start_s=self.net.engine.now,
        )
        self._next_tid += 1
        self.transfers[t.tid] = t
        if self.net.telemetry is not None:
            self.net.telemetry.router_counters["transfers"] += 1
        if src == dst or size <= _EPS:
            self._finish(t)
            return t
        self._launch(t, t.size)
        return t

    def _launch(self, t: Transfer, nbytes: float) -> None:
        paths = self.candidate_paths(t.src, t.dst, single=t.single_path)
        if self.net.telemetry is not None:
            self.net.telemetry.record_launch(paths, self.switch_node)
        # a single path needs no congestion weighting (it normalizes out),
        # and collective ring steps are all single-path — skipping the
        # all-active-flows link census there makes large multi-ring DAG
        # runs ~2.5x faster
        ws = [1.0] if len(paths) == 1 else self._weights(paths)
        tot = sum(ws)
        for p, w in zip(paths, ws):
            share = nbytes * w / tot
            if share <= _EPS:
                continue
            f = self.net.add_flow(p, share, self._on_subflow_done, meta=t)
            if not f.done:
                t.subflows[f.fid] = f
            else:
                t.delivered += f.size
        if not t.subflows:
            if t.remaining <= _EPS:
                self._finish(t)
            elif nbytes > 0:
                # every per-path share fell below _EPS (a tiny re-split
                # remainder over many paths): push it all down one path so
                # the transfer cannot strand sub-_EPS residuals forever
                f = self.net.add_flow(
                    paths[0], nbytes, self._on_subflow_done, meta=t
                )
                if not f.done:
                    t.subflows[f.fid] = f

    def _withdraw(self, t: Transfer) -> float:
        """Pull all of a transfer's live subflows off the network.

        Returns the un-sent byte count and credits the partial progress to
        ``delivered``, clamped so delivered + left == size exactly (the
        per-flow tallies carry float error that must not double-count)."""
        left = 0.0
        for f in list(t.subflows.values()):
            left += self.net.remove_flow(f)
            t.delivered += f.size - max(0.0, f.remaining)
            del t.subflows[f.fid]
        t.delivered = min(t.delivered, t.size - left)
        return left

    def _on_subflow_done(self, flow: Flow) -> None:
        t: Transfer = flow.meta
        t.subflows.pop(flow.fid, None)
        t.delivered += flow.size
        if not t.subflows:
            if t.remaining <= _EPS:
                self._finish(t)
            else:
                # a partial launch skipped sub-_EPS shares and the launched
                # subflows are all done: resend the stranded residual so
                # the transfer (and its DAG dependents) cannot stall
                self._launch(t, t.remaining)
            return
        if (
            self.adaptive
            and not t.single_path
            and t.resplits < self.MAX_RESPLITS
        ):
            # a path freed up: re-split the laggards' remaining bytes over
            # the full path set (congestion-aware), the APR re-balance
            t.resplits += 1
            if self.net.telemetry is not None:
                self.net.telemetry.router_counters["resplits"] += 1
            left = self._withdraw(t)
            if left <= _EPS:
                self._finish(t)
                return
            self._launch(t, left)

    def _finish(self, t: Transfer) -> None:
        if t.done:
            return
        t.done = True
        t.delivered = t.size
        t.end_s = self.net.engine.now
        if self.net.telemetry is not None:
            self.net.telemetry.record_transfer_done(t)
        if t.on_complete:
            t.on_complete(t)

    # -- failure handling (paper §4.2, direct notification) ----------------
    def fail_link(self, u: int, v: int) -> dict:
        """Fail u-v now; schedule per-source direct-notification reroutes.

        Returns {affected_transfers, notified_sources, max_notify_hops}.
        """
        self._path_cache.clear()
        hit_flows = self.net.fail_link(u, v)
        hit: dict[int, Transfer] = {}
        for f in hit_flows:
            if isinstance(f.meta, Transfer):
                hit[f.meta.tid] = f.meta
        notify_hops: dict[int, int] = {}
        for t in hit.values():
            hops = min(
                self.topo.hop_distance(u, t.src),
                self.topo.hop_distance(v, t.src),
            )
            notify_hops[t.src] = max(notify_hops.get(t.src, 0), hops)
            delay = max(1, hops) * self.notify_latency_s
            self.net.engine.schedule(delay, lambda tr=t: self._reroute(tr))
        stats = {
            "affected_transfers": len(hit),
            "notified_sources": len(notify_hops),
            "max_notify_hops": max(notify_hops.values(), default=0),
        }
        if self.net.telemetry is not None:
            self.net.telemetry.record_instant(
                "link_failures", {"link": [u, v], **stats}
            )
        return stats

    def _reroute(self, t: Transfer) -> None:
        if t.done:
            return
        if self.net.telemetry is not None:
            self.net.telemetry.record_instant(
                "reroutes",
                {"tid": t.tid, "src": t.src, "dst": t.dst,
                 "remaining": t.remaining},
            )
        left = self._withdraw(t)
        if left <= _EPS:
            self._finish(t)
            return
        self._launch(t, left)
