"""NetSim facade (netsim layer 5).

``NetSim`` glues the layers together: it builds a fresh ``FluidNetwork`` +
``Router`` per run, executes a collective ``FlowDAG`` (tasks start when
their deps complete, plus one per-step hop latency), and returns a
``NetSimResult`` with per-link utilization, per-transfer completion times
and collective completion times.

Cross-validation contract (enforced by tests and the ``netsim_*``
benchmarks): on an uncongested single-dimension clique the simulated
multi-ring AllReduce time matches the analytic
``MultiRingPlan.allreduce_time_s`` / ``CommModel.allreduce`` within 15%,
and under cross-rack contention the §6.3 strategies rank
Shortest < Detour < Borrow in throughput (Fig. 19 ordering).

``calibrated_axis_gbs`` closes the loop back to the analytic stack: it
measures the *effective* per-chip collective bandwidth of each logical
mesh axis from a netsim run, in the exact units ``CommModel`` carries —
``core.perf_model.NetsimPerfModel`` memoizes these measurements per
(axis, group-width, routing) key and serves them to the planner.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from ..core.cost_model import (
    A2A_CALIBRATION_MAX_NODES,
    COLLECTIVE_SHAPES,
    LATENCY_SHAPES,
    CalibrationProfile,
    CommModel,
    LatencyProfile,
    LatencyStats,
    Routing,
)
from ..core.topology import DimSpec, NDFullMesh, PASSIVE_ELECTRICAL, ub_mesh_pod
from ..core.traffic import ParallelSpec, WorkloadSpec
from .collectives import (
    FlowDAG,
    model_group,
    clique_nodes,
    compile_workload,
    grid_all_gather,
    grid_allreduce,
    grid_plane_nodes,
    hierarchical_all_gather,
    hierarchical_allreduce,
    multipath_all_to_all,
    remap_dag,
    ring_all_gather,
    ring_allreduce,
)
from .events import EventEngine
from .flows import FluidNetwork, default_rx_gbs
from .routing import Router, Transfer
from .telemetry import Telemetry


@dataclass
class NetSimResult:
    """Outcome of one netsim run."""

    name: str
    makespan_s: float
    task_end_s: dict[int, float]                   # task tid -> completion
    link_utilization: dict[tuple[int, int], float]
    bytes_delivered: float
    events: int
    collective_s: dict[str, float] = field(default_factory=dict)
    transfer_counts: dict[str, float] = field(default_factory=dict)
    incomplete: int = 0                            # tasks never finished
    failure_stats: dict = field(default_factory=dict)   # from Router.fail_link
    # message-level runs only: per-task ready-to-complete latency
    # (queueing-inclusive) — the raw samples behind a LatencyProfile
    task_latency_s: dict[int, float] = field(default_factory=dict)
    # the run's Telemetry recorder when the NetSim was built with
    # ``telemetry=True`` (None otherwise; see netsim/telemetry.py)
    telemetry: "Telemetry | None" = None

    @property
    def max_link_utilization(self) -> float:
        return max(self.link_utilization.values(), default=0.0)

    @property
    def iteration_comm_s(self) -> float:
        """Sum of per-technique times scaled by their transfer counts."""
        return sum(
            t * self.transfer_counts.get(k, 1.0)
            for k, t in self.collective_s.items()
        )


class _DagRun:
    """Executes one FlowDAG on a Router with per-step latency.

    Aggregate tasks (``task.pairs``) normally run as one weighted flow
    (``FluidNetwork.add_aggregate_flow``); with ``aggregate=False`` — the
    failure-injection path, where per-flow APR rerouting must stay live —
    they are expanded into one routed send per pair and the task completes
    when the last pair does.
    """

    def __init__(
        self,
        router: Router,
        dag: FlowDAG,
        latency_s: float,
        *,
        aggregate: bool = True,
    ):
        self.router = router
        self.dag = dag
        self.latency_s = latency_s
        self.aggregate = aggregate
        self.end_s: dict[int, float] = {}
        self.children: dict[int, list[int]] = {}
        self.indeg: dict[int, int] = {}
        self.fanout: dict[int, int] = {}    # expanded aggregates: sends left
        for t in dag.tasks:
            self.indeg[t.tid] = len(t.deps)
            for d in t.deps:
                self.children.setdefault(d, []).append(t.tid)

    def start(self) -> None:
        for t in self.dag.tasks:
            if self.indeg[t.tid] == 0:
                self._launch(t.tid)

    def _launch(self, tid: int) -> None:
        self.router.net.engine.schedule(
            self.latency_s, lambda: self._send(tid)
        )

    def _send(self, tid: int) -> None:
        task = self.dag.tasks[tid]
        tel = self.router.net.telemetry
        if tel is not None:
            tel.task_labels[tid] = task.tag or f"task{tid}"
        if task.pairs and self.aggregate:
            self.router.net.add_aggregate_flow(
                task.pairs,
                task.size,
                on_complete=lambda f, tid=tid: self._done(tid),
                meta=("task", tid),
            )
            return
        if task.pairs:
            # expanded aggregate: per-pair routed sends, countdown to done
            self.fanout[tid] = len(task.pairs)
            for src, dst in task.pairs:
                self.router.send(
                    src,
                    dst,
                    task.size,
                    on_complete=lambda tr, tid=tid: self._pair_done(tid),
                    single_path=task.single_path,
                    meta=("task", tid),
                )
            return
        self.router.send(
            task.src,
            task.dst,
            task.size,
            on_complete=lambda tr, tid=tid: self._done(tid),
            single_path=task.single_path,
            meta=("task", tid),
        )

    def _pair_done(self, tid: int) -> None:
        self.fanout[tid] -= 1
        if self.fanout[tid] == 0:
            self._done(tid)

    def _done(self, tid: int) -> None:
        self.end_s[tid] = self.router.net.engine.now
        for c in self.children.get(tid, ()):
            self.indeg[c] -= 1
            if self.indeg[c] == 0:
                self._launch(c)


# bump whenever calibration *semantics* change — DAG builders, wire-byte
# normalization, rx/IO-cap conventions — anything that can shift a measured
# bandwidth without the topology or solver changing.  Part of the
# persistent calibration cache key (core/calib_cache.py).
CALIBRATION_SCHEMA_VERSION = 1


class NetSim:
    """Flow-level discrete-event simulator of an nD-FullMesh network."""

    def __init__(
        self,
        topo: NDFullMesh | None = None,
        *,
        routing: Routing = Routing.DETOUR,
        borrow_gbs: float = 50.0,
        latency_s: float = 1e-6,
        adaptive: bool = True,
        record_rates: bool = False,
        rx_gbs: float | str | None = "auto",
        dim_io_gbs: dict[int, float] | None = None,
        solver: str = "vectorized",
        aggregate: bool = True,
        axis_dims: dict[str, tuple[int, ...]] | None = None,
        telemetry: bool = False,
        reuse_wire_template: bool = True,
        failed_links: "tuple[tuple[int, int], ...]" = (),
        message_level: bool = False,
        dim_latency_s: dict[int, float] | None = None,
    ) -> None:
        self.topo = topo or ub_mesh_pod()
        self.routing = routing
        self.borrow_gbs = borrow_gbs
        self.latency_s = latency_s
        self.adaptive = adaptive
        self.record_rates = record_rates
        # receiver-egress (incast) cap: "auto" sizes it at the node's
        # largest per-dimension clique allocation; None disables it.  A
        # topology carrying per-node ejection bandwidths (``node_rx_gbs``
        # — mixed-granularity meshes, where a chip and a rack super-node
        # differ by ~30x) hands "auto" a per-node dict instead.
        if rx_gbs == "auto":
            node_rx = getattr(self.topo, "node_rx_gbs", None)
            self.rx_gbs: float | dict[int, float] | None = (
                dict(node_rx) if node_rx is not None
                else default_rx_gbs(self.topo)
            )
        else:
            self.rx_gbs = rx_gbs
        # per-dim per-node IO caps (switched tiers, see flows.dim_io_gbs)
        self.dim_io_gbs = dim_io_gbs
        # "vectorized" numpy water-filling (default) or the pure-Python
        # "reference" oracle (netsim/solver.py)
        self.solver = solver
        # run multi-ring steps as aggregate flows; automatically expanded
        # per pair on failure-injection runs (APR reroute needs per-flow
        # paths)
        self.aggregate = aggregate
        # logical-axis -> topology-dims override (rack-coarsened meshes lay
        # their axes out differently from the pod convention)
        self.axis_dims = axis_dims
        # record a Telemetry per run (utilization timelines, bottleneck
        # attribution, router counters; exported via
        # NetSimResult.telemetry.summary()/to_perfetto())
        self.telemetry = telemetry
        # False rebuilds the wire-capacity dicts per run instead of using
        # the per-topology template cache (flows._WIRE_TEMPLATES) — only
        # the throughput benchmark's pre-cache baseline wants this
        self.reuse_wire_template = reuse_wire_template
        # links dead from t=0 in EVERY run of this sim — the degraded-mesh
        # repricing hook: calibration DAGs route around them through the
        # live APR machinery (candidate paths skip dead links), so a
        # ``calibrated_profile`` on a failed-link NetSim measures the
        # post-reroute bandwidth of the degraded fabric.  Aggregate ring
        # steps are force-expanded per pair (reroute needs per-flow paths)
        # and batched calibration is disabled (a failure breaks the
        # translation symmetry relocation relies on).
        self.failed_links = tuple(tuple(l) for l in failed_links)
        # message-level latency mode (netsim/messages.py): DAG tasks become
        # store-and-forward messages — per-hop serialization + propagation +
        # FIFO queueing replace both the fluid rate sharing AND the flat
        # per-task launch delay.  Off (the default) leaves the fluid code
        # path completely untouched: bit-identical to a sim built without
        # the flag.  ``dim_latency_s`` optionally overrides the per-hop
        # latency per topology dimension (default: ``latency_s`` flat).
        self.message_level = message_level
        self.dim_latency_s = dict(dim_latency_s or {})
        if message_level and self.failed_links:
            raise ValueError(
                "message_level does not support failed_links: failure "
                "injection and APR reroute are fluid-mode features"
            )
        self.last_network: FluidNetwork | None = None   # post-run inspection
        self.last_telemetry: Telemetry | None = None

    # -- plumbing ----------------------------------------------------------
    def _fresh(self) -> Router:
        tel = Telemetry() if self.telemetry else None
        self.last_telemetry = tel
        net = FluidNetwork(
            self.topo,
            EventEngine(),
            record_rates=self.record_rates,
            rx_gbs=self.rx_gbs,
            dim_io_gbs=self.dim_io_gbs,
            solver=self.solver,
            telemetry=tel,
            reuse_wire_template=self.reuse_wire_template,
        )
        for u, v in self.failed_links:
            net.fail_link(u, v)         # dead from t=0; no flows exist yet
        return Router(
            net,
            self.routing,
            borrow_gbs=self.borrow_gbs,
            notify_latency_s=self.latency_s,
            adaptive=self.adaptive,
        )

    # -- primitive runs ----------------------------------------------------
    def run_dag(
        self,
        dag: FlowDAG,
        *,
        fail_link: tuple[int, int] | None = None,
        fail_at_s: float = 0.0,
        name: str | None = None,
    ) -> NetSimResult:
        """Execute a flow DAG; optionally fail one physical link mid-run.

        Aggregate ring-step tasks run as single weighted flows unless a
        failure is injected (or the NetSim was built with
        ``aggregate=False``), in which case they expand into per-pair
        routed sends so APR rerouting stays per-flow."""
        if self.message_level:
            if fail_link is not None:
                raise ValueError(
                    "message_level does not support fail_link injection"
                )
            return self._run_dags_messages([dag], names=[name])[0]
        router = self._fresh()
        net = router.net
        use_agg = self.aggregate and fail_link is None and not self.failed_links
        run = _DagRun(router, dag, self.latency_s, aggregate=use_agg)
        fail_stats: dict = {}
        if fail_link is not None:
            u, v = fail_link
            net.engine.schedule_at(
                fail_at_s, lambda: fail_stats.update(router.fail_link(u, v))
            )
        run.start()
        net.run()
        self.last_network = net
        res = self._dag_result(dag, run, net, name)
        res.failure_stats = fail_stats
        res.telemetry = net.telemetry
        return res

    @staticmethod
    def _dag_result(dag, run: _DagRun, net, name: str | None = None) -> NetSimResult:
        makespan = max(run.end_s.values(), default=0.0)
        return NetSimResult(
            name=name or dag.name,
            makespan_s=makespan,
            task_end_s=dict(run.end_s),
            link_utilization=net.utilization(makespan or None),
            # transfer-level: a re-split withdraws flows mid-stream, so the
            # flow ledger undercounts; completed tasks are the ground truth
            bytes_delivered=sum(dag.tasks[tid].total_bytes for tid in run.end_s),
            events=net.engine.events_fired,
            incomplete=len(dag.tasks) - len(run.end_s),
        )

    def run_dags(self, dags: "list[FlowDAG]") -> list[NetSimResult]:
        """Execute several flow DAGs CONCURRENTLY on one shared network.

        All DAGs start at t=0 and contend for the same links — which is
        the point: e.g. a model-axis calibration inside an embedded
        chip-level rack while cross-pod DP background traffic crosses the
        rack's trunk uplinks (``netsim/coarsen.mixed_calibrated_profile``).
        Returns one result per DAG in order; each result's utilization is
        the shared network's, averaged over that DAG's own makespan."""
        if self.message_level:
            return self._run_dags_messages(dags)
        router = self._fresh()
        net = router.net
        use_agg = self.aggregate and not self.failed_links
        runs = [
            _DagRun(router, dag, self.latency_s, aggregate=use_agg)
            for dag in dags
        ]
        for run in runs:
            run.start()
        net.run()
        self.last_network = net
        results = []
        for dag, run in zip(dags, runs):
            r = self._dag_result(dag, run, net)
            r.telemetry = net.telemetry      # shared network, shared recorder
            results.append(r)
        return results

    # -- message-level (latency) runs --------------------------------------
    def _run_dags_messages(
        self, dags: "list[FlowDAG]", names: "list[str | None] | None" = None
    ) -> list[NetSimResult]:
        """Execute DAGs concurrently at message granularity
        (``netsim/messages.py``): store-and-forward serialization +
        per-hop propagation + FIFO queueing on the same wire inventory,
        no fluid solver, no flat launch delay."""
        from .messages import MessageDagRun, MessageNetwork

        msgnet = MessageNetwork(
            self.topo,
            EventEngine(),
            latency_s=self.latency_s,
            dim_latency_s=self.dim_latency_s,
            rx_gbs=self.rx_gbs,
            reuse_wire_template=self.reuse_wire_template,
        )
        runs = [MessageDagRun(msgnet, dag) for dag in dags]
        for run in runs:
            run.start()
        msgnet.engine.run()
        results = []
        for i, (dag, run) in enumerate(zip(dags, runs)):
            makespan = max(run.end_s.values(), default=0.0)
            name = names[i] if names else None
            results.append(NetSimResult(
                name=name or dag.name,
                makespan_s=makespan,
                task_end_s=dict(run.end_s),
                link_utilization=msgnet.utilization(makespan or None),
                bytes_delivered=sum(
                    dag.tasks[tid].total_bytes for tid in run.end_s
                ),
                events=msgnet.engine.events_fired,
                incomplete=len(dag.tasks) - len(run.end_s),
                task_latency_s=run.task_latency_s,
            ))
        return results

    def measure_latency_profile(
        self,
        size_bytes: float = 64e3,
        *,
        widths: "dict | None" = None,
        axes: tuple[str, ...] | None = None,
        shapes: tuple[str, ...] = LATENCY_SHAPES,
    ) -> LatencyProfile:
        """Per-``(axis, shape)`` message-level latency statistics at a
        decode-sized payload — the latency-side sibling of
        :meth:`calibrated_profile`.

        Each shape's OWN collective DAG (the same builders the bandwidth
        calibration uses) is executed at message granularity regardless of
        this sim's ``message_level`` flag: per-hop serialization +
        propagation + FIFO link/ejection queueing.  Per entry:
        ``total_s`` is the collective's completion time and p50/p99 the
        distribution of per-task ready-to-delivery latencies within the
        run — incast queueing gives the A2A dispatch a heavy p99 tail
        while the fluid model would price every task at one flat
        ``latency_s``.  ``widths`` narrows measurement groups exactly as
        in :meth:`calibrated_profile` (the planner's TP*SP / EP
        footprints); memoization lives in
        ``core.perf_model.NetsimPerfModel.latency_profile``."""
        axis_dims = self._axis_dims_map(axes)
        lat: dict[tuple[str, str], LatencyStats] = {}
        for axis, dims in axis_dims.items():
            for shape in shapes:
                if shape not in LATENCY_SHAPES:
                    raise ValueError(
                        f"latency profiles cover {LATENCY_SHAPES}, "
                        f"got {shape!r}"
                    )
                dag = self._axis_shape_dag(
                    dims, shape, size_bytes,
                    self._width_of(widths, axis, shape),
                    tag=f"lat-{axis}-{shape}",
                )
                if dag is None or not dag.tasks:
                    continue
                res = self._run_dags_messages([dag])[0]
                if res.makespan_s <= 0:
                    continue
                lat[(axis, shape)] = LatencyStats.from_samples(
                    sorted(res.task_latency_s.values()), res.makespan_s
                )
        return LatencyProfile(lat=lat, size_bytes=float(size_bytes))

    def allreduce_time(
        self, dim: int, size_bytes: float, *, fixed: dict[int, int] | None = None
    ) -> float:
        """Multi-ring AllReduce completion time on one clique of ``dim``."""
        nodes = clique_nodes(self.topo, dim, fixed)
        dag = ring_allreduce(self.topo, nodes, size_bytes, tag=f"ar-dim{dim}")
        return self.run_dag(dag).makespan_s

    # -- workload-level run ------------------------------------------------
    def run(
        self,
        workload: WorkloadSpec,
        parallel_spec: ParallelSpec,
        *,
        techniques: tuple[str, ...] | None = None,
    ) -> NetSimResult:
        """Simulate one transfer of each parallelism technique's collective
        on the concrete topology; per-technique completion times land in
        ``collective_s`` with the per-iteration transfer counts alongside
        (``iteration_comm_s`` composes them, pre-overlap)."""
        compiled = compile_workload(self.topo, workload, parallel_spec)
        result = NetSimResult(
            name=workload.name,
            makespan_s=0.0,
            task_end_s={},
            link_utilization={},
            bytes_delivered=0.0,
            events=0,
        )
        for tech, (dag, n_eff) in sorted(compiled.items()):
            if techniques and tech not in techniques:
                continue
            r = self.run_dag(dag, name=f"{workload.name}/{tech}")
            result.collective_s[tech] = r.makespan_s
            result.transfer_counts[tech] = n_eff
            result.makespan_s = max(result.makespan_s, r.makespan_s)
            result.bytes_delivered += r.bytes_delivered
            result.events += r.events
            result.incomplete += r.incomplete
            for l, u in r.link_utilization.items():
                result.link_utilization[l] = max(
                    result.link_utilization.get(l, 0.0), u
                )
        return result

    # -- calibration back into the analytic stack --------------------------
    # collective "shape" -> (grid, hierarchical, single-ring) DAG compilers
    _RING_SHAPES = {
        "allreduce": (grid_allreduce, hierarchical_allreduce, ring_allreduce),
        "all_gather": (grid_all_gather, hierarchical_all_gather, ring_all_gather),
    }
    # A2A calibration group cap — see A2A_CALIBRATION_MAX_NODES in
    # core/cost_model.py (shared with the perf_model width canonicalization)
    A2A_MAX_NODES = A2A_CALIBRATION_MAX_NODES

    def _axis_ring_dag(
        self,
        dims: tuple[int, ...],
        size_bytes: float,
        width: int | None,
        tag: str,
        shape: str = "allreduce",
    ) -> FlowDAG | None:
        """Ring-schedule DAG (AllReduce / AllGather) of one logical axis,
        optionally restricted to a ``width``-chip group (full first-dim
        cliques widened across the second dim, the ``model_group``
        convention).  Full square planes run the cross-dim 2D multi-ring;
        narrower groups the hierarchical per-dim schedule; ``width < 2``
        means no collective at all."""
        grid_fn, hier_fn, ring_fn = self._RING_SHAPES[shape]
        if width is not None and width < 2:
            return None
        x = self.topo.shape[dims[0]]
        plane = math.prod(self.topo.shape[d] for d in dims)
        if width is None or width >= plane:
            if len(dims) == 2:
                dag = grid_fn(self.topo, dims, size_bytes, tag=tag)
                if dag is not None:
                    return dag
            return hier_fn(self.topo, dims, size_bytes, tag=tag)
        if width <= x or len(dims) == 1:
            nodes = clique_nodes(self.topo, dims[0])[: max(2, width)]
            return ring_fn(self.topo, nodes, size_bytes, tag=tag)
        boards = -(-width // x)
        coords = {dims[0]: tuple(range(x)), dims[1]: tuple(range(boards))}
        return hier_fn(
            self.topo, dims[:2], size_bytes, dim_coords=coords, tag=tag
        )

    def a2a_group_cap(self, dims: tuple[int, ...]) -> int:
        """Largest A2A calibration group for an axis over ``dims``: the EP
        footprint convention (``compile_traffic_entry``) never exceeds two
        first-dim cliques, and ``A2A_MAX_NODES`` bounds the explicit-relay
        DAG size.  ``core.perf_model.NetsimPerfModel`` canonicalizes its
        width keys against this same cap."""
        plane = math.prod(self.topo.shape[d] for d in dims)
        cap = min(self.A2A_MAX_NODES, plane)
        if dims[0] == 0:
            cap = min(cap, 2 * self.topo.shape[0])
        return cap

    def _axis_a2a_group(
        self, dims: tuple[int, ...], width: int | None
    ) -> list[int] | None:
        """Node group an axis-level A2A calibration runs on: the EP
        footprint convention (first-dim cliques widened across the second
        dim), capped at ``a2a_group_cap``."""
        cap = self.a2a_group_cap(dims)
        w = min(width or cap, cap)
        if w < 2:
            return None
        if dims[0] == 0:
            return model_group(self.topo, w)
        if len(dims) == 2:
            return grid_plane_nodes(self.topo, dims)[:w]
        return clique_nodes(self.topo, dims[0])[:w]

    def _axis_shape_dag(
        self,
        dims: tuple[int, ...],
        shape: str,
        size_bytes: float,
        width: int | None,
        tag: str,
    ) -> FlowDAG | None:
        """Calibration DAG for one ``(axis-dims, shape)`` pair.
        ``size_bytes`` is the per-chip payload in the matching CommModel
        formula's convention (input for RS/A2A, gathered output for AG)."""
        if shape in self._RING_SHAPES:
            return self._axis_ring_dag(dims, size_bytes, width, tag, shape)
        if shape == "all_to_all":
            group = self._axis_a2a_group(dims, width)
            if group is None:
                return None
            return multipath_all_to_all(
                self.topo, group, size_bytes / len(group), tag=tag
            )
        if shape == "p2p":
            nodes = clique_nodes(self.topo, dims[0])[:2]
            if len(nodes) < 2:
                return None
            dag = FlowDAG(name=tag)
            dag._add(src=nodes[0], dst=nodes[1], size=size_bytes, tag=tag)
            return dag
        raise ValueError(f"unknown collective shape {shape!r}")

    def _axis_dims_map(
        self, axes: tuple[str, ...] | None
    ) -> dict[str, tuple[int, ...]]:
        """Axis -> topology dims.  Default structural convention: dims
        (0, 1) are the intra-rack "model" domain, the rest the inter-rack
        "data" domain; a rack-coarsened mesh overrides the layout via the
        constructor's ``axis_dims``."""
        if self.axis_dims is not None:
            axis_dims = dict(self.axis_dims)
        else:
            axis_dims = {"model": (0, 1)}
            if self.topo.ndim > 2:
                axis_dims["data"] = tuple(range(2, self.topo.ndim))
        if axes is not None:
            axis_dims = {k: v for k, v in axis_dims.items() if k in axes}
        return axis_dims

    @staticmethod
    def _measured_shapes(shapes: tuple[str, ...]) -> tuple[str, ...]:
        """reduce_scatter aliases the all_gather measurement (same wire
        schedule), so measure all_gather whenever either is requested —
        shared by the chip, coarse and mixed calibration paths."""
        return tuple(dict.fromkeys(
            "all_gather" if s == "reduce_scatter" else s for s in shapes
        ))

    @staticmethod
    def _alias_reduce_scatter(
        gbs: dict, axis: str, shapes: tuple[str, ...]
    ) -> None:
        """Post-measurement bookkeeping for the reduce_scatter alias."""
        if "reduce_scatter" in shapes and (axis, "all_gather") in gbs:
            gbs[(axis, "reduce_scatter")] = gbs[(axis, "all_gather")]
        if "all_gather" not in shapes:
            gbs.pop((axis, "all_gather"), None)

    @staticmethod
    def _width_of(widths: "dict | None", axis: str, shape: str) -> int | None:
        """Calibration group width: ``(axis, shape)`` key wins over the
        bare axis key."""
        if not widths:
            return None
        return widths.get((axis, shape), widths.get(axis))

    @staticmethod
    def _wire_fraction(shape: str, n: int) -> float:
        """Per-chip wire bytes of ``shape`` as a fraction of the payload —
        the inverse of the matching ``CommModel`` formula, so the measured
        bandwidth plugs straight back in."""
        if n <= 1:
            return 0.0
        if shape == "allreduce":
            return 2.0 * (n - 1) / n
        if shape in ("all_gather", "reduce_scatter", "all_to_all"):
            return (n - 1) / n
        return 1.0                      # p2p

    def calibrated_axis_gbs(
        self,
        size_bytes: float = 64e6,
        *,
        comm: "CommModel | None" = None,
        axis_sizes: dict[str, int] | None = None,
        widths: dict[str, int] | None = None,
        axes: tuple[str, ...] | None = None,
    ) -> dict[str, float]:
        """Effective per-chip AllReduce bandwidth per logical mesh axis,
        measured from netsim runs — in the units ``CommModel``'s
        ``gbs_per_chip`` uses, so a ``core.perf_model`` backend can feed
        it back into ``core/simulator.simulate``.  (The single-shape
        predecessor of :meth:`calibrated_profile`; kept as the scalar
        entry point.)

        The axis-size normalization must match the CommModel the override
        will be applied to: pass ``comm`` (its ``axes[..].size`` wins) or
        explicit ``axis_sizes``; the fallback is the production mapping's
        16-wide model/data axes.  ``widths`` optionally narrows an axis'
        node group to the chips a parallelism group actually spans (e.g.
        the TP*SP footprint), which is what makes the calibration
        spec-dependent for the planner backend.

        Full square planes are measured on the cross-dim 2D multi-ring
        (Fig. 13), which keeps both dimensions' links busy every step —
        the hierarchical per-dim schedule only reaches about half of the
        plane's analytic bandwidth."""
        prof = self.calibrated_profile(
            size_bytes,
            comm=comm,
            axis_sizes=axis_sizes,
            widths=widths,
            axes=axes,
            shapes=("allreduce",),
        )
        return {a: g for (a, _s), g in prof.gbs.items()}

    def calibrated_profile(
        self,
        size_bytes: float = 64e6,
        *,
        comm: "CommModel | None" = None,
        axis_sizes: dict[str, int] | None = None,
        widths: "dict | None" = None,
        axes: tuple[str, ...] | None = None,
        shapes: tuple[str, ...] = COLLECTIVE_SHAPES,
    ) -> CalibrationProfile:
        """Effective per-chip bandwidth per ``(axis, collective shape)``,
        measured by executing each shape's own flow DAG on this topology.

        AllReduce/AllGather ride the multi-ring schedules (edge-disjoint,
        one inbound flow per ring per node); All-to-All rides the
        Fig. 14-(a) X-then-Y / Y-then-X split with explicit relay hops,
        where relay contention and receiver-egress (incast) serialization
        — modeled when this NetSim has ``rx_gbs`` enabled, the default —
        price it strictly below the AllReduce number on any
        multi-dimension axis.  ``reduce_scatter`` shares AllGather's wire
        schedule and aliases its measurement instead of re-running it.

        ``widths`` narrows the measurement group per axis; keys are either
        an axis name or an ``(axis, shape)`` pair (the pair wins), so a
        planner backend can calibrate the TP*SP footprint for ring shapes
        and the EP footprint for A2A independently.  Callers wanting
        memoization get it from ``core.perf_model.NetsimPerfModel``, which
        caches per (topology, axis, shape, group-width, routing, payload)
        — this method always measures."""
        axis_dims = self._axis_dims_map(axes)
        if axis_sizes is None and comm is not None:
            axis_sizes = {k: a.size for k, a in comm.axes.items()}
        sizes = axis_sizes or {"model": 16, "data": 16}

        gbs: dict[tuple[str, str], float] = {}
        for axis, dims in axis_dims.items():
            n = sizes.get(axis, 16)
            for shape in self._measured_shapes(shapes):
                dag = self._axis_shape_dag(
                    dims, shape, size_bytes, self._width_of(widths, axis, shape),
                    tag=f"cal-{axis}-{shape}",
                )
                if dag is None or not dag.tasks:
                    continue
                t = self.run_dag(dag).makespan_s
                if t <= 0:
                    continue
                wire = self._wire_fraction(shape, n) * size_bytes
                gbs[(axis, shape)] = wire / t / 1e9
            self._alias_reduce_scatter(gbs, axis, shapes)
        return CalibrationProfile(gbs=gbs)

    # -- batched calibration ------------------------------------------------
    def can_batch_calibration(self) -> bool:
        """Whether independent calibration DAGs may share one solver
        session by relocation to disjoint coordinate boxes.

        Requires translation symmetry (homogeneous per-dim link capacities
        and node caps) and box-confined routing.  Under SHORTEST/DETOUR,
        every APR candidate path stays inside the src/dst coordinate box
        (shortest paths permute the differing dims; detours relay through
        a third member of the *same* clique), so DAGs whose boxes are
        disjoint can never share a link, an rx port, or an IO port —
        BORROW breaks this with its global switch plane."""
        if self.routing == Routing.BORROW:
            return False
        if self.failed_links:
            return False                # a failure breaks translation symmetry
        if getattr(self.topo, "link_gbs", None) is not None:
            return False                # heterogeneous link capacities
        if isinstance(self.rx_gbs, dict):
            return False                # per-node rx caps
        if self.dim_io_gbs:
            return False                # switched-tier IO caps
        return True

    def _dag_box(self, dag: FlowDAG) -> list[set[int]]:
        """Per-dimension coordinate sets any flow of ``dag`` can touch.

        Single-path tasks pin their direct links, so only the endpoints'
        coordinates count; router-policy tasks may relay through any third
        member of a differing dimension's clique (APR detour), so each
        differing dim expands to its full range."""
        shape = self.topo.shape
        ndim = len(shape)
        dims = range(ndim)
        full = [set(range(s)) for s in shape]
        box: list[set[int]] = [set() for _ in shape]
        coords = self.topo.coords
        cache: dict[int, tuple[int, ...]] = {}
        for t in dag.tasks:
            pairs = t.pairs if t.pairs else ((t.src, t.dst),)
            single = t.single_path
            for u, v in pairs:
                cu = cache.get(u)
                if cu is None:
                    cu = cache[u] = coords(u)
                cv = cache.get(v)
                if cv is None:
                    cv = cache[v] = coords(v)
                for d in dims:
                    box[d].add(cu[d])
                    box[d].add(cv[d])
                    if cu[d] != cv[d] and not single:
                        box[d] |= full[d]
        return box

    def _place_dag(
        self,
        dag: FlowDAG,
        box: list[set[int]],
        placed: list[list[set[int]]],
    ) -> "tuple[FlowDAG, list[set[int]]] | None":
        """Translate ``dag`` so its box is disjoint from every ``placed``
        box, or ``None`` when no translation fits.  Only dimensions the
        DAG does not use (box == {0}, the builders' base-corner
        convention) are offset; the identity placement is tried first, so
        a batch of one reproduces the sequential run exactly."""
        import itertools

        shape = self.topo.shape
        free = [d for d in range(len(shape)) if box[d] == {0}]
        for offs in itertools.product(*(range(shape[d]) for d in free)):
            tbox = [
                {offs[free.index(d)]} if d in free else set(box[d])
                for d in range(len(shape))
            ]
            ok = all(
                any(not tbox[d] & pb[d] for d in range(len(shape)))
                for pb in placed
            )
            if not ok:
                continue
            if not any(offs):
                return dag, tbox
            delta = {free[i]: offs[i] for i in range(len(free))}
            coords, node_id = self.topo.coords, self.topo.node_id
            cache: dict[int, int] = {}

            def translate(n: int) -> int:
                m = cache.get(n)
                if m is None:
                    c = list(coords(n))
                    for d, o in delta.items():
                        c[d] = o
                    m = cache[n] = node_id(tuple(c))
                return m

            return remap_dag(dag, translate), tbox
        return None

    def measure_profile_batch(
        self,
        size_bytes: float,
        requests: "list[tuple[str, str, int | None]]",
        *,
        comm: "CommModel | None" = None,
        axis_sizes: dict[str, int] | None = None,
        batch_size: int = 8,
        stats: dict | None = None,
    ) -> "dict[tuple[str, str, int | None], float | None]":
        """Measure many ``(axis, shape, width)`` calibration keys in few
        solver sessions.

        Each key's flow DAG is built exactly as :meth:`calibrated_profile`
        would, then translated (``remap_dag``) into a disjoint coordinate
        box of the same mesh — translation symmetry plus APR's
        box-confinement (see :meth:`can_batch_calibration`) make the
        concurrent DAGs provably non-interacting, so each measured
        makespan equals its sequential value (to fp accumulation order).
        Keys that cannot batch (no placement left, ``batch_size``
        reached, or the NetSim configuration forbids it) run sequentially.
        Returns measured GB/s per request (``None`` where the shape
        yields no DAG on this topology — the caller's analytic-fallback
        convention).  ``stats``, when given, accumulates ``sessions``
        (solver sessions run) and ``session_keys`` (keys measured across
        them) so sweep drivers can report batching efficiency."""
        if axis_sizes is None and comm is not None:
            axis_sizes = {k: a.size for k, a in comm.axes.items()}
        sizes = axis_sizes or {"model": 16, "data": 16}
        axis_dims = self._axis_dims_map(None)

        out: "dict[tuple[str, str, int | None], float | None]" = {}
        build: list[tuple[tuple[str, str, int | None], FlowDAG]] = []
        for axis, shape, w in requests:
            dims = axis_dims.get(axis)
            dag = (
                self._axis_shape_dag(
                    dims, shape, size_bytes, w, tag=f"cal-{axis}-{shape}"
                )
                if dims is not None
                else None
            )
            if dag is None or not dag.tasks:
                out[(axis, shape, w)] = None
                continue
            build.append(((axis, shape, w), dag))

        def finish(key, makespan: float) -> None:
            axis, shape, _w = key
            n = sizes.get(axis, 16)
            wire = self._wire_fraction(shape, n) * size_bytes
            out[key] = wire / makespan / 1e9 if makespan > 0 else None

        def count(keys: int) -> None:
            if stats is not None:
                stats["sessions"] = stats.get("sessions", 0) + 1
                stats["session_keys"] = stats.get("session_keys", 0) + keys

        if not self.can_batch_calibration():
            for key, dag in build:
                count(1)
                finish(key, self.run_dag(dag).makespan_s)
            return out

        # greedy first-fit packing into batches of relocated DAGs
        batch: list[tuple[tuple[str, str, int | None], FlowDAG]] = []
        boxes: list[list[set[int]]] = []

        def flush() -> None:
            if not batch:
                return
            count(len(batch))
            for res, (key, _dag) in zip(
                self.run_dags([dag for _k, dag in batch]), batch
            ):
                finish(key, res.makespan_s)
            batch.clear()
            boxes.clear()

        for key, dag in build:
            if len(batch) >= batch_size:
                flush()
            placed = self._place_dag(dag, self._dag_box(dag), boxes)
            if placed is None:
                flush()
                placed = self._place_dag(dag, self._dag_box(dag), [])
            if placed is None:          # does not fit even alone (cannot
                count(1)                # happen today)
                finish(key, self.run_dag(dag).makespan_s)
                continue
            tdag, tbox = placed
            batch.append((key, tdag))
            boxes.append(tbox)
        flush()
        return out


# ---------------------------------------------------------------------------
# Cross-topology batched calibration (topology co-design sweeps)
# ---------------------------------------------------------------------------

# host meshes are cached so ``flows``' per-topology wire templates survive
# across sweep groups with the same dimension specs
_HOST_MESH_CACHE: "dict[tuple, NDFullMesh]" = {}


def _host_mesh(dim_specs: "tuple[DimSpec, ...]", n_slots: int) -> NDFullMesh:
    """A common host mesh for ``n_slots`` concurrent calibration DAGs that
    all live on dimensions ``dim_specs``: the candidate dims plus one
    passive batch dimension "B" of size ``n_slots``.  DAGs in distinct
    B-slots have disjoint coordinate boxes, so by the same box-confinement
    argument as :meth:`NetSim.can_batch_calibration` they never share a
    link or an rx port."""
    key = (dim_specs, n_slots)
    topo = _HOST_MESH_CACHE.get(key)
    if topo is None:
        dims = dim_specs + (DimSpec("B", n_slots, PASSIVE_ELECTRICAL, 1),)
        topo = _HOST_MESH_CACHE[key] = NDFullMesh(dims=dims)
    return topo


def measure_cross_topology(
    jobs: "list[tuple[NetSim, float, list[tuple[str, str, int | None]], dict[str, int]]]",
    *,
    batch_size: int = 8,
    stats: dict | None = None,
) -> "list[dict[tuple[str, str, int | None], float | None]]":
    """Measure calibration keys from *different candidate topologies* in
    shared solver sessions (the cross-topology extension of
    :meth:`NetSim.measure_profile_batch`).

    ``jobs`` is one ``(sim, size_bytes, requests, axis_sizes)`` tuple per
    candidate; the return value is one ``{key: GB/s | None}`` dict per job,
    exactly what each job's own ``measure_profile_batch`` would return.

    Two levels of sharing:

    * **Dedup** — a measured makespan is a function of the DAG's structure
      and the link capacities it touches, not of the candidate it came
      from.  Requests whose *measurement signature* matches (the DimSpecs
      of the dimensions their DAG actually uses, the axis-dims shapes, the
      collective shape/width, payload, routing, latency, rx cap, solver)
      are measured once and fanned back out to every requesting candidate
      — each with its own axis-size wire normalization.
    * **Session sharing** — distinct signatures over the same used-dim
      specs are relocated into disjoint B-slots of one host mesh
      (:func:`_host_mesh`) and solved concurrently, the way
      ``measure_profile_batch`` packs one topology's keys into boxes.

    Candidates whose configuration forbids batching
    (``can_batch_calibration`` False — BORROW routing, per-node rx dicts,
    switched-tier IO caps) fall back to their own sequential
    ``measure_profile_batch`` path, parity-preserved."""
    results: "list[dict[tuple[str, str, int | None], float | None]]" = [
        {} for _ in jobs
    ]
    # group key -> dedup key -> measurement entry
    groups: dict = {}
    for j, (sim, size_bytes, requests, axis_sizes) in enumerate(jobs):
        sizes = axis_sizes or {"model": 16, "data": 16}
        if not sim.can_batch_calibration():
            results[j] = sim.measure_profile_batch(
                size_bytes,
                requests,
                axis_sizes=sizes,
                batch_size=batch_size,
                stats=stats,
            )
            continue
        axis_dims = sim._axis_dims_map(None)
        for axis, shape, w in requests:
            dims = axis_dims.get(axis)
            if dims is None:
                results[j][(axis, shape, w)] = None
                continue
            # calibration DAG builders work at the base corner of ``dims``
            # (every other coordinate is 0), so the dims of size > 1 are a
            # superset of any coordinate the DAG can touch — cheap to
            # compute (no DAG build, no box scan) and sufficient for both
            # the host-mesh embedding and the dedup signature (the DAG is
            # a pure function of the mkey shapes and the sig capacities)
            used = tuple(d for d in dims if sim.topo.shape[d] > 1)
            if not used:
                # degenerate: every dim the axis maps to has size 1, so
                # any DAG is confined to one node — run it alone
                dag = sim._axis_shape_dag(
                    dims, shape, size_bytes, w, tag=f"cal-{axis}-{shape}"
                )
                if dag is None or not dag.tasks:
                    results[j][(axis, shape, w)] = None
                    continue
                if stats is not None:
                    stats["sessions"] = stats.get("sessions", 0) + 1
                    stats["session_keys"] = stats.get("session_keys", 0) + 1
                ms = sim.run_dag(dag).makespan_s
                n = sizes.get(axis, 16)
                wire = NetSim._wire_fraction(shape, n) * size_bytes
                results[j][(axis, shape, w)] = (
                    wire / ms / 1e9 if ms > 0 else None
                )
                continue
            specs = tuple(sim.topo.dims[d] for d in used)
            # the rx (incast) cap only binds when a node's total inflow
            # through the used dims can exceed it — below that bound it is
            # inert, so canonicalize it away: candidates differing only in
            # the lanes of *unused* dims (which drive their "auto" rx) then
            # share one measurement
            rx = sim.rx_gbs
            if rx is not None and rx >= sum(s.gbs_total for s in specs):
                rx = None
            sig = (
                specs,
                sim.routing.value,
                round(sim.latency_s, 12),
                rx,
                sim.solver,
                sim.aggregate,
                sim.adaptive,
                float(size_bytes),
            )
            # everything the DAG *structure* depends on beyond the
            # signature: the axis-dims shapes (clique/plane sizes the
            # builders see), whether dims[0]==0 (the a2a group-cap and
            # model_group special case), the collective shape and width
            mkey = (
                tuple(sim.topo.shape[d] for d in dims),
                dims[0] == 0,
                shape,
                w,
            )
            entry = groups.setdefault(sig, {}).setdefault(
                mkey,
                {"sim": sim, "dims": dims, "used": used, "axis": axis,
                 "shape": shape, "w": w, "refs": []},
            )
            entry["refs"].append((j, (axis, shape, w), sizes.get(axis, 16)))

    for sig, by_key in groups.items():
        specs = sig[0]
        size_bytes = sig[-1]
        # one representative DAG per deduped (sig, mkey) — built lazily
        # here so the candidates' duplicate requests never pay for a build
        entries = []
        for e in by_key.values():
            dag = e["sim"]._axis_shape_dag(
                e["dims"],
                e["shape"],
                size_bytes,
                e["w"],
                tag=f"cal-{e['axis']}-{e['shape']}",
            )
            if dag is None or not dag.tasks:
                for j, key, n in e["refs"]:
                    results[j][key] = None
                continue
            e["dag"] = dag
            entries.append(e)
        for lo in range(0, len(entries), batch_size):
            chunk = entries[lo : lo + batch_size]
            host = _host_mesh(specs, len(chunk))
            hsim = NetSim(
                host,
                routing=Routing(sig[1]),
                latency_s=sig[2],
                rx_gbs=sig[3],
                solver=sig[4],
                aggregate=sig[5],
                adaptive=sig[6],
            )
            n_slots = len(chunk)
            dags = []
            for slot, e in enumerate(chunk):
                cand, used = e["sim"].topo, e["used"]
                # vectorized node relocation: both meshes are row-major,
                # so project the candidate coords onto the used dims and
                # ravel into the host (the trailing B dim is the slot)
                coords = np.unravel_index(
                    np.arange(cand.num_nodes), cand.shape
                )
                host_ids = np.ravel_multi_index(
                    tuple(coords[d] for d in used)
                    + (np.full(cand.num_nodes, slot),),
                    host.shape,
                ).tolist()
                dags.append(remap_dag(e["dag"], host_ids.__getitem__))
            if stats is not None:
                stats["sessions"] = stats.get("sessions", 0) + 1
                stats["session_keys"] = (
                    stats.get("session_keys", 0) + len(chunk)
                )
            for e, res in zip(chunk, hsim.run_dags(dags)):
                ms = res.makespan_s
                for j, key, n in e["refs"]:
                    wire = NetSim._wire_fraction(key[1], n) * size_bytes
                    results[j][key] = wire / ms / 1e9 if ms > 0 else None
    return results
