"""Fluid flow model with max-min fair bandwidth sharing (netsim layer 2).

Flows are fluid: each active flow drains at a rate set by progressive-
filling max-min fairness over the *directed* links it traverses (full-mesh
links are full-duplex, so each physical cable contributes one directed link
per direction at the dimension's ``gbs_per_peer``).  Between events the
rates are constant, so the next state change is the earliest flow
completion — the classic flow-level discrete-event scheme (cf. flow-level
validation in Rail-only / RailX).

The link inventory comes straight from ``core/topology.NDFullMesh``: every
``(u, v, dim)`` edge becomes two directed links of capacity
``dims[dim].gbs_per_peer``.  Extra links (e.g. the Borrow strategy's
switch-plane uplinks) can be added on top.

The rate allocator itself lives in ``netsim/solver.py``: the default
``"vectorized"`` numpy water-filling (incremental group CSR, symmetric
flows aggregated by identical constraint multisets) or the original
``"reference"`` pure-Python progressive filling kept as the parity oracle.

**Aggregate flows** (:meth:`FluidNetwork.add_aggregate_flow`): the N
parallel sends of one multi-ring step are symmetric — same size, same
per-link contention — so they are carried as ONE flow whose link set is
the union of the member links and whose ``multiplicity`` counts the
members.  The max-min solver naturally gives such a flow the min fair
share across its links, which under symmetry equals every member's
individual rate — collapsing the dominant collective DAGs from O(N)
flows per step to O(rings) while reproducing the exact completion times.

**Receiver-egress (incast) contention**: fluid max-min over per-link
capacities alone resolves many-to-one bursts instantaneously — N senders on
N distinct full-mesh links all drain at full link rate, so the receiver
absorbs N links' worth of traffic at once.  Real NPUs cannot: the ejection
port into memory is finite, and MoE dispatch/combine or DP gradient bursts
serialize behind it (pause/backpressure).  ``rx_gbs`` models this as one
virtual ingress link per destination node, capacity = the node's ejection
bandwidth, shared by every flow terminating there; the max-min allocator
treats it exactly like a wire.  ``default_rx_gbs`` sizes it at the node's
largest single-dimension clique allocation — wide enough that multi-ring
collectives (≤ one inbound flow per ring per node) keep their full
bandwidth, tight enough that cross-dimension incast serializes.

**Per-dimension IO caps** (``dim_io_gbs``): a dimension whose "links" are
really a non-blocking switch tier (the SuperPod's HRS pod-level Clos,
§3.3.4) is constrained per NODE, not per peer-pair — each rack's uplink
bundle bounds its aggregate injection AND ejection into that tier while
any single pair may burst the full uplink.  ``dim_io_gbs={dim: gbs}``
adds one virtual TX and one virtual RX link per node per capped
dimension, shared by every flow whose path crosses that dimension at that
node.  ``netsim/coarsen.py`` uses this to model the HRS tier of the
rack-coarsened SuperPod.

Invariants maintained (and unit-tested):
* sum of flow rates on a link never exceeds its capacity,
* bytes delivered per flow equals the requested flow size,
* identical scenarios produce identical event traces (determinism).
"""

from __future__ import annotations

import math
import weakref
from dataclasses import dataclass, field
from typing import Callable, Hashable

from ..core.topology import NDFullMesh
from .events import Event, EventEngine
from .solver import make_solver

DirectedLink = tuple[int, int]          # (u, v), u -> v

_EPS_BYTES = 1e-6                       # "done" threshold
_EPS_RATE = 1e-12

RX_PORT = -1                            # sentinel endpoint of virtual ingress
                                        # links: (RX_PORT, node) caps the
                                        # receiver-egress bandwidth of `node`
IO_TX = -2                              # (IO_TX, dim, node): per-dim injection
IO_RX = -3                              # (IO_RX, dim, node): per-dim ejection


def default_rx_gbs(topo: NDFullMesh) -> float:
    """Default per-node receiver-egress (ejection) bandwidth, GB/s.

    The node's largest single-dimension clique allocation: the UB IO die is
    provisioned so its widest collective domain can sink at full multi-ring
    rate (at most one inbound flow per ring per node — exactly the per-dim
    allocation), while many-to-one bursts that fan in across several
    dimensions at once exceed it and serialize.
    """
    return max(d.gbs_total for d in topo.dims)


@dataclass(slots=True)
class Flow:
    """One fluid flow — a single explicit path, or an aggregate of
    ``multiplicity`` symmetric single-hop members (``links`` then holds one
    directed link per member and ``size``/``remaining``/``rate`` are
    per-member)."""

    fid: int
    path: tuple[int, ...]
    size: float                          # bytes requested (per member)
    remaining: float                     # bytes left to send (per member)
    on_complete: Callable[["Flow"], None] | None = None
    meta: object = None                  # opaque owner handle (Transfer, task)
    rate: float = 0.0                    # bytes/s, set by the allocator
    start_s: float = 0.0
    end_s: float | None = None
    multiplicity: int = 1                # symmetric members carried
    credited: float = 0.0                # bytes already added to link ledger
    links: tuple[DirectedLink, ...] = ()   # wire links (member links if agg)
    constraints: tuple[Hashable, ...] = ()  # links + virtual rx/io links

    def __post_init__(self) -> None:
        self.links = tuple(zip(self.path, self.path[1:]))
        self.constraints = self.links

    @property
    def done(self) -> bool:
        return self.remaining <= _EPS_BYTES

    @property
    def total_bytes(self) -> float:
        return self.size * self.multiplicity


# wire-structure template cache: (capacity, link->dim) per topology.  The
# two dicts are a pure function of the topology (links x per-dim or
# per-link gbs), yet building them walks every directed link (~82k on a
# 1024-chip pod) — which used to dominate FluidNetwork construction and
# thereby the per-key cost of planner calibration (one fresh network per
# measured key).  Keyed weakly on the topology object itself: value-hashed
# frozen ``NDFullMesh`` instances share one template across equal meshes,
# identity-hashed coarse/mixed meshes get one template each.  ``capacity``
# is copied per network (callers mutate it: ``add_link``, failure tests);
# ``_link_dim`` is read-only after construction and shared.
_WIRE_TEMPLATES: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _build_wire_structure(
    topo: NDFullMesh,
) -> tuple[dict[DirectedLink, float], dict[DirectedLink, int]]:
    capacity: dict[DirectedLink, float] = {}
    link_dim: dict[DirectedLink, int] = {}
    link_gbs = getattr(topo, "link_gbs", None)
    for u, v, d in topo.links():
        gbs = (
            link_gbs(u, v) if link_gbs is not None
            else topo.dims[d].gbs_per_peer
        ) * 1e9
        capacity[(u, v)] = gbs
        capacity[(v, u)] = gbs
        link_dim[(u, v)] = d
        link_dim[(v, u)] = d
    return capacity, link_dim


def _wire_structure(
    topo: NDFullMesh,
) -> tuple[dict[DirectedLink, float], dict[DirectedLink, int]]:
    try:
        cached = _WIRE_TEMPLATES.get(topo)
    except TypeError:               # unhashable / non-weakrefable topology
        cached = None
    if cached is not None:
        return cached
    out = _build_wire_structure(topo)
    try:
        _WIRE_TEMPLATES[topo] = out
    except TypeError:
        pass
    return out


class FluidNetwork:
    """Directed-capacitated network running fluid flows on an EventEngine."""

    def __init__(
        self,
        topo: NDFullMesh,
        engine: EventEngine | None = None,
        *,
        record_rates: bool = False,
        rx_gbs: float | dict[int, float] | None = None,
        dim_io_gbs: "dict[int, float | dict[int, float]] | None" = None,
        solver: str = "vectorized",
        telemetry: "object | None" = None,
        reuse_wire_template: bool = True,
    ) -> None:
        self.topo = topo
        self.engine = engine or EventEngine()
        # a topology carrying its own ``link_gbs(u, v)`` has heterogeneous
        # per-link capacities (the mixed-granularity coarse meshes: chip
        # links next to rack trunks); a plain NDFullMesh prices every link
        # of a dimension at that dim's gbs_per_peer.  The (capacity,
        # link->dim) pair comes from the per-topology template cache;
        # capacity is copied because this network may mutate it.
        # ``reuse_wire_template=False`` bypasses the cache (the benchmark
        # baseline that prices the pre-cache construction cost).
        if reuse_wire_template:
            cap_template, link_dim = _wire_structure(topo)
        else:
            cap_template, link_dim = _build_wire_structure(topo)
        self.capacity: dict[DirectedLink, float] = dict(cap_template)
        self._link_dim: dict[DirectedLink, int] = link_dim
        # receiver-egress caps, bytes/s per node (empty = unconstrained)
        if rx_gbs is None:
            self.rx_cap: dict[int, float] = {}
        elif isinstance(rx_gbs, dict):
            self.rx_cap = {n: g * 1e9 for n, g in rx_gbs.items()}
        else:
            self.rx_cap = {n: rx_gbs * 1e9 for n in range(topo.num_nodes)}
        # per-dimension per-node IO caps (switched tiers), bytes/s.  A
        # dict-valued entry carries heterogeneous per-node caps (mixed-
        # granularity meshes: each detail chip is bounded by its own
        # uplink share, each coarse rack by the whole uplink); nodes
        # absent from a per-node dict are uncapped on that dimension.
        self.dim_io_cap: dict[int, "float | dict[int, float]"] = {}
        for d, g in (dim_io_gbs or {}).items():
            if isinstance(g, dict):
                self.dim_io_cap[d] = {n: gn * 1e9 for n, gn in g.items()}
            else:
                self.dim_io_cap[d] = g * 1e9
        self.failed: set[DirectedLink] = set()
        self.flows: dict[int, Flow] = {}                 # active flows
        self.completed: dict[int, Flow] = {}
        self._next_fid = 0
        self._last_update = 0.0
        self._completion_ev: Event | None = None
        self._flush_ev: Event | None = None
        self._dirty = False
        self._in_completion = False
        self._flowing: list[Flow] = []                   # rate > 0 after solve
        self._link_bytes: dict[DirectedLink, float] = {}  # credited per link
        self.record_rates = record_rates
        self.rate_log: list[tuple[float, DirectedLink, float, float]] = []
        # opt-in telemetry recorder (netsim/telemetry.Telemetry); every
        # hot-path hook is a single `is not None` check when disabled
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry._attach(self)
        self.solver = make_solver(solver, self)

    # -- topology edits ----------------------------------------------------
    def add_link(self, u: int, v: int, gbs: float, *, duplex: bool = True) -> None:
        """Add an extra directed link (e.g. a switch-plane uplink)."""
        self.capacity[(u, v)] = gbs * 1e9
        if duplex:
            self.capacity[(v, u)] = gbs * 1e9
        self.solver.capacity_changed()

    def fail_link(self, u: int, v: int) -> list[Flow]:
        """Zero both directions of u-v; returns the flows that crossed it."""
        self._advance()
        self.failed |= {(u, v), (v, u)}
        self.solver.capacity_changed()
        hit = [
            f for f in self.flows.values()
            if (u, v) in f.links or (v, u) in f.links
        ]
        self._mark_dirty()
        return hit

    def link_ok(self, u: int, v: int) -> bool:
        return (u, v) in self.capacity and (u, v) not in self.failed

    def effective_capacity(self, link: DirectedLink) -> float:
        return 0.0 if link in self.failed else self.capacity.get(link, 0.0)

    def constraint_capacity(self, key: Hashable) -> float:
        """Capacity (bytes/s) of any constraint key a flow may carry: a
        wire link, a virtual receiver-egress port, or a per-dim IO port."""
        k0 = key[0]
        if k0 == RX_PORT:
            return self.rx_cap[key[1]]
        if k0 == IO_TX or k0 == IO_RX:
            cap = self.dim_io_cap[key[1]]
            if isinstance(cap, dict):
                return cap[key[2]]
            return cap
        return self.effective_capacity(key)

    def _constraints_for(
        self, links: tuple[DirectedLink, ...], dsts: tuple[int, ...]
    ) -> tuple[Hashable, ...]:
        """Wire links + the virtual rx / per-dim IO ports they imply."""
        extra: list[Hashable] = []
        for dst in dsts:
            if dst in self.rx_cap:
                extra.append((RX_PORT, dst))
        if self.dim_io_cap:
            for (u, v) in links:
                d = self._link_dim.get((u, v))
                cap = self.dim_io_cap.get(d) if d is not None else None
                if cap is None:
                    continue
                if isinstance(cap, dict):
                    if u in cap:
                        extra.append((IO_TX, d, u))
                    if v in cap:
                        extra.append((IO_RX, d, v))
                else:
                    extra.append((IO_TX, d, u))
                    extra.append((IO_RX, d, v))
        return links + tuple(extra) if extra else links

    # -- flow lifecycle ----------------------------------------------------
    def add_flow(
        self,
        path: tuple[int, ...],
        size: float,
        on_complete: Callable[[Flow], None] | None = None,
        meta: object = None,
    ) -> Flow:
        fid = self._next_fid
        self._next_fid += 1
        flow = Flow(
            fid=fid,
            path=tuple(path),
            size=float(size),
            remaining=float(size),
            on_complete=on_complete,
            meta=meta,
            start_s=self.engine.now,
        )
        for l in flow.links:
            if l not in self.capacity:
                raise ValueError(f"path {path} uses nonexistent link {l}")
        flow.constraints = self._constraints_for(flow.links, (flow.path[-1],))
        if len(path) < 2 or size <= _EPS_BYTES:
            # degenerate: local copy, completes instantly
            flow.remaining = 0.0
            flow.end_s = self.engine.now
            self.completed[fid] = flow
            if self.telemetry is not None:
                self.telemetry.flow_completed(flow)
            if on_complete:
                on_complete(flow)
            return flow
        self._advance()
        self.flows[fid] = flow
        self.solver.flow_added(flow)
        if self.telemetry is not None:
            self.telemetry.flow_started(flow)
        self._mark_dirty()
        return flow

    def add_aggregate_flow(
        self,
        pairs: tuple[DirectedLink, ...],
        size: float,
        on_complete: Callable[[Flow], None] | None = None,
        meta: object = None,
    ) -> Flow:
        """One weighted flow carrying ``len(pairs)`` symmetric single-hop
        members of ``size`` bytes each (e.g. the parallel sends of one
        multi-ring step).  Every member link constrains the shared rate, so
        the aggregate completes exactly when its slowest member would —
        identical to the member-by-member run whenever the members are
        symmetric, ~N x cheaper to simulate."""
        fid = self._next_fid
        self._next_fid += 1
        flow = Flow(
            fid=fid,
            path=tuple(pairs[0]),
            size=float(size),
            remaining=float(size),
            on_complete=on_complete,
            meta=meta,
            start_s=self.engine.now,
            multiplicity=len(pairs),
        )
        for l in pairs:
            if l not in self.capacity:
                raise ValueError(f"aggregate flow uses nonexistent link {l}")
        flow.links = tuple(pairs)
        flow.constraints = self._constraints_for(
            flow.links, tuple(v for _u, v in pairs)
        )
        if size <= _EPS_BYTES:
            flow.remaining = 0.0
            flow.end_s = self.engine.now
            self.completed[fid] = flow
            if self.telemetry is not None:
                self.telemetry.flow_completed(flow)
            if on_complete:
                on_complete(flow)
            return flow
        self._advance()
        self.flows[fid] = flow
        self.solver.flow_added(flow)
        if self.telemetry is not None:
            self.telemetry.flow_started(flow)
        self._mark_dirty()
        return flow

    def remove_flow(self, flow: Flow) -> float:
        """Withdraw an active flow; returns its un-sent bytes."""
        self._advance()
        if self.flows.pop(flow.fid, None) is not None:
            self._credit(flow)
            self.solver.flow_removed(flow)
            if self.telemetry is not None:
                self.telemetry.flow_withdrawn(flow)
        self._mark_dirty()
        return max(0.0, flow.remaining)

    # -- fluid mechanics ---------------------------------------------------
    def _advance(self) -> None:
        """Accrue bytes sent at current rates since the last state change.

        Only flows the last solve left with a positive rate are walked,
        and the per-link byte ledger is NOT touched here — progress is
        credited lazily per flow on completion/withdrawal (or when the
        ledger is read), so the hot path is one subtraction per flowing
        flow per completion wave.
        """
        now = self.engine.now
        dt = now - self._last_update
        self._last_update = now
        if dt <= 0:
            return
        for f in self._flowing:
            moved = f.rate * dt
            f.remaining = f.remaining - moved if moved < f.remaining else 0.0

    def _credit(self, flow: Flow) -> None:
        """Post a flow's un-credited progress to the per-link byte ledger
        (one entry per wire-link occurrence; aggregate members credit their
        own link)."""
        delta = (flow.size - max(0.0, flow.remaining)) - flow.credited
        if delta <= 0:
            return
        flow.credited += delta
        lb = self._link_bytes
        for l in flow.links:
            lb[l] = lb.get(l, 0.0) + delta

    @property
    def link_bytes(self) -> dict[DirectedLink, float]:
        """Bytes delivered per directed link, including in-flight progress
        (flushes the lazy ledger on access)."""
        self._advance()
        for f in self.flows.values():
            self._credit(f)
        return self._link_bytes

    def _maxmin_rates(self) -> None:
        """Delegate the progressive-filling allocation to the configured
        solver (``netsim/solver.py``); remembers the flowing set so
        ``_advance`` can skip zero-rate flows up front."""
        self._flowing = self.solver.solve()
        if self.telemetry is not None:
            self.telemetry.record_solve(
                self.engine.now,
                self.flows,
                getattr(self.solver, "last_attribution", None),
                self._flowing,
            )
        if self.record_rates:
            used: dict[DirectedLink, float] = {}
            for f in self._flowing:
                for l in f.links:
                    used[l] = used.get(l, 0.0) + f.rate
            for l in sorted(used):
                self.rate_log.append(
                    (self.engine.now, l, used[l], self.effective_capacity(l))
                )

    def _mark_dirty(self) -> None:
        """Request a rate recompute; same-timestamp changes batch into one
        zero-delay flush so a 50-flow collective step costs one allocation,
        not fifty."""
        self._dirty = True
        if self._in_completion:
            return  # the completion handler recomputes once at exit
        if self._flush_ev is None:
            self._flush_ev = self.engine.schedule(0.0, self._flush)

    def _flush(self) -> None:
        self._flush_ev = None
        if self._dirty:
            self._recompute()

    def _recompute(self) -> None:
        if self._in_completion:
            self._dirty = True
            return  # batched: the completion handler recomputes once at exit
        self._dirty = False
        self._maxmin_rates()
        if self._completion_ev is not None:
            self._completion_ev.cancel()
            self._completion_ev = None
        ttc = math.inf
        for f in self._flowing:
            t = f.remaining / f.rate
            if t < ttc:
                ttc = t
        if math.isfinite(ttc):
            self._completion_ev = self.engine.schedule(
                max(0.0, ttc), self._on_completion
            )

    def _on_completion(self) -> None:
        self._completion_ev = None
        self._advance()
        done = [f for f in self.flows.values() if f.done]
        self._in_completion = True
        try:
            for f in done:
                del self.flows[f.fid]
                f.remaining = 0.0
                self._credit(f)
                self.solver.flow_removed(f)
                f.end_s = self.engine.now
                self.completed[f.fid] = f
                if self.telemetry is not None:
                    self.telemetry.flow_completed(f)
            for f in done:
                if f.on_complete:
                    f.on_complete(f)
        finally:
            self._in_completion = False
        self._recompute()

    # -- results -----------------------------------------------------------
    def run(self, until: float | None = None) -> float:
        return self.engine.run(until=until)

    def utilization(self, elapsed_s: float | None = None) -> dict[DirectedLink, float]:
        """Per-link mean utilization over ``elapsed_s`` (default: now)."""
        t = elapsed_s if elapsed_s is not None else self.engine.now
        lb = self.link_bytes
        if t <= 0:
            return {l: 0.0 for l in lb}
        return {l: b / (self.capacity[l] * t) for l, b in sorted(lb.items())}

    @property
    def bytes_delivered(self) -> float:
        """Total bytes delivered end-to-end (per-flow, not per-link)."""
        return sum(f.total_bytes for f in self.completed.values())
