"""Fluid flow model with max-min fair bandwidth sharing (netsim layer 2).

Flows are fluid: each active flow drains at a rate set by progressive-
filling max-min fairness over the *directed* links it traverses (full-mesh
links are full-duplex, so each physical cable contributes one directed link
per direction at the dimension's ``gbs_per_peer``).  Between events the
rates are constant, so the next state change is the earliest flow
completion — the classic flow-level discrete-event scheme (cf. flow-level
validation in Rail-only / RailX).

The link inventory comes straight from ``core/topology.NDFullMesh``: every
``(u, v, dim)`` edge becomes two directed links of capacity
``dims[dim].gbs_per_peer``.  Extra links (e.g. the Borrow strategy's
switch-plane uplinks) can be added on top.

**Receiver-egress (incast) contention**: fluid max-min over per-link
capacities alone resolves many-to-one bursts instantaneously — N senders on
N distinct full-mesh links all drain at full link rate, so the receiver
absorbs N links' worth of traffic at once.  Real NPUs cannot: the ejection
port into memory is finite, and MoE dispatch/combine or DP gradient bursts
serialize behind it (pause/backpressure).  ``rx_gbs`` models this as one
virtual ingress link per destination node, capacity = the node's ejection
bandwidth, shared by every flow terminating there; the max-min allocator
treats it exactly like a wire.  ``default_rx_gbs`` sizes it at the node's
largest single-dimension clique allocation — wide enough that multi-ring
collectives (≤ one inbound flow per ring per node) keep their full
bandwidth, tight enough that cross-dimension incast serializes.

Invariants maintained (and unit-tested):
* sum of flow rates on a link never exceeds its capacity,
* bytes delivered per flow equals the requested flow size,
* identical scenarios produce identical event traces (determinism).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

from ..core.topology import NDFullMesh
from .events import Event, EventEngine

DirectedLink = tuple[int, int]          # (u, v), u -> v

_EPS_BYTES = 1e-6                       # "done" threshold
_EPS_RATE = 1e-12

RX_PORT = -1                            # sentinel endpoint of virtual ingress
                                        # links: (RX_PORT, node) caps the
                                        # receiver-egress bandwidth of `node`


def default_rx_gbs(topo: NDFullMesh) -> float:
    """Default per-node receiver-egress (ejection) bandwidth, GB/s.

    The node's largest single-dimension clique allocation: the UB IO die is
    provisioned so its widest collective domain can sink at full multi-ring
    rate (at most one inbound flow per ring per node — exactly the per-dim
    allocation), while many-to-one bursts that fan in across several
    dimensions at once exceed it and serialize.
    """
    return max(d.gbs_total for d in topo.dims)


@dataclass
class Flow:
    """One fluid flow on one explicit path."""

    fid: int
    path: tuple[int, ...]
    size: float                          # bytes requested
    remaining: float                     # bytes left to send
    on_complete: Callable[["Flow"], None] | None = None
    meta: object = None                  # opaque owner handle (Transfer, task)
    rate: float = 0.0                    # bytes/s, set by the allocator
    start_s: float = 0.0
    end_s: float | None = None
    links: tuple[DirectedLink, ...] = ()   # consecutive path pairs, cached
    constraints: tuple[DirectedLink, ...] = ()  # links + virtual rx link

    def __post_init__(self) -> None:
        self.links = tuple(zip(self.path, self.path[1:]))
        self.constraints = self.links

    @property
    def done(self) -> bool:
        return self.remaining <= _EPS_BYTES


class FluidNetwork:
    """Directed-capacitated network running fluid flows on an EventEngine."""

    def __init__(
        self,
        topo: NDFullMesh,
        engine: EventEngine | None = None,
        *,
        record_rates: bool = False,
        rx_gbs: float | dict[int, float] | None = None,
    ) -> None:
        self.topo = topo
        self.engine = engine or EventEngine()
        self.capacity: dict[DirectedLink, float] = {}    # bytes/s
        for u, v, d in topo.links():
            gbs = topo.dims[d].gbs_per_peer * 1e9
            self.capacity[(u, v)] = gbs
            self.capacity[(v, u)] = gbs
        # receiver-egress caps, bytes/s per node (empty = unconstrained)
        if rx_gbs is None:
            self.rx_cap: dict[int, float] = {}
        elif isinstance(rx_gbs, dict):
            self.rx_cap = {n: g * 1e9 for n, g in rx_gbs.items()}
        else:
            self.rx_cap = {n: rx_gbs * 1e9 for n in range(topo.num_nodes)}
        self.failed: set[DirectedLink] = set()
        self.flows: dict[int, Flow] = {}                 # active flows
        self.completed: dict[int, Flow] = {}
        self._next_fid = 0
        self._last_update = 0.0
        self._completion_ev: Event | None = None
        self._flush_ev: Event | None = None
        self._dirty = False
        self._in_completion = False
        self.link_bytes: dict[DirectedLink, float] = {}  # delivered per link
        self.record_rates = record_rates
        self.rate_log: list[tuple[float, DirectedLink, float, float]] = []

    # -- topology edits ----------------------------------------------------
    def add_link(self, u: int, v: int, gbs: float, *, duplex: bool = True) -> None:
        """Add an extra directed link (e.g. a switch-plane uplink)."""
        self.capacity[(u, v)] = gbs * 1e9
        if duplex:
            self.capacity[(v, u)] = gbs * 1e9

    def fail_link(self, u: int, v: int) -> list[Flow]:
        """Zero both directions of u-v; returns the flows that crossed it."""
        self._advance()
        self.failed |= {(u, v), (v, u)}
        hit = [
            f for f in self.flows.values()
            if (u, v) in f.links or (v, u) in f.links
        ]
        self._mark_dirty()
        return hit

    def link_ok(self, u: int, v: int) -> bool:
        return (u, v) in self.capacity and (u, v) not in self.failed

    def effective_capacity(self, link: DirectedLink) -> float:
        return 0.0 if link in self.failed else self.capacity.get(link, 0.0)

    # -- flow lifecycle ----------------------------------------------------
    def add_flow(
        self,
        path: tuple[int, ...],
        size: float,
        on_complete: Callable[[Flow], None] | None = None,
        meta: object = None,
    ) -> Flow:
        fid = self._next_fid
        self._next_fid += 1
        flow = Flow(
            fid=fid,
            path=tuple(path),
            size=float(size),
            remaining=float(size),
            on_complete=on_complete,
            meta=meta,
            start_s=self.engine.now,
        )
        for l in flow.links:
            if l not in self.capacity:
                raise ValueError(f"path {path} uses nonexistent link {l}")
        dst = flow.path[-1]
        if dst in self.rx_cap:
            flow.constraints = flow.links + ((RX_PORT, dst),)
        if len(path) < 2 or size <= _EPS_BYTES:
            # degenerate: local copy, completes instantly
            flow.remaining = 0.0
            flow.end_s = self.engine.now
            self.completed[fid] = flow
            if on_complete:
                on_complete(flow)
            return flow
        self._advance()
        self.flows[fid] = flow
        self._mark_dirty()
        return flow

    def remove_flow(self, flow: Flow) -> float:
        """Withdraw an active flow; returns its un-sent bytes."""
        self._advance()
        self.flows.pop(flow.fid, None)
        self._mark_dirty()
        return max(0.0, flow.remaining)

    # -- fluid mechanics ---------------------------------------------------
    def _advance(self) -> None:
        """Accrue bytes sent at current rates since the last state change."""
        now = self.engine.now
        dt = now - self._last_update
        self._last_update = now
        if dt <= 0:
            return
        for f in self.flows.values():
            if f.rate > _EPS_RATE:
                moved = min(f.remaining, f.rate * dt)
                f.remaining -= moved
                for l in f.links:
                    self.link_bytes[l] = self.link_bytes.get(l, 0.0) + moved

    def _maxmin_rates(self) -> None:
        """Progressive filling: saturate the tightest link level-by-level.

        All links at the current minimum fair share freeze together (one
        water-filling level per round), which collapses the symmetric
        collective case — every ring link equally loaded — to one round.
        A flow's constraint set is its wire links plus (when ``rx_cap`` is
        configured) the virtual ``(RX_PORT, dst)`` ingress link shared by
        every flow terminating at ``dst`` — incast serializes there.
        """
        active = [self.flows[k] for k in sorted(self.flows)]
        for f in active:
            f.rate = 0.0
        residual: dict[DirectedLink, float] = {}
        count: dict[DirectedLink, int] = {}
        flows_on: dict[DirectedLink, list[Flow]] = {}
        for f in active:
            for l in f.constraints:
                if l not in residual:
                    residual[l] = (
                        self.rx_cap[l[1]]
                        if l[0] == RX_PORT
                        else self.effective_capacity(l)
                    )
                    count[l] = 0
                    flows_on[l] = []
                count[l] += 1
                flows_on[l].append(f)
        frozen: set[int] = set()
        n_left = len(active)
        while n_left > 0:
            best = math.inf
            for l, c in count.items():
                if c > 0:
                    share = residual[l] / c
                    if share < best:
                        best = share
            if not math.isfinite(best):
                break
            level = best * (1 + 1e-12) + 1e-9
            for l in list(count):
                if count[l] <= 0 or residual[l] / count[l] > level:
                    continue
                for f in flows_on[l]:
                    if f.fid in frozen:
                        continue
                    f.rate = best
                    frozen.add(f.fid)
                    n_left -= 1
                    for fl in f.constraints:
                        residual[fl] = max(0.0, residual[fl] - best)
                        count[fl] -= 1
        if self.record_rates:
            used: dict[DirectedLink, float] = {}
            for f in active:
                for l in f.links:
                    used[l] = used.get(l, 0.0) + f.rate
            for l in sorted(used):
                self.rate_log.append(
                    (self.engine.now, l, used[l], self.effective_capacity(l))
                )

    def _mark_dirty(self) -> None:
        """Request a rate recompute; same-timestamp changes batch into one
        zero-delay flush so a 50-flow collective step costs one allocation,
        not fifty."""
        self._dirty = True
        if self._in_completion:
            return  # the completion handler recomputes once at exit
        if self._flush_ev is None:
            self._flush_ev = self.engine.schedule(0.0, self._flush)

    def _flush(self) -> None:
        self._flush_ev = None
        if self._dirty:
            self._recompute()

    def _recompute(self) -> None:
        if self._in_completion:
            self._dirty = True
            return  # batched: the completion handler recomputes once at exit
        self._dirty = False
        self._maxmin_rates()
        if self._completion_ev is not None:
            self._completion_ev.cancel()
            self._completion_ev = None
        ttc = math.inf
        for f in self.flows.values():
            if f.rate > _EPS_RATE:
                ttc = min(ttc, f.remaining / f.rate)
        if math.isfinite(ttc):
            self._completion_ev = self.engine.schedule(
                max(0.0, ttc), self._on_completion
            )

    def _on_completion(self) -> None:
        self._completion_ev = None
        self._advance()
        done = [self.flows[k] for k in sorted(self.flows) if self.flows[k].done]
        self._in_completion = True
        try:
            for f in done:
                del self.flows[f.fid]
                f.remaining = 0.0
                f.end_s = self.engine.now
                self.completed[f.fid] = f
            for f in done:
                if f.on_complete:
                    f.on_complete(f)
        finally:
            self._in_completion = False
        self._recompute()

    # -- results -----------------------------------------------------------
    def run(self, until: float | None = None) -> float:
        return self.engine.run(until=until)

    def utilization(self, elapsed_s: float | None = None) -> dict[DirectedLink, float]:
        """Per-link mean utilization over ``elapsed_s`` (default: now)."""
        t = elapsed_s if elapsed_s is not None else self.engine.now
        if t <= 0:
            return {l: 0.0 for l in self.link_bytes}
        return {
            l: b / (self.capacity[l] * t)
            for l, b in sorted(self.link_bytes.items())
        }

    @property
    def bytes_delivered(self) -> float:
        """Total bytes delivered end-to-end (per-flow, not per-link)."""
        return sum(f.size for f in self.completed.values())
