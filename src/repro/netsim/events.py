"""Deterministic discrete-event engine (netsim layer 1).

A minimal heapq-based event queue over *virtual* time — no wall clock
anywhere, so two runs of the same scenario produce bit-identical event
orders and timestamps.  Ties in firing time are broken by a monotonically
increasing sequence number (schedule order), which is what makes the whole
simulator reproducible: the fluid flow model recomputes rates on every
event, and a nondeterministic tie-break would propagate into different
rate histories.

Events are plain callbacks.  Cancellation is lazy (a cancelled event stays
in the heap but is skipped when popped), the standard trick that keeps
``schedule``/``cancel`` O(log n) without heap surgery.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass(order=True, slots=True)
class Event:
    """One scheduled callback.  Ordering: (time, seq)."""

    time: float
    seq: int
    fn: Callable[[], Any] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)

    def cancel(self) -> None:
        self.cancelled = True


class EventEngine:
    """Virtual-time event loop."""

    def __init__(self) -> None:
        self.now: float = 0.0
        self._heap: list[Event] = []
        self._seq: int = 0
        self.events_fired: int = 0
        # optional per-event observer (telemetry); None = zero-cost
        self.observer: Callable[[float], Any] | None = None

    # -- scheduling --------------------------------------------------------
    def schedule(self, delay: float, fn: Callable[[], Any]) -> Event:
        """Schedule ``fn`` to fire ``delay`` seconds from now."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        return self.schedule_at(self.now + delay, fn)

    def schedule_at(self, time: float, fn: Callable[[], Any]) -> Event:
        if time < self.now:
            raise ValueError(f"cannot schedule in the past ({time} < {self.now})")
        ev = Event(time=time, seq=self._seq, fn=fn)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    # -- running -----------------------------------------------------------
    def step(self) -> bool:
        """Fire the next non-cancelled event.  Returns False when empty."""
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.time
            self.events_fired += 1
            if self.observer is not None:
                self.observer(ev.time)
            ev.fn()
            return True
        return False

    def run(self, until: float | None = None, max_events: int = 10_000_000) -> float:
        """Drain the queue (or up to virtual time ``until``).  Returns the
        final virtual time.

        ``max_events`` is a hard budget: at most that many events fire, and
        the error raises *before* the budget-busting event runs.  When
        ``until`` is given, ``now`` always lands exactly on ``until`` —
        including when the queue drains early — so back-to-back
        ``run(until=...)`` windows tile virtual time without gaps."""
        fired = 0
        while self._heap:
            nxt = self._peek_time()
            if nxt is None:
                break
            if until is not None and nxt > until:
                self.now = until
                return self.now
            if fired >= max_events:
                raise RuntimeError(f"event budget exceeded ({max_events})")
            if not self.step():
                break
            fired += 1
        if until is not None and self.now < until:
            self.now = until
        return self.now

    def _peek_time(self) -> float | None:
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0].time if self._heap else None

    @property
    def pending(self) -> int:
        return sum(1 for e in self._heap if not e.cancelled)
