"""Message-level store-and-forward latency model (netsim layer 2b).

The fluid model (``netsim/flows.py``) prices *bandwidth*: rates are
max-min fair shares and a DAG task's only latency is one flat
``latency_s`` launch delay.  That is the right abstraction for the
256 MB training collectives the planner calibrates on — and exactly the
wrong one for production decode serving, where per-token messages are
kilobytes and completion time is dominated by per-hop latency,
serialization and queueing behind busy links ("I've Got 99 Problems But
FLOPS Ain't One": the network-latency-dominant regime).

``MessageNetwork`` executes the SAME collective ``FlowDAG``s at message
granularity:

* **serialization** — a message occupies a directed link for
  ``size / capacity`` seconds (capacities come from the same per-topology
  wire inventory the fluid model uses, ``flows._wire_structure``);
* **propagation** — each hop adds the per-hop latency (flat by default,
  per-dimension overridable);
* **queueing** — each directed link is a FIFO: a message entering a busy
  link waits for every earlier message to finish serializing.  Entry
  order (event order, deterministic) is service order;
* **receiver ejection (incast)** — the destination port is a FIFO server
  at ``rx_gbs``.  It is *cut-through*: an uncontended message ejects
  while it serializes off the wire (no extra term — uncongested runs
  match the closed-form alpha-beta cost exactly), but N messages
  converging on one node serialize behind the port, which is what gives
  A2A dispatch its p99 tail.

Routing is the dimension-ordered shortest path (``core/apr``): at decode
message sizes multipath splitting buys nothing (serialization is already
negligible — splitting only adds per-path latency), so the latency mode
deliberately models the single-path fast path.  Failure injection stays a
fluid-mode feature.

Determinism: everything runs on the shared ``EventEngine`` ((time, seq)
order), and all queueing state is plain floats updated in event order —
two runs of the same scenario produce bit-identical latencies.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from ..core.apr import shortest_paths
from ..core.topology import NDFullMesh
from .collectives import FlowDAG
from .events import EventEngine
from .flows import DirectedLink, _build_wire_structure, _wire_structure


@dataclass(slots=True)
class Message:
    """One store-and-forward message on a pinned path."""

    mid: int
    path: tuple[int, ...]
    size: float                                  # bytes
    t_launch: float                              # entered the first hop
    t_end: float | None = None                   # delivered
    on_complete: Callable[["Message"], None] | None = None

    @property
    def latency_s(self) -> float:
        """Launch-to-delivery latency (queueing-inclusive)."""
        return (self.t_end or self.t_launch) - self.t_launch


class MessageNetwork:
    """Store-and-forward message transport over the nD-FullMesh links.

    Shares the fluid model's directed-link inventory (capacities in
    bytes/s from ``flows._wire_structure``) but replaces rate sharing
    with per-link FIFO occupancy: deterministic, queueing-aware, O(hops)
    events per message.
    """

    def __init__(
        self,
        topo: NDFullMesh,
        engine: EventEngine | None = None,
        *,
        latency_s: float = 1e-6,
        dim_latency_s: dict[int, float] | None = None,
        rx_gbs: float | dict[int, float] | None = None,
        reuse_wire_template: bool = True,
    ) -> None:
        self.topo = topo
        self.engine = engine or EventEngine()
        if reuse_wire_template:
            capacity, link_dim = _wire_structure(topo)
        else:
            capacity, link_dim = _build_wire_structure(topo)
        # read-only here (no fail_link in message mode), so the template
        # dicts are shared, not copied
        self.capacity: dict[DirectedLink, float] = capacity
        self._link_dim: dict[DirectedLink, int] = link_dim
        self.latency_s = latency_s
        self.dim_latency_s = dict(dim_latency_s or {})
        if rx_gbs is None:
            self.rx_cap: dict[int, float] = {}
        elif isinstance(rx_gbs, dict):
            self.rx_cap = {n: g * 1e9 for n, g in rx_gbs.items()}
        else:
            self.rx_cap = {n: rx_gbs * 1e9 for n in range(topo.num_nodes)}
        # FIFO state: when each directed link / ejection port frees up
        self._link_busy: dict[DirectedLink, float] = {}
        self._rx_busy: dict[int, float] = {}
        self._link_bytes: dict[DirectedLink, float] = {}
        self._next_mid = 0
        self.delivered = 0
        self.bytes_delivered = 0.0

    # -- hop pricing -------------------------------------------------------
    def hop_latency(self, link: DirectedLink) -> float:
        d = self._link_dim.get(link)
        if d is None:
            return self.latency_s
        return self.dim_latency_s.get(d, self.latency_s)

    # -- sending -----------------------------------------------------------
    def send(
        self,
        path: "tuple[int, ...] | list[int]",
        size: float,
        on_complete: Callable[[Message], None] | None = None,
    ) -> Message:
        """Launch one message along ``path`` (adjacent node ids) now."""
        if len(path) < 2:
            raise ValueError(f"path needs >= 2 nodes, got {path!r}")
        msg = Message(
            mid=self._next_mid,
            path=tuple(path),
            size=float(size),
            t_launch=self.engine.now,
            on_complete=on_complete,
        )
        self._next_mid += 1
        self._enter_hop(msg, 0)
        return msg

    def _enter_hop(self, msg: Message, i: int) -> None:
        now = self.engine.now
        link = (msg.path[i], msg.path[i + 1])
        cap = self.capacity.get(link)
        if cap is None:
            raise KeyError(f"no directed link {link} in topology")
        start = max(now, self._link_busy.get(link, 0.0))
        ser = msg.size / cap
        self._link_busy[link] = start + ser
        self._link_bytes[link] = self._link_bytes.get(link, 0.0) + msg.size
        arrive = start + ser + self.hop_latency(link)
        if i + 2 < len(msg.path):
            self.engine.schedule_at(
                arrive, lambda: self._enter_hop(msg, i + 1)
            )
            return
        # last hop: queue through the destination's ejection port.  The
        # port is cut-through — its "virtual start" is backdated by its own
        # serialization time, so an idle port adds nothing while a
        # contended one serializes messages back to back
        dst = msg.path[-1]
        rx = self.rx_cap.get(dst)
        if rx:
            rser = msg.size / rx
            rstart = max(arrive - rser, self._rx_busy.get(dst, 0.0))
            arrive = rstart + rser
            self._rx_busy[dst] = arrive
        self.engine.schedule_at(arrive, lambda: self._deliver(msg))

    def _deliver(self, msg: Message) -> None:
        msg.t_end = self.engine.now
        self.delivered += 1
        self.bytes_delivered += msg.size
        if msg.on_complete is not None:
            msg.on_complete(msg)

    # -- inspection --------------------------------------------------------
    def utilization(self, elapsed_s: float | None = None) -> dict[DirectedLink, float]:
        elapsed = elapsed_s if elapsed_s else (self.engine.now or None)
        if not elapsed:
            return {l: 0.0 for l in self._link_bytes}
        return {
            l: b / (self.capacity[l] * elapsed)
            for l, b in self._link_bytes.items()
        }


class MessageDagRun:
    """Executes one collective ``FlowDAG`` at message granularity.

    Same dependency semantics as the fluid ``_DagRun`` — a task launches
    when its deps complete — but every task (or every pair of an
    aggregate ring step) becomes one store-and-forward message on its
    dimension-ordered shortest path, with NO flat launch delay: latency
    is carried per hop by the transport instead.  Per-task
    launch/completion times are recorded so the caller can extract the
    within-collective message-latency distribution (p50/p99 calibration).
    """

    def __init__(self, msgnet: MessageNetwork, dag: FlowDAG) -> None:
        self.msgnet = msgnet
        self.dag = dag
        self.start_s: dict[int, float] = {}
        self.end_s: dict[int, float] = {}
        self.children: dict[int, list[int]] = {}
        self.indeg: dict[int, int] = {}
        self.fanout: dict[int, int] = {}
        self._path_cache: dict[tuple[int, int], tuple[int, ...]] = {}
        for t in dag.tasks:
            self.indeg[t.tid] = len(t.deps)
            for d in t.deps:
                self.children.setdefault(d, []).append(t.tid)

    def start(self) -> None:
        for t in self.dag.tasks:
            if self.indeg[t.tid] == 0:
                self._launch(t.tid)

    def _path(self, src: int, dst: int) -> tuple[int, ...]:
        p = self._path_cache.get((src, dst))
        if p is None:
            p = self._path_cache[(src, dst)] = shortest_paths(
                self.msgnet.topo, src, dst
            )[0]
        return p

    def _launch(self, tid: int) -> None:
        task = self.dag.tasks[tid]
        self.start_s[tid] = self.msgnet.engine.now
        pairs = task.pairs if task.pairs else ((task.src, task.dst),)
        self.fanout[tid] = len(pairs)
        for src, dst in pairs:
            self.msgnet.send(
                self._path(src, dst),
                task.size,
                on_complete=lambda m, tid=tid: self._msg_done(tid),
            )

    def _msg_done(self, tid: int) -> None:
        self.fanout[tid] -= 1
        if self.fanout[tid] == 0:
            self._done(tid)

    def _done(self, tid: int) -> None:
        self.end_s[tid] = self.msgnet.engine.now
        for c in self.children.get(tid, ()):
            self.indeg[c] -= 1
            if self.indeg[c] == 0:
                self._launch(c)

    @property
    def task_latency_s(self) -> dict[int, float]:
        """Per-task ready-to-complete latency (queueing-inclusive)."""
        return {
            tid: end - self.start_s[tid] for tid, end in self.end_s.items()
        }
