"""Max-min fair-share rate solvers for the fluid network (netsim layer 2).

Two interchangeable implementations of progressive-filling ("water-
filling") max-min fairness over a flow's constraint set (wire links plus
the virtual receiver-egress / per-dimension IO links):

* ``ReferenceMaxMinSolver`` — the original pure-Python dict-based
  progressive filling, kept verbatim as the correctness oracle for the
  parity suite (``tests/test_netsim_solver.py``).  O(flows x links) of
  dict churn per recompute; fine for unit-scale scenarios, the bottleneck
  at pod scale and beyond.
* ``VectorizedMaxMinSolver`` — numpy water-filling over a CSR-style
  flow-group x link incidence that is maintained *incrementally* on
  ``flow_added`` / ``flow_removed`` (no per-recompute ``sorted(flows)``
  or dict-of-tuple rebuilds).  Flows with identical constraint multisets
  are aggregated into one *group* with a multiplicity — max-min gives
  identical flows identical rates, so one group row prices all of them
  (the "Rail-only"-style symmetric-traffic aggregation).  Each round of
  the filling loop freezes every link at the current minimum fair share
  simultaneously, so symmetric collectives resolve in O(1) rounds of
  O(nnz) numpy work.

Both solvers freeze links within a *relative* tolerance of the round's
best share (``level = best * (1 + 1e-9)``).  The previous absolute
``+ 1e-9`` epsilon over-froze links whose fair share is itself ~1e-9
bytes/s (tiny capacities / huge flow counts) — pinned by a regression
test in the parity suite.

The round-level freezing is exact: removing an at-``best`` consumer from
a link whose share was *above* the level can only raise that link's
share, so no link can drop to the level mid-round — snapshot semantics
and sequential semantics coincide, which is what makes the vectorized
solver bit-compatible (to fp accumulation order) with the reference.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Hashable

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from .flows import Flow, FluidNetwork

# relative freeze tolerance: links whose fair share is within this factor
# of the round's minimum freeze together (they are equal to fp noise)
LEVEL_RTOL = 1e-9

# bump whenever an allocator change can alter solved rates (and therefore
# measured calibration bandwidths) — part of the persistent calibration
# cache key (core/calib_cache.py), so stale on-disk profiles are dropped
# instead of silently served
SOLVER_VERSION = 1


class ReferenceMaxMinSolver:
    """Pure-Python progressive filling (the PR-1 implementation).

    Stateless between solves: every ``solve`` walks ``net.flows`` and
    rebuilds the per-link residual/count/membership dicts.  Kept as the
    oracle the vectorized solver must match to 1e-6.
    """

    name = "reference"

    def __init__(self, net: "FluidNetwork") -> None:
        self.net = net
        # fid -> bottleneck constraint from the latest solve (telemetry);
        # None while no telemetry recorder is attached
        self.last_attribution: dict[int, Hashable] | None = None

    # incremental notifications are no-ops for the stateless reference
    def flow_added(self, flow: "Flow") -> None:
        pass

    def flow_removed(self, flow: "Flow") -> None:
        pass

    def capacity_changed(self) -> None:
        pass

    def solve(self) -> list["Flow"]:
        """Set ``f.rate`` for every active flow; return the flowing ones."""
        net = self.net
        rec = net.telemetry is not None
        attr: dict[int, Hashable] | None = {} if rec else None
        active = [net.flows[k] for k in sorted(net.flows)]
        for f in active:
            f.rate = 0.0
        residual: dict[Hashable, float] = {}
        count: dict[Hashable, int] = {}
        flows_on: dict[Hashable, list["Flow"]] = {}
        for f in active:
            for l in f.constraints:
                if l not in residual:
                    residual[l] = net.constraint_capacity(l)
                    count[l] = 0
                    flows_on[l] = []
                count[l] += 1
                flows_on[l].append(f)
        frozen: set[int] = set()
        n_left = len(active)
        while n_left > 0:
            best = math.inf
            for l, c in count.items():
                if c > 0:
                    share = residual[l] / c
                    if share < best:
                        best = share
            if not math.isfinite(best):
                break
            level = best * (1 + LEVEL_RTOL)
            if rec:
                # round-start freeze level set: these are the constraints
                # that pin every flow frozen this round (round snapshot ==
                # sequential, see module docstring), so the canonical
                # attribution — min key among a flow's at-level
                # constraints — is solver-independent
                level_set = {
                    l
                    for l, c in count.items()
                    if c > 0 and residual[l] / c <= level
                }
            for l in list(count):
                if count[l] <= 0 or residual[l] / count[l] > level:
                    continue
                for f in flows_on[l]:
                    if f.fid in frozen:
                        continue
                    f.rate = best
                    frozen.add(f.fid)
                    n_left -= 1
                    if rec:
                        cands = level_set.intersection(f.constraints)
                        attr[f.fid] = min(cands) if cands else l
                    for fl in f.constraints:
                        residual[fl] = max(0.0, residual[fl] - best)
                        count[fl] -= 1
        self.last_attribution = attr
        return [f for f in active if f.rate > 0.0]


class VectorizedMaxMinSolver:
    """Numpy water-filling over an incrementally maintained group CSR.

    * ``_col`` interns every constraint key (wire link, virtual rx/io
      port) to a column id; capacities are materialized into one array,
      invalidated by ``capacity_changed`` (link failure / borrow links).
    * Flows with the same constraint *multiset* share a group slot; the
      group's multiplicity counts its members and a join/leave only bumps
      that count.  The CSR (indptr/indices/weights over live groups) is
      rebuilt only when the *set* of live groups changes.
    * ``solve`` runs the filling loop entirely on arrays: per round one
      share computation, one boolean freeze mask, and two ``np.add.at``
      scatter-updates for the frozen groups' consumption.
    """

    name = "vectorized"

    def __init__(self, net: "FluidNetwork") -> None:
        self.net = net
        self._col: dict[Hashable, int] = {}       # constraint key -> column
        self._keys: list[Hashable] = []           # column -> constraint key
        self._cap: np.ndarray = np.empty(0)       # column -> bytes/s
        self._cap_dirty = True
        # group slots (parallel lists; freed slots are recycled)
        self._g_key: list[tuple | None] = []      # slot -> group key
        self._g_cols: list[np.ndarray] = []       # slot -> column ids
        self._g_wts: list[np.ndarray] = []        # slot -> per-column counts
        self._g_mult: list[int] = []              # slot -> member count
        self._groups: dict[tuple, int] = {}       # group key -> slot
        self._free: list[int] = []
        self._slot_of: dict[int, int] = {}        # fid -> slot
        # CSR over live slots (rebuilt when the live-slot set changes)
        self._csr_dirty = True
        self._rows: np.ndarray = np.empty(0, dtype=np.int64)   # live slots
        self._indptr: np.ndarray = np.empty(0, dtype=np.int64)
        self._indices: np.ndarray = np.empty(0, dtype=np.int64)
        self._weights: np.ndarray = np.empty(0)
        self._row_of_nnz: np.ndarray = np.empty(0, dtype=np.int64)
        # fid -> bottleneck constraint from the latest solve (telemetry)
        self.last_attribution: dict[int, Hashable] | None = None

    # -- incremental incidence maintenance ---------------------------------
    def _col_of(self, key: Hashable) -> int:
        c = self._col.get(key)
        if c is None:
            c = len(self._keys)
            self._col[key] = c
            self._keys.append(key)
            self._cap_dirty = True
        return c

    def flow_added(self, flow: "Flow") -> None:
        counts: dict[int, int] = {}
        for l in flow.constraints:
            c = self._col_of(l)
            counts[c] = counts.get(c, 0) + 1
        gkey = tuple(sorted(counts.items()))
        slot = self._groups.get(gkey)
        if slot is None:
            slot = self._free.pop() if self._free else len(self._g_key)
            if slot == len(self._g_key):
                self._g_key.append(None)
                self._g_cols.append(np.empty(0, dtype=np.int64))
                self._g_wts.append(np.empty(0))
                self._g_mult.append(0)
            self._g_key[slot] = gkey
            self._g_cols[slot] = np.fromiter(
                counts.keys(), dtype=np.int64, count=len(counts)
            )
            self._g_wts[slot] = np.fromiter(
                counts.values(), dtype=np.float64, count=len(counts)
            )
            self._g_mult[slot] = 0
            self._groups[gkey] = slot
            self._csr_dirty = True
        self._g_mult[slot] += 1
        self._slot_of[flow.fid] = slot

    def flow_removed(self, flow: "Flow") -> None:
        slot = self._slot_of.pop(flow.fid, None)
        if slot is None:
            return
        self._g_mult[slot] -= 1
        if self._g_mult[slot] <= 0:
            gkey = self._g_key[slot]
            self._g_key[slot] = None
            del self._groups[gkey]
            self._free.append(slot)
            self._csr_dirty = True

    def capacity_changed(self) -> None:
        self._cap_dirty = True

    # -- lazy array materialization ----------------------------------------
    def _build_cap(self) -> None:
        net = self.net
        self._cap = np.fromiter(
            (net.constraint_capacity(k) for k in self._keys),
            dtype=np.float64,
            count=len(self._keys),
        )
        self._cap_dirty = False

    def _build_csr(self) -> None:
        rows = [s for s, k in enumerate(self._g_key) if k is not None]
        self._rows = np.asarray(rows, dtype=np.int64)
        nnz = [len(self._g_cols[s]) for s in rows]
        self._indptr = np.zeros(len(rows) + 1, dtype=np.int64)
        np.cumsum(nnz, out=self._indptr[1:])
        if rows:
            self._indices = np.concatenate([self._g_cols[s] for s in rows])
            self._weights = np.concatenate([self._g_wts[s] for s in rows])
        else:
            self._indices = np.empty(0, dtype=np.int64)
            self._weights = np.empty(0)
        self._row_of_nnz = np.repeat(
            np.arange(len(rows), dtype=np.int64),
            np.asarray(nnz, dtype=np.int64) if rows else 0,
        )
        self._csr_dirty = False

    # -- the water-filling loop --------------------------------------------
    def solve(self) -> list["Flow"]:
        net = self.net
        flows = net.flows
        rec = net.telemetry is not None
        if not flows:
            self.last_attribution = {} if rec else None
            return []
        if self._cap_dirty:
            self._build_cap()
        if self._csr_dirty:
            self._build_csr()
        n_g = len(self._rows)
        n_l = len(self._keys)
        mult = np.fromiter(
            (self._g_mult[s] for s in self._rows), dtype=np.float64, count=n_g
        )
        # per-nnz consumption weight: duplicate-link count x group size
        wt = self._weights * mult[self._row_of_nnz]
        count = np.zeros(n_l)
        np.add.at(count, self._indices, wt)
        residual = self._cap[:n_l].copy()
        rate = np.zeros(n_g)
        frozen = np.zeros(n_g, dtype=bool)
        slot_attr: dict[int, Hashable] = {}
        n_left = n_g
        while n_left > 0:
            with np.errstate(divide="ignore", invalid="ignore"):
                share = np.where(count > 0, residual / count, np.inf)
            best = share.min(initial=np.inf)
            if not math.isfinite(best):
                break
            level = best * (1 + LEVEL_RTOL)
            at_level = (count > 0) & (share <= level)
            hit = np.zeros(n_g, dtype=bool)
            hit[self._row_of_nnz[at_level[self._indices]]] = True
            new = hit & ~frozen
            if not new.any():       # numerical guard; cannot happen in exact
                break               # arithmetic (the best link has members)
            rate[new] = best
            frozen |= new
            n_left -= int(new.sum())
            if rec:
                # canonical bottleneck per newly frozen group: min key
                # among the group's constraints sitting at this round's
                # freeze level (matches the reference solver's round-start
                # level set — same arithmetic, same tuple ordering)
                keys = self._keys
                indptr = self._indptr
                indices = self._indices
                for row in np.nonzero(new)[0]:
                    cols = indices[indptr[row]:indptr[row + 1]]
                    cands = cols[at_level[cols]]
                    pick = cands if cands.size else cols
                    slot_attr[int(self._rows[row])] = min(
                        keys[c] for c in pick
                    )
            sel = new[self._row_of_nnz]
            np.add.at(residual, self._indices[sel], -best * wt[sel])
            np.add.at(count, self._indices[sel], -wt[sel])
            np.maximum(residual, 0.0, out=residual)
        # scatter group rates back onto the flow objects (as native floats
        # so downstream timestamps stay plain Python numbers)
        slot_rate = np.zeros(len(self._g_key))
        slot_rate[self._rows] = rate
        rates = slot_rate.tolist()
        slot_of = self._slot_of
        flowing = []
        if rec:
            attr: dict[int, Hashable] = {}
            for f in flows.values():
                slot = slot_of[f.fid]
                r = rates[slot]
                f.rate = r
                if r > 0.0:
                    flowing.append(f)
                key = slot_attr.get(slot)
                if key is not None:
                    attr[f.fid] = key
            self.last_attribution = attr
            return flowing
        for f in flows.values():
            r = rates[slot_of[f.fid]]
            f.rate = r
            if r > 0.0:
                flowing.append(f)
        return flowing


SOLVERS = {
    "reference": ReferenceMaxMinSolver,
    "vectorized": VectorizedMaxMinSolver,
}


def make_solver(name: str, net: "FluidNetwork"):
    try:
        return SOLVERS[name](net)
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; pick one of {sorted(SOLVERS)}"
        ) from None
