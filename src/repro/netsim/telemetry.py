"""Opt-in telemetry recorder for the netsim stack (observability layer).

A ``Telemetry`` instance threads through the event engine, the fluid
network, both max-min solvers and the ``Router`` and records what the
end-of-run scalars on ``NetSimResult`` cannot show:

* **per-link utilization timelines** — at every rate-resolve event the
  summed rate of each wire link is sampled into a piecewise-constant
  series (a sample holds until the next one), so the integral of a link's
  timeline equals its byte ledger exactly — the conservation property the
  property suite pins;
* **per-flow lifecycle traces** — launch, rate changes, completion /
  withdrawal, delivered bytes, per-member multiplicity;
* **bottleneck attribution** — for every flow, which constraint (wire
  link, receiver-egress ``rx`` port, per-dim ``io`` port) froze it in the
  water-filling, read directly from the solver's freeze step.  Both
  solvers emit the canonical attribution — the *smallest* constraint key
  (plain tuple order; every key is an int tuple) among the flow's
  constraints that sat at the round's freeze level when the flow froze —
  so the vectorized and reference solvers produce identical telemetry,
  with aggregate / symmetric groups expanded by their multiplicity in the
  throttle accounting;
* **router counters** — transfers, multi-path launches, borrow-path
  usage, congestion re-splits, failure-notification reroutes, with
  timestamped instants for failures and reroutes.

Exporters: :meth:`Telemetry.to_perfetto` writes a Chrome/Perfetto trace
JSON (counter tracks for the hot links, one span lane per collective
ring, async spans per routed transfer, instants for reroutes/failures —
load it at https://ui.perfetto.dev), and :meth:`Telemetry.summary`
returns a structured dict (per-dim utilization percentiles, top-k hot
links, per-constraint-class throttle seconds, stranded-byte audit).

The recorder is strictly opt-in: every hook in the hot paths is guarded
by a single ``is not None`` check, so a disabled run (``telemetry=None``,
the default everywhere) pays nothing — pinned by the
``netsim_telemetry_overhead`` scale benchmark.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Hashable

from .flows import IO_RX, IO_TX, RX_PORT, DirectedLink

if TYPE_CHECKING:  # pragma: no cover
    from .flows import Flow, FluidNetwork

# collective ring-step tags end in "s<step>" ("ar-dim0/r3s7"); stripping
# the step suffix yields the ring lane the steps run sequentially on
_STEP_SUFFIX = re.compile(r"s\d+$")


def constraint_class(key: Hashable) -> str:
    """``"link"`` (wire), ``"rx"`` (receiver egress) or ``"io"`` (per-dim
    IO port) for any constraint key the solvers emit."""
    k0 = key[0]
    if k0 == RX_PORT:
        return "rx"
    if k0 == IO_TX or k0 == IO_RX:
        return "io"
    return "link"


def constraint_name(key: Hashable) -> str:
    """Human-readable label for a constraint key."""
    k0 = key[0]
    if k0 == RX_PORT:
        return f"rx:{key[1]}"
    if k0 == IO_TX:
        return f"io_tx:d{key[1]}:n{key[2]}"
    if k0 == IO_RX:
        return f"io_rx:d{key[1]}:n{key[2]}"
    return f"{key[0]}->{key[1]}"


def _weighted_percentile(samples: "list[tuple[float, float]]", q: float) -> float:
    """Percentile of (value, weight) samples, weight-interpolated."""
    if not samples:
        return 0.0
    samples = sorted(samples)
    total = sum(w for _, w in samples)
    if total <= 0:
        return samples[-1][0]
    target = q * total
    acc = 0.0
    for v, w in samples:
        acc += w
        if acc >= target:
            return v
    return samples[-1][0]


@dataclass
class FlowTrace:
    """Lifecycle record of one fluid flow."""

    fid: int
    path: tuple[int, ...]
    size: float                          # bytes per member
    multiplicity: int
    start_s: float
    end_s: float | None = None
    delivered: float = 0.0               # bytes, member-expanded
    withdrawn: bool = False
    task: int | None = None              # DAG task tid, when known
    rates: list[tuple[float, float]] = field(default_factory=list)
    bottlenecks: list[tuple[float, Hashable]] = field(default_factory=list)

    @property
    def bottleneck(self) -> Hashable | None:
        """The constraint that froze this flow at its last rate solve."""
        return self.bottlenecks[-1][1] if self.bottlenecks else None


class Telemetry:
    """Recorder threaded through engine, network, solver and router.

    Create one, hand it to ``FluidNetwork(..., telemetry=tel)`` (or let
    ``NetSim(telemetry=True)`` do it), run, then read ``summary()`` /
    ``to_perfetto(path)``.  One instance records one network's run;
    virtual time restarts per run, so reuse across runs would alias
    timelines.
    """

    def __init__(self) -> None:
        self.net: "FluidNetwork | None" = None
        self.samples = 0                     # rate-resolve events recorded
        self.events_observed = 0             # engine events (observer hook)
        # piecewise-constant per-link rate series: a sample (t, rate)
        # holds until the next sample on that link
        self.link_series: dict[DirectedLink, list[tuple[float, float]]] = {}
        self._last_rate: dict[DirectedLink, float] = {}
        self.flow_traces: dict[int, FlowTrace] = {}
        # constraint key -> flow-seconds throttled (multiplicity-weighted)
        self.bottleneck_s: dict[Hashable, float] = {}
        self._open: dict[int, tuple[Hashable, int, float]] = {}
        self.task_labels: dict[int, str] = {}          # DAG tid -> tag
        self.bytes_withdrawn_unsent = 0.0
        # router-side counters and timestamped instants
        self.router_counters: dict[str, int] = {
            "transfers": 0,
            "subflow_launches": 0,
            "multipath_launches": 0,
            "borrow_path_launches": 0,
            "resplits": 0,
            "reroutes": 0,
            "link_failures": 0,
        }
        self.instants: list[tuple[float, str, dict]] = []
        self.transfer_spans: list[dict] = []   # finished routed transfers

    # -- wiring ------------------------------------------------------------
    def _attach(self, net: "FluidNetwork") -> None:
        if self.net is not None and self.net is not net:
            raise ValueError(
                "a Telemetry instance records one network; create a fresh "
                "one per run"
            )
        self.net = net
        net.engine.observer = self._on_event

    def _on_event(self, t: float) -> None:
        self.events_observed += 1

    @staticmethod
    def _task_of(meta: object) -> int | None:
        """DAG task tid from a flow's meta chain (("task", tid) directly,
        or via a routed Transfer's own meta)."""
        for _ in range(2):
            if (
                isinstance(meta, tuple)
                and len(meta) == 2
                and meta[0] == "task"
            ):
                return meta[1]
            meta = getattr(meta, "meta", None)
        return None

    # -- hooks: flow lifecycle (called by FluidNetwork) --------------------
    def flow_started(self, flow: "Flow") -> None:
        self.flow_traces[flow.fid] = FlowTrace(
            fid=flow.fid,
            path=flow.path,
            size=flow.size,
            multiplicity=flow.multiplicity,
            start_s=flow.start_s,
            task=self._task_of(flow.meta),
        )

    def flow_completed(self, flow: "Flow") -> None:
        now = self.net.engine.now
        tr = self.flow_traces.get(flow.fid)
        if tr is None:                      # degenerate flow: never started
            self.flow_started(flow)
            tr = self.flow_traces[flow.fid]
        tr.end_s = now
        tr.delivered = flow.total_bytes
        self._close_attr(flow.fid, now)

    def flow_withdrawn(self, flow: "Flow") -> None:
        now = self.net.engine.now
        tr = self.flow_traces.get(flow.fid)
        if tr is None:
            return
        tr.end_s = now
        tr.withdrawn = True
        unsent = max(0.0, flow.remaining) * flow.multiplicity
        tr.delivered = flow.total_bytes - unsent
        self.bytes_withdrawn_unsent += unsent
        self._close_attr(flow.fid, now)

    def _close_attr(self, fid: int, now: float) -> None:
        open_ = self._open.pop(fid, None)
        if open_ is not None:
            key, mult, since = open_
            if now > since:
                self.bottleneck_s[key] = (
                    self.bottleneck_s.get(key, 0.0) + (now - since) * mult
                )

    # -- hook: rate resolve (called by FluidNetwork._maxmin_rates) ---------
    def record_solve(
        self,
        now: float,
        flows: "dict[int, Flow]",
        attribution: "dict[int, Hashable] | None",
        flowing: "list[Flow]",
    ) -> None:
        """One water-filling resolution: sample link rates, refresh the
        per-flow bottleneck attribution, extend rate histories."""
        self.samples += 1
        # per-link rate sampling (changed links only; vanished links -> 0)
        used: dict[DirectedLink, float] = {}
        for f in flowing:
            r = f.rate
            for l in f.links:
                used[l] = used.get(l, 0.0) + r
        last = self._last_rate
        series = self.link_series
        for l, r in used.items():
            if last.get(l) != r:
                series.setdefault(l, []).append((now, r))
                last[l] = r
        for l, r in list(last.items()):
            if r != 0.0 and l not in used:
                series[l].append((now, 0.0))
                last[l] = 0.0
        # bottleneck attribution intervals (multiplicity-weighted)
        bs = self.bottleneck_s
        for key, mult, since in self._open.values():
            if now > since:
                bs[key] = bs.get(key, 0.0) + (now - since) * mult
        if attribution:
            self._open = {
                fid: (key, flows[fid].multiplicity, now)
                for fid, key in attribution.items()
                if fid in flows
            }
        else:
            self._open = {}
        # per-flow histories (on change only)
        traces = self.flow_traces
        for f in flows.values():
            tr = traces.get(f.fid)
            if tr is None:
                continue
            if not tr.rates or tr.rates[-1][1] != f.rate:
                tr.rates.append((now, f.rate))
            if attribution:
                key = attribution.get(f.fid)
                if key is not None and (
                    not tr.bottlenecks or tr.bottlenecks[-1][1] != key
                ):
                    tr.bottlenecks.append((now, key))

    # -- hooks: router (called by Router) ----------------------------------
    def record_launch(self, paths: list, switch_node: int | None) -> None:
        c = self.router_counters
        c["subflow_launches"] += len(paths)
        if len(paths) > 1:
            c["multipath_launches"] += 1
        if switch_node is not None and any(switch_node in p for p in paths):
            c["borrow_path_launches"] += 1

    def record_instant(self, name: str, args: dict) -> None:
        self.instants.append((self.net.engine.now, name, args))
        if name in self.router_counters:
            self.router_counters[name] += 1

    def record_transfer_done(self, t) -> None:
        self.transfer_spans.append(
            {
                "tid": t.tid,
                "src": t.src,
                "dst": t.dst,
                "size": t.size,
                "start_s": t.start_s,
                "end_s": t.end_s,
                "resplits": t.resplits,
                "task": self._task_of(t.meta),
            }
        )

    # -- derived views -----------------------------------------------------
    def _cap(self, link: DirectedLink) -> float:
        return self.net.capacity.get(link, 0.0) if self.net else 0.0

    def _segments(self, link: DirectedLink):
        """(t0, t1, rate) segments of a link's piecewise-constant series,
        closed at the engine's current time."""
        series = self.link_series.get(link)
        if not series:
            return
        end = self.net.engine.now
        for (t, r), (t_next, _) in zip(series, series[1:]):
            yield t, t_next, r
        t, r = series[-1]
        yield t, max(t, end), r

    def link_bytes(self, link: DirectedLink) -> float:
        """Integral of the link's rate timeline — must equal the fluid
        network's byte ledger for that link (conservation)."""
        return sum((t1 - t0) * r for t0, t1, r in self._segments(link))

    def peak_utilization(self, link: DirectedLink) -> float:
        """Highest utilization the link *held* (zero-duration transients
        between same-timestamp re-solves are skipped)."""
        cap = self._cap(link)
        if cap <= 0:
            return 0.0
        peak = 0.0
        for t0, t1, r in self._segments(link):
            if t1 > t0 and r > peak:
                peak = r
        return peak / cap

    def mean_utilization(self, link: DirectedLink) -> float:
        cap = self._cap(link)
        dur = self.net.engine.now if self.net else 0.0
        if cap <= 0 or dur <= 0:
            return 0.0
        return self.link_bytes(link) / (cap * dur)

    def flow_bottlenecks(self) -> dict[int, Hashable]:
        """fid -> the constraint that froze the flow at its last solve."""
        return {
            fid: tr.bottleneck
            for fid, tr in self.flow_traces.items()
            if tr.bottleneck is not None
        }

    # -- exporter: structured summary --------------------------------------
    def summary(self, *, top: int = 8) -> dict:
        """Structured run digest.  Schema (see README "Observability"):

        ``duration_s``, ``events``, ``solver_samples``;
        ``links``: ``per_dim`` {dim name: {p50, p99, max}} (time-weighted
        utilization over every link segment of the dim), ``top`` hot links
        [{link, dim, peak_util, mean_util, bytes}];
        ``bottlenecks``: ``by_class`` {link/rx/io: throttled flow-seconds,
        multiplicity-weighted}, ``top`` [[constraint, seconds], ...];
        ``flows``: launched/completed/withdrawn counts + the byte audit
        (requested == delivered + withdrawn_unsent + stranded; stranded
        must be ~0 on a drained run);
        ``router``: the counter dict + instants count.
        """
        net = self.net
        dur = net.engine.now if net else 0.0
        dim_names: dict[int, str] = (
            {i: d.name for i, d in enumerate(net.topo.dims)} if net else {}
        )

        per_dim_samples: dict[str, list[tuple[float, float]]] = {}
        link_rows = []
        for link in self.link_series:
            cap = self._cap(link)
            d = net._link_dim.get(link) if net else None
            dname = dim_names.get(d, "extra")
            if cap > 0:
                bucket = per_dim_samples.setdefault(dname, [])
                for t0, t1, r in self._segments(link):
                    if t1 > t0:
                        bucket.append((r / cap, t1 - t0))
            link_rows.append(
                {
                    "link": list(link),
                    "dim": dname,
                    "peak_util": round(self.peak_utilization(link), 6),
                    "mean_util": round(self.mean_utilization(link), 6),
                    "bytes": self.link_bytes(link),
                }
            )
        link_rows.sort(key=lambda r: -r["peak_util"])
        per_dim = {
            dname: {
                "p50": round(_weighted_percentile(samples, 0.50), 6),
                "p99": round(_weighted_percentile(samples, 0.99), 6),
                "max": round(max(v for v, _ in samples), 6),
            }
            for dname, samples in sorted(per_dim_samples.items())
        }

        by_class: dict[str, float] = {}
        for key, s in self.bottleneck_s.items():
            c = constraint_class(key)
            by_class[c] = by_class.get(c, 0.0) + s
        top_bn = sorted(
            self.bottleneck_s.items(), key=lambda kv: -kv[1]
        )[:top]

        requested = sum(
            tr.size * tr.multiplicity for tr in self.flow_traces.values()
        )
        delivered = sum(tr.delivered for tr in self.flow_traces.values())
        completed = sum(
            1
            for tr in self.flow_traces.values()
            if tr.end_s is not None and not tr.withdrawn
        )
        withdrawn = sum(1 for tr in self.flow_traces.values() if tr.withdrawn)
        stranded = requested - delivered - self.bytes_withdrawn_unsent

        return {
            "duration_s": dur,
            "events": net.engine.events_fired if net else 0,
            "solver_samples": self.samples,
            "links": {"per_dim": per_dim, "top": link_rows[:top]},
            "bottlenecks": {
                "by_class": {k: round(v, 9) for k, v in sorted(by_class.items())},
                "top": [
                    [constraint_name(k), round(s, 9)] for k, s in top_bn
                ],
            },
            "flows": {
                "launched": len(self.flow_traces),
                "completed": completed,
                "withdrawn": withdrawn,
                "bytes_requested": requested,
                "bytes_delivered": delivered,
                "bytes_withdrawn_unsent": self.bytes_withdrawn_unsent,
                "stranded_bytes": stranded,
            },
            "router": {
                **self.router_counters,
                "instants": len(self.instants),
                "transfers_done": len(self.transfer_spans),
            },
        }

    # -- exporter: Chrome/Perfetto trace JSON ------------------------------
    def to_perfetto(self, path: str | None = None, *, top_links: int = 16) -> dict:
        """Write a Chrome trace-event JSON loadable at ui.perfetto.dev.

        * pid 1 — one counter track per hot link (utilization 0..1);
        * pid 2 — one span lane per collective ring (ring steps are
          sequential by construction), spans labeled with the task tag;
        * pid 3 — async spans per routed transfer plus instant events for
          link failures and reroutes.

        Timestamps are virtual seconds scaled to microseconds.  Returns
        the trace dict; also writes it to ``path`` when given.
        """
        us = 1e6
        ev: list[dict] = [
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "links (utilization)"}},
            {"ph": "M", "pid": 2, "tid": 0, "name": "process_name",
             "args": {"name": "collective tasks"}},
            {"ph": "M", "pid": 3, "tid": 0, "name": "process_name",
             "args": {"name": "router transfers"}},
        ]
        # counter tracks for the hottest links (by peak utilization)
        hot = sorted(
            self.link_series,
            key=lambda l: -self.peak_utilization(l),
        )[:top_links]
        end = self.net.engine.now if self.net else 0.0
        for link in hot:
            cap = self._cap(link)
            d = self.net._link_dim.get(link) if self.net else None
            dname = (
                self.net.topo.dims[d].name
                if self.net is not None and d is not None
                else "extra"
            )
            name = f"link {link[0]}->{link[1]} [{dname}]"
            for t, r in self.link_series[link]:
                ev.append(
                    {"name": name, "ph": "C", "ts": t * us, "pid": 1,
                     "tid": 0, "args": {"util": r / cap if cap else 0.0}}
                )
            ev.append(
                {"name": name, "ph": "C", "ts": end * us, "pid": 1,
                 "tid": 0, "args": {"util": 0.0}}
            )
        # collective ring-step spans, one lane per ring
        lanes: dict[str, int] = {}
        for tr in self.flow_traces.values():
            if tr.task is None or tr.end_s is None:
                continue
            label = self.task_labels.get(tr.task, f"task{tr.task}")
            lane = _STEP_SUFFIX.sub("", label) or label
            tid = lanes.setdefault(lane, len(lanes) + 1)
            ev.append(
                {"name": label, "ph": "X", "ts": tr.start_s * us,
                 "dur": max(0.0, (tr.end_s - tr.start_s)) * us,
                 "pid": 2, "tid": tid,
                 "args": {"bytes": tr.size * tr.multiplicity,
                          "multiplicity": tr.multiplicity,
                          "withdrawn": tr.withdrawn}}
            )
        for lane, tid in lanes.items():
            ev.append(
                {"ph": "M", "pid": 2, "tid": tid, "name": "thread_name",
                 "args": {"name": lane}}
            )
        # routed transfers as async spans (overlap-safe), id = transfer id
        for span in self.transfer_spans:
            name = f"xfer {span['src']}->{span['dst']}"
            common = {"cat": "transfer", "name": name, "pid": 3, "tid": 0,
                      "id": span["tid"]}
            ev.append({**common, "ph": "b", "ts": span["start_s"] * us,
                       "args": {"bytes": span["size"],
                                "resplits": span["resplits"]}})
            ev.append({**common, "ph": "e", "ts": span["end_s"] * us})
        # instants: failures, reroutes
        for t, name, args in self.instants:
            ev.append(
                {"name": name, "ph": "i", "ts": t * us, "pid": 3, "tid": 0,
                 "s": "g", "args": args}
            )
        trace = {"traceEvents": ev, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as fh:
                json.dump(trace, fh)
        return trace


def perfetto_doc(
    counters: "dict[str, list[tuple[float, float]]]" = {},
    spans: "list[dict]" = [],
    instants: "list[tuple[float, str, dict]]" = [],
    *,
    time_scale: float = 1e6,
    path: str | None = None,
) -> dict:
    """Assemble a Chrome trace-event JSON from plain timeline data.

    The generic sibling of :meth:`Telemetry.to_perfetto` for producers
    that are not a FluidNetwork — e.g. the availability campaign's
    week-scale failure/goodput timelines.  ``counters`` maps track name
    to ``(t, value)`` samples (ph "C"); ``spans`` are dicts with
    ``name``/``start``/``end`` plus optional ``lane`` and ``args``
    (ph "X", one tid per lane); ``instants`` are ``(t, name, args)``
    (ph "i").  Times are scaled by ``time_scale`` into trace-event
    microseconds (1e6 = input in seconds; use 3600e6 for hours)."""
    ev: list[dict] = [
        {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
         "args": {"name": "counters"}},
        {"ph": "M", "pid": 2, "tid": 0, "name": "process_name",
         "args": {"name": "spans"}},
    ]
    for name, series in counters.items():
        for t, v in series:
            ev.append(
                {"name": name, "ph": "C", "ts": t * time_scale, "pid": 1,
                 "tid": 0, "args": {"value": v}}
            )
    lanes: dict[str, int] = {}
    for span in spans:
        lane = span.get("lane", span["name"])
        tid = lanes.setdefault(lane, len(lanes) + 1)
        ev.append(
            {"name": span["name"], "ph": "X",
             "ts": span["start"] * time_scale,
             "dur": max(0.0, span["end"] - span["start"]) * time_scale,
             "pid": 2, "tid": tid, "args": span.get("args", {})}
        )
    for lane, tid in lanes.items():
        ev.append(
            {"ph": "M", "pid": 2, "tid": tid, "name": "thread_name",
             "args": {"name": lane}}
        )
    for t, name, args in instants:
        ev.append(
            {"name": name, "ph": "i", "ts": t * time_scale, "pid": 2,
             "tid": 0, "s": "g", "args": args}
        )
    trace = {"traceEvents": ev, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as fh:
            json.dump(trace, fh)
    return trace
