"""Rack-coarsened SuperPod topologies for multi-pod netsim runs.

A 8192-chip SuperPod is far beyond flow-level simulation at chip
granularity (a single 1024-chip pod already compiles ~60k-task ring DAGs).
The cross-pod questions the planner asks — how fast is a DP AllReduce over
the HRS Clos tier, how much does inter-rack contention cost at multi-pod
scale — do not depend on intra-rack detail, so this module coarsens the
topology the way RailX-style hyper-scale studies do: **racks (or whole
pods) become super-nodes**, with link capacities aggregated from
``core/topology.SuperPod``:

* the inter-rack full-mesh dims (Z, A) keep their clique structure, one
  super-link per rack pair carrying the whole trunk
  (``chips_per_rack x lanes_per_peer`` — exactly the paper's Fig. 8-(d)
  LRS trunk aggregation);
* the pod-level HRS Clos tier becomes one extra "P" dimension.  A
  non-blocking switch tier is NOT a mesh: any single rack pair may burst
  the full ``uplink_lanes_per_rack`` bandwidth, while each rack's
  *aggregate* injection/ejection into the tier is bounded by that same
  uplink.  The coarse mesh therefore gives the P dimension full-uplink
  per-peer capacity plus a per-node IO cap (``FluidNetwork.dim_io_gbs``)
  of one uplink per direction.

What coarsening loses, by construction: intra-rack (X, Y) contention and
incast detail — every rack is a perfect fluid source/sink.  Calibrations
of the intra-rack "model" axis must keep running on the chip-level pod
topology; the coarse mesh is for the "data"/"pod" axes
(``core.perf_model.NetsimPerfModel`` composes both automatically when
given a ``superpod=``).

``coarse_calibrated_profile`` converts between chip units and super-node
units: a rack aggregates ``chips_per_node`` chips' payloads (64 DP groups
of S bytes each behave like one allreduce of 64*S at rack granularity),
so it measures with ``per_chip_bytes * chips_per_node`` and divides the
resulting bandwidth back down to per-chip GB/s — the units ``CommModel``
carries.

**Mixed granularity** (``coarsen_superpod(..., detail_racks=(r, ...))``):
pure coarsening's blind spot is intra-rack contention — every rack is a
perfect fluid source/sink, so coarse runs cannot price model-axis
interference from cross-pod traffic.  A :class:`MixedMesh` keeps the
designated racks at chip granularity — real K_x/K_y cliques with
per-chip links — inside an otherwise rack-coarsened SuperPod, splicing
each detail chip's trunk/uplink SHARE onto the coarse Z/A/P dimensions
(a chip carries ``1/chips_per_rack`` of its rack's super-link to every
coarse peer; two detail racks that are peers pair chips index-to-index,
the Fig. 8-(d) trunk lanes).  This is the Rail-only / RailX evaluation
shape: fine-grained intra-domain detail composed with aggregated
inter-domain capacity.  ``mixed_calibrated_profile`` measures the model
axis INSIDE the embedded rack — optionally while a cross-pod DP
background AllReduce crosses the same rack's uplinks
(``background_per_chip_bytes``), which is what finally exposes
ejection-port and trunk sharing between DCN traffic and the TP/SP
domain.  With ``detail_racks=()`` nothing changes: the coarse-only path
is byte-for-byte the PR-4 construction (regression-pinned).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..core.cost_model import COLLECTIVE_SHAPES, CalibrationProfile, Routing
from ..core.topology import (
    DimSpec,
    NDFullMesh,
    OPTICAL_1KM,
    SuperPod,
)

COARSEN_LEVELS = ("rack", "pod")


class MixedMesh:
    """A rack-coarsened SuperPod with designated racks at chip granularity.

    Node numbering: coarse super-nodes keep their ids from the pure
    coarse mesh (``coarse``, 0..R-1); each detail rack ``r`` contributes
    ``chips_per_rack`` chip nodes at ``detail_base[r] + local`` (local =
    the standalone 2D rack mesh's row-major id) and its own coarse id is
    left DANGLING — no links touch it, so no flow can route through it
    (``netsim.collectives.splice_dag`` rewrites every coarse-DAG
    reference to the chips).

    Boundary capacities splice each chip onto the coarse dims: a chip
    carries its ``1/chips_per_rack`` share of the rack's super-link to
    every coarse Z/A peer (exactly the chip-level ``lanes_per_peer``, the
    trunk aggregation run backwards) and of the HRS uplink to every P
    peer; the per-chip HRS IO cap is the same uplink share, so the rack's
    aggregate cap is preserved (64 x uplink/64 = uplink).

    Not a Hamming graph — instead of ``core/apr``'s coordinate-based
    enumeration it provides the graph-generic hooks the netsim layers
    dispatch on: ``apr_shortest_paths`` / ``apr_all_paths`` (BFS shortest
    paths + single-relay detours, already loop-free so the Router skips
    TFC admission), ``hop_distance`` (BFS, for failure notification),
    ``link_gbs`` (heterogeneous capacities) and ``node_rx_gbs``
    (chip-level vs rack-level ejection bandwidths for ``rx_gbs="auto"``).
    """

    MAX_ENUM = 24           # shortest-path enumeration cap per (src, dst)

    def __init__(
        self,
        pod: NDFullMesh,
        coarse: NDFullMesh,
        detail_racks: tuple[int, ...],
    ) -> None:
        from .flows import default_rx_gbs  # deferred: no cycle at init

        self.pod = pod
        self.coarse = coarse
        self.rack_topo = NDFullMesh(dims=pod.dims[:2])
        self.chips_per_rack = self.rack_topo.num_nodes
        self.detail_racks = tuple(detail_racks)
        self.detail_base: dict[int, int] = {}
        base = coarse.num_nodes
        for r in self.detail_racks:
            self.detail_base[r] = base
            base += self.chips_per_rack
        self.num_nodes = base
        nc = coarse.ndim
        self.dims = coarse.dims + self.rack_topo.dims
        self._adj: dict[int, dict[int, int]] = {}     # u -> {v: dim}
        self._gbs: dict[tuple[int, int], float] = {}  # directed link -> GB/s
        self._dist_cache: dict[int, dict[int, int]] = {}
        dset = set(self.detail_racks)
        # per-chip share of each coarse dim's super-link: Z/A trunks give
        # back exactly the chip-level lanes_per_peer, the HRS "P" dim the
        # chip's uplink share
        share = {
            i: d.gbs_per_peer / self.chips_per_rack
            for i, d in enumerate(coarse.dims)
        }
        for u, v, d in coarse.links():
            if u not in dset and v not in dset:
                self._add_link(u, v, d, coarse.dims[d].gbs_per_peer)
        for r in self.detail_racks:
            for d in range(nc):
                for peer in coarse.neighbors(r, d):
                    if peer in dset:
                        if peer < r:
                            continue      # added once, from the lower id
                        for k in range(self.chips_per_rack):
                            self._add_link(
                                self.detail_base[r] + k,
                                self.detail_base[peer] + k,
                                d,
                                share[d],
                            )
                    else:
                        for k in range(self.chips_per_rack):
                            self._add_link(
                                self.detail_base[r] + k, peer, d, share[d]
                            )
            b = self.detail_base[r]
            for u, v, d in self.rack_topo.links():
                self._add_link(
                    b + u, b + v, nc + d, self.rack_topo.dims[d].gbs_per_peer
                )
        rack_rx = default_rx_gbs(coarse)
        chip_rx = max(d.gbs_total for d in self.rack_topo.dims)
        self.node_rx_gbs: dict[int, float] = {}
        for n in range(coarse.num_nodes):
            if n not in dset:
                self.node_rx_gbs[n] = rack_rx
        for r in self.detail_racks:
            for k in range(self.chips_per_rack):
                self.node_rx_gbs[self.detail_base[r] + k] = chip_rx

    def _add_link(self, u: int, v: int, dim: int, gbs: float) -> None:
        self._adj.setdefault(u, {})[v] = dim
        self._adj.setdefault(v, {})[u] = dim
        self._gbs[(u, v)] = gbs
        self._gbs[(v, u)] = gbs

    # -- NDFullMesh-facing surface the netsim layers consume ---------------
    @property
    def ndim(self) -> int:
        return len(self.dims)

    def links(self, dim: int | None = None):
        """Iterate (u, v, dim) over every link, u < v."""
        for u in sorted(self._adj):
            for v, d in sorted(self._adj[u].items()):
                if u < v and (dim is None or d == dim):
                    yield u, v, d

    def link_gbs(self, u: int, v: int) -> float:
        return self._gbs[(u, v)]

    def expand(self, node: int) -> tuple[int, ...] | None:
        """Member chip ids of a detail rack's coarse id (None otherwise) —
        the ``splice_dag`` expansion function."""
        b = self.detail_base.get(node)
        if b is None:
            return None
        return tuple(range(b, b + self.chips_per_rack))

    def chips_of(self, rack: int) -> tuple[int, ...]:
        chips = self.expand(rack)
        if chips is None:
            raise KeyError(f"rack {rack} is not a detail rack")
        return chips

    # -- graph-generic APR hooks -------------------------------------------
    def _dists(self, src: int) -> dict[int, int]:
        d = self._dist_cache.get(src)
        if d is None:
            d = {src: 0}
            frontier = [src]
            while frontier:
                nxt = []
                for u in frontier:
                    for v in self._adj.get(u, ()):
                        if v not in d:
                            d[v] = d[u] + 1
                            nxt.append(v)
                frontier = nxt
            self._dist_cache[src] = d
        return d

    def hop_distance(self, u: int, v: int) -> int:
        dist = self._dists(u).get(v)
        if dist is None:
            raise ValueError(f"{u} and {v} are disconnected")
        return dist

    def _hop_order(self, u: int) -> list[int]:
        """Neighbor iteration order: detail chips BEFORE coarse super-
        nodes.  Ties in path length between two embedded chips exist
        through ANY adjacent coarse peer (it neighbors all 64 chips),
        but those relays ride 1/chips_per_rack trunk shares that also
        carry real cross-pod traffic — the intra-rack clique links are
        both the faithful route and ~5x wider, so they must win the
        Router's in-order link-disjoint path selection."""
        first_coarse = self.coarse.num_nodes
        return sorted(self._adj.get(u, ()), key=lambda v: (v < first_coarse, v))

    def apr_shortest_paths(self, src: int, dst: int) -> list[tuple[int, ...]]:
        """All shortest src->dst paths (BFS DAG walk), capped at
        ``MAX_ENUM``; deterministic order, chip-relayed paths before
        coarse-relayed ties (see :meth:`_hop_order`)."""
        if src == dst:
            return [(src,)]
        dist = self._dists(dst)
        if src not in dist:
            return []
        paths: list[tuple[int, ...]] = []

        def walk(u: int, acc: list[int]) -> None:
            if len(paths) >= self.MAX_ENUM:
                return
            if u == dst:
                paths.append(tuple(acc))
                return
            du = dist[u]
            for v in self._hop_order(u):
                if dist.get(v, math.inf) == du - 1 and v not in acc:
                    acc.append(v)
                    walk(v, acc)
                    acc.pop()

        walk(src, [src])
        return paths

    def apr_all_paths(self, src: int, dst: int) -> list[tuple[int, ...]]:
        """Shortest paths + single-relay detours (replace one hop u-v by
        u-w-v through a common neighbor w) — the APR all-path set of a
        non-Hamming mesh.  Simple loop-free paths by construction."""
        sp = self.apr_shortest_paths(src, dst)
        out = list(sp)
        seen = set(out)
        for p in sp[:4]:
            for i in range(len(p) - 1):
                u, v = p[i], p[i + 1]
                for w in self._hop_order(u):
                    if w in p or v not in self._adj.get(w, ()):
                        continue
                    cand = p[: i + 1] + (w,) + p[i + 1 :]
                    if cand not in seen:
                        seen.add(cand)
                        out.append(cand)
                if len(out) >= 2 * self.MAX_ENUM:
                    return out
        return out


@dataclass(frozen=True)
class CoarseMesh:
    """A coarsened SuperPod: super-node topology + unit conversions.

    ``axis_dims`` maps the logical calibration axes onto the coarse dims
    (the coarse layout differs from the chip-level pod convention), and
    ``dim_io_gbs`` carries the per-super-node IO caps of the switched
    (HRS) dims — hand both to ``NetSim`` / ``FluidNetwork``.  With
    ``detail_racks`` set, ``topo`` is a :class:`MixedMesh` (those racks
    at chip granularity) and the HRS IO caps become per-node dicts.
    """

    topo: "NDFullMesh | MixedMesh"
    chips_per_node: int
    axis_dims: dict[str, tuple[int, ...]]
    dim_io_gbs: "dict[int, float | dict[int, float]]" = field(
        default_factory=dict
    )
    level: str = "rack"
    detail_racks: tuple[int, ...] = ()

    @property
    def num_chips(self) -> int:
        nodes = getattr(self.topo, "coarse", self.topo)
        return nodes.num_nodes * self.chips_per_node


def coarsen_superpod(
    sp: SuperPod,
    *,
    level: str = "rack",
    detail_racks: "tuple[int, ...] | list[int]" = (),
) -> CoarseMesh:
    """Coarsen ``sp`` to rack- or pod-granularity super-nodes.

    * ``"rack"`` — nodes are racks, dims = the pod's inter-rack dims with
      trunk-aggregated capacities plus the HRS "P" dimension (IO-capped).
    * ``"pod"`` — nodes are whole pods, a single HRS "P" dimension whose
      per-node IO cap is the pod's aggregate uplink.

    ``detail_racks`` (rack-level only) keeps the named racks — ids in the
    coarse numbering, rack 0 = coarse node 0 = (Z=0, A=0, pod 0) — at
    chip granularity inside the coarse mesh (see :class:`MixedMesh`).
    ``detail_racks=()`` reproduces the pure-coarse construction exactly.
    """
    if level not in COARSEN_LEVELS:
        raise ValueError(f"unknown coarsening level {level!r}; pick from {COARSEN_LEVELS}")
    if detail_racks and level != "rack":
        raise ValueError("detail_racks needs rack-level coarsening")
    pod = sp.pod
    uplink_gbs = sp.uplink_lanes_per_rack * OPTICAL_1KM.gbps_per_lane
    if level == "pod":
        pod_uplink = uplink_gbs * sp.racks_per_pod
        topo = NDFullMesh(
            dims=(
                DimSpec("P", sp.n_pods, OPTICAL_1KM, sp.uplink_lanes_per_rack * sp.racks_per_pod),
            )
        )
        return CoarseMesh(
            topo=topo,
            chips_per_node=pod.num_nodes,
            axis_dims={"pod": (0,)},
            dim_io_gbs={0: pod_uplink},
            level=level,
        )
    if pod.ndim <= 2:
        raise ValueError("rack-level coarsening needs a pod with inter-rack dims")
    chips_per_rack = pod.shape[0] * pod.shape[1]
    dims: list[DimSpec] = []
    for d in pod.dims[2:]:
        # one super-link per rack pair = the aggregated trunk of all
        # chips_per_rack point-to-point allocations (Fig. 8-(d))
        dims.append(
            DimSpec(d.name, d.size, d.link, d.lanes_per_peer * chips_per_rack)
        )
    axis_dims: dict[str, tuple[int, ...]] = {
        "data": tuple(range(len(dims)))
    }
    dim_io: dict = {}
    if sp.n_pods > 1:
        hrs_dim = len(dims)
        # non-blocking Clos: full uplink per peer PAIR, one uplink of
        # aggregate IO per rack (the dim_io cap)
        dims.append(DimSpec("P", sp.n_pods, OPTICAL_1KM, sp.uplink_lanes_per_rack))
        axis_dims["pod"] = (hrs_dim,)
        dim_io[hrs_dim] = uplink_gbs
    coarse_topo = NDFullMesh(dims=tuple(dims))
    if not detail_racks:
        return CoarseMesh(
            topo=coarse_topo,
            chips_per_node=chips_per_rack,
            axis_dims=axis_dims,
            dim_io_gbs=dim_io,
            level=level,
        )
    detail = tuple(sorted(set(int(r) for r in detail_racks)))
    for r in detail:
        if not (0 <= r < coarse_topo.num_nodes):
            raise ValueError(
                f"detail rack {r} out of range for the "
                f"{coarse_topo.num_nodes}-rack coarse mesh"
            )
    mm = MixedMesh(pod, coarse_topo, detail)
    mixed_io: dict = {}
    if sp.n_pods > 1:
        hrs_dim = coarse_topo.ndim - 1
        # heterogeneous HRS caps: coarse racks keep the whole uplink, each
        # detail chip is bounded by its own uplink share (their sum equals
        # the rack's cap, so rack-level accounting is preserved)
        caps = {
            r: uplink_gbs
            for r in range(coarse_topo.num_nodes)
            if r not in set(detail)
        }
        for r in detail:
            for c in mm.chips_of(r):
                caps[c] = uplink_gbs / chips_per_rack
        mixed_io[hrs_dim] = caps
    model_dims = (coarse_topo.ndim, coarse_topo.ndim + 1)
    return CoarseMesh(
        topo=mm,
        chips_per_node=chips_per_rack,
        axis_dims={**axis_dims, "model": model_dims},
        dim_io_gbs=mixed_io,
        level=level,
        detail_racks=detail,
    )


def coarse_netsim(
    cm: CoarseMesh,
    *,
    routing: Routing = Routing.DETOUR,
    latency_s: float = 5e-6,
    rx_gbs: "float | str | None" = "auto",
    solver: str = "vectorized",
    telemetry: bool = False,
    **kw,
):
    """A ``NetSim`` over the coarse topology with the coarse axis layout
    and the HRS IO caps pre-wired.  ``telemetry=True`` records link
    timelines / bottleneck attribution exactly as on chip-level meshes
    (coarse trunk links show up as one capacity-aggregated link each)."""
    from .api import NetSim  # deferred: avoid import cycle at package init

    return NetSim(
        cm.topo,
        routing=routing,
        latency_s=latency_s,
        rx_gbs=rx_gbs,
        solver=solver,
        telemetry=telemetry,
        axis_dims=cm.axis_dims,
        dim_io_gbs=cm.dim_io_gbs or None,
        **kw,
    )


def coarse_calibrated_profile(
    cm: CoarseMesh,
    per_chip_bytes: float = 64e6,
    *,
    comm=None,
    axis_sizes: dict[str, int] | None = None,
    widths: dict | None = None,
    axes: tuple[str, ...] | None = None,
    shapes: tuple[str, ...] = COLLECTIVE_SHAPES,
    sim=None,
    **netsim_kw,
) -> CalibrationProfile:
    """Per-chip effective GB/s per (axis, shape), measured at super-node
    granularity: payloads are scaled up by ``chips_per_node`` (a rack
    carries its chips' aggregate collective traffic) and the measured
    bandwidth scaled back down to per-chip units."""
    sim = sim or coarse_netsim(cm, **netsim_kw)
    prof = sim.calibrated_profile(
        per_chip_bytes * cm.chips_per_node,
        comm=comm,
        axis_sizes=axis_sizes,
        widths=widths,
        axes=axes,
        shapes=shapes,
    )
    return CalibrationProfile(
        gbs={k: g / cm.chips_per_node for k, g in prof.gbs.items()}
    )


# ---------------------------------------------------------------------------
# mixed granularity: one (or more) chip-level racks inside the coarse mesh
# ---------------------------------------------------------------------------


def mixed_netsim(
    cm: CoarseMesh,
    *,
    routing: Routing = Routing.DETOUR,
    latency_s: float = 5e-6,
    rx_gbs: "float | str | None" = "auto",
    solver: str = "vectorized",
    telemetry: bool = False,
    **kw,
):
    """A ``NetSim`` over a mixed-granularity mesh: heterogeneous per-node
    ejection caps ("auto" rx resolves to the MixedMesh's per-node dict)
    and per-node HRS IO caps pre-wired."""
    if not isinstance(cm.topo, MixedMesh):
        raise TypeError("mixed_netsim needs a coarsening with detail_racks")
    return coarse_netsim(
        cm,
        routing=routing,
        latency_s=latency_s,
        rx_gbs=rx_gbs,
        solver=solver,
        telemetry=telemetry,
        **kw,
    )


def cross_pod_background_dag(
    cm: CoarseMesh,
    per_chip_bytes: float,
    *,
    rack: int | None = None,
    tag: str = "bg-cross-pod-dp",
):
    """Cross-pod DP background traffic: a rack-granularity AllReduce over
    the HRS ("P") clique CONTAINING the detail rack, spliced onto its
    chips — so the background demonstrably crosses the embedded rack's
    uplinks and shares its chips' ejection ports.  ``None`` on a
    single-pod SuperPod (no HRS tier to cross)."""
    from .collectives import clique_nodes, ring_allreduce, splice_dag

    mm = cm.topo
    if not isinstance(mm, MixedMesh):
        raise TypeError("cross_pod_background_dag needs detail_racks")
    rack = cm.detail_racks[0] if rack is None else rack
    pod_dims = cm.axis_dims.get("pod")
    if not pod_dims:
        return None
    hrs = pod_dims[0]
    coords = mm.coarse.coords(rack)
    fixed = {i: coords[i] for i in range(mm.coarse.ndim) if i != hrs}
    nodes = clique_nodes(mm.coarse, hrs, fixed)
    dag = ring_allreduce(
        mm.coarse, nodes, per_chip_bytes * cm.chips_per_node, tag=tag
    )
    return splice_dag(dag, mm.expand)


def mixed_calibrated_profile(
    cm: CoarseMesh,
    per_chip_bytes: float = 64e6,
    *,
    comm=None,
    axis_sizes: dict[str, int] | None = None,
    widths: dict | None = None,
    axes: tuple[str, ...] | None = None,
    shapes: tuple[str, ...] = COLLECTIVE_SHAPES,
    background_per_chip_bytes: float = 0.0,
    detail_rack: int | None = None,
    sim=None,
    **netsim_kw,
) -> CalibrationProfile:
    """Per-chip effective GB/s per (axis, shape) on a MIXED-granularity
    mesh.

    * ``"model"`` — measured INSIDE the embedded chip-level rack (the
      first detail rack, or ``detail_rack``): the DAG is compiled on the
      standalone 2D rack mesh by the standard chip-level conventions
      (cross-dim grid rings for full planes, hierarchical schedules for
      partial widths, the Fig. 14 relay A2A) and remapped onto the
      embedded rack's node ids.  With ``background_per_chip_bytes > 0`` a
      cross-pod DP AllReduce over the rack's HRS clique runs
      CONCURRENTLY on the same network, so the measurement prices the
      model-axis interference from DCN traffic — the ejection-port and
      trunk sharing neither the pure-coarse nor the pure-chip path can
      see.
    * ``"data"`` / ``"pod"`` — compiled at super-node granularity on the
      coarse companion topology (payloads scaled by ``chips_per_node``
      exactly like ``coarse_calibrated_profile``) and SPLICED across the
      granularity boundary, so ring/A2A steps touching a detail rack run
      as its chips' trunk shares.
    """
    from .api import NetSim
    from .collectives import remap_dag, splice_dag

    mm = cm.topo
    if not isinstance(mm, MixedMesh):
        raise TypeError(
            "mixed_calibrated_profile needs a coarsening with detail_racks"
        )
    rack = cm.detail_racks[0] if detail_rack is None else detail_rack
    sim = sim or mixed_netsim(cm, **netsim_kw)
    if axis_sizes is None and comm is not None:
        axis_sizes = {k: a.size for k, a in comm.axes.items()}
    sizes = axis_sizes or {"model": 16, "data": 16}

    # DAG compilers: NetSim instances used only to build calibration DAGs
    # with the canonical width/footprint conventions
    local = NetSim(mm.rack_topo, axis_dims={"model": (0, 1)})
    coarse = NetSim(
        mm.coarse,
        axis_dims={k: v for k, v in cm.axis_dims.items() if k != "model"},
    )
    base = mm.detail_base[rack]
    bg_dag = None
    if background_per_chip_bytes > 0:
        bg_dag = cross_pod_background_dag(
            cm, background_per_chip_bytes, rack=rack
        )
        if bg_dag is None or not bg_dag.tasks:
            # a single-pod SuperPod has no HRS tier to cross: measuring
            # "with background" would silently return the idle numbers
            raise ValueError(
                "background_per_chip_bytes > 0 needs a multi-pod SuperPod "
                "(no cross-pod dimension to run DP background over)"
            )

    axis_dims = dict(cm.axis_dims)
    if axes is not None:
        axis_dims = {k: v for k, v in axis_dims.items() if k in axes}
    gbs: dict[tuple[str, str], float] = {}
    for axis, dims in axis_dims.items():
        n = sizes.get(axis, 16)
        for shape in NetSim._measured_shapes(shapes):
            w = NetSim._width_of(widths, axis, shape)
            tag = f"mixed-cal-{axis}-{shape}"
            if axis == "model":
                dag = local._axis_shape_dag(
                    (0, 1), shape, per_chip_bytes, w, tag
                )
                if dag is not None and dag.tasks:
                    dag = remap_dag(dag, lambda l, b=base: b + l)
            else:
                dag = coarse._axis_shape_dag(
                    dims, shape, per_chip_bytes * cm.chips_per_node, w, tag
                )
                if dag is not None and dag.tasks:
                    dag = splice_dag(dag, mm.expand)
            if dag is None or not dag.tasks:
                continue
            if axis == "model" and bg_dag is not None and bg_dag.tasks:
                t = sim.run_dags([dag, bg_dag])[0].makespan_s
            else:
                t = sim.run_dag(dag).makespan_s
            if t <= 0:
                continue
            # unit conversion: coarse-axis payloads were scaled up by
            # chips_per_node and the bandwidth scales back down by the
            # same factor, so per-chip wire bytes / time works for both
            wire = NetSim._wire_fraction(shape, n) * per_chip_bytes
            gbs[(axis, shape)] = wire / t / 1e9
        NetSim._alias_reduce_scatter(gbs, axis, shapes)
    return CalibrationProfile(gbs=gbs)
