"""Rack-coarsened SuperPod topologies for multi-pod netsim runs.

A 8192-chip SuperPod is far beyond flow-level simulation at chip
granularity (a single 1024-chip pod already compiles ~60k-task ring DAGs).
The cross-pod questions the planner asks — how fast is a DP AllReduce over
the HRS Clos tier, how much does inter-rack contention cost at multi-pod
scale — do not depend on intra-rack detail, so this module coarsens the
topology the way RailX-style hyper-scale studies do: **racks (or whole
pods) become super-nodes**, with link capacities aggregated from
``core/topology.SuperPod``:

* the inter-rack full-mesh dims (Z, A) keep their clique structure, one
  super-link per rack pair carrying the whole trunk
  (``chips_per_rack x lanes_per_peer`` — exactly the paper's Fig. 8-(d)
  LRS trunk aggregation);
* the pod-level HRS Clos tier becomes one extra "P" dimension.  A
  non-blocking switch tier is NOT a mesh: any single rack pair may burst
  the full ``uplink_lanes_per_rack`` bandwidth, while each rack's
  *aggregate* injection/ejection into the tier is bounded by that same
  uplink.  The coarse mesh therefore gives the P dimension full-uplink
  per-peer capacity plus a per-node IO cap (``FluidNetwork.dim_io_gbs``)
  of one uplink per direction.

What coarsening loses, by construction: intra-rack (X, Y) contention and
incast detail — every rack is a perfect fluid source/sink.  Calibrations
of the intra-rack "model" axis must keep running on the chip-level pod
topology; the coarse mesh is for the "data"/"pod" axes
(``core.perf_model.NetsimPerfModel`` composes both automatically when
given a ``superpod=``).

``coarse_calibrated_profile`` converts between chip units and super-node
units: a rack aggregates ``chips_per_node`` chips' payloads (64 DP groups
of S bytes each behave like one allreduce of 64*S at rack granularity),
so it measures with ``per_chip_bytes * chips_per_node`` and divides the
resulting bandwidth back down to per-chip GB/s — the units ``CommModel``
carries.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..core.cost_model import COLLECTIVE_SHAPES, CalibrationProfile, Routing
from ..core.topology import (
    DimSpec,
    NDFullMesh,
    OPTICAL_1KM,
    SuperPod,
)

COARSEN_LEVELS = ("rack", "pod")


@dataclass(frozen=True)
class CoarseMesh:
    """A coarsened SuperPod: super-node topology + unit conversions.

    ``axis_dims`` maps the logical calibration axes onto the coarse dims
    (the coarse layout differs from the chip-level pod convention), and
    ``dim_io_gbs`` carries the per-super-node IO caps of the switched
    (HRS) dims — hand both to ``NetSim`` / ``FluidNetwork``.
    """

    topo: NDFullMesh
    chips_per_node: int
    axis_dims: dict[str, tuple[int, ...]]
    dim_io_gbs: dict[int, float] = field(default_factory=dict)
    level: str = "rack"

    @property
    def num_chips(self) -> int:
        return self.topo.num_nodes * self.chips_per_node


def coarsen_superpod(sp: SuperPod, *, level: str = "rack") -> CoarseMesh:
    """Coarsen ``sp`` to rack- or pod-granularity super-nodes.

    * ``"rack"`` — nodes are racks, dims = the pod's inter-rack dims with
      trunk-aggregated capacities plus the HRS "P" dimension (IO-capped).
    * ``"pod"`` — nodes are whole pods, a single HRS "P" dimension whose
      per-node IO cap is the pod's aggregate uplink.
    """
    if level not in COARSEN_LEVELS:
        raise ValueError(f"unknown coarsening level {level!r}; pick from {COARSEN_LEVELS}")
    pod = sp.pod
    uplink_gbs = sp.uplink_lanes_per_rack * OPTICAL_1KM.gbps_per_lane
    if level == "pod":
        pod_uplink = uplink_gbs * sp.racks_per_pod
        topo = NDFullMesh(
            dims=(
                DimSpec("P", sp.n_pods, OPTICAL_1KM, sp.uplink_lanes_per_rack * sp.racks_per_pod),
            )
        )
        return CoarseMesh(
            topo=topo,
            chips_per_node=pod.num_nodes,
            axis_dims={"pod": (0,)},
            dim_io_gbs={0: pod_uplink},
            level=level,
        )
    if pod.ndim <= 2:
        raise ValueError("rack-level coarsening needs a pod with inter-rack dims")
    chips_per_rack = pod.shape[0] * pod.shape[1]
    dims: list[DimSpec] = []
    for d in pod.dims[2:]:
        # one super-link per rack pair = the aggregated trunk of all
        # chips_per_rack point-to-point allocations (Fig. 8-(d))
        dims.append(
            DimSpec(d.name, d.size, d.link, d.lanes_per_peer * chips_per_rack)
        )
    axis_dims: dict[str, tuple[int, ...]] = {
        "data": tuple(range(len(dims)))
    }
    dim_io: dict[int, float] = {}
    if sp.n_pods > 1:
        hrs_dim = len(dims)
        # non-blocking Clos: full uplink per peer PAIR, one uplink of
        # aggregate IO per rack (the dim_io cap)
        dims.append(DimSpec("P", sp.n_pods, OPTICAL_1KM, sp.uplink_lanes_per_rack))
        axis_dims["pod"] = (hrs_dim,)
        dim_io[hrs_dim] = uplink_gbs
    return CoarseMesh(
        topo=NDFullMesh(dims=tuple(dims)),
        chips_per_node=chips_per_rack,
        axis_dims=axis_dims,
        dim_io_gbs=dim_io,
        level=level,
    )


def coarse_netsim(
    cm: CoarseMesh,
    *,
    routing: Routing = Routing.DETOUR,
    latency_s: float = 5e-6,
    rx_gbs: "float | str | None" = "auto",
    solver: str = "vectorized",
    **kw,
):
    """A ``NetSim`` over the coarse topology with the coarse axis layout
    and the HRS IO caps pre-wired."""
    from .api import NetSim  # deferred: avoid import cycle at package init

    return NetSim(
        cm.topo,
        routing=routing,
        latency_s=latency_s,
        rx_gbs=rx_gbs,
        solver=solver,
        axis_dims=cm.axis_dims,
        dim_io_gbs=cm.dim_io_gbs or None,
        **kw,
    )


def coarse_calibrated_profile(
    cm: CoarseMesh,
    per_chip_bytes: float = 64e6,
    *,
    comm=None,
    axis_sizes: dict[str, int] | None = None,
    widths: dict | None = None,
    axes: tuple[str, ...] | None = None,
    shapes: tuple[str, ...] = COLLECTIVE_SHAPES,
    sim=None,
    **netsim_kw,
) -> CalibrationProfile:
    """Per-chip effective GB/s per (axis, shape), measured at super-node
    granularity: payloads are scaled up by ``chips_per_node`` (a rack
    carries its chips' aggregate collective traffic) and the measured
    bandwidth scaled back down to per-chip units."""
    sim = sim or coarse_netsim(cm, **netsim_kw)
    prof = sim.calibrated_profile(
        per_chip_bytes * cm.chips_per_node,
        comm=comm,
        axis_sizes=axis_sizes,
        widths=widths,
        axes=axes,
        shapes=shapes,
    )
    return CalibrationProfile(
        gbs={k: g / cm.chips_per_node for k, g in prof.gbs.items()}
    )
