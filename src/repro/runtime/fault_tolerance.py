"""Self-healing runtime (paper P3): 64+1 backup NPUs, link recovery,
heartbeats and straggler mitigation.

Two layers:

* **Topology layer** — exact reproduction of the paper's mechanisms on the
  UB-Mesh graph: `RackFailover` implements the 64+1 design of Fig. 9 (the
  backup NPU takes the failed logical slot; its direct links are redirected
  through the LRS, +1 hop); link failures trigger APR direct notification +
  reroute (§4.2).
* **Job layer** — `TrainingSupervisor` drives checkpoint/restart: heartbeat
  timeout -> activate backup (or shrink DP via `runtime.elastic`) -> restore
  latest checkpoint -> resume.  The CPU container simulates worker failures;
  the control flow is the production one.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

from repro.core.apr import RoutePlan, all_paths
from repro.core.topology import NDFullMesh, ub_mesh_rack


# ---------------------------------------------------------------------------
# 64+1 backup NPU (paper §3.3.2, Fig. 9)
# ---------------------------------------------------------------------------


class SparesExhausted(dict):
    """Structured spare-pool-empty outcome of :meth:`RackFailover.fail`.

    A dict subclass so callers can both ``isinstance``-check the outcome
    (the policy-engine path: degrade to checkpoint-restore / elastic
    shrink) and read fields like any other recovery record.  Carries
    ``kind="spares_exhausted"``, the logical/physical ids of the
    unrecovered failure and the rack's failure count."""

    def __init__(self, logical: int, failed_physical: int, failed_count: int):
        super().__init__(
            kind="spares_exhausted",
            logical=logical,
            failed_physical=failed_physical,
            failed_count=failed_count,
        )


@dataclass
class RackFailover:
    """Logical->physical NPU mapping for one rack with hot spares."""

    rack: NDFullMesh = field(default_factory=ub_mesh_rack)
    n_backups: int = 1

    def __post_init__(self):
        n = self.rack.num_nodes
        # physical ids: [0, n) regular, [n, n+backups) spares behind the LRS
        self.logical_to_physical = list(range(n))
        self.failed: set[int] = set()
        self.spares = list(range(n, n + self.n_backups))

    @property
    def degraded(self) -> bool:
        """True once failures exceed what the spares could absorb."""
        return len(self.failed) > self.n_backups

    def fail(self, logical: int) -> dict:
        """NPU failure: activate a spare for this logical slot.

        Returns the recovery record: which physical npu replaced it and
        which direct links became 1-hop LRS routes (Fig. 9's 5-3 ->
        5-LRS-B redirection).  When the spare pool is empty the failure
        is still recorded but the outcome is a :class:`SparesExhausted`
        record (``kind="spares_exhausted"``) instead of an exception —
        the caller's policy engine decides whether to wait for a
        restock, restore from checkpoint, or shrink the job elastically.
        """
        phys = self.logical_to_physical[logical]
        self.failed.add(phys)
        if not self.spares:
            return SparesExhausted(
                logical=logical,
                failed_physical=phys,
                failed_count=len(self.failed),
            )
        spare = self.spares.pop(0)
        self.logical_to_physical[logical] = spare
        redirected = [
            (peer, "via-LRS", 1)  # (logical peer, path type, extra hops)
            for peer, _dim in self.rack.all_neighbors(phys if phys < self.rack.num_nodes else 0)
        ]
        return {
            "kind": "backup",
            "logical": logical,
            "failed_physical": phys,
            "backup_physical": spare,
            "redirected_links": len(redirected),
            "extra_hops": 1,
        }

    def restock(self, physical: int) -> None:
        """Return a repaired NPU to the spare pool (field service swapped
        the failed board).  The physical id re-enters as a spare — the
        logical slot it used to hold stays on whatever replaced it."""
        self.failed.discard(physical)
        if physical not in self.spares and physical not in self.logical_to_physical:
            self.spares.append(physical)

    def translate(self, logical: int) -> int:
        return self.logical_to_physical[logical]


# ---------------------------------------------------------------------------
# link failure -> APR direct notification + reroute (paper §4.2)
# ---------------------------------------------------------------------------


def recover_link_failure(
    plan: RoutePlan, link: tuple[int, int]
) -> dict:
    """Direct-notification recovery; returns convergence statistics."""
    t0 = time.perf_counter()
    notified = plan.direct_notify(link)
    rerouted = plan.reroute(link)
    dt = time.perf_counter() - t0
    baseline = plan.hop_by_hop_notify(link)
    return {
        "affected_flows": len(rerouted),
        "notified_sources": len(notified),
        "max_notify_hops": max(notified.values(), default=0),
        "max_hop_by_hop_hops": max(baseline.values(), default=0),
        "control_messages_direct": len(notified),
        "control_messages_flood": plan.topo.num_nodes,
        "recovery_wall_s": dt,
    }


# ---------------------------------------------------------------------------
# job-level supervisor: heartbeats, checkpoint/restart, stragglers
# ---------------------------------------------------------------------------


@dataclass
class WorkerState:
    last_heartbeat: float
    step: int = 0
    slow_strikes: int = 0


class TrainingSupervisor:
    """Heartbeat-driven failure detection + restart orchestration.

    ``clock`` injects the time source (a zero-arg callable returning
    seconds).  The default stays ``time.monotonic`` for live use; tests
    and the Monte-Carlo campaign pass a simulated clock so detection is
    deterministic and replayable per seed."""

    def __init__(
        self,
        n_workers: int,
        heartbeat_timeout_s: float = 10.0,
        straggler_factor: float = 3.0,
        clock: Callable[[], float] | None = None,
    ):
        self._clock = clock if clock is not None else time.monotonic
        now = self._clock()
        self.workers = {i: WorkerState(now) for i in range(n_workers)}
        self.timeout = heartbeat_timeout_s
        self.straggler_factor = straggler_factor
        self.step_times: list[float] = []
        self.events: list[dict] = []

    def heartbeat(self, worker: int, step: int, step_time_s: float | None = None):
        w = self.workers[worker]
        w.last_heartbeat = self._clock()
        w.step = step
        if step_time_s is not None:
            self.step_times.append(step_time_s)
            self.step_times = self.step_times[-256:]
            med = sorted(self.step_times)[len(self.step_times) // 2]
            if step_time_s > self.straggler_factor * med:
                w.slow_strikes += 1
                if w.slow_strikes >= 3:
                    self.events.append(
                        {"kind": "straggler", "worker": worker, "step": step}
                    )
                    w.slow_strikes = 0
            else:
                w.slow_strikes = 0

    def dead_workers(self, now: float | None = None) -> list[int]:
        # `now is None` check, not truthiness: a simulated clock
        # legitimately reads 0.0 at t=0
        now = self._clock() if now is None else now
        return [
            i for i, w in self.workers.items()
            if now - w.last_heartbeat > self.timeout
        ]

    def plan_recovery(self, failover: RackFailover, dead: list[int]) -> dict:
        """Decide the recovery action for a set of dead workers."""
        actions = []
        for w in dead:
            rec = failover.fail(w % failover.rack.num_nodes)
            if isinstance(rec, SparesExhausted):
                actions.append({**rec, "kind": "elastic_shrink", "worker": w})
            else:
                actions.append(rec | {"worker": w})
        self.events.extend(actions)
        return {
            "actions": actions,
            "restart_from_checkpoint": True,
        }
