"""Seeded Monte-Carlo availability campaign over a SuperPod (paper
§3.3.2, §6.6, Table 6).

The closed-form layer (`core/availability.py`) turns AFR sums into
``MTBF/(MTBF+MTTR)``; this module *replays* the failures.  Per seed it

1. samples failure events per class (link / trunk / LRS / HRS / NPU)
   from the exponential inter-arrival times implied by the AFR
   breakdown, over a simulated multi-week horizon;
2. reprices the training step on the degraded mesh for every network
   event class through netsim APR reroute
   (``NetsimPerfModel(failed_links=...)`` — the measured DAGs route
   around the dead links), *incrementally*: only the axes a failure
   can touch get degraded cache keys, one measurement per class per
   process, everything else is a memo/`calib_cache` hit;
3. drives a recovery policy engine per event: 64+1 backup-swap
   (`RackFailover`, 13-min fast MTTR, state recovered from DP peers),
   checkpoint-restore with lost-work accounting (75-min full MTTR plus
   work since the last checkpoint at the `checkpoint/manager.py` step
   cadence), or elastic DP shrink (`ElasticPlan`) when the rack's
   spare pool is exhausted (`SparesExhausted`);
4. integrates the goodput timeline (stalls at rate 0, degraded windows
   at the repriced step-time ratio, shrunken windows at the elastic
   capacity fraction, minus recomputed work) and the Table-6-style
   *network availability* (union of network-class repair windows).

Everything on the replay path is deterministic per seed: one
``numpy.random.default_rng(seed)`` drives sampling, no wall clock is
read anywhere.

The UB-Mesh vs Clos head-to-head (`head_to_head`) reproduces the
paper's ordering (≈7.2 pp network availability gap at the 75-min MTTR)
and the ≥95% linearity-under-failures claim
(`linearity_under_failures`).  `availability_score` is the cheap
sampling-only variant (no netsim, no goodput) that gives every
`GeometryCandidate` the third Pareto dominance axis carried by
`core/codesign.DesignPoint.unavailability`.

Modeling notes (deliberate, conservative toward UB-Mesh):

* Clos network failures are charged the same repair windows in the
  availability metric but produce no goodput degradation (a
  non-blocking fabric reroutes at full bisection) — Clos only pays
  goodput for NPU failures, where its lack of an in-rack 64+1 spare
  forces a full checkpoint-restore per failure.
* Backup-swap does not roll back: §6.6's fast path migrates state
  from DP-replica peers onto the pre-heated spare, so it costs the
  13-min stall only.
* The per-NPU AFR default (0.12/yr) is the fleet-level board+HBM rate;
  `core.availability.BackupAnalysis` keeps its conservative 0.25 for
  the rack-capacity-loss analysis.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.availability import (
    AFR_PER_UNIT,
    AFRBreakdown,
    FAST_MTTR_HOURS,
    HOURS_PER_YEAR,
    PAPER_CLOS,
    PAPER_MTTR_HOURS,
    PAPER_UB_MESH,
    superpod_afr,
)
from repro.core.codesign import GeometryCandidate
from repro.core.topology import NDFullMesh
from repro.core.traffic import WorkloadSpec
from repro.runtime.elastic import shrink_plan
from repro.runtime.fault_tolerance import RackFailover, SparesExhausted

HOURS_PER_WEEK = 7 * 24

# network event classes of the UB-Mesh profile, in AFRBreakdown terms:
# x/y = passive intra-rack cables, z = active-electrical trunks,
# a = optical trunks, lrs/hrs = switches.  "npu" rides separately.
MESH_CLASSES = ("x_link", "y_link", "z_trunk", "a_trunk", "lrs", "hrs")
CLOS_CLASSES = ("clos_electrical", "clos_optical", "clos_lrs", "clos_hrs")


# ---------------------------------------------------------------------------
# canonical degraded-link sets per event class
# ---------------------------------------------------------------------------


def canonical_failed_links(
    topo: NDFullMesh, cls: str
) -> tuple[tuple[int, int], ...]:
    """The representative failed-link set one event of ``cls`` induces.

    By symmetry every single failure of a class is equivalent up to
    relabeling, so the campaign prices ONE canonical instance per class
    and reuses the measurement for all events of that class — this is
    what makes repricing memoizable.  Classes a geometry cannot survive
    (a trunk failure in a 2-deep dimension leaves no detour clique
    member) return ``()`` and are charged availability but no measured
    degradation.

    * ``x_link`` / ``y_link`` — one intra-rack cable at the base corner;
    * ``z_trunk`` / ``a_trunk`` — the full pair-link bundle between the
      first two racks of that dimension (the chips detour through the
      remaining clique members — APR's same-clique relay);
    * ``lrs`` — 1/18 of rack 0's backplane: a staggered subset of its
      trunk pair-links, at most one inter-rack link per chip per dim so
      every flow retains a detour.
    """
    shape = topo.shape
    ndim = len(shape)
    base = [0] * ndim

    def link(dim: int, cu: list[int], hi: int) -> tuple[int, int]:
        cv = list(cu)
        cv[dim] = hi
        return topo.node_id(tuple(cu)), topo.node_id(tuple(cv))

    if cls == "x_link":
        return (link(0, base, 1),) if shape[0] > 1 else ()
    if cls == "y_link":
        return (link(1, base, 1),) if ndim > 1 and shape[1] > 1 else ()
    if cls in ("z_trunk", "a_trunk"):
        dim = 2 if cls == "z_trunk" else 3
        if ndim <= dim or shape[dim] < 3:
            return ()                   # no detour clique member survives
        out = []
        for x in range(shape[0]):
            for y in range(shape[1] if ndim > 1 else 1):
                cu = list(base)
                cu[0], cu[1] = x, y
                out.append(link(dim, cu, 1))
        return tuple(out)
    if cls == "lrs":
        # one of the rack's 18 LRS: ~1/18 of its trunk pair-links, spread
        # so no chip loses more than one link per clique
        out = []
        peers = [
            (dim, hi)
            for dim in range(2, ndim)
            if shape[dim] >= 3
            for hi in range(1, shape[dim])
        ]
        n_rack = shape[0] * (shape[1] if ndim > 1 else 1)
        per_peer = max(1, round(n_rack * len(peers) / 18 / max(1, len(peers))))
        for k, (dim, hi) in enumerate(peers):
            y = k % (shape[1] if ndim > 1 else 1)
            for x in range(min(per_peer, shape[0])):
                cu = list(base)
                cu[0], cu[1] = x, y
                out.append(link(dim, cu, hi))
        return tuple(out)
    return ()                           # hrs (analytic) and npu (no links)


# ---------------------------------------------------------------------------
# failure-class rates from an AFR breakdown
# ---------------------------------------------------------------------------


def failure_class_rates(
    afr: AFRBreakdown, cand: GeometryCandidate, chips: int
) -> dict[str, float]:
    """Whole-system failures/year per mesh event class.

    The breakdown's ``electrical_cable`` pools passive intra-rack (x, y)
    and active trunk (z) cables; it is apportioned by the geometry's
    unit-weighted cable counts (the same per-unit AFRs `derived_afr`
    calibrates against Table 6)."""
    cb = cand.superpod(chips).cables_by_link_type()
    w_passive = (
        cb.get("passive_electrical", 0) * AFR_PER_UNIT["passive_electrical"]
    )
    w_active = (
        cb.get("active_electrical", 0) * AFR_PER_UNIT["active_electrical"]
    )
    tot = w_passive + w_active
    f_passive = w_passive / tot if tot > 0 else 1.0
    return {
        "x_link": afr.electrical_cable * f_passive / 2,
        "y_link": afr.electrical_cable * f_passive / 2,
        "z_trunk": afr.electrical_cable * (1.0 - f_passive),
        "a_trunk": afr.optical_cable,
        "lrs": afr.lrs,
        "hrs": afr.hrs,
    }


def clos_class_rates(afr: AFRBreakdown) -> dict[str, float]:
    return {
        "clos_electrical": afr.electrical_cable,
        "clos_optical": afr.optical_cable,
        "clos_lrs": afr.lrs,
        "clos_hrs": afr.hrs,
    }


def scale_afr(afr: AFRBreakdown, factor: float) -> AFRBreakdown:
    """Component-proportional rescaling (e.g. Table 6's 8K profile down
    to a smaller fleet)."""
    return AFRBreakdown(
        afr.name,
        electrical_cable=afr.electrical_cable * factor,
        optical_cable=afr.optical_cable * factor,
        lrs=afr.lrs * factor,
        hrs=afr.hrs * factor,
    )


# ---------------------------------------------------------------------------
# campaign configuration / event model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FailureEvent:
    t_hours: float
    cls: str
    rack: int = -1                      # NPU events only


@dataclass(frozen=True)
class CampaignConfig:
    """One architecture's campaign setup.  ``profile=None`` scales the
    paper's Table 6 breakdown to ``chips``; pass `superpod_afr(...)`
    output for component-count-derived rates instead."""

    candidate: GeometryCandidate = field(default_factory=GeometryCandidate)
    chips: int = 8192
    workload: WorkloadSpec | None = None
    horizon_weeks: float = 4.0
    seeds: tuple[int, ...] = tuple(range(8))
    profile: AFRBreakdown | None = None
    arch: str = "ub-mesh"               # "ub-mesh" | "clos"
    npu_afr_per_year: float = 0.12      # per NPU (board+HBM fleet rate)
    n_backups: int = 1                  # per rack; Clos forces 0
    repair_hours: float = 24.0          # field service restocks the spare
    checkpoint_interval_hours: float = 0.5
    mttr_full_hours: float = PAPER_MTTR_HOURS
    mttr_fast_hours: float = FAST_MTTR_HOURS
    netsim_reprice: bool = True         # False: availability-only math
    size_bytes: float = 16e6            # calibration payload

    @property
    def horizon_hours(self) -> float:
        return self.horizon_weeks * HOURS_PER_WEEK

    @property
    def n_racks(self) -> int:
        return max(1, self.chips // self.candidate.rack_size)

    def afr(self) -> AFRBreakdown:
        if self.profile is not None:
            return self.profile
        paper = PAPER_CLOS if self.arch == "clos" else PAPER_UB_MESH
        return scale_afr(paper, self.chips / 8192)

    def class_rates(self) -> dict[str, float]:
        if self.arch == "clos":
            return clos_class_rates(self.afr())
        return failure_class_rates(self.afr(), self.candidate, self.chips)


def sample_events(
    rates: dict[str, float],
    horizon_hours: float,
    rng: np.random.Generator,
    *,
    npu_rate_per_year: float = 0.0,
    n_racks: int = 1,
) -> list[FailureEvent]:
    """Poisson arrivals per class (exponential inter-arrival times), in
    deterministic class order so one seeded generator reproduces the
    exact event list."""
    events: list[FailureEvent] = []
    all_rates = dict(sorted(rates.items()))
    if npu_rate_per_year > 0:
        all_rates["npu"] = npu_rate_per_year
    for cls, per_year in all_rates.items():
        per_hour = per_year / HOURS_PER_YEAR
        if per_hour <= 0:
            continue
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / per_hour))
            if t >= horizon_hours:
                break
            rack = int(rng.integers(n_racks)) if cls == "npu" else -1
            events.append(FailureEvent(t, cls, rack))
    events.sort(key=lambda e: (e.t_hours, e.cls, e.rack))
    return events


# ---------------------------------------------------------------------------
# degraded-step repricing (netsim APR reroute, memoized per class)
# ---------------------------------------------------------------------------


class DegradedRepricer:
    """Step-time delta per failure class on the degraded mesh.

    The first query of a class builds the canonical failed-link set,
    reprices the step through a ``NetsimPerfModel(failed_links=...)``
    (only the affected axes re-measure — see
    ``NetsimPerfModel._degraded_axes``) and memoizes the delta; every
    later event of the class is a dict lookup.  ``hrs`` degrades the
    coarse pod axis analytically by (h-1)/h — chip-level netsim cannot
    see the Clos tier, and the paper's HRS count makes one switch a
    small capacity fraction."""

    def __init__(
        self,
        perf,
        w: WorkloadSpec,
        spec,
        *,
        rack_size: int,
        hrs_count: int = 0,
        reprice: bool = True,
    ):
        from repro.core.simulator import simulate

        self._simulate = simulate
        self.perf = perf
        self.w = w
        self.spec = spec
        self.rack_size = rack_size
        self.hrs_count = hrs_count
        self.reprice = reprice
        self.healthy_s = simulate(
            w, spec, perf, rack_size=rack_size
        ).iteration_s
        self._memo: dict[str, float] = {}

    def delta_s(self, cls: str) -> float:
        """Extra seconds per training step while one ``cls`` failure is
        unrepaired (>= 0; 0 for classes with no measurable path)."""
        if cls in self._memo:
            return self._memo[cls]
        d = 0.0
        if self.reprice:
            if cls == "hrs":
                axes = self.perf.comm_model(self.spec).axes
                if "pod" in axes and self.hrs_count > 1:
                    a = axes["pod"]
                    scaled = replace(
                        a,
                        gbs_per_chip=a.gbs_per_chip
                        * (self.hrs_count - 1)
                        / self.hrs_count,
                    )
                    degraded = self.perf.override_axis("pod", scaled)
                    d = (
                        self._simulate(
                            self.w, self.spec, degraded,
                            rack_size=self.rack_size,
                        ).iteration_s
                        - self.healthy_s
                    )
            elif cls in MESH_CLASSES:
                links = canonical_failed_links(self.perf.topo, cls)
                if links:
                    degraded = replace(self.perf, failed_links=links)
                    d = (
                        self._simulate(
                            self.w, self.spec, degraded,
                            rack_size=self.rack_size,
                        ).iteration_s
                        - self.healthy_s
                    )
        d = max(0.0, d)
        self._memo[cls] = d
        return d


# ---------------------------------------------------------------------------
# per-seed replay: policy engine + goodput integration
# ---------------------------------------------------------------------------


@dataclass
class SeedResult:
    seed: int
    availability: float                 # network: 1 - union(repair)/H
    job_availability: float             # 1 - union(stalls)/H
    goodput: float                      # productive fraction of the horizon
    n_events: int
    events_by_class: dict[str, int]
    policies: dict[str, int]            # backup/restore/shrink/wait counts
    stall_hours: float
    degraded_hours: float
    lost_work_hours: float
    timeline: list[dict] = field(default_factory=list)


def _union_hours(windows: list[tuple[float, float]], horizon: float) -> float:
    """Total covered hours of the interval union, clipped to [0, H]."""
    clipped = sorted(
        (max(0.0, a), min(horizon, b)) for a, b in windows if b > 0
    )
    total, cur_a, cur_b = 0.0, None, None
    for a, b in clipped:
        if b <= a:
            continue
        if cur_b is None or a > cur_b:
            if cur_b is not None:
                total += cur_b - cur_a
            cur_a, cur_b = a, b
        else:
            cur_b = max(cur_b, b)
    if cur_b is not None:
        total += cur_b - cur_a
    return total


def replay_seed(
    cfg: CampaignConfig,
    seed: int,
    repricer: DegradedRepricer | None,
) -> SeedResult:
    """Replay one seeded event trace through the recovery policy engine."""
    H = cfg.horizon_hours
    rng = np.random.default_rng(seed)
    events = sample_events(
        cfg.class_rates(),
        H,
        rng,
        npu_rate_per_year=cfg.npu_afr_per_year * cfg.chips,
        n_racks=cfg.n_racks,
    )

    healthy_s = repricer.healthy_s if repricer is not None else 1.0
    rack_mesh = None
    failovers: dict[int, RackFailover] = {}
    rack_fail_count: dict[int, int] = {}
    restocks: list[tuple[float, int, int]] = []   # (t, rack, physical)

    net_windows: list[tuple[float, float]] = []    # availability metric
    degrade: list[tuple[float, float, float]] = []  # (t0, t1, delta_s)
    stalls: list[tuple[float, float]] = []
    cap_windows: list[tuple[float, float, float]] = []  # (t0, t1, fraction)
    lost_work_h = 0.0
    policies = {"backup": 0, "restore": 0, "shrink": 0, "wait": 0}
    by_class: dict[str, int] = {}
    timeline: list[dict] = []
    n_backups = 0 if cfg.arch == "clos" else cfg.n_backups

    def rack_failover(r: int) -> RackFailover:
        nonlocal rack_mesh
        fo = failovers.get(r)
        if fo is None:
            if rack_mesh is None:
                pod = cfg.candidate.pod()
                rack_mesh = NDFullMesh(dims=pod.dims[:2])
            fo = failovers[r] = RackFailover(
                rack=rack_mesh, n_backups=n_backups
            )
        return fo

    def lost_work(t: float) -> float:
        return t - math.floor(t / cfg.checkpoint_interval_hours) * (
            cfg.checkpoint_interval_hours
        )

    for e in events:
        t = e.t_hours
        by_class[e.cls] = by_class.get(e.cls, 0) + 1
        if e.cls != "npu":
            # network failure: repair window counts against availability;
            # training continues on the rerouted mesh at the repriced rate
            net_windows.append((t, t + cfg.mttr_full_hours))
            delta = repricer.delta_s(e.cls) if repricer is not None else 0.0
            if delta > 0:
                degrade.append((t, t + cfg.mttr_full_hours, delta))
            timeline.append(
                {"t": t, "kind": e.cls, "action": "reroute",
                 "mttr_h": cfg.mttr_full_hours,
                 "step_delta_s": delta}
            )
            continue

        # NPU failure: pop due restocks, then ask the rack's policy
        while restocks and restocks[0][0] <= t:
            _, r, phys = heapq.heappop(restocks)
            rack_failover(r).restock(phys)
        fo = rack_failover(e.rack)
        k = rack_fail_count.get(e.rack, 0)
        rack_fail_count[e.rack] = k + 1
        rec = fo.fail(k % cfg.candidate.rack_size)
        if not isinstance(rec, SparesExhausted):
            # 64+1 fast swap: 13-min stall, no rollback (§6.6 migrates
            # state from DP-replica peers onto the pre-heated spare)
            stalls.append((t, t + cfg.mttr_fast_hours))
            heapq.heappush(
                restocks, (t + cfg.repair_hours, e.rack, rec["failed_physical"])
            )
            policies["backup"] += 1
            timeline.append(
                {"t": t, "kind": "npu", "rack": e.rack, "action": "backup_swap",
                 "stall_h": cfg.mttr_fast_hours}
            )
            continue
        heapq.heappush(
            restocks, (t + cfg.repair_hours, e.rack, rec["failed_physical"])
        )
        if cfg.arch == "clos":
            # any-to-any fabric: restart on a hall spare from checkpoint
            lw = lost_work(t)
            lost_work_h += lw
            stalls.append((t, t + cfg.mttr_full_hours))
            policies["restore"] += 1
            timeline.append(
                {"t": t, "kind": "npu", "rack": e.rack,
                 "action": "checkpoint_restore",
                 "stall_h": cfg.mttr_full_hours, "lost_work_h": lw}
            )
            continue
        # UB-Mesh spare pool empty: wait for the earliest restock of this
        # rack, or shrink DP around the dead rack slice — pick the policy
        # with the lower expected goodput loss
        next_restock = min(
            (rt for rt, r, _p in restocks if r == e.rack), default=t
        )
        plan = shrink_plan(
            old_dp=max(2, getattr(repricer.spec, "dp", 2))
            if repricer is not None else 2,
            old_global_batch=cfg.workload.global_batch
            if cfg.workload is not None else 512,
            lost_chips=cfg.candidate.rack_size,
            total_chips=cfg.chips,
        )
        lw = lost_work(t)
        loss_wait = (next_restock - t) + cfg.mttr_fast_hours
        loss_shrink = (
            2 * cfg.mttr_full_hours      # shrink restore + later re-expand
            + lw
            + (1.0 - plan.capacity_fraction) * (next_restock - t)
        )
        if loss_wait <= loss_shrink:
            stalls.append((t, next_restock + cfg.mttr_fast_hours))
            policies["wait"] += 1
            timeline.append(
                {"t": t, "kind": "npu", "rack": e.rack,
                 "action": "wait_for_spare",
                 "stall_h": (next_restock - t) + cfg.mttr_fast_hours}
            )
        else:
            lost_work_h += lw
            stalls.append((t, t + cfg.mttr_full_hours))
            cap_windows.append(
                (t + cfg.mttr_full_hours, next_restock, plan.capacity_fraction)
            )
            stalls.append((next_restock, next_restock + cfg.mttr_full_hours))
            policies["shrink"] += 1
            timeline.append(
                {"t": t, "kind": "npu", "rack": e.rack,
                 "action": "elastic_shrink",
                 "new_dp": plan.new_dp, "old_dp": plan.old_dp,
                 "capacity_fraction": plan.capacity_fraction,
                 "lost_work_h": lw}
            )

    # ---- integrate the goodput timeline ---------------------------------
    edges = {0.0, H}
    for a, b in stalls:
        edges |= {a, b}
    for a, b, _d in degrade:
        edges |= {a, b}
    for a, b, _f in cap_windows:
        edges |= {a, b}
    cut = sorted(x for x in edges if 0.0 <= x <= H)
    progress_h = 0.0
    for a, b in zip(cut, cut[1:]):
        if b <= a:
            continue
        mid = (a + b) / 2
        if any(sa <= mid < sb for sa, sb in stalls):
            continue
        delta = sum(d for (da, db, d) in degrade if da <= mid < db)
        rate = healthy_s / (healthy_s + delta) if healthy_s > 0 else 1.0
        for ca, cb_, f in cap_windows:
            if ca <= mid < cb_:
                rate *= f
        progress_h += (b - a) * rate
    progress_h = max(0.0, progress_h - lost_work_h)

    stall_h = _union_hours(stalls, H)
    return SeedResult(
        seed=seed,
        availability=1.0 - _union_hours(net_windows, H) / H,
        job_availability=1.0 - stall_h / H,
        goodput=progress_h / H,
        n_events=len(events),
        events_by_class=by_class,
        policies=policies,
        stall_hours=stall_h,
        degraded_hours=_union_hours([(a, b) for a, b, _ in degrade], H),
        lost_work_hours=lost_work_h,
        timeline=timeline,
    )


# ---------------------------------------------------------------------------
# campaign driver + aggregation
# ---------------------------------------------------------------------------


@dataclass
class CampaignResult:
    config: CampaignConfig
    runs: list[SeedResult]
    healthy_step_s: float
    deltas_by_class: dict[str, float]

    @property
    def availability(self) -> float:
        return float(np.mean([r.availability for r in self.runs]))

    @property
    def job_availability(self) -> float:
        return float(np.mean([r.job_availability for r in self.runs]))

    @property
    def goodput(self) -> float:
        return float(np.mean([r.goodput for r in self.runs]))

    def summary(self) -> dict:
        pol: dict[str, int] = {}
        for r in self.runs:
            for k, v in r.policies.items():
                pol[k] = pol.get(k, 0) + v
        return {
            "arch": self.config.arch,
            "chips": self.config.chips,
            "seeds": len(self.runs),
            "horizon_weeks": self.config.horizon_weeks,
            "availability": round(self.availability, 6),
            "job_availability": round(self.job_availability, 6),
            "goodput": round(self.goodput, 6),
            "events": sum(r.n_events for r in self.runs),
            "policies": pol,
            "healthy_step_s": round(self.healthy_step_s, 6),
            "step_delta_s_by_class": {
                k: round(v, 6) for k, v in sorted(self.deltas_by_class.items())
            },
            "lost_work_hours": round(
                sum(r.lost_work_hours for r in self.runs), 3
            ),
        }


def _default_workload() -> WorkloadSpec:
    from repro.core.traffic import backend_comparison_workloads

    return backend_comparison_workloads()[0]      # dense-70B


def run_campaign(cfg: CampaignConfig) -> CampaignResult:
    """All seeds of one architecture's campaign."""
    from repro.core.planner import best_parallel_spec

    w = cfg.workload or _default_workload()
    cfg = replace(cfg, workload=w)
    repricer = None
    healthy_s = 1.0
    if cfg.netsim_reprice and cfg.arch != "clos":
        perf = cfg.candidate.perf_model(cfg.chips, size_bytes=cfg.size_bytes)
        spec = best_parallel_spec(
            w, cfg.chips, perf, rack_size=cfg.candidate.rack_size
        )
        repricer = DegradedRepricer(
            perf,
            w,
            spec,
            rack_size=cfg.candidate.rack_size,
            hrs_count=cfg.candidate.superpod(cfg.chips).hrs_count(),
        )
        healthy_s = repricer.healthy_s
    elif cfg.arch == "clos":
        # Clos prices its healthy step analytically for the stall math;
        # degradation windows are zero by the non-blocking assumption
        pass
    runs = [replay_seed(cfg, s, repricer) for s in cfg.seeds]
    deltas = dict(repricer._memo) if repricer is not None else {}
    return CampaignResult(
        config=cfg,
        runs=runs,
        healthy_step_s=healthy_s if repricer is not None else float("nan"),
        deltas_by_class=deltas,
    )


def head_to_head(
    chips: int = 8192,
    *,
    candidate: GeometryCandidate | None = None,
    seeds: tuple[int, ...] = tuple(range(8)),
    horizon_weeks: float = 4.0,
    workload: WorkloadSpec | None = None,
    netsim_reprice: bool = True,
    size_bytes: float = 16e6,
) -> dict:
    """UB-Mesh vs Clos under the same seeds: the Table 6 reproduction.

    Both architectures are charged the identical 75-min repair MTTR; the
    ordering comes from the AFR gap (Table 6: 88.9 vs 632.8 failures/yr
    at 8K NPUs — optical modules dominate Clos).  Expected availability
    gap ≈ 7.2 pp, paper §6.6."""
    cand = candidate or GeometryCandidate()
    ub_cfg = CampaignConfig(
        candidate=cand, chips=chips, workload=workload, seeds=seeds,
        horizon_weeks=horizon_weeks, arch="ub-mesh",
        netsim_reprice=netsim_reprice, size_bytes=size_bytes,
    )
    clos_cfg = replace(ub_cfg, arch="clos", netsim_reprice=False)
    ub = run_campaign(ub_cfg)
    clos = run_campaign(clos_cfg)
    return {
        "ub": ub,
        "clos": clos,
        "availability_gap": ub.availability - clos.availability,
        "goodput_gap": ub.goodput - clos.goodput,
        "analytic_gap": (
            ub_cfg.afr().availability(PAPER_MTTR_HOURS)
            - clos_cfg.afr().availability(PAPER_MTTR_HOURS)
        ),
    }


def linearity_under_failures(
    base_chips: int = 1024,
    chips: int = 8192,
    *,
    candidate: GeometryCandidate | None = None,
    seeds: tuple[int, ...] = tuple(range(8)),
    horizon_weeks: float = 4.0,
    workload: WorkloadSpec | None = None,
    arch: str = "ub-mesh",
    netsim_reprice: bool = True,
    perf_backend: str = "netsim",
    size_bytes: float = 16e6,
) -> dict:
    """Per-NPU *goodput* at scale relative to base, under failures.

    Weak scaling à la Fig. 22 (`core.simulator.linearity_curve`): global
    batch grows with the fleet, the planner re-picks the spec per scale,
    and each scale runs its own campaign (failure rates scale with
    component counts).  Linearity is the ratio of failure-discounted
    per-NPU throughput — the paper claims UB-Mesh holds ≥95% at 8K while
    a backup-less Clos pays a full checkpoint-restore per NPU failure."""
    from repro.core.planner import best_parallel_spec
    from repro.core.simulator import simulate

    cand = candidate or GeometryCandidate()
    w = workload or _default_workload()
    base_w = replace(w, global_batch=max(w.global_batch, base_chips // 8))

    def leg(n: int) -> dict:
        wn = replace(
            base_w, global_batch=base_w.global_batch * n // base_chips
        )
        cfg = CampaignConfig(
            candidate=cand, chips=n, workload=wn, seeds=seeds,
            horizon_weeks=horizon_weeks, arch=arch,
            netsim_reprice=netsim_reprice and arch != "clos",
            size_bytes=size_bytes,
        )
        if arch == "clos" or perf_backend == "analytic":
            # Clos (no chip-level netsim backend) and the fast golden-pin
            # path price the healthy step analytically; the failure
            # discount still comes from the seeded campaign
            perf = cand.comm_model(n)
        else:
            perf = cand.perf_model(n, size_bytes=size_bytes)
        spec = best_parallel_spec(wn, n, perf, rack_size=cand.rack_size)
        r = simulate(wn, spec, perf, rack_size=cand.rack_size)
        camp = run_campaign(cfg)
        per_npu = r.tokens_per_s / n
        return {
            "chips": n,
            "per_npu_tokens_s": per_npu,
            "goodput": camp.goodput,
            "effective_per_npu": per_npu * camp.goodput,
            "campaign": camp,
        }

    base = leg(base_chips)
    top = leg(chips)
    return {
        "base": base,
        "scaled": top,
        "linearity": top["effective_per_npu"] / base["effective_per_npu"],
        "healthy_linearity": (
            top["per_npu_tokens_s"] / base["per_npu_tokens_s"]
        ),
    }


# ---------------------------------------------------------------------------
# per-candidate availability score (codesign third Pareto axis)
# ---------------------------------------------------------------------------


def availability_score(
    candidate: GeometryCandidate,
    chips: int,
    *,
    afr: AFRBreakdown | None = None,
    seeds: tuple[int, ...] = tuple(range(8)),
    horizon_weeks: float = 4.0,
    mttr_hours: float = PAPER_MTTR_HOURS,
) -> float:
    """UNavailability (1 - availability, minimized) of one geometry.

    The sampling-only campaign: component-count AFRs from the
    candidate's own cable/switch counts (`superpod_afr`), seeded event
    sampling, union of repair windows — no netsim, no goodput, so the
    codesign sweep can score its whole candidate grid in milliseconds.
    Deterministic for fixed seeds, which keeps the extended Pareto cull
    winner-safe (the cull and the frontier see the same number)."""
    a = afr or superpod_afr(candidate.superpod(chips))
    return unavailability_for_afr(
        a, seeds=seeds, horizon_weeks=horizon_weeks, mttr_hours=mttr_hours
    )


def unavailability_for_afr(
    afr: AFRBreakdown,
    *,
    seeds: tuple[int, ...] = tuple(range(8)),
    horizon_weeks: float = 4.0,
    mttr_hours: float = PAPER_MTTR_HOURS,
) -> float:
    """Sampling-only unavailability for an arbitrary AFR breakdown (the
    Clos/hybrid baseline points use their own fabric profiles)."""
    H = horizon_weeks * HOURS_PER_WEEK
    rate_h = afr.total / HOURS_PER_YEAR
    if rate_h <= 0:
        return 0.0
    vals = []
    for seed in seeds:
        rng = np.random.default_rng(seed)
        windows = []
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / rate_h))
            if t >= H:
                break
            windows.append((t, t + mttr_hours))
        vals.append(_union_hours(windows, H) / H)
    return float(np.mean(vals))


# ---------------------------------------------------------------------------
# timeline export (netsim/telemetry.py Perfetto doc)
# ---------------------------------------------------------------------------


def campaign_trace(run: SeedResult, path: str | None = None) -> dict:
    """One seed's failure/recovery timeline as a Chrome/Perfetto trace.

    Hours map to trace seconds (a 4-week horizon stays navigable in the
    Perfetto UI); the goodput counter tracks the instantaneous
    productive rate, spans show repair/stall windows per event class,
    instants mark each policy decision."""
    from repro.netsim.telemetry import perfetto_doc

    spans = []
    instants = []
    goodput_edges: list[tuple[float, float]] = [(0.0, 1.0)]
    for ev in run.timeline:
        t = ev["t"]
        dur = ev.get("stall_h", ev.get("mttr_h", 0.0))
        spans.append(
            {
                "name": ev["action"],
                "lane": ev["kind"],
                "start": t,
                "end": t + dur,
                "args": {
                    k: v for k, v in ev.items() if k not in ("t", "kind")
                },
            }
        )
        instants.append((t, f"{ev['kind']}:{ev['action']}", dict(ev)))
        if "stall_h" in ev:
            goodput_edges.append((t, 0.0))
            goodput_edges.append((t + ev["stall_h"], 1.0))
    goodput_edges.sort(key=lambda p: p[0])
    return perfetto_doc(
        counters={"productive_rate": goodput_edges},
        spans=spans,
        instants=instants,
        time_scale=1e6,                 # 1 simulated hour -> 1 trace second
        path=path,
    )
