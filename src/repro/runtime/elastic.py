"""Elastic scaling: resume a job on a different DP width.

Parameters and ZeRO-1 optimizer state are stored UNSHARDED in checkpoints
(checkpoint/manager.py), so rescaling is: rebuild shardings for the new
mesh, `restore(..., shardings=new)`, and rescale the data pipeline's
global batch.  The only semantic knobs are batch/LR rescaling, handled
here explicitly so restarts are bitwise-documented.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ElasticPlan:
    old_dp: int
    new_dp: int
    old_global_batch: int
    keep_global_batch: bool = True     # True: same batch, different per-host
    lr_scale: float = 1.0

    @property
    def new_global_batch(self) -> int:
        if self.keep_global_batch:
            if self.old_global_batch % self.new_dp:
                raise ValueError(
                    f"global batch {self.old_global_batch} not divisible by "
                    f"new dp {self.new_dp}"
                )
            return self.old_global_batch
        return self.old_global_batch * self.new_dp // self.old_dp

    @property
    def effective_lr_scale(self) -> float:
        if self.keep_global_batch:
            return 1.0
        # linear-scaling rule when the batch actually changes
        return self.lr_scale * self.new_dp / self.old_dp

    @property
    def capacity_fraction(self) -> float:
        """Throughput fraction retained by the shrunken job — the
        goodput multiplier the availability campaign charges while a
        shrink is in effect (per-replica step time is unchanged; only
        replica count drops)."""
        return self.new_dp / self.old_dp


def shrink_plan(
    old_dp: int, old_global_batch: int, lost_chips: int, total_chips: int
) -> ElasticPlan:
    """The DP-shrink plan for losing ``lost_chips`` of ``total_chips``:
    drop the DP replicas that lived on the lost capacity (at least one),
    keeping per-replica batch constant (the global batch shrinks with
    the fleet — the linear-scaling LR rule applies on resume)."""
    chips_per_replica = max(1, total_chips // max(1, old_dp))
    lost_replicas = -(-lost_chips // chips_per_replica)  # ceil
    new_dp = max(1, old_dp - lost_replicas)
    return ElasticPlan(
        old_dp=old_dp,
        new_dp=new_dp,
        old_global_batch=old_global_batch,
        keep_global_batch=False,
    )


def rescale(
    manager,
    step: int,
    tree_like,
    new_shardings,
    plan: ElasticPlan,
):
    """Restore a checkpoint onto the new mesh; returns (state, plan)."""
    state = manager.restore(step, tree_like, shardings=new_shardings)
    return state, plan
