"""RWKV6 chunked linear-attention scan — Pallas TPU kernel.

Grid (B*H, n_chunks), last axis sequential; per-head state (N, N fp32) in
VMEM scratch.  The intra-chunk part uses the numerically safe DIRECT
pairwise decay form (every exponent <= 0), tiled into (T x T) sub-blocks so
the (T, T, N) temporary stays in VMEM — the same tiling as the jnp
reference (models/rwkv6.rwkv6_chunked), here made explicit per-core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(
    r_ref,      # (1, Q, 1, N)
    k_ref,      # (1, Q, 1, N)
    v_ref,      # (1, Q, 1, N)
    l_ref,      # (1, Q, 1, N)   log decay (<= 0)
    u_ref,      # (1, N)         bonus
    y_ref,      # (1, Q, 1, N)
    sout_ref,   # (1, N, N)
    s_ref,      # scratch (N, N) fp32
    *,
    tile: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    rq = r_ref[0, :, 0, :].astype(jnp.float32)      # (Q, N)
    kq = k_ref[0, :, 0, :].astype(jnp.float32)
    vq = v_ref[0, :, 0, :].astype(jnp.float32)
    lq = l_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                # (N,)
    Q, N = rq.shape

    cum = jnp.cumsum(lq, axis=0)                    # (Q, N)
    # inter-chunk: y_i += (r_i * exp(cum_i - l_i)) S
    y = jax.lax.dot_general(
        rq * jnp.exp(cum - lq), s_ref[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )                                               # (Q, N)
    # bonus diagonal term
    y = y + jnp.sum(rq * u[None, :] * kq, axis=1, keepdims=True) * vq

    # intra-chunk: tiled pairwise decay (exponents <= 0, always safe)
    n_tiles = Q // tile
    ci_dec = cum - lq
    for ti in range(n_tiles):
        i0 = ti * tile
        ri = rq[i0 : i0 + tile]
        di = ci_dec[i0 : i0 + tile]
        acc = jnp.zeros((tile, N), jnp.float32)
        for tj in range(ti + 1):
            j0 = tj * tile
            kj = kq[j0 : j0 + tile]
            vj = vq[j0 : j0 + tile]
            cj = cum[j0 : j0 + tile]
            d = di[:, None, :] - cj[None, :, :]     # (T, T, N)
            if ti == tj:
                mask = jnp.tril(jnp.ones((tile, tile), jnp.bool_), k=-1)
                d = jnp.where(mask[:, :, None], d, -jnp.inf)
            att = jnp.einsum("in,jn,ijn->ij", ri, kj, jnp.exp(d))
            acc = acc + jax.lax.dot_general(
                att, vj, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        y = jax.lax.dynamic_update_slice_in_dim(
            y, y[i0 : i0 + tile] + acc, i0, axis=0
        )

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: S' = diag(prod w) S + sum_j (prod_{t>j} w) k_j v_j^T
    tail = jnp.exp(cum[-1:, :] - cum)               # (Q, N)
    s_ref[...] = s_ref[...] * jnp.exp(cum[-1])[:, None] + jax.lax.dot_general(
        kq * tail, vq, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    sout_ref[0] = s_ref[...]


def rwkv6_scan(
    r: jax.Array,       # (B, S, H, N)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,       # decay in (0, 1)
    u: jax.Array,       # (H, N)
    *,
    chunk: int = 128,
    tile: int = 16,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, S, H, N = r.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    logw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-6, 1.0))

    kernel = functools.partial(_rwkv_kernel, tile=min(tile, Q))
    spec = pl.BlockSpec((1, Q, 1, N), lambda h, c: (h // H, c, h % H, 0))
    y, s = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            spec,
            spec,
            spec,
            spec,
            pl.BlockSpec((1, N), lambda h, c: (h % H, 0)),
        ],
        out_specs=[
            spec,
            pl.BlockSpec((1, N, N), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, N), r.dtype),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return y, s.reshape(B, H, N, N)
