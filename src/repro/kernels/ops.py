"""Public jit'd wrappers for the Pallas kernels.

Each wrapper validates shapes, handles layout adaptation from the model
layers' conventions, and routes through interpret mode on CPU (the
container) vs compiled mode on TPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ccu_reduce import ccu_reduce as _ccu_reduce
from .flash_attention import flash_attention as _flash
from .moe_dispatch import moe_dispatch as _moe_dispatch, moe_gather_matmul
from .rwkv6_scan import rwkv6_scan as _rwkv6
from .ssd_scan import ssd_scan as _ssd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("causal", "window", "prefix_len", "block_q", "block_k"))
def flash_attention_bkgsd(
    q, k, v, *, causal=True, window=None, prefix_len=0, block_q=128, block_k=128
):
    """q (B,K,G,Sq,D), k/v (B,K,Sk,D) -> (B,K,G,Sq,D)."""
    assert q.ndim == 5 and k.ndim == 4 and v.shape == k.shape
    assert q.shape[0] == k.shape[0] and q.shape[1] == k.shape[1]
    return _flash(
        q, k, v,
        causal=causal, window=window, prefix_len=prefix_len,
        block_q=block_q, block_k=block_k, interpret=_on_cpu(),
    )


@partial(jax.jit, static_argnames=("causal", "window", "prefix_len"))
def flash_attention_bsnd(
    q, k, v, *, causal=True, window=None, prefix_len=0
):
    """Model-layer layout: q (B,S,N,Dh), k/v (B,S,K,Dh) GQA."""
    B, S, N, D = q.shape
    K = k.shape[2]
    G = N // K
    qk = q.reshape(B, S, K, G, D).transpose(0, 2, 3, 1, 4)   # (B,K,G,S,D)
    kk = k.transpose(0, 2, 1, 3)                              # (B,K,S,D)
    vv = v.transpose(0, 2, 1, 3)
    o = _flash(
        qk, kk, vv, causal=causal, window=window, prefix_len=prefix_len,
        block_q=min(128, S), block_k=min(128, S), interpret=_on_cpu(),
    )
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, N, D)


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xh, log_l, Bm, Cm, *, chunk=128):
    return _ssd(xh, log_l, Bm, Cm, chunk=chunk, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("chunk", "tile"))
def rwkv6_scan(r, k, v, w, u, *, chunk=128, tile=16):
    return _rwkv6(r, k, v, w, u, chunk=chunk, tile=tile, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("block_t",))
def moe_dispatch(disp, x, *, block_t=128):
    return _moe_dispatch(disp, x, block_t=block_t, interpret=_on_cpu())


@partial(jax.jit, static_argnames=("block_n",))
def ccu_reduce(bufs, scales=None, *, block_n=512):
    return _ccu_reduce(bufs, scales, block_n=block_n, interpret=_on_cpu())
