"""MoE capacity-bucketed dispatch matmul — Pallas TPU kernel.

Computes expert inputs ``out[e, c, :] = sum_t disp[t, e, c] * x[t, :]`` — the
GShard dispatch einsum — as a blocked matmul: grid (E, n_token_blocks) with
the token axis sequential, accumulating each expert's (C, D) buffer in VMEM.
The one-hot dispatch block arrives VMEM-resident and feeds the MXU directly
(one (C, BT) x (BT, D) matmul per step) — no gather/scatter engines needed,
which is exactly why this formulation is the TPU-native MoE.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dispatch_kernel(
    d_ref,      # (BT, 1, C)  dispatch block for this expert
    x_ref,      # (BT, D)
    o_ref,      # (1, C, D)
    acc_ref,    # scratch (C, D) fp32
    *,
    nt: int,
):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = d_ref[:, 0, :].astype(jnp.float32)          # (BT, C)
    x = x_ref[...].astype(jnp.float32)              # (BT, D)
    acc_ref[...] += jax.lax.dot_general(
        d, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (C, D)

    @pl.when(ti == nt - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_dispatch(
    disp: jax.Array,    # (T, E, C) one-hot dispatch
    x: jax.Array,       # (T, D)
    *,
    block_t: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns expert inputs (E, C, D)."""
    T, E, C = disp.shape
    D = x.shape[-1]
    bt = min(block_t, T)
    assert T % bt == 0
    nt = T // bt

    kernel = functools.partial(_dispatch_kernel, nt=nt)
    return pl.pallas_call(
        kernel,
        grid=(E, nt),
        in_specs=[
            pl.BlockSpec((bt, 1, C), lambda e, t: (t, e, 0)),
            pl.BlockSpec((bt, D), lambda e, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, D), lambda e, t: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((C, D), jnp.float32)],
        interpret=interpret,
    )(disp, x)


def moe_gather_matmul(
    disp: jax.Array,    # (T, E, C)
    x: jax.Array,       # (T, D)
    w: jax.Array,       # (E, D, F)
    *,
    interpret: bool = True,
) -> jax.Array:
    """Dispatch + expert matmul: (E, C, F)."""
    ein = moe_dispatch(disp, x, interpret=interpret)        # (E, C, D)
    return jnp.einsum("ecd,edf->ecf", ein.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
