"""Flash attention (GQA) — Pallas TPU kernel.

Online-softmax blocked attention: the grid walks (batch*kv_head, q_block,
kv_block); running max/denominator/accumulator live in VMEM scratch and the
output block is written on the LAST kv step.  Block shapes are MXU-aligned
(multiples of 128 where the head_dim allows; q/kv block = 128 rows).

Supports causal masking, sliding windows and bidirectional prefixes — the
union of what the zoo needs (starcoder2/mixtral SWA, paligemma prefix-LM,
whisper bidirectional encoder via causal=False).

Layout: q (B, K, G, Sq, D)  k/v (B, K, Sk, D)  — G = query heads per kv
head folded into the q-block rows so one kernel serves MHA/GQA/MQA.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref,      # (1, 1, G, BQ, D)
    k_ref,      # (1, 1, BK, D)
    v_ref,      # (1, 1, BK, D)
    o_ref,      # (1, 1, G, BQ, D)
    m_ref,      # scratch (G, BQ)       running max
    l_ref,      # scratch (G, BQ)       running denom
    acc_ref,    # scratch (G, BQ, D)    running numerator
    *,
    sm_scale: float,
    causal: bool,
    window: int | None,
    prefix_len: int,
    bq: int,
    bk: int,
    nk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale                                  # (G, BQ, BK)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok = ok & (q_pos >= k_pos)
    if window is not None:
        ok = ok & ((q_pos - k_pos) < window)
    if prefix_len > 0:
        ok = ok | (k_pos < prefix_len)
    s = jnp.where(ok[None], s, NEG_INF)

    m_prev = m_ref[...]                           # (G, BQ)
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])             # (G, BQ, BK)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        p, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (B, K, G, Sq, D)
    k: jax.Array,            # (B, K, Sk, D)
    v: jax.Array,            # (B, K, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    B, K, G, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _attn_kernel,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        prefix_len=prefix_len,
        bq=bq,
        bk=bk,
        nk=nk,
    )
    grid = (B * K, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, G, bq, D), lambda h, i, j: (h // K, h % K, 0, i, 0)
            ),
            pl.BlockSpec((1, 1, bk, D), lambda h, i, j: (h // K, h % K, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda h, i, j: (h // K, h % K, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, bq, D), lambda h, i, j: (h // K, h % K, 0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
