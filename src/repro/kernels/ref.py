"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These mirror the math the model layers use, restated here in the kernels'
native layouts so tests can assert_allclose directly.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,            # (B, K, G, Sq, D)
    k: jax.Array,            # (B, K, Sk, D)
    v: jax.Array,            # (B, K, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
    sm_scale: float | None = None,
) -> jax.Array:
    B, K, G, Sq, D = q.shape
    Sk = k.shape[2]
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum(
        "bkgqd,bksd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok = ok & (q_pos >= k_pos)
    if window is not None:
        ok = ok & ((q_pos - k_pos) < window)
    if prefix_len > 0:
        ok = ok | (k_pos < prefix_len)
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(xh, log_l, Bm, Cm, h0=None):
    """Token-level SSD recurrence: the chunked kernel's oracle.

    xh (B,S,H,P), log_l (B,S,H), Bm/Cm (B,S,N) -> y (B,S,H,P), h (B,H,P,N).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0

    def step(h, t):
        lam = jnp.exp(log_l[:, t])                         # (B,H)
        dh = jnp.einsum("bhp,bn->bhpn", xh[:, t].astype(jnp.float32),
                        Bm[:, t].astype(jnp.float32))
        h = h * lam[:, :, None, None] + dh
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, t].astype(jnp.float32), h)
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return jnp.swapaxes(ys, 0, 1).astype(xh.dtype), h


def rwkv6_scan_ref(r, k, v, w, u, s0=None):
    """Token-level RWKV6 recurrence (B,S,H,N) -> (y, final state)."""
    B, S, H, N = r.shape
    s = jnp.zeros((B, H, N, N), jnp.float32) if s0 is None else s0

    def step(s, t):
        rt = r[:, t].astype(jnp.float32)
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        wt = w[:, t].astype(jnp.float32)
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        y = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, :, :, None] * kv)
        s = s * wt[..., None] + kv
        return s, y

    s, ys = jax.lax.scan(step, s, jnp.arange(S))
    return jnp.swapaxes(ys, 0, 1).astype(r.dtype), s


def moe_gather_matmul_ref(disp, x, w):
    """disp (T,E,C) one-hot dispatch; x (T,D); w (E,D,F) -> (E,C,F)."""
    ein = jnp.einsum("tec,td->ecd", disp.astype(jnp.float32), x.astype(jnp.float32))
    return jnp.einsum("ecd,edf->ecf", ein, w.astype(jnp.float32)).astype(x.dtype)


def ccu_reduce_ref(bufs):
    """bufs (P, N): deterministic-order peer reduction -> (N,) fp32."""
    acc = jnp.zeros(bufs.shape[1:], jnp.float32)
    for p in range(bufs.shape[0]):
        acc = acc + bufs[p].astype(jnp.float32)
    return acc
