"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid (B, n_chunks) with the LAST axis sequential: the inter-chunk state
(H, P, N fp32) lives in VMEM scratch and persists across chunk steps.
Per chunk: intra-chunk decay attention (two MXU matmuls over (Q, Q)) plus
the state contribution — the exact math of models/mamba2.ssd_chunked,
tiled so the working set (chunk x heads x P + state) stays in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,      # (1, Q, H, P)
    l_ref,      # (1, Q, H)
    b_ref,      # (1, Q, N)
    c_ref,      # (1, Q, N)
    y_ref,      # (1, Q, H, P)
    hout_ref,   # (1, H, P, N)
    h_ref,      # scratch (H, P, N) fp32
    *,
    nc: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xq = x_ref[0].astype(jnp.float32)       # (Q, H, P)
    lq = l_ref[0].astype(jnp.float32)       # (Q, H)
    bq = b_ref[0].astype(jnp.float32)       # (Q, N)
    cq = c_ref[0].astype(jnp.float32)       # (Q, N)
    Q = xq.shape[0]

    cum = jnp.cumsum(lq, axis=0)            # (Q, H)
    scores = jax.lax.dot_general(
        cq, bq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                       # (Q, Q) = C_i . B_j
    decay = cum[:, None, :] - cum[None, :, :]          # (Q, Q, H)
    causal = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    att = scores[:, :, None] * jnp.exp(
        jnp.where(causal[:, :, None], decay, -jnp.inf)
    )                                       # (Q, Q, H)
    # y_intra[i,h,p] = sum_j att[i,j,h] x[j,h,p]
    y_intra = jnp.einsum("ijh,jhp->ihp", att, xq)
    # inter-chunk from carried state
    y_inter = jnp.einsum("in,hpn->ihp", cq, h_ref[...]) * jnp.exp(cum)[:, :, None]
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    tail = jnp.exp(cum[-1:, :] - cum)                  # (Q, H)
    dh = jnp.einsum("jhp,jn,jh->hpn", xq, bq, tail)
    h_ref[...] = h_ref[...] * jnp.exp(cum[-1])[:, None, None] + dh
    hout_ref[0] = h_ref[...]


def ssd_scan(
    xh: jax.Array,      # (B, S, H, P)
    log_l: jax.Array,   # (B, S, H)
    Bm: jax.Array,      # (B, S, N)
    Cm: jax.Array,      # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, Q, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), xh.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(xh, log_l, Bm, Cm)
    return y, h
