"""CCU in-line reduce — Pallas TPU kernel (paper §7's co-processor analogue).

The paper's Collective Communication Unit reads peer buffers and reduces
them IN-LINE into on-chip SRAM, skipping the copy through the application's
HBM buffer and keeping a deterministic reduction order.  The TPU analogue:
a blocked kernel whose grid walks (chunk, peer) with the peer axis
sequential — the fp32 accumulator for the current chunk never leaves VMEM,
peers are streamed in deterministic order p=0..P-1, and one optional
bf16/int8 dequant happens on the fly (compressed-gradient ingestion).

On real hardware the peer dimension is fed by ICI remote DMA; here the
peers arrive as a stacked array so the kernel semantics (tiling, ordering,
accumulation dtype) are exactly testable in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ccu_kernel(
    in_ref,     # (1, BN) one peer's chunk
    scale_ref,  # (1, 1) dequant scale for this peer
    o_ref,      # (BN,)
    acc_ref,    # scratch (BN,) fp32
    *,
    np_: int,
):
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = in_ref[0].astype(jnp.float32) * scale_ref[0, 0]
    acc_ref[...] += x

    @pl.when(pi == np_ - 1)
    def _finish():
        o_ref[...] = acc_ref[...]


def ccu_reduce(
    bufs: jax.Array,             # (P, N) peer buffers (any float/int8 dtype)
    scales: jax.Array | None = None,   # (P,) dequant scales (int8 ingestion)
    *,
    block_n: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Deterministic-order peer reduction -> (N,) fp32."""
    P, N = bufs.shape
    bn = min(block_n, N)
    assert N % bn == 0
    if scales is None:
        scales = jnp.ones((P,), jnp.float32)
    scales2 = scales.reshape(P, 1).astype(jnp.float32)

    kernel = functools.partial(_ccu_kernel, np_=P)
    return pl.pallas_call(
        kernel,
        grid=(N // bn, P),
        in_specs=[
            pl.BlockSpec((1, bn), lambda n, p: (p, n)),
            pl.BlockSpec((1, 1), lambda n, p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda n, p: (n,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32)],
        interpret=interpret,
    )(bufs, scales2)
