"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE, sliding window 4096, LayerNorm+GELU, qkv bias
[arXiv:2402.19173; hf]."""

from repro.models.api import TransformerHarness
from repro.models.transformer import LMConfig


def get_harness(smoke: bool = False) -> TransformerHarness:
    if smoke:
        cfg = LMConfig(
            name="starcoder2-smoke", n_layers=2, d_model=96, n_heads=3,
            n_kv_heads=1, head_dim=32, d_ff=192, vocab_size=512,
            norm="ln", act="gelu", window=64, qkv_bias=True,
        )
    else:
        cfg = LMConfig(
            name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36,
            n_kv_heads=4, head_dim=128, d_ff=18432, vocab_size=49152,
            norm="ln", act="gelu", window=4096, qkv_bias=True,
        )
    return TransformerHarness("starcoder2-7b", cfg, family="dense")
