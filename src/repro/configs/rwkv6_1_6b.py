"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
— Finch, data-dependent decay [arXiv:2404.05892; unverified]."""

from repro.models.api import RWKVHarness
from repro.models.rwkv_lm import RWKVLMConfig


def get_harness(smoke: bool = False) -> RWKVHarness:
    if smoke:
        cfg = RWKVLMConfig(
            name="rwkv6-smoke", n_layers=2, d_model=128, d_ff=256,
            vocab_size=512, head_dim=32, chunk=16,
        )
    else:
        cfg = RWKVLMConfig(
            name="rwkv6-1.6b", n_layers=24, d_model=2048, d_ff=7168,
            vocab_size=65536, head_dim=64,
        )
    return RWKVHarness("rwkv6-1.6b", cfg)
