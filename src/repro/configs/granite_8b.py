"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152
— llama-arch, code model [arXiv:2405.04324; hf]."""

from repro.models.api import TransformerHarness
from repro.models.transformer import LMConfig


def get_harness(smoke: bool = False) -> TransformerHarness:
    if smoke:
        cfg = LMConfig(
            name="granite-8b-smoke", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        )
    else:
        cfg = LMConfig(
            name="granite-8b", n_layers=36, d_model=4096, n_heads=32,
            n_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=49152,
        )
    return TransformerHarness("granite-8b", cfg, family="dense")
