"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865 — enc-dec,
conv frontend STUB (precomputed frame embeddings; 1500 frames padded to 1536
for even sharding) [arXiv:2212.04356; unverified]."""

from repro.models.api import EncDecHarness
from repro.models.encdec import EncDecConfig


def get_harness(smoke: bool = False) -> EncDecHarness:
    if smoke:
        cfg = EncDecConfig(
            name="whisper-smoke", n_layers=2, d_model=64, n_heads=2,
            n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=384, n_frames=24,
        )
    else:
        cfg = EncDecConfig(
            name="whisper-base", n_layers=6, d_model=512, n_heads=8,
            n_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=51865,
            n_frames=1536,
        )
    return EncDecHarness("whisper-base", cfg)
