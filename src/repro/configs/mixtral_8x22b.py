"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA [arXiv:2401.04088; hf].

Expert-TP sharding (8 experts < 16-way model axis): expert ff over "model",
embed over "data" (FSDP gather) — see DESIGN.md §5.
"""

from repro.models.api import TransformerHarness
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def get_harness(smoke: bool = False) -> TransformerHarness:
    if smoke:
        cfg = LMConfig(
            name="mixtral-smoke", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512, window=64,
            moe=MoEConfig(n_experts=4, topk=2, d_ff=256, strategy="expert_tp"),
        )
    else:
        cfg = LMConfig(
            name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
            n_kv_heads=8, head_dim=128, d_ff=16384, vocab_size=32768,
            window=4096,
            moe=MoEConfig(n_experts=8, topk=2, d_ff=16384, strategy="expert_tp"),
        )
    return TransformerHarness(
        "mixtral-8x22b", cfg, family="moe", long_context_ok=True
    )
