"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; hf]."""

from repro.models.api import HybridHarness
from repro.models.hybrid import HybridConfig


def get_harness(smoke: bool = False) -> HybridHarness:
    if smoke:
        cfg = HybridConfig(
            name="zamba2-smoke", n_layers=4, d_model=128, n_heads=4,
            n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
            ssm_state=16, share_every=2,
        )
    else:
        cfg = HybridConfig(
            name="zamba2-1.2b", n_layers=38, d_model=2048, n_heads=32,
            n_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=32000,
            ssm_state=64, share_every=6,
        )
    return HybridHarness("zamba2-1.2b", cfg)
