"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP frontend STUB (256 precomputed patch embeddings,
bidirectional prefix) + gemma backbone [arXiv:2407.07726; hf]."""

from repro.models.api import TransformerHarness
from repro.models.transformer import LMConfig


def get_harness(smoke: bool = False) -> TransformerHarness:
    if smoke:
        cfg = LMConfig(
            name="paligemma-smoke", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512,
            embed_scale=True, act="gelu",
        )
        return TransformerHarness(
            "paligemma-3b", cfg, family="vlm", prefix_tokens=8
        )
    cfg = LMConfig(
        name="paligemma-3b", n_layers=18, d_model=2048, n_heads=8,
        n_kv_heads=1, head_dim=256, d_ff=16384, vocab_size=257216,
        embed_scale=True, act="gelu",
    )
    return TransformerHarness(
        "paligemma-3b", cfg, family="vlm", prefix_tokens=256
    )
