"""Architecture registry: one module per assigned arch (+ paper models).

``load(arch_id, smoke=False)`` returns the Harness; ``ARCH_IDS`` lists all
ten assigned architectures.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "granite_8b",
    "phi4_mini_3_8b",
    "granite_3_2b",
    "starcoder2_7b",
    "zamba2_1_2b",
    "rwkv6_1_6b",
    "mixtral_8x22b",
    "dbrx_132b",
    "whisper_base",
    "paligemma_3b",
]

# pool ids use dashes
CANONICAL = {a.replace("_", "-"): a for a in ARCH_IDS}


def load(arch_id: str, smoke: bool = False):
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.get_harness(smoke=smoke)
