"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 [hf:ibm-granite/granite-3.0-2b-base; hf]."""

from repro.models.api import TransformerHarness
from repro.models.transformer import LMConfig


def get_harness(smoke: bool = False) -> TransformerHarness:
    if smoke:
        cfg = LMConfig(
            name="granite-3-2b-smoke", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=515,
        )
    else:
        cfg = LMConfig(
            name="granite-3-2b", n_layers=40, d_model=2048, n_heads=32,
            n_kv_heads=8, head_dim=64, d_ff=8192, vocab_size=49155,
        )
    return TransformerHarness("granite-3-2b", cfg, family="dense")
