"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""

from repro.models.api import TransformerHarness
from repro.models.transformer import LMConfig


def get_harness(smoke: bool = False) -> TransformerHarness:
    if smoke:
        cfg = LMConfig(
            name="phi4-mini-smoke", n_layers=2, d_model=96, n_heads=3,
            n_kv_heads=1, head_dim=32, d_ff=192, vocab_size=512,
        )
    else:
        cfg = LMConfig(
            name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24,
            n_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=200064,
        )
    return TransformerHarness("phi4-mini-3.8b", cfg, family="dense")
