"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base; unverified].

True expert-parallel sharding (16 experts == 16-way model axis): expert dim
over "model" (A2A dispatch), expert ff over "data" (FSDP gather).
"""

from repro.models.api import TransformerHarness
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def get_harness(smoke: bool = False) -> TransformerHarness:
    if smoke:
        cfg = LMConfig(
            name="dbrx-smoke", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
            moe=MoEConfig(n_experts=4, topk=2, d_ff=256, strategy="expert_parallel"),
        )
    else:
        cfg = LMConfig(
            name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
            n_kv_heads=8, head_dim=128, d_ff=10752, vocab_size=100352,
            moe=MoEConfig(
                n_experts=16, topk=4, d_ff=10752, strategy="expert_parallel"
            ),
        )
    return TransformerHarness("dbrx-132b", cfg, family="moe")
