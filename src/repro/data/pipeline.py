"""Training data pipeline: sharded token streams with prefetch.

Production framing: every host process owns the slice of the global batch
that lives on its addressable devices (``process_index``-keyed sharding).
Sources:

* ``SyntheticSource`` — deterministic PRNG token stream (CI / smoke / bench);
  reproducible per (seed, host, step) so restarts re-produce the stream.
* ``MemmapSource``   — flat uint16/uint32 token file (np.memmap), the usual
  packed-corpus format.

``Pipeline`` adds: document packing into (tokens, labels) next-token pairs,
background prefetch (double buffering), straggler mitigation via a bounded
queue timeout + skip-ahead (a slow shard never stalls the job more than
``straggler_timeout_s``), and checkpointable iterator state.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    prefetch: int = 2
    straggler_timeout_s: float = 30.0
    pattern: str = "arith"      # arith (learnable) | uniform (stress)


class SyntheticSource:
    """Deterministic token stream — same (seed, host, step) => same batch.

    ``arith`` emits arithmetic token runs (next token = prev + stride mod V):
    a predictable language the smoke models can actually learn, so e2e
    training tests can assert loss decreases.
    """

    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        assert cfg.global_batch % host_count == 0
        self.local_batch = cfg.global_batch // host_count

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, self.host_index, step))
        B, S, V = self.local_batch, self.cfg.seq_len + 1, self.cfg.vocab_size
        if self.cfg.pattern == "uniform":
            return rng.integers(0, V, size=(B, S), dtype=np.int32)
        start = rng.integers(0, V, size=(B, 1))
        stride = rng.integers(1, 4, size=(B, 1))
        t = np.arange(S)[None, :]
        return ((start + stride * t) % V).astype(np.int32)


class MemmapSource:
    """Packed-token corpus file; hosts stride through disjoint offsets."""

    def __init__(
        self,
        path: str,
        cfg: DataConfig,
        host_index: int = 0,
        host_count: int = 1,
        dtype=np.uint16,
    ):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        self.stride = self.local_batch * (cfg.seq_len + 1)

    def batch_at(self, step: int) -> np.ndarray:
        n = len(self.tokens)
        base = (step * self.host_count + self.host_index) * self.stride
        idx = (base + np.arange(self.stride)) % (n - 1)
        flat = np.asarray(self.tokens[idx], dtype=np.int32)
        return flat.reshape(self.local_batch, self.cfg.seq_len + 1)


class Pipeline:
    """Prefetching iterator of {"tokens","labels"} next-token batches."""

    def __init__(self, source, cfg: DataConfig, start_step: int = 0):
        self.source = source
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            raw = self.source.batch_at(step)
            batch = {
                "tokens": raw[:, :-1],
                "labels": raw[:, 1:],
                "step": step,
            }
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        # straggler mitigation: if the producer stalls (slow storage shard),
        # synthesize the batch inline rather than stalling the whole step
        try:
            batch = self._q.get(timeout=self.cfg.straggler_timeout_s)
        except queue.Empty:
            raw = self.source.batch_at(self.step)
            batch = {"tokens": raw[:, :-1], "labels": raw[:, 1:], "step": self.step}
        self.step = batch["step"] + 1
        return batch

    def state(self) -> dict:
        """Checkpointable position."""
        return {"step": self.step}

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
