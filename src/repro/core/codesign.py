"""Topology co-design: SuperPod geometry candidates, a winner-safe
analytic cull, and capex/perf Pareto dominance (paper §6.4, Fig. 21).

The paper's headline 2.04x cost-efficiency claim is a *co-design* result:
SuperPod geometry (per-dim lane provisioning, rack arrangement, HRS
uplink width) traded against measured collective bandwidth.  This module
supplies the pieces the search in ``benchmarks/topo_search.py`` composes:

* ``GeometryCandidate`` — one parameterized SuperPod geometry, with its
  pod topology, BOM (``core/capex.superpod_bom``), analytic ``CommModel``
  and netsim-calibrated ``NetsimPerfModel`` all derived consistently;
* ``enumerate_geometries`` — the candidate grid;
* ``prefilter_geometries`` — a ``planner.Prefilter``-style cull over
  *geometries*: closed-form capex plus analytic iteration-time bounds
  (``planner.analytic_iteration_arrays``) as numpy batch ops, discarding
  candidates that are Pareto-dominated before any netsim pricing.
  Winner-safe by the same clamp argument as the spec pre-filter: the
  measured backend prices comm at or *below* the analytic bandwidth
  (``CalibrationProfile.apply(clamp=True)``), so the analytic iteration
  is a lower bound ``LB`` on any candidate's measured step time, and
  ``compute + bubble + margin * comm`` an upper bound ``UB`` (margin 5x
  covers the worst observed analytic/netsim divergence, the ~4.2x
  relay-priced A2A).  A candidate is culled only when another candidate's
  UB beats its LB at no greater TCO — then the measured search could
  never put it on the frontier;
* ``DesignPoint`` / ``pareto_frontier`` — the multi-objective dominance
  relation (the NoC-optimisation ``__gt__`` idiom from SNIPPETS): a
  point dominates when it is no worse on every objective and strictly
  better on at least one; the frontier is the undominated set.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

import numpy as np

from .capex import BOM, superpod_bom
from .cost_model import AxisCost, CommModel, Routing
from .multiring import plan_multiring
from .planner import (
    analytic_iteration_arrays,
    enumerate_specs,
    memory_feasible,
)
from .topology import NDFullMesh, OPTICAL_1KM, SuperPod, ub_mesh_pod
from .traffic import WorkloadSpec

if TYPE_CHECKING:  # pragma: no cover
    from .perf_model import NetsimPerfModel


# ---------------------------------------------------------------------------
# Geometry candidates
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GeometryCandidate:
    """One SuperPod geometry in the co-design grid.

    The intra-rack board/rack shape stays the paper's 8x8 (the NPU and
    board form factors are fixed); the *provisioning* knobs — per-dim UB
    lane allocation, rack arrangement and the pod->HRS uplink width —
    are the search dimensions, exactly the §6.4 trade: thinner lanes and
    uplinks cut the network BOM but shrink the bandwidth the calibrated
    planner can schedule around.
    """

    x_lanes: int = 4
    y_lanes: int = 4
    z_lanes: int = 2
    a_lanes: int = 2
    racks_per_row: int = 4
    rows: int = 4
    uplink_lanes_per_rack: int = 256
    board: int = 8
    boards_per_rack: int = 8

    @property
    def name(self) -> str:
        return (
            f"xy{self.x_lanes}{self.y_lanes}"
            f"-za{self.z_lanes}{self.a_lanes}"
            f"-r{self.racks_per_row}x{self.rows}"
            f"-u{self.uplink_lanes_per_rack}"
        )

    @property
    def rack_size(self) -> int:
        return self.board * self.boards_per_rack

    @property
    def chips_per_pod(self) -> int:
        return self.rack_size * self.racks_per_row * self.rows

    def n_pods(self, chips: int) -> int:
        return max(1, chips // self.chips_per_pod)

    def pod(self) -> NDFullMesh:
        return ub_mesh_pod(
            board=self.board,
            boards_per_rack=self.boards_per_rack,
            racks_per_row=self.racks_per_row,
            rows=self.rows,
            x_lanes=self.x_lanes,
            y_lanes=self.y_lanes,
            z_lanes=self.z_lanes,
            a_lanes=self.a_lanes,
        )

    def superpod(self, chips: int) -> SuperPod:
        return SuperPod(
            pod=self.pod(),
            n_pods=self.n_pods(chips),
            uplink_lanes_per_rack=self.uplink_lanes_per_rack,
        )

    def bom(self, chips: int) -> BOM:
        """Capex/opex BOM — the uplink is *built* at
        ``uplink_lanes_per_rack``, so it is priced fully provisioned (a
        thin uplink is a thin ``uplink_lanes_per_rack``, not an
        accounting discount)."""
        return superpod_bom(self.superpod(chips), name=self.name)

    def comm_model(
        self, chips: int, *, routing: Routing = Routing.DETOUR
    ) -> CommModel:
        """The candidate's analytic cost model — the generalization of
        ``cost_model.build_comm_model`` to arbitrary geometry: multi-ring
        effective bandwidth per axis, and the pod axis at the rack
        uplink's per-chip share (the ``production_mesh_view``
        convention)."""
        topo = self.pod()

        def axis_bw(dims: tuple[int, ...]) -> float:
            if routing == Routing.SHORTEST:
                return sum(topo.dims[d].gbs_per_peer for d in dims)
            return sum(
                plan_multiring(topo, d).effective_bandwidth_gbs()
                for d in dims
            )

        axes = {
            "model": AxisCost(16, axis_bw((0, 1)), 0.5e-6),
            "data": AxisCost(
                16, axis_bw(tuple(range(2, topo.ndim))), 2.0e-6
            ),
        }
        if self.n_pods(chips) > 1:
            uplink_per_chip = (
                self.uplink_lanes_per_rack
                * OPTICAL_1KM.gbps_per_lane
                / self.rack_size
            )
            axes["pod"] = AxisCost(2, uplink_per_chip, 5.0e-6)
        return CommModel(axes=axes, routing=routing)

    def perf_model(
        self,
        chips: int,
        *,
        size_bytes: float = 64e6,
        routing: Routing = Routing.DETOUR,
        **kw,
    ) -> "NetsimPerfModel":
        """The netsim-calibrated backend for this geometry: chip-level
        calibration on the candidate pod, pod-axis calibration on its
        rack-coarsened SuperPod (when multi-pod)."""
        from .perf_model import NetsimPerfModel

        sp = self.superpod(chips)
        return NetsimPerfModel(
            self.comm_model(chips, routing=routing),
            topo=self.pod(),
            size_bytes=size_bytes,
            superpod=sp if sp.n_pods > 1 else None,
            **kw,
        )


def enumerate_geometries(
    *,
    x_lanes: Sequence[int] = (4, 3),
    y_lanes: Sequence[int] = (4, 3),
    z_lanes: Sequence[int] = (2, 1),
    a_lanes: Sequence[int] = (2, 1),
    uplinks: Sequence[int] = (256, 128, 64, 32),
    arrangements: Sequence[tuple[int, int]] = ((4, 4),),
) -> list[GeometryCandidate]:
    """The candidate grid (defaults: 2*2*2*2*4*1 = 64 candidates)."""
    return [
        GeometryCandidate(
            x_lanes=xl,
            y_lanes=yl,
            z_lanes=zl,
            a_lanes=al,
            racks_per_row=rpr,
            rows=rows,
            uplink_lanes_per_rack=u,
        )
        for xl, yl, zl, al, u, (rpr, rows) in itertools.product(
            x_lanes, y_lanes, z_lanes, a_lanes, uplinks, arrangements
        )
    ]


# ---------------------------------------------------------------------------
# Winner-safe analytic cull over geometries
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GeometryBounds:
    """Closed-form per-candidate bounds the cull decides on."""

    candidate: GeometryCandidate
    tco: float                  # exact (capex is closed-form)
    step_lb_s: float            # lower bound on the measured best step
    step_ub_s: float            # upper bound (margin-degraded analytic)
    n_specs: int                # feasible specs priced


def geometry_bounds(
    w: WorkloadSpec,
    candidates: Sequence[GeometryCandidate],
    chips: int,
    *,
    margin: float = 5.0,
    max_tp: int = 64,
    microbatch_options: tuple[int, ...] = (1, 2, 4, 8, 13, 16, 32),
) -> list[GeometryBounds]:
    """Analytic (TCO, step-time LB/UB) per candidate, no netsim work.

    ``LB = min_spec(compute + bubble + analytic_comm)`` — below any
    measured step time because the calibrated backend clamps at the
    analytic bandwidth; ``UB = min_spec(compute + bubble + margin *
    analytic_comm)`` — above the measured time of the spec attaining it
    as long as no bandwidth degrades by more than ``margin`` (the
    ``planner.Prefilter`` soundness argument, applied per geometry)."""
    out = []
    for cand in candidates:
        tco = cand.bom(chips).tco()
        comm = cand.comm_model(chips)
        specs = [
            p
            for p in enumerate_specs(
                w,
                chips,
                rack_size=cand.rack_size,
                max_tp=max_tp,
                microbatch_options=microbatch_options,
            )
            if memory_feasible(w, p)
        ]
        if not specs:
            # unplannable geometry: infinitely slow, cullable by any
            # candidate that can run the workload at all
            out.append(
                GeometryBounds(cand, tco, float("inf"), float("inf"), 0)
            )
            continue
        try:
            compute_s, comm_s, bubble_s = analytic_iteration_arrays(
                w, specs, comm, rack_size=cand.rack_size
            )
        except Exception:
            # unpriceable analytically: keep it, price it in full
            out.append(GeometryBounds(cand, tco, 0.0, float("inf"), len(specs)))
            continue
        lb = float(np.min(compute_s + bubble_s + comm_s))
        ub = float(np.min(compute_s + bubble_s + margin * comm_s))
        out.append(GeometryBounds(cand, tco, lb, ub, len(specs)))
    return out


def prefilter_geometries(
    w: WorkloadSpec,
    candidates: Sequence[GeometryCandidate],
    chips: int,
    *,
    margin: float = 5.0,
    max_tp: int = 64,
    microbatch_options: tuple[int, ...] = (1, 2, 4, 8, 13, 16, 32),
    unavailability: "Sequence[float] | None" = None,
) -> tuple[list[GeometryCandidate], list[GeometryCandidate], list[GeometryBounds]]:
    """Cull Pareto-dominated geometries before netsim pricing.

    Candidate ``i`` is culled iff some ``j`` has ``tco_j <= tco_i`` and
    ``UB_j <= LB_i`` with at least one strict — then whatever the
    measured step times turn out to be, ``j``'s (step, TCO) dominates
    ``i``'s, so ``i`` cannot sit on the measured frontier.  Winner-safe:
    TCO is exact and the step bounds bracket the measurement (see
    :func:`geometry_bounds`).

    ``unavailability`` (aligned with ``candidates``) extends dominance to
    the third Pareto axis: when given, ``j`` must ALSO be no less
    available than ``i`` to cull it — the scores are exact per candidate
    (the same deterministic Monte-Carlo number later attached to the
    ``DesignPoint``), so the cull stays winner-safe on the 3-axis
    frontier.  Returns ``(survivors, culled, bounds)``.
    """
    bounds = geometry_bounds(
        w,
        candidates,
        chips,
        margin=margin,
        max_tp=max_tp,
        microbatch_options=microbatch_options,
    )
    tco = np.array([b.tco for b in bounds])
    lb = np.array([b.step_lb_s for b in bounds])
    ub = np.array([b.step_ub_s for b in bounds])
    # [i, j] True when j proves i off-frontier (diagonal safe: UB >= LB)
    cheaper_eq = tco[None, :] <= tco[:, None]
    faster_eq = ub[None, :] <= lb[:, None]
    strict = (tco[None, :] < tco[:, None]) | (ub[None, :] < lb[:, None])
    dominated = cheaper_eq & faster_eq & strict
    if unavailability is not None:
        if len(unavailability) != len(candidates):
            raise ValueError("unavailability must align with candidates")
        ua = np.array(list(unavailability), dtype=float)
        dominated &= ua[None, :] <= ua[:, None]
    culled_mask = dominated.any(axis=1)
    survivors = [c for c, x in zip(candidates, culled_mask) if not x]
    culled = [c for c, x in zip(candidates, culled_mask) if x]
    return survivors, culled, bounds


# ---------------------------------------------------------------------------
# Pareto dominance (the SNIPPETS NoC-optimisation idiom)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DesignPoint:
    """One evaluated design in objective space (all objectives minimized).

    ``a > b`` reads "a dominates b": no worse on every objective,
    strictly better on at least one — the comparison-operator dominance
    idiom of the NoC-optimisation exemplar.  Equal-fitness points do not
    dominate each other, so exact ties coexist on the frontier."""

    name: str
    step_time_s: float
    tco: float
    # third dominance axis (minimized): 1 - measured availability from the
    # Monte-Carlo campaign (`runtime.campaign.availability_score`).  The
    # default 0.0 keeps two-objective usage byte-identical: equal third
    # components never decide dominance.
    unavailability: float = 0.0
    meta: dict = field(default_factory=dict, compare=False)

    @property
    def fitness(self) -> tuple[float, ...]:
        return (self.step_time_s, self.tco, self.unavailability)

    def __gt__(self, other: "DesignPoint") -> bool:
        s, o = self.fitness, other.fitness
        return all(a <= b for a, b in zip(s, o)) and any(
            a < b for a, b in zip(s, o)
        )

    def __lt__(self, other: "DesignPoint") -> bool:
        return other > self

    @property
    def cost_efficiency(self) -> float:
        """Perf per TCO unit (higher is better), the Fig. 21 metric."""
        return 1.0 / (self.step_time_s * self.tco)


def pareto_frontier(points: Sequence[DesignPoint]) -> list[DesignPoint]:
    """The undominated subset, sorted by step time."""
    front = [p for p in points if not any(q > p for q in points)]
    return sorted(front, key=lambda p: (p.step_time_s, p.tco))
