"""Topology-aware All-to-All (paper §5.1, Fig. 14).

Two schemes on the 2D-FullMesh (generalizing to nD):

* **Multi-Path All2All** — each (src, dst) message is split into two
  partitions sent simultaneously over the X-then-Y and Y-then-X paths
  (at most one relay hop), doubling the usable bandwidth and balancing
  link load.
* **Hierarchical Broadcast+Reduce** — MoE token dispatch/combine is
  semantically overlapping broadcasts (tokens to experts) and reduces
  (expert outputs back); doing them hierarchically (intra-clique first,
  then one inter-clique copy) removes duplicate bytes from the long links.

These functions compute exact per-link loads so the benchmarks and cost
model can quantify the claims; the runtime lowering of the same idea lives
in ``repro/parallel/collectives.py`` (hierarchical all_to_all in shard_map).
"""

from __future__ import annotations

import itertools
from collections import defaultdict
from dataclasses import dataclass

import numpy as np

from .topology import NDFullMesh


@dataclass(frozen=True)
class A2AReport:
    scheme: str
    total_bytes: float            # bytes crossing links, summed over links
    max_link_bytes: float         # the bottleneck link load
    mean_link_bytes: float
    links_used: int
    max_hops: int

    @property
    def balance(self) -> float:
        """max/mean link load — 1.0 is perfectly balanced."""
        return self.max_link_bytes / self.mean_link_bytes if self.mean_link_bytes else 0.0


def _link(u: int, v: int) -> tuple[int, int]:
    return (u, v) if u < v else (v, u)


def multipath_a2a_loads(
    topo: NDFullMesh, msg_bytes: float = 1.0, *, split: bool = True
) -> A2AReport:
    """Uniform All-to-All on a 2D (or nD) full-mesh with XY/YX path splitting.

    ``split=False`` gives the single-path (dimension-ordered) baseline.
    """
    loads: dict[tuple[int, int], float] = defaultdict(float)
    n = topo.num_nodes
    max_hops = 0
    for src in range(n):
        cs = topo.coords(src)
        for dst in range(n):
            if src == dst:
                continue
            cd = topo.coords(dst)
            diff = [i for i in range(topo.ndim) if cs[i] != cd[i]]
            # enumerate the k! dimension orders; use 2 of them (or 1)
            orders = list(itertools.permutations(diff))
            chosen = orders if split else orders[:1]
            if split and len(orders) > 2:
                chosen = [orders[0], orders[-1]]       # XY... and reversed
            share = msg_bytes / len(chosen)
            for order in chosen:
                cur = list(cs)
                prev = src
                hops = 0
                for d in order:
                    cur[d] = cd[d]
                    nxt = topo.node_id(cur)
                    loads[_link(prev, nxt)] += share
                    prev = nxt
                    hops += 1
                max_hops = max(max_hops, hops)
    vals = np.array(list(loads.values())) if loads else np.zeros(1)
    return A2AReport(
        scheme="multipath" if split else "single-path",
        total_bytes=float(vals.sum()),
        max_link_bytes=float(vals.max()),
        mean_link_bytes=float(vals.mean()),
        links_used=len(loads),
        max_hops=max_hops,
    )


def permutation_a2a_pair_bandwidth(
    topo: NDFullMesh, *, multipath: bool = True
) -> float:
    """Per-pair bandwidth (GB/s) for permutation / skewed traffic.

    A single (src, dst) flow on the 2D-FullMesh uses ONE egress link under
    dimension-ordered routing; Multi-Path All2All (Fig. 14-(a)) splits it
    over the X-then-Y and Y-then-X paths simultaneously — 2x the per-flow
    bandwidth (and more with deeper APR detours).
    """
    # both paths' first hops leave on different dims => bandwidth adds
    gbs = [d.gbs_per_peer for d in topo.dims]
    return (gbs[0] + gbs[1]) if multipath and topo.ndim >= 2 else gbs[0]


@dataclass(frozen=True)
class MoEDispatchReport:
    """Long-link bytes for MoE dispatch/combine (paper Fig. 14-(b/c))."""

    scheme: str
    long_link_bytes_per_token: float   # expected bytes crossing clique edges
    local_bytes_per_token: float

    @property
    def total(self) -> float:
        return self.long_link_bytes_per_token + self.local_bytes_per_token


def hierarchical_moe_dispatch(
    n_cliques: int,
    topk: int,
    bytes_per_token: float = 1.0,
    *,
    local_clique_size: int = 8,
) -> tuple[MoEDispatchReport, MoEDispatchReport]:
    """Direct A2A vs hierarchical broadcast+reduce for MoE token dispatch.

    Token semantics: the SAME activation goes to ``topk`` experts (dispatch
    = overlapping broadcasts) and the ``topk`` expert outputs are SUMMED
    (combine = overlapping reduces).  Direct A2A ships ``topk`` copies over
    the long links; the hierarchical scheme ships ONE copy per *distinct
    destination clique* (broadcast dedup) and pre-reduces expert outputs
    inside each clique before the return trip.

    Expected distinct cliques for k uniform draws over c cliques:
        E[distinct] = c * (1 - (1 - 1/c)^k)

    Returns (direct, hierarchical) per-token byte reports.
    """
    c = n_cliques
    k = topk
    e_distinct = c * (1.0 - (1.0 - 1.0 / c) ** k)
    # probability a given expert lands in the source's own clique
    p_local = 1.0 / c
    direct = MoEDispatchReport(
        scheme="direct-a2a",
        long_link_bytes_per_token=bytes_per_token * k * (1 - p_local),
        local_bytes_per_token=bytes_per_token * k * p_local,
    )
    # hierarchical: one copy per distinct remote clique + local fan-out
    e_remote_distinct = e_distinct - (1.0 - (1.0 - 1.0 / c) ** k)  # exclude own
    hier = MoEDispatchReport(
        scheme="hierarchical",
        long_link_bytes_per_token=bytes_per_token * e_remote_distinct,
        local_bytes_per_token=bytes_per_token * k,  # fan-out within cliques
    )
    return direct, hier


def moe_dispatch_savings(n_cliques: int, topk: int) -> float:
    """Long-link byte reduction factor of the hierarchical scheme."""
    d, h = hierarchical_moe_dispatch(n_cliques, topk)
    if h.long_link_bytes_per_token == 0:
        return float("inf")
    return d.long_link_bytes_per_token / h.long_link_bytes_per_token


def a2a_time_s(
    topo: NDFullMesh,
    bytes_per_pair: float,
    *,
    multipath: bool = True,
    latency_s: float = 1e-6,
) -> float:
    """Completion time of a uniform A2A: bottleneck link load / link bw."""
    rep = multipath_a2a_loads(topo, bytes_per_pair, split=multipath)
    # a link in dim d has lanes_per_peer * gbps_per_lane bandwidth; use the
    # weakest dim the traffic crosses for a conservative bound.
    link_gbs = min(d.gbs_per_peer for d in topo.dims)
    return rep.max_link_bytes / (link_gbs * 1e9) + rep.max_hops * latency_s
