"""Multi-Ring AllReduce planner (paper §5.1, Fig. 13).

In a full-mesh clique of ``n`` nodes a single ring uses only ``n`` of the
``n(n-1)/2`` links — the rest idle.  The paper's Multi-Ring algorithm maps
the AllReduce onto MANY edge-disjoint rings simultaneously ("ensuring
exclusive path usage without traffic conflicts"), then *borrows* the links
that are still idle via APR to carry overflow traffic.

This module plans those rings:

* odd  n: Walecki decomposition — (n-1)/2 edge-disjoint Hamiltonian cycles
  covering EVERY clique link.
* even n: zig-zag decomposition — n/2 edge-disjoint Hamiltonian paths
  covering every link ("multi-chain"; a chain AllReduce has the same
  asymptotic per-link traffic as a ring).

Every decomposition is verified by construction (`verify=True` asserts
edge-disjointness + full coverage), and the planner computes the effective
per-chip AllReduce bandwidth the cost model uses.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass

import numpy as np

from .topology import NDFullMesh

Ring = tuple[int, ...]   # cyclic order of nodes (cycle: implicit wrap) / path


def _edges_of_cycle(cycle: Ring) -> set[tuple[int, int]]:
    return {
        tuple(sorted((cycle[i], cycle[(i + 1) % len(cycle)])))
        for i in range(len(cycle))
    }


def _edges_of_path(path: Ring) -> set[tuple[int, int]]:
    return {tuple(sorted(e)) for e in zip(path, path[1:])}


def walecki_cycles(n: int) -> list[Ring]:
    """Edge-disjoint Hamiltonian cycles of K_n for ODD n ((n-1)/2 of them).

    Classical construction: hub vertex ``n-1``; the other ``n-1`` vertices sit
    on a circle (Z_{n-1}); cycle k is the hub plus the zig-zag
    k, k+1, k-1, k+2, k-2, ... rotated by k.
    """
    if n % 2 == 0:
        raise ValueError("walecki_cycles needs odd n")
    if n == 1:
        return []
    m = (n - 1) // 2
    # zig-zag 0, 1, -1, 2, -2, ... over Z_{n-1}
    zig = [0]
    for j in range(1, n - 1):
        k = (j + 1) // 2
        zig.append(k if j % 2 == 1 else -k)
    cycles = []
    for k in range(m):
        cyc = [n - 1] + [(k + z) % (n - 1) for z in zig]
        cycles.append(tuple(cyc))
    return cycles


def zigzag_paths(n: int) -> list[Ring]:
    """Edge-disjoint Hamiltonian paths of K_n for EVEN n (n/2 of them)."""
    if n % 2 == 1:
        raise ValueError("zigzag_paths needs even n")
    m = n // 2
    zig = [0]
    for j in range(1, n):
        k = (j + 1) // 2
        zig.append(k if j % 2 == 1 else -k)
    # zig has n entries; differences are +1,-2,+3,... covering 1..n-1 once
    paths = []
    for k in range(m):
        paths.append(tuple((k + z) % n for z in zig))
    return paths


def clique_decomposition(n: int, verify: bool = True) -> tuple[list[Ring], bool]:
    """Decompose K_n into edge-disjoint Hamiltonian rings/chains.

    Returns (rings, closed) where ``closed`` says whether entries are cycles
    (odd n) or open chains (even n).
    """
    if n < 2:
        return [], False
    if n == 2:
        return [(0, 1)], False
    rings = walecki_cycles(n) if n % 2 == 1 else zigzag_paths(n)
    closed = n % 2 == 1
    if verify:
        edge_fn = _edges_of_cycle if closed else _edges_of_path
        all_edges: set[tuple[int, int]] = set()
        for r in rings:
            assert len(set(r)) == n, f"not Hamiltonian: {r}"
            e = edge_fn(r)
            assert not (e & all_edges), f"rings not edge-disjoint for n={n}"
            all_edges |= e
        expected = n * (n - 1) // 2
        assert len(all_edges) == expected, (
            f"decomposition covers {len(all_edges)}/{expected} edges of K_{n}"
        )
    return rings, closed


@dataclass(frozen=True)
class MultiRingPlan:
    """A planned multi-ring AllReduce over one full-mesh clique."""

    n: int
    rings: tuple[Ring, ...]
    closed: bool            # cycles (True) or chains (False)
    lanes_per_peer: int     # UB lanes on each clique link
    gbps_per_lane: float

    @property
    def links_used(self) -> int:
        per = self.n if self.closed else self.n - 1
        return per * len(self.rings)

    @property
    def links_total(self) -> int:
        return self.n * (self.n - 1) // 2

    @property
    def utilization(self) -> float:
        """Fraction of clique links carrying AllReduce traffic."""
        return self.links_used / max(1, self.links_total)

    def effective_bandwidth_gbs(self) -> float:
        """Per-chip AllReduce *algorithm* bandwidth.

        Single ring: per-chip injection = one link's bandwidth.
        Multi-ring: data is split across R rings => R links inject in
        parallel from every chip => R x one-link bandwidth, which for a full
        decomposition equals (almost) the node's whole clique allocation —
        the paper's "fully utilize the bandwidth of direct links".
        """
        link_gbs = self.lanes_per_peer * self.gbps_per_lane
        return len(self.rings) * link_gbs

    def allreduce_wire_bytes_per_chip(self, size_bytes: int) -> float:
        """Ring/chain AllReduce: each chip sends 2(n-1)/n of its shard count
        per ring; total across rings is still 2(n-1)/n * size (the split
        shrinks per-ring payload, not the total).
        """
        n = self.n
        if n <= 1:
            return 0.0
        return 2.0 * (n - 1) / n * size_bytes

    def allreduce_time_s(self, size_bytes: int, latency_s: float = 1e-6) -> float:
        n = self.n
        if n <= 1:
            return 0.0
        wire = self.allreduce_wire_bytes_per_chip(size_bytes)
        bw = self.effective_bandwidth_gbs() * 1e9
        steps = 2 * (n - 1)
        return wire / bw + steps * latency_s


def plan_multiring(topo: NDFullMesh, dim: int) -> MultiRingPlan:
    """Plan the multi-ring AllReduce for the clique of dimension ``dim``."""
    n = topo.shape[dim]
    rings, closed = clique_decomposition(n)
    d = topo.dims[dim]
    return MultiRingPlan(
        n=n,
        rings=tuple(rings),
        closed=closed,
        lanes_per_peer=d.lanes_per_peer,
        gbps_per_lane=d.link.gbps_per_lane,
    )


def single_ring_bandwidth_gbs(topo: NDFullMesh, dim: int) -> float:
    """Baseline: one ring through the clique uses one link per chip."""
    d = topo.dims[dim]
    return d.lanes_per_peer * d.link.gbps_per_lane


def borrowed_bandwidth_gbs(
    topo: NDFullMesh, dim: int, *, borrow_lanes: int = 0
) -> float:
    """`Borrow` strategy (paper §6.3): racks may route overflow through the
    LRS/HRS switch plane, adding ``borrow_lanes`` of switched bandwidth on
    top of the direct-link multi-ring.
    """
    plan = plan_multiring(topo, dim)
    return plan.effective_bandwidth_gbs() + borrow_lanes * topo.dims[dim].link.gbps_per_lane
