"""Multi-Ring AllReduce planner (paper §5.1, Fig. 13).

In a full-mesh clique of ``n`` nodes a single ring uses only ``n`` of the
``n(n-1)/2`` links — the rest idle.  The paper's Multi-Ring algorithm maps
the AllReduce onto MANY edge-disjoint rings simultaneously ("ensuring
exclusive path usage without traffic conflicts"), then *borrows* the links
that are still idle via APR to carry overflow traffic.

This module plans those rings:

* odd  n: Walecki decomposition — (n-1)/2 edge-disjoint Hamiltonian cycles
  covering EVERY clique link.
* even n: zig-zag decomposition — n/2 edge-disjoint Hamiltonian paths
  covering every link ("multi-chain"; a chain AllReduce has the same
  asymptotic per-link traffic as a ring).
* **cross-dimension 2D grids** (paper Fig. 13's joint (X, Y) schedule):
  ``grid_ring_decomposition`` decomposes the 2D Hamming graph
  ``K_x [] K_y`` — the graph whose edges are BOTH cliques' links — into
  edge-disjoint Hamiltonian cycles that zig-zag between X and Y links.
  A per-dimension hierarchical schedule drives only one dimension's links
  per phase, so a rack measures ~half its clique allocation; cross-dim
  rings keep X and Y links busy simultaneously and recover it.

Every decomposition is verified by construction (`verify=True` asserts
edge-disjointness + full coverage), and the planner computes the effective
per-chip AllReduce bandwidth the cost model uses.
"""

from __future__ import annotations

import itertools
import logging
import random
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from .topology import NDFullMesh

log = logging.getLogger(__name__)

Ring = tuple[int, ...]   # cyclic order of nodes (cycle: implicit wrap) / path


def _edges_of_cycle(cycle: Ring) -> set[tuple[int, int]]:
    return {
        tuple(sorted((cycle[i], cycle[(i + 1) % len(cycle)])))
        for i in range(len(cycle))
    }


def _edges_of_path(path: Ring) -> set[tuple[int, int]]:
    return {tuple(sorted(e)) for e in zip(path, path[1:])}


def walecki_cycles(n: int) -> list[Ring]:
    """Edge-disjoint Hamiltonian cycles of K_n for ODD n ((n-1)/2 of them).

    Classical construction: hub vertex ``n-1``; the other ``n-1`` vertices sit
    on a circle (Z_{n-1}); cycle k is the hub plus the zig-zag
    k, k+1, k-1, k+2, k-2, ... rotated by k.
    """
    if n % 2 == 0:
        raise ValueError("walecki_cycles needs odd n")
    if n == 1:
        return []
    m = (n - 1) // 2
    # zig-zag 0, 1, -1, 2, -2, ... over Z_{n-1}
    zig = [0]
    for j in range(1, n - 1):
        k = (j + 1) // 2
        zig.append(k if j % 2 == 1 else -k)
    cycles = []
    for k in range(m):
        cyc = [n - 1] + [(k + z) % (n - 1) for z in zig]
        cycles.append(tuple(cyc))
    return cycles


def zigzag_paths(n: int) -> list[Ring]:
    """Edge-disjoint Hamiltonian paths of K_n for EVEN n (n/2 of them)."""
    if n % 2 == 1:
        raise ValueError("zigzag_paths needs even n")
    m = n // 2
    zig = [0]
    for j in range(1, n):
        k = (j + 1) // 2
        zig.append(k if j % 2 == 1 else -k)
    # zig has n entries; differences are +1,-2,+3,... covering 1..n-1 once
    paths = []
    for k in range(m):
        paths.append(tuple((k + z) % n for z in zig))
    return paths


def clique_decomposition(n: int, verify: bool = True) -> tuple[list[Ring], bool]:
    """Decompose K_n into edge-disjoint Hamiltonian rings/chains.

    Returns (rings, closed) where ``closed`` says whether entries are cycles
    (odd n) or open chains (even n).
    """
    if n < 2:
        return [], False
    if n == 2:
        return [(0, 1)], False
    rings = walecki_cycles(n) if n % 2 == 1 else zigzag_paths(n)
    closed = n % 2 == 1
    if verify:
        edge_fn = _edges_of_cycle if closed else _edges_of_path
        all_edges: set[tuple[int, int]] = set()
        for r in rings:
            assert len(set(r)) == n, f"not Hamiltonian: {r}"
            e = edge_fn(r)
            assert not (e & all_edges), f"rings not edge-disjoint for n={n}"
            all_edges |= e
        expected = n * (n - 1) // 2
        assert len(all_edges) == expected, (
            f"decomposition covers {len(all_edges)}/{expected} edges of K_{n}"
        )
    return rings, closed


@dataclass(frozen=True)
class MultiRingPlan:
    """A planned multi-ring AllReduce over one full-mesh clique."""

    n: int
    rings: tuple[Ring, ...]
    closed: bool            # cycles (True) or chains (False)
    lanes_per_peer: int     # UB lanes on each clique link
    gbps_per_lane: float

    @property
    def links_used(self) -> int:
        per = self.n if self.closed else self.n - 1
        return per * len(self.rings)

    @property
    def links_total(self) -> int:
        return self.n * (self.n - 1) // 2

    @property
    def utilization(self) -> float:
        """Fraction of clique links carrying AllReduce traffic."""
        return self.links_used / max(1, self.links_total)

    def effective_bandwidth_gbs(self) -> float:
        """Per-chip AllReduce *algorithm* bandwidth.

        Single ring: per-chip injection = one link's bandwidth.
        Multi-ring: data is split across R rings => R links inject in
        parallel from every chip => R x one-link bandwidth, which for a full
        decomposition equals (almost) the node's whole clique allocation —
        the paper's "fully utilize the bandwidth of direct links".
        """
        link_gbs = self.lanes_per_peer * self.gbps_per_lane
        return len(self.rings) * link_gbs

    def allreduce_wire_bytes_per_chip(self, size_bytes: int) -> float:
        """Ring/chain AllReduce: each chip sends 2(n-1)/n of its shard count
        per ring; total across rings is still 2(n-1)/n * size (the split
        shrinks per-ring payload, not the total).
        """
        n = self.n
        if n <= 1:
            return 0.0
        return 2.0 * (n - 1) / n * size_bytes

    def allreduce_time_s(self, size_bytes: int, latency_s: float = 1e-6) -> float:
        n = self.n
        if n <= 1:
            return 0.0
        wire = self.allreduce_wire_bytes_per_chip(size_bytes)
        bw = self.effective_bandwidth_gbs() * 1e9
        steps = 2 * (n - 1)
        return wire / bw + steps * latency_s


def plan_multiring(topo: NDFullMesh, dim: int) -> MultiRingPlan:
    """Plan the multi-ring AllReduce for the clique of dimension ``dim``."""
    n = topo.shape[dim]
    rings, closed = clique_decomposition(n)
    d = topo.dims[dim]
    return MultiRingPlan(
        n=n,
        rings=tuple(rings),
        closed=closed,
        lanes_per_peer=d.lanes_per_peer,
        gbps_per_lane=d.link.gbps_per_lane,
    )


def single_ring_bandwidth_gbs(topo: NDFullMesh, dim: int) -> float:
    """Baseline: one ring through the clique uses one link per chip."""
    d = topo.dims[dim]
    return d.lanes_per_peer * d.link.gbps_per_lane


def borrowed_bandwidth_gbs(
    topo: NDFullMesh, dim: int, *, borrow_lanes: int = 0
) -> float:
    """`Borrow` strategy (paper §6.3): racks may route overflow through the
    LRS/HRS switch plane, adding ``borrow_lanes`` of switched bandwidth on
    top of the direct-link multi-ring.
    """
    plan = plan_multiring(topo, dim)
    return plan.effective_bandwidth_gbs() + borrow_lanes * topo.dims[dim].link.gbps_per_lane


# ---------------------------------------------------------------------------
# Cross-dimension 2D multi-ring (rings spanning the (X, Y) cliques jointly)
# ---------------------------------------------------------------------------
#
# The 2D Hamming graph K_n [] K_n (nodes (i, j); edges between nodes that
# differ in exactly one coordinate) is (2n-2)-regular with n^2(n-1) edges, so
# a perfect decomposition has exactly n-1 Hamiltonian cycles of n^2 edges.
#
# * even n — "rainbow rotation": let phi rotate both coordinates by the
#   (n-1)-cycle (0 1 ... n-2), fixing n-1.  Every edge orbit under phi has
#   size exactly n-1 (a smaller orbit would need 2k = 0 mod n-1 with n-1
#   odd), giving n^2 orbits.  A base Hamiltonian cycle that uses each orbit
#   at most once therefore has n-1 pairwise edge-disjoint images covering
#   every edge.  Base cycles for the UB-Mesh sizes (4, 6, 8) were found by
#   ``_search_rainbow_cycle`` and are inlined; other even sizes fall back to
#   the same deterministic search at runtime.
# * odd n — Walecki pairing: pair the i-th Walecki Hamiltonian cycle of the
#   row clique with the i-th of the column clique; their Cartesian product
#   is an n x n torus, which splits into two "helix" Hamiltonian cycles
#   (right n-1 / down 1 vs. down n-1 / right 1, entry points matched so the
#   leftover diagonals complement each other) — 2 * (n-1)/2 = n-1 cycles.
#
# Both constructions are re-verified at runtime (edge-disjointness + full
# coverage) before any schedule is built on them.

# base rainbow cycles (local ids i*n + j), discovered by _search_rainbow_cycle
_RAINBOW_BASE: dict[int, tuple[int, ...]] = {
    4: (15, 7, 11, 8, 12, 4, 0, 3, 2, 1, 9, 10, 6, 5, 13, 14),
    6: (35, 17, 16, 22, 18, 20, 26, 2, 0, 6, 30, 24, 12, 15, 27, 29, 28, 10,
        9, 21, 19, 23, 5, 11, 8, 7, 1, 4, 3, 33, 32, 14, 13, 25, 31, 34),
    8: (63, 59, 57, 56, 60, 52, 51, 55, 23, 15, 31, 28, 4, 12, 20, 36, 39,
        32, 33, 49, 48, 50, 58, 42, 44, 47, 40, 41, 1, 25, 30, 54, 62, 46,
        38, 34, 37, 45, 43, 3, 35, 27, 24, 0, 16, 17, 21, 18, 19, 22, 14,
        6, 2, 26, 10, 8, 9, 11, 13, 53, 61, 29, 5, 7),
}


def _rot(k: int, n: int) -> int:
    """The coordinate rotation phi: (n-1)-cycle on 0..n-2, fixing n-1."""
    return k if k == n - 1 else (k + 1) % (n - 1)


def _grid_orbit_id(u: tuple[int, int], v: tuple[int, int], n: int) -> tuple:
    """Canonical representative of edge {u, v}'s orbit under phi x phi."""
    best = None
    a, b = u, v
    for _ in range(n - 1):
        a = (_rot(a[0], n), _rot(a[1], n))
        b = (_rot(b[0], n), _rot(b[1], n))
        e = (a, b) if a < b else (b, a)
        if best is None or e < best:
            best = e
    return best


def _grid_neighbors(u: tuple[int, int], n: int) -> list[tuple[int, int]]:
    i, j = u
    return [(i, jj) for jj in range(n) if jj != j] + [
        (ii, j) for ii in range(n) if ii != i
    ]


def _search_rainbow_cycle(
    n: int, *, seeds: int = 64, max_steps: int = 400_000
) -> tuple[int, ...] | None:
    """Deterministic Warnsdorff-style DFS for a Hamiltonian cycle of
    K_n [] K_n using at most one edge per phi-orbit (even n only)."""
    start = (n - 1, n - 1)
    for seed in range(seeds):
        rng = random.Random(seed)
        used: set[tuple] = set()
        path = [start]
        on = {start}
        steps = 0

        def options(u):
            out = []
            for v in _grid_neighbors(u, n):
                if v in on:
                    continue
                oid = _grid_orbit_id(u, v, n)
                if oid not in used:
                    out.append((v, oid))
            return out

        def dfs() -> bool:
            nonlocal steps
            steps += 1
            if steps > max_steps:
                raise TimeoutError
            u = path[-1]
            if len(path) == n * n:
                return _grid_orbit_id(u, start, n) not in used
            scored = []
            for v, oid in options(u):
                used.add(oid)
                on.add(v)
                scored.append((len(options(v)), rng.random(), v, oid))
                used.discard(oid)
                on.discard(v)
            scored.sort()
            for _k, _r, v, oid in scored:
                used.add(oid)
                path.append(v)
                on.add(v)
                if dfs():
                    return True
                used.discard(oid)
                path.pop()
                on.discard(v)
            return False

        try:
            if dfs():
                return tuple(i * n + j for i, j in path)
        except TimeoutError:
            continue
    return None


def _helix_pair(C: Ring, D: Ring) -> tuple[Ring, Ring]:
    """Split the torus C [] D (product of two n-cycles) into two Hamiltonian
    "helix" cycles.  Helix A repeats [n-1 steps along D, 1 step along C];
    helix B repeats [n-1 steps along C, 1 step along D].  With matched entry
    points A's skipped diagonal is exactly the set of edges B uses, so the
    two are edge-disjoint and together cover the torus."""
    n = len(C)
    a_seq, b_seq = [], []
    t = s = 0
    for _ in range(n):
        for _ in range(n - 1):
            a_seq.append((t, s))
            s = (s + 1) % n
        a_seq.append((t, s))
        t = (t + 1) % n
    t = s = 0
    for _ in range(n):
        for _ in range(n - 1):
            b_seq.append((t, s))
            t = (t + 1) % n
        b_seq.append((t, s))
        s = (s + 1) % n
    to_grid = lambda seq: tuple(C[t] * n + D[s] for t, s in seq)  # noqa: E731
    return to_grid(a_seq), to_grid(b_seq)


def _verify_grid_rings(rings: list[Ring], n: int) -> None:
    all_edges: set[tuple[int, int]] = set()
    for r in rings:
        assert len(set(r)) == n * n, "grid ring is not Hamiltonian"
        for t in range(len(r)):
            a, b = r[t], r[(t + 1) % len(r)]
            ai, aj = divmod(a, n)
            bi, bj = divmod(b, n)
            assert (ai == bi) != (aj == bj), f"not a grid edge: {a}-{b}"
            e = (a, b) if a < b else (b, a)
            assert e not in all_edges, "grid rings are not edge-disjoint"
            all_edges.add(e)
    expected = n * n * (n - 1)
    assert len(all_edges) == expected, (
        f"grid decomposition covers {len(all_edges)}/{expected} edges"
    )


class UnsupportedGridError(ValueError):
    """No cross-dim Hamiltonian ring decomposition exists for this plane.

    Structured signal (rather than a silent ``None``) so callers must
    explicitly acknowledge — and can log — the fall-back to the
    per-dimension hierarchical schedule, which only drives one dimension's
    links per phase (~half the plane's bandwidth).
    """

    def __init__(self, x: int, y: int, reason: str):
        self.x = x
        self.y = y
        self.reason = reason
        super().__init__(
            f"no grid ring decomposition for K_{x} □ K_{y}: {reason}"
        )


@lru_cache(maxsize=32)
def _grid_ring_decomposition_cached(x: int, y: int) -> tuple[Ring, ...] | None:
    """Cached construction; ``None`` marks an impossible/failed plane so a
    miss (including an exhausted runtime search) is only paid once."""
    if x != y or x < 2:
        return None
    n = x
    if n == 2:  # K_2 [] K_2 is a single 4-cycle
        rings = [(0, 1, 3, 2)]
    elif n % 2 == 1:
        rings = []
        for C, D in zip(walecki_cycles(n), walecki_cycles(n)):
            rings.extend(_helix_pair(C, D))
    else:
        base = _RAINBOW_BASE.get(n)
        if base is None:
            # runtime search for even sizes outside the inlined bases: can
            # take seconds-to-minutes for large n; cached, and a miss just
            # means callers keep the per-dim hierarchical schedule
            log.warning(
                "no inlined rainbow base for K_%d [] K_%d; running the "
                "Hamiltonian-decomposition search (one-time, may be slow)",
                n, n,
            )
            base = _search_rainbow_cycle(n)
        if base is None:
            return None
        rings = []
        cyc = [divmod(v, n) for v in base]
        for _ in range(n - 1):
            rings.append(tuple(i * n + j for i, j in cyc))
            cyc = [(_rot(i, n), _rot(j, n)) for i, j in cyc]
    _verify_grid_rings(rings, n)
    return tuple(rings)


def grid_ring_decomposition(x: int, y: int) -> tuple[Ring, ...]:
    """Edge-disjoint Hamiltonian cycles of the 2D Hamming graph K_x [] K_y.

    Returns ``n-1`` cycles over local node ids ``i * y + j`` (a perfect
    decomposition: every X and Y link of the grid carries exactly one
    ring).  Raises :class:`UnsupportedGridError` when no construction is
    available — non-square (K_x != K_y) planes, or an even size the
    rainbow-cycle search cannot reach — so callers explicitly fall back to
    (and log) the per-dimension hierarchical schedule instead of silently
    degrading.
    """
    rings = _grid_ring_decomposition_cached(x, y)
    if rings is None:
        if x != y:
            reason = "non-square planes have no known decomposition"
        elif x < 2:
            reason = "plane is degenerate (fewer than 2x2 nodes)"
        else:
            reason = "rainbow-cycle search exhausted for this even size"
        raise UnsupportedGridError(x, y, reason)
    return rings


def grid_effective_bandwidth_gbs(topo: NDFullMesh, dims: tuple[int, int]) -> float | None:
    """Per-chip AllReduce bandwidth of the cross-dim 2D multi-ring over the
    plane spanned by ``dims``: each of the R rings injects on one distinct
    link per chip in parallel, so R x the slower dimension's link bandwidth
    (rings alternate between both dims' links, the slower bounds the step).
    ``None`` when no grid decomposition exists for this plane."""
    d0, d1 = (topo.dims[d] for d in dims)
    try:
        rings = grid_ring_decomposition(
            topo.shape[dims[0]], topo.shape[dims[1]]
        )
    except UnsupportedGridError as e:
        log.info("grid bandwidth unavailable for dims %s (%s)", dims, e.reason)
        return None
    return len(rings) * min(d0.gbs_per_peer, d1.gbs_per_peer)
