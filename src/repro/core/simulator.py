"""Cluster-scale LLM training simulator (paper §6's evaluation engine).

Computes per-iteration time = compute + exposed communication for a workload
under a parallelization spec on a given communication model (topology
variant).  This is the engine behind the Fig. 17 / 19 / 20 / 22 benchmarks
and the §5.2 planner's objective function.

Calibration targets (paper):
* 2D-FM intra-rack reaches 93.2%..95.9% of Clos training performance,
* inter-rack Detour/Borrow close the 2D-FM vs Clos gap to <1%,
* inter-rack x16 optimal for 8K-32K seq, x32 for 64K-10M,
* linearity >= 95% up to 64x base scale.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import TYPE_CHECKING

from .cost_model import AxisCost, CommModel, Routing, build_comm_model, clos_comm_model
from .traffic import ParallelSpec, TrafficTable, WorkloadSpec, analyze_traffic

if TYPE_CHECKING:  # pragma: no cover
    from .perf_model import PerfModel

# The simulator models the PAPER's NPU class (its accelerator/bandwidth
# ratio sets the comm-exposure that Figs 17-22 measure).  The roofline for
# OUR framework uses the v5e constants in launch/hlo_stats.py instead.
PEAK_FLOPS = 1000e12         # bf16 / chip (paper-class NPU)
MFU_CEILING = 0.60           # achievable fraction of peak on matmul steps


@dataclass(frozen=True)
class SimResult:
    name: str
    compute_s: float
    comm_s: dict[str, float]       # technique -> exposed seconds
    bubble_s: float
    iteration_s: float
    tokens: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.iteration_s

    @property
    def comm_total_s(self) -> float:
        return sum(self.comm_s.values())


def _compute_seconds(w: WorkloadSpec, p: ParallelSpec) -> float:
    """Per-chip matmul seconds for one iteration (fwd+bwd)."""
    tokens = w.global_batch * w.seq_len
    if w.n_experts > 0:
        active = w.params_total * (
            (1 - w.moe_param_frac) + w.moe_param_frac * w.topk / w.n_experts
        )
    else:
        active = w.params_total
    dense_flops = 6.0 * active * tokens
    # attention score/value matmuls: 12 * L * b * s^2 * (heads*head_dim)
    attn_flops = 12.0 * w.n_layers * w.global_batch * (w.seq_len ** 2) * (
        w.n_heads * w.head_dim
    )
    total = dense_flops + attn_flops
    return total / (p.chips * PEAK_FLOPS * MFU_CEILING)


# overlap fractions: how much of each technique's traffic hides under compute
OVERLAP = {"TP": 0.10, "SP": 0.30, "EP": 0.20, "PP": 0.90, "DP": 0.80}


def _collective_time(
    comm: CommModel, axis: str, shape: str, size_bytes: float
) -> float:
    """Price one transfer of ``shape`` on ``axis`` — the dispatch point
    where a traffic entry's collective shape (``TrafficEntry.shape``)
    selects the matching shape-resolved ``CommModel`` cost, so an A2A
    entry rides the A2A-calibrated bandwidth, not the AllReduce proxy."""
    if shape == "allreduce":
        return comm.allreduce(axis, size_bytes)
    if shape == "all_gather":
        return comm.all_gather(axis, size_bytes)
    if shape == "reduce_scatter":
        return comm.reduce_scatter(axis, size_bytes)
    if shape == "all_to_all":
        return comm.all_to_all(axis, size_bytes)
    if shape == "p2p":
        return comm.p2p(axis, size_bytes)
    raise KeyError(f"unknown collective shape {shape!r}")


def simulate(
    w: WorkloadSpec,
    p: ParallelSpec,
    perf: "PerfModel | CommModel",
    *,
    name: str = "",
    rack_size: int = 64,
) -> SimResult:
    """Analytic iteration-time simulation.

    ``perf`` is any ``core.perf_model.PerfModel`` backend: a plain
    ``CommModel`` (the closed-form analytic backend), an
    ``AnalyticPerfModel`` with explicit bandwidth overrides (and
    optionally a measured ``CalibrationProfile``), or a
    ``NetsimPerfModel`` whose ``comm_model(p)`` resolves to flow-level
    *measured* per-(axis, collective-shape) bandwidths for this spec —
    pricing in the contention, relay and incast effects the closed-form
    model idealizes away.  Each traffic entry is priced on its own
    collective shape (``TrafficEntry.shape``): EP's A2A rides the
    A2A-calibrated number while TP/DP keep theirs.
    """
    comm = perf.comm_model(p)
    traffic = analyze_traffic(w, p)
    compute_s = _compute_seconds(w, p)

    # map techniques onto axes; when the TP*SP footprint exceeds the rack
    # high-bandwidth domain, the overflow fraction of TP/SP traffic crosses
    # the inter-rack ("data") axis — the Fig. 20 effect.
    tp_sp_footprint = p.tp * p.sp
    spill = 0.0
    if tp_sp_footprint > rack_size:
        spill = 1.0 - rack_size / tp_sp_footprint

    comm_s: dict[str, float] = {}
    for e in traffic.entries:
        per_transfer = e.volume_per_transfer
        n = e.n_transfers
        if e.technique in ("TP", "SP", "EP"):
            n = max(1, n // p.pp)   # each device hosts L/pp of the layers
        if e.technique == "EP":
            # Table-1 ledger stores the per-peer chunk; the device-level A2A
            # payload per op is chunk * ep
            per_transfer = per_transfer * p.ep
        if e.technique == "PP":
            t_local = _collective_time(comm, "data", e.shape, per_transfer) * n
            t_spill = t_local
        elif e.technique == "DP":
            axes = ["data"] + (["pod"] if "pod" in comm.axes else [])
            t_local = comm.hierarchical_allreduce(axes, per_transfer) * n
            t_spill = t_local
        else:   # TP / SP / EP live on the model axis, spilling to "data"
            t_local = _collective_time(comm, "model", e.shape, per_transfer) * n
            t_spill = _collective_time(comm, "data", e.shape, per_transfer) * n
        t = (1 - spill) * t_local + spill * t_spill
        exposed = t * (1 - OVERLAP[e.technique])
        comm_s[e.technique] = comm_s.get(e.technique, 0.0) + exposed

    bubble_s = compute_s * (p.pp - 1) / max(p.microbatches, 1) if p.pp > 1 else 0.0
    iteration_s = compute_s + sum(comm_s.values()) + bubble_s
    return SimResult(
        name=name or w.name,
        compute_s=compute_s,
        comm_s=comm_s,
        bubble_s=bubble_s,
        iteration_s=iteration_s,
        tokens=w.global_batch * w.seq_len,
    )


# ---------------------------------------------------------------------------
# Intra-rack architecture variants (paper Fig. 16/17)
# ---------------------------------------------------------------------------

# effective per-chip "model"-axis bandwidth (GB/s) of each intra-rack variant:
#   2D-FM    — 56 direct lanes, multi-ring recovers them all        ~350
#   1D-FM-A  — 28 X lanes direct + x16 LRS-switched cross-board     ~380*
#   1D-FM-B  — 28 X lanes direct + x32 HRS-switched                  ~430
#   Clos     — all 72 lanes switched, fully symmetric                450
# 2D-FM multiring recovers the 56 direct lanes at ~80% efficiency (even-n
# cliques decompose into CHAINS, whose endpoints idle half-duplex; boundary
# turns between X/Y rings cost the rest) — see core/multiring.py
INTRA_RACK_GBS = {
    "2D-FM": 280.0,
    "1D-FM-A": 350.0,
    "1D-FM-B": 420.0,
    "Clos": 450.0,
}


def intra_rack_comm_model(variant: str, *, multi_pod: bool = True) -> CommModel:
    # the paper fixes the inter-rack fabric at 2D-FM for this comparison
    # (§6.2); only the intra-rack ("model") bandwidth varies
    base = build_comm_model(multi_pod=multi_pod, routing=Routing.DETOUR)
    axes = dict(base.axes)
    axes["model"] = replace(axes["model"], gbs_per_chip=INTRA_RACK_GBS[variant])
    return CommModel(axes=axes, routing=base.routing)


def inter_rack_comm_model(strategy: str, *, multi_pod: bool = True) -> CommModel:
    """Fig. 18/19: 2D-FM inter-rack with Shortest/Detour/Borrow, or Clos."""
    if strategy == "Clos":
        m = build_comm_model(multi_pod=multi_pod, routing=Routing.DETOUR)
        axes = dict(m.axes)
        axes["data"] = replace(axes["data"], gbs_per_chip=450.0)
        return CommModel(axes=axes, routing=m.routing)
    routing = {
        "Shortest": Routing.SHORTEST,
        "Detour": Routing.DETOUR,
        "Borrow": Routing.BORROW,
    }[strategy]
    m = build_comm_model(multi_pod=multi_pod, routing=routing)
    if routing == Routing.SHORTEST:
        # single-path also halves the *model* axis? No — Fig 19 varies only
        # the inter-rack strategy; intra-rack keeps multi-ring.
        base = build_comm_model(multi_pod=multi_pod, routing=Routing.DETOUR)
        axes = dict(base.axes)
        shortest = build_comm_model(multi_pod=multi_pod, routing=Routing.SHORTEST)
        axes["data"] = shortest.axes["data"]
        return CommModel(axes=axes, routing=Routing.SHORTEST)
    return m


def linearity_curve(
    w: WorkloadSpec,
    base_chips: int,
    scales: list[int],
    *,
    perf: "PerfModel | CommModel | None" = None,
) -> dict[int, float]:
    """Paper Fig. 22: per-NPU throughput at scale k relative to base.

    Global batch grows with scale (weak scaling); the planner (priority
    heuristic inlined here) re-picks DP/PP split at each scale.  ``perf``
    may be any ``PerfModel`` backend; the DCN penalty above one SuperPod is
    applied by pinning the "pod" axis through ``override_axis``.
    """
    from .planner import best_parallel_spec  # local import to avoid cycle

    perf = perf or build_comm_model(multi_pod=True, routing=Routing.BORROW)
    base_axes = perf.comm_model(None).axes
    out: dict[int, float] = {}
    base_w = replace(w, global_batch=max(w.global_batch, base_chips // 8))
    base_p = best_parallel_spec(base_w, base_chips, perf)
    base_r = simulate(base_w, base_p, perf)
    base_per_npu = base_r.tokens_per_s / base_chips
    for k in scales:
        chips = base_chips * k
        wk = replace(base_w, global_batch=base_w.global_batch * k)
        # beyond one SuperPod (8K), DP crosses the DCN: cheaper per-chip BW
        perf_k = perf
        if chips > 8192 and "pod" in base_axes:
            dcn_gbs = base_axes["pod"].gbs_per_chip / 2.5
            perf_k = perf.override_axis(
                "pod",
                AxisCost(
                    size=max(2, chips // 8192),
                    gbs_per_chip=dcn_gbs,
                    latency_s=10e-6,
                ),
            )
        pk = best_parallel_spec(wk, chips, perf_k)
        rk = simulate(wk, pk, perf_k)
        per_npu = rk.tokens_per_s / chips
        if chips > 8192:
            # cross-SuperPod DCN jitter/straggler amortization (§6.5): the
            # 64x points in Fig. 22 sit at 95-97%
            per_npu /= 1.0 + 0.012 * math.log2(chips / 8192)
        out[k] = per_npu / base_per_npu
    return out
