"""Topology-aware parallelization planner (paper §5.2, Fig. 15).

Step 1 — generate feasible parallelism configurations mapped onto UB-Mesh;
Step 2 — price each through a ``core.perf_model.PerfModel`` backend (the
closed-form analytic ``CommModel``, or the netsim-calibrated backend whose
``CalibrationProfile`` prices each collective SHAPE on its own measured
bandwidth — so EP's all-to-all is no longer flattered by an
AllReduce-calibrated scalar);
Step 3 — pick the minimum-cost configuration.

Search-space pruning follows the paper's priority heuristic: TP and SP
(high volume) are pinned to the high-bandwidth intra-rack domain first;
PP and DP get what remains; for MoE, SP*DP must be an integer multiple of EP.
"""

from __future__ import annotations

import itertools
import logging
import math
import time
from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Iterator

from .cost_model import CommModel
from .traffic import ParallelSpec, WorkloadSpec

if TYPE_CHECKING:  # pragma: no cover
    from .perf_model import PerfModel

log = logging.getLogger(__name__)


def _divisors_pow2(n: int, cap: int) -> list[int]:
    out = []
    d = 1
    while d <= min(n, cap):
        if n % d == 0:
            out.append(d)
        d *= 2
    return out


HBM_BYTES = 48e9        # datacenter-class NPU HBM (the paper's NPUs; the
                        # production-mesh fit for OUR framework is checked by
                        # the dry-run's memory_analysis, not this constant)


def memory_feasible(w: WorkloadSpec, p: ParallelSpec, hbm: float = HBM_BYTES) -> bool:
    """First-order per-chip memory: bf16 params + ZeRO-1 optimizer shards +
    remat'd activation boundaries must fit HBM.  This is what forces PP at
    small scale (and creates the paper's Fig. 22 super-linearity when larger
    scale unlocks bubble-free configs).
    """
    if w.n_experts > 0:
        dense = w.params_total * (1 - w.moe_param_frac)
        moe = w.params_total * w.moe_param_frac
        p_local = dense / (p.tp * p.pp) + moe / (p.tp * p.pp * p.ep)
    else:
        p_local = w.params_total / (p.tp * p.pp)
    param_bytes = p_local * 2.0
    grad_bytes = p_local * 2.0
    optim_bytes = p_local * 12.0 / p.dp          # ZeRO-1: fp32 master + m + v
    seqs_per_dp = max(1, w.global_batch // p.dp)
    s_loc = max(1, w.seq_len // p.sp)
    tokens_mb = max(1, seqs_per_dp * s_loc // max(1, p.microbatches))
    layers_local = max(1, w.n_layers // p.pp)
    # remat: keep ~2 boundary tensors per layer + pipeline in-flight copies
    act_bytes = tokens_mb * w.hidden * 2.0 * 2.0 * layers_local
    act_bytes *= min(p.pp, p.microbatches)      # 1F1B in-flight microbatches
    return param_bytes + grad_bytes + optim_bytes + act_bytes <= hbm


@dataclass(frozen=True)
class PlanResult:
    spec: ParallelSpec
    iteration_s: float
    compute_s: float
    comm_s: float
    bubble_s: float


@dataclass(frozen=True)
class PlanReport:
    """Ranked plan results plus the search's bookkeeping.

    Sequence-like over ``results`` so ``plan(...)[0]`` / iteration keep
    working; ``skipped`` counts specs whose simulation RAISED (by exception
    type) — previously swallowed silently, which hid cost-model bugs.

    ``wall_s`` is the search's wall-clock cost and ``calibration`` the
    netsim calibration-memo delta over the search (``hits`` / ``misses`` /
    ``measure_s`` / ``per_key_s`` from
    ``core.perf_model.calibration_stats``) — together they attribute
    planner latency: a search that re-measures is slow in ``measure_s``,
    a memo-warm one is pure enumeration.
    """

    results: tuple[PlanResult, ...]
    n_enumerated: int = 0
    n_infeasible: int = 0                      # failed memory_feasible
    skipped: dict[str, int] = field(default_factory=dict)
    wall_s: float = 0.0
    calibration: dict = field(default_factory=dict)

    @property
    def n_skipped(self) -> int:
        return sum(self.skipped.values())

    def __iter__(self) -> Iterator[PlanResult]:
        return iter(self.results)

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i):
        return self.results[i]


def enumerate_specs(
    w: WorkloadSpec,
    chips: int,
    *,
    rack_size: int = 64,
    max_tp: int = 64,
    microbatch_options: tuple[int, ...] = (1, 2, 4, 8, 13, 16, 32),
) -> list[ParallelSpec]:
    """Feasible (tp, sp, pp, dp, ep, m) factorizations of ``chips``."""
    specs: list[ParallelSpec] = []
    for tp in _divisors_pow2(chips, max_tp):
        rem = chips // tp
        for pp in _divisors_pow2(rem, min(rem, w.n_layers)):
            dp = rem // pp
            if dp < 1:
                continue
            seqs_per_dp = w.global_batch / dp
            if seqs_per_dp < 1:
                continue
            sp_options = [
                s for s in (1, 2, 4, 8, 16, 32, 64) if w.seq_len % s == 0
            ]
            for sp in sp_options:
                # paper heuristic: prioritize TP*SP into the rack domain;
                # long-context jobs may spill across racks (Fig. 20), but
                # never beyond a quarter pod.
                if tp * sp > 16 * rack_size:
                    continue
                ep_options = [1]
                if w.n_experts > 0:
                    ep_options = [
                        e
                        for e in (1, 2, 4, 8, 16, 32)
                        if e <= w.n_experts
                        and w.n_experts % e == 0
                        and (sp * dp) % e == 0  # paper: SP*DP multiple of EP
                    ]
                for ep in ep_options:
                    s_loc = max(1, w.seq_len // sp)
                    # sequence-split microbatching: long-context jobs may
                    # chop the local sequence into >=2048-token microbatches
                    max_m = max(1, int(seqs_per_dp)) * max(1, s_loc // 2048)
                    for m in microbatch_options:
                        if m > max_m:
                            continue
                        if pp > 1 and m < pp:  # bubble-dominated; prune
                            continue
                        specs.append(
                            ParallelSpec(
                                tp=tp, sp=sp, pp=pp, dp=dp, ep=ep, microbatches=m
                            )
                        )
    return specs


def plan(
    w: WorkloadSpec,
    chips: int,
    perf: "PerfModel | CommModel",
    *,
    rack_size: int = 64,
    top_k: int = 5,
) -> PlanReport:
    """Rank feasible specs by simulated iteration time (Step 2+3).

    ``perf`` is any ``core.perf_model.PerfModel`` backend (a plain
    ``CommModel`` is the analytic one); a ``NetsimPerfModel`` ranks specs
    on flow-level *measured* axis bandwidths instead of idealized ones.

    Specs whose simulation raises (missing axis, degenerate bandwidth) are
    counted per exception type on ``PlanReport.skipped`` and summarized in
    one log line — not silently dropped, so model bugs stay visible.
    """
    from .perf_model import calibration_stats  # local import to avoid cycle
    from .simulator import simulate  # local import to avoid cycle

    t_start = time.perf_counter()
    cal_before = calibration_stats()
    results: list[PlanResult] = []
    skipped: dict[str, int] = {}
    n_enumerated = 0
    n_infeasible = 0
    for spec in enumerate_specs(w, chips, rack_size=rack_size):
        n_enumerated += 1
        if not memory_feasible(w, spec):
            n_infeasible += 1
            continue
        try:
            r = simulate(w, spec, perf, rack_size=rack_size)
        except (KeyError, ZeroDivisionError) as e:
            skipped[type(e).__name__] = skipped.get(type(e).__name__, 0) + 1
            continue
        results.append(
            PlanResult(
                spec=spec,
                iteration_s=r.iteration_s,
                compute_s=r.compute_s,
                comm_s=r.comm_total_s,
                bubble_s=r.bubble_s,
            )
        )
    if skipped:
        log.warning(
            "plan(%s, %d chips): %d/%d specs skipped by simulate errors %s",
            w.name, chips, sum(skipped.values()), n_enumerated, skipped,
        )
    results.sort(key=lambda x: x.iteration_s)
    cal_after = calibration_stats()
    calibration = {
        "hits": cal_after["hits"] - cal_before["hits"],
        "misses": cal_after["misses"] - cal_before["misses"],
        "measure_s": cal_after["measure_s"] - cal_before["measure_s"],
        "per_key_s": {
            "{}/{}/{}".format(*k): dt - cal_before["per_key_s"].get(k, 0.0)
            for k, dt in cal_after["per_key_s"].items()
            if dt - cal_before["per_key_s"].get(k, 0.0) > 0.0
        },
    }
    return PlanReport(
        results=tuple(results[:top_k]),
        n_enumerated=n_enumerated,
        n_infeasible=n_infeasible,
        skipped=skipped,
        wall_s=time.perf_counter() - t_start,
        calibration=calibration,
    )


def best_parallel_spec(
    w: WorkloadSpec, chips: int, perf: "PerfModel | CommModel", *, rack_size: int = 64
) -> ParallelSpec:
    ranked = plan(w, chips, perf, rack_size=rack_size, top_k=1)
    if not ranked:
        raise ValueError(f"no feasible parallelization for {w.name} on {chips} chips")
    return ranked[0].spec
