"""Topology-aware parallelization planner (paper §5.2, Fig. 15).

Step 1 — generate feasible parallelism configurations mapped onto UB-Mesh;
Step 2 — price each through a ``core.perf_model.PerfModel`` backend (the
closed-form analytic ``CommModel``, or the netsim-calibrated backend whose
``CalibrationProfile`` prices each collective SHAPE on its own measured
bandwidth — so EP's all-to-all is no longer flattered by an
AllReduce-calibrated scalar);
Step 3 — pick the minimum-cost configuration.

Search-space pruning follows the paper's priority heuristic: TP and SP
(high volume) are pinned to the high-bandwidth intra-rack domain first;
PP and DP get what remains; for MoE, SP*DP must be an integer multiple of EP.
"""

from __future__ import annotations

import itertools
import logging
import math
import time
from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Iterator

from .cost_model import CommModel
from .traffic import ParallelSpec, WorkloadSpec

if TYPE_CHECKING:  # pragma: no cover
    from .perf_model import PerfModel

log = logging.getLogger(__name__)


def _divisors_pow2(n: int, cap: int) -> list[int]:
    out = []
    d = 1
    while d <= min(n, cap):
        if n % d == 0:
            out.append(d)
        d *= 2
    return out


HBM_BYTES = 48e9        # datacenter-class NPU HBM (the paper's NPUs; the
                        # production-mesh fit for OUR framework is checked by
                        # the dry-run's memory_analysis, not this constant)


def memory_feasible(w: WorkloadSpec, p: ParallelSpec, hbm: float = HBM_BYTES) -> bool:
    """First-order per-chip memory: bf16 params + ZeRO-1 optimizer shards +
    remat'd activation boundaries must fit HBM.  This is what forces PP at
    small scale (and creates the paper's Fig. 22 super-linearity when larger
    scale unlocks bubble-free configs).
    """
    if w.n_experts > 0:
        dense = w.params_total * (1 - w.moe_param_frac)
        moe = w.params_total * w.moe_param_frac
        p_local = dense / (p.tp * p.pp) + moe / (p.tp * p.pp * p.ep)
    else:
        p_local = w.params_total / (p.tp * p.pp)
    param_bytes = p_local * 2.0
    grad_bytes = p_local * 2.0
    optim_bytes = p_local * 12.0 / p.dp          # ZeRO-1: fp32 master + m + v
    seqs_per_dp = max(1, w.global_batch // p.dp)
    s_loc = max(1, w.seq_len // p.sp)
    tokens_mb = max(1, seqs_per_dp * s_loc // max(1, p.microbatches))
    layers_local = max(1, w.n_layers // p.pp)
    # remat: keep ~2 boundary tensors per layer + pipeline in-flight copies
    act_bytes = tokens_mb * w.hidden * 2.0 * 2.0 * layers_local
    act_bytes *= min(p.pp, p.microbatches)      # 1F1B in-flight microbatches
    return param_bytes + grad_bytes + optim_bytes + act_bytes <= hbm


@dataclass(frozen=True)
class PlanResult:
    spec: ParallelSpec
    iteration_s: float
    compute_s: float
    comm_s: float
    bubble_s: float


@dataclass(frozen=True)
class Prefilter:
    """Tuning of the vectorized analytic pre-filter (see ``plan``).

    ``keep_k`` specs with the best analytic iteration time always survive
    (never fewer than the requested ``top_k``).  ``margin`` is the safety
    factor on the analytic comm estimate that extends the survivor set:
    every spec whose analytic time beats the best achievable time under a
    ``margin``-fold bandwidth degradation also survives.  Because a
    measured backend only ever prices comm at or *below* the analytic
    bandwidth (``CalibrationProfile.apply(clamp=True)``), a spec whose
    analytic time exceeds that cutoff cannot win unless measurement
    degrades some bandwidth by more than ``margin`` — 5x covers the worst
    observed analytic/netsim ratio (the relay-and-incast-priced A2A at
    ~4.2x) with slack.
    """

    keep_k: int = 64
    margin: float = 5.0


def analytic_iteration_arrays(
    w: WorkloadSpec,
    specs: list[ParallelSpec],
    comm: CommModel,
    *,
    rack_size: int = 64,
):
    """Per-spec ``(compute_s, comm_s, bubble_s)`` numpy arrays from the
    vectorized analytic cost model — the batch replica of
    ``analyze_traffic`` + ``simulate``.

    Every closed-form collective cost is linear in the payload for a
    fixed ``CommModel`` (``c1 * bytes + c0``), so each (axis, shape)
    needs one two-point probe and the per-spec composition is pure
    arithmetic on the (tp, sp, pp, dp, ep, m) arrays.  Raises on models
    the analytic composition cannot price (missing axes).

    Shared by the planner's spec pre-filter (:func:`_prefilter_mask`) and
    the topology co-design geometry cull (``core/codesign.py``) — when a
    measured backend clamps at the analytic bound, ``compute + bubble +
    comm`` is a LOWER bound and ``compute + bubble + margin * comm`` an
    upper-bound proxy on the measured iteration, which is what both
    winner-safety arguments rest on."""
    import numpy as np

    from .simulator import OVERLAP, _compute_seconds

    def lin(f) -> tuple[float, float]:
        # closed forms return c1 * size + c0 for size > 0 (and 0 at 0)
        s1, s2 = 1e6, 2e6
        t1, t2 = f(s1), f(s2)
        c1 = (t2 - t1) / (s2 - s1)
        return c1, t1 - c1 * s1

    cost = {
        ("model", "allreduce"): lin(lambda s: comm.allreduce("model", s)),
        ("model", "all_gather"): lin(lambda s: comm.all_gather("model", s)),
        ("model", "all_to_all"): lin(lambda s: comm.all_to_all("model", s)),
        ("data", "allreduce"): lin(lambda s: comm.allreduce("data", s)),
        ("data", "all_gather"): lin(lambda s: comm.all_gather("data", s)),
        ("data", "all_to_all"): lin(lambda s: comm.all_to_all("data", s)),
        ("data", "p2p"): lin(lambda s: comm.p2p("data", s)),
    }
    dp_axes = ["data"] + (["pod"] if "pod" in comm.axes else [])
    hier = lin(lambda s: comm.hierarchical_allreduce(dp_axes, s))

    tp = np.array([p.tp for p in specs], dtype=np.int64)
    sp = np.array([p.sp for p in specs], dtype=np.int64)
    pp = np.array([p.pp for p in specs], dtype=np.int64)
    dp = np.array([p.dp for p in specs], dtype=np.int64)
    ep = np.array([p.ep for p in specs], dtype=np.int64)
    m = np.array([p.microbatches for p in specs], dtype=np.int64)
    buckets = np.array([p.grad_buckets for p in specs], dtype=np.int64)

    def price(axis_local: str, shape: str, v, n):
        c1l, c0l = cost[(axis_local, shape)]
        t_local = np.where(n > 0, (c1l * v + c0l) * n, 0.0)
        if axis_local == "model":       # TP/SP/EP spill to the data axis
            c1s, c0s = cost[("data", shape)]
            t_spill = np.where(n > 0, (c1s * v + c0s) * n, 0.0)
            return (1.0 - spill) * t_local + spill * t_spill
        return t_local

    # ---- analyze_traffic, vectorized -------------------------------------
    bpe = w.bytes_per_elem
    L = w.n_layers
    seqs = np.maximum(1, w.global_batch // dp)
    s_loc = np.maximum(1, w.seq_len // sp)
    tokens_mb = np.maximum(1, seqs * s_loc // m)
    v_act = tokens_mb.astype(np.float64) * w.hidden * bpe

    footprint = tp * sp
    spill = np.where(
        footprint > rack_size, 1.0 - rack_size / footprint, 0.0
    )

    comm_total = np.zeros(len(specs))
    n_base = 4 * L * m
    n_eff = np.maximum(1, n_base // pp)          # simulate's L/pp hosting
    # TP: AllReduce on the model axis
    comm_total += (
        price("model", "allreduce", v_act, np.where(tp > 1, n_eff, 0))
        * (1 - OVERLAP["TP"])
    )
    # SP: half-width re-gathers + full-width gathers
    sp_mask = sp > 1
    comm_total += (
        price("model", "all_gather", v_act / 2, np.where(sp_mask, n_eff, 0))
        + price(
            "model", "all_gather", v_act,
            np.where(sp_mask, np.maximum(1, (n_base // 3) // pp), 0),
        )
    ) * (1 - OVERLAP["SP"])
    # EP: dispatch/combine A2A (ledger stores the per-peer chunk; the
    # device-level payload per op is chunk * ep)
    if w.n_experts > 0:
        ep_mask = ep > 1
        off = np.where(ep_mask, (ep - 1) / np.maximum(ep, 1), 0.0)
        v_a2a = tokens_mb * w.topk * (w.hidden / tp) * bpe * off / np.maximum(ep, 1)
        comm_total += (
            price(
                "model", "all_to_all", v_a2a * ep,
                np.where(ep_mask, n_eff, 0),
            )
            * (1 - OVERLAP["EP"])
        )
    # PP: boundary activations on the data axis
    comm_total += (
        price("data", "p2p", v_act, np.where(pp > 1, 2 * m, 0))
        * (1 - OVERLAP["PP"])
    )
    # DP: bucketed gradient AllReduce up the data(+pod) hierarchy
    if w.n_experts > 0:
        dense = w.params_total * (1 - w.moe_param_frac)
        moe = w.params_total * w.moe_param_frac
        p_local = dense / (tp * pp) + moe / (tp * pp * ep)
    else:
        p_local = w.params_total / (tp * pp)
    v_grad = p_local * 4.0 / buckets
    c1h, c0h = hier
    comm_total += np.where(
        dp > 1, (c1h * v_grad + c0h) * buckets, 0.0
    ) * (1 - OVERLAP["DP"])

    compute_s = _compute_seconds(w, specs[0])    # chips-invariant scalar
    bubble_s = np.where(pp > 1, compute_s * (pp - 1) / np.maximum(m, 1), 0.0)
    return np.full(len(specs), compute_s), comm_total, bubble_s


def _prefilter_mask(
    w: WorkloadSpec,
    specs: list[ParallelSpec],
    comm: CommModel,
    *,
    rack_size: int,
    keep_k: int,
    margin: float,
):
    """Boolean survivor mask over ``specs`` from
    :func:`analytic_iteration_arrays`."""
    import numpy as np

    compute_s, comm_total, bubble_s = analytic_iteration_arrays(
        w, specs, comm, rack_size=rack_size
    )
    iteration = compute_s + comm_total + bubble_s

    # survivors: the analytic top keep_k, plus everything that could still
    # win under a margin-fold bandwidth degradation of the best candidate
    cutoff = np.min(compute_s + bubble_s + margin * comm_total)
    keep = iteration <= cutoff
    if len(specs) > keep_k:
        keep |= iteration <= np.partition(iteration, keep_k - 1)[keep_k - 1]
    else:
        keep[:] = True
    return keep


@dataclass(frozen=True)
class PlanReport:
    """Ranked plan results plus the search's bookkeeping.

    Sequence-like over ``results`` so ``plan(...)[0]`` / iteration keep
    working; ``skipped`` counts specs whose simulation RAISED (by exception
    type) — previously swallowed silently, which hid cost-model bugs.

    ``wall_s`` is the search's wall-clock cost and ``calibration`` the
    netsim calibration-memo delta over the search (``hits`` / ``misses`` /
    ``measure_s`` / ``per_key_s`` from
    ``core.perf_model.calibration_stats``) — together they attribute
    planner latency: a search that re-measures is slow in ``measure_s``,
    a memo-warm one is pure enumeration.
    """

    results: tuple[PlanResult, ...]
    n_enumerated: int = 0
    n_infeasible: int = 0                      # failed memory_feasible
    skipped: dict[str, int] = field(default_factory=dict)
    wall_s: float = 0.0
    calibration: dict = field(default_factory=dict)
    n_prefiltered: int = 0                     # culled by the analytic pre-filter

    @property
    def n_skipped(self) -> int:
        return sum(self.skipped.values())

    def __iter__(self) -> Iterator[PlanResult]:
        return iter(self.results)

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, i):
        return self.results[i]


def enumerate_specs(
    w: WorkloadSpec,
    chips: int,
    *,
    rack_size: int = 64,
    max_tp: int = 64,
    microbatch_options: tuple[int, ...] = (1, 2, 4, 8, 13, 16, 32),
) -> list[ParallelSpec]:
    """Feasible (tp, sp, pp, dp, ep, m) factorizations of ``chips``."""
    specs: list[ParallelSpec] = []
    for tp in _divisors_pow2(chips, max_tp):
        rem = chips // tp
        for pp in _divisors_pow2(rem, min(rem, w.n_layers)):
            dp = rem // pp
            if dp < 1:
                continue
            seqs_per_dp = w.global_batch / dp
            if seqs_per_dp < 1:
                continue
            sp_options = [
                s for s in (1, 2, 4, 8, 16, 32, 64) if w.seq_len % s == 0
            ]
            for sp in sp_options:
                # paper heuristic: prioritize TP*SP into the rack domain;
                # long-context jobs may spill across racks (Fig. 20), but
                # never beyond a quarter pod.
                if tp * sp > 16 * rack_size:
                    continue
                ep_options = [1]
                if w.n_experts > 0:
                    ep_options = [
                        e
                        for e in (1, 2, 4, 8, 16, 32)
                        if e <= w.n_experts
                        and w.n_experts % e == 0
                        and (sp * dp) % e == 0  # paper: SP*DP multiple of EP
                    ]
                for ep in ep_options:
                    s_loc = max(1, w.seq_len // sp)
                    # sequence-split microbatching: long-context jobs may
                    # chop the local sequence into >=2048-token microbatches
                    max_m = max(1, int(seqs_per_dp)) * max(1, s_loc // 2048)
                    for m in microbatch_options:
                        if m > max_m:
                            continue
                        if pp > 1 and m < pp:  # bubble-dominated; prune
                            continue
                        specs.append(
                            ParallelSpec(
                                tp=tp, sp=sp, pp=pp, dp=dp, ep=ep, microbatches=m
                            )
                        )
    return specs


def enumerate_decode_specs(
    w: WorkloadSpec,
    chips: int,
    *,
    max_tp: int = 64,
    hbm: float = HBM_BYTES,
) -> list[ParallelSpec]:
    """Feasible (tp, dp) shardings of ``chips`` for decode serving.

    Decode inference has no gradients, optimizer shards or pipeline
    microbatching to trade off: the factorization is TP (weight sharding
    inside the rack plane) x DP (independent serving replicas), and the
    only hard constraint is that the bf16 weight shard fits HBM.  The
    interesting tension — maximum TP streams the smallest shard per step
    but pays the widest collective latency per token — is priced by
    ``launch.serve.decode_step_s``, not filtered here.
    """
    specs: list[ParallelSpec] = []
    for tp in _divisors_pow2(chips, max_tp):
        dp = chips // tp
        if tp * dp != chips:
            continue
        if w.params_total * w.bytes_per_elem / tp > hbm:
            continue
        specs.append(
            ParallelSpec(
                tp=tp, sp=1, pp=1, dp=dp, ep=1,
                microbatches=1, grad_buckets=1,
            )
        )
    return specs


def _prefilter_comm(perf: "PerfModel | CommModel") -> CommModel:
    """The spec-invariant analytic model the pre-filter prices against.

    For the netsim backend this is its analytic *base* (plus any pinned
    axis overrides) — deliberately NOT ``comm_model(None)``, which would
    trigger netsim measurement of the default widths before the filter
    has trimmed the spec set.  Measured backends clamp at the analytic
    bound, so the base is a true lower bound on what pricing will return
    — exactly what the ``Prefilter.margin`` soundness argument needs.
    Spec-invariant backends resolve ``comm_model(None)`` directly (cheap,
    and identical to what final pricing uses)."""
    base = getattr(perf, "base", None)
    if getattr(perf, "backend", "") == "netsim" and isinstance(base, CommModel):
        pinned = getattr(perf, "pinned", None) or {}
        if pinned:
            axes = dict(base.axes)
            axes.update(pinned)
            return CommModel(axes=axes, routing=base.routing)
        return base
    return perf.comm_model(None)


def plan(
    w: WorkloadSpec,
    chips: int,
    perf: "PerfModel | CommModel",
    *,
    rack_size: int = 64,
    top_k: int = 5,
    max_tp: int = 64,
    microbatch_options: tuple[int, ...] = (1, 2, 4, 8, 13, 16, 32),
    prefilter: "Prefilter | None" = Prefilter(),
    precalibrate: bool = True,
) -> PlanReport:
    """Rank feasible specs by simulated iteration time (Step 2+3).

    ``perf`` is any ``core.perf_model.PerfModel`` backend (a plain
    ``CommModel`` is the analytic one); a ``NetsimPerfModel`` ranks specs
    on flow-level *measured* axis bandwidths instead of idealized ones.

    ``max_tp`` / ``microbatch_options`` thread straight through to
    ``enumerate_specs`` so callers can narrow the search space without
    reimplementing the loop.

    ``prefilter`` (default on) evaluates the analytic cost model as numpy
    array ops over the whole spec batch and sends only the plausible
    Pareto tail (``Prefilter.keep_k`` best plus a ``margin``-fold safety
    band) to per-spec pricing — for a netsim backend that means far fewer
    calibration keys to measure.  Pass ``prefilter=None`` to price every
    feasible spec (the escape hatch; winner preservation of the default
    against this path is pinned by tests on every bench config).  Models
    the analytic composition cannot price (e.g. a missing axis) fall back
    to the unfiltered path automatically, so skip accounting is unchanged.

    ``precalibrate`` (default on) front-loads every calibration key the
    surviving specs need through ``NetsimPerfModel.precalibrate`` — few
    batched solver sessions instead of one per key — for backends that
    expose it.

    Specs whose simulation raises (missing axis, degenerate bandwidth) are
    counted per exception type on ``PlanReport.skipped`` and summarized in
    one log line — not silently dropped, so model bugs stay visible.
    """
    from .perf_model import calibration_stats  # local import to avoid cycle
    from .simulator import simulate  # local import to avoid cycle

    t_start = time.perf_counter()
    cal_before = calibration_stats()
    specs = enumerate_specs(
        w, chips, rack_size=rack_size, max_tp=max_tp,
        microbatch_options=microbatch_options,
    )
    n_enumerated = len(specs)
    feasible = [s for s in specs if memory_feasible(w, s)]
    n_infeasible = n_enumerated - len(feasible)

    survivors = feasible
    n_prefiltered = 0
    if prefilter is not None and len(feasible) > max(prefilter.keep_k, top_k):
        try:
            mask = _prefilter_mask(
                w, feasible, _prefilter_comm(perf),
                rack_size=rack_size,
                keep_k=max(prefilter.keep_k, top_k),
                margin=prefilter.margin,
            )
            survivors = [s for s, keep in zip(feasible, mask) if keep]
            n_prefiltered = len(feasible) - len(survivors)
        except Exception as e:  # unpriceable model: fall back to full search
            log.debug(
                "plan(%s): analytic prefilter disabled (%s: %s)",
                w.name, type(e).__name__, e,
            )
            survivors = feasible

    if precalibrate and survivors:
        pre = getattr(perf, "precalibrate", None)
        if pre is not None:
            pre(survivors)

    results: list[PlanResult] = []
    skipped: dict[str, int] = {}
    for spec in survivors:
        try:
            r = simulate(w, spec, perf, rack_size=rack_size)
        except (KeyError, ZeroDivisionError) as e:
            skipped[type(e).__name__] = skipped.get(type(e).__name__, 0) + 1
            continue
        results.append(
            PlanResult(
                spec=spec,
                iteration_s=r.iteration_s,
                compute_s=r.compute_s,
                comm_s=r.comm_total_s,
                bubble_s=r.bubble_s,
            )
        )
    if skipped:
        log.warning(
            "plan(%s, %d chips): %d/%d specs skipped by simulate errors %s",
            w.name, chips, sum(skipped.values()), n_enumerated, skipped,
        )
    results.sort(key=lambda x: x.iteration_s)
    cal_after = calibration_stats()
    calibration = {
        "hits": cal_after["hits"] - cal_before["hits"],
        "misses": cal_after["misses"] - cal_before["misses"],
        "disk_hits": cal_after["disk_hits"] - cal_before["disk_hits"],
        "measure_s": cal_after["measure_s"] - cal_before["measure_s"],
        "sessions": cal_after["sessions"] - cal_before["sessions"],
        "session_keys": cal_after["session_keys"] - cal_before["session_keys"],
        "per_key_s": {
            "{}/{}/{}".format(*k): dt - cal_before["per_key_s"].get(k, 0.0)
            for k, dt in cal_after["per_key_s"].items()
            if dt - cal_before["per_key_s"].get(k, 0.0) > 0.0
        },
    }
    return PlanReport(
        results=tuple(results[:top_k]),
        n_enumerated=n_enumerated,
        n_infeasible=n_infeasible,
        skipped=skipped,
        wall_s=time.perf_counter() - t_start,
        calibration=calibration,
        n_prefiltered=n_prefiltered,
    )


def best_parallel_spec(
    w: WorkloadSpec,
    chips: int,
    perf: "PerfModel | CommModel",
    *,
    rack_size: int = 64,
    max_tp: int = 64,
    microbatch_options: tuple[int, ...] = (1, 2, 4, 8, 13, 16, 32),
    prefilter: "Prefilter | None" = Prefilter(),
) -> ParallelSpec:
    ranked = plan(
        w, chips, perf, rack_size=rack_size, top_k=1, max_tp=max_tp,
        microbatch_options=microbatch_options, prefilter=prefilter,
    )
    if not ranked:
        raise ValueError(f"no feasible parallelization for {w.name} on {chips} chips")
    return ranked[0].spec
