"""Topology-aware communication cost model (paper §5.2, step 2).

Alpha-beta costs for every collective the training workloads emit, priced on
the UB-Mesh topology: each logical mesh axis maps to a set of full-mesh
dimensions with a concrete per-chip bandwidth (multi-ring effective BW for
AllReduce-like ops, bottleneck-link BW for All2All), plus per-hop latency.

The same model is used by
* the parallelization planner (`core/planner.py`) to rank configs,
* the training-iteration simulator (`core/simulator.py`) for Figs 17/19/20/22,
* the roofline collective refinement in `benchmarks/roofline.py`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from enum import Enum

from .topology import MeshView, NDFullMesh, production_mesh_view, ub_mesh_pod
from .multiring import plan_multiring


class Routing(str, Enum):
    SHORTEST = "shortest"   # single shortest path / single ring
    DETOUR = "detour"       # APR multi-ring / multi-path
    BORROW = "borrow"       # detour + switch-plane bandwidth borrowing


@dataclass(frozen=True)
class AxisCost:
    """Communication characteristics of one logical mesh axis."""

    size: int
    gbs_per_chip: float       # effective per-chip injection bandwidth
    latency_s: float          # per step


@dataclass(frozen=True)
class CommModel:
    """Cost model over named logical axes.

    A ``CommModel`` is itself the closed-form (analytic) backend of the
    ``core.perf_model.PerfModel`` protocol: ``comm_model()`` returns the
    model unchanged for every candidate spec.  The netsim-calibrated
    backend lives in ``core/perf_model.py``.
    """

    axes: dict[str, AxisCost]
    routing: Routing = Routing.DETOUR

    # ---- PerfModel protocol ----------------------------------------------
    @property
    def backend(self) -> str:
        return "analytic"

    def comm_model(self, p=None) -> "CommModel":
        """Resolve to a concrete cost model for spec ``p`` (spec-invariant
        for the analytic backend)."""
        return self

    def override_axis(self, name: str, cost: AxisCost) -> "CommModel":
        """A copy with one axis replaced (added if absent)."""
        axes = dict(self.axes)
        axes[name] = cost
        return CommModel(axes=axes, routing=self.routing)

    # ---- primitive collectives (per-chip completion time, seconds) -------
    def allreduce(self, axis: str, size_bytes: float) -> float:
        a = self.axes[axis]
        if a.size <= 1 or size_bytes <= 0:
            return 0.0
        wire = 2.0 * (a.size - 1) / a.size * size_bytes
        steps = 2 * (a.size - 1)
        return wire / (a.gbs_per_chip * 1e9) + steps * a.latency_s

    def reduce_scatter(self, axis: str, size_bytes: float) -> float:
        a = self.axes[axis]
        if a.size <= 1 or size_bytes <= 0:
            return 0.0
        wire = (a.size - 1) / a.size * size_bytes
        return wire / (a.gbs_per_chip * 1e9) + (a.size - 1) * a.latency_s

    def all_gather(self, axis: str, size_bytes: float) -> float:
        return self.reduce_scatter(axis, size_bytes)

    def all_to_all(self, axis: str, size_bytes: float) -> float:
        """Per-chip A2A of ``size_bytes`` total payload per chip."""
        a = self.axes[axis]
        if a.size <= 1 or size_bytes <= 0:
            return 0.0
        wire = (a.size - 1) / a.size * size_bytes
        # multi-path A2A recovers full clique bandwidth; single path halves it
        bw = a.gbs_per_chip if self.routing != Routing.SHORTEST else a.gbs_per_chip / 2
        return wire / (bw * 1e9) + a.latency_s * 2

    def p2p(self, axis: str, size_bytes: float) -> float:
        a = self.axes[axis]
        if size_bytes <= 0:
            return 0.0
        return size_bytes / (a.gbs_per_chip * 1e9) + a.latency_s

    # ---- hierarchical collectives ----------------------------------------
    def hierarchical_allreduce(
        self, axes: list[str], size_bytes: float
    ) -> float:
        """Reduce-scatter up the hierarchy, all-reduce at the top, gather
        back down — the Multi-Ring schedule across tiers (fast axes first).
        """
        if not axes:
            return 0.0
        if len(axes) == 1:
            return self.allreduce(axes[0], size_bytes)
        t = 0.0
        frac = size_bytes
        # scatter down fast->slow
        for ax in axes[:-1]:
            t += self.reduce_scatter(ax, frac)
            frac /= self.axes[ax].size
        t += self.allreduce(axes[-1], frac)
        for ax in reversed(axes[:-1]):
            frac *= self.axes[ax].size
            t += self.all_gather(ax, frac)
        return t


def build_comm_model(
    topo: NDFullMesh | None = None,
    *,
    multi_pod: bool = False,
    routing: Routing = Routing.DETOUR,
    borrow_gbs: float = 50.0,
    inter_rack_lanes: int | None = None,
) -> CommModel:
    """CommModel for the production mesh mapped onto the UB-Mesh pod.

    ``routing`` reproduces the §6.3 strategies:
      * SHORTEST — single-ring / single-path (baseline Fig. 10-(a))
      * DETOUR   — APR multi-ring & multi-path (full direct-link bandwidth)
      * BORROW   — DETOUR + switch-plane bandwidth on the inter-rack axis
    ``inter_rack_lanes`` rescales the Z/A allocation (Fig. 20 sweep).
    """
    topo = topo or ub_mesh_pod()
    if inter_rack_lanes is not None:
        per_peer = max(1, inter_rack_lanes // 8)  # split over 3+3 peers + HRS
        dims = list(topo.dims)
        dims[2] = replace(dims[2], lanes_per_peer=per_peer)
        dims[3] = replace(dims[3], lanes_per_peer=per_peer)
        topo = replace(topo, dims=tuple(dims))
    view = production_mesh_view(topo, multi_pod=multi_pod)

    def axis_bw(axis: str) -> float:
        if axis == "pod":
            return view.axis_gbs["pod"]
        dims = view.axis_dims[axis]
        if routing == Routing.SHORTEST:
            # one ring per dimension only
            bw = sum(
                topo.dims[d].gbs_per_peer for d in dims
            )
        else:
            bw = sum(
                plan_multiring(topo, d).effective_bandwidth_gbs() for d in dims
            )
        if routing == Routing.BORROW and axis == "data":
            bw += borrow_gbs
        return bw

    sizes = {"model": 16, "data": 16}
    lat = view.axis_latency_us
    axes = {
        name: AxisCost(size, axis_bw(name), lat[name] * 1e-6)
        for name, size in sizes.items()
    }
    if multi_pod:
        axes["pod"] = AxisCost(2, view.axis_gbs["pod"], lat["pod"] * 1e-6)
    return CommModel(axes=axes, routing=routing)


def clos_comm_model(*, multi_pod: bool = False, gbs: float = 450.0) -> CommModel:
    """Ideal non-oversubscribed Clos: full symmetric bandwidth everywhere."""
    axes = {
        "model": AxisCost(16, gbs, 2e-6),
        "data": AxisCost(16, gbs, 2e-6),
    }
    if multi_pod:
        axes["pod"] = AxisCost(2, gbs, 3e-6)
    return CommModel(axes=axes, routing=Routing.SHORTEST)
