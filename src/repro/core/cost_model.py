"""Topology-aware communication cost model (paper §5.2, step 2).

Alpha-beta costs for every collective the training workloads emit, priced on
the UB-Mesh topology: each logical mesh axis maps to a set of full-mesh
dimensions with a concrete per-chip bandwidth (multi-ring effective BW for
AllReduce-like ops, bottleneck-link BW for All2All), plus per-hop latency.

**Collective-shape awareness** (§2.2 / §5.1): AllReduce-shaped and
All-to-All-shaped traffic stress an nD-FullMesh very differently — MoE
dispatch rides relay hops and many-to-one bursts a ring-calibrated scalar
cannot price.  ``AxisCost`` therefore optionally carries per-shape
effective bandwidths (``shape_gbs``), and every collective method resolves
its own shape before falling back to the scalar ``gbs_per_chip``.  A
``CalibrationProfile`` — effective GB/s keyed by ``(axis, shape)``,
measured by ``repro.netsim``'s ``NetSim.calibrated_profile`` — stamps
those per-shape bandwidths onto a ``CommModel``.

The same model is used by
* the parallelization planner (`core/planner.py`) to rank configs,
* the training-iteration simulator (`core/simulator.py`) for Figs 17/19/20/22,
* the roofline collective refinement in `benchmarks/roofline.py`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from enum import Enum
from typing import Mapping

from .topology import MeshView, NDFullMesh, production_mesh_view, ub_mesh_pod
from .multiring import plan_multiring


class Routing(str, Enum):
    SHORTEST = "shortest"   # single shortest path / single ring
    DETOUR = "detour"       # APR multi-ring / multi-path
    BORROW = "borrow"       # detour + switch-plane bandwidth borrowing


# the collective shapes a CalibrationProfile distinguishes; reduce_scatter
# and all_gather share one wire schedule (the (n-1)-step ring half) so a
# measurement of one prices both
COLLECTIVE_SHAPES = (
    "allreduce",
    "all_gather",
    "reduce_scatter",
    "all_to_all",
    "p2p",
)

# upper bound on the node count of an A2A calibration group: a full-plane
# explicit-relay A2A DAG is ~8k tasks, while the EP footprint convention
# never exceeds two first-dim cliques.  Shared by the netsim measurement
# (``NetSim.a2a_group_cap``) and the calibration-cache width
# canonicalization (``perf_model.NetsimPerfModel._widths``) — one source
# of truth so cache keys always match the group actually measured.
A2A_CALIBRATION_MAX_NODES = 16


@dataclass(frozen=True)
class AxisCost:
    """Communication characteristics of one logical mesh axis.

    ``shape_gbs`` optionally refines the scalar ``gbs_per_chip`` with
    per-collective-shape effective bandwidths (shape ∈ COLLECTIVE_SHAPES);
    ``bw_for(shape)`` resolves shape-first with scalar fallback, so a
    profile-free AxisCost prices exactly as before.
    """

    size: int
    gbs_per_chip: float       # effective per-chip injection bandwidth
    latency_s: float          # per step
    shape_gbs: tuple[tuple[str, float], ...] = ()   # ((shape, GB/s), ...)

    def __post_init__(self) -> None:
        if isinstance(self.shape_gbs, Mapping):   # accept dicts for ergonomics
            object.__setattr__(
                self, "shape_gbs", tuple(sorted(self.shape_gbs.items()))
            )

    def bw_for(self, shape: str) -> float:
        """Effective GB/s for ``shape``, falling back to the scalar."""
        for s, gbs in self.shape_gbs:
            if s == shape:
                return gbs
        return self.gbs_per_chip

    def has_shape(self, shape: str) -> bool:
        return any(s == shape for s, _ in self.shape_gbs)


@dataclass(frozen=True)
class CommModel:
    """Cost model over named logical axes.

    A ``CommModel`` is itself the closed-form (analytic) backend of the
    ``core.perf_model.PerfModel`` protocol: ``comm_model()`` returns the
    model unchanged for every candidate spec.  The netsim-calibrated
    backend lives in ``core/perf_model.py``.
    """

    axes: dict[str, AxisCost]
    routing: Routing = Routing.DETOUR

    # ---- PerfModel protocol ----------------------------------------------
    @property
    def backend(self) -> str:
        return "analytic"

    def comm_model(self, p=None) -> "CommModel":
        """Resolve to a concrete cost model for spec ``p`` (spec-invariant
        for the analytic backend)."""
        return self

    def override_axis(self, name: str, cost: AxisCost) -> "CommModel":
        """A copy with one axis replaced (added if absent)."""
        axes = dict(self.axes)
        axes[name] = cost
        return CommModel(axes=axes, routing=self.routing)

    # ---- primitive collectives (per-chip completion time, seconds) -------
    def allreduce(self, axis: str, size_bytes: float) -> float:
        a = self.axes[axis]
        if a.size <= 1 or size_bytes <= 0:
            return 0.0
        wire = 2.0 * (a.size - 1) / a.size * size_bytes
        steps = 2 * (a.size - 1)
        return wire / (a.bw_for("allreduce") * 1e9) + steps * a.latency_s

    def reduce_scatter(self, axis: str, size_bytes: float) -> float:
        a = self.axes[axis]
        if a.size <= 1 or size_bytes <= 0:
            return 0.0
        wire = (a.size - 1) / a.size * size_bytes
        return wire / (a.bw_for("reduce_scatter") * 1e9) + (a.size - 1) * a.latency_s

    def all_gather(self, axis: str, size_bytes: float) -> float:
        a = self.axes[axis]
        if a.size <= 1 or size_bytes <= 0:
            return 0.0
        wire = (a.size - 1) / a.size * size_bytes
        return wire / (a.bw_for("all_gather") * 1e9) + (a.size - 1) * a.latency_s

    def all_to_all(self, axis: str, size_bytes: float) -> float:
        """Per-chip A2A of ``size_bytes`` total payload per chip."""
        a = self.axes[axis]
        if a.size <= 1 or size_bytes <= 0:
            return 0.0
        wire = (a.size - 1) / a.size * size_bytes
        if a.has_shape("all_to_all"):
            # a measured A2A bandwidth already embodies the routing policy
            # (relay hops, multipath splits, incast serialization)
            bw = a.bw_for("all_to_all")
        else:
            # multi-path A2A recovers full clique bandwidth; single path
            # halves it
            bw = (
                a.gbs_per_chip
                if self.routing != Routing.SHORTEST
                else a.gbs_per_chip / 2
            )
        return wire / (bw * 1e9) + a.latency_s * 2

    def p2p(self, axis: str, size_bytes: float) -> float:
        a = self.axes[axis]
        if size_bytes <= 0:
            return 0.0
        return size_bytes / (a.bw_for("p2p") * 1e9) + a.latency_s

    # ---- hierarchical collectives ----------------------------------------
    def hierarchical_allreduce(
        self, axes: list[str], size_bytes: float
    ) -> float:
        """Reduce-scatter up the hierarchy, all-reduce at the top, gather
        back down — the Multi-Ring schedule across tiers (fast axes first).
        """
        if not axes:
            return 0.0
        if len(axes) == 1:
            return self.allreduce(axes[0], size_bytes)
        t = 0.0
        frac = size_bytes
        # scatter down fast->slow
        for ax in axes[:-1]:
            t += self.reduce_scatter(ax, frac)
            frac /= self.axes[ax].size
        t += self.allreduce(axes[-1], frac)
        for ax in reversed(axes[:-1]):
            frac *= self.axes[ax].size
            t += self.all_gather(ax, frac)
        return t


@dataclass(frozen=True)
class CalibrationProfile:
    """Measured effective bandwidths keyed by ``(axis, collective shape)``.

    Produced by executing each shape's flow DAG on the flow-level simulator
    (``NetSim.calibrated_profile``), in the per-chip GB/s units ``CommModel``
    carries: plugging ``gbs[(axis, shape)]`` into the matching closed-form
    collective formula reproduces the measured completion time.  Because
    A2A rides relay hops and incast-capped receivers while AllReduce rides
    edge-disjoint rings, ``gbs[(ax, "all_to_all")] < gbs[(ax, "allreduce")]``
    on any multi-dimension axis — the whole point of shape-aware pricing.
    """

    gbs: dict[tuple[str, str], float] = field(default_factory=dict)

    def get(self, axis: str, shape: str, default: float | None = None):
        return self.gbs.get((axis, shape), default)

    def axis_shapes(self, axis: str) -> dict[str, float]:
        """shape -> GB/s of every measurement for ``axis``."""
        return {s: g for (a, s), g in sorted(self.gbs.items()) if a == axis}

    def merged(self, other: "CalibrationProfile") -> "CalibrationProfile":
        return CalibrationProfile(gbs={**self.gbs, **other.gbs})

    def apply(self, comm: CommModel, *, clamp: bool = True) -> CommModel:
        """Stamp the profile onto ``comm``: each measured axis gains
        per-shape bandwidths, and its scalar ``gbs_per_chip`` drops to the
        AllReduce measurement (so shape-unaware consumers see the same
        number the scalar calibration used to produce).  ``clamp`` keeps
        every measured bandwidth at or below the analytic value — a flow-
        level measurement can only tighten the closed-form bound."""
        axes = {}
        for name, a in comm.axes.items():
            shapes = self.axis_shapes(name)
            if not shapes:
                axes[name] = a
                continue
            if clamp:
                shapes = {s: min(g, a.gbs_per_chip) for s, g in shapes.items()}
            scalar = shapes.get("allreduce", a.gbs_per_chip)
            axes[name] = replace(
                a, gbs_per_chip=scalar, shape_gbs=tuple(sorted(shapes.items()))
            )
        return CommModel(axes=axes, routing=comm.routing)


# the collective shapes a LatencyProfile distinguishes: the decode-serving
# per-token ops (TP allreduce, EP dispatch/combine A2A, PP boundary p2p) —
# latency calibration is for small-message shapes, so the bandwidth-only
# all_gather/reduce_scatter pair stays out
LATENCY_SHAPES = ("allreduce", "all_to_all", "p2p")


def _percentile(sorted_vals: "list[float]", q: float) -> float:
    """Nearest-rank-with-interpolation percentile over a pre-sorted list
    (pure python — deterministic, no numpy dependency in core)."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


@dataclass(frozen=True)
class LatencyStats:
    """Message-level latency measurement of one (axis, shape) collective.

    ``total_s`` is the collective's completion time (the number a
    per-token decode step pays); ``p50_s``/``p99_s``/``mean_s`` summarize
    the distribution of per-message ready-to-delivery latencies *within*
    the run — queueing-inclusive, so an incast-heavy A2A dispatch shows a
    p99 far above its p50 while an uncongested p2p has p50 == p99.
    """

    p50_s: float
    p99_s: float
    mean_s: float
    total_s: float
    n: int = 0

    @staticmethod
    def from_samples(samples: "list[float]", total_s: float) -> "LatencyStats":
        vals = sorted(samples)
        mean = sum(vals) / len(vals) if vals else 0.0
        return LatencyStats(
            p50_s=_percentile(vals, 0.50),
            p99_s=_percentile(vals, 0.99),
            mean_s=mean,
            total_s=total_s,
            n=len(vals),
        )


@dataclass(frozen=True)
class LatencyProfile:
    """Measured message-level latencies keyed by ``(axis, shape)``.

    The latency-side sibling of :class:`CalibrationProfile`: where that
    one carries effective GB/s from fluid (bandwidth) runs, this one
    carries :class:`LatencyStats` from message-level runs
    (``NetSim.measure_latency_profile``) at a decode-sized payload —
    serialization + per-hop propagation + FIFO queueing, phenomena the
    fluid model's single flat ``latency_s`` cannot see.  ``size_bytes``
    records the per-chip payload the profile was measured at.
    """

    lat: dict[tuple[str, str], LatencyStats] = field(default_factory=dict)
    size_bytes: float = 0.0

    def get(self, axis: str, shape: str) -> "LatencyStats | None":
        return self.lat.get((axis, shape))

    def collective_s(
        self, axis: str, shape: str, default: float | None = None
    ) -> "float | None":
        """Completion latency of one ``shape`` collective on ``axis``."""
        st = self.lat.get((axis, shape))
        return st.total_s if st is not None else default

    def axis_shapes(self, axis: str) -> dict[str, LatencyStats]:
        return {s: st for (a, s), st in sorted(self.lat.items()) if a == axis}

    def merged(self, other: "LatencyProfile") -> "LatencyProfile":
        return LatencyProfile(
            lat={**self.lat, **other.lat},
            size_bytes=other.size_bytes or self.size_bytes,
        )


def build_comm_model(
    topo: NDFullMesh | None = None,
    *,
    multi_pod: bool = False,
    routing: Routing = Routing.DETOUR,
    borrow_gbs: float = 50.0,
    inter_rack_lanes: int | None = None,
) -> CommModel:
    """CommModel for the production mesh mapped onto the UB-Mesh pod.

    ``routing`` reproduces the §6.3 strategies:
      * SHORTEST — single-ring / single-path (baseline Fig. 10-(a))
      * DETOUR   — APR multi-ring & multi-path (full direct-link bandwidth)
      * BORROW   — DETOUR + switch-plane bandwidth on the inter-rack axis
    ``inter_rack_lanes`` rescales the Z/A allocation (Fig. 20 sweep).
    """
    topo = topo or ub_mesh_pod()
    if inter_rack_lanes is not None:
        per_peer = max(1, inter_rack_lanes // 8)  # split over 3+3 peers + HRS
        dims = list(topo.dims)
        dims[2] = replace(dims[2], lanes_per_peer=per_peer)
        dims[3] = replace(dims[3], lanes_per_peer=per_peer)
        topo = replace(topo, dims=tuple(dims))
    view = production_mesh_view(topo, multi_pod=multi_pod)

    def axis_bw(axis: str) -> float:
        if axis == "pod":
            return view.axis_gbs["pod"]
        dims = view.axis_dims[axis]
        if routing == Routing.SHORTEST:
            # one ring per dimension only
            bw = sum(
                topo.dims[d].gbs_per_peer for d in dims
            )
        else:
            bw = sum(
                plan_multiring(topo, d).effective_bandwidth_gbs() for d in dims
            )
        if routing == Routing.BORROW and axis == "data":
            bw += borrow_gbs
        return bw

    sizes = {"model": 16, "data": 16}
    lat = view.axis_latency_us
    axes = {
        name: AxisCost(size, axis_bw(name), lat[name] * 1e-6)
        for name, size in sizes.items()
    }
    if multi_pod:
        axes["pod"] = AxisCost(2, view.axis_gbs["pod"], lat["pod"] * 1e-6)
    return CommModel(axes=axes, routing=routing)


def clos_comm_model(*, multi_pod: bool = False, gbs: float = 450.0) -> CommModel:
    """Ideal non-oversubscribed Clos: full symmetric bandwidth everywhere."""
    axes = {
        "model": AxisCost(16, gbs, 2e-6),
        "data": AxisCost(16, gbs, 2e-6),
    }
    if multi_pod:
        axes["pod"] = AxisCost(2, gbs, 3e-6)
    return CommModel(axes=axes, routing=Routing.SHORTEST)
