"""Unified Bus (UB) IO model (paper §3.2.2, Fig. 5-6).

Every component (NPU / CPU / LRS / HRS) exposes a number of UB *lanes* that
can be flexibly budgeted across uses — inter-NPU dimensions, CPU traffic,
switch uplinks.  This module is the single source of truth for lane budgets;
the topology, cost model, planner and roofline all derive bandwidth from it,
which is the paper's "flexible IO resource allocation" made concrete.
"""

from __future__ import annotations

from dataclasses import dataclass, field

# Table 3 IO capabilities
NPU_LANES = 72
CPU_LANES = 32
LRS_LANES = 72
HRS_LANES = 512

GBPS_PER_LANE = 6.25  # GB/s per UB lane (x72 => 450 GB/s ~= 3.6 Tbps, R2)


@dataclass(frozen=True)
class LaneAllocation:
    """Per-NPU lane budget across the nD-FullMesh dims + switched IO."""

    per_dim: dict[str, int] = field(
        default_factory=lambda: {"X": 28, "Y": 28, "Z": 6, "A": 6}
    )
    switched: int = 4  # LRS uplink share (CPU traffic, backup NPU, borrow)

    @property
    def total(self) -> int:
        return sum(self.per_dim.values()) + self.switched

    def validate(self, budget: int = NPU_LANES) -> None:
        if self.total > budget:
            raise ValueError(
                f"lane allocation {self.total} exceeds UB x{budget} budget"
            )

    def bandwidth_gbs(self, dim: str) -> float:
        return self.per_dim.get(dim, 0) * GBPS_PER_LANE

    def intra_rack_gbs(self) -> float:
        return (self.per_dim.get("X", 0) + self.per_dim.get("Y", 0)) * GBPS_PER_LANE

    def inter_rack_gbs(self) -> float:
        return (self.per_dim.get("Z", 0) + self.per_dim.get("A", 0)) * GBPS_PER_LANE

    def rebalance(self, **per_dim: int) -> "LaneAllocation":
        """The Fig. 5-(b) knob: shift lanes between dimensions."""
        new = dict(self.per_dim)
        new.update(per_dim)
        alloc = LaneAllocation(per_dim=new, switched=self.switched)
        alloc.validate()
        return alloc


DEFAULT_ALLOCATION = LaneAllocation()
# paper §6.3: inter-rack UB x16 per NPU default; x32 favored for >=64K seq.
LONG_CONTEXT_ALLOCATION = LaneAllocation(
    per_dim={"X": 20, "Y": 20, "Z": 14, "A": 14}, switched=4
)
