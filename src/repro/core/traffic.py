"""LLM-training traffic analysis (paper §2.2, Table 1).

Analytic, Megatron-style accounting of the bytes each parallelism technique
moves per training iteration.  The output drives three things:

* the Table-1 reproduction benchmark (locality: TP+SP ~ 97% of traffic),
* the parallelization planner's objective (which axis carries which volume),
* the training-iteration simulator (per-axis communication time).

All volumes are per-DP-replica per-iteration unless noted; bf16 payloads.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class WorkloadSpec:
    """Just enough of a model + schedule to price its traffic."""

    name: str
    n_layers: int
    hidden: int
    n_heads: int
    head_dim: int
    n_kv_heads: int | None = None
    seq_len: int = 8192
    global_batch: int = 512            # sequences
    params_total: float = 7e10
    n_experts: int = 0                 # 0 => dense
    topk: int = 2
    moe_param_frac: float = 0.8        # fraction of params in expert MLPs
    bytes_per_elem: int = 2            # bf16

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads


@dataclass(frozen=True)
class ParallelSpec:
    tp: int = 8
    sp: int = 8          # sequence/context parallel degree
    pp: int = 8
    dp: int = 8
    ep: int = 1
    microbatches: int = 13
    grad_buckets: int = 64

    @property
    def chips(self) -> int:
        # TP and SP share the high-bandwidth group in UB-Mesh (§5.2): the
        # model axis is tp*sp wide only when they shard different resources;
        # Megatron-SP reuses the TP group, so the footprint is tp * pp * dp.
        return self.tp * self.pp * self.dp


# ledger pattern label -> the canonical collective shape a
# ``core.cost_model.CalibrationProfile`` prices (COLLECTIVE_SHAPES)
_PATTERN_SHAPE = {
    "AllReduce": "allreduce",
    "AllGather": "all_gather",
    "AllGather(full)": "all_gather",
    "ReduceScatter": "reduce_scatter",
    "AlltoAll": "all_to_all",
    "P2P": "p2p",
}


@dataclass(frozen=True)
class TrafficEntry:
    technique: str
    pattern: str
    volume_per_transfer: float   # bytes
    n_transfers: int
    total_bytes: float
    locality: str                # which mesh axis carries it

    @property
    def shape(self) -> str:
        """Collective shape of this entry, in ``CalibrationProfile`` terms
        — the single source of truth the simulator dispatches on, so EP
        volume is priced on the A2A bandwidth while TP/DP keep theirs."""
        return _PATTERN_SHAPE[self.pattern]

    @property
    def volume_mb(self) -> float:
        return self.volume_per_transfer / 1e6

    @property
    def total_gb(self) -> float:
        return self.total_bytes / 1e9


@dataclass(frozen=True)
class TrafficTable:
    entries: tuple[TrafficEntry, ...]

    @property
    def total_bytes(self) -> float:
        return sum(e.total_bytes for e in self.entries)

    def share(self, technique: str) -> float:
        tot = self.total_bytes
        return (
            sum(e.total_bytes for e in self.entries if e.technique == technique)
            / tot
            if tot
            else 0.0
        )

    def local_share(self) -> float:
        """Fraction of traffic on the high-bandwidth (intra-rack) domain."""
        tot = self.total_bytes
        return (
            sum(e.total_bytes for e in self.entries if e.locality == "model")
            / tot
            if tot
            else 0.0
        )


def analyze_traffic(w: WorkloadSpec, p: ParallelSpec) -> TrafficTable:
    """Per-iteration traffic per technique (per DP replica)."""
    bpe = w.bytes_per_elem
    # local activation tile: tokens per microbatch (sequence-split
    # microbatching allowed for long-context jobs) x hidden
    seqs_per_replica = max(1, w.global_batch // p.dp)
    s_loc = max(1, w.seq_len // p.sp)
    tokens_mb = max(1, seqs_per_replica * s_loc // p.microbatches)
    b_mb = max(1, seqs_per_replica // p.microbatches)
    v_act = tokens_mb * w.hidden * bpe

    entries: list[TrafficEntry] = []

    # --- TP: 2 AllReduce fwd + 2 bwd per layer per microbatch (Megatron) ---
    if p.tp > 1:
        n = 4 * w.n_layers * p.microbatches
        entries.append(
            TrafficEntry("TP", "AllReduce", v_act, n, v_act * n, "model")
        )

    # --- SP: AllGathers around attention/MLP (two size classes, like the
    # paper's 180/360 MB mix: half-width re-gathers of the TP-sliced tiles
    # plus full-width gathers for the attention inputs) -------------------
    if p.sp > 1:
        n_half = 4 * w.n_layers * p.microbatches
        n_full = n_half // 3
        entries.append(
            TrafficEntry("SP", "AllGather", v_act / 2, n_half, v_act / 2 * n_half, "model")
        )
        entries.append(
            TrafficEntry("SP", "AllGather(full)", v_act, n_full, v_act * n_full, "model")
        )

    # --- EP: dispatch+combine All2All, fwd+bwd, per MoE layer --------------
    # Ledger follows the paper's Table 1: "volume per transfer" is the
    # per-peer A2A chunk of the TP-sliced token tile.
    if w.n_experts > 0 and p.ep > 1:
        off = (p.ep - 1) / p.ep
        v_a2a = tokens_mb * w.topk * (w.hidden / p.tp) * bpe * off / p.ep
        n = 4 * w.n_layers * p.microbatches
        entries.append(
            TrafficEntry("EP", "AlltoAll", v_a2a, n, v_a2a * n, "model")
        )

    # --- PP: boundary activations, fwd + bwd per microbatch ----------------
    if p.pp > 1:
        n = 2 * p.microbatches
        entries.append(
            TrafficEntry("PP", "P2P", v_act, n, v_act * n, "data")
        )

    # --- DP: gradient AllReduce (bucketed, fp32 reduction payloads) --------
    if p.dp > 1:
        if w.n_experts > 0:
            dense = w.params_total * (1 - w.moe_param_frac)
            moe = w.params_total * w.moe_param_frac
            p_local = dense / (p.tp * p.pp) + moe / (p.tp * p.pp * p.ep)
        else:
            p_local = w.params_total / (p.tp * p.pp)
        grad_bytes = p_local * 4
        v = grad_bytes / p.grad_buckets
        entries.append(
            TrafficEntry("DP", "AllReduce", v, p.grad_buckets, grad_bytes, "data")
        )

    return TrafficTable(entries=tuple(entries))


# Paper Table 1 reference values (in-house MoE-2T measurement) for the
# side-by-side benchmark.
PAPER_TABLE1 = {
    "TP": dict(pattern="AllReduce", volume_mb=360.0, transfers=4992, total_gb=1775.0, share=0.529),
    "SP": dict(pattern="AllGather", volume_mb=360.0, transfers=4992, total_gb=1462.5, share=0.4408),
    "EP": dict(pattern="AlltoAll", volume_mb=10.5, transfers=4992, total_gb=51.19, share=0.0154),
    "PP": dict(pattern="P2P", volume_mb=192.0, transfers=26, total_gb=4.875, share=0.0014),
    "DP": dict(pattern="AllReduce", volume_mb=711.75, transfers=64, total_gb=44.48, share=0.0134),
}


def backend_comparison_workloads() -> tuple[WorkloadSpec, WorkloadSpec]:
    """The canonical (uncongested, contended) workload pair for comparing
    PerfModel backends — shared by ``benchmarks/planner_bench.py`` and the
    backend-contract tests so the two cannot drift apart.

    * uncongested dense-70B: TP*SP = 64 fills the rack plane exactly, every
      strong candidate rides the full-bandwidth cross-dim 2D multi-ring, so
      measured and idealized rankings coincide.
    * contended MoE-600B @ seq 2500: the sequence length caps SP at 4, so
      the search is between NARROW model-axis groups (tp*sp = 16..32 chips
      -> per-dim hierarchical schedule, measured ~85 GB/s) and the full
      64-chip plane (2D multi-ring, ~140-165 GB/s).  The analytic backend
      prices them all at a flat 200 GB/s; the netsim backend knows narrow
      groups are ~2x slower and flips the winner (the Rail-only / RailX
      observation: placement decisions flip when contention is priced
      realistically).
    """
    clean = WorkloadSpec(
        "dense-70B", 80, 8192, 64, 128, 8,
        seq_len=5000, global_batch=512, params_total=7e10,
    )
    contended = WorkloadSpec(
        "moe-600B-s2500", 64, 8192, 64, 128, 8,
        seq_len=2500, global_batch=512, params_total=6e11,
        n_experts=16, topk=2, moe_param_frac=0.85,
    )
    return clean, contended


def a2a_divergence_workload() -> WorkloadSpec:
    """The canonical MoE config whose winning spec flips between
    AllReduce-proxy pricing and the A2A-aware ``CalibrationProfile`` —
    shared by ``benchmarks/planner_bench.py`` and the backend-contract
    tests.

    seq 2500 caps SP at 4, so TP*SP cannot soak up chips and EP carries a
    large dispatch volume (topk=8 of 16 experts, wide hidden, small dense
    params keep compute from masking it).  Priced on the AllReduce proxy
    the A2A is nearly free and the planner maxes out expert parallelism
    (ep=16, dp=128); priced on the measured A2A bandwidth (~3x lower:
    relay hops + incast) the same search retreats to ep=4 and buys
    pipeline stages instead — the Rail-only / "99 Problems" observation
    that topology-cost conclusions flip when A2A-shaped traffic is priced
    with its real contention pattern.
    """
    return WorkloadSpec(
        "moe-a2a-div", 32, 12288, 96, 128, 8,
        seq_len=2500, global_batch=512, params_total=8e10,
        n_experts=16, topk=8, moe_param_frac=0.9,
    )


def moe_2t_workload() -> tuple[WorkloadSpec, ParallelSpec]:
    """An MoE-2T-like setup calibrated to reproduce Table 1's locality."""
    w = WorkloadSpec(
        name="MoE-2T",
        n_layers=96,
        hidden=12288,
        n_heads=96,
        head_dim=128,
        n_kv_heads=8,
        seq_len=131072,
        global_batch=104,          # 13 sequences per replica => 13 microbatches
        params_total=2e12,
        n_experts=16,
        topk=2,
        moe_param_frac=0.8,
    )
    p = ParallelSpec(tp=8, sp=8, pp=8, dp=8, ep=8, microbatches=13, grad_buckets=64)
    return w, p
