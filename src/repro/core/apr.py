"""All-Path Routing (APR) — paper §4.

APR exposes *all* useful paths between two endpoints of the nD-FullMesh
instead of only the shortest one, enabling Detour (non-shortest paths) and
Borrow (switch-assisted paths) strategies.  Three mechanisms make it cheap:

* **Source Routing** (§4.1.1): the sender encodes per-hop forwarding
  instructions into a compact 8-byte header (Fig. 11).
* **Structured Addressing & Linear Table Lookup** (§4.1.2): addresses are the
  coordinate tuple; each segment (pod / row / rack / board / npu) is a linear
  offset, so next-hop lookup is O(1) array indexing, no LPM/TCAM.
* **Topology-aware deadlock-free Flow Control (TFC)** (§4.1.3): a 2-VL
  scheme; we build the Channel Dependency Graph of the planned paths and
  verify acyclicity.

On a real TPU the ICI router is fixed-function; in this framework APR is the
*path planner* that drives the Multi-Ring collective planner, the borrow/
detour simulator strategies, and fast fault recovery (direct notification,
§4.2).  Everything here is exact and unit-tested.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Sequence

import numpy as np

from .topology import NDFullMesh

Path = tuple[int, ...]  # sequence of node ids, path[0]=src, path[-1]=dst


# ---------------------------------------------------------------------------
# Path enumeration (shortest + detours)
# ---------------------------------------------------------------------------


def shortest_paths(topo: NDFullMesh, src: int, dst: int) -> list[Path]:
    """All shortest paths src->dst.

    In an nD-FullMesh the shortest path fixes each differing coordinate with
    exactly one hop; every ORDER of fixing them is a distinct shortest path,
    so with k differing dims there are k! shortest paths.
    """
    cs, cd = topo.coords(src), topo.coords(dst)
    diff = [i for i, (a, b) in enumerate(zip(cs, cd)) if a != b]
    paths: list[Path] = []
    for order in itertools.permutations(diff):
        cur = list(cs)
        path = [src]
        for d in order:
            cur[d] = cd[d]
            path.append(topo.node_id(cur))
        paths.append(tuple(path))
    return paths or [(src,)] if src == dst else paths


def detour_paths(
    topo: NDFullMesh, src: int, dst: int, *, max_extra_hops: int = 1
) -> list[Path]:
    """Non-shortest APR paths: replace a direct intra-dim hop by a 2-hop
    relay through a third member of the same clique (the Fig. 10-(b) "all
    path" detours).  ``max_extra_hops`` bounds how many hops are relayed.
    """
    out: list[Path] = []
    for base in shortest_paths(topo, src, dst):
        hop_dims = [topo.are_adjacent(u, v) for u, v in zip(base, base[1:])]
        n = len(base) - 1
        for relay_positions in itertools.combinations(range(n), min(max_extra_hops, n)):
            for pos in relay_positions:
                u, v = base[pos], base[pos + 1]
                dim = hop_dims[pos]
                cu = topo.coords(u)
                for w in topo.neighbors(u, dim):
                    if w == v:
                        continue
                    # relay u -> w -> v stays inside the clique of `dim`
                    cand = base[: pos + 1] + (w,) + base[pos + 1 :]
                    if len(set(cand)) == len(cand):
                        out.append(cand)
    return out


def all_paths(
    topo: NDFullMesh, src: int, dst: int, *, max_extra_hops: int = 1
) -> list[Path]:
    """APR path set: all shortest paths + single-relay detours."""
    if src == dst:
        return [(src,)]
    sp = shortest_paths(topo, src, dst)
    dp = detour_paths(topo, src, dst, max_extra_hops=max_extra_hops)
    seen: set[Path] = set()
    out: list[Path] = []
    for p in sp + dp:
        if p not in seen:
            seen.add(p)
            out.append(p)
    return out


def path_diversity(topo: NDFullMesh, src: int, dst: int) -> int:
    """Number of link-disjoint shortest+detour paths (for resilience eval)."""
    paths = all_paths(topo, src, dst)
    used: set[tuple[int, int]] = set()
    count = 0
    for p in sorted(paths, key=len):
        edges = {tuple(sorted(e)) for e in zip(p, p[1:])}
        if edges & used:
            continue
        used |= edges
        count += 1
    return count


# ---------------------------------------------------------------------------
# Source-routing header (paper Fig. 11)
# ---------------------------------------------------------------------------

_PTR_BITS = 4
_BITMAP_BITS = 12
_N_INSTR = 6
_INSTR_BITS = 8


@dataclass(frozen=True)
class SourceRouteHeader:
    """The 8-byte SR header: 4-bit ptr | 12-bit bitmap | 6 x 8-bit instrs.

    ``bitmap[i] == 1``  => hop i is source-routed; the instruction index is
    the POPCOUNT of bitmap[:i] (instructions are packed in order of the SR
    hops).  ``bitmap[i] == 0`` => hop i uses default (table) forwarding.
    """

    ptr: int
    bitmap: int
    instructions: tuple[int, ...]

    def __post_init__(self):
        if not (0 <= self.ptr < (1 << _PTR_BITS)):
            raise ValueError("ptr out of range")
        if not (0 <= self.bitmap < (1 << _BITMAP_BITS)):
            raise ValueError("bitmap out of range")
        if len(self.instructions) > _N_INSTR:
            raise ValueError("too many SR instructions (max 6)")
        if any(not (0 <= i < (1 << _INSTR_BITS)) for i in self.instructions):
            raise ValueError("instruction out of range")

    # -- wire format -------------------------------------------------------
    def pack(self) -> bytes:
        instrs = list(self.instructions) + [0] * (_N_INSTR - len(self.instructions))
        word = self.ptr | (self.bitmap << _PTR_BITS)
        raw = word.to_bytes(2, "little")
        raw += bytes(instrs)
        assert len(raw) == 8
        return raw

    @classmethod
    def unpack(cls, raw: bytes) -> "SourceRouteHeader":
        if len(raw) != 8:
            raise ValueError("SR header must be 8 bytes")
        word = int.from_bytes(raw[:2], "little")
        ptr = word & ((1 << _PTR_BITS) - 1)
        bitmap = word >> _PTR_BITS
        return cls(ptr=ptr, bitmap=bitmap, instructions=tuple(raw[2:8]))

    # -- semantics ---------------------------------------------------------
    def instruction_for_hop(self, hop: int) -> int | None:
        """Return the SR instruction for hop index ``hop`` or None (table)."""
        if hop >= _BITMAP_BITS or not (self.bitmap >> hop) & 1:
            return None
        idx = bin(self.bitmap & ((1 << hop) - 1)).count("1")
        if idx >= len(self.instructions):
            raise ValueError("bitmap refers past instruction array")
        return self.instructions[idx]

    def advance(self) -> "SourceRouteHeader":
        return SourceRouteHeader(self.ptr + 1, self.bitmap, self.instructions)


def encode_path(topo: NDFullMesh, path: Path) -> SourceRouteHeader:
    """Encode an explicit path into an SR header.

    Each hop instruction packs (dim, target-coordinate) of the next node:
    3 bits of dimension + 5 bits of coordinate — enough for dims of size <=32
    (UB-Mesh-Pod dims are 8/8/4/4).
    """
    hops = list(zip(path, path[1:]))
    if len(hops) > _N_INSTR:
        raise ValueError(f"path longer than {_N_INSTR} SR hops")
    instrs = []
    for u, v in hops:
        dim = topo.are_adjacent(u, v)
        if dim is None:
            raise ValueError(f"hop {u}->{v} is not a direct link")
        coord = topo.coords(v)[dim]
        if dim >= 8 or coord >= 32:
            raise ValueError("dim/coord exceed SR instruction encoding")
        instrs.append((dim << 5) | coord)
    bitmap = (1 << len(hops)) - 1
    instrs += [0] * (_N_INSTR - len(instrs))   # wire format stores all six
    return SourceRouteHeader(ptr=0, bitmap=bitmap, instructions=tuple(instrs))


def walk_header(topo: NDFullMesh, src: int, hdr: SourceRouteHeader) -> Path:
    """Execute an SR header from ``src``; returns the traversed path."""
    node = src
    path = [node]
    hop = hdr.ptr
    while True:
        instr = hdr.instruction_for_hop(hop)
        if instr is None:
            break
        dim, coord = instr >> 5, instr & 0x1F
        c = list(topo.coords(node))
        c[dim] = coord
        node = topo.node_id(c)
        path.append(node)
        hop += 1
    return tuple(path)


# ---------------------------------------------------------------------------
# Structured addressing & linear table lookup (paper §4.1.2)
# ---------------------------------------------------------------------------


class LinearRouteTable:
    """O(1) next-hop lookup exploiting structured addresses.

    For a node ``n`` and destination ``d``: find the FIRST dimension (scanned
    in a configurable order) where coordinates differ and emit the direct
    neighbor fixing it.  The "table" per node is just ``ndim`` dense arrays
    of size ``dims[i]`` (segment -> egress port), exactly the paper's
    linear-offset scheme — no prefix matching.
    """

    def __init__(self, topo: NDFullMesh, dim_order: Sequence[int] | None = None):
        self.topo = topo
        self.dim_order = tuple(dim_order) if dim_order is not None else tuple(
            range(topo.ndim)
        )
        # table[node][dim][coord] = next node id (or -1 for "local")
        shape = topo.shape
        self._tables = [
            np.full((topo.ndim, max(shape)), -1, dtype=np.int64)
            for _ in range(topo.num_nodes)
        ]
        for node in range(topo.num_nodes):
            c = topo.coords(node)
            for dim in range(topo.ndim):
                for coord in range(shape[dim]):
                    if coord == c[dim]:
                        self._tables[node][dim, coord] = node
                    else:
                        cc = list(c)
                        cc[dim] = coord
                        self._tables[node][dim, coord] = topo.node_id(cc)

    def table_entries(self) -> int:
        """Total table entries — LINEAR in sum(dims), not product (vs LPM)."""
        return self.topo.num_nodes * sum(self.topo.shape)

    def next_hop(self, node: int, dst: int) -> int:
        if node == dst:
            return node
        cn, cd = self.topo.coords(node), self.topo.coords(dst)
        for dim in self.dim_order:
            if cn[dim] != cd[dim]:
                return int(self._tables[node][dim, cd[dim]])
        return node

    def route(self, src: int, dst: int, max_hops: int = 16) -> Path:
        path = [src]
        node = src
        while node != dst:
            node = self.next_hop(node, dst)
            path.append(node)
            if len(path) > max_hops:
                raise RuntimeError("routing loop")
        return tuple(path)


# ---------------------------------------------------------------------------
# TFC: topology-aware deadlock-free flow control (paper §4.1.3)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Channel:
    """A virtual channel: directed link (u -> v) on virtual lane vl."""

    u: int
    v: int
    vl: int


def assign_vls(topo: NDFullMesh, path: Path, n_vls: int = 2) -> list[Channel]:
    """Assign virtual lanes to a path under the TFC rules.

    Loop-breaking rules (the paper's two principles, instantiated):

    * **cross-dimensional**: hops are expected in non-decreasing dimension
      order (dimension-ordered base routing).  A hop that moves to a LOWER
      dimension than its predecessor — only possible on detour/non-shortest
      paths — escalates the VL by one.
    * **same-dimensional**: inside a clique (a ring in the CDG sense), a hop
      from a higher node-index to a lower node-index ("dateline crossing")
      escalates the VL.

    With one escalation budget (2 VLs) every APR path of the 4D mesh is
    routable deadlock-free; paths needing more than ``n_vls-1`` escalations
    are rejected (the planner then picks another path).
    """
    channels: list[Channel] = []
    vl = 0
    prev_dim = -1
    for u, v in zip(path, path[1:]):
        dim = topo.are_adjacent(u, v)
        if dim is None:
            raise ValueError(f"hop {u}->{v} not a direct link")
        esc = 0
        if dim < prev_dim:
            esc = 1  # cross-dimensional loop-breaking
        cu, cv = topo.coords(u)[dim], topo.coords(v)[dim]
        if dim == prev_dim and cu > cv:
            esc = 1  # same-dimensional dateline
        vl += esc
        if vl >= n_vls:
            raise DeadlockRisk(
                f"path {path} needs more than {n_vls} VLs under TFC"
            )
        channels.append(Channel(u, v, vl))
        prev_dim = dim
    return channels


class DeadlockRisk(RuntimeError):
    pass


def channel_dependency_graph(
    paths_channels: Iterable[list[Channel]],
) -> dict[Channel, set[Channel]]:
    """CDG: edge c1 -> c2 if some packet holds c1 while requesting c2."""
    cdg: dict[Channel, set[Channel]] = {}
    for chans in paths_channels:
        for c1, c2 in zip(chans, chans[1:]):
            cdg.setdefault(c1, set()).add(c2)
            cdg.setdefault(c2, set())
    return cdg


def is_acyclic(cdg: dict[Channel, set[Channel]]) -> bool:
    """Kahn's algorithm over the CDG."""
    indeg: dict[Channel, int] = {c: 0 for c in cdg}
    for c, outs in cdg.items():
        for o in outs:
            indeg[o] = indeg.get(o, 0) + 1
    stack = [c for c, d in indeg.items() if d == 0]
    seen = 0
    while stack:
        c = stack.pop()
        seen += 1
        for o in cdg.get(c, ()):
            indeg[o] -= 1
            if indeg[o] == 0:
                stack.append(o)
    return seen == len(indeg)


def tfc_admissible(
    topo: NDFullMesh, paths: Iterable[Path], n_vls: int = 2
) -> list[tuple[Path, list[Channel]]]:
    """The TFC-admissible subset of an APR path set with its VL mapping.

    This is the paper's "generates all-path combinations and VL mappings":
    paths whose loop-breaking events exceed the VL budget are excluded from
    the all-path set (the planner simply never schedules them).
    """
    out = []
    for p in paths:
        if len(p) <= 1:
            continue
        try:
            out.append((p, assign_vls(topo, p, n_vls=n_vls)))
        except DeadlockRisk:
            continue
    return out


def verify_deadlock_free(
    topo: NDFullMesh, paths: Iterable[Path], n_vls: int = 2
) -> bool:
    """VL-map the TFC-admissible paths and check the CDG is acyclic."""
    adm = tfc_admissible(topo, paths, n_vls=n_vls)
    return is_acyclic(channel_dependency_graph(ch for _, ch in adm))


# ---------------------------------------------------------------------------
# Fast fault recovery via direct notification (paper §4.2)
# ---------------------------------------------------------------------------


@dataclass
class RoutePlan:
    """Installed paths for a communication pattern + reverse index by link."""

    topo: NDFullMesh
    paths: dict[tuple[int, int], Path] = field(default_factory=dict)
    _by_link: dict[tuple[int, int], set[tuple[int, int]]] = field(
        default_factory=dict
    )

    def install(self, src: int, dst: int, path: Path) -> None:
        self.paths[(src, dst)] = path
        for u, v in zip(path, path[1:]):
            self._by_link.setdefault(tuple(sorted((u, v))), set()).add((src, dst))

    def affected_flows(self, link: tuple[int, int]) -> set[tuple[int, int]]:
        return set(self._by_link.get(tuple(sorted(link)), set()))

    def direct_notify(self, link: tuple[int, int]) -> dict[int, int]:
        """Direct notification: the two link endpoints send ONE message to
        each affected source (paper Fig. 12 right).  Returns
        {source: notification_hops} — hop count of the notification path.
        """
        out: dict[int, int] = {}
        for src, _dst in self.affected_flows(link):
            out[src] = min(
                self.topo.hop_distance(link[0], src),
                self.topo.hop_distance(link[1], src),
            )
        return out

    def hop_by_hop_notify(self, link: tuple[int, int]) -> dict[int, int]:
        """Baseline: failure floods hop-by-hop through the whole component —
        convergence latency for a source is its BFS depth from the failure,
        but every node in the network participates (control-plane load =
        num_nodes), which is what direct notification eliminates.
        """
        out: dict[int, int] = {}
        for src, _dst in self.affected_flows(link):
            out[src] = max(
                self.topo.hop_distance(link[0], src),
                self.topo.hop_distance(link[1], src),
            ) + 2  # flood propagates via neighbors, not beeline
        return out

    def reroute(self, link: tuple[int, int]) -> dict[tuple[int, int], Path]:
        """Recompute paths for affected flows avoiding the failed link."""
        bad = tuple(sorted(link))
        fixed: dict[tuple[int, int], Path] = {}
        for src, dst in self.affected_flows(link):
            for cand in all_paths(self.topo, src, dst):
                edges = {tuple(sorted(e)) for e in zip(cand, cand[1:])}
                if bad not in edges:
                    fixed[(src, dst)] = cand
                    self.install(src, dst, cand)
                    break
            else:
                raise RuntimeError(f"no APR path avoids {link} for {src}->{dst}")
        return fixed
