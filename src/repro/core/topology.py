"""nD-FullMesh topology — the core abstraction of UB-Mesh (paper §3.1).

An n-dimensional full-mesh ("Hamming graph") places every node at a coordinate
``(c_0, ..., c_{n-1})`` with ``c_i in [0, dims[i])``.  Two nodes are directly
linked iff their coordinates differ in exactly ONE dimension — i.e. along each
dimension, the nodes sharing all other coordinates form a clique (a 1D
full-mesh).  Recursively, adjacent 1D meshes form a 2D mesh, and so on —
exactly the paper's "board -> rack -> rack-row -> pod" hierarchy.

The concrete UB-Mesh-Pod (paper §3.3) is the 4D instance ``dims=(8, 8, 4, 4)``:

* dim 0 ("X"):  8 NPUs on a board              — passive electrical, ~1 m
* dim 1 ("Y"):  8 boards in a rack             — passive electrical, ~1 m
* dim 2 ("Z"):  4 racks in a row               — active electrical, ~10 m
* dim 3 ("A"):  4 rack-rows in a pod           — optical, ~100 m

SuperPod = several pods joined by high-radix switches (HRS) in a Clos tier
("B"/"G" dimensions, ~1 km optical).  Beyond that, the DCN.

This module is pure Python/numpy — it is the *model* of the network that the
APR router, the multi-ring collective planner, the cost model, the
parallelization planner and the reliability analysis all share.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field, replace
from functools import cached_property, lru_cache
from typing import Iterator, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Link / cable taxonomy  (paper Table 2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkSpec:
    """Physical characteristics of one cable class."""

    name: str
    reach_m: float            # typical reach
    lanes_per_cable: int      # UB lanes carried by one physical cable
    gbps_per_lane: float      # line rate of one UB lane (GByte/s)
    afr_per_unit: float       # annualized failure rate, % per cable (rel.)
    cost_per_cable: float     # relative cost units
    watts_per_cable: float    # OpEx model input


# Calibrated so the Table-2 cable-ratio benchmark lands near the paper's
# 86.7 / 7.2 / 4.8 / 1.2 split and Table-6 AFRs are reproducible.
PASSIVE_ELECTRICAL = LinkSpec("passive_electrical", 1.0, 4, 6.25, 0.0020, 1.0, 0.1)
ACTIVE_ELECTRICAL = LinkSpec("active_electrical", 10.0, 5, 6.25, 0.0060, 4.0, 2.5)
OPTICAL_100M = LinkSpec("optical_100m", 100.0, 8, 6.25, 0.0400, 25.0, 7.0)
OPTICAL_1KM = LinkSpec("optical_1km", 1000.0, 8, 6.25, 0.0450, 40.0, 9.0)

LINK_SPECS = {
    s.name: s
    for s in (PASSIVE_ELECTRICAL, ACTIVE_ELECTRICAL, OPTICAL_100M, OPTICAL_1KM)
}


@dataclass(frozen=True)
class DimSpec:
    """One dimension of the nD-FullMesh."""

    name: str                 # "X", "Y", "Z", "A", ...
    size: int                 # clique size along this dim
    link: LinkSpec            # cable class used for this dim
    lanes_per_peer: int       # UB lanes allocated to EACH peer in the clique
    trunk_width: int = 1      # NPUs aggregated per physical trunk (LRS dims:
                              # 64 NPUs share one UB x128 rack-to-rack trunk,
                              # paper Fig. 8-(d))

    @property
    def gbs_per_peer(self) -> float:
        return self.lanes_per_peer * self.link.gbps_per_lane

    @property
    def gbs_total(self) -> float:
        """Aggregate bandwidth of one node into this dimension."""
        return self.gbs_per_peer * (self.size - 1)


# ---------------------------------------------------------------------------
# The nD-FullMesh graph
# ---------------------------------------------------------------------------


@lru_cache(maxsize=1 << 20)
def _coords(shape: tuple[int, ...], node: int) -> tuple[int, ...]:
    """Row-major node id -> coordinate tuple (memoized: id decoding is the
    single hottest scalar call in netsim DAG compilation and routing)."""
    out = []
    for size in reversed(shape):
        out.append(node % size)
        node //= size
    return tuple(reversed(out))


@dataclass(frozen=True)
class NDFullMesh:
    """An n-dimensional full-mesh of NPUs.

    Node ids are row-major over ``dims`` (last dim fastest), so the id is also
    the paper's *structured address*: the coordinate tuple IS the
    (pod, row, rack, board, npu) hierarchy and each dimension is a segment.
    """

    dims: tuple[DimSpec, ...]

    # -- basic shape ------------------------------------------------------
    # shape/num_nodes are cached per instance (frozen dataclass, so the
    # dims never change): the netsim hot paths call them millions of times
    @property
    def ndim(self) -> int:
        return len(self.dims)

    @cached_property
    def shape(self) -> tuple[int, ...]:
        return tuple(d.size for d in self.dims)

    @cached_property
    def num_nodes(self) -> int:
        return int(np.prod(self.shape))

    # -- addressing (paper §4.1.2: structured addressing) -----------------
    def coords(self, node: int) -> tuple[int, ...]:
        return _coords(self.shape, node)

    def node_id(self, coords: Sequence[int]) -> int:
        nid = 0
        for c, size in zip(coords, self.shape):
            if not (0 <= c < size):
                raise ValueError(f"coordinate {coords} out of range for {self.shape}")
            nid = nid * size + c
        return nid

    # -- adjacency ---------------------------------------------------------
    def neighbors(self, node: int, dim: int) -> list[int]:
        """All peers of ``node`` in the clique of dimension ``dim``."""
        c = list(self.coords(node))
        out = []
        for v in range(self.shape[dim]):
            if v != c[dim]:
                cc = list(c)
                cc[dim] = v
                out.append(self.node_id(cc))
        return out

    def all_neighbors(self, node: int) -> list[tuple[int, int]]:
        """(peer, dim) for every direct link of ``node``."""
        return [(p, d) for d in range(self.ndim) for p in self.neighbors(node, d)]

    def are_adjacent(self, u: int, v: int) -> int | None:
        """Return the dimension of the direct link u-v, or None."""
        cu, cv = self.coords(u), self.coords(v)
        diff = [i for i, (a, b) in enumerate(zip(cu, cv)) if a != b]
        return diff[0] if len(diff) == 1 else None

    def hop_distance(self, u: int, v: int) -> int:
        """Shortest-path hops = Hamming distance of the coordinates."""
        cu, cv = self.coords(u), self.coords(v)
        return sum(a != b for a, b in zip(cu, cv))

    def links(self, dim: int | None = None) -> Iterator[tuple[int, int, int]]:
        """Iterate (u, v, dim) over every direct link, u < v."""
        dims = range(self.ndim) if dim is None else (dim,)
        for d in dims:
            for node in range(self.num_nodes):
                for peer in self.neighbors(node, d):
                    if node < peer:
                        yield node, peer, d

    def link_count(self, dim: int) -> int:
        """Number of direct links in dimension ``dim``."""
        k = self.shape[dim]
        groups = self.num_nodes // k
        return groups * k * (k - 1) // 2

    # -- physical accounting (Table 2 / CapEx / AFR) ----------------------
    def _cables_for_dim(self, i: int) -> int:
        """Physical cable count for dimension ``i``.

        Direct dims (trunk_width=1): one cable bundle per NPU pair.
        Trunked dims (e.g. inter-rack via LRS): the ``trunk_width`` NPU-pairs
        between two groups share one fat trunk of
        ``lanes_per_peer * trunk_width`` lanes (paper Fig. 8-(d): UB x128).
        """
        d = self.dims[i]
        n_links = self.link_count(i)
        if d.trunk_width <= 1:
            per = max(1, math.ceil(d.lanes_per_peer / d.link.lanes_per_cable))
            return n_links * per
        n_trunks = n_links // d.trunk_width
        lanes = d.lanes_per_peer * d.trunk_width
        return n_trunks * max(1, math.ceil(lanes / d.link.lanes_per_cable))

    def cables_by_dim(self) -> dict[str, int]:
        return {d.name: self._cables_for_dim(i) for i, d in enumerate(self.dims)}

    def cables_by_link_type(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for i, d in enumerate(self.dims):
            out[d.link.name] = out.get(d.link.name, 0) + self._cables_for_dim(i)
        return out

    # -- per-node bandwidth ------------------------------------------------
    def node_bandwidth_gbs(self) -> float:
        """Aggregate injection bandwidth of one NPU (all dims)."""
        return sum(d.gbs_total for d in self.dims)

    def dim_bandwidth_gbs(self, dim: int) -> float:
        return self.dims[dim].gbs_total

    def bisection_bandwidth_gbs(self, dim: int) -> float:
        """Bisection bandwidth cutting dimension ``dim`` in half."""
        k = self.shape[dim]
        half = k // 2
        cross_links_per_group = half * (k - half)
        groups = self.num_nodes // k
        return groups * cross_links_per_group * self.dims[dim].gbs_per_peer

    # -- derived topologies -------------------------------------------------
    def subgroup_nodes(self, fixed: dict[int, int]) -> list[int]:
        """All node ids whose coordinate matches ``fixed`` {dim: value}."""
        ranges = [
            [fixed[i]] if i in fixed else list(range(s))
            for i, s in enumerate(self.shape)
        ]
        return [self.node_id(c) for c in itertools.product(*ranges)]


# ---------------------------------------------------------------------------
# UB-Mesh reference instances
# ---------------------------------------------------------------------------


def ub_mesh_pod(
    *,
    board: int = 8,
    boards_per_rack: int = 8,
    racks_per_row: int = 4,
    rows: int = 4,
    x_lanes: int = 4,
    y_lanes: int = 4,
    z_lanes: int = 2,
    a_lanes: int = 2,
) -> NDFullMesh:
    """The paper's 4D-FullMesh UB-Mesh-Pod: 8x8 NPUs per rack, 4x4 racks.

    Per-NPU UB x72 budget (Table 3): 7 X-peers * 4 + 7 Y-peers * 4 = 56 lanes
    intra-rack, plus x16 inter-rack IO (paper §6.3 default) split between the
    Z and A dimensions through the LRS backplane (3 peers * 2 lanes each + HRS
    uplink headroom).
    """
    rack = board * boards_per_rack
    return NDFullMesh(
        dims=(
            DimSpec("X", board, PASSIVE_ELECTRICAL, x_lanes),
            DimSpec("Y", boards_per_rack, PASSIVE_ELECTRICAL, y_lanes),
            DimSpec("Z", racks_per_row, ACTIVE_ELECTRICAL, z_lanes, trunk_width=rack),
            DimSpec("A", rows, OPTICAL_100M, a_lanes, trunk_width=rack),
        )
    )


def ub_mesh_rack() -> NDFullMesh:
    """One rack = 2D-FullMesh of 64 NPUs (8 per board x 8 boards)."""
    return NDFullMesh(
        dims=(
            DimSpec("X", 8, PASSIVE_ELECTRICAL, 4),
            DimSpec("Y", 8, PASSIVE_ELECTRICAL, 4),
        )
    )


@dataclass(frozen=True)
class SuperPod:
    """UB-Mesh-SuperPod: ``n_pods`` 4D-FullMesh pods + HRS Clos tier (§3.3.4).

    The pod-level interconnection is symmetrical Clos via HRS so the cloud
    manager can slice the SuperPod; we model it as a single-stage non-blocking
    abstraction with per-rack uplink bandwidth ``uplink_lanes_per_rack``.
    """

    pod: NDFullMesh = field(default_factory=ub_mesh_pod)
    n_pods: int = 8
    uplink_lanes_per_rack: int = 256     # four UB x256 backplane IO, 1 to HRS
    hrs_radix: int = 512

    @property
    def num_nodes(self) -> int:
        return self.pod.num_nodes * self.n_pods

    @property
    def racks_per_pod(self) -> int:
        # rack = (X, Y) subgroup => racks = product of inter-rack dims
        return int(np.prod(self.pod.shape[2:])) if self.pod.ndim > 2 else 1

    @property
    def n_racks(self) -> int:
        return self.racks_per_pod * self.n_pods

    def hrs_count(self, uplink_provisioning: float = 1.0) -> int:
        """High-radix switches needed for the pod-level Clos tier.

        ``uplink_provisioning`` mirrors the knob on
        ``cables_by_link_type``: a thinner pod->HRS tier needs
        proportionally fewer switch ports, hence fewer HRS.
        """
        lanes = self.uplink_lanes_per_rack * uplink_provisioning
        total_uplinks = self.n_racks * lanes
        return max(1, math.ceil(total_uplinks / self.hrs_radix))

    def optical_modules(self, uplink_provisioning: float = 1.0) -> int:
        """Optical transceivers: 2 per optical cable (both ends)."""
        per_pod = self.pod.cables_by_link_type()
        pod_optical = sum(
            v for k, v in per_pod.items() if k.startswith("optical")
        )
        lanes = self.uplink_lanes_per_rack * uplink_provisioning
        uplink_cables = self.n_racks * math.ceil(
            lanes / OPTICAL_1KM.lanes_per_cable
        )
        return 2 * (pod_optical * self.n_pods + uplink_cables)

    def lrs_count(self) -> int:
        # paper §3.3.1: 18 LRS per rack backplane (x4 switch planes worth are
        # folded into the 18 fully-connected LRS of one plane description).
        return 18 * self.n_racks

    def cables_by_link_type(self, uplink_provisioning: float = 1.0) -> dict[str, int]:
        """Cable counts.  ``uplink_provisioning < 1`` models a thinner
        pod->HRS tier (the paper's Table-2 estimation assumes the Clos tier
        is provisioned for the <2% long-range DP traffic, not full x256).
        """
        out: dict[str, int] = {}
        per_pod = self.pod.cables_by_link_type()
        for k, v in per_pod.items():
            out[k] = out.get(k, 0) + v * self.n_pods
        lanes = self.uplink_lanes_per_rack * uplink_provisioning
        uplink_cables = self.n_racks * math.ceil(
            lanes / OPTICAL_1KM.lanes_per_cable
        )
        out[OPTICAL_1KM.name] = out.get(OPTICAL_1KM.name, 0) + uplink_cables
        return out


# ---------------------------------------------------------------------------
# Baseline fabrics for comparison (paper §2.3, §6)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClosFabric:
    """Non-oversubscribed 2-tier (leaf/spine) Clos of HRS switches.

    Every NPU port goes to a leaf; leaves connect to spines with full
    bisection.  This is the paper's cost baseline: all NPU bandwidth is
    switched, every inter-switch link is optical.
    """

    n_npus: int
    lanes_per_npu: int = 72
    hrs_radix: int = 512

    def leaf_count(self) -> int:
        # half the radix faces NPUs, half faces spines (non-oversubscribed)
        down = self.hrs_radix // 2
        return math.ceil(self.n_npus * self.lanes_per_npu / down)

    def spine_count(self) -> int:
        up_total = self.leaf_count() * (self.hrs_radix // 2)
        return math.ceil(up_total / self.hrs_radix)

    def hrs_count(self) -> int:
        return self.leaf_count() + self.spine_count()

    def optical_modules(self) -> int:
        # NPU->leaf may be short DAC in-rack for a fraction; the paper's
        # baseline assumes optical everywhere above the NIC: 2 modules/cable.
        npu_leaf_cables = self.n_npus * math.ceil(
            self.lanes_per_npu / OPTICAL_100M.lanes_per_cable
        )
        leaf_spine_cables = self.leaf_count() * (self.hrs_radix // 2) // OPTICAL_1KM.lanes_per_cable
        return 2 * (npu_leaf_cables + leaf_spine_cables)

    def cables_by_link_type(self) -> dict[str, int]:
        npu_leaf = self.n_npus * math.ceil(
            self.lanes_per_npu / OPTICAL_100M.lanes_per_cable
        )
        leaf_spine = self.leaf_count() * (self.hrs_radix // 2) // OPTICAL_1KM.lanes_per_cable
        return {OPTICAL_100M.name: npu_leaf, OPTICAL_1KM.name: leaf_spine}


@dataclass(frozen=True)
class Torus3D:
    """3D torus baseline (paper Fig. 3): 6 neighbors per node."""

    shape: tuple[int, int, int]
    lanes_per_link: int = 12

    @property
    def n_npus(self) -> int:
        return int(np.prod(self.shape))

    def link_count(self) -> int:
        return 3 * self.n_npus  # each node owns +1 link per dim (torus wrap)

    def node_bandwidth_gbs(self) -> float:
        return 6 * self.lanes_per_link * PASSIVE_ELECTRICAL.gbps_per_lane


# ---------------------------------------------------------------------------
# Mapping the logical JAX mesh onto the UB-Mesh hierarchy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshView:
    """How a logical ``jax.sharding.Mesh`` axis maps onto UB-Mesh dimensions.

    The production mesh is ("data", "model") = (16, 16) per pod (and a "pod"
    axis across pods).  "model" = the intra-rack high-bandwidth domain
    (paper's TP/SP domain), "data" = inter-rack 2D-FullMesh, "pod" = HRS Clos.

    ``axis_gbs`` is the per-chip bandwidth available to collectives running
    over that axis; the cost model and the roofline collective term both read
    it, so topology-awareness is one consistent story end-to-end.
    """

    axis_dims: dict[str, tuple[int, ...]]   # mesh axis -> UB-Mesh dims it spans
    axis_gbs: dict[str, float]              # mesh axis -> per-chip GB/s
    axis_latency_us: dict[str, float]       # mesh axis -> per-hop latency


def production_mesh_view(topo: NDFullMesh | None = None, *, multi_pod: bool = False) -> MeshView:
    """The canonical mapping used by cost model + roofline.

    model axis (16) = one board X-clique x 2 lanes-groups... concretely we map
    it to the intra-rack 2D-FM slice (X full-mesh of 8 x 2 boards) giving each
    chip the full intra-rack allocation; data axis (16) = inter-rack (Z, A)
    2D-FM; pod axis (2) = HRS Clos tier.
    """
    topo = topo or ub_mesh_pod()
    x, y, z, a = topo.dims
    intra_gbs = x.gbs_total + y.gbs_total          # 56 lanes * 6.25 = 350 GB/s
    inter_gbs = z.gbs_total + a.gbs_total          # x16-ish inter-rack IO
    view = {
        "model": ((0, 1), intra_gbs, 0.5),
        "data": ((2, 3), inter_gbs, 2.0),
    }
    if multi_pod:
        # HRS Clos tier: one x256 uplink shared by the 64 NPUs of a rack.
        uplink_per_chip = 256 * OPTICAL_1KM.gbps_per_lane / 64.0
        view["pod"] = ((), uplink_per_chip, 5.0)
    return MeshView(
        axis_dims={k: v[0] for k, v in view.items()},
        axis_gbs={k: v[1] for k, v in view.items()},
        axis_latency_us={k: v[2] for k, v in view.items()},
    )
