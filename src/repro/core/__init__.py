"""UB-Mesh core: the paper's contributions as composable modules.

* topology    — nD-FullMesh graph + baselines (C1, C2)
* ub          — Unified Bus lane budgeting (C2)
* apr         — All-Path Routing: SR header, linear tables, TFC, direct
                notification (C3, C4)
* multiring   — Multi-Ring AllReduce planner (C5)
* alltoall    — Multi-Path / hierarchical All2All analysis (C5)
* cost_model  — topology-aware communication cost model (C6)
* perf_model  — pluggable PerfModel backends: analytic / netsim-calibrated
* planner     — topology-aware parallelization search (C6)
* traffic     — per-technique traffic accounting (Table 1)
* capex       — CapEx/OpEx/cost-efficiency (Fig. 21)
* availability— MTBF/availability + 64+1 backup analysis (Table 6)
* simulator   — cluster-scale training simulation (Figs 17/19/20/22)
"""

from . import (  # noqa: F401
    alltoall,
    apr,
    availability,
    capex,
    cost_model,
    multiring,
    perf_model,
    planner,
    simulator,
    topology,
    traffic,
    ub,
)
