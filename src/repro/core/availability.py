"""Reliability / availability analysis (paper §3.3.2, §6.6, Table 6).

MTBF  = 8760 / AFR_total          (hours; AFR = failures per year)
Avail = MTBF / (MTBF + MTTR)

Two layers:

* component-count based — AFRs derived from the actual cable/switch counts
  of a topology (via `core/topology`), using per-unit AFRs;
* the paper's Table 6 headline numbers, reproduced exactly for the 8K
  SuperPod comparison benchmark.

Plus the 64+1 backup-NPU model: the probability that a rack survives an NPU
failure without losing capacity, and the effective job-level MTBF gain.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .topology import ClosFabric, LINK_SPECS, SuperPod

HOURS_PER_YEAR = 365 * 24


@dataclass(frozen=True)
class AFRBreakdown:
    name: str
    electrical_cable: float
    optical_cable: float
    lrs: float
    hrs: float

    @property
    def total(self) -> float:
        return self.electrical_cable + self.optical_cable + self.lrs + self.hrs

    @property
    def mtbf_hours(self) -> float:
        return HOURS_PER_YEAR / self.total if self.total else math.inf

    def availability(self, mttr_hours: float = 1.25) -> float:
        m = self.mtbf_hours
        return m / (m + mttr_hours)


# --- paper Table 6 (8K-NPU SuperPod) ---------------------------------------
PAPER_UB_MESH = AFRBreakdown("UB-Mesh", 5.82, 1.55, 81.0, 0.56)
PAPER_CLOS = AFRBreakdown("Clos", 13.8, 574.0, 18.0, 27.0)
PAPER_MTTR_HOURS = 1.25            # 75 minutes
FAST_MTTR_HOURS = 13.0 / 60.0      # 10 min locate + 3 min migrate (§6.6)


# per-unit AFRs (failures/year/unit), calibrated against Table 6 given the
# component counts of an 8K system — shared by `derived_afr`, the per-
# GeometryCandidate availability scoring (`superpod_afr`) and the campaign's
# failure-class rate builder
AFR_PER_UNIT = {
    "passive_electrical": 1.0e-4,
    "active_electrical": 6.0e-4,
    "optical_100m": 1.3e-3,
    "optical_1km": 1.3e-3,
    "lrs": 3.5e-2,
    "hrs": 3.5e-2,
}


def superpod_afr(sp: SuperPod, name: str = "UB-Mesh(derived)") -> AFRBreakdown:
    """Component-count AFR breakdown for an arbitrary SuperPod geometry —
    the per-candidate form of :func:`derived_afr`'s UB-Mesh leg, so the
    codesign sweep can score availability for every `GeometryCandidate`."""
    cb = sp.cables_by_link_type()
    return AFRBreakdown(
        name,
        electrical_cable=(
            cb.get("passive_electrical", 0) * AFR_PER_UNIT["passive_electrical"]
            + cb.get("active_electrical", 0) * AFR_PER_UNIT["active_electrical"]
        ),
        optical_cable=(
            cb.get("optical_100m", 0) * AFR_PER_UNIT["optical_100m"]
            + cb.get("optical_1km", 0) * AFR_PER_UNIT["optical_1km"]
        ),
        lrs=sp.lrs_count() * AFR_PER_UNIT["lrs"],
        hrs=sp.hrs_count() * AFR_PER_UNIT["hrs"],
    )


def clos_afr(n_npus: int, name: str = "Clos(derived)") -> AFRBreakdown:
    """Component-count AFR breakdown for the Clos baseline fabric."""
    fab = ClosFabric(n_npus=n_npus)
    cc = fab.cables_by_link_type()
    return AFRBreakdown(
        name,
        electrical_cable=n_npus * 2 * AFR_PER_UNIT["passive_electrical"],
        optical_cable=(
            cc.get("optical_100m", 0) * AFR_PER_UNIT["optical_100m"]
            + cc.get("optical_1km", 0) * AFR_PER_UNIT["optical_1km"]
        ),
        lrs=0.0,
        hrs=fab.hrs_count() * AFR_PER_UNIT["hrs"],
    )


def derived_afr(n_npus: int = 8192) -> tuple[AFRBreakdown, AFRBreakdown]:
    """AFRs computed from our topology objects' component counts.

    Per-unit AFRs (failures/year/unit) calibrated against Table 6 given the
    component counts of an 8K system.
    """
    sp = SuperPod(n_pods=max(1, n_npus // 1024))
    return superpod_afr(sp), clos_afr(n_npus)


# --- 64+1 backup NPU (paper §3.3.2, Fig. 9) --------------------------------


@dataclass(frozen=True)
class BackupAnalysis:
    """Effect of the +1 backup NPU per 64-NPU rack."""

    npu_afr: float = 0.25        # NPU failures / year / NPU
    rack_size: int = 64
    n_backups: int = 1

    def rack_failure_rate_no_backup(self) -> float:
        """Rack loses capacity on ANY NPU failure."""
        return self.rack_size * self.npu_afr

    def rack_failure_rate_with_backup(self, repair_hours: float = 24.0) -> float:
        """Rack loses capacity only if a SECOND NPU fails while the first is
        being repaired/replaced (backup already holding the slot).
        Birthday-style thinning: rate2 ~ rate1 * (rate_rest * window).
        """
        rate1 = self.rack_size * self.npu_afr / HOURS_PER_YEAR  # per hour
        rate_rest = (self.rack_size - 1) * self.npu_afr / HOURS_PER_YEAR
        p_second_in_window = 1.0 - math.exp(-rate_rest * repair_hours)
        return rate1 * p_second_in_window * HOURS_PER_YEAR  # per year

    def capacity_loss_improvement(self, repair_hours: float = 24.0) -> float:
        return self.rack_failure_rate_no_backup() / max(
            self.rack_failure_rate_with_backup(repair_hours), 1e-12
        )

    def redirected_path_penalty_hops(self) -> int:
        """Fig. 9: direct link 5-3 becomes 5-LRS-B — one extra hop."""
        return 1
