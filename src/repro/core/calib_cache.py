"""Persistent on-disk store for netsim calibration measurements.

``core.perf_model.NetsimPerfModel`` memoizes measured per-(axis, shape,
group-width) bandwidths in a process-wide dict, which makes the *second*
``plan()`` of a process nearly free — but every new process re-pays the
full netsim measurement bill.  That is fatal for the sweeps the ROADMAP
wants next (topology co-design, Monte-Carlo availability campaigns):
100 outer candidates x ~30 keys x ~45 ms is minutes of pure re-measurement
of numbers that are a deterministic function of the configuration.

This module persists those measurements as small versioned JSON files:

* **Location** — ``$CALIB_CACHE_DIR`` if set, else
  ``~/.cache/ubmesh-repro/calib``; callers may also pass an explicit
  directory.  One file per *store key*.
* **Store key** — a content hash of everything that determines a
  measurement besides the (axis, shape, width) request itself: the
  topology geometry and capacities (``perf_model``'s topology key, plus
  the coarse/mixed tags for SuperPod pricing), routing strategy, payload
  size, latency, rx cap — and the code versions that define measurement
  semantics (``netsim.solver.SOLVER_VERSION``,
  ``netsim.api.CALIBRATION_SCHEMA_VERSION``, this module's
  ``SCHEMA_VERSION``).  Any change lands in a different file, so stale
  profiles are never served; they are just orphaned.
* **Robustness** — a truncated, corrupt or version-skewed file is ignored
  with one ``log.warning`` and the entries are re-measured; writes go
  through a temp file + ``os.replace`` so readers never see a partial
  file.  The cache never raises into the planner.

The JSON payload::

    {"schema": 1, "solver": 1, "netsim": 1,
     "config": [...],                  # the un-hashed key, for humans
     "entries": {"model|allreduce|None": 141.84, ...}}

Latency-mode profiles (``NetsimPerfModel.latency_profile``) ride the same
format: their config carries a ``("latency-mode", size_bytes)`` tag so
they land in a separate store file, and each ``LatencyStats`` field is one
entry under a ``shape@field`` name — e.g.
``"model|allreduce@p99_s|8": 2.1e-06`` — which the 3-part ``axis|shape|
width`` key split parses unchanged.
"""

from __future__ import annotations

import hashlib
import json
import logging
import os
import tempfile
from pathlib import Path

log = logging.getLogger(__name__)

# version of THIS file format (layout of the JSON document); bump on
# layout changes.  Measurement-semantics versions ride alongside it in
# the store key (see module docstring).
SCHEMA_VERSION = 1

ENV_VAR = "CALIB_CACHE_DIR"
_DEFAULT_SUBDIR = ("ubmesh-repro", "calib")

# geometry sweeps create one store file per candidate topology; cap the
# directory at this many stores (least-recently-written evicted first)
MAX_STORES_ENV_VAR = "CALIB_CACHE_MAX_STORES"
DEFAULT_MAX_STORES = 256


def max_stores() -> int:
    """Store-count cap: ``$CALIB_CACHE_MAX_STORES`` if set, else 256.
    ``0`` (or a negative / unparsable value <= 0) disables pruning."""
    env = os.environ.get(MAX_STORES_ENV_VAR)
    if env:
        try:
            return int(env)
        except ValueError:
            log.warning(
                "ignoring unparsable %s=%r", MAX_STORES_ENV_VAR, env
            )
    return DEFAULT_MAX_STORES


def default_cache_dir() -> Path:
    """``$CALIB_CACHE_DIR`` if set (and non-empty), else
    ``$XDG_CACHE_HOME``/``~/.cache`` + ``ubmesh-repro/calib``."""
    env = os.environ.get(ENV_VAR)
    if env:
        return Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path.home() / ".cache"
    return base.joinpath(*_DEFAULT_SUBDIR)


def _versions() -> tuple[int, int, int]:
    # deferred: core must not hard-require netsim at import time
    from ..netsim.api import CALIBRATION_SCHEMA_VERSION
    from ..netsim.solver import SOLVER_VERSION

    return SCHEMA_VERSION, SOLVER_VERSION, CALIBRATION_SCHEMA_VERSION


def _entry_key(axis: str, shape: str, width: int | None) -> str:
    return f"{axis}|{shape}|{width}"


class CalibCache:
    """One directory of per-configuration JSON calibration files.

    ``get_profile(config)`` returns the stored ``(axis, shape, width) ->
    GB/s`` mapping for a configuration (empty on miss/corruption);
    ``update(config, entries)`` merges newly measured entries back in.
    ``config`` is any JSON-serializable structure that pins the
    measurement context (see module docstring); its canonical JSON string
    is hashed into the file name.
    """

    def __init__(self, directory: "str | os.PathLike | None" = None) -> None:
        self.dir = Path(directory) if directory is not None else default_cache_dir()
        self._warned: set[str] = set()

    # -- key / path ------------------------------------------------------
    def _config_blob(self, config) -> str:
        schema, solver, netsim = _versions()
        doc = {"schema": schema, "solver": solver, "netsim": netsim,
               "config": config}
        return json.dumps(doc, sort_keys=True, default=repr)

    def path_for(self, config) -> Path:
        digest = hashlib.sha256(
            self._config_blob(config).encode()
        ).hexdigest()[:16]
        return self.dir / f"calib-{digest}.json"

    # -- read ------------------------------------------------------------
    def get_profile(self, config) -> dict[tuple[str, str, int | None], float]:
        """All stored entries for ``config`` (empty dict on miss)."""
        path = self.path_for(config)
        try:
            with open(path) as f:
                doc = json.load(f)
            schema, solver, netsim = _versions()
            if (doc.get("schema"), doc.get("solver"), doc.get("netsim")) != (
                schema, solver, netsim,
            ):
                # hash collisions aside, this means the file predates a
                # version bump of the hashing itself — treat as stale
                raise ValueError("version skew")
            entries = doc["entries"]
            out: dict[tuple[str, str, int | None], float] = {}
            for k, v in entries.items():
                axis, shape, w = k.split("|")
                out[(axis, shape, None if w == "None" else int(w))] = float(v)
            return out
        except FileNotFoundError:
            return {}
        except (OSError, ValueError, KeyError, AttributeError) as e:
            if str(path) not in self._warned:
                self._warned.add(str(path))
                log.warning(
                    "calibration cache %s unreadable (%s: %s) — ignoring "
                    "and re-measuring", path, type(e).__name__, e,
                )
            return {}

    # -- write -----------------------------------------------------------
    def update(
        self,
        config,
        entries: dict[tuple[str, str, int | None], float],
    ) -> None:
        """Merge ``entries`` into the configuration's file (best-effort:
        IO errors are logged, never raised)."""
        if not entries:
            return
        path = self.path_for(config)
        try:
            merged = {
                _entry_key(*k): v
                for k, v in self.get_profile(config).items()
            }
            merged.update({_entry_key(*k): float(v) for k, v in entries.items()})
            schema, solver, netsim = _versions()
            doc = {
                "schema": schema,
                "solver": solver,
                "netsim": netsim,
                "config": json.loads(json.dumps(config, default=repr)),
                "entries": merged,
            }
            self.dir.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(
                dir=self.dir, prefix=path.name, suffix=".tmp"
            )
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(doc, f, sort_keys=True)
                os.replace(tmp, path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            self.prune()
        except OSError as e:
            log.warning(
                "calibration cache %s not writable (%s: %s) — measurement "
                "kept in memory only", path, type(e).__name__, e,
            )

    # -- maintenance -----------------------------------------------------
    def prune(self, keep: int | None = None) -> list[Path]:
        """Evict least-recently-written store files beyond ``keep``.

        A geometry sweep writes one ``calib-*.json`` per candidate topology,
        so an unbounded ``$CALIB_CACHE_DIR`` grows with every sweep.  Keeps
        the ``keep`` most recently modified stores (default:
        ``max_stores()``, i.e. ``$CALIB_CACHE_MAX_STORES`` or 256); a
        ``keep`` <= 0 disables pruning.  Best-effort: IO errors are
        swallowed.  Returns the paths actually removed.
        """
        limit = max_stores() if keep is None else keep
        if limit <= 0:
            return []
        try:
            stores = sorted(
                self.dir.glob("calib-*.json"),
                key=lambda p: p.stat().st_mtime,
                reverse=True,
            )
        except OSError:
            return []
        removed: list[Path] = []
        for path in stores[limit:]:
            try:
                path.unlink()
                removed.append(path)
            except OSError:
                pass
        if removed:
            log.info(
                "calibration cache pruned %d store(s) beyond keep=%d",
                len(removed), limit,
            )
        return removed
