"""CapEx / OpEx / cost-efficiency model (paper §6.4, Fig. 21).

Relative cost units (NPU := 100).  The paper reports only ratios, so unit
prices are calibrated to land its headline numbers:

* 4D-FM+Clos vs {2D-FM+x16Clos, 1D-FM+x16Clos, x64T Clos}: 1.18x / 1.26x /
  1.65x / 2.46x CapEx reduction,
* network share of system cost: 67% (Clos) -> 20% (UB-Mesh),
* 98% of HRS and 93% of optical modules saved,
* OpEx ~ 30% of the Clos system's TCO, UB-Mesh OpEx ~ 35-40% lower,
* cost-efficiency = perf / (CapEx + OpEx)  =>  ~2.04x.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .topology import (
    ClosFabric,
    LINK_SPECS,
    OPTICAL_1KM,
    OPTICAL_100M,
    SuperPod,
    ub_mesh_pod,
)

# relative unit prices (NPU = 100)
# Calibrated against the paper's published ratios (network share 67% for
# Clos / 20% for UB-Mesh, 2.46x CapEx gap => in NPU=100 units the 8K system
# needs Clos-network ~= 1.73M and UB-network ~= 0.21M; solved per component
# against the 8192-chip BOM counts — with these constants
# ``compare_architectures()`` lands CE gain 2.046, CapEx gain 2.446 and
# network shares 0.673 / 0.201, all within 1% of the paper (pinned at
# +-2% by ``tests/test_codesign.py``)
PRICE = {
    "npu": 100.0,
    "cpu": 12.0,
    "lrs": 34.0,
    "hrs": 150.0,
    "passive_electrical": 0.9,
    "active_electrical": 2.0,
    "optical_100m": 7.0,         # cable + 2 transceivers
    "optical_1km": 9.2,
    "nic": 1.0,
}

WATTS = {  # OpEx drivers, relative (NPU board incl. memory/VRM dominates)
    "npu": 140.0,
    "cpu": 25.0,
    "lrs": 8.0,
    "hrs": 90.0,
    "passive_electrical": 0.0,
    "active_electrical": 0.5,
    "optical_100m": 3.0,
    "optical_1km": 3.6,
}


@dataclass(frozen=True)
class BOM:
    """Bill of materials for one architecture at a given NPU count."""

    name: str
    n_npus: int
    n_cpus: int
    n_lrs: int
    n_hrs: int
    cables: dict[str, int]
    optical_modules: int

    def capex(self) -> float:
        c = (
            self.n_npus * PRICE["npu"]
            + self.n_cpus * PRICE["cpu"]
            + self.n_lrs * PRICE["lrs"]
            + self.n_hrs * PRICE["hrs"]
        )
        for k, v in self.cables.items():
            c += v * PRICE[k]
        return c

    def network_capex(self) -> float:
        c = self.n_lrs * PRICE["lrs"] + self.n_hrs * PRICE["hrs"]
        for k, v in self.cables.items():
            c += v * PRICE[k]
        return c

    def network_share(self) -> float:
        return self.network_capex() / self.capex()

    def power(self) -> float:
        w = (
            self.n_npus * WATTS["npu"]
            + self.n_cpus * WATTS["cpu"]
            + self.n_lrs * WATTS["lrs"]
            + self.n_hrs * WATTS["hrs"]
        )
        for k, v in self.cables.items():
            w += v * WATTS[k]
        return w

    def opex(self, years: float = 4.0, price_per_watt_year: float = 0.12) -> float:
        """Lifetime energy + maintenance; calibrated so OpEx ~ 30% of TCO."""
        maint = 0.05 * self.capex() * years / 4.0
        return self.power() * price_per_watt_year * years + maint

    def tco(self) -> float:
        return self.capex() + self.opex()


def superpod_bom(
    sp: SuperPod,
    *,
    name: str = "UB-Mesh(4D-FM+Clos)",
    uplink_provisioning: float = 1.0,
) -> BOM:
    """BOM of an arbitrary ``SuperPod`` geometry (co-design candidates).

    ``uplink_provisioning`` thins the pod->HRS Clos tier consistently across
    cables, transceivers and switches — the paper's Table-2 estimation prices
    the uplink for the <2% long-range DP share, not the full x256.
    """
    return BOM(
        name=name,
        n_npus=sp.num_nodes,
        n_cpus=sp.num_nodes // 8,
        n_lrs=sp.lrs_count(),
        n_hrs=sp.hrs_count(uplink_provisioning),
        cables=sp.cables_by_link_type(uplink_provisioning),
        optical_modules=sp.optical_modules(uplink_provisioning),
    )


def ub_mesh_bom(n_npus: int = 8192, uplink_provisioning: float = 1.0) -> BOM:
    """UB-Mesh SuperPod: 4D-FM pods + HRS Clos pod tier."""
    sp = SuperPod(n_pods=max(1, n_npus // 1024))
    return BOM(
        name="UB-Mesh(4D-FM+Clos)",
        n_npus=sp.num_nodes,
        n_cpus=sp.num_nodes // 8,
        n_lrs=sp.lrs_count(),
        n_hrs=sp.hrs_count(uplink_provisioning),
        cables=sp.cables_by_link_type(uplink_provisioning),
        optical_modules=sp.optical_modules(uplink_provisioning),
    )


def clos_bom(n_npus: int = 8192, lanes_per_npu: int = 72, name: str = "Clos(x64T)") -> BOM:
    fab = ClosFabric(n_npus=n_npus, lanes_per_npu=lanes_per_npu)
    return BOM(
        name=name,
        n_npus=n_npus,
        n_cpus=n_npus // 8,
        n_lrs=0,
        n_hrs=fab.hrs_count(),
        cables=fab.cables_by_link_type(),
        optical_modules=fab.optical_modules(),
    )


def hybrid_bom(n_npus: int = 8192, fm_dims: int = 2, inter_lanes: int = 16) -> BOM:
    """2D-FM or 1D-FM intra-rack + x{inter_lanes} Clos beyond (Fig. 16 b/c).

    The full-mesh part keeps its electrical cables; everything beyond the
    rack (or board for 1D) goes through a non-oversubscribed Clos built for
    ``inter_lanes`` per NPU.
    """
    pod = ub_mesh_pod()
    n_pods = max(1, n_npus // 1024)
    if fm_dims == 2:
        # keep X+Y cliques; Z/A/pod traffic switched
        per_pod = {
            k: v
            for k, v in pod.cables_by_link_type().items()
            if k == "passive_electrical"
        }
        kept_lanes = 56
        name = f"2D-FM+x{inter_lanes}Clos"
    else:
        # keep only the board X clique
        x = pod.dims[0]
        n_links = pod.link_count(0)
        cables_per_link = max(1, math.ceil(x.lanes_per_peer / x.link.lanes_per_cable))
        per_pod = {"passive_electrical": n_links * cables_per_link}
        kept_lanes = 28
        name = f"1D-FM+x{inter_lanes}Clos"
    cables = {k: v * n_pods for k, v in per_pod.items()}
    fab = ClosFabric(n_npus=n_npus, lanes_per_npu=inter_lanes)
    clos_cables = fab.cables_by_link_type()
    for k, v in clos_cables.items():
        cables[k] = cables.get(k, 0) + v
    lrs = 18 * 16 * n_pods if fm_dims == 2 else 18 * 16 * n_pods
    return BOM(
        name=name,
        n_npus=n_npus,
        n_cpus=n_npus // 8,
        n_lrs=lrs,
        n_hrs=fab.hrs_count(),
        cables=cables,
        optical_modules=fab.optical_modules(),
    )


@dataclass(frozen=True)
class CostEfficiency:
    name: str
    capex: float
    opex: float
    performance: float          # relative training throughput (Clos = 1.0)

    @property
    def tco(self) -> float:
        return self.capex + self.opex

    @property
    def cost_efficiency(self) -> float:
        return self.performance / self.tco


def compare_architectures(
    n_npus: int = 8192, perf: dict[str, float] | None = None
) -> list[CostEfficiency]:
    """The Fig. 21 comparison.  ``perf`` maps arch name -> relative perf
    (defaults to the paper's ~0.95 for UB-Mesh vs 1.0 Clos).
    """
    perf = perf or {}
    boms = [
        ub_mesh_bom(n_npus),
        hybrid_bom(n_npus, fm_dims=2, inter_lanes=16),
        hybrid_bom(n_npus, fm_dims=1, inter_lanes=16),
        clos_bom(n_npus),
    ]
    default_perf = {
        "UB-Mesh(4D-FM+Clos)": 0.95,
        "2D-FM+x16Clos": 0.97,
        "1D-FM+x16Clos": 0.985,
        "Clos(x64T)": 1.0,
    }
    out = []
    for b in boms:
        out.append(
            CostEfficiency(
                name=b.name,
                capex=b.capex(),
                opex=b.opex(),
                performance=perf.get(b.name, default_perf.get(b.name, 1.0)),
            )
        )
    return out
