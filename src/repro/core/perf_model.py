"""Pluggable performance-model backends for planning and simulation.

The §5.2 planner, the iteration simulator and the benchmark harness all
price communication through one interface, the ``PerfModel`` protocol:

    comm_model(p)  ->  CommModel      # concrete axis costs for spec ``p``

Two backends implement it:

* **analytic** — ``CommModel`` itself (closed-form alpha-beta costs with
  idealized multi-ring bandwidths; spec-invariant).  ``AnalyticPerfModel``
  is the same backend with explicit per-axis bandwidth overrides, the
  typed replacement for the old ``simulate(axis_gbs_override=...)``
  plumbing.
* **netsim-calibrated** — ``NetsimPerfModel`` measures each axis' effective
  collective bandwidth by *executing* the collective's flow DAG on the
  flow-level simulator (``repro.netsim``), so contention, chain-endpoint
  idling and schedule structure are priced instead of assumed.  Ranking
  hundreds of candidate specs stays tractable because calibration is
  memoized per unique ``(topology, axis, group-width, routing, payload)``
  key — NOT per spec: a 1024-chip search hits only a handful of distinct
  TP*SP footprints.

The spec-dependence that matters for planning is the **model-axis group
width**: a TP*SP group that spans the whole (X, Y) rack plane rides the
cross-dim 2D multi-ring (~85% of the analytic bandwidth), while a partial
plane is stuck with the per-dimension hierarchical schedule (~50%) — so
realistic pricing can flip the planner's winner on contended workloads.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Protocol, runtime_checkable

from .cost_model import AxisCost, CommModel
from .topology import NDFullMesh, ub_mesh_pod
from .traffic import ParallelSpec


@runtime_checkable
class PerfModel(Protocol):
    """Anything that can resolve a candidate spec to concrete axis costs."""

    @property
    def backend(self) -> str: ...

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel: ...

    def override_axis(self, name: str, cost: AxisCost) -> "PerfModel": ...


@dataclass(frozen=True)
class AnalyticPerfModel:
    """Closed-form backend with explicit per-axis bandwidth overrides.

    ``axis_gbs`` replaces the per-chip bandwidth of named axes — e.g. a
    one-off calibration from ``NetSim.calibrated_axis_gbs`` — without the
    untyped dict plumbing ``simulate`` used to carry.
    """

    base: CommModel
    axis_gbs: dict[str, float] = field(default_factory=dict)

    @property
    def backend(self) -> str:
        return "analytic"

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel:
        if not self.axis_gbs:
            return self.base
        axes = {
            k: replace(a, gbs_per_chip=self.axis_gbs.get(k, a.gbs_per_chip))
            for k, a in self.base.axes.items()
        }
        return CommModel(axes=axes, routing=self.base.routing)

    def override_axis(self, name: str, cost: AxisCost) -> "AnalyticPerfModel":
        gbs = {k: v for k, v in self.axis_gbs.items() if k != name}
        return AnalyticPerfModel(self.base.override_axis(name, cost), gbs)


def _topo_key(topo: NDFullMesh) -> tuple:
    return tuple(
        (d.name, d.size, d.lanes_per_peer, d.link.name) for d in topo.dims
    )


# calibration memo shared across backend instances: one netsim execution per
# unique (topology, axis, group-width, routing, payload, latency) — the same
# key appears once whether the planner scores 10 specs or 1000
_CALIBRATION_CACHE: dict[tuple, float] = {}


@dataclass(frozen=True)
class NetsimPerfModel:
    """Netsim-calibrated backend: effective axis bandwidths measured by
    executing each axis' collective flow DAG on the concrete topology.

    ``comm_model(p)`` narrows the model-axis calibration to the TP*SP
    footprint of ``p`` (capped at the topology's own (X, Y) rack plane, so
    the cap always matches the fabric being simulated), which makes wide
    groups that can ride the cross-dim 2D multi-ring price differently
    from narrow ones; the data axis is calibrated once over the full
    inter-rack plane.  Axes the netsim topology cannot measure (e.g. the
    HRS "pod" tier) keep their analytic cost.
    """

    base: CommModel
    topo: NDFullMesh = field(default_factory=ub_mesh_pod)
    size_bytes: float = 256e6
    latency_s: float = 1e-6
    pinned: dict[str, AxisCost] = field(default_factory=dict)

    @property
    def backend(self) -> str:
        return "netsim"

    # -- calibration (memoized) -------------------------------------------
    def _calibrate(self, widths: dict[str, int | None]) -> dict[str, float]:
        from ..netsim import NetSim  # deferred: core must not hard-require netsim

        key_base = (
            _topo_key(self.topo),
            self.base.routing.value,
            self.size_bytes,
            self.latency_s,
        )
        missing = {
            axis: w
            for axis, w in widths.items()
            if key_base + (axis, w) not in _CALIBRATION_CACHE
        }
        if missing:
            sim = NetSim(
                self.topo,
                routing=self.base.routing,
                latency_s=self.latency_s,
            )
            cal = sim.calibrated_axis_gbs(
                self.size_bytes,
                comm=self.base,
                widths={a: w for a, w in missing.items() if w is not None},
                axes=tuple(missing),
            )
            for axis, w in missing.items():
                # axes netsim could not measure fall back to the analytic bw
                _CALIBRATION_CACHE[key_base + (axis, w)] = cal.get(
                    axis, self.base.axes[axis].gbs_per_chip
                )
        return {
            axis: _CALIBRATION_CACHE[key_base + (axis, w)]
            for axis, w in widths.items()
        }

    def _widths(self, p: ParallelSpec | None) -> dict[str, int | None]:
        """Calibration group width per measurable axis for spec ``p``.
        ``None`` means the full plane; widths that cover the plane are
        canonicalized to ``None`` so they share one cache entry."""
        widths: dict[str, int | None] = {}
        if "model" in self.base.axes:
            plane = self.topo.shape[0] * (
                self.topo.shape[1] if self.topo.ndim > 1 else 1
            )
            w = None if p is None else p.tp * p.sp
            widths["model"] = None if w is None or w >= plane else w
        if "data" in self.base.axes and self.topo.ndim > 2:
            widths["data"] = None               # full inter-rack plane
        return widths

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel:
        cal = self._calibrate(self._widths(p))
        axes = {}
        for name, a in self.base.axes.items():
            if name in cal:
                # measured effective bw can only tighten the analytic bound
                a = replace(a, gbs_per_chip=min(a.gbs_per_chip, cal[name]))
            if name in self.pinned:
                a = self.pinned[name]
            axes[name] = a
        for name, a in self.pinned.items():
            axes.setdefault(name, a)
        return CommModel(axes=axes, routing=self.base.routing)

    def override_axis(self, name: str, cost: AxisCost) -> "NetsimPerfModel":
        return replace(self, pinned={**self.pinned, name: cost})
