"""Pluggable performance-model backends for planning and simulation.

The §5.2 planner, the iteration simulator and the benchmark harness all
price communication through one interface, the ``PerfModel`` protocol:

    comm_model(p)  ->  CommModel      # concrete axis costs for spec ``p``

Two backends implement it:

* **analytic** — ``CommModel`` itself (closed-form alpha-beta costs with
  idealized multi-ring bandwidths; spec-invariant).  ``AnalyticPerfModel``
  is the same backend with explicit per-axis bandwidth overrides — the
  typed replacement for the old ``simulate(axis_gbs_override=...)``
  plumbing — and can additionally carry a ``CalibrationProfile`` of
  measured per-(axis, collective-shape) bandwidths.
* **netsim-calibrated** — ``NetsimPerfModel`` measures each axis'
  effective bandwidth **per collective shape** by *executing* the matching
  flow DAG on the flow-level simulator (``repro.netsim``): AllReduce /
  AllGather ride the multi-ring schedules, All-to-All rides the Fig. 14
  X-then-Y / Y-then-X split with explicit relay hops and receiver-egress
  (incast) caps, P2P a routed transfer.  Contention, chain-endpoint
  idling, relay serialization and incast are priced instead of assumed.
  Ranking hundreds of candidate specs stays tractable because calibration
  is memoized per unique ``(topology, axis, shape, group-width, routing,
  payload)`` key — NOT per spec: a 1024-chip search hits only a handful
  of distinct TP*SP / EP footprints.

Two spec-dependences matter for planning:

* the **model-axis group width**: a TP*SP group spanning the whole (X, Y)
  rack plane rides the cross-dim 2D multi-ring (~85% of the analytic
  bandwidth), while a partial plane is stuck with the per-dimension
  hierarchical schedule (~50%);
* the **collective shape**: the MoE dispatch A2A prices ~3x below the
  AllReduce number on the same axis (relay hops + incast), so an
  AllReduce-proxy backend systematically flatters expert parallelism —
  restrict ``shapes=("allreduce",)`` to reproduce that proxy behavior.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Protocol, runtime_checkable

from .cost_model import (
    A2A_CALIBRATION_MAX_NODES,
    COLLECTIVE_SHAPES,
    LATENCY_SHAPES,
    AxisCost,
    CalibrationProfile,
    CommModel,
    LatencyProfile,
    LatencyStats,
)
from .topology import NDFullMesh, SuperPod, ub_mesh_pod
from .traffic import ParallelSpec

# collective shapes that cross the HRS pod tier (DP gradient traffic and
# pipeline boundaries); EP's all-to-all never leaves the model axis
_POD_SHAPES = ("allreduce", "all_gather", "reduce_scatter", "p2p")


@runtime_checkable
class PerfModel(Protocol):
    """Anything that can resolve a candidate spec to concrete axis costs."""

    @property
    def backend(self) -> str: ...

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel: ...

    def override_axis(self, name: str, cost: AxisCost) -> "PerfModel": ...


@dataclass(frozen=True)
class AnalyticPerfModel:
    """Closed-form backend with explicit per-axis bandwidth overrides.

    ``axis_gbs`` replaces the per-chip bandwidth of named axes — e.g. a
    one-off calibration from ``NetSim.calibrated_axis_gbs`` — without the
    untyped dict plumbing ``simulate`` used to carry.  ``profile``
    optionally stamps measured per-(axis, collective-shape) bandwidths
    (a ``NetSim.calibrated_profile`` result) on top, so a one-off
    measurement can drive shape-aware pricing without the netsim backend's
    per-spec recalibration.
    """

    base: CommModel
    axis_gbs: dict[str, float] = field(default_factory=dict)
    profile: CalibrationProfile | None = None

    @property
    def backend(self) -> str:
        return "analytic"

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel:
        comm = self.base
        if self.axis_gbs:
            axes = {
                k: replace(a, gbs_per_chip=self.axis_gbs.get(k, a.gbs_per_chip))
                for k, a in comm.axes.items()
            }
            comm = CommModel(axes=axes, routing=comm.routing)
        if self.profile is not None:
            comm = self.profile.apply(comm)
        return comm

    def override_axis(self, name: str, cost: AxisCost) -> "AnalyticPerfModel":
        gbs = {k: v for k, v in self.axis_gbs.items() if k != name}
        return AnalyticPerfModel(
            self.base.override_axis(name, cost), gbs, self.profile
        )


def _topo_key(topo: NDFullMesh) -> tuple:
    return tuple(
        (d.name, d.size, d.lanes_per_peer, d.link.name) for d in topo.dims
    )


# calibration memo shared across backend instances: one netsim execution per
# unique (topology, axis, shape, group-width, routing, payload, latency, rx)
# — the same key appears once whether the planner scores 10 specs or 1000
_CALIBRATION_CACHE: dict[tuple, float] = {}

# latency-mode sibling of the bandwidth memo: one message-level netsim
# execution per unique (topology, routing, ..., "latency-mode", payload,
# axis, shape, width) key, holding the full LatencyStats (p50/p99/mean/
# total) rather than a scalar GB/s
_LATENCY_CACHE: dict[tuple, LatencyStats] = {}

# LatencyStats fields persisted per key in the disk store; each becomes a
# ``(axis, f"{shape}@{field}", width)`` entry so the store's 3-part
# ``axis|shape|width`` key format carries stats without a schema change
_LATENCY_STAT_FIELDS = ("p50_s", "p99_s", "mean_s", "total_s", "n")

# persistent-store handles per resolved cache directory (shares the
# corrupt-file warn-once bookkeeping across NetsimPerfModel instances)
_DISK_CACHES: dict[str, object] = {}

# running memo-effectiveness counters, cumulative since import (or the last
# ``reset_calibration_stats``).  ``per_key_s`` keeps the netsim wall cost of
# each (axis, shape, width) actually measured — the observability hook that
# shows WHERE planner time goes when the memo misses
_CALIBRATION_STATS: dict = {
    "hits": 0,
    "misses": 0,
    "disk_hits": 0,
    "measure_s": 0.0,
    "per_key_s": {},
    "sessions": 0,
    "session_keys": 0,
}


def calibration_stats() -> dict:
    """Snapshot of the shared calibration-memo counters: ``hits`` /
    ``misses`` (in-memory memo lookups by ``_calibrate``), ``disk_hits``
    (misses served by the persistent ``core.calib_cache`` store instead
    of a netsim run), ``measure_s`` (total netsim wall seconds spent
    measuring), ``per_key_s`` mapping each measured ``(axis, shape,
    width)`` to its wall cost (batched measurements split their batch
    wall time evenly across the batch's keys), and ``sessions`` /
    ``session_keys`` (solver sessions run and keys measured across them
    — ``session_keys / sessions`` is the batching efficiency)."""
    return {
        "hits": _CALIBRATION_STATS["hits"],
        "misses": _CALIBRATION_STATS["misses"],
        "disk_hits": _CALIBRATION_STATS["disk_hits"],
        "measure_s": _CALIBRATION_STATS["measure_s"],
        "per_key_s": dict(_CALIBRATION_STATS["per_key_s"]),
        "sessions": _CALIBRATION_STATS["sessions"],
        "session_keys": _CALIBRATION_STATS["session_keys"],
    }


def reset_calibration_stats() -> None:
    """Zero the memo counters (the cache itself is untouched)."""
    _CALIBRATION_STATS.update(
        hits=0, misses=0, disk_hits=0, measure_s=0.0,
        sessions=0, session_keys=0,
    )
    _CALIBRATION_STATS["per_key_s"] = {}


def _record_measurement(axis: str, shape: str, w: int | None, dt: float) -> None:
    _CALIBRATION_STATS["measure_s"] += dt
    per_key = _CALIBRATION_STATS["per_key_s"]
    k = (axis, shape, w)
    per_key[k] = per_key.get(k, 0.0) + dt


@dataclass(frozen=True)
class NetsimPerfModel:
    """Netsim-calibrated backend: effective axis bandwidths measured by
    executing each (axis, collective shape)'s flow DAG on the concrete
    topology, assembled into a ``CalibrationProfile`` per spec.

    ``comm_model(p)`` narrows the model-axis ring-collective calibration
    to the TP*SP footprint of ``p`` (capped at the topology's own (X, Y)
    rack plane, so the cap always matches the fabric being simulated) and
    the model-axis A2A calibration to the EP footprint (the
    ``compile_traffic_entry`` convention: up to two first-dim cliques) —
    so wide groups that can ride the cross-dim 2D multi-ring price
    differently from narrow ones, and EP volume is priced on the measured
    A2A number while TP/DP keep theirs.  The data axis is calibrated once
    over the full inter-rack plane.  Axes the netsim topology cannot
    measure (e.g. the HRS "pod" tier) keep their analytic cost.

    ``shapes`` selects what gets measured: the default is the full
    ``COLLECTIVE_SHAPES`` profile; ``("allreduce",)`` reproduces the
    PR-2-era AllReduce-proxy backend, where every collective is priced on
    the ring-calibrated scalar (useful as the baseline that shows why
    shape-aware pricing changes planner decisions).  ``rx_gbs`` is the
    receiver-egress (incast) cap handed to netsim ("auto" = the node's
    largest per-dim clique allocation).

    ``superpod`` unlocks multi-pod pricing: the "pod" axis — previously
    pinned to its analytic DCN cost because the chip-level pod topology
    cannot see the HRS tier — is calibrated on the **rack-coarsened**
    SuperPod mesh (``netsim/coarsen.py``: racks become super-nodes, the
    Clos tier an IO-capped extra dimension), so cross-pod DP/PP traffic
    is priced on measured multi-pod bandwidths.  The memo key gains the
    coarsening level (``coarsen_level``), so rack- and pod-granularity
    calibrations never alias.

    ``detail_racks`` (with ``superpod``) switches the MODEL-axis
    calibration from the isolated chip-level pod onto a
    **mixed-granularity** mesh: the named racks stay at chip granularity
    inside the rack-coarsened SuperPod, and the model-axis collectives
    are measured inside the embedded rack WHILE a cross-pod DP
    background AllReduce (``background_bytes`` per chip, default
    ``size_bytes``) crosses the same rack's trunk uplinks — so the
    planner finally sees model-axis interference from DCN traffic
    (ejection-port and uplink sharing), which both the pure-chip and
    pure-coarse calibrations miss by construction.  The memo key gains
    the ``detail_racks`` tuple and the background payload, so mixed and
    isolated model calibrations never alias.
    """

    base: CommModel
    topo: NDFullMesh = field(default_factory=ub_mesh_pod)
    size_bytes: float = 256e6
    latency_s: float = 1e-6
    pinned: dict[str, AxisCost] = field(default_factory=dict)
    shapes: tuple[str, ...] = COLLECTIVE_SHAPES
    rx_gbs: float | str | None = "auto"
    superpod: SuperPod | None = None
    coarsen_level: str = "rack"
    detail_racks: tuple[int, ...] = ()
    background_bytes: float | None = None
    # persistent calibration cache directory: "auto" resolves
    # $CALIB_CACHE_DIR / ~/.cache (core/calib_cache.py), an explicit path
    # pins it, None disables disk persistence entirely
    cache_dir: "str | None" = "auto"
    # how many independent chip-level calibration DAGs share one netsim
    # solver session (NetSim.measure_profile_batch); 1 = sequential
    batch_size: int = 4
    # False rebuilds the FluidNetwork wire structure from scratch on every
    # measurement session (the pre-template-cache behavior) — the per-spec
    # baseline leg of benchmarks/netsim_scale.netsim_planner_throughput
    reuse_wire_template: bool = True
    # degraded-mesh repricing (runtime/campaign.py): chip-level links dead
    # from t=0 in every measurement — calibration DAGs route around them
    # through APR reroute, so the profile prices the POST-FAILURE fabric.
    # Only the axes whose dims contain a failed link get degraded cache
    # keys; unaffected axes keep their healthy keys (box-confined routing
    # never crosses the failure), which is what makes repricing
    # incremental: the first degraded query measures only the hit axes and
    # every healthy axis is a memo/disk hit.
    failed_links: "tuple[tuple[int, int], ...]" = ()

    def __post_init__(self) -> None:
        if self.failed_links and self.detail_racks:
            raise ValueError(
                "failed_links and detail_racks cannot combine: degraded "
                "repricing runs on the isolated chip-level pod"
            )
        if self.detail_racks and self.superpod is None:
            # without a SuperPod there is no coarse mesh to embed the
            # detail racks in — silently falling back to the isolated
            # chip-level calibration would defeat the caller's intent
            raise ValueError(
                "detail_racks requires superpod= (the mixed-granularity "
                "mesh embeds the racks in the coarsened SuperPod)"
            )

    @property
    def backend(self) -> str:
        return "netsim"

    # -- calibration (memoized) -------------------------------------------
    def _tags(self) -> tuple[tuple, tuple, tuple, float]:
        """(key_base, coarse_tag, detail_tag, bg_bytes) — everything that
        pins a measurement besides the (axis, shape, width) request."""
        key_base = (
            _topo_key(self.topo),
            self.base.routing.value,
            self.size_bytes,
            self.latency_s,
            self.rx_gbs,
        )
        coarse_tag = ()
        if self.superpod is not None:
            # the coarse capacities derive from the SuperPod's OWN pod
            # (trunk widths, racks per pod), which need not equal
            # self.topo — key on its geometry too so distinct SuperPods
            # never alias in the shared cache
            coarse_tag = (
                "coarse",
                self.coarsen_level,
                self.superpod.n_pods,
                self.superpod.uplink_lanes_per_rack,
                _topo_key(self.superpod.pod),
            )
        detail_tag = ()
        bg_bytes = (
            self.size_bytes if self.background_bytes is None
            else self.background_bytes
        )
        if self.superpod is not None and self.detail_racks:
            # mixed-granularity model-axis calibration: keyed on the
            # embedded racks AND the background payload so isolated and
            # interference-priced measurements never alias
            detail_tag = ("detail", tuple(self.detail_racks), bg_bytes)
        return key_base, coarse_tag, detail_tag, bg_bytes

    def _degraded_axes(self) -> frozenset:
        """Chip-level axes whose calibration DAGs can see a failed link.

        An axis is affected iff some failed link's dimension belongs to
        the axis' dim set (model = dims 0-1, data = the rest): calibration
        DAGs are built at the base corner and routing is box-confined
        under SHORTEST/DETOUR, so a flow only ever traverses links of its
        own axis' dimensions.  The coarse "pod" axis is never affected by
        chip-level failures."""
        if not self.failed_links:
            return frozenset()
        ndim = len(self.topo.shape)
        axis_dims = {"model": (0, 1)}
        if ndim > 2:
            axis_dims["data"] = tuple(range(2, ndim))
        hit = set()
        for u, v in self.failed_links:
            d = self.topo.are_adjacent(u, v)
            if d is None:
                raise ValueError(
                    f"failed link ({u}, {v}) is not a physical link of the "
                    "topology"
                )
            for a, dims in axis_dims.items():
                if d in dims:
                    hit.add(a)
        return frozenset(hit)

    def _store_kind(self, axis: str, detail_tag: tuple) -> str:
        """Which persistent-cache file an axis' measurements live in —
        mirrors the in-memory key composition exactly."""
        if axis == "pod":
            return "pod"
        if axis == "model" and detail_tag:
            return "mixed"
        if axis in self._degraded_axes():
            return "degraded"
        return "chip"

    def _disk_cache(self) -> "object | None":
        if self.cache_dir is None:
            return None
        from .calib_cache import CalibCache, default_cache_dir

        d = (
            default_cache_dir() if self.cache_dir == "auto"
            else self.cache_dir
        )
        cache = _DISK_CACHES.get(str(d))
        if cache is None:
            cache = _DISK_CACHES[str(d)] = CalibCache(d)
        return cache

    def _calibrate(
        self, widths: dict[tuple[str, str], int | None]
    ) -> dict[tuple[str, str], float]:
        """(axis, shape) -> measured GB/s for the requested group widths,
        via the shared cross-instance memo (and the persistent disk store
        when enabled); ``reduce_scatter`` aliases the ``all_gather``
        measurement (same wire schedule)."""
        triples = [(a, s, w) for (a, s), w in widths.items()]
        vals = self._calibrate_keys(triples)
        return {(a, s): vals[(a, s, w)] for (a, s), w in widths.items()}

    def _key_context(self):
        """The memo-key closure plus persistent-store configs — shared by
        the per-model ``_calibrate_keys`` path and the cross-topology
        ``precalibrate_models`` sweep path so keys always compose the same
        way.  Returns ``(key, store_configs, detail_tag, bg_bytes)``."""
        key_base, coarse_tag, detail_tag, bg_bytes = self._tags()
        degraded_axes = self._degraded_axes()
        degraded_tag = ()
        if degraded_axes:
            degraded_tag = (
                "degraded",
                tuple(sorted(tuple(sorted(l)) for l in self.failed_links)),
            )

        def key(axis: str, shape: str, w: int | None) -> tuple:
            if shape == "reduce_scatter":
                shape = "all_gather"
            if axis == "pod":
                return key_base + coarse_tag + (axis, shape, w)
            if axis == "model" and detail_tag:
                return key_base + coarse_tag + detail_tag + (axis, shape, w)
            if axis in degraded_axes:
                return key_base + degraded_tag + (axis, shape, w)
            return key_base + (axis, shape, w)

        store_configs = {
            "chip": list(key_base),
            "pod": list(key_base + coarse_tag),
            "mixed": list(key_base + coarse_tag + detail_tag),
            "degraded": list(key_base + degraded_tag),
        }
        return key, store_configs, detail_tag, bg_bytes

    def _resolve_disk(self, missing: set, key, store_configs, detail_tag):
        """Serve memo ``missing`` entries from the persistent store
        (mutating ``missing``, the memo and the stats counters); returns
        the disk handle for later write-back (None when disabled)."""
        disk = self._disk_cache() if missing else None
        if disk is not None:
            stored: dict[str, dict] = {}
            for axis, shape, w in list(missing):
                kind = self._store_kind(axis, detail_tag)
                if kind not in stored:
                    stored[kind] = disk.get_profile(store_configs[kind])
                mshape = "all_gather" if shape == "reduce_scatter" else shape
                v = stored[kind].get((axis, mshape, w))
                if v is not None:
                    _CALIBRATION_CACHE[key(axis, shape, w)] = v
                    _CALIBRATION_STATS["disk_hits"] += 1
                    missing.discard((axis, shape, w))
        return disk

    def _to_measure(
        self, missing: set, detail_tag
    ) -> "dict[tuple[str, str, int | None], str]":
        """De-alias and de-duplicate what still needs a netsim run: the
        reduce_scatter/all_gather pair must measure ONCE, not twice.
        Maps each measured triple to its store kind."""
        to_measure: dict[tuple[str, str, int | None], str] = {}
        for axis, shape, w in sorted(missing, key=str):
            mshape = "all_gather" if shape == "reduce_scatter" else shape
            kind = self._store_kind(axis, detail_tag)
            to_measure.setdefault((axis, mshape, w), kind)
        return to_measure

    def _calibrate_keys(
        self, triples: "list[tuple[str, str, int | None]]"
    ) -> "dict[tuple[str, str, int | None], float]":
        """Measured GB/s per ``(axis, shape, width)`` triple.

        Resolution order per key: in-memory memo -> persistent disk store
        (``core/calib_cache.py``) -> netsim measurement.  Chip-level
        misses are measured in batched solver sessions
        (``NetSim.measure_profile_batch``); "pod"-axis entries on the
        rack-coarsened SuperPod mesh and mixed-granularity model entries
        on the embedded-rack mesh, one run each (their cache keys carry
        the coarsening / detail tags so granularities never alias).
        Newly measured values are written back to the disk store."""
        from ..netsim import NetSim  # deferred: core must not hard-require netsim

        key, store_configs, detail_tag, bg_bytes = self._key_context()

        missing = {
            (axis, shape, w)
            for axis, shape, w in triples
            if key(axis, shape, w) not in _CALIBRATION_CACHE
        }
        _CALIBRATION_STATS["hits"] += len(triples) - len(missing)
        _CALIBRATION_STATS["misses"] += len(missing)

        # persistent read-through: serve misses from the on-disk profile
        disk = self._resolve_disk(missing, key, store_configs, detail_tag)
        to_measure = self._to_measure(missing, detail_tag)

        new_by_kind: dict[str, dict] = {}

        def store(axis: str, mshape: str, w: int | None, kind: str,
                  gbs: "float | None") -> None:
            # shapes netsim could not measure fall back to the analytic bw
            val = (
                gbs if gbs is not None
                else self.base.axes[axis].gbs_per_chip
            )
            _CALIBRATION_CACHE[key(axis, mshape, w)] = val
            new_by_kind.setdefault(kind, {})[(axis, mshape, w)] = val

        chip_keys = [k for k, kind in to_measure.items() if kind == "chip"]
        if chip_keys:
            sim = NetSim(
                self.topo,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
                reuse_wire_template=self.reuse_wire_template,
            )
            t0 = time.perf_counter()
            measured = sim.measure_profile_batch(
                self.size_bytes,
                chip_keys,
                comm=self.base,
                batch_size=max(1, self.batch_size),
                stats=_CALIBRATION_STATS,
            )
            dt = (time.perf_counter() - t0) / len(chip_keys)
            for axis, mshape, w in chip_keys:
                _record_measurement(axis, mshape, w, dt)
                store(axis, mshape, w, "chip", measured[(axis, mshape, w)])
        degraded_keys = [
            k for k, kind in to_measure.items() if kind == "degraded"
        ]
        if degraded_keys:
            # affected axes re-measure on the failed-link mesh; APR reroute
            # happens inside netsim (can_batch_calibration is False there,
            # so measure_profile_batch falls back to sequential runs)
            dsim = NetSim(
                self.topo,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
                reuse_wire_template=self.reuse_wire_template,
                failed_links=self.failed_links,
            )
            t0 = time.perf_counter()
            dmeasured = dsim.measure_profile_batch(
                self.size_bytes,
                degraded_keys,
                comm=self.base,
                batch_size=max(1, self.batch_size),
                stats=_CALIBRATION_STATS,
            )
            dt = (time.perf_counter() - t0) / len(degraded_keys)
            for axis, mshape, w in degraded_keys:
                _record_measurement(axis, mshape, w, dt)
                store(
                    axis, mshape, w, "degraded", dmeasured[(axis, mshape, w)]
                )
        pod_keys = [k for k, kind in to_measure.items() if kind == "pod"]
        if pod_keys:
            from ..netsim.coarsen import (
                coarse_calibrated_profile,
                coarse_netsim,
                coarsen_superpod,
            )

            cm = coarsen_superpod(self.superpod, level=self.coarsen_level)
            csim = coarse_netsim(
                cm,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
            )
            for axis, mshape, w in pod_keys:
                _CALIBRATION_STATS["sessions"] += 1
                _CALIBRATION_STATS["session_keys"] += 1
                t0 = time.perf_counter()
                cal = coarse_calibrated_profile(
                    cm,
                    self.size_bytes,
                    comm=self.base,
                    widths={} if w is None else {axis: w},
                    axes=(axis,),
                    shapes=(mshape,),
                    sim=csim,
                )
                _record_measurement(axis, mshape, w, time.perf_counter() - t0)
                store(axis, mshape, w, "pod", cal.gbs.get((axis, mshape)))
        mixed_keys = [k for k, kind in to_measure.items() if kind == "mixed"]
        if mixed_keys:
            from ..netsim.coarsen import (
                coarsen_superpod,
                mixed_calibrated_profile,
                mixed_netsim,
            )

            cm = coarsen_superpod(
                self.superpod,
                level=self.coarsen_level,
                detail_racks=self.detail_racks,
            )
            msim = mixed_netsim(
                cm,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
            )
            for axis, mshape, w in mixed_keys:
                _CALIBRATION_STATS["sessions"] += 1
                _CALIBRATION_STATS["session_keys"] += 1
                t0 = time.perf_counter()
                cal = mixed_calibrated_profile(
                    cm,
                    self.size_bytes,
                    comm=self.base,
                    widths={} if w is None else {axis: w},
                    axes=(axis,),
                    shapes=(mshape,),
                    background_per_chip_bytes=bg_bytes,
                    sim=msim,
                )
                _record_measurement(axis, mshape, w, time.perf_counter() - t0)
                store(axis, mshape, w, "mixed", cal.gbs.get((axis, mshape)))

        # persistent write-back (best-effort; never raises into planning)
        if new_by_kind and disk is not None:
            for kind, entries in new_by_kind.items():
                disk.update(store_configs[kind], entries)

        return {
            (axis, shape, w): _CALIBRATION_CACHE[key(axis, shape, w)]
            for axis, shape, w in triples
        }

    def _measure_coarse_key(
        self, cm, kind: str, axis: str, mshape: str, w: "int | None"
    ) -> "float | None":
        """One coarse ("pod") or mixed-granularity key measured on mesh
        ``cm`` — a single solver session.  Used by ``precalibrate_models``
        to measure each distinct coarse signature once and fan the value
        out to every candidate that shares it."""
        _CALIBRATION_STATS["sessions"] += 1
        _CALIBRATION_STATS["session_keys"] += 1
        t0 = time.perf_counter()
        if kind == "pod":
            from ..netsim.coarsen import (
                coarse_calibrated_profile,
                coarse_netsim,
            )

            sim = coarse_netsim(
                cm,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
            )
            cal = coarse_calibrated_profile(
                cm,
                self.size_bytes,
                comm=self.base,
                widths={} if w is None else {axis: w},
                axes=(axis,),
                shapes=(mshape,),
                sim=sim,
            )
        else:
            from ..netsim.coarsen import (
                mixed_calibrated_profile,
                mixed_netsim,
            )

            bg = (
                self.size_bytes if self.background_bytes is None
                else self.background_bytes
            )
            sim = mixed_netsim(
                cm,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
            )
            cal = mixed_calibrated_profile(
                cm,
                self.size_bytes,
                comm=self.base,
                widths={} if w is None else {axis: w},
                axes=(axis,),
                shapes=(mshape,),
                background_per_chip_bytes=bg,
                sim=sim,
            )
        _record_measurement(axis, mshape, w, time.perf_counter() - t0)
        return cal.gbs.get((axis, mshape))

    def precalibrate(
        self, specs: "list[ParallelSpec] | tuple[ParallelSpec, ...]"
    ) -> dict:
        """Front-load every calibration key a spec set will need.

        Collects the union of ``_widths(p)`` over ``specs`` (one dry pass,
        no netsim work) and resolves all unique ``(axis, shape, width)``
        keys at once — so the chip-level misses land in few batched
        ``NetSim.run_dags`` sessions instead of one session per key, and a
        sweep pays measurement exactly once up front.  ``plan()`` calls
        this automatically for backends that expose it; standalone sweeps
        can call it with ``enumerate_specs(...)`` output directly.

        Returns ``{"keys": unique keys, "measured": netsim-measured,
        "disk_hits": served from the persistent store, "wall_s": ...}``.
        """
        keys: set[tuple[str, str, int | None]] = set()
        for p in specs:
            keys.update(
                (a, s, w) for (a, s), w in self._widths(p).items()
            )
        before = calibration_stats()
        t0 = time.perf_counter()
        if keys:
            self._calibrate_keys(sorted(keys, key=str))
        after = calibration_stats()
        return {
            "keys": len(keys),
            "measured": after["misses"] - before["misses"]
            - (after["disk_hits"] - before["disk_hits"]),
            "disk_hits": after["disk_hits"] - before["disk_hits"],
            "wall_s": time.perf_counter() - t0,
        }

    def _widths(
        self, p: ParallelSpec | None
    ) -> dict[tuple[str, str], int | None]:
        """Calibration group width per measurable (axis, shape) for spec
        ``p``.  ``None`` means the shape's default group (full plane for
        ring collectives, the capped A2A footprint for all_to_all); widths
        that cover it are canonicalized to ``None`` so they share one
        cache entry."""
        widths: dict[tuple[str, str], int | None] = {}
        x = self.topo.shape[0]
        plane = x * (self.topo.shape[1] if self.topo.ndim > 1 else 1)
        if "model" in self.base.axes:
            for shape in self.shapes:
                if shape in ("allreduce", "all_gather", "reduce_scatter"):
                    w = None if p is None else p.tp * p.sp
                    widths[("model", shape)] = (
                        None if w is None or w >= plane else w
                    )
                elif shape == "all_to_all":
                    # EP footprint (compile_traffic_entry convention),
                    # canonicalized against the SAME cap the measurement
                    # group uses; an ep=1 spec has no A2A traffic to price
                    if p is not None and p.ep <= 1:
                        continue
                    cap = min(A2A_CALIBRATION_MAX_NODES, 2 * x, plane)
                    w = None if p is None else min(2 * p.ep, cap)
                    widths[("model", shape)] = (
                        None if w is None or w >= cap else w
                    )
                else:                           # p2p: width-independent
                    widths[("model", shape)] = None
        if "data" in self.base.axes and self.topo.ndim > 2:
            for shape in self.shapes:
                widths[("data", shape)] = None  # full inter-rack plane
        if self.superpod is not None and "pod" in self.base.axes:
            # HRS pod tier, measured on the rack-coarsened mesh; the
            # calibration ring spans the pod-axis group (spec-invariant:
            # the DP-across-pods footprint is the axis itself), capped at
            # the SuperPod's pod count
            w = min(self.base.axes["pod"].size, self.superpod.n_pods)
            for shape in self.shapes:
                if shape in _POD_SHAPES:
                    widths[("pod", shape)] = (
                        None if w >= self.superpod.n_pods else w
                    )
        return widths

    def _latency_widths(
        self, p: ParallelSpec | None
    ) -> dict[tuple[str, str], int | None]:
        """The latency-measurable subset of ``_widths(p)``: decode-regime
        shapes only (``LATENCY_SHAPES``) on the chip-level axes — the HRS
        "pod" tier lives on the coarse mesh, which the message-level
        transport does not model."""
        return {
            (a, s): w
            for (a, s), w in self._widths(p).items()
            if s in LATENCY_SHAPES and a != "pod"
        }

    def _analytic_latency(
        self, axis: str, shape: str, size_bytes: float
    ) -> float:
        """Closed-form alpha-beta time for shapes the topology cannot
        host (fallback; flagged by ``n=0`` in the stats)."""
        return getattr(self.base, shape)(axis, size_bytes)

    def latency_profile(
        self, p: ParallelSpec | None = None, *, size_bytes: float = 64e3
    ) -> LatencyProfile:
        """Measured message-level latency stats per (axis, shape) at a
        decode-sized payload — the latency-mode sibling of
        :meth:`calibration_profile`.

        Each (axis, shape, width) key executes its collective DAG ONCE on
        the message-level transport (``NetSim(message_level=True)``) and
        is memoized in the shared ``_LATENCY_CACHE`` under the bandwidth
        memo's ``key_base`` extended with a ``("latency-mode",
        size_bytes)`` tag — so latency and bandwidth calibrations never
        alias, while specs sharing a TP*SP / EP footprint share
        measurements exactly as they do for GB/s.  Values persist through
        the same ``core.calib_cache`` store (config = key_base + the
        latency tag) with each ``LatencyStats`` field flattened to an
        ``axis|shape@field|width`` entry.

        Widths resolve from ``_widths(p)`` restricted to
        ``LATENCY_SHAPES``, so the measured group is the spec's REAL
        footprint: a tp*sp=64 plane group pays the full 2(w-1)-step ring
        latency while a tp*sp=8 clique group pays ~1/8 of it — the
        spec-dependence the analytic model's pinned axis size hides, and
        the reason SLO-driven decode planning can disagree with
        bandwidth-optimal planning."""
        from ..netsim import NetSim  # deferred: core must not hard-require netsim

        if self.failed_links:
            raise ValueError(
                "latency profiles run on the healthy mesh: message mode "
                "does not model failure injection"
            )
        widths = self._latency_widths(p)
        key_base, _coarse, _detail, _bg = self._tags()
        tag = ("latency-mode", float(size_bytes))

        def lkey(axis: str, shape: str, w: "int | None") -> tuple:
            return key_base + tag + (axis, shape, w)

        triples = [(a, s, w) for (a, s), w in widths.items()]
        missing = {t for t in triples if lkey(*t) not in _LATENCY_CACHE}
        _CALIBRATION_STATS["hits"] += len(triples) - len(missing)
        _CALIBRATION_STATS["misses"] += len(missing)

        # persistent read-through: a key hits only when every stat field
        # is present (partial rows re-measure rather than mixing sources)
        store_config = list(key_base + tag)
        disk = self._disk_cache() if missing else None
        if disk is not None:
            stored = disk.get_profile(store_config)
            for axis, shape, w in list(missing):
                vals = {
                    f: stored.get((axis, f"{shape}@{f}", w))
                    for f in _LATENCY_STAT_FIELDS
                }
                if all(v is not None for v in vals.values()):
                    _LATENCY_CACHE[lkey(axis, shape, w)] = LatencyStats(
                        p50_s=vals["p50_s"],
                        p99_s=vals["p99_s"],
                        mean_s=vals["mean_s"],
                        total_s=vals["total_s"],
                        n=int(vals["n"]),
                    )
                    _CALIBRATION_STATS["disk_hits"] += 1
                    missing.discard((axis, shape, w))

        if missing:
            sim = NetSim(
                self.topo,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
                reuse_wire_template=self.reuse_wire_template,
                message_level=True,
            )
            new_entries: dict = {}
            for axis, shape, w in sorted(missing, key=str):
                _CALIBRATION_STATS["sessions"] += 1
                _CALIBRATION_STATS["session_keys"] += 1
                t0 = time.perf_counter()
                prof = sim.measure_latency_profile(
                    size_bytes,
                    widths={(axis, shape): w},
                    axes=(axis,),
                    shapes=(shape,),
                )
                _record_measurement(
                    axis, f"{shape}@lat", w, time.perf_counter() - t0
                )
                st = prof.get(axis, shape)
                if st is None:
                    t_an = self._analytic_latency(axis, shape, size_bytes)
                    st = LatencyStats(
                        p50_s=t_an, p99_s=t_an, mean_s=t_an,
                        total_s=t_an, n=0,
                    )
                _LATENCY_CACHE[lkey(axis, shape, w)] = st
                for f in _LATENCY_STAT_FIELDS:
                    new_entries[(axis, f"{shape}@{f}", w)] = float(
                        getattr(st, f)
                    )
            # persistent write-back (best-effort; never raises into
            # planning)
            if disk is not None and new_entries:
                disk.update(store_config, new_entries)

        return LatencyProfile(
            lat={
                (a, s): _LATENCY_CACHE[lkey(a, s, w)]
                for (a, s), w in widths.items()
            },
            size_bytes=float(size_bytes),
        )

    def calibration_profile(
        self, p: ParallelSpec | None = None
    ) -> CalibrationProfile:
        """The measured (axis, shape) profile resolved for spec ``p``
        (memoized; unclamped — ``comm_model`` clamps at the analytic
        bound when pricing)."""
        return CalibrationProfile(gbs=dict(self._calibrate(self._widths(p))))

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel:
        comm = self.calibration_profile(p).apply(self.base, clamp=True)
        axes = dict(comm.axes)
        for name, a in self.pinned.items():
            axes[name] = a
        return CommModel(axes=axes, routing=self.base.routing)

    def override_axis(self, name: str, cost: AxisCost) -> "NetsimPerfModel":
        return replace(self, pinned={**self.pinned, name: cost})


# ---------------------------------------------------------------------------
# Cross-topology batched precalibration (geometry sweeps)
# ---------------------------------------------------------------------------


def _coarse_measure_sig(
    m: NetsimPerfModel, kind: str, cm, store_configs: dict
) -> tuple:
    """Everything that determines a coarse-mesh measurement's outcome
    besides the (axis, shape, width) triple — the cross-candidate dedup
    key of ``precalibrate_models``.

    The "pod" signature is *structural*: the coarse mesh derives from the
    pod's inter-rack dims and the uplink only, so candidates that differ
    in intra-rack lanes (different chip topologies, different memo keys)
    still share one coarse measurement.  Mixed-granularity entries stay
    conservative: their exact store config (which pins the embedded chip
    topology too) is the signature."""
    if kind == "mixed":
        return ("mixed",) + tuple(store_configs["mixed"])
    sizes = tuple(sorted((k, a.size) for k, a in m.base.axes.items()))
    return (
        "pod",
        cm.topo.dims,
        tuple(sorted((cm.dim_io_gbs or {}).items())),
        cm.chips_per_node,
        tuple(sorted((k, tuple(v)) for k, v in cm.axis_dims.items())),
        m.base.routing.value,
        float(m.size_bytes),
        m.latency_s,
        m.rx_gbs,
        sizes,
    )


def precalibrate_models(
    models: "list[NetsimPerfModel] | tuple[NetsimPerfModel, ...]",
    specs_by_model: "list | None" = None,
    *,
    batch_size: int = 8,
) -> dict:
    """Front-load calibration for MANY candidate topologies at once — the
    cross-topology extension of :meth:`NetsimPerfModel.precalibrate` that
    makes a geometry sweep pay roughly one candidate's measurement bill.

    ``specs_by_model`` optionally aligns one spec list per model (the
    widths each candidate's planner run will request); ``None`` entries
    calibrate the spec-independent default widths.

    Three sharings stack on top of the per-model memo/disk resolution:

    * chip-level misses from all candidates go through ONE
      ``netsim.api.measure_cross_topology`` call — identical measurements
      (same used-dim specs, same DAG structure) dedup across candidates,
      and distinct ones share host-mesh solver sessions;
    * coarse "pod"-axis misses dedup by structural signature
      (:func:`_coarse_measure_sig`) — candidates differing only in
      intra-rack provisioning share one coarse-mesh run;
    * every resolved value lands in each candidate's own memo key and
      persistent store, so subsequent ``plan()`` calls are measurement-free.

    Returns ``{"models", "keys", "measured", "unique_measured",
    "deduped", "disk_hits", "sessions", "session_keys", "wall_s"}``.
    """
    from ..netsim import NetSim  # deferred: core must not hard-require netsim
    from ..netsim.api import measure_cross_topology

    t0 = time.perf_counter()
    before = calibration_stats()
    models = list(models)
    specs_list = (
        list(specs_by_model) if specs_by_model is not None
        else [None] * len(models)
    )
    if len(specs_list) != len(models):
        raise ValueError("specs_by_model must align with models")

    ctx: list[dict] = []
    chip_jobs: list = []
    chip_job_model: list[int] = []
    coarse_groups: dict = {}
    coarse_meshes: dict = {}
    total_keys = 0

    for i, m in enumerate(models):
        specs = specs_list[i]
        keys: set = set()
        for p in (specs if specs else [None]):
            keys.update((a, s, w) for (a, s), w in m._widths(p).items())
        total_keys += len(keys)
        if m.failed_links:
            # degraded models cannot share relocated solver sessions (the
            # failure breaks translation symmetry) — resolve them through
            # the per-model sequential path and keep ctx aligned
            if keys:
                m._calibrate_keys(sorted(keys, key=str))
            ctx.append({
                "key": None,
                "store_configs": None,
                "disk": None,
                "new_by_kind": {},
            })
            continue
        key, store_configs, detail_tag, _bg = m._key_context()
        missing = {k for k in keys if key(*k) not in _CALIBRATION_CACHE}
        _CALIBRATION_STATS["hits"] += len(keys) - len(missing)
        _CALIBRATION_STATS["misses"] += len(missing)
        disk = m._resolve_disk(missing, key, store_configs, detail_tag)
        to_measure = m._to_measure(missing, detail_tag)
        ctx.append({
            "key": key,
            "store_configs": store_configs,
            "disk": disk,
            "new_by_kind": {},
        })
        chip_keys = sorted(
            (k for k, kind in to_measure.items() if kind == "chip"), key=str
        )
        if chip_keys:
            sim = NetSim(
                m.topo,
                routing=m.base.routing,
                latency_s=m.latency_s,
                rx_gbs=m.rx_gbs,
                reuse_wire_template=m.reuse_wire_template,
            )
            sizes = {k: a.size for k, a in m.base.axes.items()}
            chip_jobs.append((sim, m.size_bytes, chip_keys, sizes))
            chip_job_model.append(i)
        for triple, kind in to_measure.items():
            if kind == "chip":
                continue
            cm = coarse_meshes.get((i, kind))
            if cm is None:
                from ..netsim.coarsen import coarsen_superpod

                cm = coarsen_superpod(
                    m.superpod,
                    level=m.coarsen_level,
                    detail_racks=(
                        m.detail_racks if kind == "mixed" else ()
                    ),
                )
                coarse_meshes[(i, kind)] = cm
            sig = _coarse_measure_sig(m, kind, cm, store_configs) + triple
            coarse_groups.setdefault(sig, []).append((i, kind, triple))

    # chip-level: one cross-topology batched measurement over all models
    if chip_jobs:
        t0c = time.perf_counter()
        measured = measure_cross_topology(
            chip_jobs, batch_size=batch_size, stats=_CALIBRATION_STATS
        )
        dtc = time.perf_counter() - t0c
        n_chip = sum(len(j[2]) for j in chip_jobs) or 1
        for i, job, out in zip(chip_job_model, chip_jobs, measured):
            m, c = models[i], ctx[i]
            for triple in job[2]:
                axis, mshape, w = triple
                _record_measurement(axis, mshape, w, dtc / n_chip)
                gbs = out[triple]
                val = (
                    gbs if gbs is not None
                    else m.base.axes[axis].gbs_per_chip
                )
                _CALIBRATION_CACHE[c["key"](axis, mshape, w)] = val
                c["new_by_kind"].setdefault("chip", {})[triple] = val

    # coarse/mixed: measured once per distinct signature, fanned out
    for sig, refs in coarse_groups.items():
        i0, kind0, (axis, mshape, w) = refs[0]
        gbs = models[i0]._measure_coarse_key(
            coarse_meshes[(i0, kind0)], kind0, axis, mshape, w
        )
        for i, kind, triple in refs:
            m, c = models[i], ctx[i]
            val = gbs if gbs is not None else m.base.axes[axis].gbs_per_chip
            _CALIBRATION_CACHE[c["key"](*triple)] = val
            c["new_by_kind"].setdefault(kind, {})[triple] = val

    # persistent write-back, per candidate per store kind (best-effort)
    for c in ctx:
        if c["new_by_kind"] and c["disk"] is not None:
            for kind, entries in c["new_by_kind"].items():
                c["disk"].update(c["store_configs"][kind], entries)

    after = calibration_stats()
    measured_reqs = (after["misses"] - before["misses"]) - (
        after["disk_hits"] - before["disk_hits"]
    )
    unique = after["session_keys"] - before["session_keys"]
    return {
        "models": len(models),
        "keys": total_keys,
        "measured": measured_reqs,
        "unique_measured": unique,
        "deduped": max(0, measured_reqs - unique),
        "disk_hits": after["disk_hits"] - before["disk_hits"],
        "sessions": after["sessions"] - before["sessions"],
        "session_keys": unique,
        "wall_s": time.perf_counter() - t0,
    }
