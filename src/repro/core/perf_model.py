"""Pluggable performance-model backends for planning and simulation.

The §5.2 planner, the iteration simulator and the benchmark harness all
price communication through one interface, the ``PerfModel`` protocol:

    comm_model(p)  ->  CommModel      # concrete axis costs for spec ``p``

Two backends implement it:

* **analytic** — ``CommModel`` itself (closed-form alpha-beta costs with
  idealized multi-ring bandwidths; spec-invariant).  ``AnalyticPerfModel``
  is the same backend with explicit per-axis bandwidth overrides — the
  typed replacement for the old ``simulate(axis_gbs_override=...)``
  plumbing — and can additionally carry a ``CalibrationProfile`` of
  measured per-(axis, collective-shape) bandwidths.
* **netsim-calibrated** — ``NetsimPerfModel`` measures each axis'
  effective bandwidth **per collective shape** by *executing* the matching
  flow DAG on the flow-level simulator (``repro.netsim``): AllReduce /
  AllGather ride the multi-ring schedules, All-to-All rides the Fig. 14
  X-then-Y / Y-then-X split with explicit relay hops and receiver-egress
  (incast) caps, P2P a routed transfer.  Contention, chain-endpoint
  idling, relay serialization and incast are priced instead of assumed.
  Ranking hundreds of candidate specs stays tractable because calibration
  is memoized per unique ``(topology, axis, shape, group-width, routing,
  payload)`` key — NOT per spec: a 1024-chip search hits only a handful
  of distinct TP*SP / EP footprints.

Two spec-dependences matter for planning:

* the **model-axis group width**: a TP*SP group spanning the whole (X, Y)
  rack plane rides the cross-dim 2D multi-ring (~85% of the analytic
  bandwidth), while a partial plane is stuck with the per-dimension
  hierarchical schedule (~50%);
* the **collective shape**: the MoE dispatch A2A prices ~3x below the
  AllReduce number on the same axis (relay hops + incast), so an
  AllReduce-proxy backend systematically flatters expert parallelism —
  restrict ``shapes=("allreduce",)`` to reproduce that proxy behavior.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Protocol, runtime_checkable

from .cost_model import (
    A2A_CALIBRATION_MAX_NODES,
    COLLECTIVE_SHAPES,
    AxisCost,
    CalibrationProfile,
    CommModel,
)
from .topology import NDFullMesh, SuperPod, ub_mesh_pod
from .traffic import ParallelSpec

# collective shapes that cross the HRS pod tier (DP gradient traffic and
# pipeline boundaries); EP's all-to-all never leaves the model axis
_POD_SHAPES = ("allreduce", "all_gather", "reduce_scatter", "p2p")


@runtime_checkable
class PerfModel(Protocol):
    """Anything that can resolve a candidate spec to concrete axis costs."""

    @property
    def backend(self) -> str: ...

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel: ...

    def override_axis(self, name: str, cost: AxisCost) -> "PerfModel": ...


@dataclass(frozen=True)
class AnalyticPerfModel:
    """Closed-form backend with explicit per-axis bandwidth overrides.

    ``axis_gbs`` replaces the per-chip bandwidth of named axes — e.g. a
    one-off calibration from ``NetSim.calibrated_axis_gbs`` — without the
    untyped dict plumbing ``simulate`` used to carry.  ``profile``
    optionally stamps measured per-(axis, collective-shape) bandwidths
    (a ``NetSim.calibrated_profile`` result) on top, so a one-off
    measurement can drive shape-aware pricing without the netsim backend's
    per-spec recalibration.
    """

    base: CommModel
    axis_gbs: dict[str, float] = field(default_factory=dict)
    profile: CalibrationProfile | None = None

    @property
    def backend(self) -> str:
        return "analytic"

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel:
        comm = self.base
        if self.axis_gbs:
            axes = {
                k: replace(a, gbs_per_chip=self.axis_gbs.get(k, a.gbs_per_chip))
                for k, a in comm.axes.items()
            }
            comm = CommModel(axes=axes, routing=comm.routing)
        if self.profile is not None:
            comm = self.profile.apply(comm)
        return comm

    def override_axis(self, name: str, cost: AxisCost) -> "AnalyticPerfModel":
        gbs = {k: v for k, v in self.axis_gbs.items() if k != name}
        return AnalyticPerfModel(
            self.base.override_axis(name, cost), gbs, self.profile
        )


def _topo_key(topo: NDFullMesh) -> tuple:
    return tuple(
        (d.name, d.size, d.lanes_per_peer, d.link.name) for d in topo.dims
    )


# calibration memo shared across backend instances: one netsim execution per
# unique (topology, axis, shape, group-width, routing, payload, latency, rx)
# — the same key appears once whether the planner scores 10 specs or 1000
_CALIBRATION_CACHE: dict[tuple, float] = {}

# running memo-effectiveness counters, cumulative since import (or the last
# ``reset_calibration_stats``).  ``per_key_s`` keeps the netsim wall cost of
# each (axis, shape, width) actually measured — the observability hook that
# shows WHERE planner time goes when the memo misses
_CALIBRATION_STATS: dict = {
    "hits": 0,
    "misses": 0,
    "measure_s": 0.0,
    "per_key_s": {},
}


def calibration_stats() -> dict:
    """Snapshot of the shared calibration-memo counters: ``hits`` /
    ``misses`` (cache lookups by ``_calibrate``), ``measure_s`` (total
    netsim wall seconds spent measuring), and ``per_key_s`` mapping each
    measured ``(axis, shape, width)`` to its wall cost."""
    return {
        "hits": _CALIBRATION_STATS["hits"],
        "misses": _CALIBRATION_STATS["misses"],
        "measure_s": _CALIBRATION_STATS["measure_s"],
        "per_key_s": dict(_CALIBRATION_STATS["per_key_s"]),
    }


def reset_calibration_stats() -> None:
    """Zero the memo counters (the cache itself is untouched)."""
    _CALIBRATION_STATS.update(hits=0, misses=0, measure_s=0.0)
    _CALIBRATION_STATS["per_key_s"] = {}


def _record_measurement(axis: str, shape: str, w: int | None, dt: float) -> None:
    _CALIBRATION_STATS["measure_s"] += dt
    per_key = _CALIBRATION_STATS["per_key_s"]
    k = (axis, shape, w)
    per_key[k] = per_key.get(k, 0.0) + dt


@dataclass(frozen=True)
class NetsimPerfModel:
    """Netsim-calibrated backend: effective axis bandwidths measured by
    executing each (axis, collective shape)'s flow DAG on the concrete
    topology, assembled into a ``CalibrationProfile`` per spec.

    ``comm_model(p)`` narrows the model-axis ring-collective calibration
    to the TP*SP footprint of ``p`` (capped at the topology's own (X, Y)
    rack plane, so the cap always matches the fabric being simulated) and
    the model-axis A2A calibration to the EP footprint (the
    ``compile_traffic_entry`` convention: up to two first-dim cliques) —
    so wide groups that can ride the cross-dim 2D multi-ring price
    differently from narrow ones, and EP volume is priced on the measured
    A2A number while TP/DP keep theirs.  The data axis is calibrated once
    over the full inter-rack plane.  Axes the netsim topology cannot
    measure (e.g. the HRS "pod" tier) keep their analytic cost.

    ``shapes`` selects what gets measured: the default is the full
    ``COLLECTIVE_SHAPES`` profile; ``("allreduce",)`` reproduces the
    PR-2-era AllReduce-proxy backend, where every collective is priced on
    the ring-calibrated scalar (useful as the baseline that shows why
    shape-aware pricing changes planner decisions).  ``rx_gbs`` is the
    receiver-egress (incast) cap handed to netsim ("auto" = the node's
    largest per-dim clique allocation).

    ``superpod`` unlocks multi-pod pricing: the "pod" axis — previously
    pinned to its analytic DCN cost because the chip-level pod topology
    cannot see the HRS tier — is calibrated on the **rack-coarsened**
    SuperPod mesh (``netsim/coarsen.py``: racks become super-nodes, the
    Clos tier an IO-capped extra dimension), so cross-pod DP/PP traffic
    is priced on measured multi-pod bandwidths.  The memo key gains the
    coarsening level (``coarsen_level``), so rack- and pod-granularity
    calibrations never alias.

    ``detail_racks`` (with ``superpod``) switches the MODEL-axis
    calibration from the isolated chip-level pod onto a
    **mixed-granularity** mesh: the named racks stay at chip granularity
    inside the rack-coarsened SuperPod, and the model-axis collectives
    are measured inside the embedded rack WHILE a cross-pod DP
    background AllReduce (``background_bytes`` per chip, default
    ``size_bytes``) crosses the same rack's trunk uplinks — so the
    planner finally sees model-axis interference from DCN traffic
    (ejection-port and uplink sharing), which both the pure-chip and
    pure-coarse calibrations miss by construction.  The memo key gains
    the ``detail_racks`` tuple and the background payload, so mixed and
    isolated model calibrations never alias.
    """

    base: CommModel
    topo: NDFullMesh = field(default_factory=ub_mesh_pod)
    size_bytes: float = 256e6
    latency_s: float = 1e-6
    pinned: dict[str, AxisCost] = field(default_factory=dict)
    shapes: tuple[str, ...] = COLLECTIVE_SHAPES
    rx_gbs: float | str | None = "auto"
    superpod: SuperPod | None = None
    coarsen_level: str = "rack"
    detail_racks: tuple[int, ...] = ()
    background_bytes: float | None = None

    def __post_init__(self) -> None:
        if self.detail_racks and self.superpod is None:
            # without a SuperPod there is no coarse mesh to embed the
            # detail racks in — silently falling back to the isolated
            # chip-level calibration would defeat the caller's intent
            raise ValueError(
                "detail_racks requires superpod= (the mixed-granularity "
                "mesh embeds the racks in the coarsened SuperPod)"
            )

    @property
    def backend(self) -> str:
        return "netsim"

    # -- calibration (memoized) -------------------------------------------
    def _calibrate(
        self, widths: dict[tuple[str, str], int | None]
    ) -> dict[tuple[str, str], float]:
        """(axis, shape) -> measured GB/s for the requested group widths,
        via the shared cross-instance cache; ``reduce_scatter`` aliases
        the ``all_gather`` measurement (same wire schedule).  "pod"-axis
        entries are measured on the rack-coarsened SuperPod mesh; their
        cache key carries the coarsening level and the SuperPod geometry
        instead of the chip-level topology key."""
        from ..netsim import NetSim  # deferred: core must not hard-require netsim

        key_base = (
            _topo_key(self.topo),
            self.base.routing.value,
            self.size_bytes,
            self.latency_s,
            self.rx_gbs,
        )
        coarse_tag = ()
        if self.superpod is not None:
            # the coarse capacities derive from the SuperPod's OWN pod
            # (trunk widths, racks per pod), which need not equal
            # self.topo — key on its geometry too so distinct SuperPods
            # never alias in the shared cache
            coarse_tag = (
                "coarse",
                self.coarsen_level,
                self.superpod.n_pods,
                self.superpod.uplink_lanes_per_rack,
                _topo_key(self.superpod.pod),
            )

        detail_tag = ()
        bg_bytes = (
            self.size_bytes if self.background_bytes is None
            else self.background_bytes
        )
        if self.superpod is not None and self.detail_racks:
            # mixed-granularity model-axis calibration: keyed on the
            # embedded racks AND the background payload so isolated and
            # interference-priced measurements never alias
            detail_tag = ("detail", tuple(self.detail_racks), bg_bytes)

        def key(axis: str, shape: str, w: int | None) -> tuple:
            if shape == "reduce_scatter":
                shape = "all_gather"
            if axis == "pod":
                return key_base + coarse_tag + (axis, shape, w)
            if axis == "model" and detail_tag:
                return key_base + coarse_tag + detail_tag + (axis, shape, w)
            return key_base + (axis, shape, w)

        missing = {
            (axis, shape): w
            for (axis, shape), w in widths.items()
            if key(axis, shape, w) not in _CALIBRATION_CACHE
        }
        _CALIBRATION_STATS["hits"] += len(widths) - len(missing)
        _CALIBRATION_STATS["misses"] += len(missing)
        pod_missing = {k: w for k, w in missing.items() if k[0] == "pod"}
        mixed_missing = {
            k: w for k, w in missing.items()
            if k[0] == "model" and detail_tag
        }
        chip_missing = {
            k: w for k, w in missing.items()
            if k[0] != "pod" and k not in mixed_missing
        }
        if chip_missing:
            sim = NetSim(
                self.topo,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
            )
            for (axis, shape), w in chip_missing.items():
                mshape = "all_gather" if shape == "reduce_scatter" else shape
                t0 = time.perf_counter()
                cal = sim.calibrated_profile(
                    self.size_bytes,
                    comm=self.base,
                    widths={} if w is None else {axis: w},
                    axes=(axis,),
                    shapes=(mshape,),
                )
                _record_measurement(axis, shape, w, time.perf_counter() - t0)
                # shapes netsim could not measure fall back to the analytic bw
                _CALIBRATION_CACHE[key(axis, shape, w)] = cal.get(
                    axis, mshape, self.base.axes[axis].gbs_per_chip
                )
        if pod_missing:
            from ..netsim.coarsen import (
                coarse_calibrated_profile,
                coarse_netsim,
                coarsen_superpod,
            )

            cm = coarsen_superpod(self.superpod, level=self.coarsen_level)
            csim = coarse_netsim(
                cm,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
            )
            for (axis, shape), w in pod_missing.items():
                mshape = "all_gather" if shape == "reduce_scatter" else shape
                t0 = time.perf_counter()
                cal = coarse_calibrated_profile(
                    cm,
                    self.size_bytes,
                    comm=self.base,
                    widths={} if w is None else {axis: w},
                    axes=(axis,),
                    shapes=(mshape,),
                    sim=csim,
                )
                _record_measurement(axis, shape, w, time.perf_counter() - t0)
                _CALIBRATION_CACHE[key(axis, shape, w)] = cal.get(
                    axis, mshape, self.base.axes[axis].gbs_per_chip
                )
        if mixed_missing:
            from ..netsim.coarsen import (
                coarsen_superpod,
                mixed_calibrated_profile,
                mixed_netsim,
            )

            cm = coarsen_superpod(
                self.superpod,
                level=self.coarsen_level,
                detail_racks=self.detail_racks,
            )
            msim = mixed_netsim(
                cm,
                routing=self.base.routing,
                latency_s=self.latency_s,
                rx_gbs=self.rx_gbs,
            )
            for (axis, shape), w in mixed_missing.items():
                mshape = "all_gather" if shape == "reduce_scatter" else shape
                t0 = time.perf_counter()
                cal = mixed_calibrated_profile(
                    cm,
                    self.size_bytes,
                    comm=self.base,
                    widths={} if w is None else {axis: w},
                    axes=(axis,),
                    shapes=(mshape,),
                    background_per_chip_bytes=bg_bytes,
                    sim=msim,
                )
                _record_measurement(axis, shape, w, time.perf_counter() - t0)
                _CALIBRATION_CACHE[key(axis, shape, w)] = cal.get(
                    axis, mshape, self.base.axes[axis].gbs_per_chip
                )
        return {
            (axis, shape): _CALIBRATION_CACHE[key(axis, shape, w)]
            for (axis, shape), w in widths.items()
        }

    def _widths(
        self, p: ParallelSpec | None
    ) -> dict[tuple[str, str], int | None]:
        """Calibration group width per measurable (axis, shape) for spec
        ``p``.  ``None`` means the shape's default group (full plane for
        ring collectives, the capped A2A footprint for all_to_all); widths
        that cover it are canonicalized to ``None`` so they share one
        cache entry."""
        widths: dict[tuple[str, str], int | None] = {}
        x = self.topo.shape[0]
        plane = x * (self.topo.shape[1] if self.topo.ndim > 1 else 1)
        if "model" in self.base.axes:
            for shape in self.shapes:
                if shape in ("allreduce", "all_gather", "reduce_scatter"):
                    w = None if p is None else p.tp * p.sp
                    widths[("model", shape)] = (
                        None if w is None or w >= plane else w
                    )
                elif shape == "all_to_all":
                    # EP footprint (compile_traffic_entry convention),
                    # canonicalized against the SAME cap the measurement
                    # group uses; an ep=1 spec has no A2A traffic to price
                    if p is not None and p.ep <= 1:
                        continue
                    cap = min(A2A_CALIBRATION_MAX_NODES, 2 * x, plane)
                    w = None if p is None else min(2 * p.ep, cap)
                    widths[("model", shape)] = (
                        None if w is None or w >= cap else w
                    )
                else:                           # p2p: width-independent
                    widths[("model", shape)] = None
        if "data" in self.base.axes and self.topo.ndim > 2:
            for shape in self.shapes:
                widths[("data", shape)] = None  # full inter-rack plane
        if self.superpod is not None and "pod" in self.base.axes:
            # HRS pod tier, measured on the rack-coarsened mesh; the
            # calibration ring spans the pod-axis group (spec-invariant:
            # the DP-across-pods footprint is the axis itself), capped at
            # the SuperPod's pod count
            w = min(self.base.axes["pod"].size, self.superpod.n_pods)
            for shape in self.shapes:
                if shape in _POD_SHAPES:
                    widths[("pod", shape)] = (
                        None if w >= self.superpod.n_pods else w
                    )
        return widths

    def calibration_profile(
        self, p: ParallelSpec | None = None
    ) -> CalibrationProfile:
        """The measured (axis, shape) profile resolved for spec ``p``
        (memoized; unclamped — ``comm_model`` clamps at the analytic
        bound when pricing)."""
        return CalibrationProfile(gbs=dict(self._calibrate(self._widths(p))))

    def comm_model(self, p: ParallelSpec | None = None) -> CommModel:
        comm = self.calibration_profile(p).apply(self.base, clamp=True)
        axes = dict(comm.axes)
        for name, a in self.pinned.items():
            axes[name] = a
        return CommModel(axes=axes, routing=self.base.routing)

    def override_axis(self, name: str, cost: AxisCost) -> "NetsimPerfModel":
        return replace(self, pinned={**self.pinned, name: cost})
