"""AdamW with ZeRO-1 sharded optimizer state + mixed precision.

Storage layout (the distributed-optimization core):

* params   — bf16, replicated over the DP axes (model-sharded dims only)
* masters  — fp32, ZeRO-1 sharded over ("pod","data") via
             ``parallel.sharding.zero1_pspec``
* m, v     — fp32, ZeRO-1 sharded likewise

With these in/out shardings, GSPMD lowers the update into exactly the
paper-faithful schedule: bf16 gradient reduce(-scatter) over the DP axes,
sharded Adam update, param all-gather back — the Multi-Ring hierarchical
AllReduce's compiled form.  (fp32-grad baseline available via
``OptConfig.grad_dtype`` for the §Perf before/after.)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.param import ParamSpec, is_spec


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0
    grad_dtype: Any = jnp.bfloat16     # payload dtype of the DP reduction


def opt_state_specs(param_specs) -> dict:
    """ParamSpec tree for the optimizer state (fp32 masters + moments)."""

    def f32(s: ParamSpec, init: str) -> ParamSpec:
        return ParamSpec(s.shape, s.logical, init=init, dtype=jnp.float32)

    return {
        "master": jax.tree.map(lambda s: f32(s, s.init), param_specs, is_leaf=is_spec),
        "m": jax.tree.map(lambda s: f32(s, "zeros"), param_specs, is_leaf=is_spec),
        "v": jax.tree.map(lambda s: f32(s, "zeros"), param_specs, is_leaf=is_spec),
        "step": ParamSpec((), (), init="zeros", dtype=jnp.int32),
    }


def init_opt_state(params) -> dict:
    return {
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def apply(
    cfg: OptConfig, params, grads, state: dict
) -> tuple[Any, dict, dict]:
    """One AdamW update.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, state["step"])
    grads = jax.tree.map(lambda g: g.astype(cfg.grad_dtype), grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        )
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)

    params_dtype = jax.tree.leaves(params)[0].dtype
    new_params = treedef.unflatten([w.astype(params_dtype) for w in new_w])
    new_state = {
        "master": treedef.unflatten(new_w),
        "m": treedef.unflatten(new_m),
        "v": treedef.unflatten(new_v),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
