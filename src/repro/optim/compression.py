"""Gradient compression with error feedback (distributed-optimization trick).

Two modes, both usable as drop-in transforms around the DP reduction:

* ``bf16``  — cast the reduction payload to bf16 (2x wire-byte cut; visible
  in the dry-run's collective bytes).  No error feedback needed in practice.
* ``int8``  — QSGD-style symmetric per-tensor quantization WITH an error-
  feedback residual carried in the optimizer state.  NOTE: inside a single
  jit, GSPMD's all-reduce payload dtype follows the tensor dtype at the
  collective; int8 ring-summation needs a widened accumulator, so the wire
  format here is int8 quantize -> fp32 reduce of the dequantized value.
  The *model-quality* semantics (quantization noise + error feedback) are
  exact; the wire-byte saving is modeled in the cost model and realized by
  the CCU-style Pallas reduce kernel (kernels/ccu_reduce.py) on real HW.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    mode: str = "none"          # none | bf16 | int8
    ef: bool = True             # error feedback (int8 mode)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(cfg: CompressionConfig, grads, residual=None):
    """Returns (payload_grads, new_residual).

    int8: g' = Q(g + residual); residual' = (g + residual) - deQ(g')
    """
    if cfg.mode == "none":
        return grads, residual
    if cfg.mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), residual
    if cfg.mode != "int8":
        raise ValueError(cfg.mode)

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def q(g, r):
        acc = g.astype(jnp.float32) + (r if cfg.ef else 0.0)
        qq, scale = quantize_int8(acc)
        deq = dequantize_int8(qq, scale)
        new_r = acc - deq if cfg.ef else jnp.zeros_like(acc)
        return deq, new_r

    pairs = jax.tree.map(q, grads, residual)
    payload = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return payload, new_res


def wire_bytes_factor(cfg: CompressionConfig) -> float:
    """Payload-size multiplier vs fp32 — feeds the comm cost model."""
    return {"none": 1.0, "bf16": 0.5, "int8": 0.25}[cfg.mode]
