"""Jitted train/serve step builders with full sharding annotations.

These are the functions the launcher jits and the dry-run lowers.  The
in/out shardings come from the harness's ParamSpec logical axes + the
topology-aware rules (parallel/sharding.py); optimizer state uses the
ZeRO-1 pspecs.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.api import Harness, ShapeCell
from repro.models.layers import Runtime
from repro.models.param import (
    ShardingRules,
    is_spec,
    tree_abstract,
    tree_pspecs,
)
from repro.optim import adamw
from repro.optim.compression import CompressionConfig, compress_grads
from repro.parallel.sharding import rules_for_cell, tree_zero1_pspecs


@dataclass
class StepBundle:
    """Everything needed to jit/lower one (arch x shape x mesh) cell."""

    fn: Callable
    in_shardings: Any
    out_shardings: Any
    abstract_args: tuple
    donate_argnums: tuple = ()


def _shardings(mesh: Mesh, pspec_tree):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_train_step(
    harness: Harness,
    cell: ShapeCell,
    mesh: Mesh,
    *,
    multi_pod: bool = False,
    opt_cfg: adamw.OptConfig | None = None,
    compression: CompressionConfig | None = None,
    rules: ShardingRules | None = None,
) -> StepBundle:
    opt_cfg = opt_cfg or adamw.OptConfig()
    compression = compression or CompressionConfig()
    rules = rules or rules_for_cell(harness, cell, multi_pod=multi_pod)
    rt = Runtime(rules=rules)
    loss_fn = harness.loss(rt)
    dp_size = 32 if multi_pod else 16

    param_specs = harness.param_specs()
    opt_specs = adamw.opt_state_specs(param_specs)
    input_specs = harness.train_input_specs(cell)

    param_ps = tree_pspecs(param_specs, rules)
    opt_ps = {
        "master": tree_zero1_pspecs(param_specs, rules, dp_size),
        "m": tree_zero1_pspecs(param_specs, rules, dp_size),
        "v": tree_zero1_pspecs(param_specs, rules, dp_size),
        "step": P(),
    }
    input_ps = tree_pspecs(input_specs, rules)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, _ = compress_grads(compression, grads)
        new_params, new_opt, metrics = adamw.apply(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    abstract = (
        tree_abstract(param_specs, dtype=jnp.bfloat16),
        tree_abstract(opt_specs),
        tree_abstract(input_specs),
    )
    in_sh = (
        _shardings(mesh, param_ps),
        _shardings(mesh, opt_ps),
        _shardings(mesh, input_ps),
    )
    out_sh = (
        _shardings(mesh, param_ps),
        _shardings(mesh, opt_ps),
        None,
    )
    return StepBundle(
        fn=train_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_args=abstract,
        donate_argnums=(0, 1),
    )


def build_serve_step(
    harness: Harness,
    cell: ShapeCell,
    mesh: Mesh,
    *,
    multi_pod: bool = False,
    rules: ShardingRules | None = None,
) -> StepBundle:
    """Prefill (cell.kind == 'prefill') or decode step bundle."""
    rules = rules or rules_for_cell(harness, cell, multi_pod=multi_pod)
    rt = Runtime(rules=rules)

    param_specs = harness.param_specs()
    state_specs = harness.serve_state_specs(cell)
    input_specs = harness.serve_input_specs(cell)

    param_ps = tree_pspecs(param_specs, rules)
    state_ps = tree_pspecs(state_specs, rules)
    input_ps = tree_pspecs(input_specs, rules)

    if cell.kind == "prefill":
        inner = harness.prefill(rt)
    else:
        inner = harness.decode(rt)

    def serve_step(params, state, inputs):
        logits, new_state = inner(params, state, **inputs)
        return logits, new_state

    abstract = (
        tree_abstract(param_specs, dtype=jnp.bfloat16),
        tree_abstract(state_specs),
        tree_abstract(input_specs),
    )
    in_sh = (
        _shardings(mesh, param_ps),
        _shardings(mesh, state_ps),
        _shardings(mesh, input_ps),
    )
    out_sh = (None, _shardings(mesh, state_ps))
    return StepBundle(
        fn=serve_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        abstract_args=abstract,
        donate_argnums=(1,),
    )


def build_bundle(harness, cell: ShapeCell, mesh, *, multi_pod: bool, **kw) -> StepBundle:
    if cell.kind == "train":
        return build_train_step(harness, cell, mesh, multi_pod=multi_pod, **kw)
    return build_serve_step(harness, cell, mesh, multi_pod=multi_pod)


def lower_bundle(bundle: StepBundle, mesh: Mesh):
    """jit().lower() under the mesh — the dry-run entry point."""
    jitted = jax.jit(
        bundle.fn,
        in_shardings=bundle.in_shardings,
        out_shardings=bundle.out_shardings,
        donate_argnums=bundle.donate_argnums,
    )
    with mesh:
        return jitted.lower(*bundle.abstract_args)
