import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count on first init).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell, both meshes

Each cell writes ``results/dryrun/<arch>__<shape>__<mesh>.json`` with
memory_analysis, cost_analysis, collective wire bytes, and roofline terms.
"""

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, load
from repro.launch.hlo_stats import Roofline, collective_stats
from repro.launch.mesh import make_production_mesh
from repro.models.api import SHAPES
from repro.models.param import param_count
from repro.train.train_step import build_bundle, lower_bundle

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def analytic_model_flops(harness, cell) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), per device."""
    n_params = param_count(harness.param_specs())
    cfg = harness.cfg
    moe = getattr(cfg, "moe", None)
    if moe is not None:
        # embedding + attention stay dense; experts scale by topk/E
        expert_frac = 0.0
        from repro.models.moe import moe_specs

        expert_params = param_count(moe_specs(cfg.d_model, moe)) * cfg.n_layers
        active = n_params - expert_params + expert_params * moe.topk / moe.n_experts
    else:
        active = n_params
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        total = 6.0 * active * tokens
    elif cell.kind == "prefill":
        total = 2.0 * active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * active * cell.global_batch
    return total


def _cost_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` to one flat dict.

    Depending on the jax/XLA version the call returns a dict, a list of
    per-device dicts (we want device 0: SPMD devices are identical), or
    None when analysis is unavailable.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return dict(cost) if cost else {}


def _probe_metrics(harness, cell, mesh, multi_pod) -> dict:
    """Compile one UNROLLED probe and return its per-device counters.

    XLA's cost analysis visits while-loop (lax.scan) bodies once, so the
    official scanned compile undercounts FLOPs/bytes/collectives by the trip
    count.  Probes unroll all loops at reduced depth/length, then the caller
    extrapolates with the known cost structure (linear in layers; linear in
    chunks for SSM scans; attention's S^2 captured exactly at full S or via
    a quadratic fit for the hybrid's shared block).
    """
    bundle = build_bundle(harness, cell, mesh, multi_pod=multi_pod)
    compiled = lower_bundle(bundle, mesh).compile()
    cost = _cost_dict(compiled)
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm": float(cost.get("bytes accessed", 0.0)),
        "wire": float(coll.wire_bytes),
        "ops": coll.count,
        "by_kind": dict(coll.by_kind),
    }


def _probe_cell(cell, seq_len):
    import dataclasses

    return dataclasses.replace(cell, seq_len=seq_len)


def extrapolated_metrics(harness, cell, mesh, multi_pod) -> dict:
    """Per-device (flops, hbm bytes, wire bytes) at the FULL (L, S)."""
    fam = harness.family
    keys = ("flops", "hbm", "wire")

    def probe(L, S=None, **extra):
        h = harness.clone(n_layers=L, unroll=True, **extra)
        c = cell if S is None else _probe_cell(cell, S)
        return _probe_metrics(h, c, mesh, multi_pod)

    if fam in ("dense", "moe", "vlm", "audio"):
        L_full = harness.cfg.n_layers
        f1, f2 = probe(1), probe(2)
        out = {k: f1[k] + (L_full - 1) * (f2[k] - f1[k]) for k in keys}
        out["by_kind"] = {
            kk: f1["by_kind"].get(kk, 0.0)
            + (L_full - 1) * (f2["by_kind"].get(kk, 0.0) - f1["by_kind"].get(kk, 0.0))
            for kk in set(f1["by_kind"]) | set(f2["by_kind"])
        }
        return out

    if fam == "ssm":
        L_full, S_full = harness.cfg.n_layers, cell.seq_len
        if cell.kind == "decode":
            f1, f2 = probe(1), probe(2)
            return {k: f1[k] + (L_full - 1) * (f2[k] - f1[k]) for k in keys}
        S0 = min(256, S_full)
        pts = {(L, S): probe(L, S) for L in (1, 2) for S in (S0, 2 * S0)}
        out = {}
        for k in keys:
            P1 = pts[(2, S0)][k] - pts[(1, S0)][k]       # per-layer @ S0
            P2 = pts[(2, 2 * S0)][k] - pts[(1, 2 * S0)][k]
            p1 = (P2 - P1) / S0
            p0 = P1 - S0 * p1
            E1 = pts[(1, S0)][k] - P1
            E2 = pts[(1, 2 * S0)][k] - P2
            e1 = (E2 - E1) / S0
            e0 = E1 - S0 * e1
            out[k] = e0 + e1 * S_full + L_full * (p0 + p1 * S_full)
        return out

    if fam == "hybrid":
        # F(L, S) = E(S) + n_mamba(L) * M(S) + n_shared(L) * A(S)
        # probes L in {6, 7, 8}: n_shared = 0, 1, 1 so
        #   M = F8 - F7,  A = (F7 - F6) - M,  E = F6 - 6M
        L_full = harness.cfg.n_layers
        S_full = cell.seq_len
        n_shared_full = sum(
            1
            for d in range(1, L_full)
            if d % harness.cfg.share_every == 0
        )

        def solve(S=None):
            f6, f7, f8 = probe(6, S), probe(7, S), probe(8, S)
            sol = {}
            for k in keys:
                M = f8[k] - f7[k]
                A = (f7[k] - f6[k]) - M
                E = f6[k] - 6 * M
                sol[k] = (E, M, A)
            return sol

        if cell.kind == "decode":
            sol = solve()
            return {
                k: sol[k][0] + L_full * sol[k][1] + n_shared_full * sol[k][2]
                for k in keys
            }
        Ss = [s for s in (256, 512, 1024) if s <= S_full] or [S_full]
        sols = {S: solve(S) for S in Ss}
        import numpy as np

        out = {}
        for k in keys:
            Es = np.array([sols[S][k][0] for S in Ss])
            Ms = np.array([sols[S][k][1] for S in Ss])
            As = np.array([sols[S][k][2] for S in Ss])
            Sv = np.array(Ss, dtype=float)
            ce = np.polyfit(Sv, Es, min(1, len(Ss) - 1))
            cm = np.polyfit(Sv, Ms, min(1, len(Ss) - 1))
            ca = np.polyfit(Sv, As, min(2, len(Ss) - 1))
            E = float(np.polyval(ce, S_full))
            M = float(np.polyval(cm, S_full))
            A = float(np.polyval(ca, S_full))
            out[k] = E + L_full * M + n_shared_full * A
        return out

    raise ValueError(f"unknown family {fam}")


def run_cell(arch: str, shape: str, multi_pod: bool, probes: bool = True) -> dict:
    harness = load(arch)
    cell = SHAPES[shape]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "kind": cell.kind,
        "status": "ok",
    }
    skip = harness.skip_reason(shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec

    chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)

    # ---- official artifact: the scanned full-depth program ----------------
    t0 = time.time()
    bundle = build_bundle(harness, cell, mesh, multi_pod=multi_pod)
    lowered = lower_bundle(bundle, mesh)
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_per_device_gb": round(
            (mem.argument_size_in_bytes + mem.output_size_in_bytes
             + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 1e9, 3
        ),
    }

    # ---- cost counters: probe-extrapolated (see _probe_metrics docstring) -
    t2 = time.time()
    if probes:
        metrics = extrapolated_metrics(harness, cell, mesh, multi_pod)
    else:
        cost = _cost_dict(compiled)
        coll = collective_stats(compiled.as_text())
        metrics = {
            "flops": float(cost.get("flops", 0.0)),
            "hbm": float(cost.get("bytes accessed", 0.0)),
            "wire": float(coll.wire_bytes),
        }
        rec["counters"] = "scanned-only (loop bodies counted once; LOWER BOUND)"
    rec["probe_s"] = round(time.time() - t2, 1)

    model_flops_total = analytic_model_flops(harness, cell)
    roof = Roofline(
        flops=metrics["flops"],
        hbm_bytes=metrics["hbm"],
        wire_bytes=metrics["wire"],
        model_flops=model_flops_total / chips,
    )
    rec["cost"] = {
        "flops_per_device": metrics["flops"],
        "hbm_bytes_per_device": metrics["hbm"],
    }
    rec["collectives"] = {
        "wire_bytes_per_device": metrics["wire"],
        "by_kind": metrics.get("by_kind", {}),
    }
    rec["roofline"] = roof.to_dict()
    rec["params"] = param_count(harness.param_specs())
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                cells.append((arch, shape, True))
    else:
        arches = [args.arch] if args.arch else ARCH_IDS
        shapes = [args.shape] if args.shape else list(SHAPES)
        meshes = [args.multi_pod] if not args.both_meshes else [False, True]
        for a in arches:
            for s in shapes:
                for m in meshes:
                    cells.append((a, s, m))

    failures = 0
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        out = RESULTS / f"{arch.replace('-', '_')}__{shape}__{mesh_name}.json"
        if out.exists() and not args.force:
            rec = json.loads(out.read_text())
            if rec.get("status") in ("ok", "skipped"):
                print(f"[dryrun] {arch:16s} {shape:12s} {mesh_name:10s} cached",
                      flush=True)
                continue
        try:
            rec = run_cell(arch, shape, mp, probes=not args.no_probes)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rec = {
                "arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
            }
            failures += 1
        out.write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (
                f" mem={rec['memory']['peak_per_device_gb']}GB"
                f" flops/dev={rec['cost']['flops_per_device']:.3e}"
                f" wire/dev={rec['collectives']['wire_bytes_per_device']:.3e}B"
                f" bottleneck={rec['roofline']['bottleneck']}"
                f" compile={rec['compile_s']}s"
            )
        elif status == "skipped":
            extra = f" ({rec['reason'][:60]})"
        print(f"[dryrun] {arch:16s} {shape:12s} {mesh_name:10s} {status}{extra}",
              flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
