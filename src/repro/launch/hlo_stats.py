"""HLO analysis: collective-traffic + roofline terms from compiled modules.

``collective_stats`` parses the (compiled) HLO text and accounts every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute:
per-device WIRE bytes under ring-algorithm conventions:

    all-reduce      2 (n-1)/n * bytes(result)
    all-gather        (n-1)/n * bytes(result)
    reduce-scatter    (n-1)/n * bytes(operand) = (n-1) * bytes(result)
    all-to-all        (n-1)/n * bytes(result)
    collective-permute            bytes(result)

Group size n comes from replica_groups (explicit lists or iota form).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Total bytes of a result signature like 'f32[16,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                       # per-device, ring conv.
    by_kind: dict = field(default_factory=lambda: defaultdict(float))
    count: int = 0
    ops: list = field(default_factory=list)

    def add(self, kind: str, bytes_: float, n: int):
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / max(n, 1) * bytes_
        elif kind in ("all-gather", "all-to-all"):
            wire = (n - 1) / max(n, 1) * bytes_
        elif kind == "reduce-scatter":
            wire = (n - 1) * bytes_          # bytes_ is the (scattered) result
        else:  # collective-permute
            wire = bytes_
        self.wire_bytes += wire
        self.by_kind[kind] += wire
        self.count += 1


def collective_stats(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        # `%name = <sig> <op>(...)` — find which collective op this is
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", s):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in s:
            continue  # avoid double counting async pairs
        lhs, rhs = s.split("=", 1)
        sig = rhs.strip().split(" ")[0]
        bytes_ = _shape_bytes(sig)
        if bytes_ == 0:
            continue
        n = _group_size(s, default_group)
        stats.add(kind, float(bytes_), n)
        stats.ops.append((kind, bytes_, n))
    return stats


# ---------------------------------------------------------------------------
# Roofline terms (hardware constants per harness spec: TPU v5e-class)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link; model axis rides the intra-rack
                             # multi-ring (DESIGN.md §2), ~1 link per chip


@dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    wire_bytes: float            # per-device collective wire bytes
    model_flops: float = 0.0     # analytic 6*N*D (or 6*N_active*D)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }
