"""Production mesh construction (harness-specified shapes).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Axes map onto the UB-Mesh hierarchy:
"model" = intra-rack 2D-FullMesh (high-bandwidth TP/SP domain),
"data"  = inter-rack 2D-FullMesh, "pod" = HRS Clos tier (DESIGN.md §2/§5).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (requires that many host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
