"""Batched serving driver: prefill + decode loop with KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import load
from repro.models.api import ShapeCell
from repro.models.layers import Runtime
from repro.models.param import tree_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    harness = load(args.arch, smoke=args.smoke)
    cfg = harness.cfg
    rt = Runtime(rules=None)
    key = jax.random.PRNGKey(0)
    params = tree_init(harness.param_specs(), key, dtype=jnp.bfloat16)

    max_len = args.prompt_len + args.gen + 8
    cell = ShapeCell("serve", "decode", max_len, args.batch)
    state = tree_init(harness.serve_state_specs(cell), key)

    prefill = jax.jit(harness.prefill(rt))
    decode = jax.jit(harness.decode(rt))

    rng = np.random.default_rng(0)
    vocab = cfg.vocab_size
    prompts = jnp.asarray(
        rng.integers(0, vocab, size=(args.batch, args.prompt_len), dtype=np.int32)
    )

    t0 = time.time()
    if harness.family == "audio":
        frames = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)
        logits, state = prefill(params, state, frames, prompts)
    else:
        logits, state = prefill(params, state, prompts)
    t_prefill = time.time() - t0

    def sample(logits, key):
        lg = logits[:, -1, :vocab].astype(jnp.float32)
        if args.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / args.temperature).astype(jnp.int32)

    tok = sample(logits, key)
    out_tokens = [tok]
    t1 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, state = decode(params, state, tok[:, None], pos)
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)
        out_tokens.append(tok)
    t_decode = time.time() - t1

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve] arch={args.arch} batch={args.batch} "
          f"prefill={t_prefill*1e3:.0f}ms "
          f"decode={t_decode/max(args.gen-1,1)*1e3:.1f}ms/tok")
    print(f"[serve] generated token ids (first row): {gen[0][:16].tolist()}")
    assert gen.shape == (args.batch, args.gen)
    assert np.all(gen >= 0) and np.all(gen < vocab)


if __name__ == "__main__":
    main()
