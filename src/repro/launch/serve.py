"""Batched serving: a real prefill+decode driver and an SLO-driven
decode-serving simulator.

Two layers share this module:

* ``main()`` — the executable serving loop over the real model harness
  (prefill + KV-cache decode with sampled tokens)::

      PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \\
          --batch 4 --prompt-len 32 --gen 16        # --no-smoke for full

* the **decode-serving simulator** — ``decode_step_s`` prices one
  continuous-batching decode step on a UB-Mesh rack under either backend
  (bandwidth-calibrated analytic pricing vs the message-level latency
  profile), ``simulate_decode_serving`` runs Poisson arrivals through a
  continuous-batching server at that step time, and ``plan_decode``
  searches ``core.planner.enumerate_decode_specs`` for (a) the
  bandwidth-optimal sharding and (b) the sharding that actually meets a
  p99 token-latency SLO at a target QPS.  The two disagree on real
  configs: bandwidth pricing inherits the analytic model's pinned axis
  width, so its per-token collective cost is spec-invariant and maximum
  TP always wins (smallest weight shard to stream); the measured latency
  profile pays 2(w-1) ring steps for a width-``w`` group, which makes
  the widest group the slowest per token and pushes the SLO choice to a
  narrower TP x wider DP sharding.

Everything simulator-side is importable without jax (the model-harness
imports are deferred into ``main``), so benchmarks and planners can load
it in environments where the accelerator stack is absent.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

# effective HBM streaming bandwidth during decode (GB/s per chip): decode
# is weight-streaming-bound, so the per-step compute floor is
# local_param_bytes / (DECODE_HBM_GBS * 1e9)
DECODE_HBM_GBS = 1600.0

# payload the latency profile is calibrated at: one decode step's
# per-layer TP AllReduce moves O(batch x hidden) activation bytes — tens
# of KB, squarely in the latency-dominated regime
DECODE_MSG_BYTES = 64e3


# ---------------------------------------------------------------------------
# Decode step pricing
# ---------------------------------------------------------------------------


def decode_comm_bytes(w, batch: int) -> float:
    """Per-layer TP AllReduce payload of one decode step: the batch's
    activation row (batch x hidden, bf16)."""
    return float(batch) * w.hidden * w.bytes_per_elem


def decode_step_s(
    w,
    p,
    perf,
    *,
    batch: int = 8,
    pricing: str = "bandwidth",
    msg_bytes: float = DECODE_MSG_BYTES,
) -> float:
    """One continuous-batching decode step (seconds) for workload ``w``
    sharded as ``p`` — HBM weight streaming plus per-layer TP collectives.

    ``pricing`` selects the communication backend:

    * ``"bandwidth"`` — ``perf.comm_model(p)``'s closed-form AllReduce
      cost at the decode payload.  The analytic latency term rides the
      CommModel's pinned axis width, so it is (nearly) spec-invariant.
    * ``"latency"`` — the measured message-level profile
      (``perf.latency_profile(p)``): each collective costs its measured
      makespan ``total_s`` at the calibrated decode payload, which scales
      with the spec's REAL group width.  Requires a backend exposing
      ``latency_profile`` (``core.perf_model.NetsimPerfModel``).
    """
    shard = max(1, p.tp * p.sp * p.pp)
    params_bytes = w.params_total * w.bytes_per_elem
    t_hbm = (params_bytes / shard) / (DECODE_HBM_GBS * 1e9)

    group_w = p.tp * p.sp
    if group_w <= 1:
        return t_hbm
    n_coll = 2 * w.n_layers          # attention out-proj + MLP down-proj
    if pricing == "latency":
        if not hasattr(perf, "latency_profile"):
            raise TypeError(
                f"pricing='latency' needs a latency-calibrated backend "
                f"(got {type(perf).__name__})"
            )
        prof = perf.latency_profile(p, size_bytes=msg_bytes)
        st = prof.get("model", "allreduce")
        if st is None:
            raise ValueError("latency profile has no model-axis allreduce")
        t_coll = st.total_s
    elif pricing == "bandwidth":
        comm = perf.comm_model(p)
        t_coll = comm.allreduce("model", decode_comm_bytes(w, batch))
    else:
        raise ValueError(f"unknown pricing {pricing!r}")
    return t_hbm + n_coll * t_coll


# ---------------------------------------------------------------------------
# Continuous-batching serving simulator
# ---------------------------------------------------------------------------


def simulate_decode_serving(
    step_s: float,
    *,
    qps: float,
    slots: int,
    gen_tokens: int = 64,
    duration_s: float = 20.0,
    seed: int = 0,
    slo_s: float | None = None,
) -> dict:
    """Poisson request arrivals through a continuous-batching decode
    server: ``slots`` concurrent sequences (batch x DP replicas), one
    token per occupied slot per ``step_s``.

    Token latency is the inter-token gap for steady-state tokens and
    (admission wait + one step) for a request's first token — so queueing
    under load shows up where it hurts, in the p99.  Deterministic for a
    given ``seed``.  Returns p50/p99/mean token latency, aggregate
    tokens/s, slot utilization and (when ``slo_s`` is given) SLO
    attainment.
    """
    if step_s <= 0 or qps <= 0 or slots <= 0:
        raise ValueError("step_s, qps and slots must be positive")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, size=max(16, int(qps * duration_s * 2)))
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration_s]

    lat: list[float] = []            # first-token latencies (wait + 1 step)
    queue: list[float] = []          # arrival times, FIFO
    active: list[int] = []           # remaining tokens per occupied slot
    nxt = 0                          # next arrival index
    t = 0.0
    busy_slot_steps = 0
    total_steps = 0
    while nxt < len(arrivals) or queue or active:
        if not queue and not active:
            # idle: jump to the next arrival's step boundary
            t = max(t, float(arrivals[nxt]))
        while nxt < len(arrivals) and arrivals[nxt] <= t:
            queue.append(float(arrivals[nxt]))
            nxt += 1
        t_end = t + step_s
        # admit waiting requests into free slots; their first token lands
        # at the end of this step and carries the admission wait
        while queue and len(active) < slots:
            arr = queue.pop(0)
            active.append(gen_tokens)
            lat.append(t_end - arr)
        busy_slot_steps += len(active)
        total_steps += 1
        active = [r - 1 for r in active if r > 1]
        t = t_end
        if total_steps > 10_000_000:
            raise RuntimeError("serving simulation runaway")

    # steady-state tokens: each admitted request emits gen_tokens total,
    # the first is in ``lat`` already, the rest cost exactly step_s each
    n_requests = len(lat)
    n_steady_tokens = n_requests * (gen_tokens - 1)
    samples = np.concatenate([
        np.asarray(lat, dtype=float),
        np.full(n_steady_tokens, step_s, dtype=float),
    ]) if n_steady_tokens else np.asarray(lat, dtype=float)
    total_tokens = len(samples)
    out = {
        "step_s": step_s,
        "qps": qps,
        "slots": slots,
        "requests": n_requests,
        "tokens": int(total_tokens),
        "makespan_s": t,
        "tokens_per_s": float(total_tokens / t) if t else 0.0,
        "utilization": (
            busy_slot_steps / (total_steps * slots) if total_steps else 0.0
        ),
        "p50_s": float(np.percentile(samples, 50)) if total_tokens else 0.0,
        "p99_s": float(np.percentile(samples, 99)) if total_tokens else 0.0,
        "mean_s": float(samples.mean()) if total_tokens else 0.0,
    }
    if slo_s is not None:
        out["slo_s"] = slo_s
        out["attainment"] = (
            float((samples <= slo_s).mean()) if total_tokens else 1.0
        )
    return out


# ---------------------------------------------------------------------------
# SLO-driven decode planning
# ---------------------------------------------------------------------------


def plan_decode(
    w,
    chips: int,
    perf,
    *,
    qps: float,
    slo_s: float,
    batch: int = 8,
    gen_tokens: int = 64,
    duration_s: float = 20.0,
    seed: int = 0,
    max_tp: int = 64,
    msg_bytes: float = DECODE_MSG_BYTES,
) -> dict:
    """Search decode shardings of ``chips`` for workload ``w`` against a
    p99 token-latency SLO at a target request rate.

    Every candidate from ``enumerate_decode_specs`` is priced twice —
    ``pricing="bandwidth"`` (the classic throughput objective) and
    ``pricing="latency"`` (the measured message-level profile) — and the
    latency-priced step time drives a serving simulation at ``qps``.

    Returns ``{"candidates": [...], "bandwidth_choice": spec-dict,
    "slo_choice": spec-dict, "diverged": bool}``: ``bandwidth_choice``
    minimizes the bandwidth-priced step time; ``slo_choice`` maximizes
    simulated throughput among specs whose simulated p99 meets ``slo_s``
    (falling back to the lowest-p99 spec when none do).
    """
    from ..core.planner import enumerate_decode_specs

    specs = enumerate_decode_specs(w, chips, max_tp=max_tp)
    if not specs:
        raise ValueError(
            f"no feasible decode sharding of {chips} chips for {w.name}"
        )
    candidates = []
    for p in specs:
        step_bw = decode_step_s(
            w, p, perf, batch=batch, pricing="bandwidth", msg_bytes=msg_bytes
        )
        step_lat = decode_step_s(
            w, p, perf, batch=batch, pricing="latency", msg_bytes=msg_bytes
        )
        serving = simulate_decode_serving(
            step_lat,
            qps=qps,
            slots=batch * p.dp,
            gen_tokens=gen_tokens,
            duration_s=duration_s,
            seed=seed,
            slo_s=slo_s,
        )
        candidates.append({
            "tp": p.tp,
            "dp": p.dp,
            "step_bandwidth_s": step_bw,
            "step_latency_s": step_lat,
            "p50_s": serving["p50_s"],
            "p99_s": serving["p99_s"],
            "tokens_per_s": serving["tokens_per_s"],
            "attainment": serving["attainment"],
            "meets_slo": serving["p99_s"] <= slo_s,
        })

    bw_choice = min(candidates, key=lambda c: c["step_bandwidth_s"])
    meeting = [c for c in candidates if c["meets_slo"]]
    if meeting:
        slo_choice = max(meeting, key=lambda c: c["tokens_per_s"])
    else:
        slo_choice = min(candidates, key=lambda c: c["p99_s"])
    return {
        "workload": w.name,
        "chips": chips,
        "qps": qps,
        "slo_s": slo_s,
        "batch": batch,
        "candidates": candidates,
        "bandwidth_choice": bw_choice,
        "slo_choice": slo_choice,
        "diverged": (bw_choice["tp"], bw_choice["dp"])
        != (slo_choice["tp"], slo_choice["dp"]),
    }


def rack_perf_model(cache_dir: "str | None" = "auto"):
    """The serving-default latency-calibrated backend: the production
    CommModel measured on one UB-Mesh rack (the 8x8 plane decode TP
    groups live in)."""
    from ..core.cost_model import build_comm_model
    from ..core.perf_model import NetsimPerfModel
    from ..core.topology import ub_mesh_rack

    return NetsimPerfModel(
        base=build_comm_model(),
        topo=ub_mesh_rack(),
        cache_dir=cache_dir,
    )


# ---------------------------------------------------------------------------
# Real-model serving driver
# ---------------------------------------------------------------------------


def main():
    import jax
    import jax.numpy as jnp

    from repro.configs import load
    from repro.models.api import ShapeCell
    from repro.models.layers import Runtime
    from repro.models.param import tree_init

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument(
        "--smoke", action=argparse.BooleanOptionalAction, default=True,
        help="shrunken config (default; --no-smoke for the full arch)",
    )
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    harness = load(args.arch, smoke=args.smoke)
    cfg = harness.cfg
    rt = Runtime(rules=None)
    # independent streams for params, serve state and sampling — reusing
    # one key would correlate weight init with KV-state init and make the
    # first sampled token share the params' randomness
    key = jax.random.PRNGKey(args.seed)
    key, params_key, state_key = jax.random.split(key, 3)
    params = tree_init(harness.param_specs(), params_key, dtype=jnp.bfloat16)

    max_len = args.prompt_len + args.gen + 8
    cell = ShapeCell("serve", "decode", max_len, args.batch)
    state = tree_init(harness.serve_state_specs(cell), state_key)

    prefill = jax.jit(harness.prefill(rt))
    decode = jax.jit(harness.decode(rt))

    rng = np.random.default_rng(args.seed)
    vocab = cfg.vocab_size
    prompts = jnp.asarray(
        rng.integers(0, vocab, size=(args.batch, args.prompt_len), dtype=np.int32)
    )

    t0 = time.time()
    if harness.family == "audio":
        frames = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)
        logits, state = prefill(params, state, frames, prompts)
    else:
        logits, state = prefill(params, state, prompts)
    t_prefill = time.time() - t0

    def sample(logits, key):
        lg = logits[:, -1, :vocab].astype(jnp.float32)
        if args.temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / args.temperature).astype(jnp.int32)

    key, sub = jax.random.split(key)
    tok = sample(logits, sub)
    out_tokens = [tok]
    t1 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, state = decode(params, state, tok[:, None], pos)
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)
        out_tokens.append(tok)
    t_decode = time.time() - t1

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve] arch={args.arch} batch={args.batch} "
          f"prefill={t_prefill*1e3:.0f}ms "
          f"decode={t_decode/max(args.gen-1,1)*1e3:.1f}ms/tok")
    print(f"[serve] generated token ids (first row): {gen[0][:16].tolist()}")
    assert gen.shape == (args.batch, args.gen)
    assert np.all(gen >= 0) and np.all(gen < vocab)


if __name__ == "__main__":
    main()
