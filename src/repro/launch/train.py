"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On the CPU container this trains reduced configs (one device, rules=None);
on a real cluster the same driver jits with the production mesh + rules
(--production).  Features: ZeRO-1 AdamW, checkpoint/restart (resumes from
the latest step automatically), fault-tolerant supervision hooks, gradient
compression, --auto-parallel (plans via the §5.2 topology-aware planner and
logs the chosen spec).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import load
from repro.data.pipeline import DataConfig, Pipeline, SyntheticSource
from repro.models.api import ShapeCell
from repro.models.layers import Runtime
from repro.models.param import tree_init
from repro.optim import adamw
from repro.optim.compression import CompressionConfig
from repro.runtime.fault_tolerance import TrainingSupervisor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none", choices=["none", "bf16", "int8"])
    ap.add_argument("--auto-parallel", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    harness = load(args.arch, smoke=args.smoke)
    cfg = harness.cfg
    print(f"[train] arch={args.arch} smoke={args.smoke} "
          f"params={sum(np.prod(s.shape) for s in jax.tree.leaves(harness.param_specs(), is_leaf=lambda x: hasattr(x, 'logical'))):.3g}")

    if args.auto_parallel:
        from repro.core.cost_model import Routing, build_comm_model
        from repro.core.planner import plan
        from repro.core.traffic import WorkloadSpec

        w = WorkloadSpec(
            name=args.arch,
            n_layers=cfg.n_layers,
            hidden=cfg.d_model,
            n_heads=getattr(cfg, "n_heads", cfg.d_model // 64),
            head_dim=getattr(cfg, "head_dim", 64),
            seq_len=args.seq,
            global_batch=max(args.batch, 256),
            params_total=float(
                sum(np.prod(s.shape) for s in jax.tree.leaves(
                    harness.param_specs(), is_leaf=lambda x: hasattr(x, "logical")))
            ),
        )
        comm = build_comm_model(multi_pod=True, routing=Routing.BORROW)
        for r in plan(w, 512, comm, top_k=3):
            s = r.spec
            print(f"[planner] tp={s.tp} sp={s.sp} pp={s.pp} dp={s.dp} ep={s.ep} "
                  f"m={s.microbatches} iter={r.iteration_s:.3f}s")

    rt = Runtime(rules=None)
    loss_fn = harness.loss(rt)
    opt_cfg = adamw.OptConfig(lr=args.lr, warmup_steps=10, decay_steps=args.steps)
    comp = CompressionConfig(mode=args.compression)

    key = jax.random.PRNGKey(0)
    params = tree_init(harness.param_specs(), key, dtype=jnp.bfloat16)
    opt_state = adamw.init_opt_state(params)

    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if manager and manager.latest_step() is not None:
        s = manager.latest_step()
        print(f"[train] resuming from checkpoint step {s}")
        state = manager.restore(s, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = s

    from repro.optim.compression import compress_grads

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, _ = compress_grads(comp, grads)
        params, opt_state, metrics = adamw.apply(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    data_cfg = DataConfig(
        global_batch=args.batch, seq_len=args.seq,
        vocab_size=cfg.vocab_size, seed=0,
    )
    pipeline = Pipeline(SyntheticSource(data_cfg), data_cfg, start_step=start_step)
    supervisor = TrainingSupervisor(n_workers=jax.device_count())

    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = next(pipeline)
        t0 = time.time()
        params, opt_state, metrics = train_step(
            params, opt_state,
            {"tokens": jnp.asarray(batch["tokens"]), "labels": jnp.asarray(batch["labels"])},
        )
        dt = time.time() - t0
        supervisor.heartbeat(0, step, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} dt={dt*1e3:.0f}ms")
        if manager and step > 0 and step % args.ckpt_every == 0:
            manager.save(step, {"params": params, "opt": opt_state})
    if manager:
        manager.save(args.steps, {"params": params, "opt": opt_state}, blocking=True)
    tput = (args.steps - start_step) * args.batch * args.seq / (time.time() - t_start)
    print(f"[train] done. first loss={losses[0]:.4f} last loss={losses[-1]:.4f} "
          f"({tput:.0f} tok/s)")
    pipeline.close()
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
