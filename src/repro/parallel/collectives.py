"""Topology-aware collectives — explicit shard_map lowering (paper §5.1).

GSPMD usually derives collective schedules from sharding annotations; these
functions make the paper's hierarchical schedules EXPLICIT where that
matters (gradient sync, MoE dispatch), so the compiled HLO provably follows
the Multi-Ring / hierarchical pattern:

* ``hierarchical_allreduce`` — reduce-scatter over the FAST axis (intra-rack
  2D-FM = "model"), all-reduce over the SLOW axes ("data", "pod"), then
  all-gather back over the fast axis.  Wire bytes on the slow (expensive)
  links drop by the fast-axis size — the Multi-Ring tiering of Fig. 13.
* ``hierarchical_all_to_all`` — the Fig. 14-(b) broadcast/reduce-style MoE
  dispatch: A2A within the local clique first, then one exchange across
  cliques (dedups the long-link copies).
* ``multipath_split`` — the Fig. 14-(a) trick at the JAX level: split a
  tensor in two and route the halves over two different mesh axes
  simultaneously (bandwidth of both dimensions adds).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def hierarchical_allreduce(mesh: Mesh, fast_axis: str, slow_axes: tuple[str, ...]):
    """Returns fn(x_sharded_anyhow) -> allreduced x, lowered hierarchically.

    x enters replicated per-device shard-wise (shard_map sees local shards);
    semantics match a flat psum over (fast, *slow) but the schedule is
    RS(fast) -> AR(slow) -> AG(fast).
    """

    def inner(x):
        n_fast = mesh.shape[fast_axis]
        # reduce-scatter over the fast axis: each fast-rank owns 1/n_fast
        x = jax.lax.psum_scatter(x, fast_axis, scatter_dimension=0, tiled=True)
        # all-reduce the owned shard over the slow (long-range) axes
        for ax in slow_axes:
            x = jax.lax.psum(x, ax)
        # gather the fast axis back
        x = jax.lax.all_gather(x, fast_axis, axis=0, tiled=True)
        return x

    return shard_map(
        inner, mesh=mesh,
        in_specs=P(), out_specs=P(),
        check_rep=False,
    )


def flat_allreduce(mesh: Mesh, axes: tuple[str, ...]):
    """Baseline: single flat psum over all axes (for wire-byte comparison)."""

    def inner(x):
        return jax.lax.psum(x, axes)

    return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)


def multipath_split(mesh: Mesh, axis_a: str, axis_b: str):
    """Fig. 14-(a): move a tensor across the mesh via TWO axes at once.

    Splits x in half; half 1 rides an all_gather over axis_a, half 2 over
    axis_b — on the physical 2D-FullMesh both dimension's links carry
    traffic simultaneously, doubling per-pair bandwidth.
    """

    def inner(x):
        h = x.shape[0] // 2
        a = jax.lax.all_gather(x[:h], axis_a, axis=0, tiled=True)
        b = jax.lax.all_gather(x[h:], axis_b, axis=0, tiled=True)
        return a, b

    return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=(P(), P()),
                     check_rep=False)


def hierarchical_all_to_all(mesh: Mesh, intra_axis: str, inter_axis: str):
    """Two-stage A2A: exchange within the local clique first, then one
    exchange across cliques (the Fig. 14-(b/c) hierarchy).

    x: (n_intra * n_inter, chunk, ...) — destination-major layout.
    """

    def inner(x):
        n_intra = mesh.shape[intra_axis]
        n_inter = mesh.shape[inter_axis]
        # stage 1: intra-clique exchange of the inter-destined groups
        x = x.reshape(n_inter, n_intra, *x.shape[1:])
        x = jax.lax.all_to_all(x, intra_axis, split_axis=1, concat_axis=1, tiled=False)
        # stage 2: one cross-clique exchange
        x = jax.lax.all_to_all(x, inter_axis, split_axis=0, concat_axis=0, tiled=False)
        return x.reshape(n_inter * n_intra, *x.shape[2:])

    return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)
