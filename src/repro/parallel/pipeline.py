"""Pipeline parallelism: GPipe-style microbatch pipeline via shard_map.

The paper maps PP onto the inter-rack axis (P2P boundary transfers, <0.2%
of traffic — Table 1).  This module implements the schedule as a
``shard_map`` over a "stage" mesh axis with ``jax.lax.ppermute`` boundary
transfers, so the compiled HLO carries exactly the paper's collective
pattern (collective-permute on the "data"/inter-rack axis).

Used for memory-constrained configs (the planner decides when); the
dry-run's default cells use DP×TP/SP which already fit, so PP is exercised
by its own unit test (tests/test_pipeline.py, 4 host devices) and available
via ``pipelined_forward`` for launchers.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipelined_forward(
    mesh: Mesh,
    stage_axis: str,
    stage_fn: Callable,     # (stage_params, x) -> x  per-stage computation
    n_microbatches: int,
):
    """Build a pipelined forward: params sharded over the stage axis
    (leading dim = n_stages), batch split into microbatches.

    GPipe schedule: T = n_micro + n_stages - 1 ticks; at each tick every
    stage processes its resident microbatch then ppermutes the activation
    to the next stage.  Returns fn(stage_params, x) -> y where x and y are
    (n_micro, mb, ...) batches living on stage 0 / stage n-1 respectively.
    """
    n_stages = mesh.shape[stage_axis]

    def per_stage(params, x):
        # params: this stage's slice (leading dim 1); x: full microbatches
        # on every stage (only stage 0's content matters at tick 0)
        stage = jax.lax.axis_index(stage_axis)
        p = jax.tree.map(lambda t: t[0], params)
        n_ticks = n_microbatches + n_stages - 1

        def tick(carry, t):
            buf, outputs = carry
            # which microbatch is entering stage 0 at tick t
            mb_in = jnp.where(t < n_microbatches, t, 0)
            incoming = jnp.where(
                (stage == 0) & (t < n_microbatches),
                x[mb_in],
                buf,
            )
            y = stage_fn(p, incoming)
            # last stage collects its finished microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            collect = (stage == n_stages - 1) & (out_idx >= 0)
            outputs = jax.lax.cond(
                collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0
                ),
                lambda o: o,
                outputs,
            )
            # boundary transfer: stage i -> i+1 (paper's PP P2P)
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, i + 1) for i in range(n_stages - 1)],
            )
            return (nxt, outputs), None

        buf0 = jnp.zeros_like(x[0])
        outs0 = jnp.zeros_like(x)
        (_, outputs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(n_ticks)
        )
        return outputs[None]                  # (1, n_micro, mb, ...)

    mapped = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),        # params staged; x replicated
        out_specs=P(stage_axis),              # (n_stages, n_micro, mb, ...)
        check_rep=False,
    )

    def fn(stage_params, x):
        return mapped(stage_params, x)[-1]    # the LAST stage's collected y

    return fn


def stage_split(tree, n_stages: int):
    """Split a stacked-layer param tree (L, ...) into (n_stages, L/st, ...)."""
    def f(x):
        L = x.shape[0]
        assert L % n_stages == 0
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(f, tree)
