"""Sharding rules: UB-Mesh topology-aware logical-axis -> mesh-axis maps.

The production mesh is ("data", "model") = (16, 16) per pod, plus a leading
"pod" axis (2) for multi-pod.  Mapping follows the paper's hierarchy (§5.2):

* "model" = the intra-rack high-bandwidth 2D-FullMesh domain -> carries the
  TP/SP-class traffic: sequence-parallel activations, tensor-sharded weight
  dims, MoE expert dim, SSM head dim, KV-cache sequence dim.
* "data" (+ "pod") = the inter-rack mesh / HRS Clos tier -> carries the
  DP-class traffic: batch dim, ZeRO-1 optimizer shards, FSDP dims of the
  100B+ experts.

``ShardingRules.pspec`` drops an axis that is already used by an earlier
tensor dim, so ONE rule set adapts between train (sp-sharded activations =
FSDP-like weight gathers) and decode (sp off => ff/vocab dims take "model" =
classic TP).  See DESIGN.md §5.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.param import ParamSpec, ShardingRules, is_spec

MODEL_AXIS = "model"
DATA_AXIS = "data"
POD_AXIS = "pod"


def make_rules(
    *,
    multi_pod: bool = False,
    sp: bool = True,                 # sequence-parallel activations (train)
    batch_shardable: bool = True,    # False for global_batch=1 cells
    moe_strategy: str | None = None,
    extra: dict | None = None,
) -> ShardingRules:
    dp = (POD_AXIS, DATA_AXIS) if multi_pod else (DATA_AXIS,)
    rules: dict = {
        # activations
        "batch": dp if batch_shardable else None,
        "sp": MODEL_AXIS if sp else None,
        "ff_act": MODEL_AXIS,
        "cache_seq": MODEL_AXIS,
        "ssm_heads": MODEL_AXIS,
        # weights (all these dims divide 16 for every zoo arch)
        "qkv": MODEL_AXIS,
        "kv": MODEL_AXIS,
        "ff": MODEL_AXIS,
        "rkv": MODEL_AXIS,
        "ssm_proj": MODEL_AXIS,
        "ssm_inner": MODEL_AXIS,
        "table_embed": MODEL_AXIS,
        "vocab": MODEL_AXIS,
        "embed_in": None,
        "layers": None,
    }
    if moe_strategy == "expert_parallel":
        rules.update(
            experts=MODEL_AXIS,
            experts_act=MODEL_AXIS,
            moe_fsdp=DATA_AXIS,
            moe_ff_act=None,
            moe_d_act=MODEL_AXIS,
        )
    elif moe_strategy == "expert_tp":
        rules.update(
            experts=None,
            experts_act=None,
            moe_fsdp=DATA_AXIS,
            moe_ff_act=MODEL_AXIS,
            moe_d_act=MODEL_AXIS,
        )
    if extra:
        rules.update(extra)
    return ShardingRules(rules=rules)


def rules_for_cell(harness, cell, *, multi_pod: bool) -> ShardingRules:
    """Pick the per-(arch x shape) rule set the dry-run/train/serve use."""
    dp_size = 32 if multi_pod else 16
    batch_ok = cell.global_batch % dp_size == 0 and cell.global_batch >= dp_size
    return make_rules(
        multi_pod=multi_pod,
        sp=cell.kind != "decode",
        batch_shardable=batch_ok,
        moe_strategy=harness.moe_strategy,
    )


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state sharding over the DP axes
# ---------------------------------------------------------------------------


def zero1_pspec(spec: ParamSpec, rules: ShardingRules, dp_size: int) -> P:
    """Param pspec + the DP axes added on the first free, divisible dim.

    This is the ZeRO-1 partitioning of fp32 master/moment tensors: model-
    sharded dims stay, and one replicated dim additionally shards over
    ("pod","data").  Falls back to the plain param spec when nothing divides.
    """
    base = rules.pspec(spec.logical)
    entries = list(base) + [None] * (len(spec.shape) - len(base))
    used = {a for e in entries if e for a in ((e,) if isinstance(e, str) else e)}
    dp_axes = tuple(
        a for a in ((POD_AXIS, DATA_AXIS) if dp_size > 16 else (DATA_AXIS,))
        if a not in used
    )
    if not dp_axes:
        return base
    dp_total = int(np.prod([dp_size // 16 if a == POD_AXIS else 16 for a in dp_axes]))
    # skip the scanned-layers dim (dim 0 when logical starts with "layers")
    start = 1 if spec.logical and spec.logical[0] == "layers" else 0
    for i in range(start, len(spec.shape)):
        if entries[i] is None and spec.shape[i] % dp_total == 0 and spec.shape[i] > 0:
            entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_zero1_pspecs(spec_tree, rules: ShardingRules, dp_size: int):
    return jax.tree.map(
        lambda s: zero1_pspec(s, rules, dp_size), spec_tree, is_leaf=is_spec
    )
