"""Sharding-aware checkpointing with async save and elastic restore.

Layout:  <dir>/step_<N>/
    meta.json            — step, flat key list, shapes/dtypes, mesh shape
    <flat_key>.npy       — one file per leaf (gathered to host)

Design points for the 1000+-node story:

* **async** — `save()` snapshots device arrays to host (cheap, device->host
  copy) then writes files on a background thread; training continues.
* **elastic restore** — leaves are stored UNSHARDED (gathered), so a restart
  may use a different mesh/DP width: `restore(..., shardings=)` re-shards
  via `jax.device_put` onto the new topology.  (At real scale this becomes
  one file per shard + lazy resharding; the manifest format already carries
  everything needed.)
* **integrity** — a checkpoint directory is committed by writing meta.json
  LAST; partial saves are ignored by `latest_step`.
"""

from __future__ import annotations

import json
import pathlib
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        flat = _flatten(tree)

        def to_host(v):
            a = np.asarray(v)
            # npy has no bf16: store any non-native dtype widened; restore()
            # re-narrows per the target tree's dtype
            if a.dtype.kind not in "fiub":
                a = a.astype(np.float32)
            return a

        host = {k: to_host(v) for k, v in flat.items()}  # gather to host

        def write():
            out = self.dir / f"step_{step:08d}"
            out.mkdir(parents=True, exist_ok=True)
            for k, v in host.items():
                np.save(out / (k.replace("/", "__") + ".npy"), v)
            meta = {
                "step": step,
                "keys": sorted(host.keys()),
                "shapes": {k: list(v.shape) for k, v in host.items()},
                "dtypes": {k: str(v.dtype) for k, v in host.items()},
            }
            # commit marker: write to a temp name then rename, so a crash
            # mid-write can never leave a truncated meta.json that makes a
            # partial checkpoint look committed
            tmp = out / "meta.json.tmp"
            tmp.write_text(json.dumps(meta))
            tmp.replace(out / "meta.json")
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            d = self.dir / f"step_{s:08d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()

    # -- restore ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in self.dir.glob("step_*"):
            if (d / "meta.json").exists():
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, tree_like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``tree_like``; optionally re-shard
        onto a (possibly different) mesh — the elastic-restart path."""
        src = self.dir / f"step_{step:08d}"
        meta = json.loads((src / "meta.json").read_text())
        flat_like = _flatten(tree_like)
        missing = set(flat_like) - set(meta["keys"])
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
        loaded = {
            k: np.load(src / (k.replace("/", "__") + ".npy"))
            for k in flat_like
        }
        flat_sh = _flatten(shardings) if shardings is not None else {}
        leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
        keys = list(_flatten(tree_like).keys())
        out = []
        for k, like in zip(keys, leaves_like):
            arr = loaded[k]
            if hasattr(like, "dtype") and arr.dtype != like.dtype:
                arr = jnp_astype(arr, like.dtype)
            sh = flat_sh.get(k)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)


def jnp_astype(arr, dtype):
    import jax.numpy as jnp

    return np.asarray(jnp.asarray(arr).astype(dtype))
