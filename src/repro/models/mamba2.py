"""Mamba2 (SSD) block — chunked state-space scan, TPU-matmul friendly.

The chunked SSD formulation computes, per chunk of Q tokens, an intra-chunk
causal "linear attention with decay" via matmuls plus an inter-chunk state
recurrence carried by ``lax.scan`` — this is the structure our Pallas
``ssd_scan`` kernel tiles into VMEM (kernels/ssd_scan.py; this module is the
reference implementation and the decode path).

Sharding: SSM heads shard over "model" (state ops are head-local), batch
over "data".  The sequence dim stays unsharded inside the scan (it is the
scan axis); hybrid models reshard at block boundaries.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import Runtime, rmsnorm, rmsnorm_spec
from .param import ParamSpec


@dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_inner: int             # typically 2 * d_model
    d_state: int = 64        # N
    head_dim: int = 64       # P
    d_conv: int = 4
    chunk: int = 128
    unroll: bool = False   # python-loop chunks (dry-run cost probes)

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba2_specs(cfg: Mamba2Config) -> dict:
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    return {
        # order: [z | x | B | C | dt]
        "in_proj": ParamSpec(
            (D, 2 * DI + 2 * N + H), ("embed_in", "ssm_proj"), init="scaled"
        ),
        "conv_w": ParamSpec((cfg.d_conv, DI + 2 * N), (None, None), init="scaled"),
        "conv_b": ParamSpec((DI + 2 * N,), (None,), init="zeros"),
        "dt_bias": ParamSpec((H,), (None,), init="zeros"),
        "A_log": ParamSpec((H,), (None,), init="zeros"),
        "D": ParamSpec((H,), (None,), init="ones"),
        "out_norm": rmsnorm_spec(DI),
        "out_proj": ParamSpec((DI, D), ("ssm_inner", "embed_in"), init="scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state=None):
    """Depthwise causal conv over (B, S, C); returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1) :, :] if K > 1 else None
    return y + b, new_state


def ssd_chunked(
    xh: jax.Array,      # (B, S, H, P)   dt-weighted inputs
    log_l: jax.Array,   # (B, S, H)      log decay per token (dt * A, <= 0)
    Bm: jax.Array,      # (B, S, N)
    Cm: jax.Array,      # (B, S, N)
    chunk: int,
    h0: jax.Array | None = None,   # (B, H, P, N)
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), h_final)."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    n_chunks = S // Q
    assert S % Q == 0, "sequence must be divisible by the chunk size"

    xh_c = xh.reshape(B, n_chunks, Q, H, P)
    ll_c = log_l.reshape(B, n_chunks, Q, H)
    B_c = Bm.reshape(B, n_chunks, Q, N)
    C_c = Cm.reshape(B, n_chunks, Q, N)

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def body(h, xs):
        xq, lq, bq, cq = xs          # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        cum = jnp.cumsum(lq, axis=1)                     # (B,Q,H)
        # intra-chunk: att[i,j] = (C_i . B_j) * exp(cum_i - cum_j) for i>=j
        scores = jnp.einsum("bin,bjn->bij", cq, bq)      # (B,Q,Q)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        att = scores[..., None] * jnp.exp(
            jnp.where(causal[None, :, :, None], decay, -jnp.inf)
        )                                                # (B,Q,Q,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", att.astype(xq.dtype), xq)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum(
            "bin,bhpn->bihp", cq, h.astype(cq.dtype)
        ) * jnp.exp(cum)[..., None].astype(cq.dtype)
        # state update: h' = h * exp(sum l) + sum_j exp(cum_Q - cum_j) x_j B_j^T
        tail = jnp.exp(cum[:, -1:, :] - cum)             # (B,Q,H)
        dh = jnp.einsum(
            "bjhp,bjn,bjh->bhpn", xq.astype(jnp.float32), bq.astype(jnp.float32), tail
        )
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + dh
        return h_new, (y_intra + y_inter).astype(xq.dtype)

    xs = (
        xh_c.transpose(1, 0, 2, 3, 4),
        ll_c.transpose(1, 0, 2, 3),
        B_c.transpose(1, 0, 2, 3),
        C_c.transpose(1, 0, 2, 3),
    )
    if unroll:
        h = h0
        ylist = []
        for c in range(n_chunks):
            h, yc = body(h, jax.tree.map(lambda t: t[c], xs))
            ylist.append(yc)
        h_final, ys = h, jnp.stack(ylist, axis=0)
    else:
        h_final, ys = jax.lax.scan(body, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, h_final


def mamba2_apply(
    rt: Runtime,
    p: dict,
    x: jax.Array,               # (B, S, D)
    cfg: Mamba2Config,
    state: dict | None = None,  # decode: {"h": (B,H,P,N), "conv": (B,K-1,C)}
) -> tuple[jax.Array, dict | None]:
    B, S, D = x.shape
    DI, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim

    zxbcdt = jnp.einsum("bsd,dp->bsp", x, p["in_proj"])
    z, xc, Bm, Cm, dt = jnp.split(
        zxbcdt, [DI, 2 * DI, 2 * DI + N, 2 * DI + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, p["conv_w"], p["conv_b"], None if state is None else state["conv"]
    )
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [DI, DI + N], axis=-1)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    log_l = dt * a                                                # (B,S,H) <=0
    xh = xc.reshape(B, S, H, P)
    xh = rt.shard(xh, "batch", None, "ssm_heads", None)
    xdt = xh * dt[..., None].astype(xh.dtype)

    if state is None:
        y, h_final = ssd_chunked(xdt, log_l, Bm, Cm, cfg.chunk, unroll=cfg.unroll)
        new_state = None
    else:
        # single-token recurrence (S small, typically 1)
        h = state["h"]
        ys = []
        for t in range(S):
            lam = jnp.exp(log_l[:, t])                            # (B,H)
            dh = jnp.einsum("bhp,bn->bhpn", xdt[:, t].astype(jnp.float32), Bm[:, t].astype(jnp.float32))
            h = h * lam[:, :, None, None] + dh
            ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t].astype(jnp.float32), h))
        y = jnp.stack(ys, axis=1).astype(x.dtype)
        h_final = h
        new_state = {"h": h_final, "conv": conv_state}

    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, DI)
    y = rmsnorm(p["out_norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("bsp,pd->bsd", y, p["out_proj"])
    return rt.shard(out, "batch", None, None), new_state


def mamba2_state_specs(cfg: Mamba2Config, batch: int, n_layers: int) -> dict:
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.d_state
    C = cfg.d_inner + 2 * N
    return {
        "h": ParamSpec(
            (n_layers, batch, H, P, N),
            ("layers", "batch", "ssm_heads", None, None),
            init="zeros",
            dtype=jnp.float32,
        ),
        "conv": ParamSpec(
            (n_layers, batch, cfg.d_conv - 1, C),
            ("layers", "batch", None, None),
            init="zeros",
            dtype=jnp.bfloat16,
        ),
    }
