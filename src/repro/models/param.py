"""Parameter trees with logical sharding axes.

Every model in the zoo describes its parameters as a pytree of ``ParamSpec``
(shape + init + *logical axis names*).  From one spec tree we derive:

* materialized parameters        (``tree_init`` — smoke tests / examples),
* ``jax.ShapeDtypeStruct`` trees (``tree_abstract`` — the dry-run, NO alloc),
* ``PartitionSpec`` trees        (``tree_pspecs`` via a ``ShardingRules`` map).

Logical axes used across the zoo (all sharded dims are constructed to divide
the 16-way "model" axis evenly — virtual KV heads, padded vocabs, seq-CP):

    vocab      — padded vocabulary dim
    embed      — d_model residual dim
    heads      — query heads (sharded only in head-TP mode)
    kv_heads   — virtual KV heads (replicated up to a multiple of 16)
    head_dim   — per-head dim
    ff         — MLP hidden dim
    experts    — MoE expert dim
    layers     — scanned layer stack dim (never sharded)
    conv/state — SSM internals (never sharded)
    fsdp       — extra weight-sharding dim over the data axis (ZeRO-3 style)
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "scaled":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        return (
            jax.random.normal(key, spec.shape, spec.dtype)
            * (1.0 / math.sqrt(fan_in))
        )
    return jax.random.normal(key, spec.shape, spec.dtype) * spec.scale


def tree_init(spec_tree: PyTree, key: jax.Array, dtype=None) -> PyTree:
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        x = _init_leaf(spec, k)
        if dtype is not None:
            x = x.astype(dtype)
        out.append(x)
    return jax.tree.unflatten(treedef, out)


def tree_abstract(spec_tree: PyTree, dtype=None) -> PyTree:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        spec_tree,
        is_leaf=is_spec,
    )


@dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis mapping.

    ``rules`` maps a logical name to a mesh axis name (or tuple of axes, or
    None).  Unlisted logical names are unsharded.
    """

    rules: dict[str, Any]

    def pspec(self, logical: tuple[str | None, ...]) -> P:
        axes = []
        used: set[str] = set()
        for name in logical:
            ax = self.rules.get(name) if name else None
            if ax is None:
                axes.append(None)
                continue
            # one mesh axis may shard only one tensor dim
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            flat = tuple(a for a in flat if a not in used)
            if not flat:
                axes.append(None)
                continue
            used.update(flat)
            axes.append(flat[0] if len(flat) == 1 else flat)
        while axes and axes[-1] is None:
            axes.pop()
        return P(*axes)


def tree_pspecs(spec_tree: PyTree, rules: ShardingRules) -> PyTree:
    return jax.tree.map(lambda s: rules.pspec(s.logical), spec_tree, is_leaf=is_spec)


def tree_shardings(spec_tree: PyTree, rules: ShardingRules, mesh: Mesh) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, rules.pspec(s.logical)),
        spec_tree,
        is_leaf=is_spec,
    )


def param_count(spec_tree: PyTree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def param_bytes(spec_tree: PyTree, bytes_per_elem: int = 2) -> int:
    return param_count(spec_tree) * bytes_per_elem


# ---------------------------------------------------------------------------
# helpers used by every model family
# ---------------------------------------------------------------------------


def stack_specs(spec_tree: PyTree, n_layers: int) -> PyTree:
    """Add a leading scanned-layers dim to every ParamSpec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec(
            (n_layers,) + s.shape,
            ("layers",) + s.logical,
            init=s.init,
            scale=s.scale,
            dtype=s.dtype,
        ),
        spec_tree,
        is_leaf=is_spec,
    )


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def cast_floats(tree: PyTree, dtype) -> PyTree:
    """Cast float leaves to the compute dtype (fp32 masters -> bf16)."""
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(cast, tree)


def virtual_kv_heads(n_kv: int, tp: int = 16) -> int:
    """Replicate KV heads so the kv-head dim divides the model axis."""
    if n_kv % tp == 0:
        return n_kv
    if tp % n_kv == 0:
        return tp
    return round_up(n_kv, tp)
