"""Shared layers for the architecture zoo.

All layers are pure functions ``(rt, params, x, ...) -> y`` where ``rt`` is a
:class:`Runtime` carrying the sharding rules (no-op when absent, so the same
code runs single-device smoke tests and 512-chip dry-runs).

Sharding strategy (see DESIGN.md §5): weights store their projection dims
FLATTENED — ``(d_model, n_heads*head_dim)`` etc. — because every such dim in
the zoo divides the 16-way "model" axis evenly, while head counts (24, 36, 8)
often don't.  Activations are sequence-sharded over "model" (the paper's SP /
ring-attention form; logical axis ``sp``), batch over "data"/"pod" (DP).  KV
caches shard their sequence dim (flash-decode style).  GSPMD inserts the
all-gathers/psums these annotations imply — that compiled collective schedule
is what the roofline reads.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .param import ParamSpec, ShardingRules


@dataclass(frozen=True)
class Runtime:
    """Sharding context threaded through every layer."""

    rules: ShardingRules | None = None
    interpret_kernels: bool = True    # pallas interpret mode (CPU container)
    use_kernels: bool = False         # route hot-spots through Pallas ops

    def shard(self, x: jax.Array, *logical: str | None) -> jax.Array:
        if self.rules is None:
            return x
        spec = self.rules.pspec(tuple(logical))
        return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int) -> ParamSpec:
    return ParamSpec((dim,), (None,), init="ones")


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def layernorm_specs(dim: int) -> dict:
    return {
        "scale": ParamSpec((dim,), (None,), init="ones"),
        "bias": ParamSpec((dim,), (None,), init="zeros"),
    }


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(dt) * p["scale"].astype(dt)) + p["bias"].astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]                         # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    return jnp.concatenate(
        [
            (x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin).astype(dt),
            (x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin).astype(dt),
        ],
        axis=-1,
    )


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    window: int | None = None      # sliding-window size (None = full)
    rope_theta: float | None = 10000.0
    qkv_bias: bool = False
    prefix_len: int = 0            # bidirectional prefix (VLM / audio stubs)
    impl: str = "reference"        # reference | blocked (flash-style)


def attn_specs(cfg: AttnConfig) -> dict:
    """Flattened projections — every sharded dim divides the model axis."""
    D, N, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((D, N * Dh), ("embed_in", "qkv"), init="scaled"),
        "wk": ParamSpec((D, K * Dh), ("embed_in", "kv"), init="scaled"),
        "wv": ParamSpec((D, K * Dh), ("embed_in", "kv"), init="scaled"),
        "wo": ParamSpec((N * Dh, D), ("qkv", "embed_in"), init="scaled"),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((N * Dh,), ("qkv",), init="zeros")
        specs["bk"] = ParamSpec((K * Dh,), ("kv",), init="zeros")
        specs["bv"] = ParamSpec((K * Dh,), ("kv",), init="zeros")
        specs["bo"] = ParamSpec((D,), (None,), init="zeros")
    return specs


def _mask_bias(
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool,
    window: int | None,
    prefix_len: int = 0,
) -> jax.Array:
    """Additive attention bias (0 / -1e9), shape (Sq, Sk), float32.

    ``prefix_len`` makes the first N key positions visible to everyone
    (prefix-LM attention for VLM stubs, paligemma-style).
    """
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok = ok & (q_pos[:, None] >= k_pos[None, :])
    if window is not None:
        ok = ok & ((q_pos[:, None] - k_pos[None, :]) < window)
    if prefix_len > 0:
        ok = ok | (k_pos[None, :] < prefix_len)
    return jnp.where(ok, 0.0, -1e9).astype(jnp.float32)


def sdpa(
    q: jax.Array,      # (B, Sq, K, G, Dh)  q heads grouped by kv head
    k: jax.Array,      # (B, Sk, K, Dh)
    v: jax.Array,      # (B, Sk, K, Dh)
    bias: jax.Array | None,   # (Sq, Sk)
) -> jax.Array:
    """Reference grouped-query attention (the Pallas kernel's oracle)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if bias is not None:
        scores = scores + bias[None, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def blocked_sdpa(
    q: jax.Array,      # (B, Sq, K, G, Dh)
    k: jax.Array,      # (B, Sk, K, Dh)
    v: jax.Array,      # (B, Sk, K, Dh)
    *,
    q_start: int = 0,  # static global position of q[0] / k[0]
    k_start: int = 0,
    causal: bool,
    window: int | None,
    prefix_len: int,
    block_q: int = 2048,
    block_k: int = 2048,
) -> jax.Array:
    """Flash-style online-softmax attention with STATIC block skipping.

    The beyond-paper §Perf optimization (hypothesis H-mem in
    EXPERIMENTS.md): never materializes the (Sq, Sk) score matrix, and
    skips (q-block, kv-block) pairs that the causal/sliding-window mask
    rules out entirely — for starcoder2's 4K window at 32K prefill that's
    ~7/8 of all blocks.  Pure jnp (python loop = unrolled HLO), mirroring
    kernels/flash_attention.py which is the TPU execution path.
    """
    B, Sq, K, G, Dh = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    scale = 1.0 / math.sqrt(Dh)
    q0, k0 = q_start, k_start

    out_blocks = []
    for iq in range(nq):
        qs, qe = iq * bq, min((iq + 1) * bq, Sq)
        q_blk = q[:, qs:qe].astype(jnp.float32)
        q_lo, q_hi = q0 + qs, q0 + qe - 1
        m = jnp.full((B, qe - qs, K, G), -1e30, jnp.float32)
        l = jnp.zeros((B, qe - qs, K, G), jnp.float32)
        acc = jnp.zeros((B, qe - qs, K, G, Dh), jnp.float32)
        for ik in range(nk):
            ks_, ke = ik * bk, min((ik + 1) * bk, Sk)
            k_lo, k_hi = k0 + ks_, k0 + ke - 1
            # ---- static skip tests (whole block masked out?) -------------
            in_prefix = prefix_len > 0 and k_lo < prefix_len
            if not in_prefix:
                if causal and k_lo > q_hi:
                    continue
                if window is not None and (q_lo - k_hi) >= window:
                    continue
            k_blk = k[:, ks_:ke].astype(jnp.float32)
            v_blk = v[:, ks_:ke].astype(jnp.float32)
            s = jnp.einsum("bqkgd,bskd->bqkgs", q_blk, k_blk) * scale
            bias = _mask_bias(
                q0 + qs + jnp.arange(qe - qs),
                k0 + ks_ + jnp.arange(ke - ks_),
                causal, window, prefix_len,
            )
            s = s + bias[None, :, None, None, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqkgs,bskd->bqkgd", p, v_blk
            )
            m = m_new
        out_blocks.append(acc / jnp.maximum(l, 1e-20)[..., None])
    return jnp.concatenate(out_blocks, axis=1).astype(q.dtype)


def attention(
    rt: Runtime,
    p: dict,
    x: jax.Array,                  # (B, S, D)
    cfg: AttnConfig,
    positions: jax.Array,          # (S,) token positions for q
    kv_cache: tuple[jax.Array, jax.Array] | None = None,  # (B,Smax,K,Dh) x2
    cache_pos: jax.Array | None = None,  # scalar write offset (decode)
    kv_override: jax.Array | None = None,  # encoder states for cross-attn
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """Full attention layer.  Returns (out, updated_cache)."""
    B, S, D = x.shape
    N, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = N // K

    kv_src = kv_override if kv_override is not None else x
    q = jnp.einsum("bsd,dp->bsp", x, p["wq"])
    k = jnp.einsum("bsd,dp->bsp", kv_src, p["wk"])
    v = jnp.einsum("bsd,dp->bsp", kv_src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, N, Dh)
    k = k.reshape(B, kv_src.shape[1], K, Dh)
    v = v.reshape(B, kv_src.shape[1], K, Dh)
    q = rt.shard(q, "batch", "sp", None, None)

    if cfg.rope_theta is not None and kv_override is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        if cache_pos is not None:
            ck = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, cache_pos, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, cache_pos, 0, 0)
            )
        k, v = ck, cv
        new_cache = (ck, cv)
        k_pos = jnp.arange(k.shape[1])
        k = rt.shard(k, "batch", "cache_seq", None, None)
        v = rt.shard(v, "batch", "cache_seq", None, None)
    else:
        k_pos = positions
        # ring-attention allgather form: kv replicated across the sp shards
        k = rt.shard(k, "batch", None, None, None)
        v = rt.shard(v, "batch", None, None, None)

    qg = q.reshape(B, S, K, G, Dh)
    # blocked path: train (no cache) and full-length prefill (cache written
    # from position 0 over its whole extent => causal mask covers validity)
    blocked_ok = (
        cfg.impl == "blocked"
        and kv_override is None
        and (kv_cache is None or (S > 1 and S == k.shape[1]))
    )
    if blocked_ok:
        out = blocked_sdpa(
            qg, k, v,
            causal=cfg.causal, window=cfg.window, prefix_len=cfg.prefix_len,
        )
    else:
        if kv_override is not None:
            bias = None                                # cross-attn: full view
        else:
            # positions are the q tokens' GLOBAL positions, so the same mask
            # covers train (full S), prefill (cache write at 0) and decode
            # (single token at cache_pos)
            bias = _mask_bias(
                positions, k_pos, cfg.causal, cfg.window, cfg.prefix_len
            )
        out = sdpa(qg, k, v, bias)
    out = out.reshape(B, S, N * Dh)
    out = rt.shard(out, "batch", "sp", None)
    y = jnp.einsum("bsp,pd->bsd", out, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    y = rt.shard(y, "batch", "sp", None)
    return y, new_cache


def init_kv_cache(
    cfg: AttnConfig, batch: int, max_len: int, n_layers: int, dtype=jnp.bfloat16
) -> dict:
    """Stacked (L, B, S, K, Dh) cache specs for the scanned decoder."""
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    logical = ("layers", "batch", "cache_seq", None, None)
    return {
        "k": ParamSpec(shape, logical, init="zeros", dtype=dtype),
        "v": ParamSpec(shape, logical, init="zeros", dtype=dtype),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_specs(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("embed_in", "ff"), init="scaled"),
        "w_up": ParamSpec((d_model, d_ff), ("embed_in", "ff"), init="scaled"),
        "w_down": ParamSpec((d_ff, d_model), ("ff", "embed_in"), init="scaled"),
    }


def swiglu(rt: Runtime, p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g) * u
    h = rt.shard(h, "batch", "sp", "ff_act")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return rt.shard(y, "batch", "sp", None)


def gelu_mlp_specs(d_model: int, d_ff: int, bias: bool = True) -> dict:
    s = {
        "w_in": ParamSpec((d_model, d_ff), ("embed_in", "ff"), init="scaled"),
        "w_out": ParamSpec((d_ff, d_model), ("ff", "embed_in"), init="scaled"),
    }
    if bias:
        s["b_in"] = ParamSpec((d_ff,), ("ff",), init="zeros")
        s["b_out"] = ParamSpec((d_model,), (None,), init="zeros")
    return s


def gelu_mlp(rt: Runtime, p: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if "b_in" in p:
        h = h + p["b_in"]
    h = jax.nn.gelu(h)
    h = rt.shard(h, "batch", "sp", "ff_act")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    if "b_out" in p:
        y = y + p["b_out"]
    return rt.shard(y, "batch", "sp", None)


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------


def embed_specs(vocab_padded: int, d_model: int) -> dict:
    """Untied: lookup table sharded on its EMBED dim (gathers stay local);
    unembedding sharded on VOCAB (logits + loss stay vocab-sharded)."""
    return {
        "tok": ParamSpec((vocab_padded, d_model), (None, "table_embed")),
        "unembed": ParamSpec(
            (d_model, vocab_padded), (None, "vocab"), init="scaled"
        ),
    }


def embed(rt: Runtime, p: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    return rt.shard(x, "batch", "sp", None)


def unembed(rt: Runtime, p: dict, x: jax.Array) -> jax.Array:
    logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"])
    return rt.shard(logits, "batch", "sp", "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_real: int) -> jax.Array:
    """Mean NLL over (possibly vocab-sharded) logits; fused one-hot gold
    extraction so GSPMD never all-gathers the vocab dim; padded tail masked.
    """
    lg = logits.astype(jnp.float32)
    V = lg.shape[-1]
    if vocab_real < V:
        mask = jnp.arange(V) < vocab_real
        lg = jnp.where(mask, lg, -1e9)
    logz = jax.nn.logsumexp(lg, axis=-1)
    onehot = jax.nn.one_hot(labels, V, dtype=lg.dtype)
    gold = jnp.sum(lg * onehot, axis=-1)
    return jnp.mean(logz - gold)
