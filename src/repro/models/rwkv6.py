"""RWKV-6 "Finch" — attention-free time-mix with data-dependent decay.

Per head (dim N): state S in R^{N x N};  per token t:
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)        (u: per-channel bonus)
with data-dependent w_t = exp(-exp(w0 + lora(x_t))) in (0, 1).

Chunked form (matmul-heavy, the Pallas ``rwkv6_scan`` kernel's shape): per
chunk, intra-chunk is a causal linear attention with per-channel decay
products; inter-chunk carries S.  Token-shift mixing follows RWKV's x_t /
x_{t-1} lerp (static per-channel mu here; the data-dependent LoRA applies to
the decay, the dominant Finch novelty).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import Runtime, rmsnorm, rmsnorm_spec
from .param import ParamSpec


@dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    head_dim: int = 64
    d_ff: int = 7168
    decay_lora: int = 64
    chunk: int = 128
    unroll: bool = False   # python-loop chunks (dry-run cost probes)

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def timemix_specs(cfg: RWKV6Config) -> dict:
    D, L = cfg.d_model, cfg.decay_lora
    return {
        "mu": ParamSpec((5, D), (None, None), init="zeros"),  # r,k,v,g,w shifts
        "wr": ParamSpec((D, D), ("embed_in", "rkv"), init="scaled"),
        "wk": ParamSpec((D, D), ("embed_in", "rkv"), init="scaled"),
        "wv": ParamSpec((D, D), ("embed_in", "rkv"), init="scaled"),
        "wg": ParamSpec((D, D), ("embed_in", "rkv"), init="scaled"),
        "w0": ParamSpec((D,), (None,), init="zeros"),
        "w_lora_a": ParamSpec((D, L), ("embed_in", None), init="scaled"),
        "w_lora_b": ParamSpec((L, D), (None, "rkv"), init="scaled"),
        "bonus_u": ParamSpec((D,), (None,), init="zeros"),
        "ln_out": rmsnorm_spec(D),
        "wo": ParamSpec((D, D), ("rkv", "embed_in"), init="scaled"),
    }


def channelmix_specs(cfg: RWKV6Config) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "mu": ParamSpec((2, D), (None, None), init="zeros"),   # k, r shifts
        "wk": ParamSpec((D, F), ("embed_in", "ff"), init="scaled"),
        "wv": ParamSpec((F, D), ("ff", "embed_in"), init="scaled"),
        "wr": ParamSpec((D, D), ("embed_in", "rkv"), init="scaled"),
    }


def _token_shift(x: jax.Array, last: jax.Array | None) -> jax.Array:
    """x_{t-1} stream; ``last`` carries the final token across steps."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _lerp(x, xprev, mu):
    return x + (xprev - x) * mu[None, None, :]


def rwkv6_chunked(
    r: jax.Array,   # (B, S, H, N)
    k: jax.Array,   # (B, S, H, N)
    v: jax.Array,   # (B, S, H, N)
    w: jax.Array,   # (B, S, H, N)  per-channel decay in (0,1)  (float32)
    u: jax.Array,   # (H, N) bonus
    chunk: int,
    s0: jax.Array | None = None,    # (B, H, N, N)
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked linear-attention scan.  Returns (y, final_state)."""
    B, S, H, N = r.shape
    Q = min(chunk, S)
    n_chunks = S // Q
    assert S % Q == 0

    logw = jnp.log(jnp.clip(w, 1e-6, 1.0))             # (B,S,H,N) <= 0

    def reshape(x):
        return x.reshape(B, n_chunks, Q, H, N).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lc = reshape(r), reshape(k), reshape(v), reshape(logw)
    if s0 is None:
        s0 = jnp.zeros((B, H, N, N), jnp.float32)

    T = min(16, Q)                                       # pairwise sub-tile
    n_tiles = Q // T

    def body(s, xs):
        rq, kq, vq, lq = xs                             # (B,Q,H,N)...
        rq32 = rq.astype(jnp.float32)
        kq32 = kq.astype(jnp.float32)
        vq32 = vq.astype(jnp.float32)
        cum = jnp.cumsum(lq, axis=1)                    # (B,Q,H,N) <= 0
        # inter-chunk: y_i += (r_i * prod_{t<i} w_t) S ; cum - lq <= 0 safe
        y_inter = jnp.einsum("bihn,bhnm->bihm", rq32 * jnp.exp(cum - lq), s)
        # intra-chunk, DIRECT pairwise form — the decay difference
        # cum_i - lq_i - cum_j is <= 0 for j < i, so every exp is bounded.
        # Tiled over (T x T) sub-blocks to bound the (B,T,T,H,N) temporary
        # (this tiling is exactly what kernels/rwkv6_scan does in VMEM).
        y_intra = jnp.zeros_like(vq32)
        for ti in range(n_tiles):
            i0 = ti * T
            ci = (cum - lq)[:, i0 : i0 + T]              # decay BEFORE i
            ri = rq32[:, i0 : i0 + T]
            acc = jnp.zeros((B, T, H, N), jnp.float32)
            for tj in range(ti + 1):
                j0 = tj * T
                cj = cum[:, j0 : j0 + T]
                d = ci[:, :, None] - cj[:, None, :]      # (B,T,T,H,N)
                if ti == tj:
                    mask = jnp.tril(jnp.ones((T, T), bool), k=-1)
                    d = jnp.where(mask[None, :, :, None, None], d, -jnp.inf)
                att = jnp.einsum(
                    "bihn,bjhn,bijhn->bhij",
                    ri,
                    kq32[:, j0 : j0 + T],
                    jnp.exp(d),
                )
                acc = acc + jnp.einsum(
                    "bhij,bjhn->bihn", att, vq32[:, j0 : j0 + T]
                )
            y_intra = jax.lax.dynamic_update_slice_in_dim(y_intra, acc, i0, axis=1)
        bonus = jnp.einsum("bihn,hn,bihn->bih", rq32, u, kq32)
        y_bonus = bonus[..., None] * vq32
        # state update: S' = diag(prod w) S + sum_j (prod_{t>j} w_t) k_j v_j^T
        tail = jnp.exp(cum[:, -1:, :, :] - cum)              # <= 1 safe
        s_new = s * jnp.exp(cum[:, -1])[:, :, :, None] + jnp.einsum(
            "bjhn,bjhm->bhnm", kq32 * tail, vq32
        )
        return s_new, (y_inter + y_intra + y_bonus).astype(rq.dtype)

    if unroll:
        s = s0
        ylist = []
        for c in range(n_chunks):
            s, yc = body(s, (rc[c], kc[c], vc[c], lc[c]))
            ylist.append(yc)
        s_final, ys = s, jnp.stack(ylist, axis=0)
    else:
        s_final, ys = jax.lax.scan(body, s0, (rc, kc, vc, lc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
    return y, s_final


def timemix_apply(
    rt: Runtime,
    p: dict,
    x: jax.Array,
    cfg: RWKV6Config,
    state: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    B, S, D = x.shape
    H, N = cfg.n_heads, cfg.head_dim
    xprev = _token_shift(x, None if state is None else state["shift"])
    mu = p["mu"]
    r = jnp.einsum("bsd,de->bse", _lerp(x, xprev, mu[0]), p["wr"])
    k = jnp.einsum("bsd,de->bse", _lerp(x, xprev, mu[1]), p["wk"])
    v = jnp.einsum("bsd,de->bse", _lerp(x, xprev, mu[2]), p["wv"])
    g = jnp.einsum("bsd,de->bse", _lerp(x, xprev, mu[3]), p["wg"])
    xw = _lerp(x, xprev, mu[4])
    wlog = p["w0"][None, None] + jnp.einsum(
        "bsd,dl,le->bse", xw, p["w_lora_a"], p["w_lora_b"]
    )
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32)))          # (0,1) decay

    def heads(t):
        return t.reshape(B, S, H, N)

    r4, k4, v4, w4 = heads(r), heads(k), heads(v), heads(w)
    r4 = rt.shard(r4, "batch", None, "ssm_heads", None)
    u = p["bonus_u"].reshape(H, N)

    if state is None:
        y, s_final = rwkv6_chunked(r4, k4, v4, w4, u, cfg.chunk, unroll=cfg.unroll)
        new_state = None
    else:
        s = state["s"]
        ys = []
        for t in range(S):
            rt_, kt, vt, wt = (
                r4[:, t].astype(jnp.float32),
                k4[:, t].astype(jnp.float32),
                v4[:, t].astype(jnp.float32),
                w4[:, t],
            )
            kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
            y_t = jnp.einsum("bhn,bhnm->bhm", rt_, s + u[None, :, :, None] * kv)
            s = s * wt[..., None] + kv
            ys.append(y_t)
        y = jnp.stack(ys, axis=1).astype(x.dtype)
        s_final = s
        new_state = {"s": s_final, "shift": x[:, -1:]}

    y = y.reshape(B, S, D)
    y = rmsnorm(p["ln_out"], y) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, p["wo"])
    return rt.shard(out, "batch", None, None), new_state


def channelmix_apply(
    rt: Runtime,
    p: dict,
    x: jax.Array,
    state: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    xprev = _token_shift(x, None if state is None else state["shift"])
    k = jnp.einsum("bsd,df->bsf", _lerp(x, xprev, p["mu"][0]), p["wk"])
    k = jnp.square(jax.nn.relu(k))
    k = rt.shard(k, "batch", None, "ff_act")
    vv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", _lerp(x, xprev, p["mu"][1]), p["wr"])
    )
    out = rr * vv
    new_state = None if state is None else {"shift": x[:, -1:]}
    return rt.shard(out, "batch", None, None), new_state


def rwkv6_state_specs(cfg: RWKV6Config, batch: int, n_layers: int) -> dict:
    H, N, D = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {
        "tm_s": ParamSpec(
            (n_layers, batch, H, N, N),
            ("layers", "batch", "ssm_heads", None, None),
            init="zeros",
            dtype=jnp.float32,
        ),
        "tm_shift": ParamSpec(
            (n_layers, batch, 1, D), ("layers", "batch", None, None),
            init="zeros", dtype=jnp.bfloat16,
        ),
        "cm_shift": ParamSpec(
            (n_layers, batch, 1, D), ("layers", "batch", None, None),
            init="zeros", dtype=jnp.bfloat16,
        ),
    }
