"""Unified model harness — one interface over all 10 assigned architectures.

Each architecture config (``repro/configs/<id>.py``) builds a Harness that
exposes:

* ``param_specs()``                  — ParamSpec tree (shapes + logical axes)
* ``loss(rt)``                       — training loss callable
* ``train_input_specs(cell)``        — ParamSpec dict for the batch
* ``prefill(rt)`` / ``decode(rt)``   — serving callables
* ``serve_state_specs(cell)``        — KV-cache / SSM-state ParamSpec tree
* ``skip_reason(shape)``             — e.g. long_500k on full-attention archs

The dry-run lowers these with ShapeDtypeStructs (no allocation); smoke tests
materialize reduced configs with ``tree_init``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, hybrid, rwkv_lm, transformer
from .layers import Runtime
from .param import ParamSpec, round_up


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

TOKENS = jnp.int32
POS = jnp.int32


def _tok(shape, logical):
    return ParamSpec(shape, logical, init="zeros", dtype=TOKENS)


class Harness:
    """Base interface; family subclasses below."""

    arch_id: str = ""
    family: str = ""
    long_context_ok: bool = False
    moe_strategy: str | None = None

    def skip_reason(self, shape: str) -> str | None:
        if shape == "long_500k" and not self.long_context_ok:
            return "full quadratic attention — sub-quadratic required (DESIGN.md §4)"
        return None

    def clone(self, **cfg_updates) -> "Harness":
        """Same harness with a modified config (dry-run cost probes)."""
        import copy
        import dataclasses

        new = copy.copy(self)
        new.cfg = dataclasses.replace(self.cfg, **cfg_updates)
        return new

    # subclasses implement:
    def param_specs(self) -> Any: ...
    def loss(self, rt: Runtime) -> Callable: ...
    def train_input_specs(self, cell: ShapeCell) -> dict: ...
    def prefill(self, rt: Runtime) -> Callable: ...
    def decode(self, rt: Runtime) -> Callable: ...
    def serve_state_specs(self, cell: ShapeCell) -> Any: ...
    def serve_input_specs(self, cell: ShapeCell) -> dict: ...


# ---------------------------------------------------------------------------
# Dense / MoE / VLM decoder-only transformers
# ---------------------------------------------------------------------------


class TransformerHarness(Harness):
    def __init__(
        self,
        arch_id: str,
        cfg: transformer.LMConfig,
        *,
        family: str = "dense",
        prefix_tokens: int = 0,          # VLM stub patches (prepended)
        long_context_ok: bool = False,
    ):
        self.arch_id = arch_id
        self.cfg = cfg
        self.family = family
        self.prefix_tokens = prefix_tokens
        self.long_context_ok = long_context_ok
        self.moe_strategy = cfg.moe.strategy if cfg.moe else None

    def param_specs(self):
        return transformer.lm_specs(self.cfg)

    def loss(self, rt: Runtime):
        def fn(params, batch):
            return transformer.loss_fn(rt, self.cfg, params, batch)

        return fn

    def train_input_specs(self, cell: ShapeCell) -> dict:
        B, S = cell.global_batch, cell.seq_len
        specs = {
            "tokens": _tok((B, S), ("batch", "sp")),
            "labels": _tok((B, S), ("batch", "sp")),
        }
        if self.prefix_tokens:
            specs["prefix_embeds"] = ParamSpec(
                (B, self.prefix_tokens, self.cfg.d_model),
                ("batch", "sp", None),
                init="normal",
                dtype=jnp.bfloat16,
            )
        return specs

    # -- serving ------------------------------------------------------------
    def serve_state_specs(self, cell: ShapeCell):
        max_len = cell.seq_len + self.prefix_tokens
        if self.cfg.window is not None and cell.name == "long_500k":
            # SWA: the live window bounds the cache (rolling not required for
            # the dry-run; window+slack keeps the mask exact)
            max_len = min(max_len, self.cfg.window * 2)
        return transformer.cache_specs(self.cfg, cell.global_batch, max_len)

    def serve_input_specs(self, cell: ShapeCell) -> dict:
        B = cell.global_batch
        if cell.kind == "prefill":
            specs = {"tokens": _tok((B, cell.seq_len), ("batch", "sp"))}
            if self.prefix_tokens:
                specs["prefix_embeds"] = ParamSpec(
                    (B, self.prefix_tokens, self.cfg.d_model),
                    ("batch", "sp", None),
                    init="normal",
                    dtype=jnp.bfloat16,
                )
            return specs
        return {
            "tokens": _tok((B, 1), ("batch", None)),
            "pos": ParamSpec((), (), init="zeros", dtype=POS),
        }

    def prefill(self, rt: Runtime):
        def fn(params, cache, tokens, prefix_embeds=None):
            return transformer.prefill(
                rt, self.cfg, params, tokens, cache, prefix_embeds
            )

        return fn

    def decode(self, rt: Runtime):
        def fn(params, cache, tokens, pos):
            return transformer.decode_step(rt, self.cfg, params, tokens, cache, pos)

        return fn


# ---------------------------------------------------------------------------
# RWKV-6 (attention-free)
# ---------------------------------------------------------------------------


class RWKVHarness(Harness):
    family = "ssm"
    long_context_ok = True

    def __init__(self, arch_id: str, cfg: rwkv_lm.RWKVLMConfig):
        self.arch_id = arch_id
        self.cfg = cfg

    def param_specs(self):
        return rwkv_lm.lm_specs(self.cfg)

    def loss(self, rt: Runtime):
        def fn(params, batch):
            return rwkv_lm.loss_fn(rt, self.cfg, params, batch)

        return fn

    def train_input_specs(self, cell: ShapeCell) -> dict:
        B, S = cell.global_batch, cell.seq_len
        return {
            "tokens": _tok((B, S), ("batch", None)),
            "labels": _tok((B, S), ("batch", None)),
        }

    def serve_state_specs(self, cell: ShapeCell):
        return rwkv_lm.state_specs(self.cfg, cell.global_batch)

    def serve_input_specs(self, cell: ShapeCell) -> dict:
        B = cell.global_batch
        if cell.kind == "prefill":
            return {"tokens": _tok((B, cell.seq_len), ("batch", None))}
        return {
            "tokens": _tok((B, 1), ("batch", None)),
            "pos": ParamSpec((), (), init="zeros", dtype=POS),
        }

    def prefill(self, rt: Runtime):
        # recurrent prefill: chunked forward that RETURNS final states would
        # duplicate decode logic; for serving we score the prompt with the
        # chunked form and re-run the last token recurrently.
        def fn(params, state, tokens):
            logits = rwkv_lm.forward(rt, self.cfg, params, tokens)
            return logits[:, -1:], state

        return fn

    def decode(self, rt: Runtime):
        def fn(params, state, tokens, pos):
            return rwkv_lm.decode_step(rt, self.cfg, params, tokens, state, pos)

        return fn


# ---------------------------------------------------------------------------
# Zamba2 hybrid
# ---------------------------------------------------------------------------


class HybridHarness(Harness):
    family = "hybrid"
    long_context_ok = True

    def __init__(self, arch_id: str, cfg: hybrid.HybridConfig):
        self.arch_id = arch_id
        self.cfg = cfg

    def param_specs(self):
        return hybrid.lm_specs(self.cfg)

    def loss(self, rt: Runtime):
        def fn(params, batch):
            return hybrid.loss_fn(rt, self.cfg, params, batch)

        return fn

    def train_input_specs(self, cell: ShapeCell) -> dict:
        B, S = cell.global_batch, cell.seq_len
        return {
            "tokens": _tok((B, S), ("batch", "sp")),
            "labels": _tok((B, S), ("batch", "sp")),
        }

    def serve_state_specs(self, cell: ShapeCell):
        # shared attention block's KV grows with context; cap per shape
        return hybrid.state_specs(self.cfg, cell.global_batch, cell.seq_len)

    def serve_input_specs(self, cell: ShapeCell) -> dict:
        B = cell.global_batch
        if cell.kind == "prefill":
            return {"tokens": _tok((B, cell.seq_len), ("batch", "sp"))}
        return {
            "tokens": _tok((B, 1), ("batch", None)),
            "pos": ParamSpec((), (), init="zeros", dtype=POS),
        }

    def prefill(self, rt: Runtime):
        def fn(params, state, tokens):
            logits = hybrid.forward(rt, self.cfg, params, tokens)
            return logits[:, -1:], state

        return fn

    def decode(self, rt: Runtime):
        def fn(params, state, tokens, pos):
            return hybrid.decode_step(rt, self.cfg, params, tokens, state, pos)

        return fn


# ---------------------------------------------------------------------------
# Whisper enc-dec
# ---------------------------------------------------------------------------


class EncDecHarness(Harness):
    family = "audio"
    long_context_ok = False

    def __init__(self, arch_id: str, cfg: encdec.EncDecConfig):
        self.arch_id = arch_id
        self.cfg = cfg

    def param_specs(self):
        return encdec.model_specs(self.cfg)

    def loss(self, rt: Runtime):
        def fn(params, batch):
            return encdec.loss_fn(rt, self.cfg, params, batch)

        return fn

    def train_input_specs(self, cell: ShapeCell) -> dict:
        B, S = cell.global_batch, cell.seq_len
        return {
            "frames": ParamSpec(
                (B, self.cfg.n_frames, self.cfg.d_model),
                ("batch", "sp", None),
                init="normal",
                dtype=jnp.bfloat16,
            ),
            "tokens": _tok((B, S), ("batch", "sp")),
            "labels": _tok((B, S), ("batch", "sp")),
        }

    def serve_state_specs(self, cell: ShapeCell):
        return encdec.cache_specs(self.cfg, cell.global_batch, cell.seq_len)

    def serve_input_specs(self, cell: ShapeCell) -> dict:
        B = cell.global_batch
        if cell.kind == "prefill":
            return {
                "frames": ParamSpec(
                    (B, self.cfg.n_frames, self.cfg.d_model),
                    ("batch", "sp", None),
                    init="normal",
                    dtype=jnp.bfloat16,
                ),
                "tokens": _tok((B, cell.seq_len), ("batch", "sp")),
            }
        return {
            "tokens": _tok((B, 1), ("batch", None)),
            "pos": ParamSpec((), (), init="zeros", dtype=POS),
        }

    def prefill(self, rt: Runtime):
        def fn(params, cache, frames, tokens):
            logits, new = encdec.prefill(rt, self.cfg, params, frames, tokens, cache)
            return logits, new

        return fn

    def decode(self, rt: Runtime):
        def fn(params, cache, tokens, pos):
            return encdec.decode_step(rt, self.cfg, params, tokens, cache, pos)

        return fn
