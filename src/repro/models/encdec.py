"""Whisper-style encoder-decoder backbone (pool arch ``whisper-base``).

The conv/mel frontend is a STUB per the harness spec: ``input_specs()``
provides precomputed frame embeddings (B, T_frames, d_model).  Sinusoidal
positions are added here; the encoder is bidirectional, the decoder has
causal self-attention + cross-attention over the encoder output.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .param import ParamSpec, cast_floats, round_up, stack_specs


@dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_layers: int               # per stack (encoder AND decoder)
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    n_frames: int = 1500        # stub frontend output length (30 s audio)
    remat_policy: str = "nothing"
    unroll: bool = False
    dtype: Any = jnp.bfloat16

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab_size, 256)

    def attn(self, causal: bool) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            causal=causal,
            rope_theta=None,          # whisper: absolute sinusoidal positions
            qkv_bias=True,
        )


def _scan_or_unroll(cfg, body, init, xs):
    if not cfg.unroll:
        return jax.lax.scan(body, init, xs)
    carry = init
    ys = []
    n = jax.tree.leaves(xs)[0].shape[0]
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a, axis=0), *ys)
    else:
        ys = None
    return carry, ys


def sinusoid(max_len: int, dim: int) -> jax.Array:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim)
    )
    pe = jnp.zeros((max_len, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def enc_block_specs(cfg: EncDecConfig) -> dict:
    return {
        "ln1": L.layernorm_specs(cfg.d_model),
        "attn": L.attn_specs(cfg.attn(False)),
        "ln2": L.layernorm_specs(cfg.d_model),
        "mlp": L.gelu_mlp_specs(cfg.d_model, cfg.d_ff),
    }


def dec_block_specs(cfg: EncDecConfig) -> dict:
    return {
        "ln1": L.layernorm_specs(cfg.d_model),
        "self_attn": L.attn_specs(cfg.attn(True)),
        "ln_x": L.layernorm_specs(cfg.d_model),
        "cross_attn": L.attn_specs(cfg.attn(False)),
        "ln2": L.layernorm_specs(cfg.d_model),
        "mlp": L.gelu_mlp_specs(cfg.d_model, cfg.d_ff),
    }


def model_specs(cfg: EncDecConfig) -> dict:
    return {
        "embed": L.embed_specs(cfg.vocab_padded, cfg.d_model),
        "enc_blocks": stack_specs(enc_block_specs(cfg), cfg.n_layers),
        "enc_norm": L.layernorm_specs(cfg.d_model),
        "dec_blocks": stack_specs(dec_block_specs(cfg), cfg.n_layers),
        "dec_norm": L.layernorm_specs(cfg.d_model),
    }


def encode(rt, cfg: EncDecConfig, params, frames: jax.Array) -> jax.Array:
    x = frames.astype(cfg.dtype) + sinusoid(frames.shape[1], cfg.d_model).astype(cfg.dtype)
    x = rt.shard(x, "batch", "sp", None)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        a, _ = L.attention(rt, lp["attn"], L.layernorm(lp["ln1"], h), cfg.attn(False), positions)
        h = h + a
        h = h + L.gelu_mlp(rt, lp["mlp"], L.layernorm(lp["ln2"], h))
        return rt.shard(h, "batch", "sp", None), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = _scan_or_unroll(cfg, body, x, params["enc_blocks"])
    return L.layernorm(params["enc_norm"], x)


def _dec_block(rt, cfg, lp, h, enc_out, positions, cache=None, cache_pos=None):
    a, new_cache = L.attention(
        rt, lp["self_attn"], L.layernorm(lp["ln1"], h), cfg.attn(True),
        positions, cache, cache_pos,
    )
    h = h + a
    c, _ = L.attention(
        rt, lp["cross_attn"], L.layernorm(lp["ln_x"], h), cfg.attn(False),
        positions, kv_override=enc_out,
    )
    h = h + c
    h = h + L.gelu_mlp(rt, lp["mlp"], L.layernorm(lp["ln2"], h))
    return rt.shard(h, "batch", "sp", None), new_cache


def forward(rt, cfg: EncDecConfig, params, frames, tokens):
    """Teacher-forced training forward.  Returns logits."""
    params = cast_floats(params, cfg.dtype)
    enc_out = encode(rt, cfg, params, frames)
    y = L.embed(rt, params["embed"], tokens).astype(cfg.dtype)
    S = y.shape[1]
    y = y + sinusoid(S, cfg.d_model).astype(cfg.dtype)
    positions = jnp.arange(S)

    def body(h, lp):
        h, _ = _dec_block(rt, cfg, lp, h, enc_out, positions)
        return h, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    y, _ = _scan_or_unroll(cfg, body, y, params["dec_blocks"])
    y = L.layernorm(params["dec_norm"], y)
    return L.unembed(rt, params["embed"], y)


def loss_fn(rt, cfg, params, batch):
    logits = forward(rt, cfg, params, batch["frames"], batch["tokens"])
    return L.cross_entropy(logits, batch["labels"], cfg.vocab_size)


def cache_specs(cfg: EncDecConfig, batch: int, max_len: int) -> dict:
    kv = L.init_kv_cache(cfg.attn(True), batch, max_len, cfg.n_layers, cfg.dtype)
    kv["enc_out"] = ParamSpec(
        (batch, cfg.n_frames, cfg.d_model),
        ("batch", None, None),
        init="zeros",
        dtype=jnp.bfloat16,
    )
    return kv


def prefill(rt, cfg: EncDecConfig, params, frames, tokens, cache):
    """Encode + write decoder self-attn cache for positions [0, S)."""
    params = cast_floats(params, cfg.dtype)
    enc_out = encode(rt, cfg, params, frames)
    y = L.embed(rt, params["embed"], tokens).astype(cfg.dtype)
    S = y.shape[1]
    y = y + sinusoid(S, cfg.d_model).astype(cfg.dtype)
    positions = jnp.arange(S)
    zero = jnp.zeros((), jnp.int32)

    def body(h, xs):
        lp, ck, cv = xs
        h, new_cache = _dec_block(
            rt, cfg, lp, h, enc_out, positions, cache=(ck, cv), cache_pos=zero
        )
        return h, new_cache

    y, (ck, cv) = _scan_or_unroll(cfg, body, y, (params["dec_blocks"], cache["k"], cache["v"]))
    y = L.layernorm(params["dec_norm"], y)
    logits = L.unembed(rt, params["embed"], y[:, -1:])
    return logits, {"k": ck, "v": cv, "enc_out": enc_out}


def decode_step(rt, cfg: EncDecConfig, params, tokens, cache, pos):
    params = cast_floats(params, cfg.dtype)
    enc_out = cache["enc_out"].astype(cfg.dtype)
    y = L.embed(rt, params["embed"], tokens).astype(cfg.dtype)
    # gather the single position's sinusoid dynamically
    pe_t = jax.lax.dynamic_slice_in_dim(
        sinusoid(65536, cfg.d_model), pos if pos.ndim == 0 else pos[0], 1, axis=0
    )
    y = y + pe_t.astype(cfg.dtype)[None]
    positions = pos[None] if pos.ndim == 0 else pos

    def body(h, xs):
        lp, ck, cv = xs
        h, new_cache = _dec_block(
            rt, cfg, lp, h, enc_out, positions, cache=(ck, cv), cache_pos=pos
        )
        return h, new_cache

    y, (ck, cv) = _scan_or_unroll(cfg, body, y, (params["dec_blocks"], cache["k"], cache["v"]))
    y = L.layernorm(params["dec_norm"], y)
    logits = L.unembed(rt, params["embed"], y)
    return logits, {"k": ck, "v": cv, "enc_out": cache["enc_out"]}
