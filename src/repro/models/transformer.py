"""Unified decoder-only LM — covers the dense, MoE and VLM-backbone archs.

One config class parameterizes: GQA/MQA attention (RoPE, optional sliding
window, optional qkv bias), RMSNorm/LayerNorm, SwiGLU/GELU MLP or a MoE
layer, an optional bidirectional prefix (paligemma's SigLIP stub embeds),
and an optional gemma-style sqrt(d) embedding scale.

Layers are stacked with ``jax.lax.scan`` over a leading layer dim (compile
time O(1) in depth) and rematerialized per the configured policy.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .moe import MoEConfig, moe_apply, moe_specs
from .param import ParamSpec, cast_floats, round_up, stack_specs


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    norm: str = "rms"              # rms | ln
    act: str = "swiglu"            # swiglu | gelu
    window: int | None = None      # sliding-window attention
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    moe: MoEConfig | None = None
    prefix_len: int = 0            # VLM/audio stub prefix (train/prefill)
    embed_scale: bool = False      # gemma: x *= sqrt(d_model)
    remat_policy: str = "nothing"  # nothing | dots
    attn_impl: str = "reference"   # reference | blocked (flash-style)
    unroll: bool = False           # python-loop layers (dry-run cost probes)
    dtype: Any = jnp.bfloat16

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab_size, 256)

    def attn(self, prefix: int = 0) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            causal=True,
            window=self.window,
            rope_theta=self.rope_theta,
            qkv_bias=self.qkv_bias,
            prefix_len=prefix,
            impl=self.attn_impl,
        )

    @property
    def param_count(self) -> int:
        from .param import param_count

        return param_count(lm_specs(self))


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def _norm_specs(cfg: LMConfig) -> Any:
    return (
        L.rmsnorm_spec(cfg.d_model) if cfg.norm == "rms" else L.layernorm_specs(cfg.d_model)
    )


def _apply_norm(cfg: LMConfig, p: Any, x: jax.Array) -> jax.Array:
    return L.rmsnorm(p, x) if cfg.norm == "rms" else L.layernorm(p, x)


def block_specs(cfg: LMConfig) -> dict:
    specs = {
        "ln1": _norm_specs(cfg),
        "attn": L.attn_specs(cfg.attn()),
        "ln2": _norm_specs(cfg),
    }
    if cfg.moe is not None:
        specs["moe"] = moe_specs(cfg.d_model, cfg.moe)
    elif cfg.act == "swiglu":
        specs["mlp"] = L.swiglu_specs(cfg.d_model, cfg.d_ff)
    else:
        specs["mlp"] = L.gelu_mlp_specs(cfg.d_model, cfg.d_ff)
    return specs


def lm_specs(cfg: LMConfig) -> dict:
    return {
        "embed": L.embed_specs(cfg.vocab_padded, cfg.d_model),
        "blocks": stack_specs(block_specs(cfg), cfg.n_layers),
        "final_norm": _norm_specs(cfg),
    }


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _block(
    rt: L.Runtime,
    cfg: LMConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: tuple[jax.Array, jax.Array] | None = None,
    cache_pos: jax.Array | None = None,
    prefix: int = 0,
):
    h = _apply_norm(cfg, p["ln1"], x)
    a, new_cache = L.attention(
        rt, p["attn"], h, cfg.attn(prefix), positions, cache, cache_pos
    )
    x = x + a
    h = _apply_norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        m, aux = moe_apply(rt, p["moe"], h, cfg.moe)
    elif cfg.act == "swiglu":
        m = L.swiglu(rt, p["mlp"], h)
    else:
        m = L.gelu_mlp(rt, p["mlp"], h)
    x = x + m
    x = rt.shard(x, "batch", "sp", None)
    return x, new_cache, aux


def _scan_or_unroll(cfg, body, init, xs):
    """lax.scan, or a python loop when cfg.unroll (cost probes)."""
    if not cfg.unroll:
        return jax.lax.scan(body, init, xs)
    carry = init
    ys = []
    n = jax.tree.leaves(xs)[0].shape[0]
    for i in range(n):
        xi = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    stacked = jax.tree.map(lambda *a: jnp.stack(a, axis=0), *ys)
    return carry, stacked


def _remat(cfg: LMConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat_policy == "dots"
        else jax.checkpoint_policies.nothing_saveable
    )
    return jax.checkpoint(fn, policy=policy)


def forward(
    rt: L.Runtime,
    cfg: LMConfig,
    params: dict,
    tokens: jax.Array,                       # (B, S)
    prefix_embeds: jax.Array | None = None,  # (B, P, D) modality stub
) -> tuple[jax.Array, jax.Array]:
    """Training/scoring forward.  Returns (logits, aux_loss)."""
    params = cast_floats(params, cfg.dtype)
    x = L.embed(rt, params["embed"], tokens)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    prefix = 0
    if prefix_embeds is not None:
        prefix = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        x = rt.shard(x, "batch", "sp", None)
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(carry, lp):
        h, aux = carry
        h, _, a = _block(rt, cfg, lp, h, positions, prefix=prefix)
        return (h, aux + a), None

    carry = (x.astype(cfg.dtype), jnp.zeros((), jnp.float32))
    if cfg.unroll:
        rb = _remat(cfg, body)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], params["blocks"])
            carry, _ = rb(carry, lp)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(_remat(cfg, body), carry, params["blocks"])
    x = _apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(rt, params["embed"], x)
    if prefix:
        logits = logits[:, prefix:]
    return logits, aux


def loss_fn(
    rt: L.Runtime,
    cfg: LMConfig,
    params: dict,
    batch: dict,
) -> jax.Array:
    logits, aux = forward(
        rt, cfg, params, batch["tokens"], batch.get("prefix_embeds")
    )
    return L.cross_entropy(logits, batch["labels"], cfg.vocab_size) + aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with a scanned KV cache
# ---------------------------------------------------------------------------


def cache_specs(cfg: LMConfig, batch: int, max_len: int) -> dict:
    return L.init_kv_cache(cfg.attn(), batch, max_len, cfg.n_layers, cfg.dtype)


def prefill(
    rt: L.Runtime,
    cfg: LMConfig,
    params: dict,
    tokens: jax.Array,          # (B, S)
    cache: dict,                # {"k","v"}: (L, B, Smax, K, Dh)
    prefix_embeds: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Populate the cache positions [0, S); return last-token logits."""
    params = cast_floats(params, cfg.dtype)
    x = L.embed(rt, params["embed"], tokens)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    prefix = 0
    if prefix_embeds is not None:
        prefix = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)
    zero = jnp.zeros((), jnp.int32)

    def body(h, xs):
        lp, ck, cv = xs
        h, new_cache, _ = _block(
            rt, cfg, lp, h, positions, cache=(ck, cv), cache_pos=zero, prefix=prefix
        )
        return h, new_cache

    x, (ck, cv) = _scan_or_unroll(cfg, body, x.astype(cfg.dtype), (params["blocks"], cache["k"], cache["v"]))
    x = _apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(rt, params["embed"], x[:, -1:])
    return logits, {"k": ck, "v": cv}


def decode_step(
    rt: L.Runtime,
    cfg: LMConfig,
    params: dict,
    tokens: jax.Array,          # (B, 1) the newest token ids
    cache: dict,
    pos: jax.Array,             # scalar int32: current write position
) -> tuple[jax.Array, dict]:
    """One autoregressive step against a populated cache."""
    params = cast_floats(params, cfg.dtype)
    x = L.embed(rt, params["embed"], tokens)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    positions = pos[None] if pos.ndim == 0 else pos

    def body(h, xs):
        lp, ck, cv = xs
        h, new_cache, _ = _block(
            rt, cfg, lp, h, positions, cache=(ck, cv), cache_pos=pos
        )
        return h, new_cache

    x, (ck, cv) = _scan_or_unroll(cfg, body, x.astype(cfg.dtype), (params["blocks"], cache["k"], cache["v"]))
    x = _apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(rt, params["embed"], x)
    return logits, {"k": ck, "v": cv}
