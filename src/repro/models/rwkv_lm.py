"""RWKV-6 language model (attention-free; pool arch ``rwkv6-1.6b``)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .param import ParamSpec, cast_floats, round_up, stack_specs
from .rwkv6 import (
    RWKV6Config,
    channelmix_apply,
    channelmix_specs,
    rwkv6_state_specs,
    timemix_apply,
    timemix_specs,
)


@dataclass(frozen=True)
class RWKVLMConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    head_dim: int = 64
    chunk: int = 128
    remat_policy: str = "nothing"
    unroll: bool = False
    dtype: Any = jnp.bfloat16

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def inner(self) -> RWKV6Config:
        return RWKV6Config(
            d_model=self.d_model, head_dim=self.head_dim, d_ff=self.d_ff,
            chunk=self.chunk, unroll=self.unroll,
        )


def block_specs(cfg: RWKVLMConfig) -> dict:
    return {
        "ln1": L.layernorm_specs(cfg.d_model),
        "tm": timemix_specs(cfg.inner),
        "ln2": L.layernorm_specs(cfg.d_model),
        "cm": channelmix_specs(cfg.inner),
    }


def lm_specs(cfg: RWKVLMConfig) -> dict:
    return {
        "embed": L.embed_specs(cfg.vocab_padded, cfg.d_model),
        "ln_in": L.layernorm_specs(cfg.d_model),
        "blocks": stack_specs(block_specs(cfg), cfg.n_layers),
        "final_norm": L.layernorm_specs(cfg.d_model),
    }


def _block(rt, cfg, p, x, state=None):
    tm_state = None if state is None else {"s": state["tm_s"], "shift": state["tm_shift"]}
    h, tm_new = timemix_apply(rt, p["tm"], L.layernorm(p["ln1"], x), cfg.inner, tm_state)
    x = x + h
    cm_state = None if state is None else {"shift": state["cm_shift"]}
    h, cm_new = channelmix_apply(rt, p["cm"], L.layernorm(p["ln2"], x), cm_state)
    x = x + h
    new_state = None
    if state is not None:
        new_state = {
            "tm_s": tm_new["s"],
            "tm_shift": tm_new["shift"],
            "cm_shift": cm_new["shift"],
        }
    return rt.shard(x, "batch", None, None), new_state


def forward(rt, cfg: RWKVLMConfig, params, tokens):
    params = cast_floats(params, cfg.dtype)
    x = L.embed(rt, params["embed"], tokens)
    x = L.layernorm(params["ln_in"], x).astype(cfg.dtype)

    def body(h, lp):
        h, _ = _block(rt, cfg, lp, h)
        return h, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.unroll:
        for i in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda t: t[i], params["blocks"]))
    else:
        x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.layernorm(params["final_norm"], x)
    return L.unembed(rt, params["embed"], x)


def loss_fn(rt, cfg, params, batch):
    logits = forward(rt, cfg, params, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"], cfg.vocab_size)


def state_specs(cfg: RWKVLMConfig, batch: int) -> dict:
    return rwkv6_state_specs(cfg.inner, batch, cfg.n_layers)


def decode_step(rt, cfg: RWKVLMConfig, params, tokens, state, pos=None):
    """One token through the recurrent form.  tokens: (B, 1)."""
    params = cast_floats(params, cfg.dtype)
    x = L.embed(rt, params["embed"], tokens)
    x = L.layernorm(params["ln_in"], x).astype(cfg.dtype)

    def body(h, xs):
        lp, tm_s, tm_shift, cm_shift = xs
        st = {"tm_s": tm_s, "tm_shift": tm_shift, "cm_shift": cm_shift}
        h, new = _block(rt, cfg, lp, h, st)
        return h, (new["tm_s"], new["tm_shift"], new["cm_shift"])

    xs = (params["blocks"], state["tm_s"], state["tm_shift"], state["cm_shift"])
    if cfg.unroll:
        outs = []
        for i in range(cfg.n_layers):
            x, o = body(x, jax.tree.map(lambda t: t[i], xs))
            outs.append(o)
        tm_s, tm_shift, cm_shift = (
            jnp.stack([o[j] for o in outs], axis=0) for j in range(3)
        )
    else:
        x, (tm_s, tm_shift, cm_shift) = jax.lax.scan(body, x, xs)
    x = L.layernorm(params["final_norm"], x)
    logits = L.unembed(rt, params["embed"], x)
    return logits, {"tm_s": tm_s, "tm_shift": tm_shift, "cm_shift": cm_shift}
