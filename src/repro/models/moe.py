"""Mixture-of-Experts layer — GShard-style einsum dispatch.

Token-choice top-k routing with per-sequence expert capacity and dropped
overflow tokens (the standard TPU MoE formulation).  The dispatch/combine
einsums between token-sharded activations and expert-sharded weights are
what make GSPMD emit the EP All-to-All — the traffic class the paper's
hierarchical All2All (§5.1) optimizes.

Sharding strategies (both keep jit-boundary shapes evenly divisible):

* ``expert_parallel`` (dbrx, 16 experts == model axis): expert dim over
  "model"  => real EP with A2A; expert ff dim over "data" (FSDP gather).
* ``expert_tp`` (mixtral, 8 experts < model axis): expert ff dim over
  "model" (tensor-parallel experts), embed dim over "data" (FSDP gather).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import Runtime
from .param import ParamSpec


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    topk: int
    d_ff: int
    strategy: str = "expert_parallel"   # expert_parallel | expert_tp
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # §Perf knobs (EXPERIMENTS.md, dbrx hillclimb):
    reshard_tokens: bool = False   # reshard x seq->d_model before dispatch so
                                   # GSPMD lowers dispatch/combine as A2A over
                                   # the expert axis instead of full psums
    dispatch_dtype: str = "f32"    # f32 | bf16 collective payloads


def moe_specs(d_model: int, cfg: MoEConfig) -> dict:
    E, F = cfg.n_experts, cfg.d_ff
    if cfg.strategy == "expert_parallel":
        logical = ("experts", None, "moe_fsdp")
        logical_out = ("experts", "moe_fsdp", None)
    else:
        logical = (None, "moe_fsdp", "ff")
        logical_out = (None, "ff", "moe_fsdp")
    return {
        "router": ParamSpec((d_model, E), (None, None), init="scaled"),
        "w_gate": ParamSpec((E, d_model, F), logical, init="scaled"),
        "w_up": ParamSpec((E, d_model, F), logical, init="scaled"),
        "w_down": ParamSpec((E, F, d_model), logical_out, init="scaled"),
    }


def moe_apply(
    rt: Runtime, p: dict, x: jax.Array, cfg: MoEConfig
) -> tuple[jax.Array, jax.Array]:
    """Returns (output, router aux loss).  x: (B, S, D)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.topk
    C = max(1, int(S * K * cfg.capacity_factor / E))

    if cfg.reshard_tokens:
        # move the model-axis sharding from seq to d_model for the MoE body:
        # the dispatch einsum then contracts an UNSHARDED seq dim and the
        # (tokens -> experts) switch becomes an all-to-all over "model"
        x = rt.shard(x, "batch", None, "moe_d_act")

    gate_logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # (B, S, K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- capacity assignment (GShard): position of each routed token in
    # its expert's buffer; overflow beyond C is dropped --------------------
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B, S, K, E)
    # order: k-th choices of earlier tokens first
    flat = onehot.transpose(0, 2, 1, 3).reshape(B, K * S, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat          # (B, K*S, E)
    pos = pos_in_expert.reshape(B, K, S, E).transpose(0, 2, 1, 3)  # (B,S,K,E)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (B, S, K)
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch / combine tensors
    pos_onehot = jax.nn.one_hot(pos, C, dtype=x.dtype)       # (B, S, K, C)
    disp = jnp.einsum(
        "bske,bskc->bsec", onehot.astype(x.dtype) * keep[..., None].astype(x.dtype), pos_onehot
    )                                                        # (B, S, E, C)
    comb = jnp.einsum(
        "bske,bskc,bsk->bsec",
        onehot.astype(x.dtype),
        pos_onehot,
        gate_vals.astype(x.dtype),
    )

    dd = jnp.bfloat16 if cfg.dispatch_dtype == "bf16" else None
    expert_in = jnp.einsum(
        "bsec,bsd->ebcd", disp, x, preferred_element_type=dd
    )                                                        # (E, B, C, D)
    expert_in = rt.shard(expert_in, "experts_act", "batch", None, None)
    if dd is not None:
        expert_in = expert_in.astype(jnp.bfloat16)
    g = jnp.einsum("ebcd,edf->ebcf", expert_in, p["w_gate"])
    u = jnp.einsum("ebcd,edf->ebcf", expert_in, p["w_up"])
    h = jax.nn.silu(g) * u
    h = rt.shard(h, "experts_act", "batch", None, "moe_ff_act")
    eo = jnp.einsum("ebcf,efd->ebcd", h, p["w_down"])
    eo = rt.shard(eo, "experts_act", "batch", None, None)
    if dd is not None:
        eo = eo.astype(jnp.bfloat16)
    y = jnp.einsum("bsec,ebcd->bsd", comb, eo, preferred_element_type=dd)
    y = rt.shard(y, "batch", "sp", None)

    # ---- load-balancing auxiliary loss (Switch/GShard form) --------------
    me = jnp.mean(onehot[..., 0, :] if K == 1 else jnp.sum(onehot, axis=2), axis=(0, 1)) / K
    ce = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return y.astype(x.dtype), aux
