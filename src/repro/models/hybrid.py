"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block.

38 Mamba2 blocks; after every ``share_every``-th block the single shared
(weight-tied) attention+MLP block runs (zamba2's global-context injector).
Pool spec: 38L, d_model=2048, 32H GQA kv=32, d_ff=8192, ssm_state=64.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .mamba2 import (
    Mamba2Config,
    mamba2_apply,
    mamba2_specs,
    mamba2_state_specs,
)
from .param import ParamSpec, cast_floats, round_up, stack_specs


@dataclass(frozen=True)
class HybridConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    ssm_state: int = 64
    share_every: int = 6
    rope_theta: float = 10000.0
    remat_policy: str = "nothing"
    unroll: bool = False
    dtype: Any = jnp.bfloat16

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def mamba(self) -> Mamba2Config:
        return Mamba2Config(
            d_model=self.d_model,
            d_inner=2 * self.d_model,
            d_state=self.ssm_state,
            unroll=self.unroll,
        )

    @property
    def attn(self) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            causal=True,
            rope_theta=self.rope_theta,
        )

    @property
    def n_shared_calls(self) -> int:
        # shared block runs after every share_every-th mamba block EXCEPT
        # when that block is the last one (forward loop: done < n_layers)
        return (self.n_layers - 1) // self.share_every


def lm_specs(cfg: HybridConfig) -> dict:
    return {
        "embed": L.embed_specs(cfg.vocab_padded, cfg.d_model),
        "mamba_blocks": stack_specs(
            {"norm": L.rmsnorm_spec(cfg.d_model), "mamba": mamba2_specs(cfg.mamba)},
            cfg.n_layers,
        ),
        "shared": {
            "ln1": L.rmsnorm_spec(cfg.d_model),
            "attn": L.attn_specs(cfg.attn),
            "ln2": L.rmsnorm_spec(cfg.d_model),
            "mlp": L.swiglu_specs(cfg.d_model, cfg.d_ff),
        },
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }


def _tree_slice(tree, start, size):
    return jax.tree.map(lambda x: jax.lax.slice_in_dim(x, start, start + size, axis=0), tree)


def _shared_block(rt, cfg, p, x, positions, cache=None, cache_pos=None):
    h = L.rmsnorm(p["ln1"], x)
    a, new_cache = L.attention(rt, p["attn"], h, cfg.attn, positions, cache, cache_pos)
    x = x + a
    h = L.rmsnorm(p["ln2"], x)
    x = x + L.swiglu(rt, p["mlp"], h)
    return rt.shard(x, "batch", "sp", None), new_cache


def forward(rt, cfg: HybridConfig, params, tokens):
    params = cast_floats(params, cfg.dtype)
    x = L.embed(rt, params["embed"], tokens).astype(cfg.dtype)
    S = x.shape[1]
    positions = jnp.arange(S)

    def mamba_body(h, lp):
        y, _ = mamba2_apply(rt, lp["mamba"], L.rmsnorm(lp["norm"], h), cfg.mamba)
        return (h + y).astype(cfg.dtype), None

    mamba_body = jax.checkpoint(
        mamba_body, policy=jax.checkpoint_policies.nothing_saveable
    )

    done = 0
    group = cfg.share_every
    while done < cfg.n_layers:
        size = min(group, cfg.n_layers - done)
        blk = _tree_slice(params["mamba_blocks"], done, size)
        if cfg.unroll:
            for i in range(size):
                x, _ = mamba_body(x, jax.tree.map(lambda t: t[i], blk))
        else:
            x, _ = jax.lax.scan(mamba_body, x, blk)
        done += size
        if done % group == 0 and done < cfg.n_layers:
            x, _ = _shared_block(rt, cfg, params["shared"], x, positions)
    x = L.rmsnorm(params["final_norm"], x)
    return L.unembed(rt, params["embed"], x)


def loss_fn(rt, cfg, params, batch):
    logits = forward(rt, cfg, params, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"], cfg.vocab_size)


def state_specs(cfg: HybridConfig, batch: int, max_attn_len: int) -> dict:
    """Decode state: per-layer SSM states + ONE shared-attn KV cache per
    shared call site."""
    ssm = mamba2_state_specs(cfg.mamba, batch, cfg.n_layers)
    n_calls = cfg.n_shared_calls
    kv = L.init_kv_cache(cfg.attn, batch, max_attn_len, n_calls, cfg.dtype)
    return {"ssm": ssm, "kv": kv}


def decode_step(rt, cfg: HybridConfig, params, tokens, state, pos):
    params = cast_floats(params, cfg.dtype)
    x = L.embed(rt, params["embed"], tokens).astype(cfg.dtype)
    positions = pos[None] if pos.ndim == 0 else pos
    ssm, kv = state["ssm"], state["kv"]

    def mamba_body(h, xs):
        lp, hs, cs = xs
        y, new = mamba2_apply(
            rt, lp["mamba"], L.rmsnorm(lp["norm"], h), cfg.mamba,
            state={"h": hs, "conv": cs},
        )
        return (h + y).astype(cfg.dtype), (new["h"], new["conv"])

    new_h, new_conv, new_k, new_v = [], [], [], []
    done = 0
    call = 0
    group = cfg.share_every
    while done < cfg.n_layers:
        size = min(group, cfg.n_layers - done)
        blk = _tree_slice(params["mamba_blocks"], done, size)
        hs = jax.lax.slice_in_dim(ssm["h"], done, done + size, axis=0)
        cs = jax.lax.slice_in_dim(ssm["conv"], done, done + size, axis=0)
        if cfg.unroll:
            houts, couts = [], []
            for i in range(size):
                x, (ho, co) = mamba_body(
                    x, jax.tree.map(lambda t: t[i], (blk, hs, cs))
                )
                houts.append(ho)
                couts.append(co)
            h_out = jnp.stack(houts, axis=0)
            c_out = jnp.stack(couts, axis=0)
        else:
            x, (h_out, c_out) = jax.lax.scan(mamba_body, x, (blk, hs, cs))
        new_h.append(h_out)
        new_conv.append(c_out)
        done += size
        if done % group == 0 and done < cfg.n_layers:
            ck = kv["k"][call]
            cv = kv["v"][call]
            x, (nk, nv) = _shared_block(
                rt, cfg, params["shared"], x, positions,
                cache=(ck, cv), cache_pos=pos,
            )
            new_k.append(nk[None])
            new_v.append(nv[None])
            call += 1
    x = L.rmsnorm(params["final_norm"], x)
    logits = L.unembed(rt, params["embed"], x)
    new_state = {
        "ssm": {
            "h": jnp.concatenate(new_h, axis=0),
            "conv": jnp.concatenate(new_conv, axis=0),
        },
        "kv": {
            "k": jnp.concatenate(new_k, axis=0) if new_k else kv["k"],
            "v": jnp.concatenate(new_v, axis=0) if new_v else kv["v"],
        },
    }
    return logits, new_state
