"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-shim

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def rand(key, shape, dtype=jnp.float32, scale=0.5):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestFlashAttention:
    @pytest.mark.parametrize("B,K,G,S,D", [
        (1, 1, 1, 128, 64),
        (2, 2, 3, 256, 64),
        (1, 4, 2, 256, 128),
        (2, 1, 8, 128, 32),     # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, B, K, G, S, D, dtype):
        ks = jax.random.split(KEY, 3)
        q = rand(ks[0], (B, K, G, S, D), dtype)
        k = rand(ks[1], (B, K, S, D), dtype)
        v = rand(ks[2], (B, K, S, D), dtype)
        o = ops.flash_attention_bkgsd(q, k, v, causal=True)
        r = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(r, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype],
        )

    @pytest.mark.parametrize("kwargs", [
        dict(causal=True, window=64),
        dict(causal=True, prefix_len=48),
        dict(causal=False),
        dict(causal=True, window=32, prefix_len=16),
    ])
    def test_masks(self, kwargs):
        ks = jax.random.split(KEY, 3)
        q = rand(ks[0], (2, 2, 2, 256, 64))
        k = rand(ks[1], (2, 2, 256, 64))
        v = rand(ks[2], (2, 2, 256, 64))
        o = ops.flash_attention_bkgsd(q, k, v, **kwargs)
        r = ref.attention_ref(q, k, v, **kwargs)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)

    def test_block_size_invariance(self):
        ks = jax.random.split(KEY, 3)
        q = rand(ks[0], (1, 2, 2, 256, 64))
        k = rand(ks[1], (1, 2, 256, 64))
        v = rand(ks[2], (1, 2, 256, 64))
        o1 = ops.flash_attention_bkgsd(q, k, v, block_q=64, block_k=64)
        o2 = ops.flash_attention_bkgsd(q, k, v, block_q=128, block_k=128)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-6)

    def test_model_layout_wrapper(self):
        ks = jax.random.split(KEY, 3)
        B, S, N, K, D = 2, 128, 8, 2, 64
        q = rand(ks[0], (B, S, N, D))
        k = rand(ks[1], (B, S, K, D))
        v = rand(ks[2], (B, S, K, D))
        o = ops.flash_attention_bsnd(q, k, v, causal=True)
        from repro.models.layers import sdpa, _mask_bias

        qg = q.reshape(B, S, K, N // K, D)
        bias = _mask_bias(jnp.arange(S), jnp.arange(S), True, None)
        r = sdpa(qg, k, v, bias).reshape(B, S, N, D)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (1, 128, 2, 16, 16, 64),
        (2, 256, 4, 32, 16, 128),
        (1, 256, 1, 64, 64, 32),
    ])
    def test_matches_recurrence(self, B, S, H, P, N, chunk):
        ks = jax.random.split(KEY, 4)
        xh = rand(ks[0], (B, S, H, P))
        ll = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        Bm = rand(ks[2], (B, S, N))
        Cm = rand(ks[3], (B, S, N))
        y, h = ops.ssd_scan(xh, ll, Bm, Cm, chunk=chunk)
        yr, hr = ref.ssd_scan_ref(xh, ll, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-5)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=5e-5)

    def test_strong_decay_is_stable(self):
        """the failure mode that NaN'd the factored form"""
        ks = jax.random.split(KEY, 4)
        B, S, H, P, N = 1, 256, 2, 16, 16
        xh = rand(ks[0], (B, S, H, P))
        ll = jnp.full((B, S, H), -13.0)      # near-total forgetting
        Bm = rand(ks[2], (B, S, N))
        Cm = rand(ks[3], (B, S, N))
        y, h = ops.ssd_scan(xh, ll, Bm, Cm, chunk=128)
        assert np.isfinite(np.asarray(y)).all()


class TestRWKV6Scan:
    @pytest.mark.parametrize("B,S,H,N,chunk", [
        (1, 64, 1, 16, 32),
        (2, 128, 2, 32, 32),
        (1, 256, 4, 64, 128),
    ])
    def test_matches_recurrence(self, B, S, H, N, chunk):
        ks = jax.random.split(KEY, 5)
        r = rand(ks[0], (B, S, H, N))
        k = rand(ks[1], (B, S, H, N))
        v = rand(ks[2], (B, S, H, N))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N))) * 0.98 + 0.01
        u = rand(ks[4], (H, N), scale=0.3)
        y, s = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk, tile=16)
        yr, sr = ref.rwkv6_scan_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-5)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=5e-5)

    def test_extreme_decay_stable(self):
        ks = jax.random.split(KEY, 5)
        B, S, H, N = 1, 128, 1, 16
        r = rand(ks[0], (B, S, H, N))
        k = rand(ks[1], (B, S, H, N))
        v = rand(ks[2], (B, S, H, N))
        w = jnp.full((B, S, H, N), 1e-6)     # decays that overflow exp(-cum)
        u = rand(ks[4], (H, N))
        y, s = ops.rwkv6_scan(r, k, v, w, u, chunk=64)
        assert np.isfinite(np.asarray(y)).all()
        yr, sr = ref.rwkv6_scan_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4)


class TestMoEDispatch:
    @given(st.integers(1, 4), st.integers(16, 64))
    @settings(max_examples=8, deadline=None)
    def test_property_random_routing(self, e_pow, c):
        E = 2 ** e_pow
        T, D = 128, 32
        rng = np.random.default_rng(E * 100 + c)
        idx = rng.integers(0, E, T)
        disp = np.zeros((T, E, c), np.float32)
        cnt = np.zeros(E, int)
        for t in range(T):
            e = idx[t]
            if cnt[e] < c:
                disp[t, e, cnt[e]] = 1.0
                cnt[e] += 1
        disp = jnp.asarray(disp)
        x = rand(KEY, (T, D))
        out = ops.moe_dispatch(disp, x, block_t=64)
        expect = jnp.einsum("tec,td->ecd", disp, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


class TestCCUReduce:
    @pytest.mark.parametrize("P,N,block", [(2, 512, 512), (8, 2048, 512), (16, 1024, 256)])
    def test_matches_sum(self, P, N, block):
        bufs = rand(KEY, (P, N))
        out = ops.ccu_reduce(bufs, block_n=block)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.ccu_reduce_ref(bufs)), atol=1e-5
        )

    def test_int8_dequant_ingestion(self):
        """compressed-gradient ingestion: int8 peers + per-peer scales"""
        rng = np.random.default_rng(0)
        P, N = 4, 1024
        q = jnp.asarray(rng.integers(-127, 128, (P, N), dtype=np.int8))
        scales = jnp.asarray(rng.uniform(0.5, 2.0, P).astype(np.float32))
        out = ops.ccu_reduce(q, scales, block_n=512)
        expect = (np.asarray(q, np.float32) * np.asarray(scales)[:, None]).sum(0)
        np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-3)

    def test_deterministic_order(self):
        """same peers, same order => bitwise identical (CCU determinism)"""
        bufs = rand(KEY, (8, 1024))
        o1 = np.asarray(ops.ccu_reduce(bufs))
        o2 = np.asarray(ops.ccu_reduce(bufs))
        assert (o1 == o2).all()
