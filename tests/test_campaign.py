"""Monte-Carlo availability campaign tests (`runtime/campaign.py`):
seeded determinism, the recovery policy engine, netsim degraded-mesh
repricing (incremental keying + memoization), and the codesign
availability axis."""

from __future__ import annotations

from dataclasses import replace

import pytest

from repro.core.availability import PAPER_CLOS, PAPER_UB_MESH
from repro.core.codesign import (
    DesignPoint,
    GeometryCandidate,
    pareto_frontier,
    prefilter_geometries,
)
from repro.runtime.campaign import (
    CampaignConfig,
    DegradedRepricer,
    FailureEvent,
    availability_score,
    campaign_trace,
    canonical_failed_links,
    failure_class_rates,
    head_to_head,
    replay_seed,
    run_campaign,
    sample_events,
    scale_afr,
    unavailability_for_afr,
    _union_hours,
)

import numpy as np

SMOKE = GeometryCandidate(board=4, boards_per_rack=4)   # (4,4,4,4) = 256
CAL_BYTES = 4e6


@pytest.fixture(scope="module")
def smoke_campaign():
    cfg = CampaignConfig(
        candidate=SMOKE, chips=256, seeds=(0, 1, 2), size_bytes=CAL_BYTES
    )
    return run_campaign(cfg)


class TestSampling:
    def test_events_deterministic_per_seed(self):
        rates = failure_class_rates(PAPER_UB_MESH, SMOKE, 256)
        a = sample_events(rates, 672.0, np.random.default_rng(42),
                          npu_rate_per_year=30.0, n_racks=16)
        b = sample_events(rates, 672.0, np.random.default_rng(42),
                          npu_rate_per_year=30.0, n_racks=16)
        assert a == b
        c = sample_events(rates, 672.0, np.random.default_rng(43),
                          npu_rate_per_year=30.0, n_racks=16)
        assert a != c

    def test_event_rate_unbiased(self):
        rates = {"x": 632.8}
        n = np.mean([
            len(sample_events(rates, 672.0, np.random.default_rng(s)))
            for s in range(24)
        ])
        assert n == pytest.approx(632.8 * 672.0 / 8760.0, rel=0.1)

    def test_scale_afr_proportional(self):
        half = scale_afr(PAPER_CLOS, 0.5)
        assert half.total == pytest.approx(PAPER_CLOS.total / 2)
        assert half.optical_cable == pytest.approx(574.0 / 2)

    def test_union_hours_merges_overlaps(self):
        assert _union_hours([(0, 2), (1, 3), (10, 11)], 100.0) == 4.0
        assert _union_hours([(-5, 1), (99, 200)], 100.0) == 2.0
        assert _union_hours([], 100.0) == 0.0


class TestCanonicalLinks:
    def test_classes_survivable_on_smoke_pod(self):
        topo = SMOKE.pod()
        for cls in ("x_link", "y_link", "z_trunk", "a_trunk", "lrs"):
            links = canonical_failed_links(topo, cls)
            assert links, cls
            for u, v in links:
                assert topo.are_adjacent(u, v) is not None

    def test_trunk_classes_need_detour_clique(self):
        # z/a depth 2: a trunk failure leaves no same-clique relay, so
        # the class is charged availability but no measured degradation
        thin = GeometryCandidate(z_lanes=2, a_lanes=2).pod()
        assert thin.shape[2] == 4               # default is deep enough
        two_deep = replace(SMOKE, rows=2, racks_per_row=2).pod()
        assert two_deep.shape[2] == 2
        assert canonical_failed_links(two_deep, "z_trunk") == ()

    def test_staggered_lrs_leaves_every_chip_a_detour(self):
        topo = SMOKE.pod()
        links = canonical_failed_links(topo, "lrs")
        per_chip_dim: dict[tuple[int, int], int] = {}
        for u, v in links:
            d = topo.are_adjacent(u, v)
            for node in (u, v):
                per_chip_dim[(node, d)] = per_chip_dim.get((node, d), 0) + 1
        # no chip loses more than one link in any dimension's clique
        assert max(per_chip_dim.values()) == 1


class TestRepricing:
    @pytest.fixture(scope="class")
    def repricer(self):
        from repro.core.planner import best_parallel_spec
        from repro.runtime.campaign import _default_workload

        perf = SMOKE.perf_model(256, size_bytes=CAL_BYTES)
        w = _default_workload()
        spec = best_parallel_spec(w, 256, perf, rack_size=SMOKE.rack_size)
        return DegradedRepricer(
            perf, w, spec, rack_size=SMOKE.rack_size,
            hrs_count=SMOKE.superpod(256).hrs_count(),
        )

    def test_trunk_failure_reprices_through_netsim(self, repricer):
        # the degraded number comes from the flow simulator's APR reroute
        # on the failed mesh — a_trunk/lrs must cost a measurable slowdown
        assert repricer.delta_s("a_trunk") > 0.01
        assert repricer.delta_s("lrs") > 0.01

    def test_single_link_absorbed_by_detour(self, repricer):
        # the paper's graceful-degradation claim: one intra-rack cable
        # loss detours inside the 4-clique with no step-time cost
        assert repricer.delta_s("x_link") == 0.0
        assert repricer.delta_s("y_link") == 0.0

    def test_deltas_memoized(self, repricer):
        d1 = repricer.delta_s("a_trunk")
        assert repricer._memo["a_trunk"] == d1
        assert repricer.delta_s("a_trunk") == d1

    def test_degraded_axes_incremental_keying(self):
        perf = SMOKE.perf_model(256, size_bytes=CAL_BYTES)
        links = canonical_failed_links(perf.topo, "a_trunk")
        deg = replace(perf, failed_links=links)
        # chip-level trunk failures touch only the data axis: model keys
        # stay healthy cache hits, the pod axis is never degraded
        assert deg._degraded_axes() == frozenset({"data"})
        x = replace(perf, failed_links=canonical_failed_links(perf.topo, "x_link"))
        assert x._degraded_axes() == frozenset({"model"})

    def test_degraded_bandwidth_below_healthy(self):
        from repro.netsim.api import NetSim

        topo = SMOKE.pod()
        links = canonical_failed_links(topo, "a_trunk")
        req = [("data", "allreduce", None)]
        healthy = NetSim(topo).measure_profile_batch(CAL_BYTES, req)[req[0]]
        degraded = NetSim(topo, failed_links=links).measure_profile_batch(
            CAL_BYTES, req
        )[req[0]]
        assert degraded < healthy * 0.9


class TestReplayPolicyEngine:
    def _cfg(self, **kw) -> CampaignConfig:
        base = dict(candidate=SMOKE, chips=256, seeds=(0,),
                    netsim_reprice=False)
        base.update(kw)
        return CampaignConfig(**base)

    def test_replay_deterministic(self, smoke_campaign):
        a = replay_seed(smoke_campaign.config, 1, None)
        b = replay_seed(smoke_campaign.config, 1, None)
        assert a.availability == b.availability
        assert a.goodput == b.goodput
        assert a.timeline == b.timeline

    def test_backup_swap_charges_fast_mttr_only(self):
        cfg = self._cfg(npu_afr_per_year=2.0)   # dense NPU failures
        r = replay_seed(cfg, 3, None)
        swaps = [e for e in r.timeline if e["action"] == "backup_swap"]
        assert swaps
        for e in swaps:
            assert e["stall_h"] == pytest.approx(13.0 / 60.0)
        assert r.lost_work_hours == 0.0 or any(
            e["action"] != "backup_swap" for e in r.timeline
        )

    def test_clos_pays_checkpoint_restore_per_npu_failure(self):
        cfg = self._cfg(arch="clos", npu_afr_per_year=2.0)
        r = replay_seed(cfg, 3, None)
        restores = [e for e in r.timeline if e["action"] == "checkpoint_restore"]
        assert restores
        for e in restores:
            assert e["stall_h"] == pytest.approx(1.25)
            assert 0.0 <= e["lost_work_h"] <= cfg.checkpoint_interval_hours
        assert r.lost_work_hours > 0.0
        assert r.policies["backup"] == 0

    def test_spares_exhausted_falls_back_to_policy_choice(self):
        # huge NPU rate on one tiny horizon -> same rack fails repeatedly
        # before the 24 h restock, exhausting the +1 spare
        cfg = self._cfg(npu_afr_per_year=80.0, horizon_weeks=1.0)
        r = replay_seed(cfg, 0, None)
        assert r.policies["backup"] > 0
        assert r.policies["wait"] + r.policies["shrink"] > 0

    def test_network_availability_excludes_npu_stalls(self):
        # NPU-only failures: job availability dips, network metric doesn't
        cfg = self._cfg(npu_afr_per_year=5.0, profile=scale_afr(PAPER_UB_MESH, 0.0))
        r = replay_seed(cfg, 2, None)
        assert r.availability == 1.0
        assert r.job_availability < 1.0

    def test_goodput_discounts_degraded_windows(self, smoke_campaign):
        for run in smoke_campaign.runs:
            assert 0.0 <= run.goodput <= run.job_availability + 1e-9


class TestCampaignAggregation:
    def test_summary_shape(self, smoke_campaign):
        s = smoke_campaign.summary()
        assert s["arch"] == "ub-mesh"
        assert s["seeds"] == 3
        assert 0.9 <= s["availability"] <= 1.0
        assert set(s["policies"]) <= {"backup", "restore", "shrink", "wait"}
        assert s["healthy_step_s"] > 0

    def test_head_to_head_gap_band(self):
        h = head_to_head(chips=8192, seeds=tuple(range(16)),
                         netsim_reprice=False)
        assert h["ub"].availability > h["clos"].availability
        assert abs(h["availability_gap"] - 0.072) <= 0.02
        assert h["goodput_gap"] > 0

    def test_trace_export(self, smoke_campaign, tmp_path):
        run = max(smoke_campaign.runs, key=lambda r: r.n_events)
        doc = campaign_trace(run, path=str(tmp_path / "trace.json"))
        assert (tmp_path / "trace.json").exists()
        kinds = {e["ph"] for e in doc["traceEvents"]}
        assert "C" in kinds                     # goodput counter track
        if run.timeline:
            assert "X" in kinds and "i" in kinds


class TestCodesignAvailabilityAxis:
    def test_score_deterministic_and_ordered(self):
        ua = availability_score(SMOKE, 256)
        assert ua == availability_score(SMOKE, 256)
        # more chips -> more components -> strictly less available
        assert availability_score(GeometryCandidate(), 8192) > ua
        # the optical-heavy Clos profile is worse than the paper's 64-chip
        # -rack geometry at equal scale (the tiny-rack SMOKE pod is NOT —
        # 32x the racks means 32x the LRS fleet, a real co-design tension
        # the third Pareto axis is there to expose)
        from repro.core.availability import clos_afr, superpod_afr

        paper_geom = GeometryCandidate()
        assert unavailability_for_afr(
            clos_afr(8192)
        ) > unavailability_for_afr(superpod_afr(paper_geom.superpod(8192)))
        assert unavailability_for_afr(
            superpod_afr(SMOKE.superpod(8192))
        ) > unavailability_for_afr(superpod_afr(paper_geom.superpod(8192)))

    def test_three_axis_dominance(self):
        a = DesignPoint("a", 1.0, 100.0, unavailability=0.01)
        b = DesignPoint("b", 1.1, 110.0, unavailability=0.02)  # dominated
        c = DesignPoint("c", 1.1, 110.0, unavailability=0.005)  # saved by axis 3
        front = pareto_frontier([a, b, c])
        names = {p.name for p in front}
        assert names == {"a", "c"}

    def test_default_zero_axis_keeps_two_axis_behavior(self):
        a = DesignPoint("a", 1.0, 100.0)
        b = DesignPoint("b", 2.0, 200.0)
        assert {p.name for p in pareto_frontier([a, b])} == {"a"}

    def test_prefilter_availability_conjunct_winner_safe(self):
        from repro.runtime.campaign import _default_workload

        cands = [SMOKE, GeometryCandidate(board=4, boards_per_rack=4,
                                          uplink_lanes_per_rack=64)]
        w = _default_workload()
        # identical perf/tco bounds candidate can only be culled if its
        # availability is also no better — give the second candidate a
        # strictly better (lower) score and require it survives
        ua = [0.5, 0.001]
        survivors, culled, _ = prefilter_geometries(
            w, cands, 256, margin=5.0, unavailability=ua
        )
        assert cands[1] in survivors
        with pytest.raises(ValueError):
            prefilter_geometries(w, cands, 256, unavailability=[0.1])
