"""Topology co-design (ISSUE 8): Pareto dominance, the winner-safe
analytic geometry cull, cross-topology batched calibration parity, and
the Fig. 21 capex/cost-efficiency goldens.

The contracts under test:

* ``DesignPoint.__gt__`` is a strict partial order (irreflexive,
  antisymmetric) and ``pareto_frontier`` returns exactly the
  undominated set, ties included.
* ``prefilter_geometries`` never culls a candidate that the *measured*
  search would put on the frontier: the analytic step-time bounds
  bracket the netsim-measured best step (LB <= measured <= UB), and at
  the sound default margin the cull is conservative.  At ``margin=1``
  (bounds collapse to the analytic step itself) the machinery provably
  fires.
* ``perf_model.precalibrate_models`` (cross-topology batched
  calibration) produces bit-compatible measurements with each model's
  own sequential ``precalibrate`` while sharing solver sessions, and a
  reduced sweep ranks candidates identically in both modes.
* ``capex.compare_architectures`` stays on the paper's Fig. 21 numbers:
  ~2.04x cost-efficiency, 2.46x CapEx, network share 67% -> 20%.
"""

import os
import tempfile

import pytest

from repro.core import perf_model as pm
from repro.core.capex import (
    clos_bom,
    compare_architectures,
    ub_mesh_bom,
)
from repro.core.codesign import (
    DesignPoint,
    GeometryCandidate,
    enumerate_geometries,
    geometry_bounds,
    pareto_frontier,
    prefilter_geometries,
)
from repro.core.perf_model import (
    precalibrate_models,
    reset_calibration_stats,
)
from repro.core.planner import Prefilter, plan
from repro.core.topology import SuperPod
from repro.core.traffic import backend_comparison_workloads

W_DENSE, _ = backend_comparison_workloads()


def _fresh_calibration():
    pm._CALIBRATION_CACHE.clear()
    pm._DISK_CACHES.clear()
    reset_calibration_stats()


# ---------------------------------------------------------------------------
# Pareto dominance
# ---------------------------------------------------------------------------


class TestDominance:
    def test_strict_partial_order(self):
        a = DesignPoint("a", 1.0, 100.0)
        b = DesignPoint("b", 2.0, 200.0)
        assert a > b and not b > a          # antisymmetry
        assert not a > a and not b > b      # irreflexivity

    def test_equal_fitness_ties_coexist(self):
        a = DesignPoint("a", 1.0, 100.0)
        b = DesignPoint("b", 1.0, 100.0)
        assert not a > b and not b > a
        assert set(p.name for p in pareto_frontier([a, b])) == {"a", "b"}

    def test_partial_improvement_does_not_dominate(self):
        fast_pricey = DesignPoint("fast", 1.0, 200.0)
        slow_cheap = DesignPoint("cheap", 2.0, 100.0)
        assert not fast_pricey > slow_cheap
        assert not slow_cheap > fast_pricey

    def test_hand_built_frontier(self):
        pts = [
            DesignPoint("fast", 1.0, 300.0),
            DesignPoint("mid", 2.0, 200.0),
            DesignPoint("cheap", 3.0, 100.0),
            DesignPoint("dominated", 2.5, 250.0),   # beaten by "mid"
            DesignPoint("worst", 4.0, 400.0),       # beaten by all three
        ]
        front = pareto_frontier(pts)
        assert [p.name for p in front] == ["fast", "mid", "cheap"]

    def test_lt_is_the_mirror(self):
        a = DesignPoint("a", 1.0, 100.0)
        b = DesignPoint("b", 2.0, 200.0)
        assert b < a and not a < b


# ---------------------------------------------------------------------------
# Winner-safe geometry cull
# ---------------------------------------------------------------------------


def _tiny_grid():
    """A 4-candidate slice of the grid, single-pod sized for speed."""
    return enumerate_geometries(
        x_lanes=(4, 3), y_lanes=(4,), z_lanes=(2,), a_lanes=(2,),
        uplinks=(256, 64), arrangements=((4, 4),),
    )


class TestGeometryCull:
    def test_bounds_are_ordered(self):
        for b in geometry_bounds(W_DENSE, _tiny_grid(), 1024):
            assert b.step_lb_s <= b.step_ub_s
            assert b.tco > 0

    def test_margin_default_is_conservative(self):
        cands = _tiny_grid()
        survivors, culled, _ = prefilter_geometries(W_DENSE, cands, 1024)
        assert len(survivors) + len(culled) == len(cands)
        # the paper-default geometry always survives the sound margin
        assert any(c.name == GeometryCandidate().name for c in survivors)

    def test_cull_fires_at_margin_one(self):
        # margin=1 collapses UB onto LB: the cull degenerates to exact
        # analytic dominance and must remove the dominated bulk of the
        # full grid (cost-monotone at equal arrangement)
        cands = enumerate_geometries()
        survivors, culled, _ = prefilter_geometries(
            W_DENSE, cands, 8192, margin=1.0
        )
        assert len(culled) > len(cands) // 2
        assert survivors  # never empties the grid

    def test_cull_never_removes_an_analytic_frontier_member(self):
        # at margin=1 the bounds ARE the analytic objectives, so the
        # survivors must contain the full analytic Pareto frontier
        cands = enumerate_geometries()
        survivors, culled, bounds = prefilter_geometries(
            W_DENSE, cands, 8192, margin=1.0
        )
        pts = {
            b.candidate.name: DesignPoint(b.candidate.name, b.step_lb_s, b.tco)
            for b in bounds
        }
        front = {p.name for p in pareto_frontier(list(pts.values()))}
        assert front <= {c.name for c in survivors}
        assert not front & {c.name for c in culled}

    def test_unplannable_candidate_is_cullable(self):
        bounds = geometry_bounds(
            W_DENSE, [GeometryCandidate()], 1024,
            microbatch_options=(10_000_000,),   # no feasible spec
        )
        assert bounds[0].n_specs == 0
        assert bounds[0].step_lb_s == float("inf")

    def test_bounds_bracket_the_measured_step(self):
        # the soundness contract on a real netsim-measured candidate:
        # LB <= best measured step <= UB at the default margin
        cand = GeometryCandidate()
        chips = 1024
        _fresh_calibration()
        rep = plan(
            W_DENSE, chips, cand.perf_model(chips),
            rack_size=cand.rack_size, top_k=1,
            prefilter=Prefilter(keep_k=8),
        )
        (b,) = geometry_bounds(W_DENSE, [cand], chips)
        assert b.step_lb_s <= rep[0].iteration_s <= b.step_ub_s


# ---------------------------------------------------------------------------
# Cross-topology batched calibration
# ---------------------------------------------------------------------------


def _models_and_specs(cands, chips):
    from benchmarks.topo_search import _feasible_specs

    models, specs_by = [], []
    for c in cands:
        s = _feasible_specs(W_DENSE, c, chips)
        if s:
            models.append(c.perf_model(chips))
            specs_by.append(s)
    return models, specs_by


class TestCrossTopologyCalibration:
    def test_batched_matches_sequential_bitwise(self, tmp_path, monkeypatch):
        cands = _tiny_grid()[:3]
        chips = 1024

        monkeypatch.setenv("CALIB_CACHE_DIR", str(tmp_path / "seq"))
        _fresh_calibration()
        models, specs_by = _models_and_specs(cands, chips)
        for m, s in zip(models, specs_by):
            m.precalibrate(s)
        seq = dict(pm._CALIBRATION_CACHE)

        monkeypatch.setenv("CALIB_CACHE_DIR", str(tmp_path / "bat"))
        _fresh_calibration()
        models, specs_by = _models_and_specs(cands, chips)
        stats = precalibrate_models(models, specs_by)
        bat = dict(pm._CALIBRATION_CACHE)

        assert set(seq) == set(bat)
        for k in seq:
            if seq[k] is None or bat[k] is None:
                assert seq[k] == bat[k]
            else:
                assert bat[k] == pytest.approx(seq[k], abs=1e-9)
        # and the batching actually shared sessions
        assert stats["session_keys"] >= stats["sessions"]
        assert stats["deduped"] > 0

    def test_reduced_sweep_same_frontier_and_winners(self):
        from benchmarks.topo_search import _cold_sweep

        cands = _tiny_grid()
        chips = 1024
        seq = _cold_sweep(W_DENSE, chips, cands, "sequential")
        bat = _cold_sweep(W_DENSE, chips, cands, "batched")
        assert [p.name for p in seq["frontier"]] == [
            p.name for p in bat["frontier"]
        ]
        for a, b in zip(seq["points"], bat["points"]):
            assert a.name == b.name
            assert a.meta["spec"] == b.meta["spec"]
            assert a.step_time_s == pytest.approx(b.step_time_s, rel=1e-9)

    def test_cull_winner_safe_on_measured_sweep(self):
        from benchmarks.topo_search import _cold_sweep

        sweep = _cold_sweep(W_DENSE, 1024, _tiny_grid(), "batched")
        culled = set(sweep["culled"])
        frontier = {p.name for p in sweep["frontier"]}
        assert not culled & frontier


# ---------------------------------------------------------------------------
# Fig. 21 goldens (paper §6.4)
# ---------------------------------------------------------------------------


class TestFig21Goldens:
    def test_cost_efficiency_gain(self):
        ce = {r.name: r.cost_efficiency for r in compare_architectures()}
        gain = ce["UB-Mesh(4D-FM+Clos)"] / ce["Clos(x64T)"]
        assert gain == pytest.approx(2.04, rel=0.02)

    def test_capex_gain(self):
        rows = {r.name: r for r in compare_architectures()}
        gain = rows["Clos(x64T)"].capex / rows["UB-Mesh(4D-FM+Clos)"].capex
        assert gain == pytest.approx(2.46, rel=0.02)

    def test_network_share_collapse(self):
        assert clos_bom(8192).network_share() == pytest.approx(0.67, rel=0.02)
        assert ub_mesh_bom(8192).network_share() == pytest.approx(0.20, rel=0.02)

    def test_ce_ordering_matches_fig21(self):
        # UB-Mesh best, Clos worst, both hybrids strictly in between
        ce = {r.name: r.cost_efficiency for r in compare_architectures()}
        ub, clos = ce["UB-Mesh(4D-FM+Clos)"], ce["Clos(x64T)"]
        for hybrid in ("2D-FM+x16Clos", "1D-FM+x16Clos"):
            assert clos < ce[hybrid] < ub


# ---------------------------------------------------------------------------
# Satellite: uplink provisioning in the BOM
# ---------------------------------------------------------------------------


class TestUplinkProvisioning:
    def test_hrs_count_scales_with_provisioning(self):
        sp = SuperPod(n_pods=8)
        full, half = sp.hrs_count(1.0), sp.hrs_count(0.5)
        assert 0 < half < full
        assert half >= full * 0.5 - 1  # ceil granularity, never below

    def test_thin_uplink_candidate_is_cheaper(self):
        thick = GeometryCandidate(uplink_lanes_per_rack=256)
        thin = GeometryCandidate(uplink_lanes_per_rack=32)
        assert thin.bom(8192).capex() < thick.bom(8192).capex()
