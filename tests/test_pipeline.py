"""Pipeline parallelism: GPipe schedule over a real multi-device stage axis
(subprocess with 4 host devices), validated against the sequential stack."""

import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipelined_forward, stage_split

    mesh = jax.make_mesh((4,), ("stage",))
    L, D, MB, NMB = 8, 16, 4, 6
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (L, D, D)) * 0.1

    def stage_fn(p, x):            # p: (L/4, D, D) for this stage
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, p)
        return h

    x = jax.random.normal(jax.random.PRNGKey(1), (NMB, MB, D))

    # sequential reference
    def ref(x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h
    y_ref = jax.vmap(ref)(x)

    with mesh:
        fn = pipelined_forward(mesh, "stage", stage_fn, NMB)
        y = jax.jit(fn)(stage_split(ws, 4), x)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    assert err < 1e-5, err

    # the compiled HLO must carry the paper's PP pattern
    with mesh:
        txt = jax.jit(fn).lower(stage_split(ws, 4), x).compile().as_text()
    assert "collective-permute" in txt
    print("PIPELINE_OK", err)
    """
)


@pytest.mark.slow
def test_pipeline_matches_sequential():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PIPELINE_OK" in r.stdout
