"""End-to-end integration: training convergence, checkpoint-restart
equivalence, serving, and the dry-run machinery on a tiny mesh."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline, SyntheticSource
from repro.models.api import ShapeCell
from repro.models.layers import Runtime
from repro.models.param import tree_init
from repro.optim import adamw


def _train(harness, steps, params, opt_state, start=0, batch=8, seq=64):
    rt = Runtime(rules=None)
    loss_fn = harness.loss(rt)
    cfg = adamw.OptConfig(lr=1e-3, warmup_steps=2, decay_steps=steps)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, m = adamw.apply(cfg, params, grads, opt_state)
        return params, opt_state, loss

    dcfg = DataConfig(global_batch=batch, seq_len=seq, vocab_size=harness.cfg.vocab_size)
    src = SyntheticSource(dcfg)
    losses = []
    for i in range(start, steps):
        raw = src.batch_at(i)
        b = {"tokens": jnp.asarray(raw[:, :-1]), "labels": jnp.asarray(raw[:, 1:])}
        params, opt_state, loss = step(params, opt_state, b)
        losses.append(float(loss))
    return params, opt_state, losses


class TestTrainingConvergence:
    def test_loss_decreases_granite(self):
        h = load("granite-8b", smoke=True)
        params = tree_init(h.param_specs(), jax.random.PRNGKey(0), dtype=jnp.bfloat16)
        opt = adamw.init_opt_state(params)
        _, _, losses = _train(h, 40, params, opt)
        assert losses[-1] < losses[0] - 0.5

    def test_loss_decreases_rwkv(self):
        h = load("rwkv6-1.6b", smoke=True)
        params = tree_init(h.param_specs(), jax.random.PRNGKey(0), dtype=jnp.bfloat16)
        opt = adamw.init_opt_state(params)
        _, _, losses = _train(h, 40, params, opt)
        assert losses[-1] < losses[0] - 0.5


class TestCheckpointRestart:
    def test_restart_is_equivalent(self, tmp_path):
        """train 10 -> checkpoint -> train 10 more == train 20 straight"""
        h = load("granite-3-2b", smoke=True)
        params0 = tree_init(h.param_specs(), jax.random.PRNGKey(0), dtype=jnp.bfloat16)
        opt0 = adamw.init_opt_state(params0)

        pA, oA, _ = _train(h, 20, params0, opt0)

        pB, oB, _ = _train(h, 10, params0, adamw.init_opt_state(params0))
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(10, {"params": pB, "opt": oB}, blocking=True)
        restored = mgr.restore(10, {"params": pB, "opt": oB})
        pC, oC, _ = _train(h, 20, restored["params"], restored["opt"], start=10)

        for a, c in zip(jax.tree.leaves(pA), jax.tree.leaves(pC)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(c, np.float32), atol=1e-2
            )


class TestServing:
    def test_prefill_then_greedy_decode(self):
        h = load("granite-8b", smoke=True)
        params = tree_init(h.param_specs(), jax.random.PRNGKey(1), dtype=jnp.bfloat16)
        cell = ShapeCell("t", "decode", 48, 2)
        cache = tree_init(h.serve_state_specs(cell), jax.random.PRNGKey(0))
        rt = Runtime(rules=None)
        prompts = jnp.asarray(
            np.random.default_rng(0).integers(0, 64, (2, 16)), jnp.int32
        )
        logits, cache = jax.jit(h.prefill(rt))(params, cache, prompts)
        tok = jnp.argmax(logits[:, -1, : h.cfg.vocab_size], -1).astype(jnp.int32)
        decode = jax.jit(h.decode(rt))
        for i in range(4):
            logits, cache = decode(params, cache, tok[:, None], jnp.asarray(16 + i))
            tok = jnp.argmax(logits[:, -1, : h.cfg.vocab_size], -1).astype(jnp.int32)
            assert int(tok.min()) >= 0 and int(tok.max()) < h.cfg.vocab_size


class TestDryRunMachinery:
    """The dry-run itself runs as a subprocess (needs its own XLA device
    count); here we test the pieces importable under 1 device."""

    def test_collective_stats_parser(self):
        from repro.launch.hlo_stats import collective_stats

        hlo = """
  %ar = f32[1024,256]{1,0} all-reduce(f32[1024,256] %x), replica_groups=[32,16]<=[512], to_apply=%add
  %ag = bf16[512]{0} all-gather(bf16[32] %y), replica_groups={{0,1,2,3}}, dimensions={0}
  %done = f32[8]{0} all-reduce-done(f32[8] %h)
"""
        st = collective_stats(hlo)
        ar_wire = 2 * 15 / 16 * 1024 * 256 * 4
        ag_wire = 3 / 4 * 512 * 2
        assert abs(st.by_kind["all-reduce"] - ar_wire) < 1
        assert abs(st.by_kind["all-gather"] - ag_wire) < 1
        assert st.count == 2  # -done not double counted

    def test_roofline_terms(self):
        from repro.launch.hlo_stats import Roofline

        r = Roofline(flops=1.97e14, hbm_bytes=8.19e11, wire_bytes=5e10, model_flops=1e14)
        assert abs(r.compute_s - 1.0) < 1e-6
        assert abs(r.memory_s - 1.0) < 1e-6
        assert r.collective_s == 1.0
        assert r.useful_flops_ratio == pytest.approx(0.5077, abs=1e-3)

    def test_mesh_constructor_shapes(self):
        # shape math only — actual 512-device construction happens in the
        # dry-run subprocess
        from repro.launch import mesh as mesh_mod

        import inspect

        src = inspect.getsource(mesh_mod.make_production_mesh)
        assert "(2, 16, 16)" in src and "(16, 16)" in src
        assert '"pod", "data", "model"' in src.replace("'", '"')

    @pytest.mark.slow
    def test_one_dryrun_cell_subprocess(self):
        """compile one real cell on the 512-device mesh (slow ~1 min)"""
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "whisper-base", "--shape", "decode_32k", "--force"],
            capture_output=True, text=True, timeout=1200,
        )
        assert r.returncode == 0, r.stdout + r.stderr
        assert " ok " in r.stdout
