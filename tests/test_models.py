"""Per-architecture smoke tests: reduced configs, one forward/train step +
one decode step on CPU, asserting shapes and finiteness (harness deliverable
f), plus model-level invariants (causality, prefill/decode consistency)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, load
from repro.models.api import SHAPES, ShapeCell
from repro.models.layers import Runtime
from repro.models.param import param_count, tree_init

RT = Runtime(rules=None)
KEY = jax.random.PRNGKey(0)
CELL = ShapeCell("smoke", "train", 32, 2)
DECODE_CELL = ShapeCell("smoke_decode", "decode", 64, 2)


def make_batch(harness, cell):
    batch = {}
    for k, s in harness.train_input_specs(cell).items():
        if s.dtype == jnp.int32:
            batch[k] = jnp.asarray(
                np.random.default_rng(0).integers(0, 64, s.shape), jnp.int32
            )
        else:
            batch[k] = jnp.full(s.shape, 0.01, s.dtype)
    return batch


@pytest.fixture(scope="module")
def harnesses():
    return {a: load(a, smoke=True) for a in ARCH_IDS}


@pytest.fixture(scope="module")
def all_params(harnesses):
    return {a: tree_init(h.param_specs(), KEY) for a, h in harnesses.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmoke:
    def test_train_step_loss_finite(self, arch, harnesses, all_params):
        h = harnesses[arch]
        params = all_params[arch]
        batch = make_batch(h, CELL)
        loss, grads = jax.jit(jax.value_and_grad(h.loss(RT)))(params, batch)
        assert np.isfinite(float(loss))
        gnorm = sum(
            float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads)
        )
        assert np.isfinite(gnorm) and gnorm > 0

    def test_decode_step_shapes(self, arch, harnesses, all_params):
        h = harnesses[arch]
        params = all_params[arch]
        state = tree_init(h.serve_state_specs(DECODE_CELL), KEY)
        tokens = jnp.zeros((2, 1), jnp.int32) + 3
        pos = jnp.asarray(5, jnp.int32)
        logits, new_state = jax.jit(h.decode(RT))(params, state, tokens, pos)
        assert logits.shape[0] == 2 and logits.shape[1] == 1
        assert logits.shape[2] >= h.cfg.vocab_size
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # state structure preserved
        assert jax.tree.structure(new_state) == jax.tree.structure(state)

    def test_skip_matrix_matches_design(self, arch, harnesses):
        h = harnesses[arch]
        skip = h.skip_reason("long_500k")
        if arch in ("zamba2_1_2b", "rwkv6_1_6b", "mixtral_8x22b"):
            assert skip is None
        else:
            assert skip is not None
        assert h.skip_reason("train_4k") is None


class TestInvariants:
    def test_causality_dense(self):
        """perturbing a future token must not change earlier logits"""
        h = load("granite_8b", smoke=True)
        params = tree_init(h.param_specs(), KEY)
        from repro.models import transformer

        tok1 = jnp.zeros((1, 16), jnp.int32) + 5
        tok2 = tok1.at[0, 12].set(9)
        lg1, _ = transformer.forward(RT, h.cfg, params, tok1)
        lg2, _ = transformer.forward(RT, h.cfg, params, tok2)
        np.testing.assert_allclose(
            np.asarray(lg1[:, :12], np.float32),
            np.asarray(lg2[:, :12], np.float32),
            atol=1e-5,
        )
        assert not np.allclose(
            np.asarray(lg1[:, 12:], np.float32), np.asarray(lg2[:, 12:], np.float32)
        )

    def test_prefill_decode_consistency(self):
        """prefill(S tokens) then decode == prefill(S+1 tokens) logits"""
        h = load("granite_8b", smoke=True)
        params = tree_init(h.param_specs(), KEY)
        from repro.models import transformer

        S = 8
        tokens = jnp.asarray(
            np.random.default_rng(1).integers(0, 64, (2, S + 1)), jnp.int32
        )
        cell = ShapeCell("t", "decode", S + 4, 2)
        cache = tree_init(h.serve_state_specs(cell), KEY)
        lg_pre, cache = transformer.prefill(RT, h.cfg, params, tokens[:, :S], cache)
        lg_dec, _ = transformer.decode_step(
            RT, h.cfg, params, tokens[:, S:], cache, jnp.asarray(S, jnp.int32)
        )
        # reference: full forward over S+1 tokens, last position
        lg_full, _ = transformer.forward(RT, h.cfg, params, tokens)
        np.testing.assert_allclose(
            np.asarray(lg_dec[:, -1], np.float32),
            np.asarray(lg_full[:, -1], np.float32),
            atol=3e-2,  # bf16 cache
        )

    def test_rwkv_decode_matches_forward(self):
        h = load("rwkv6_1_6b", smoke=True)
        params = tree_init(h.param_specs(), KEY)
        from repro.models import rwkv_lm

        S = 12
        tokens = jnp.asarray(
            np.random.default_rng(2).integers(0, 64, (1, S)), jnp.int32
        )
        lg_full = rwkv_lm.forward(RT, h.cfg, params, tokens)
        # recurrent: feed tokens one by one
        state = tree_init(h.serve_state_specs(ShapeCell("t", "decode", S, 1)), KEY)
        outs = []
        for t in range(S):
            lg, state = rwkv_lm.decode_step(
                RT, h.cfg, params, tokens[:, t : t + 1], state, jnp.asarray(t)
            )
            outs.append(lg[:, 0])
        lg_rec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(lg_rec, np.float32), np.asarray(lg_full, np.float32),
            atol=5e-2,
        )

    def test_sliding_window_limits_context(self):
        """starcoder2 SWA: tokens beyond the window have no influence"""
        h = load("starcoder2_7b", smoke=True)   # window=64 in smoke
        params = tree_init(h.param_specs(), KEY)
        from repro.models import transformer

        S = 128
        base = np.random.default_rng(3).integers(0, 64, (1, S))
        t1 = jnp.asarray(base, jnp.int32)
        pert = base.copy()
        pert[0, 0] = (pert[0, 0] + 7) % 64
        t2 = jnp.asarray(pert, jnp.int32)
        lg1, _ = transformer.forward(RT, h.cfg, params, t1)
        lg2, _ = transformer.forward(RT, h.cfg, params, t2)
        # with 2 layers x window 64, influence dies beyond ~2*64 tokens
        np.testing.assert_allclose(
            np.asarray(lg1[:, -1], np.float32), np.asarray(lg2[:, -1], np.float32),
            atol=1e-5,
        )

    def test_param_counts_full_configs(self):
        """full (non-smoke) configs land near their nameplate sizes"""
        expect = {
            "granite_8b": (7e9, 10e9),
            "phi4_mini_3_8b": (3e9, 5.5e9),
            "granite_3_2b": (2e9, 3.3e9),
            "starcoder2_7b": (6e9, 9e9),
            "zamba2_1_2b": (0.9e9, 1.9e9),
            "rwkv6_1_6b": (1.3e9, 2.3e9),
            "mixtral_8x22b": (120e9, 160e9),
            "dbrx_132b": (110e9, 150e9),
            "whisper_base": (0.04e9, 0.12e9),
            "paligemma_3b": (2e9, 4e9),
        }
        for arch, (lo, hi) in expect.items():
            n = param_count(load(arch).param_specs())
            assert lo < n < hi, f"{arch}: {n:.3g} params not in ({lo:.2g},{hi:.2g})"
