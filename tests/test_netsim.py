"""repro.netsim: event engine, fluid fair sharing, APR routing, collectives.

Covers the subsystem's contract: deterministic event order, per-flow byte
conservation, the max-min fair-share capacity invariant, agreement with the
analytic multi-ring model on uncongested cliques, Fig. 19 strategy
ordering under contention, and completion under link failure.
"""

import math

import pytest

from repro.core.cost_model import Routing
from repro.core.multiring import plan_multiring
from repro.core.topology import (
    ACTIVE_ELECTRICAL,
    DimSpec,
    NDFullMesh,
    OPTICAL_100M,
    PASSIVE_ELECTRICAL,
    ub_mesh_rack,
)
from repro.netsim import (
    EventEngine,
    FluidNetwork,
    NetSim,
    Router,
    Telemetry,
    hotspot_dag,
    ring_allreduce,
    trunk_congestion,
)
from repro.netsim.collectives import clique_nodes, hierarchical_allreduce
from repro.netsim.scenarios import inter_rack_mesh as mesh_2d


class TestEventEngine:
    def test_fires_in_time_then_seq_order(self):
        eng = EventEngine()
        fired = []
        eng.schedule(2.0, lambda: fired.append("late"))
        eng.schedule(1.0, lambda: fired.append("a"))
        eng.schedule(1.0, lambda: fired.append("b"))  # same time: seq order
        eng.run()
        assert fired == ["a", "b", "late"]
        assert eng.now == 2.0

    def test_cancel_is_skipped(self):
        eng = EventEngine()
        fired = []
        ev = eng.schedule(1.0, lambda: fired.append("x"))
        eng.schedule(2.0, lambda: fired.append("y"))
        ev.cancel()
        eng.run()
        assert fired == ["y"]

    def test_no_scheduling_in_the_past(self):
        eng = EventEngine()
        eng.schedule(1.0, lambda: None)
        eng.run()
        with pytest.raises(ValueError):
            eng.schedule_at(0.5, lambda: None)

    def test_budget_raises_before_excess_event_fires(self):
        # the guard must trip BEFORE event max_events+1 runs: exactly
        # max_events callbacks fire, the raise preempts the next one
        eng = EventEngine()
        fired = []
        for i in range(5):
            eng.schedule(float(i + 1), lambda i=i: fired.append(i))
        with pytest.raises(RuntimeError, match="event budget"):
            eng.run(max_events=3)
        assert fired == [0, 1, 2]
        assert eng.events_fired == 3

    def test_until_advances_now_when_queue_drains_early(self):
        # run(until=T) with the last event before T must still land now
        # exactly on T, so back-to-back windows tile virtual time
        eng = EventEngine()
        eng.schedule(0.25, lambda: None)
        assert eng.run(until=1.0) == 1.0
        assert eng.now == 1.0
        # an empty queue behaves the same
        assert eng.run(until=2.0) == 2.0
        assert eng.now == 2.0
        # and a future event past the window is untouched
        fired = []
        eng.schedule_at(5.0, lambda: fired.append("x"))
        assert eng.run(until=3.0) == 3.0
        assert not fired
        assert eng.pending == 1


class TestFairShare:
    def test_single_flow_gets_full_link(self):
        topo = ub_mesh_rack()
        net = FluidNetwork(topo)
        done = []
        net.add_flow((0, 1), 25e9, on_complete=lambda f: done.append(f))
        net.run()
        # X link = 4 lanes * 6.25 GB/s: 25 GB in exactly 1 s
        assert done and math.isclose(net.engine.now, 1.0, rel_tol=1e-9)

    def test_two_flows_share_one_link_fairly(self):
        topo = ub_mesh_rack()
        net = FluidNetwork(topo)
        net.add_flow((0, 1), 25e9)
        net.add_flow((0, 1), 25e9)
        net.run()
        assert math.isclose(net.engine.now, 2.0, rel_tol=1e-9)

    def test_rates_never_exceed_capacity(self):
        topo = mesh_2d()
        net = FluidNetwork(topo, record_rates=True)
        router = Router(net, Routing.DETOUR)
        for t in hotspot_dag(topo).tasks:
            router.send(t.src, t.dst, t.size)
        net.run()
        assert net.rate_log, "no rate snapshots recorded"
        for _t, _l, used, cap in net.rate_log:
            assert used <= cap * (1 + 1e-6) + 1e-3

    def test_byte_conservation_single_paths(self):
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        dag = ring_allreduce(topo, nodes, 32e6)
        sim = NetSim(topo, routing=Routing.DETOUR)
        r = sim.run_dag(dag)
        net = sim.last_network
        assert r.incomplete == 0
        # every launched flow delivered exactly its size (aggregate ring
        # steps deliver size x multiplicity)...
        assert not net.flows
        total_flow = sum(f.total_bytes for f in net.completed.values())
        assert math.isclose(total_flow, dag.total_bytes, rel_tol=1e-9)
        # ...and each byte crossed exactly one link (1-hop ring steps)
        assert math.isclose(
            sum(net.link_bytes.values()), dag.total_bytes, rel_tol=1e-6
        )

    def test_byte_conservation_across_source_cut_multipath(self):
        # adaptive re-splitting must not resend or drop bytes: everything a
        # transfer delivers crosses the {src} cut exactly once
        topo = mesh_2d()
        net = FluidNetwork(topo)
        router = Router(net, Routing.DETOUR)
        src, dst = topo.node_id((0, 0)), topo.node_id((1, 1))
        size = 16e6
        router.send(src, dst, size)
        net.run()
        egress = sum(
            b for (u, _v), b in net.link_bytes.items() if u == src
        )
        assert math.isclose(egress, size, rel_tol=1e-6)


class TestDeterminism:
    def test_identical_runs_identical_traces(self):
        topo = mesh_2d()
        dag = hotspot_dag(topo)
        r1 = NetSim(topo, routing=Routing.DETOUR).run_dag(dag)
        r2 = NetSim(topo, routing=Routing.DETOUR).run_dag(dag)
        assert r1.task_end_s == r2.task_end_s     # exact float equality
        assert r1.events == r2.events
        assert r1.link_utilization == r2.link_utilization


class TestAnalyticAgreement:
    @pytest.mark.parametrize("n,lanes", [(5, 4), (8, 4), (4, 2)])
    def test_clique_allreduce_within_15pct(self, n, lanes):
        # odd n: Walecki cycles; even n: zig-zag chains — both must agree
        topo = NDFullMesh(dims=(DimSpec("X", n, PASSIVE_ELECTRICAL, lanes),))
        size = 48e6
        sim = NetSim(topo, routing=Routing.DETOUR)
        t = sim.allreduce_time(0, size)
        ta = plan_multiring(topo, 0).allreduce_time_s(size)
        assert abs(t - ta) / ta <= 0.15

    def test_hierarchical_allreduce_runs_full_2d(self):
        topo = mesh_2d(3, 3)
        dag = hierarchical_allreduce(topo, (0, 1), 8e6)
        r = NetSim(topo, routing=Routing.DETOUR).run_dag(dag)
        assert r.incomplete == 0
        assert r.makespan_s > 0


class TestGridMultiRing:
    def test_grid_allreduce_completes_and_beats_hierarchical(self):
        from repro.netsim.collectives import grid_allreduce

        topo = ub_mesh_rack()
        size = 64e6
        sim = NetSim(topo, routing=Routing.DETOUR)
        grid = sim.run_dag(grid_allreduce(topo, (0, 1), size))
        hier = sim.run_dag(hierarchical_allreduce(topo, (0, 1), size))
        assert grid.incomplete == 0
        # both dims' links carry traffic in the same run, so the joint
        # schedule must finish well ahead of the phase-per-dim one
        assert grid.makespan_s < hier.makespan_s * 0.75

    @pytest.mark.slow
    def test_calibrated_model_axis_reaches_80pct_of_analytic(self):
        # the tentpole acceptance number: cross-dim 2D multi-ring lifts the
        # measured "model"-axis bandwidth from ~87-95 GB/s (hierarchical)
        # to >= 160 GB/s = 80% of the analytic 200 GB/s (per-chip X+Y
        # multi-ring allocation) at a bandwidth-dominated payload
        from repro.core.cost_model import build_comm_model

        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        analytic_model_gbs = comm.axes["model"].gbs_per_chip
        sim = NetSim(ub_mesh_rack(), routing=Routing.DETOUR)
        cal = sim.calibrated_axis_gbs(512e6, comm=comm)
        assert cal["model"] >= 160.0
        assert cal["model"] >= 0.80 * analytic_model_gbs


class TestIncast:
    """Receiver-egress caps: many-to-one bursts serialize (ISSUE 3)."""

    def test_n_to_one_takes_n_times_single_flow_under_egress_cap(self):
        # 7 senders on 7 DISTINCT X links into node 0: the fluid model
        # resolves this at full rate per link; under an egress cap of one
        # link's bandwidth it must take ~7x the single-flow time
        topo = ub_mesh_rack()
        x_gbs = topo.dims[0].gbs_per_peer
        net = FluidNetwork(topo, rx_gbs=x_gbs)
        net.add_flow((1, 0), 25e9)
        net.run()
        t1 = net.engine.now
        net = FluidNetwork(topo, rx_gbs=x_gbs)
        for s in range(1, 8):
            net.add_flow((s, 0), 25e9)
        net.run()
        assert math.isclose(net.engine.now, 7 * t1, rel_tol=1e-6)
        # without the cap the same burst resolves in single-flow time
        net = FluidNetwork(topo)
        for s in range(1, 8):
            net.add_flow((s, 0), 25e9)
        net.run()
        assert math.isclose(net.engine.now, t1, rel_tol=1e-6)

    def test_rx_cap_never_exceeded(self):
        # sum of inbound flow rates at a capped node stays <= the cap
        topo = ub_mesh_rack()
        cap_gbs = 40.0
        net = FluidNetwork(topo, rx_gbs=cap_gbs)
        for s in range(1, 8):
            net.add_flow((s, 0), 5e9)
        net._recompute()
        inbound = sum(
            f.rate for f in net.flows.values() if f.path[-1] == 0
        )
        assert inbound <= cap_gbs * 1e9 * (1 + 1e-6)

    def test_moe_dispatch_strictly_slower_than_incast_blind_fluid(self):
        # 64 token-holders dispatching to 4 hot expert chips: the MoE
        # all_to_all burst must strictly exceed its no-incast fluid time
        from repro.netsim.collectives import model_group, moe_dispatch

        topo = ub_mesh_rack()
        dag = moe_dispatch(
            topo, list(range(topo.num_nodes)), model_group(topo, 4), 16e6
        )
        capped = NetSim(topo, routing=Routing.DETOUR).run_dag(dag)
        fluid = NetSim(topo, routing=Routing.DETOUR, rx_gbs=None).run_dag(dag)
        assert capped.incomplete == 0 and fluid.incomplete == 0
        assert capped.makespan_s > fluid.makespan_s * 1.2

    def test_default_rx_cap_preserves_multiring_allreduce(self):
        # the auto cap (largest per-dim clique allocation) must NOT slow
        # the multi-ring AllReduce: <= one inbound flow per ring per node
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        dag = ring_allreduce(topo, nodes, 32e6)
        with_cap = NetSim(topo, routing=Routing.DETOUR).run_dag(dag)
        without = NetSim(topo, routing=Routing.DETOUR, rx_gbs=None).run_dag(dag)
        assert math.isclose(
            with_cap.makespan_s, without.makespan_s, rel_tol=1e-9
        )


class TestCalibrationProfile:
    """(axis, collective-shape)-keyed calibration (ISSUE 3 tentpole)."""

    def test_a2a_calibrated_at_most_allreduce_on_model_axis(self):
        # the crossval contract: the Multi-Path A2A rides relay hops and
        # the cross-board cut, so its effective bandwidth must sit at or
        # below (in practice far below) the multi-ring AllReduce number
        from repro.core.cost_model import build_comm_model

        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        sim = NetSim(ub_mesh_rack(), routing=Routing.DETOUR)
        prof = sim.calibrated_profile(
            16e6, comm=comm, axes=("model",),
            shapes=("allreduce", "all_to_all"),
        )
        ar = prof.get("model", "allreduce")
        a2a = prof.get("model", "all_to_all")
        assert ar is not None and a2a is not None
        assert a2a <= ar
        assert a2a < 0.6 * ar          # relay + cut effects are large

    def test_reduce_scatter_aliases_all_gather(self):
        from repro.core.cost_model import build_comm_model

        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        sim = NetSim(ub_mesh_rack(), routing=Routing.DETOUR)
        prof = sim.calibrated_profile(
            8e6, comm=comm, axes=("model",),
            shapes=("all_gather", "reduce_scatter"),
        )
        assert prof.get("model", "reduce_scatter") == prof.get(
            "model", "all_gather"
        )

    def test_calibrated_axis_gbs_matches_profile_allreduce(self):
        # the legacy scalar entry point is the allreduce slice of the
        # profile — back-compat for PR-2 consumers
        sim = NetSim(ub_mesh_rack(), routing=Routing.DETOUR)
        scalar = sim.calibrated_axis_gbs(8e6)
        prof = sim.calibrated_profile(8e6, shapes=("allreduce",))
        assert scalar["model"] == pytest.approx(
            prof.get("model", "allreduce")
        )

    def test_profile_apply_prices_shapes_separately(self):
        from repro.core.cost_model import (
            CalibrationProfile, build_comm_model,
        )

        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        prof = CalibrationProfile(
            gbs={("model", "allreduce"): 140.0, ("model", "all_to_all"): 45.0}
        )
        cm = prof.apply(comm)
        size = 64e6
        assert cm.axes["model"].gbs_per_chip == pytest.approx(140.0)
        # A2A rides its own (much lower) measured bandwidth...
        assert cm.all_to_all("model", size) > comm.all_to_all("model", size)
        # ...while an unmeasured axis is untouched
        assert cm.axes["data"] == comm.axes["data"]


class TestRoutingPolicies:
    def test_fig19_ordering_under_contention(self):
        topo = mesh_2d()
        dag = hotspot_dag(topo)
        total = sum(t.size for t in dag.tasks)
        tput = {}
        for pol in (Routing.SHORTEST, Routing.DETOUR, Routing.BORROW):
            r = NetSim(topo, routing=pol).run_dag(dag)
            assert r.incomplete == 0
            tput[pol] = total / r.makespan_s
        assert tput[Routing.SHORTEST] < tput[Routing.DETOUR] < tput[Routing.BORROW]

    def test_detour_splits_isolated_transfer_over_disjoint_paths(self):
        topo = mesh_2d()
        net = FluidNetwork(topo)
        router = Router(net, Routing.DETOUR)
        paths = router.candidate_paths(
            topo.node_id((0, 0)), topo.node_id((1, 1))
        )
        assert len(paths) >= 2
        used = set()
        for p in paths:
            edges = {tuple(sorted(e)) for e in zip(p, p[1:])}
            assert not (edges & used)
            used |= edges


class TestFailureRecovery:
    def test_failure_reroute_completes_all_flows(self):
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        dag = ring_allreduce(topo, nodes, 32e6)
        sim = NetSim(topo, routing=Routing.DETOUR)
        healthy = sim.run_dag(dag)
        failed = sim.run_dag(
            dag,
            fail_link=(nodes[0], nodes[1]),
            fail_at_s=healthy.makespan_s / 3,
        )
        assert failed.incomplete == 0
        assert failed.bytes_delivered == pytest.approx(dag.total_bytes)
        assert failed.makespan_s >= healthy.makespan_s * 0.999
        # the failed link carried nothing after the failure instant
        net = sim.last_network
        a, b = nodes[0], nodes[1]
        assert net.effective_capacity((a, b)) == 0.0

    def test_failure_before_start_avoids_link_entirely(self):
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        dag = ring_allreduce(topo, nodes, 8e6)
        sim = NetSim(topo, routing=Routing.DETOUR)
        r = sim.run_dag(dag, fail_link=(nodes[2], nodes[3]), fail_at_s=0.0)
        assert r.incomplete == 0
        net = sim.last_network
        u, v = nodes[2], nodes[3]
        assert net.link_bytes.get((u, v), 0.0) == 0.0
        assert net.link_bytes.get((v, u), 0.0) == 0.0


class TestWorkloadRun:
    def test_moe_workload_collectives_complete(self):
        # tiny 4D mesh keeps the DAGs small but exercises every technique
        topo = NDFullMesh(
            dims=(
                DimSpec("X", 4, PASSIVE_ELECTRICAL, 4),
                DimSpec("Y", 2, PASSIVE_ELECTRICAL, 4),
                DimSpec("Z", 2, ACTIVE_ELECTRICAL, 2),
                DimSpec("A", 2, OPTICAL_100M, 2),
            )
        )
        from repro.core.traffic import ParallelSpec, WorkloadSpec

        w = WorkloadSpec(
            name="tiny-moe", n_layers=4, hidden=1024, n_heads=8, head_dim=64,
            seq_len=4096, global_batch=16, params_total=1e9,
            n_experts=4, topk=2,
        )
        p = ParallelSpec(tp=4, sp=2, pp=2, dp=2, ep=2, microbatches=4)
        r = NetSim(topo, routing=Routing.DETOUR).run(w, p)
        assert r.incomplete == 0
        assert set(r.collective_s) == {"TP", "SP", "EP", "PP", "DP"}
        assert all(v > 0 for v in r.collective_s.values())
        assert r.iteration_comm_s > 0

    def test_tp_group_width_respected(self):
        # tp*sp=16 on the 64-chip rack: the TP DAG must span exactly the
        # 16-chip group (full X clique x 2 Y boards), not the whole plane
        from repro.core.traffic import ParallelSpec
        from repro.netsim.collectives import compile_traffic_entry

        topo = ub_mesh_rack()
        p = ParallelSpec(tp=8, sp=2, pp=1, dp=1)
        dag = compile_traffic_entry(topo, "TP", 8e6, p)
        touched = {n for t in dag.tasks for n in t.endpoints()}
        assert len(touched) == 16
        assert all(topo.coords(n)[1] < 2 for n in touched)

    def test_calibration_feeds_simulator_via_perf_model(self):
        from repro.core.cost_model import build_comm_model
        from repro.core.perf_model import AnalyticPerfModel
        from repro.core.simulator import simulate
        from repro.core.traffic import moe_2t_workload

        topo = ub_mesh_rack()
        sim = NetSim(topo, routing=Routing.DETOUR)
        cal = sim.calibrated_axis_gbs(4e6)
        assert "model" in cal and cal["model"] > 0
        w, p = moe_2t_workload()
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        base = simulate(w, p, comm)
        over = simulate(w, p, AnalyticPerfModel(comm, axis_gbs=cal))
        # calibrated bandwidth <= idealized analytic => no faster iteration
        assert over.iteration_s >= base.iteration_s * 0.999


class TestScenarios:
    def test_trunk_congestion_geometry(self):
        sc = trunk_congestion()
        src = sc.topo.node_id((0, 0))
        assert sc.hot_link == (src, sc.topo.node_id((1, 0)))
        assert len(sc.dag.tasks) == 3
        # never sends to (1, 0) directly: the trunk is only ever a relay
        dsts = {t.dst for t in sc.dag.tasks}
        assert sc.hot_link[1] not in dsts
        assert all(t.src == src for t in sc.dag.tasks)
        assert sc.rx_gbs == pytest.approx(sc.topo.dims[0].gbs_per_peer / 2)

    def test_trunk_congestion_validates_geometry(self):
        with pytest.raises(ValueError):
            trunk_congestion(z=1)
        with pytest.raises(ValueError):
            trunk_congestion(a=4, fan=4)     # fan must leave (1,0) alone

    def test_shortest_saturates_trunk_and_attribution_names_it(self):
        sc = trunk_congestion()
        sim = NetSim(
            sc.topo, routing=Routing.SHORTEST, rx_gbs=sc.rx_gbs,
            telemetry=True,
        )
        res = sim.run_dag(sc.dag)
        assert res.incomplete == 0
        tel = res.telemetry
        assert tel.peak_utilization(sc.hot_link) == pytest.approx(1.0)
        # every flow rides the trunk and the solver blames it, not rx
        assert set(tel.flow_bottlenecks().values()) == {sc.hot_link}

    def test_borrow_relieves_trunk(self):
        sc = trunk_congestion()
        peaks = {}
        for pol in (Routing.SHORTEST, Routing.BORROW):
            sim = NetSim(
                sc.topo, routing=pol, rx_gbs=sc.rx_gbs, telemetry=True
            )
            res = sim.run_dag(sc.dag)
            assert res.incomplete == 0
            peaks[pol] = res.telemetry.peak_utilization(sc.hot_link)
        assert peaks[Routing.BORROW] < peaks[Routing.SHORTEST] - 0.2


class TestTelemetry:
    def test_disabled_by_default_and_zero_cost(self):
        topo = ub_mesh_rack()
        sim = NetSim(topo, routing=Routing.DETOUR)
        res = sim.run_dag(ring_allreduce(topo, clique_nodes(topo, 0), 8e6))
        assert res.telemetry is None
        assert sim.last_telemetry is None
        net = sim.last_network
        assert net.telemetry is None
        # the solver skips attribution work entirely when nobody listens
        assert net.solver.last_attribution is None

    def test_timeline_integral_matches_byte_ledger(self):
        topo = mesh_2d()
        tel = Telemetry()
        net = FluidNetwork(topo, telemetry=tel)
        router = Router(net, Routing.DETOUR)
        for t in hotspot_dag(topo).tasks:
            router.send(t.src, t.dst, t.size)
        net.run()
        assert net.link_bytes, "scenario must use links"
        for link, b in net.link_bytes.items():
            assert tel.link_bytes(link) == pytest.approx(b, rel=1e-6)
        # and links the ledger never saw are absent from the series too
        assert set(tel.link_series) <= set(net.link_bytes)

    def test_summary_schema_and_byte_audit(self):
        sc = trunk_congestion()
        sim = NetSim(
            sc.topo, routing=Routing.DETOUR, rx_gbs=sc.rx_gbs, telemetry=True
        )
        res = sim.run_dag(sc.dag)
        s = res.telemetry.summary()
        assert set(s) == {
            "duration_s", "events", "solver_samples", "links",
            "bottlenecks", "flows", "router",
        }
        assert s["duration_s"] == pytest.approx(res.makespan_s)
        assert s["solver_samples"] > 0
        assert s["links"]["top"] and "peak_util" in s["links"]["top"][0]
        assert set(s["links"]["per_dim"]) <= {"Z", "A"}
        f = s["flows"]
        # congestion re-splits withdraw subflows and relaunch the
        # remainder, so launched = completed + withdrawn — and the byte
        # audit still closes over the withdrawn-unsent bucket
        assert f["launched"] == f["completed"] + f["withdrawn"]
        assert f["bytes_delivered"] + f["bytes_withdrawn_unsent"] == (
            pytest.approx(f["bytes_requested"])
        )
        assert abs(f["stranded_bytes"]) < 1.0
        # detour throttles on the rx cap: the class accounting must see it
        assert s["bottlenecks"]["by_class"].get("rx", 0.0) > 0.0

    def test_perfetto_export_is_valid_trace_json(self, tmp_path):
        import json

        sc = trunk_congestion()
        sim = NetSim(
            sc.topo, routing=Routing.BORROW, rx_gbs=sc.rx_gbs, telemetry=True
        )
        res = sim.run_dag(sc.dag)
        path = tmp_path / "trace.json"
        trace = res.telemetry.to_perfetto(str(path))
        on_disk = json.loads(path.read_text())
        assert on_disk == trace
        evs = trace["traceEvents"]
        phases = {e["ph"] for e in evs}
        assert {"M", "C", "X", "b", "e"} <= phases
        assert all(
            e["ts"] >= 0 for e in evs if "ts" in e
        )
        # async transfer spans pair up per id
        b_ids = sorted(e["id"] for e in evs if e["ph"] == "b")
        e_ids = sorted(e["id"] for e in evs if e["ph"] == "e")
        assert b_ids == e_ids and len(b_ids) == len(sc.dag.tasks)
        # counter samples never exceed capacity
        assert all(
            0.0 <= e["args"]["util"] <= 1.0 + 1e-9
            for e in evs if e["ph"] == "C"
        )

    def test_failure_instants_and_reroute_counters(self):
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        dag = ring_allreduce(topo, nodes, 32e6)
        sim = NetSim(topo, routing=Routing.DETOUR, telemetry=True)
        healthy = sim.run_dag(dag)
        failed = sim.run_dag(
            dag,
            fail_link=(nodes[0], nodes[1]),
            fail_at_s=healthy.makespan_s / 3,
        )
        assert failed.incomplete == 0
        tel = failed.telemetry
        assert tel is not healthy.telemetry     # fresh recorder per run
        c = tel.router_counters
        assert c["link_failures"] == 1
        assert c["reroutes"] >= 1
        names = [name for _, name, _ in tel.instants]
        assert "link_failures" in names and "reroutes" in names
        t_fail = next(
            t for t, name, _ in tel.instants if name == "link_failures"
        )
        assert t_fail == pytest.approx(healthy.makespan_s / 3)
        # withdrawn flows keep the byte audit closed
        f = tel.summary()["flows"]
        assert f["withdrawn"] >= 1
        assert f["bytes_delivered"] + f["bytes_withdrawn_unsent"] == (
            pytest.approx(f["bytes_requested"])
        )

    def test_one_recorder_per_network(self):
        tel = Telemetry()
        FluidNetwork(ub_mesh_rack(), telemetry=tel)
        with pytest.raises(ValueError):
            FluidNetwork(ub_mesh_rack(), telemetry=tel)
