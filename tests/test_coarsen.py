"""Rack-coarsened SuperPod calibration (netsim/coarsen.py).

Contracts:
* the coarse mesh's aggregate capacities follow from SuperPod geometry
  (trunked inter-rack cliques, one HRS uplink of IO per rack),
* coarse-measured cross-pod DP bandwidth lands within 20% of the analytic
  DCN ("pod" axis) model on an uncontended config — the acceptance bar —
  and coarse-measured inter-rack ("data") bandwidth within a few % of the
  exact chip-level pod measurement,
* ``NetsimPerfModel(superpod=...)`` prices the pod axis on the coarse
  measurement (memo key carries the coarsening level) and a 4-pod
  4096-chip ``plan()`` stays fast.
"""

import time
from dataclasses import replace

import pytest

from repro.core.cost_model import Routing, build_comm_model
from repro.core.perf_model import NetsimPerfModel
from repro.core.topology import SuperPod, ub_mesh_pod
from repro.netsim import NetSim
from repro.netsim.coarsen import (
    MixedMesh,
    coarse_calibrated_profile,
    coarse_netsim,
    coarsen_superpod,
    cross_pod_background_dag,
    mixed_calibrated_profile,
    mixed_netsim,
)
from repro.netsim.collectives import (
    FlowDAG,
    clique_nodes,
    ring_allreduce,
    splice_dag,
)


@pytest.fixture(scope="module")
def superpod4() -> SuperPod:
    return SuperPod(pod=ub_mesh_pod(), n_pods=4)


@pytest.fixture(scope="module")
def mixed4(superpod4):
    """The 4-pod SuperPod with rack 0 = (Z0, A0, pod 0) at chip level."""
    return coarsen_superpod(superpod4, detail_racks=(0,))


class TestCoarseMesh:
    def test_rack_level_geometry(self, superpod4):
        cm = coarsen_superpod(superpod4)
        pod = superpod4.pod
        assert cm.topo.shape == (pod.shape[2], pod.shape[3], 4)
        assert cm.chips_per_node == pod.shape[0] * pod.shape[1]
        assert cm.num_chips == superpod4.num_nodes == 4096
        # trunk aggregation: 64 chips x 2 lanes x 6.25 GB/s = 800 per peer
        assert cm.topo.dims[0].gbs_per_peer == pytest.approx(
            cm.chips_per_node * pod.dims[2].gbs_per_peer
        )
        # the HRS dim carries the full uplink per pair, capped per rack
        uplink = superpod4.uplink_lanes_per_rack * 6.25
        assert cm.topo.dims[2].gbs_per_peer == pytest.approx(uplink)
        assert cm.dim_io_gbs == {2: pytest.approx(uplink)}
        assert cm.axis_dims == {"data": (0, 1), "pod": (2,)}

    def test_pod_level_geometry(self, superpod4):
        cm = coarsen_superpod(superpod4, level="pod")
        assert cm.topo.shape == (4,)
        assert cm.chips_per_node == superpod4.pod.num_nodes
        assert cm.axis_dims == {"pod": (0,)}

    def test_unknown_level_rejected(self, superpod4):
        with pytest.raises(ValueError):
            coarsen_superpod(superpod4, level="board")

    def test_single_pod_has_no_hrs_dim(self):
        cm = coarsen_superpod(SuperPod(pod=ub_mesh_pod(), n_pods=1))
        assert "pod" not in cm.axis_dims
        assert cm.dim_io_gbs == {}


class TestCoarseAccuracy:
    def test_cross_pod_dp_bw_within_20pct_of_analytic(self, superpod4):
        # uncontended cross-pod DP: the HRS tier is a non-blocking Clos,
        # so the measured AllReduce bandwidth must track the analytic
        # uplink allocation (25 GB/s per chip) within the 20% bar
        comm = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        cm = coarsen_superpod(superpod4)
        prof = coarse_calibrated_profile(
            cm, 64e6, axis_sizes={"pod": 4}, axes=("pod",),
            shapes=("allreduce",),
        )
        measured = prof.get("pod", "allreduce")
        analytic = comm.axes["pod"].gbs_per_chip
        assert measured is not None
        assert abs(measured - analytic) / analytic <= 0.20

    def test_coarse_data_axis_tracks_chip_level_measurement(self, superpod4):
        # rack granularity loses intra-rack detail but must keep the
        # inter-rack trunks' effective bandwidth: within 5% of the exact
        # 1024-chip pod measurement
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        exact = NetSim(ub_mesh_pod(), routing=Routing.DETOUR).calibrated_profile(
            16e6, comm=comm, axes=("data",), shapes=("allreduce",)
        ).get("data", "allreduce")
        cm = coarsen_superpod(superpod4)
        coarse = coarse_calibrated_profile(
            cm, 16e6, axis_sizes={"data": 16}, axes=("data",),
            shapes=("allreduce",), latency_s=1e-6,   # match the exact run
        ).get("data", "allreduce")
        assert coarse == pytest.approx(exact, rel=0.05)

    def test_hrs_io_cap_binds_on_fanout(self, superpod4):
        # one rack bursting to every peer pod at once must be squeezed to
        # its single uplink, not n_pods-1 uplinks
        cm = coarsen_superpod(superpod4)
        sim = coarse_netsim(cm)
        net = sim._fresh().net
        uplink = cm.dim_io_gbs[2] * 1e9
        hrs_peers = [
            v for v in range(cm.topo.num_nodes)
            if cm.topo.are_adjacent(0, v) == 2
        ]
        flows = [net.add_flow((0, v), 1e9) for v in hrs_peers]
        net._recompute()
        total = sum(f.rate for f in flows)
        assert total <= uplink * (1 + 1e-6)
        assert total == pytest.approx(uplink, rel=1e-6)


class TestSuperpodPerfModel:
    def test_pod_axis_priced_on_coarse_measurement(self, superpod4):
        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        base = base.override_axis("pod", replace(base.axes["pod"], size=4))
        perf = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=superpod4
        )
        cm = perf.comm_model(None)
        pod = cm.axes["pod"]
        assert pod.has_shape("allreduce")
        # measured, clamped at the analytic bound, and within the 20% bar
        assert pod.gbs_per_chip <= base.axes["pod"].gbs_per_chip + 1e-9
        assert pod.gbs_per_chip >= 0.80 * base.axes["pod"].gbs_per_chip

    def test_without_superpod_pod_axis_stays_analytic(self):
        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        perf = NetsimPerfModel(base, topo=ub_mesh_pod(), size_bytes=64e6)
        cm = perf.comm_model(None)
        assert cm.axes["pod"].gbs_per_chip == base.axes["pod"].gbs_per_chip
        assert not cm.axes["pod"].has_shape("allreduce")

    def test_4096_chip_plan_under_budget(self, superpod4):
        from repro.core.planner import plan
        from repro.core.traffic import moe_2t_workload

        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        base = base.override_axis("pod", replace(base.axes["pod"], size=4))
        perf = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=superpod4
        )
        w, _ = moe_2t_workload()
        t0 = time.perf_counter()
        rep = plan(w, 4096, perf)
        wall = time.perf_counter() - t0
        assert len(rep) > 0
        assert rep[0].spec.chips == 4096
        assert wall < 60.0


class TestMixedMeshGeometry:
    def test_empty_detail_racks_is_pure_coarse(self, superpod4):
        # the coarse-only path must stay byte-for-byte the PR-4
        # construction: same topology object type, dims, caps, layout
        cm0 = coarsen_superpod(superpod4)
        cm1 = coarsen_superpod(superpod4, detail_racks=())
        assert type(cm1.topo) is type(cm0.topo)
        assert cm1.topo == cm0.topo
        assert cm1.dim_io_gbs == cm0.dim_io_gbs
        assert cm1.axis_dims == cm0.axis_dims
        assert cm1.detail_racks == ()
        p0 = coarse_calibrated_profile(
            cm0, 16e6, axis_sizes={"pod": 4}, axes=("pod",),
            shapes=("allreduce",),
        )
        p1 = coarse_calibrated_profile(
            cm1, 16e6, axis_sizes={"pod": 4}, axes=("pod",),
            shapes=("allreduce",),
        )
        assert p0.gbs == p1.gbs           # bit-identical, not approx

    def test_mixed_geometry_and_boundary_capacities(self, superpod4, mixed4):
        mm = mixed4.topo
        pod = superpod4.pod
        assert isinstance(mm, MixedMesh)
        cpr = pod.shape[0] * pod.shape[1]
        # 64 coarse ids (rack 0 dangling) + 64 chips
        assert mm.num_nodes == mm.coarse.num_nodes + cpr
        assert mixed4.num_chips == superpod4.num_nodes == 4096
        assert mm.expand(0) == tuple(range(64, 128))
        assert mm.expand(1) is None
        chips = mm.chips_of(0)
        # the dangling coarse id has no links; every chip has X+Y+Z+A+P
        assert all(u != 0 and v != 0 for u, v, _ in mm.links())
        z_peers = [v for v in range(mm.coarse.num_nodes)
                   if mm.coarse.are_adjacent(0, v) == 0]
        c = chips[0]
        # chip's trunk share on Z = the chip-level lanes (12.5 GB/s)
        assert mm.link_gbs(c, z_peers[0]) == pytest.approx(
            pod.dims[2].gbs_per_peer
        )
        # chip's HRS uplink share = uplink / chips_per_rack (25 GB/s)
        uplink = superpod4.uplink_lanes_per_rack * 6.25
        hrs_dim = mixed4.axis_dims["pod"][0]
        p_peer = next(
            v for v, d in mm._adj[c].items() if d == hrs_dim
        )
        assert mm.link_gbs(c, p_peer) == pytest.approx(uplink / cpr)
        # per-node HRS IO caps: chips' shares sum to the rack's cap
        caps = mixed4.dim_io_gbs[hrs_dim]
        assert caps[1] == pytest.approx(uplink)
        assert sum(caps[ch] for ch in chips) == pytest.approx(uplink)
        assert 0 not in caps
        # heterogeneous ejection: chip-level vs rack-level rx
        assert mm.node_rx_gbs[chips[0]] == pytest.approx(
            pod.dims[0].gbs_total
        )
        assert mm.node_rx_gbs[1] > 10 * mm.node_rx_gbs[chips[0]]

    def test_detail_racks_validation(self, superpod4):
        with pytest.raises(ValueError):
            coarsen_superpod(superpod4, level="pod", detail_racks=(0,))
        with pytest.raises(ValueError):
            coarsen_superpod(superpod4, detail_racks=(999,))
        # detail_racks without a SuperPod to embed them in must not
        # silently fall back to the isolated chip-level calibration
        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        with pytest.raises(ValueError):
            NetsimPerfModel(base, topo=ub_mesh_pod(), detail_racks=(0,))
        # background on a single-pod SuperPod has no HRS tier to cross —
        # measuring "with background" would silently return idle numbers
        single = coarsen_superpod(
            SuperPod(pod=ub_mesh_pod(), n_pods=1), detail_racks=(0,)
        )
        with pytest.raises(ValueError):
            mixed_calibrated_profile(
                single, 8e6, axes=("model",), shapes=("allreduce",),
                background_per_chip_bytes=8e6,
            )

    def test_splice_dag_classes_and_barrier(self, mixed4):
        mm = mixed4.topo
        dag = FlowDAG(name="t")
        # one aggregate step mixing all three pair classes
        t0 = dag._add(src=1, dst=2, size=64.0, single_path=True,
                      pairs=((1, 2), (0, 3), (2, 0)))
        t1 = dag._add(src=2, dst=1, size=64.0, deps=(t0.tid,))
        out = splice_dag(dag, mm.expand)
        # classes: coarse-coarse, detail->coarse, coarse->detail
        assert len(out.tasks) == 4
        first = [t for t in out.tasks if not t.deps]
        assert len(first) == 3
        sizes = sorted(t.size for t in first)
        assert sizes == [1.0, 1.0, 64.0]     # 64-way splits carry 1/64th
        assert sum(t.total_bytes for t in first) == pytest.approx(3 * 64.0)
        # the barrier: the dependent task waits on every spliced piece
        last = out.tasks[-1]
        assert set(last.deps) == {t.tid for t in first}

    def test_intra_rack_routing_prefers_clique_links(self, mixed4):
        # two embedded chips differing in both X and Y reach each other
        # in 2 hops via a sibling chip (25 GB/s clique links) OR via any
        # adjacent coarse rack (12.5 GB/s trunk shares that also carry
        # cross-pod traffic); the chip relays must win the Router's
        # in-order link-disjoint selection
        mm = mixed4.topo
        chips = mm.chips_of(0)
        c1, c2 = chips[0], chips[9]          # local (0,0) and (1,1)
        first_coarse = mm.coarse.num_nodes
        sp = mm.apr_shortest_paths(c1, c2)
        assert len(sp[0]) == 3
        assert all(n >= first_coarse for n in sp[0])
        router = mixed_netsim(mixed4)._fresh()
        cand = router.candidate_paths(c1, c2)
        assert len(cand) >= 2
        assert all(n >= first_coarse for p in cand[:2] for n in p), (
            "multi-path split between embedded chips must lead with the "
            "intra-rack clique relays, not coarse trunk shares"
        )

    def test_apr_hooks_on_mixed_mesh(self, mixed4):
        mm = mixed4.topo
        chips = mm.chips_of(0)
        c = chips[0]
        z_peer = next(v for v, d in mm._adj[c].items() if d == 0)
        # adjacent: one direct shortest path
        assert mm.apr_shortest_paths(c, z_peer)[0] == (c, z_peer)
        assert mm.hop_distance(c, z_peer) == 1
        # detours relay through the rack's other chips (X/Y) or racks
        detours = [p for p in mm.apr_all_paths(c, z_peer) if len(p) == 3]
        assert detours
        assert all(p[0] == c and p[-1] == z_peer for p in detours)


class TestMixedAccuracy:
    def test_pod_axis_matches_pure_coarse_within_2pct(self, superpod4, mixed4):
        coarse = coarse_calibrated_profile(
            coarsen_superpod(superpod4), 64e6, axis_sizes={"pod": 4},
            axes=("pod",), shapes=("allreduce",),
        ).get("pod", "allreduce")
        mixed = mixed_calibrated_profile(
            mixed4, 64e6, axis_sizes={"pod": 4}, axes=("pod",),
            shapes=("allreduce",),
        ).get("pod", "allreduce")
        assert mixed == pytest.approx(coarse, rel=0.02)

    def test_pod_axis_within_pr4_bound_of_analytic(self, superpod4, mixed4):
        comm = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        mixed = mixed_calibrated_profile(
            mixed4, 64e6, axis_sizes={"pod": 4}, axes=("pod",),
            shapes=("allreduce",),
        ).get("pod", "allreduce")
        analytic = comm.axes["pod"].gbs_per_chip
        assert abs(mixed - analytic) / analytic <= 0.20

    def test_idle_model_axis_matches_chip_level(self, mixed4):
        # with zero background the embedded rack is the chip-level rack:
        # same links, same rx caps, same DAG conventions
        chip = NetSim(ub_mesh_pod(), routing=Routing.DETOUR).calibrated_profile(
            64e6, axis_sizes={"model": 16}, axes=("model",),
            shapes=("allreduce",),
        ).get("model", "allreduce")
        mixed = mixed_calibrated_profile(
            mixed4, 64e6, axis_sizes={"model": 16}, axes=("model",),
            shapes=("allreduce",), latency_s=1e-6,
        ).get("model", "allreduce")
        assert mixed == pytest.approx(chip, rel=0.02)

    def test_background_dp_degrades_model_axis_over_5pct(self, mixed4):
        # the acceptance bar: cross-pod DP background crossing the
        # embedded rack's uplinks must shave >5% off the measured
        # model-axis bandwidth (ejection-port sharing the pure paths
        # cannot see)
        iso = mixed_calibrated_profile(
            mixed4, 64e6, axis_sizes={"model": 16}, axes=("model",),
            shapes=("allreduce",), latency_s=1e-6,
        ).get("model", "allreduce")
        loaded = mixed_calibrated_profile(
            mixed4, 64e6, axis_sizes={"model": 16}, axes=("model",),
            shapes=("allreduce",), latency_s=1e-6,
            background_per_chip_bytes=64e6,
        ).get("model", "allreduce")
        assert loaded < iso
        assert 1 - loaded / iso > 0.05

    def test_spliced_a2a_spans_detail_chips_and_coarse_racks(self, mixed4):
        # the Fig. 14 relay A2A at rack granularity, spliced: store-and-
        # forward hops through the embedded rack run as 64 trunk-share
        # flows terminating/originating at its chips
        prof = mixed_calibrated_profile(
            mixed4, 8e6, axis_sizes={"data": 16}, axes=("data",),
            shapes=("all_to_all",),
        )
        val = prof.get("data", "all_to_all")
        assert val is not None and val > 0
        # the A2A group (Z clique widened over A) contains rack 0, so the
        # spliced run must touch the detail chips
        net = mixed_netsim(mixed4)
        mm = mixed4.topo
        from repro.netsim import NetSim as _NS

        coarse_sim = _NS(mm.coarse, axis_dims={"data": (0, 1)})
        dag = coarse_sim._axis_shape_dag(
            (0, 1), "all_to_all", 8e6 * mixed4.chips_per_node, None, "a2a"
        )
        spliced = splice_dag(dag, mm.expand)
        chips = set(mm.chips_of(0))
        endpoints = {n for t in spliced.tasks for n in t.endpoints()}
        assert endpoints & chips and 0 not in endpoints
        r = net.run_dag(spliced)
        assert r.incomplete == 0
        assert r.bytes_delivered == pytest.approx(spliced.total_bytes)

    def test_background_dag_crosses_detail_uplinks(self, mixed4):
        mm = mixed4.topo
        dag = cross_pod_background_dag(mixed4, 8e6)
        chips = set(mm.chips_of(0))
        endpoints = {n for t in dag.tasks for n in t.endpoints()}
        assert endpoints & chips            # spliced onto the chips
        assert 0 not in endpoints           # dangling coarse id rewritten
        r = mixed_netsim(mixed4).run_dag(dag)
        assert r.incomplete == 0
        assert r.bytes_delivered == pytest.approx(dag.total_bytes)


class TestMixedFailureReroute:
    def test_trunk_failure_adjacent_to_detail_rack_recovers(self, mixed4):
        # kill a chip's Z-trunk share mid-run: APR must reroute through a
        # sibling chip's X/Y links and the byte accounting must balance
        mm = mixed4.topo
        sim = mixed_netsim(mixed4, latency_s=1e-6)
        chips = mm.chips_of(0)
        c = chips[0]
        z_peer = next(v for v, d in mm._adj[c].items() if d == 0)
        nodes = clique_nodes(mm.coarse, 0, {1: 0, 2: 0})   # Z clique of rack 0
        dag = splice_dag(
            ring_allreduce(mm.coarse, nodes, 64e6 * mixed4.chips_per_node,
                           tag="z-ar"),
            mm.expand,
        )
        clean = sim.run_dag(dag)
        assert clean.incomplete == 0
        r = sim.run_dag(
            dag, fail_link=(c, z_peer), fail_at_s=clean.makespan_s / 4
        )
        assert r.failure_stats["affected_transfers"] > 0
        assert r.incomplete == 0                        # everything recovered
        assert r.bytes_delivered == pytest.approx(dag.total_bytes)
        assert r.makespan_s >= clean.makespan_s         # rerouting cannot win
        # the failed trunk share carried no bytes after the failure:
        # utilization stays below the clean run's on that link
        net = sim.last_network
        assert (c, z_peer) in net.failed


class TestMixedPerfModel:
    def test_detail_racks_degrade_planner_model_axis(self, superpod4):
        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        base = base.override_axis(
            "pod", replace(base.axes["pod"], size=4)
        )
        iso = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=superpod4
        )
        mix = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=superpod4,
            detail_racks=(0,),
        )
        cm_iso = iso.comm_model(None)
        cm_mix = mix.comm_model(None)
        # model axis priced lower under DCN interference; memo keys are
        # distinct so the isolated number is not clobbered
        ar_iso = cm_iso.axes["model"].bw_for("allreduce")
        ar_mix = cm_mix.axes["model"].bw_for("allreduce")
        assert ar_mix < ar_iso
        assert 1 - ar_mix / ar_iso > 0.05
        # pod axis still priced on the (cached) coarse measurement
        assert cm_mix.axes["pod"].gbs_per_chip == pytest.approx(
            cm_iso.axes["pod"].gbs_per_chip
        )
        # re-resolving the isolated backend returns the isolated number
        assert iso.comm_model(None).axes["model"].bw_for(
            "allreduce"
        ) == pytest.approx(ar_iso)

    def test_spec_narrowed_mixed_calibration(self, superpod4):
        # partial-width TP*SP groups ride the hierarchical schedule
        # inside the embedded rack too (same conventions as chip level),
        # still with the DCN background applied
        from repro.core.traffic import ParallelSpec

        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        base = base.override_axis(
            "pod", replace(base.axes["pod"], size=4)
        )
        mix = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=superpod4,
            detail_racks=(0,),
        )
        spec = ParallelSpec(tp=8, sp=2, pp=2, dp=16, ep=2)
        cm = mix.comm_model(spec)
        full = mix.comm_model(None)
        narrow = cm.axes["model"].bw_for("allreduce")
        wide = full.axes["model"].bw_for("allreduce")
        assert narrow > 0
        # a 16-chip group cannot beat the full-plane grid rings
        assert narrow <= wide * (1 + 1e-6)
