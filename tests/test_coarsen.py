"""Rack-coarsened SuperPod calibration (netsim/coarsen.py).

Contracts:
* the coarse mesh's aggregate capacities follow from SuperPod geometry
  (trunked inter-rack cliques, one HRS uplink of IO per rack),
* coarse-measured cross-pod DP bandwidth lands within 20% of the analytic
  DCN ("pod" axis) model on an uncontended config — the acceptance bar —
  and coarse-measured inter-rack ("data") bandwidth within a few % of the
  exact chip-level pod measurement,
* ``NetsimPerfModel(superpod=...)`` prices the pod axis on the coarse
  measurement (memo key carries the coarsening level) and a 4-pod
  4096-chip ``plan()`` stays fast.
"""

import time
from dataclasses import replace

import pytest

from repro.core.cost_model import Routing, build_comm_model
from repro.core.perf_model import NetsimPerfModel
from repro.core.topology import SuperPod, ub_mesh_pod
from repro.netsim import NetSim
from repro.netsim.coarsen import (
    coarse_calibrated_profile,
    coarse_netsim,
    coarsen_superpod,
)


@pytest.fixture(scope="module")
def superpod4() -> SuperPod:
    return SuperPod(pod=ub_mesh_pod(), n_pods=4)


class TestCoarseMesh:
    def test_rack_level_geometry(self, superpod4):
        cm = coarsen_superpod(superpod4)
        pod = superpod4.pod
        assert cm.topo.shape == (pod.shape[2], pod.shape[3], 4)
        assert cm.chips_per_node == pod.shape[0] * pod.shape[1]
        assert cm.num_chips == superpod4.num_nodes == 4096
        # trunk aggregation: 64 chips x 2 lanes x 6.25 GB/s = 800 per peer
        assert cm.topo.dims[0].gbs_per_peer == pytest.approx(
            cm.chips_per_node * pod.dims[2].gbs_per_peer
        )
        # the HRS dim carries the full uplink per pair, capped per rack
        uplink = superpod4.uplink_lanes_per_rack * 6.25
        assert cm.topo.dims[2].gbs_per_peer == pytest.approx(uplink)
        assert cm.dim_io_gbs == {2: pytest.approx(uplink)}
        assert cm.axis_dims == {"data": (0, 1), "pod": (2,)}

    def test_pod_level_geometry(self, superpod4):
        cm = coarsen_superpod(superpod4, level="pod")
        assert cm.topo.shape == (4,)
        assert cm.chips_per_node == superpod4.pod.num_nodes
        assert cm.axis_dims == {"pod": (0,)}

    def test_unknown_level_rejected(self, superpod4):
        with pytest.raises(ValueError):
            coarsen_superpod(superpod4, level="board")

    def test_single_pod_has_no_hrs_dim(self):
        cm = coarsen_superpod(SuperPod(pod=ub_mesh_pod(), n_pods=1))
        assert "pod" not in cm.axis_dims
        assert cm.dim_io_gbs == {}


class TestCoarseAccuracy:
    def test_cross_pod_dp_bw_within_20pct_of_analytic(self, superpod4):
        # uncontended cross-pod DP: the HRS tier is a non-blocking Clos,
        # so the measured AllReduce bandwidth must track the analytic
        # uplink allocation (25 GB/s per chip) within the 20% bar
        comm = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        cm = coarsen_superpod(superpod4)
        prof = coarse_calibrated_profile(
            cm, 64e6, axis_sizes={"pod": 4}, axes=("pod",),
            shapes=("allreduce",),
        )
        measured = prof.get("pod", "allreduce")
        analytic = comm.axes["pod"].gbs_per_chip
        assert measured is not None
        assert abs(measured - analytic) / analytic <= 0.20

    def test_coarse_data_axis_tracks_chip_level_measurement(self, superpod4):
        # rack granularity loses intra-rack detail but must keep the
        # inter-rack trunks' effective bandwidth: within 5% of the exact
        # 1024-chip pod measurement
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        exact = NetSim(ub_mesh_pod(), routing=Routing.DETOUR).calibrated_profile(
            16e6, comm=comm, axes=("data",), shapes=("allreduce",)
        ).get("data", "allreduce")
        cm = coarsen_superpod(superpod4)
        coarse = coarse_calibrated_profile(
            cm, 16e6, axis_sizes={"data": 16}, axes=("data",),
            shapes=("allreduce",), latency_s=1e-6,   # match the exact run
        ).get("data", "allreduce")
        assert coarse == pytest.approx(exact, rel=0.05)

    def test_hrs_io_cap_binds_on_fanout(self, superpod4):
        # one rack bursting to every peer pod at once must be squeezed to
        # its single uplink, not n_pods-1 uplinks
        cm = coarsen_superpod(superpod4)
        sim = coarse_netsim(cm)
        net = sim._fresh().net
        uplink = cm.dim_io_gbs[2] * 1e9
        hrs_peers = [
            v for v in range(cm.topo.num_nodes)
            if cm.topo.are_adjacent(0, v) == 2
        ]
        flows = [net.add_flow((0, v), 1e9) for v in hrs_peers]
        net._recompute()
        total = sum(f.rate for f in flows)
        assert total <= uplink * (1 + 1e-6)
        assert total == pytest.approx(uplink, rel=1e-6)


class TestSuperpodPerfModel:
    def test_pod_axis_priced_on_coarse_measurement(self, superpod4):
        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        base = base.override_axis("pod", replace(base.axes["pod"], size=4))
        perf = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=superpod4
        )
        cm = perf.comm_model(None)
        pod = cm.axes["pod"]
        assert pod.has_shape("allreduce")
        # measured, clamped at the analytic bound, and within the 20% bar
        assert pod.gbs_per_chip <= base.axes["pod"].gbs_per_chip + 1e-9
        assert pod.gbs_per_chip >= 0.80 * base.axes["pod"].gbs_per_chip

    def test_without_superpod_pod_axis_stays_analytic(self):
        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        perf = NetsimPerfModel(base, topo=ub_mesh_pod(), size_bytes=64e6)
        cm = perf.comm_model(None)
        assert cm.axes["pod"].gbs_per_chip == base.axes["pod"].gbs_per_chip
        assert not cm.axes["pod"].has_shape("allreduce")

    def test_4096_chip_plan_under_budget(self, superpod4):
        from repro.core.planner import plan
        from repro.core.traffic import moe_2t_workload

        base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        base = base.override_axis("pod", replace(base.axes["pod"], size=4))
        perf = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=superpod4
        )
        w, _ = moe_2t_workload()
        t0 = time.perf_counter()
        rep = plan(w, 4096, perf)
        wall = time.perf_counter() - t0
        assert len(rep) > 0
        assert rep[0].spec.chips == 4096
        assert wall < 60.0
