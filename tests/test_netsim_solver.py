"""Parity suite: vectorized max-min solver vs the pure-Python reference.

The vectorized numpy water-filling (``netsim/solver.py``) must reproduce
the reference progressive filling's rates to 1e-6 on randomized
topologies and flow sets — including receiver-egress (incast) caps,
per-dim IO caps, link failures and aggregate flows — and whole DAG runs
must produce identical completion times under either solver and under
aggregate-vs-expanded ring-step execution.

Also pins the freeze-tolerance regression: the old absolute ``+ 1e-9``
epsilon over-froze links whose fair share is itself ~1e-9 bytes/s.
"""

import math
import random

import pytest

from repro.core.cost_model import Routing
from repro.core.topology import (
    ACTIVE_ELECTRICAL,
    DimSpec,
    NDFullMesh,
    PASSIVE_ELECTRICAL,
    ub_mesh_rack,
)
from repro.netsim import FluidNetwork, NetSim, ring_allreduce
from repro.netsim.collectives import clique_nodes, hierarchical_allreduce
from repro.netsim.solver import SOLVERS


def _random_topo(rng: random.Random) -> NDFullMesh:
    ndim = rng.randint(1, 3)
    dims = tuple(
        DimSpec(
            f"D{i}",
            rng.randint(2, 5),
            PASSIVE_ELECTRICAL if i < 2 else ACTIVE_ELECTRICAL,
            rng.choice((1, 2, 4)),
        )
        for i in range(ndim)
    )
    return NDFullMesh(dims=dims)


def _random_path(topo: NDFullMesh, rng: random.Random) -> tuple[int, ...]:
    """A random dimension-hopping walk of 1-3 hops (every hop is a direct
    full-mesh link)."""
    node = rng.randrange(topo.num_nodes)
    path = [node]
    for _ in range(rng.randint(1, 3)):
        c = list(topo.coords(path[-1]))
        d = rng.randrange(topo.ndim)
        choices = [v for v in range(topo.shape[d]) if v != c[d]]
        c[d] = rng.choice(choices)
        nxt = topo.node_id(c)
        if nxt != path[-1]:
            path.append(nxt)
    return tuple(path)


def _pair_networks(topo, rng, *, rx_gbs=None, dim_io_gbs=None, n_flows=24):
    """Two FluidNetworks (reference / vectorized) loaded with the same
    random flow set; returns (ref_net, vec_net, flows_per_net)."""
    nets = [
        FluidNetwork(topo, rx_gbs=rx_gbs, dim_io_gbs=dim_io_gbs, solver=s)
        for s in ("reference", "vectorized")
    ]
    paths = []
    for _ in range(n_flows):
        p = _random_path(topo, rng)
        if len(p) >= 2:
            paths.append((p, rng.uniform(1e6, 1e9)))
    for net in nets:
        for p, size in paths:
            net.add_flow(p, size)
    return nets[0], nets[1]


def _assert_rates_match(ref: FluidNetwork, vec: FluidNetwork, tol=1e-6):
    ref._recompute()
    vec._recompute()
    assert set(ref.flows) == set(vec.flows)
    for fid, rf in ref.flows.items():
        vf = vec.flows[fid]
        scale = max(abs(rf.rate), abs(vf.rate), 1e-30)
        assert abs(rf.rate - vf.rate) / scale <= tol, (
            f"flow {fid} on {rf.path}: ref={rf.rate} vec={vf.rate}"
        )


class TestSolverParity:
    @pytest.mark.parametrize("seed", range(8))
    def test_random_flow_sets_match_reference(self, seed):
        rng = random.Random(seed)
        topo = _random_topo(rng)
        ref, vec = _pair_networks(topo, rng)
        _assert_rates_match(ref, vec)

    @pytest.mark.parametrize("seed", range(4))
    def test_incast_rx_caps_match_reference(self, seed):
        rng = random.Random(1000 + seed)
        topo = _random_topo(rng)
        ref, vec = _pair_networks(topo, rng, rx_gbs=rng.uniform(1.0, 20.0))
        _assert_rates_match(ref, vec)

    @pytest.mark.parametrize("seed", range(4))
    def test_link_failure_match_reference(self, seed):
        rng = random.Random(2000 + seed)
        topo = _random_topo(rng)
        ref, vec = _pair_networks(topo, rng)
        links = [l for l in ref.capacity if l[0] < l[1]]
        u, v = rng.choice(links)
        ref.fail_link(u, v)
        vec.fail_link(u, v)
        _assert_rates_match(ref, vec)

    def test_dim_io_caps_match_reference(self):
        rng = random.Random(42)
        topo = _random_topo(rng)
        ref, vec = _pair_networks(
            topo, rng, dim_io_gbs={topo.ndim - 1: 3.0}
        )
        _assert_rates_match(ref, vec)

    def test_aggregate_flows_match_reference(self):
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        pairs = tuple(
            (nodes[i], nodes[(i + 1) % len(nodes)]) for i in range(len(nodes))
        )
        nets = [FluidNetwork(topo, solver=s) for s in ("reference", "vectorized")]
        for net in nets:
            net.add_aggregate_flow(pairs, 8e6)
            net.add_flow((nodes[0], nodes[1]), 4e6)   # contends with member 0
        _assert_rates_match(*nets)

    @pytest.mark.parametrize("solver", sorted(SOLVERS))
    def test_full_run_times_identical_across_solvers(self, solver):
        # same DAG, either solver: identical completion times (the solvers
        # are exact, not approximations of each other)
        topo = ub_mesh_rack()
        dag = hierarchical_allreduce(topo, (0, 1), 16e6)
        r = NetSim(topo, routing=Routing.DETOUR, solver=solver).run_dag(dag)
        ref = NetSim(topo, routing=Routing.DETOUR, solver="reference").run_dag(dag)
        assert r.incomplete == 0
        for tid, t in ref.task_end_s.items():
            assert r.task_end_s[tid] == pytest.approx(t, rel=1e-6)

    @pytest.mark.slow
    def test_reference_solver_pod_clique_crossval(self):
        # the reference slow path still reproduces the analytic multi-ring
        # number on a pod-scale clique (the PR-1 crossval contract)
        from repro.core.multiring import plan_multiring
        from repro.core.topology import ub_mesh_pod

        topo = ub_mesh_pod()
        sim = NetSim(topo, routing=Routing.DETOUR, solver="reference")
        t = sim.allreduce_time(0, 48e6)
        ta = plan_multiring(topo, 0).allreduce_time_s(48e6)
        assert abs(t - ta) / ta <= 0.15


class TestAggregateExecution:
    """Aggregate ring steps vs per-pair expansion: same physics."""

    @pytest.mark.parametrize("solver", sorted(SOLVERS))
    def test_ring_allreduce_aggregate_equals_expanded(self, solver):
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        dag = ring_allreduce(topo, nodes, 32e6)
        agg = NetSim(topo, solver=solver, aggregate=True).run_dag(dag)
        exp = NetSim(topo, solver=solver, aggregate=False).run_dag(dag)
        assert agg.incomplete == 0 and exp.incomplete == 0
        assert agg.makespan_s == pytest.approx(exp.makespan_s, rel=1e-9)
        assert agg.bytes_delivered == pytest.approx(exp.bytes_delivered)

    def test_grid_allreduce_aggregate_equals_expanded(self):
        from repro.netsim.collectives import grid_allreduce

        topo = ub_mesh_rack()
        dag = grid_allreduce(topo, (0, 1), 64e6)
        agg = NetSim(topo, aggregate=True).run_dag(dag)
        exp = NetSim(topo, aggregate=False).run_dag(dag)
        assert agg.makespan_s == pytest.approx(exp.makespan_s, rel=1e-9)

    def test_failure_injection_expands_and_completes(self):
        # fail_link runs force per-pair expansion so APR rerouting stays
        # live; every task must still finish
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        dag = ring_allreduce(topo, nodes, 16e6)
        sim = NetSim(topo, routing=Routing.DETOUR)
        healthy = sim.run_dag(dag)
        failed = sim.run_dag(
            dag,
            fail_link=(nodes[1], nodes[2]),
            fail_at_s=healthy.makespan_s / 3,
        )
        assert failed.incomplete == 0
        assert failed.makespan_s >= healthy.makespan_s * 0.999


class TestFreezeTolerance:
    """Regression: the freeze level must be RELATIVE to the round's best
    share.  The old absolute ``+ 1e-9`` epsilon froze every link whose
    share was within 1e-9 bytes/s of the minimum — at nano-scale
    capacities that is *every* link, collapsing distinct fair shares."""

    @pytest.mark.parametrize("solver", sorted(SOLVERS))
    def test_tiny_capacities_keep_distinct_fair_shares(self, solver):
        topo = NDFullMesh(
            dims=(DimSpec("X", 3, PASSIVE_ELECTRICAL, 1),)
        )
        net = FluidNetwork(topo, solver=solver)
        # shrink two links into the nano-bytes/s regime with distinct caps
        net.capacity[(0, 1)] = 1.0e-9
        net.capacity[(0, 2)] = 1.5e-9
        net.solver.capacity_changed()
        f1 = net.add_flow((0, 1), 1.0)
        f2 = net.add_flow((0, 2), 1.0)
        net._recompute()
        assert f1.rate == pytest.approx(1.0e-9, rel=1e-6)
        # the old absolute epsilon froze f2 at 1.0e-9 as well
        assert f2.rate == pytest.approx(1.5e-9, rel=1e-6)

    @pytest.mark.parametrize("solver", sorted(SOLVERS))
    def test_shared_tiny_link_splits_fairly(self, solver):
        topo = NDFullMesh(
            dims=(DimSpec("X", 2, PASSIVE_ELECTRICAL, 1),)
        )
        net = FluidNetwork(topo, solver=solver)
        net.capacity[(0, 1)] = 4e-9
        net.solver.capacity_changed()
        flows = [net.add_flow((0, 1), 1.0) for _ in range(4)]
        net._recompute()
        for f in flows:
            assert f.rate == pytest.approx(1e-9, rel=1e-6)


class TestLazyLinkBytes:
    """The per-link byte ledger is credited lazily (on completion /
    withdrawal / read), but must stay exact whenever it is read."""

    def test_mid_run_read_includes_in_flight_progress(self):
        topo = ub_mesh_rack()
        net = FluidNetwork(topo)
        net.add_flow((0, 1), 25e9)          # 1 s at the 25 GB/s X link
        net.engine.schedule(0.5, lambda: None)
        net.run(until=0.5)                   # halfway through the flow
        assert net.link_bytes[(0, 1)] == pytest.approx(12.5e9, rel=1e-9)
        net.run()
        assert net.link_bytes[(0, 1)] == pytest.approx(25e9, rel=1e-9)

    def test_multi_hop_flow_credits_every_link(self):
        topo = ub_mesh_rack()
        net = FluidNetwork(topo)
        path = (0, 1, 9)                    # X hop then Y hop
        net.add_flow(path, 5e9)
        net.run()
        for l in zip(path, path[1:]):
            assert net.link_bytes[l] == pytest.approx(5e9, rel=1e-9)

    def test_aggregate_members_credit_their_own_links(self):
        topo = ub_mesh_rack()
        nodes = clique_nodes(topo, 0)
        pairs = tuple((nodes[i], nodes[i + 1]) for i in range(4))
        net = FluidNetwork(topo)
        net.add_aggregate_flow(pairs, 2e9)
        net.run()
        for l in pairs:
            assert net.link_bytes[l] == pytest.approx(2e9, rel=1e-9)
        assert net.bytes_delivered == pytest.approx(8e9, rel=1e-9)


class TestDimIOCaps:
    """Per-dim per-node IO caps: the switched-tier (HRS) constraint."""

    def test_fanout_over_capped_dim_serializes(self):
        # 3 concurrent sends out of node 0 across the capped dim: per-pair
        # capacity alone would run all three at full rate; the IO cap
        # must squeeze them to a third each
        topo = NDFullMesh(dims=(DimSpec("P", 4, ACTIVE_ELECTRICAL, 8),))
        per_peer = topo.dims[0].gbs_per_peer
        net = FluidNetwork(topo, dim_io_gbs={0: per_peer})
        flows = [net.add_flow((0, v), 1e9) for v in (1, 2, 3)]
        net._recompute()
        for f in flows:
            assert f.rate == pytest.approx(per_peer * 1e9 / 3, rel=1e-9)

    def test_single_pair_bursts_full_uplink(self):
        topo = NDFullMesh(dims=(DimSpec("P", 4, ACTIVE_ELECTRICAL, 8),))
        per_peer = topo.dims[0].gbs_per_peer
        net = FluidNetwork(topo, dim_io_gbs={0: per_peer})
        f = net.add_flow((0, 1), 1e9)
        net._recompute()
        assert f.rate == pytest.approx(per_peer * 1e9, rel=1e-9)
