"""Optional-`hypothesis` shim for the property-based tests.

``hypothesis`` is a dev-only dependency (declared in pyproject.toml's
``dev`` extra).  When it is installed, this module re-exports the real
``given`` / ``settings`` / ``strategies``.  When it is absent, it provides
stand-ins whose ``@given`` marks the test with ``pytest.mark.skip`` — so
the property tests skip cleanly while every example-based test in the same
module still collects and runs (the seed behavior was an ImportError that
killed collection of all four modules).
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis missing
    import pytest

    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(reason="hypothesis not installed (dev extra)")

    def given(*_args, **_kwargs):
        def deco(fn):
            return _SKIP(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Inert placeholder: builds no values, supports chained calls."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()
