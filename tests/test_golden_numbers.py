"""Golden-number regression pins for the paper-facing calibrations.

The headline figures from PRs 2-4 — the numbers quoted in README /
ROADMAP and consumed by the planner — pinned with EXPLICIT tolerances so
a future solver/schedule change cannot silently drift them:

* model-axis multi-ring AllReduce ~163 GB/s per chip at 512 MB (>= 80%
  of the analytic 200; the cross-dim 2D grid-ring number from PR 2),
* model-axis AllReduce ~142 vs All-to-All ~47 GB/s at 64 MB (the 3x
  shape gap from PR 3 that the AllReduce-proxy scalar hid),
* rack-coarsened cross-pod DP ("pod" axis) ~24.8 GB/s per chip vs the
  analytic 25.0 (PR 4's 0.8% accuracy claim),
* the rectangular-plane fallback: an 8x4 (X, Y) plane has no cross-dim
  Hamiltonian decomposition, so calibration falls back to the per-dim
  hierarchical schedule at ~90 GB/s (~45% of the analytic plane
  bandwidth) — previously the fallback was only logged, never asserted.

A deliberate 2% band: tight enough to catch schedule/solver drift, loose
enough to survive fp-accumulation-order changes.  If a change moves a
number on purpose, update the constant AND the README table in the same
commit.
"""

import logging

import pytest

from repro.core.cost_model import Routing, build_comm_model
from repro.core.multiring import UnsupportedGridError, grid_ring_decomposition
from repro.core.topology import (
    DimSpec,
    NDFullMesh,
    PASSIVE_ELECTRICAL,
    SuperPod,
    ub_mesh_pod,
)
from repro.netsim import NetSim, grid_allreduce
from repro.netsim.coarsen import coarse_calibrated_profile, coarsen_superpod

GOLDEN_REL = 0.02

# (value, payload) measured on the DETOUR-routed 1024-chip pod /
# 4-pod rack-coarsened SuperPod with the default calibration settings
MODEL_ALLREDUCE_512MB_GBS = 163.1
MODEL_ALLREDUCE_64MB_GBS = 141.8
MODEL_A2A_64MB_GBS = 46.8
COARSE_POD_64MB_GBS = 24.8
RECT_8X4_FALLBACK_GBS = 89.9

# Monte-Carlo availability campaign (Table 6 / §6.6 reproduction):
# 8K-NPU UB-Mesh vs Clos over 16 seeds x 4 weeks at the 75-min MTTR
# (sampling-only — the availability metric is an AFR/repair property),
# and the weak-scaled 1K -> 8K linearity under failures with the
# analytic perf backend (the netsim-repriced variant is exercised by
# tests/test_campaign.py and the availability_smoke benchmark)
AVAILABILITY_GAP = 0.0722          # paper: "about 7.2%"
UB_AVAILABILITY = 0.98704          # paper analytic: 0.98747
CLOS_AVAILABILITY = 0.91481        # paper analytic: 0.91718
UB_LINEARITY = 0.9654              # paper claim: >= 0.95
CLOS_LINEARITY = 0.8586


@pytest.fixture(scope="module")
def pod_sim() -> NetSim:
    return NetSim(ub_mesh_pod(), routing=Routing.DETOUR)


class TestGoldenCalibrations:
    def test_model_allreduce_512mb(self, pod_sim):
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        cal = pod_sim.calibrated_axis_gbs(512e6, comm=comm)["model"]
        assert cal == pytest.approx(MODEL_ALLREDUCE_512MB_GBS, rel=GOLDEN_REL)
        # and the PR-2 acceptance bar it came from
        assert cal >= 0.80 * comm.axes["model"].gbs_per_chip

    def test_model_shape_gap_64mb(self, pod_sim):
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        prof = pod_sim.calibrated_profile(
            64e6, comm=comm, axes=("model",),
            shapes=("allreduce", "all_to_all"),
        )
        ar = prof.get("model", "allreduce")
        a2a = prof.get("model", "all_to_all")
        assert ar == pytest.approx(MODEL_ALLREDUCE_64MB_GBS, rel=GOLDEN_REL)
        assert a2a == pytest.approx(MODEL_A2A_64MB_GBS, rel=GOLDEN_REL)
        # the ~3x AllReduce/A2A gap is the PR-3 planner-facing claim
        assert 2.5 <= ar / a2a <= 3.5

    def test_coarse_pod_axis_64mb(self):
        sp = SuperPod(pod=ub_mesh_pod(), n_pods=4)
        cal = coarse_calibrated_profile(
            coarsen_superpod(sp), 64e6, axis_sizes={"pod": 4},
            axes=("pod",), shapes=("allreduce",),
        ).get("pod", "allreduce")
        assert cal == pytest.approx(COARSE_POD_64MB_GBS, rel=GOLDEN_REL)
        # PR 4's accuracy claim vs the analytic 25.0 GB/s/chip DCN model
        comm = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
        analytic = comm.axes["pod"].gbs_per_chip
        assert abs(cal - analytic) / analytic <= 0.02


class TestRectangularGridFallback:
    """The 8x4 plane: no cross-dim decomposition, hierarchical fallback."""

    def _topo_8x4(self) -> NDFullMesh:
        return NDFullMesh(
            dims=(
                DimSpec("X", 8, PASSIVE_ELECTRICAL, 4),
                DimSpec("Y", 4, PASSIVE_ELECTRICAL, 4),
            )
        )

    def test_error_names_the_offending_dims(self):
        with pytest.raises(UnsupportedGridError) as ei:
            grid_ring_decomposition(8, 4)
        assert ei.value.x == 8 and ei.value.y == 4
        msg = str(ei.value)
        assert "K_8" in msg and "K_4" in msg
        assert "non-square" in msg

    def test_grid_compiler_falls_back_and_logs_dims(self, caplog):
        topo = self._topo_8x4()
        with caplog.at_level(logging.INFO, logger="repro.netsim.collectives"):
            dag = grid_allreduce(topo, (0, 1), 64e6, tag="rect")
        assert dag is None                    # explicit fallback signal
        assert any(
            "(0, 1)" in r.message and "non-square" in r.message
            for r in caplog.records
        ), "fallback log must name the offending dims and the reason"

    def test_fallback_bandwidth_pinned(self):
        # the per-dim hierarchical schedule only drives one dimension's
        # links per phase: ~90 GB/s on the 32-chip 8x4 plane, well under
        # the 250 GB/s aggregate (X+Y) clique allocation — the fidelity
        # cost the UnsupportedGridError fallback path accepts, now
        # asserted instead of just logged
        sim = NetSim(self._topo_8x4(), routing=Routing.DETOUR)
        cal = sim.calibrated_axis_gbs(64e6, axis_sizes={"model": 32})
        assert cal["model"] == pytest.approx(
            RECT_8X4_FALLBACK_GBS, rel=GOLDEN_REL
        )
        analytic_plane = sum(
            d.gbs_total for d in sim.topo.dims
        )
        assert cal["model"] < 0.55 * analytic_plane


class TestGoldenAvailability:
    """Campaign-measured Table 6 gap + linearity-under-failures pins."""

    def test_table6_availability_gap(self):
        from repro.runtime.campaign import head_to_head

        h = head_to_head(
            chips=8192, seeds=tuple(range(16)), netsim_reprice=False
        )
        assert h["ub"].availability == pytest.approx(
            UB_AVAILABILITY, rel=GOLDEN_REL
        )
        assert h["clos"].availability == pytest.approx(
            CLOS_AVAILABILITY, rel=GOLDEN_REL
        )
        assert h["availability_gap"] == pytest.approx(
            AVAILABILITY_GAP, rel=GOLDEN_REL
        )
        # the paper's band: "about 7.2% higher availability"
        assert abs(h["availability_gap"] - 0.072) <= 0.02
        # and the seeded MC must agree with the closed-form MTBF/MTTR gap
        assert abs(h["availability_gap"] - h["analytic_gap"]) <= 0.02

    def test_linearity_under_failures(self):
        from repro.runtime.campaign import linearity_under_failures

        lin = linearity_under_failures(
            1024, 8192, seeds=tuple(range(8)),
            netsim_reprice=False, perf_backend="analytic",
        )
        assert lin["linearity"] == pytest.approx(UB_LINEARITY, rel=GOLDEN_REL)
        assert lin["linearity"] >= 0.95          # the paper's claim
        clos = linearity_under_failures(
            1024, 8192, seeds=tuple(range(8)), arch="clos",
            netsim_reprice=False,
        )
        assert clos["linearity"] == pytest.approx(
            CLOS_LINEARITY, rel=GOLDEN_REL
        )
        # the 64+1 backup + reroute story: Clos's restart tax at scale
        assert clos["linearity"] < lin["linearity"] - 0.05


# Message-level latency goldens (one 8x8 rack, DETOUR, 64 KB decode
# payload, 1 us/hop): the decode-serving regime the SLO planner prices.
# The plane-wide AllReduce's 126.7 us vs the 8-clique's 15.2 us is the
# 2(w-1)-step width scaling that makes bandwidth-optimal and SLO-optimal
# decode shardings diverge.
MSG_P2P_64KB_US = 3.56               # exactly size/cap + latency
MSG_RING_AR_8CLIQUE_64KB_US = 15.154
MSG_PLANE_AR_64KB_US = 126.72
MSG_A2A_TOTAL_64KB_US = 3.29
MSG_A2A_P99_64KB_US = 1.96


class TestGoldenMessageLatency:
    """Message-level engine pins: closed-form alpha-beta agreement on
    uncongested paths plus absolute latency-profile goldens."""

    @pytest.fixture(scope="class")
    def rack_profile(self):
        from repro.core.topology import ub_mesh_rack

        sim = NetSim(ub_mesh_rack(), routing=Routing.DETOUR)
        return sim, sim.measure_latency_profile(64e3)

    def test_p2p_matches_closed_form(self, rack_profile):
        # one X-dim hop: serialization at the 4-lane 25 GB/s link plus
        # one propagation latency, nothing else — exact, not just <= 2%
        sim, prof = rack_profile
        from repro.netsim.flows import _wire_structure

        cap, _ = _wire_structure(sim.topo)
        closed = 64e3 / cap[(0, 1)] + sim.latency_s
        assert prof.get("model", "p2p").total_s == pytest.approx(
            closed, rel=1e-9
        )
        assert closed * 1e6 == pytest.approx(MSG_P2P_64KB_US, rel=GOLDEN_REL)

    def test_ring_allreduce_matches_alpha_beta(self, rack_profile):
        # uncongested 8-clique multi-ring: per dependency-chain step the
        # message engine pays chunk/cap + latency, which is exactly the
        # fluid model's launch-latency + wire-time alpha-beta cost — the
        # two engines must agree within the golden band
        sim, _ = rack_profile
        prof8 = sim.measure_latency_profile(
            64e3, widths={("model", "allreduce"): 8},
        )
        msg_t = prof8.get("model", "allreduce").total_s
        from repro.netsim.collectives import clique_nodes, ring_allreduce

        ring = ring_allreduce(
            sim.topo, clique_nodes(sim.topo, 0), 64e3, tag="golden-ring"
        )
        fluid_t = sim.run_dag(ring).makespan_s
        assert msg_t == pytest.approx(fluid_t, rel=GOLDEN_REL)
        assert msg_t * 1e6 == pytest.approx(
            MSG_RING_AR_8CLIQUE_64KB_US, rel=GOLDEN_REL
        )

    def test_plane_allreduce_width_scaling(self, rack_profile):
        _, prof = rack_profile
        total = prof.get("model", "allreduce").total_s
        assert total * 1e6 == pytest.approx(
            MSG_PLANE_AR_64KB_US, rel=GOLDEN_REL
        )
        # the SLO-divergence mechanism: the full 64-chip plane costs ~8x
        # the 8-clique per collective at decode payloads
        assert total > 5 * MSG_RING_AR_8CLIQUE_64KB_US / 1e6

    def test_a2a_incast_tail(self, rack_profile):
        _, prof = rack_profile
        a2a = prof.get("model", "all_to_all")
        assert a2a.total_s * 1e6 == pytest.approx(
            MSG_A2A_TOTAL_64KB_US, rel=GOLDEN_REL
        )
        assert a2a.p99_s * 1e6 == pytest.approx(
            MSG_A2A_P99_64KB_US, rel=GOLDEN_REL
        )
        # queueing behind links/ejection ports: a real tail, which the
        # fluid model's single flat launch latency cannot produce
        assert a2a.p99_s > a2a.p50_s
