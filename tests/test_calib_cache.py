"""Persistent calibration cache (ISSUE 7): disk-warm plan() parity,
store-key invalidation, and corruption robustness.

The contract under test: a second *process* (simulated by clearing the
in-memory memo) that plans the same workload on the same configuration
must read every calibration entry back from disk and produce a
bit-identical ``PlanReport`` ranking — while any change to what defines
a measurement (topology, routing, schema versions) lands in a different
file, and a damaged file is ignored with a warning, never a crash.
"""

import json
import os

import pytest

from repro.core import calib_cache as cc
from repro.core import perf_model as pm
from repro.core.calib_cache import CalibCache, default_cache_dir
from repro.core.cost_model import Routing, build_comm_model
from repro.core.perf_model import NetsimPerfModel, reset_calibration_stats
from repro.core.planner import plan
from repro.core.topology import ub_mesh_pod
from repro.core.traffic import backend_comparison_workloads

W_CLEAN, _ = backend_comparison_workloads()


def _perf(tmp_path, **kw):
    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
    kw.setdefault("cache_dir", str(tmp_path / "store"))
    return NetsimPerfModel(comm, topo=ub_mesh_pod(), size_bytes=16e6, **kw)


def _restart():
    """Simulate a process restart: drop every in-memory calibration."""
    pm._CALIBRATION_CACHE.clear()
    pm._DISK_CACHES.clear()
    reset_calibration_stats()


class TestDiskWarmParity:
    def test_cold_then_warm_plan_bit_identical(self, tmp_path):
        perf = _perf(tmp_path)
        _restart()
        cold = plan(W_CLEAN, 256, perf)
        assert cold.calibration["misses"] > 0
        assert cold.calibration["disk_hits"] == 0
        files = list((tmp_path / "store").glob("calib-*.json"))
        assert files, "cold plan must write the store"

        _restart()
        warm = plan(W_CLEAN, 256, _perf(tmp_path))
        # every miss served from disk, nothing re-measured
        assert warm.calibration["disk_hits"] == warm.calibration["misses"] > 0
        assert warm.calibration["measure_s"] == 0.0
        # bit-identical ranking: JSON float repr roundtrips exactly
        assert [(r.spec, r.iteration_s) for r in warm] == [
            (r.spec, r.iteration_s) for r in cold
        ]

    def test_precalibrate_reports_disk_hits(self, tmp_path):
        from repro.core.planner import enumerate_specs

        perf = _perf(tmp_path)
        specs = enumerate_specs(W_CLEAN, 256)
        _restart()
        first = perf.precalibrate(specs)
        assert first["measured"] == first["keys"] > 0
        _restart()
        second = _perf(tmp_path).precalibrate(specs)
        assert second["disk_hits"] == second["keys"] == first["keys"]
        assert second["measured"] == 0


class TestStoreInvalidation:
    def test_config_changes_land_in_different_files(self, tmp_path):
        cache = CalibCache(tmp_path)
        base = ["topo", "detour", 16e6]
        assert cache.path_for(base) == cache.path_for(list(base))
        assert cache.path_for(base) != cache.path_for(["topo2", "detour", 16e6])
        assert cache.path_for(base) != cache.path_for(["topo", "shortest", 16e6])

    def test_schema_bump_changes_the_store_key(self, tmp_path, monkeypatch):
        cache = CalibCache(tmp_path)
        p_old = cache.path_for(["cfg"])
        monkeypatch.setattr(cc, "SCHEMA_VERSION", cc.SCHEMA_VERSION + 1)
        assert cache.path_for(["cfg"]) != p_old

    def test_routing_change_remeasures_end_to_end(self, tmp_path):
        _restart()
        plan(W_CLEAN, 256, _perf(tmp_path))
        _restart()
        comm = build_comm_model(multi_pod=False, routing=Routing.SHORTEST)
        other = NetsimPerfModel(
            comm, topo=ub_mesh_pod(), size_bytes=16e6,
            cache_dir=str(tmp_path / "store"),
        )
        rep = plan(W_CLEAN, 256, other)
        # nothing from the DETOUR store may serve a SHORTEST measurement
        assert rep.calibration["disk_hits"] == 0
        assert rep.calibration["misses"] > 0

    def test_version_skewed_file_ignored_with_warning(self, tmp_path, caplog):
        cache = CalibCache(tmp_path)
        cache.update(["cfg"], {("model", "allreduce", None): 100.0})
        path = cache.path_for(["cfg"])
        doc = json.loads(path.read_text())
        doc["solver"] = -1
        path.write_text(json.dumps(doc))
        with caplog.at_level("WARNING", logger="repro.core.calib_cache"):
            assert CalibCache(tmp_path).get_profile(["cfg"]) == {}
        assert any("re-measuring" in r.message for r in caplog.records)


class TestCorruptionRobustness:
    def test_truncated_file_warns_once_and_remeasures(self, tmp_path, caplog):
        perf = _perf(tmp_path)
        _restart()
        plan(W_CLEAN, 256, perf)
        for f in (tmp_path / "store").glob("calib-*.json"):
            f.write_text(f.read_text()[: len(f.read_text()) // 2])
        _restart()
        with caplog.at_level("WARNING", logger="repro.core.calib_cache"):
            rep = plan(W_CLEAN, 256, _perf(tmp_path))
        assert rep.calibration["disk_hits"] == 0
        assert rep.calibration["misses"] > 0
        assert len(rep) > 0
        warned = [r for r in caplog.records if "unreadable" in r.message]
        assert warned, "corruption must be logged"
        # ...once per file, not once per key
        assert len(warned) <= len(list((tmp_path / "store").glob("*.json")))

    def test_garbage_json_returns_empty(self, tmp_path, caplog):
        cache = CalibCache(tmp_path)
        cache.update(["cfg"], {("model", "allreduce", None): 100.0})
        cache.path_for(["cfg"]).write_text("{not json")
        with caplog.at_level("WARNING", logger="repro.core.calib_cache"):
            assert CalibCache(tmp_path).get_profile(["cfg"]) == {}

    def test_unwritable_dir_never_raises(self, tmp_path, caplog):
        blocker = tmp_path / "blocked"
        blocker.write_text("a file where the cache dir should be")
        cache = CalibCache(blocker)  # mkdir will fail with NotADirectoryError
        with caplog.at_level("WARNING", logger="repro.core.calib_cache"):
            cache.update(["cfg"], {("model", "allreduce", None): 1.0})
        assert cache.get_profile(["cfg"]) == {}


class TestCacheLocation:
    def test_env_override_wins(self, monkeypatch, tmp_path):
        monkeypatch.setenv("CALIB_CACHE_DIR", str(tmp_path / "envdir"))
        assert default_cache_dir() == tmp_path / "envdir"

    def test_xdg_fallback(self, monkeypatch, tmp_path):
        monkeypatch.delenv("CALIB_CACHE_DIR", raising=False)
        monkeypatch.setenv("XDG_CACHE_HOME", str(tmp_path / "xdg"))
        assert default_cache_dir() == tmp_path / "xdg" / "ubmesh-repro" / "calib"

    def test_update_merges_entries(self, tmp_path):
        cache = CalibCache(tmp_path)
        cache.update(["cfg"], {("model", "allreduce", None): 100.0})
        cache.update(["cfg"], {("model", "all_gather", 8): 50.0})
        prof = cache.get_profile(["cfg"])
        assert prof == {
            ("model", "allreduce", None): 100.0,
            ("model", "all_gather", 8): 50.0,
        }


class TestPrune:
    """Store-count cap (ISSUE 8): geometry sweeps write one file per
    candidate topology, so the directory is pruned LRU-by-mtime."""

    def _fill(self, cache, n):
        for i in range(n):
            cache.update([f"cfg-{i}"], {("model", "allreduce", None): float(i)})
            # mtime-ordered: make each store strictly newer than the last
            os_path = cache.path_for([f"cfg-{i}"])
            os.utime(os_path, (1_000_000 + i, 1_000_000 + i))

    def test_prune_keeps_newest(self, tmp_path):
        cache = CalibCache(tmp_path)
        self._fill(cache, 6)
        removed = cache.prune(keep=2)
        assert len(removed) == 4
        left = sorted(tmp_path.glob("calib-*.json"))
        assert len(left) == 2
        # the survivors are the two most recently written configs
        assert cache.get_profile(["cfg-5"]) != {}
        assert cache.get_profile(["cfg-4"]) != {}
        assert cache.get_profile(["cfg-0"]) == {}

    def test_prune_disabled_by_nonpositive_keep(self, tmp_path):
        cache = CalibCache(tmp_path)
        self._fill(cache, 4)
        assert cache.prune(keep=0) == []
        assert len(list(tmp_path.glob("calib-*.json"))) == 4

    def test_env_override_controls_cap(self, tmp_path, monkeypatch):
        monkeypatch.setenv(cc.MAX_STORES_ENV_VAR, "3")
        assert cc.max_stores() == 3
        cache = CalibCache(tmp_path)
        # update() prunes automatically after each write
        self._fill(cache, 5)
        assert len(list(tmp_path.glob("calib-*.json"))) <= 3

    def test_unparsable_env_falls_back_to_default(self, monkeypatch, caplog):
        monkeypatch.setenv(cc.MAX_STORES_ENV_VAR, "lots")
        with caplog.at_level("WARNING", logger="repro.core.calib_cache"):
            assert cc.max_stores() == cc.DEFAULT_MAX_STORES

    def test_default_cap_is_256(self, monkeypatch):
        monkeypatch.delenv(cc.MAX_STORES_ENV_VAR, raising=False)
        assert cc.max_stores() == 256
