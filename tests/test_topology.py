"""Core topology + APR unit & property tests."""

import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-shim

from repro.core import apr, topology
from repro.core.topology import DimSpec, NDFullMesh, PASSIVE_ELECTRICAL, ub_mesh_pod


def small_mesh(shape=(3, 2, 2)):
    return NDFullMesh(
        dims=tuple(
            DimSpec(f"D{i}", s, PASSIVE_ELECTRICAL, 2) for i, s in enumerate(shape)
        )
    )


class TestNDFullMesh:
    def test_pod_shape(self):
        pod = ub_mesh_pod()
        assert pod.num_nodes == 1024
        assert pod.shape == (8, 8, 4, 4)

    def test_coords_roundtrip(self):
        t = small_mesh()
        for n in range(t.num_nodes):
            assert t.node_id(t.coords(n)) == n

    def test_neighbors_are_single_dim(self):
        t = small_mesh()
        for n in range(t.num_nodes):
            for peer, dim in t.all_neighbors(n):
                assert t.are_adjacent(n, peer) == dim

    def test_link_count_formula(self):
        t = small_mesh((4, 3))
        # dim0: 3 groups * C(4,2)=6 -> 18 ; dim1: 4 groups * C(3,2)=3 -> 12
        assert t.link_count(0) == 18
        assert t.link_count(1) == 12

    def test_hop_distance_is_hamming(self):
        t = small_mesh()
        assert t.hop_distance(0, t.node_id((2, 1, 1))) == 3

    @given(st.integers(0, 1023), st.integers(0, 1023))
    @settings(max_examples=50, deadline=None)
    def test_hop_distance_symmetric_pod(self, u, v):
        pod = ub_mesh_pod()
        assert pod.hop_distance(u, v) == pod.hop_distance(v, u)
        assert pod.hop_distance(u, v) <= pod.ndim


class TestAPR:
    def test_shortest_path_count_is_factorial(self):
        pod = ub_mesh_pod()
        src = 0
        dst = pod.node_id((1, 1, 1, 1))
        paths = apr.shortest_paths(pod, src, dst)
        assert len(paths) == 24  # 4 differing dims -> 4!
        for p in paths:
            assert len(p) == 5
            assert p[0] == src and p[-1] == dst

    @given(st.integers(0, 1023), st.integers(0, 1023))
    @settings(max_examples=30, deadline=None)
    def test_all_paths_valid(self, src, dst):
        pod = ub_mesh_pod()
        for p in apr.all_paths(pod, src, dst):
            assert p[0] == src and p[-1] == dst
            for a, b in zip(p, p[1:]):
                assert pod.are_adjacent(a, b) is not None
            assert len(set(p)) == len(p)  # loop-free

    def test_sr_header_roundtrip(self):
        pod = ub_mesh_pod()
        paths = apr.all_paths(pod, 0, pod.node_id((1, 1, 0, 0)))
        for p in paths[:10]:
            hdr = apr.encode_path(pod, p)
            assert apr.walk_header(pod, p[0], hdr) == p
            assert len(hdr.pack()) == 8
            assert apr.SourceRouteHeader.unpack(hdr.pack()) == hdr

    def test_linear_table_routes(self):
        pod = ub_mesh_pod()
        lrt = apr.LinearRouteTable(pod)
        rng = np.random.default_rng(0)
        for _ in range(50):
            s, d = rng.integers(0, pod.num_nodes, 2)
            path = lrt.route(int(s), int(d))
            assert path[0] == s and path[-1] == d
            assert len(path) - 1 == pod.hop_distance(int(s), int(d))

    def test_linear_table_space_is_linear(self):
        pod = ub_mesh_pod()
        lrt = apr.LinearRouteTable(pod)
        # linear in sum(dims), NOT product: 1024 * (8+8+4+4)
        assert lrt.table_entries() == 1024 * 24

    def test_tfc_deadlock_free_random_traffic(self):
        pod = ub_mesh_pod()
        rng = np.random.default_rng(1)
        paths = []
        for _ in range(100):
            s, d = rng.integers(0, pod.num_nodes, 2)
            if s != d:
                paths.extend(apr.all_paths(pod, int(s), int(d)))
        assert apr.verify_deadlock_free(pod, paths, n_vls=2)

    def test_tfc_admissible_nonempty(self):
        pod = ub_mesh_pod()
        paths = apr.all_paths(pod, 0, pod.node_id((1, 1, 1, 1)))
        adm = apr.tfc_admissible(pod, paths)
        assert len(adm) >= 1
        # the in-dimension-order shortest path is always admissible
        assert any(len(p) == 5 for p, _ in adm)

    def test_reroute_avoids_failed_link(self):
        pod = ub_mesh_pod()
        plan = apr.RoutePlan(pod)
        dst = pod.node_id((1, 1, 0, 0))
        plan.install(0, dst, apr.shortest_paths(pod, 0, dst)[0])
        link = (0, pod.node_id((1, 0, 0, 0)))
        if plan.affected_flows(link):
            fixed = plan.reroute(link)
            for p in fixed.values():
                edges = {tuple(sorted(e)) for e in zip(p, p[1:])}
                assert tuple(sorted(link)) not in edges

    def test_direct_notification_fewer_messages(self):
        pod = ub_mesh_pod()
        plan = apr.RoutePlan(pod)
        rng = np.random.default_rng(2)
        for _ in range(64):
            s, d = rng.integers(0, pod.num_nodes, 2)
            if s != d:
                plan.install(int(s), int(d), apr.shortest_paths(pod, int(s), int(d))[0])
        link = next(iter(plan._by_link))
        direct = plan.direct_notify(link)
        flood = plan.hop_by_hop_notify(link)
        assert len(direct) <= pod.num_nodes
        for src in direct:
            assert direct[src] <= flood[src]


class TestCables:
    def test_table2_ratios(self):
        sp = topology.SuperPod()
        cb = sp.cables_by_link_type(uplink_provisioning=0.25)
        tot = sum(cb.values())
        frac = {k: v / tot for k, v in cb.items()}
        # paper Table 2: 86.7 / 7.2 / 4.8 / 1.2
        assert frac["passive_electrical"] > 0.80
        assert frac["active_electrical"] < 0.12
        assert frac["optical_100m"] + frac["optical_1km"] < 0.10

    def test_switch_and_optics_savings(self):
        sp = topology.SuperPod()
        clos = topology.ClosFabric(8192)
        assert 1 - sp.hrs_count() / clos.hrs_count() > 0.95      # paper: 98%
        assert 1 - sp.optical_modules() / clos.optical_modules() > 0.90  # 93%
