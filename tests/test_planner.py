"""Planner + PerfModel backends: memory model, linearity, skip accounting,
and the analytic-vs-netsim backend contract (agree when uncongested,
diverge — documented below — when the model-axis groups are contended)."""

import time

import pytest

from repro.core import planner
from repro.core.cost_model import (
    AxisCost,
    CommModel,
    Routing,
    build_comm_model,
    clos_comm_model,
)
from repro.core.perf_model import (
    AnalyticPerfModel,
    NetsimPerfModel,
    PerfModel,
)
from repro.core.planner import PlanReport, memory_feasible, plan
from repro.core.simulator import linearity_curve, simulate
from repro.core.topology import ub_mesh_pod
from repro.core import traffic as traffic_mod
from repro.core.traffic import ParallelSpec, WorkloadSpec


def _dense(params=8e9, **kw):
    kw.setdefault("seq_len", 512)
    kw.setdefault("global_batch", 16)
    return WorkloadSpec(
        "dense-test", 8, 1024, 8, 128, 8, params_total=params, **kw
    )


class TestMemoryFeasible:
    def test_zero1_optimizer_shards_scale_with_dp(self):
        # params alone fit (2+2 bytes/param = 32 GB < 48), the fp32 ZeRO-1
        # optimizer state (12 bytes/param) only fits once sharded over dp
        w = _dense(params=8e9)
        assert not memory_feasible(w, ParallelSpec(tp=1, sp=1, pp=1, dp=1, microbatches=1))
        assert memory_feasible(w, ParallelSpec(tp=1, sp=1, pp=1, dp=16, microbatches=1))

    def test_dense_branch_tp_pp_shard_params(self):
        w = _dense(params=64e9)
        assert not memory_feasible(w, ParallelSpec(tp=1, sp=1, pp=1, dp=64, microbatches=1))
        assert memory_feasible(w, ParallelSpec(tp=8, sp=1, pp=2, dp=64, microbatches=2))

    def test_moe_branch_ep_shards_expert_params_only(self):
        # 16B params, 80% in experts: dense 3.2B replicated, experts 12.8B
        # sharded over ep — ep=8 fits where ep=1 cannot
        w = _dense(params=16e9)
        w = WorkloadSpec(
            w.name, w.n_layers, w.hidden, w.n_heads, w.head_dim, 8,
            seq_len=512, global_batch=64, params_total=16e9,
            n_experts=8, topk=2, moe_param_frac=0.8,
        )
        infeasible = ParallelSpec(tp=1, sp=1, pp=1, dp=64, ep=1, microbatches=1)
        feasible = ParallelSpec(tp=1, sp=1, pp=1, dp=64, ep=8, microbatches=1)
        assert not memory_feasible(w, infeasible)
        assert memory_feasible(w, feasible)


class _SpyPerf:
    """PerfModel wrapper recording override_axis calls (protocol probe)."""

    def __init__(self, base, log=None):
        self.base = base
        self.overrides = log if log is not None else []

    @property
    def backend(self):
        return self.base.backend

    def comm_model(self, p=None):
        return self.base.comm_model(p)

    def override_axis(self, name, cost):
        self.overrides.append((name, cost))
        return _SpyPerf(self.base.override_axis(name, cost), self.overrides)


class TestLinearityCurve:
    W = WorkloadSpec(
        "lin-test", 48, 8192, 64, 128, 8,
        seq_len=16384, global_batch=64, params_total=7e10,
    )

    def test_weak_scaling_sane_within_pod(self):
        lin = linearity_curve(self.W, 1024, [1, 4])
        assert lin[1] == pytest.approx(1.0)
        # weak scaling inside the pod fabric: near-linear, never a free lunch
        assert 0.90 <= lin[4] <= 1.05

    def test_dcn_penalty_branch_above_8192_chips(self):
        comm = build_comm_model(multi_pod=True, routing=Routing.BORROW)
        spy = _SpyPerf(comm)
        lin = linearity_curve(self.W, 2048, [4, 8], perf=spy)
        # scale 4 (8192 chips) stays on the HRS pod tier; scale 8 (16384)
        # crosses the DCN: the pod axis must be re-pinned at 1/2.5 bandwidth
        pods = [(n, c) for n, c in spy.overrides if n == "pod"]
        assert len(pods) == 1
        _, cost = pods[0]
        assert cost.gbs_per_chip == pytest.approx(
            comm.axes["pod"].gbs_per_chip / 2.5
        )
        assert cost.size == 2
        # and the penalized point scales worse than the in-fabric one
        assert lin[8] < lin[4]


class TestPlanReport:
    W = WorkloadSpec(
        "report-test", 16, 4096, 32, 128, 8,
        seq_len=8192, global_batch=64, params_total=1e10,
    )

    def test_simulate_errors_are_counted_not_swallowed(self, caplog):
        # a cost model without the "data" axis makes PP/DP pricing raise
        # KeyError for every spec that needs it — previously silently eaten
        broken = CommModel(axes={"model": AxisCost(16, 200.0, 1e-6)})
        with caplog.at_level("WARNING", logger="repro.core.planner"):
            rep = plan(self.W, 64, broken)
        assert isinstance(rep, PlanReport)
        assert rep.skipped.get("KeyError", 0) > 0
        assert rep.n_skipped == sum(rep.skipped.values())
        assert any("skipped by simulate errors" in r.message for r in caplog.records)

    def test_healthy_plan_reports_zero_skips(self):
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        rep = plan(self.W, 64, comm)
        assert rep.n_skipped == 0 and rep.skipped == {}
        assert rep.n_enumerated > len(rep)
        # sequence protocol: iteration, len, indexing all work
        assert [r.spec for r in rep][0] == rep[0].spec


class TestPerfModelBackends:
    # the canonical (uncongested -> agree, contended -> diverge) pair,
    # shared with benchmarks/planner_bench.py; the helper's docstring
    # documents WHY the contended MoE config flips the winner (narrow
    # hierarchical model groups measure ~2x below the full-plane 2D
    # multi-ring that the analytic backend prices identically)
    W_CLEAN, W_CONTENDED = traffic_mod.backend_comparison_workloads()

    @pytest.fixture(scope="class")
    def backends(self):
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        return (
            AnalyticPerfModel(comm),
            NetsimPerfModel(comm, topo=ub_mesh_pod(), size_bytes=64e6),
        )

    def test_both_backends_satisfy_protocol(self, backends):
        analytic, netsim = backends
        assert isinstance(analytic, PerfModel)
        assert isinstance(netsim, PerfModel)
        assert isinstance(analytic.comm_model(None), CommModel)
        assert isinstance(netsim.comm_model(None), CommModel)

    def test_backends_agree_on_uncongested_config(self, backends):
        analytic, netsim = backends
        sa = planner.best_parallel_spec(self.W_CLEAN, 256, analytic)
        sn = planner.best_parallel_spec(self.W_CLEAN, 256, netsim)
        assert sa == sn

    def test_backends_diverge_on_contended_config(self, backends):
        analytic, netsim = backends
        sa = planner.best_parallel_spec(self.W_CONTENDED, 256, analytic)
        sn = planner.best_parallel_spec(self.W_CONTENDED, 256, netsim)
        assert sa != sn
        # the netsim winner buys a wider model-axis group (full plane ->
        # cross-dim rings) precisely because narrow groups measure slower
        assert sn.tp * sn.sp >= sa.tp * sa.sp
        # and under the measured bandwidths its own winner really is faster
        t_sa = simulate(self.W_CONTENDED, sa, netsim).iteration_s
        t_sn = simulate(self.W_CONTENDED, sn, netsim).iteration_s
        assert t_sn <= t_sa

    def test_netsim_backend_full_plan_1024_chips_under_60s(self, backends):
        _, netsim = backends
        w = WorkloadSpec(
            "dense-70B-1k", 80, 8192, 64, 128, 8,
            seq_len=8192, global_batch=512, params_total=7e10,
        )
        t0 = time.time()
        rep = plan(w, 1024, netsim)
        elapsed = time.time() - t0
        assert len(rep) > 0
        assert elapsed < 60.0, f"netsim-backed plan took {elapsed:.1f}s"

    def test_calibration_memoized_per_width_not_per_spec(self, backends):
        from repro.core import perf_model as pm

        _, netsim = backends
        plan(self.W_CLEAN, 256, netsim)  # warm
        before = len(pm._CALIBRATION_CACHE)
        plan(self.W_CLEAN, 256, netsim)  # hundreds of specs, zero new keys
        assert len(pm._CALIBRATION_CACHE) == before

    def test_netsim_never_prices_above_analytic(self, backends):
        analytic, netsim = backends
        ca = analytic.comm_model(None)
        cn = netsim.comm_model(None)
        for name, a in cn.axes.items():
            assert a.gbs_per_chip <= ca.axes[name].gbs_per_chip * 1.001


class TestAnalyticPrefilter:
    """ISSUE-7 pre-filter: the vectorized analytic cull must never change
    the winner on any bench config (prefilter=None is the proven-equal
    escape hatch), must actually cull, and must fall back to the
    unfiltered path on models it cannot price."""

    def _configs(self):
        moe2t, _ = traffic_mod.moe_2t_workload()
        for w in traffic_mod.backend_comparison_workloads():
            yield w, 1024
            yield w, 4096
        yield traffic_mod.a2a_divergence_workload(), 1024
        yield moe2t, 4096

    @pytest.mark.parametrize("factory,label", [
        (lambda: build_comm_model(multi_pod=True, routing=Routing.DETOUR), "ubmesh"),
        (lambda: clos_comm_model(multi_pod=True), "clos"),
    ])
    def test_winner_preserved_on_every_bench_config(self, factory, label):
        comm = factory()
        for w, chips in self._configs():
            full = plan(w, chips, comm, prefilter=None)
            fast = plan(w, chips, comm)
            assert fast[0].spec == full[0].spec, (label, w.name, chips)
            assert fast[0].iteration_s == pytest.approx(
                full[0].iteration_s, rel=1e-12
            )
            # the filter genuinely culls (these spaces are all 200+ specs)
            assert fast.n_prefiltered > 0, (label, w.name, chips)
            assert full.n_prefiltered == 0

    def test_winner_preserved_on_netsim_backend(self):
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        netsim = NetsimPerfModel(comm, topo=ub_mesh_pod(), size_bytes=16e6)
        w = traffic_mod.a2a_divergence_workload()
        fast = plan(w, 256, netsim)
        full = plan(w, 256, netsim, prefilter=None, precalibrate=False)
        assert fast[0].spec == full[0].spec
        assert fast[0].iteration_s == pytest.approx(
            full[0].iteration_s, rel=1e-12
        )
        assert fast.n_prefiltered > 0

    def test_unpriceable_model_falls_back_to_unfiltered(self):
        # no "data" axis: the prefilter cannot price PP/DP and must get out
        # of the way — same skip accounting as the unfiltered path
        broken = CommModel(axes={"model": AxisCost(16, 200.0, 1e-6)})
        w = TestPlanReport.W
        rep = plan(w, 64, broken)
        assert rep.n_prefiltered == 0
        assert rep.skipped.get("KeyError", 0) > 0

    def test_enumeration_knobs_thread_through(self):
        w = TestPlanReport.W
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        wide = plan(w, 64, comm)
        narrow = plan(w, 64, comm, max_tp=2, microbatch_options=(1,))
        assert narrow.n_enumerated < wide.n_enumerated
        assert all(r.spec.tp <= 2 and r.spec.microbatches == 1 for r in narrow)
        s = planner.best_parallel_spec(
            w, 64, comm, max_tp=2, microbatch_options=(1,)
        )
        assert s.tp <= 2 and s.microbatches == 1


class TestBatchedPrecalibration:
    """ISSUE-7 batched calibration: precalibrate() front-loads every key a
    spec set needs, and the relocated concurrent DAGs measure exactly what
    sequential runs measure (the box-disjointness invariant)."""

    def test_precalibrate_covers_plan_keys(self):
        from repro.core import perf_model as pm

        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        netsim = NetsimPerfModel(
            comm, topo=ub_mesh_pod(), size_bytes=16e6, cache_dir=None
        )
        w = TestPerfModelBackends.W_CLEAN
        specs = planner.enumerate_specs(w, 256)
        info = netsim.precalibrate(specs)
        assert info["keys"] > 0
        # a subsequent plan over the same space measures nothing new
        before = len(pm._CALIBRATION_CACHE)
        rep = plan(w, 256, netsim, prefilter=None)
        assert len(pm._CALIBRATION_CACHE) == before
        assert rep.calibration["misses"] == 0

    def test_batched_measurement_matches_sequential(self):
        from repro.netsim import NetSim

        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        sim = NetSim(ub_mesh_pod(), routing=Routing.DETOUR)
        reqs = [
            ("model", "allreduce", None), ("model", "all_gather", 8),
            ("model", "all_to_all", 4), ("data", "allreduce", None),
            ("data", "p2p", None), ("model", "allreduce", 16),
        ]
        batched = sim.measure_profile_batch(16e6, reqs, comm=comm, batch_size=6)
        sequential = sim.measure_profile_batch(16e6, reqs, comm=comm, batch_size=1)
        for key in reqs:
            assert batched[key] == pytest.approx(sequential[key], rel=1e-9), key

    def test_borrow_routing_disables_batching(self):
        from repro.netsim import NetSim

        sim = NetSim(ub_mesh_pod(), routing=Routing.BORROW)
        assert not sim.can_batch_calibration()
        # sequential fallback still measures every key
        comm = build_comm_model(multi_pod=False, routing=Routing.BORROW)
        out = sim.measure_profile_batch(
            16e6, [("model", "allreduce", None)], comm=comm
        )
        assert out[("model", "allreduce", None)] > 0


class TestShapeAwareProfile:
    """AllReduce-proxy vs CalibrationProfile pricing (ISSUE 3 tentpole):
    one scalar per axis systematically flatters expert parallelism; the
    shape-keyed profile prices EP's A2A on its own measured bandwidth and
    flips the planner's winner on the canonical divergence config."""

    W_DIV = traffic_mod.a2a_divergence_workload()

    @pytest.fixture(scope="class")
    def backends(self):
        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        kw = dict(topo=ub_mesh_pod(), size_bytes=16e6)
        return (
            NetsimPerfModel(comm, shapes=("allreduce",), **kw),   # PR-2 proxy
            NetsimPerfModel(comm, **kw),                          # full profile
        )

    def test_winner_flips_on_a2a_pricing(self, backends):
        proxy, profile = backends
        sp = planner.best_parallel_spec(self.W_DIV, 256, proxy)
        sf = planner.best_parallel_spec(self.W_DIV, 256, profile)
        assert sp != sf
        # the proxy maxes out expert parallelism because the dispatch A2A
        # is priced at ring bandwidth; the profile retreats to smaller,
        # clique-local EP groups
        assert sf.ep < sp.ep
        # and under the shape-aware prices its own winner really is faster
        t_sp = simulate(self.W_DIV, sp, profile).iteration_s
        t_sf = simulate(self.W_DIV, sf, profile).iteration_s
        assert t_sf <= t_sp

    def test_profile_comm_model_carries_shape_bandwidths(self, backends):
        _, profile = backends
        p = ParallelSpec(tp=2, sp=4, pp=1, dp=32, ep=8, microbatches=1)
        a = profile.comm_model(p).axes["model"]
        assert a.has_shape("all_to_all")
        # ep=8 spans two boards: A2A rides the cross-board cut, well below
        # the ring bandwidth
        assert a.bw_for("all_to_all") < a.bw_for("allreduce")

    def test_proxy_backend_prices_all_shapes_on_scalar(self, backends):
        proxy, _ = backends
        a = proxy.comm_model(None).axes["model"]
        assert not a.has_shape("all_to_all")
        assert a.bw_for("all_to_all") == a.gbs_per_chip

    def test_analytic_perf_model_carries_profile(self):
        from repro.core.cost_model import CalibrationProfile

        comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
        prof = CalibrationProfile(gbs={("model", "all_to_all"): 45.0})
        pm = AnalyticPerfModel(comm, profile=prof)
        assert pm.comm_model(None).axes["model"].bw_for("all_to_all") == 45.0
        # override_axis must not drop the profile
        pm2 = pm.override_axis("pod", AxisCost(2, 10.0, 1e-6))
        assert pm2.profile is prof
