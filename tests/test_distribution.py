"""Distribution-layer tests: sharding rules, ZeRO-1, optimizer, compression,
checkpoint/restart + elastic restore, data pipeline, fault tolerance."""

import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-shim
from jax.sharding import PartitionSpec as P

from repro.data.pipeline import DataConfig, Pipeline, SyntheticSource
from repro.checkpoint.manager import CheckpointManager
from repro.models.param import ParamSpec, ShardingRules, tree_init, tree_pspecs
from repro.optim import adamw
from repro.optim.compression import CompressionConfig, compress_grads
from repro.parallel.sharding import make_rules, tree_zero1_pspecs, zero1_pspec
from repro.runtime.elastic import ElasticPlan
from repro.runtime.fault_tolerance import RackFailover, TrainingSupervisor


class TestShardingRules:
    def test_train_rules_seq_shard_wins_over_ff(self):
        rules = make_rules(multi_pod=False, sp=True)
        # activation (batch, sp, ff_act): sp takes "model", ff dropped
        spec = rules.pspec(("batch", "sp", "ff_act"))
        assert spec == P("data", "model")

    def test_decode_rules_ff_gets_model(self):
        rules = make_rules(multi_pod=False, sp=False)
        spec = rules.pspec(("batch", "sp", "ff_act"))
        assert spec == P("data", None, "model")

    def test_multipod_batch_spans_pod_and_data(self):
        rules = make_rules(multi_pod=True, sp=True)
        spec = rules.pspec(("batch", "sp", None))
        assert spec == P(("pod", "data"), "model")

    def test_zero1_adds_dp_axis_on_free_dim(self):
        rules = make_rules(multi_pod=False, sp=True)
        s = ParamSpec((4096, 1024), ("embed_in", "ff"))
        ps = zero1_pspec(s, rules, dp_size=16)
        assert ps == P("data", "model")

    def test_zero1_skips_layer_dim(self):
        rules = make_rules(multi_pod=False, sp=True)
        s = ParamSpec((36, 4096, 1024), ("layers", "embed_in", "ff"))
        ps = zero1_pspec(s, rules, dp_size=16)
        assert ps == P(None, "data", "model")

    @given(st.integers(1, 4), st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_pspec_never_reuses_axis(self, a, b):
        rules = make_rules(multi_pod=True, sp=True)
        logical = ("batch", "sp", "ff_act", "vocab")[: a + b]
        spec = rules.pspec(tuple(logical))
        used = []
        for entry in spec:
            if entry is None:
                continue
            used.extend([entry] if isinstance(entry, str) else list(entry))
        assert len(used) == len(set(used))


class TestOptimizer:
    def _setup(self):
        specs = {
            "w": ParamSpec((64, 32), (None, None)),
            "b": ParamSpec((32,), (None,), init="zeros"),
        }
        params = tree_init(specs, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
        return params, adamw.init_opt_state(params)

    def test_step_reduces_quadratic_loss(self):
        params, opt = self._setup()
        cfg = adamw.OptConfig(lr=1e-2, warmup_steps=1, decay_steps=100, weight_decay=0.0)

        def loss_fn(p):
            return jnp.sum(p["w"].astype(jnp.float32) ** 2) + jnp.sum(
                p["b"].astype(jnp.float32) ** 2
            )

        l0 = float(loss_fn(params))
        for _ in range(20):
            grads = jax.grad(lambda p: loss_fn(p))(params)
            params, opt, m = adamw.apply(cfg, params, grads, opt)
        assert float(loss_fn(params)) < l0 * 0.8

    def test_masters_stay_fp32(self):
        params, opt = self._setup()
        cfg = adamw.OptConfig()
        grads = jax.tree.map(jnp.ones_like, params)
        params, opt, _ = adamw.apply(cfg, params, grads, opt)
        assert all(x.dtype == jnp.float32 for x in jax.tree.leaves(opt["master"]))
        assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(params))

    def test_grad_clipping(self):
        params, opt = self._setup()
        cfg = adamw.OptConfig(clip_norm=1.0)
        grads = jax.tree.map(lambda p: jnp.full_like(p, 100.0), params)
        _, _, metrics = adamw.apply(cfg, params, grads, opt)
        assert float(metrics["grad_norm"]) > 1.0  # reported pre-clip


class TestCompression:
    def test_int8_error_feedback_reduces_bias(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
        cfg = CompressionConfig(mode="int8", ef=True)
        residual = None
        total_err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        for _ in range(50):
            payload, residual = compress_grads(cfg, g, residual)
            acc = acc + payload
        # with error feedback the time-averaged payload converges to g
        np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g), atol=2e-3)

    def test_bf16_halves_payload(self):
        from repro.optim.compression import wire_bytes_factor

        assert wire_bytes_factor(CompressionConfig(mode="bf16")) == 0.5
        assert wire_bytes_factor(CompressionConfig(mode="int8")) == 0.25


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {
            "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
        }
        mgr.save(7, tree, blocking=True)
        assert mgr.latest_step() == 7
        like = jax.tree.map(jnp.zeros_like, tree)
        out = mgr.restore(7, like)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        np.testing.assert_array_equal(
            np.asarray(out["nested"]["b"], np.float32),
            np.asarray(tree["nested"]["b"], np.float32),
        )

    def test_gc_keeps_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.ones((2,))}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree, blocking=True)
        assert mgr.steps() == [3, 4]

    def test_elastic_plan_validates(self):
        p = ElasticPlan(old_dp=16, new_dp=8, old_global_batch=256)
        assert p.new_global_batch == 256
        with pytest.raises(ValueError):
            ElasticPlan(old_dp=16, new_dp=7, old_global_batch=256).new_global_batch


class TestDataPipeline:
    def test_deterministic_per_step(self):
        cfg = DataConfig(global_batch=4, seq_len=16, vocab_size=97)
        s1 = SyntheticSource(cfg)
        s2 = SyntheticSource(cfg)
        np.testing.assert_array_equal(s1.batch_at(5), s2.batch_at(5))
        assert not np.array_equal(s1.batch_at(5), s1.batch_at(6))

    def test_next_token_alignment(self):
        cfg = DataConfig(global_batch=2, seq_len=8, vocab_size=64)
        pipe = Pipeline(SyntheticSource(cfg), cfg)
        b = next(pipe)
        # arith pattern: labels are tokens shifted by one position
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
        pipe.close()

    def test_host_sharding_disjoint(self):
        cfg = DataConfig(global_batch=8, seq_len=8, vocab_size=1 << 20, pattern="uniform")
        b0 = SyntheticSource(cfg, host_index=0, host_count=2).batch_at(0)
        b1 = SyntheticSource(cfg, host_index=1, host_count=2).batch_at(0)
        assert b0.shape == (4, 9)
        assert not np.array_equal(b0, b1)

    def test_resume_state(self):
        cfg = DataConfig(global_batch=2, seq_len=8, vocab_size=64)
        pipe = Pipeline(SyntheticSource(cfg), cfg, start_step=0)
        first = next(pipe)
        pipe.close()
        pipe2 = Pipeline(SyntheticSource(cfg), cfg, start_step=first["step"] + 1)
        second = next(pipe2)
        assert second["step"] == first["step"] + 1
        pipe2.close()


class TestFaultTolerance:
    def test_backup_activation(self):
        fo = RackFailover()
        rec = fo.fail(3)
        assert rec["backup_physical"] == 64
        assert fo.translate(3) == 64
        assert not fo.degraded

    def test_no_spare_returns_structured_exhaustion(self):
        fo = RackFailover(n_backups=1)
        fo.fail(1)
        rec = fo.fail(2)
        assert rec["kind"] == "spares_exhausted"
        assert rec["failed_count"] == 2
        assert fo.degraded

    def test_supervisor_detects_dead(self):
        sup = TrainingSupervisor(n_workers=4, heartbeat_timeout_s=1000.0)
        assert sup.dead_workers() == []
        sup.workers[2].last_heartbeat -= 10_000
        assert sup.dead_workers() == [2]

    def test_supervisor_straggler_detection(self):
        sup = TrainingSupervisor(n_workers=2, straggler_factor=2.0)
        for i in range(20):
            sup.heartbeat(0, i, 1.0)
        for i in range(3):
            sup.heartbeat(1, 20 + i, 10.0)
        assert any(e["kind"] == "straggler" for e in sup.events)

    def test_recovery_plan_mixes_backup_and_elastic(self):
        sup = TrainingSupervisor(n_workers=4)
        fo = RackFailover(n_backups=1)
        plan = sup.plan_recovery(fo, [0, 1])
        kinds = [a["kind"] for a in plan["actions"]]
        assert kinds == ["backup", "elastic_shrink"]
        assert plan["restart_from_checkpoint"]
