"""Runtime fault-tolerance unit coverage: RackFailover spare-pool
lifecycle (64+1, Fig. 9), the structured ``SparesExhausted`` outcome,
`TrainingSupervisor` with an injected deterministic clock, elastic
shrink planning, and CheckpointManager partial-save integrity."""

from __future__ import annotations

import json

import pytest

from repro.core.topology import ub_mesh_rack
from repro.runtime.elastic import ElasticPlan, shrink_plan
from repro.runtime.fault_tolerance import (
    RackFailover,
    SparesExhausted,
    TrainingSupervisor,
)


class TestRackFailover:
    def test_backup_swap_record(self):
        fo = RackFailover(rack=ub_mesh_rack(), n_backups=1)
        rec = fo.fail(5)
        assert rec["kind"] == "backup"
        assert rec["failed_physical"] == 5
        assert rec["backup_physical"] == fo.rack.num_nodes
        assert fo.translate(5) == fo.rack.num_nodes
        assert rec["extra_hops"] == 1           # Fig. 9: via-LRS redirect
        assert not fo.degraded

    def test_spares_exhausted_is_structured_not_raised(self):
        fo = RackFailover(rack=ub_mesh_rack(), n_backups=1)
        fo.fail(0)
        rec = fo.fail(1)                        # pool empty now
        assert isinstance(rec, SparesExhausted)
        assert isinstance(rec, dict)            # still a recovery record
        assert rec["kind"] == "spares_exhausted"
        assert rec["logical"] == 1
        assert rec["failed_physical"] == 1
        assert rec["failed_count"] == 2
        assert fo.degraded                      # 2 failures > 1 spare

    def test_zero_backups_always_exhausted(self):
        fo = RackFailover(rack=ub_mesh_rack(), n_backups=0)
        assert isinstance(fo.fail(3), SparesExhausted)

    def test_restock_returns_npu_to_pool(self):
        fo = RackFailover(rack=ub_mesh_rack(), n_backups=1)
        rec = fo.fail(5)                        # spare takes slot 5
        assert not fo.spares
        fo.restock(rec["failed_physical"])      # field service swaps board
        assert fo.spares == [5]
        assert 5 not in fo.failed
        rec2 = fo.fail(7)                       # pool usable again
        assert rec2["kind"] == "backup"
        assert rec2["backup_physical"] == 5

    def test_restock_ignores_active_and_duplicate_ids(self):
        fo = RackFailover(rack=ub_mesh_rack(), n_backups=1)
        fo.restock(3)                           # 3 is still mapped: no-op
        assert fo.spares == [fo.rack.num_nodes]
        fo.restock(fo.rack.num_nodes)           # already a spare: no dup
        assert fo.spares == [fo.rack.num_nodes]


class TestTrainingSupervisorClock:
    def test_injected_clock_detects_timeout_deterministically(self):
        t = [0.0]
        sup = TrainingSupervisor(
            n_workers=3, heartbeat_timeout_s=10.0, clock=lambda: t[0]
        )
        sup.heartbeat(0, step=1)
        sup.heartbeat(1, step=1)
        t[0] = 11.0
        sup.heartbeat(2, step=2)                # 2 stays alive
        assert sup.dead_workers() == [0, 1]

    def test_dead_workers_accepts_explicit_now_zero(self):
        # now=0.0 is falsy — the check must be `is None`, not truthiness
        t = [5.0]
        sup = TrainingSupervisor(
            n_workers=1, heartbeat_timeout_s=1.0, clock=lambda: t[0]
        )
        sup.workers[0].last_heartbeat = -10.0
        assert sup.dead_workers(now=0.0) == [0]
        sup.workers[0].last_heartbeat = -0.5
        assert sup.dead_workers(now=0.0) == []

    def test_plan_recovery_backup_then_elastic_fallback(self):
        sup = TrainingSupervisor(n_workers=4, clock=lambda: 0.0)
        fo = RackFailover(rack=ub_mesh_rack(), n_backups=1)
        plan = sup.plan_recovery(fo, dead=[2, 3])
        kinds = [a["kind"] for a in plan["actions"]]
        assert kinds == ["backup", "elastic_shrink"]
        # the exhausted record keeps its structured fields
        assert plan["actions"][1]["failed_count"] == 2
        assert plan["actions"][1]["worker"] == 3
        assert plan["restart_from_checkpoint"]


class TestElasticShrink:
    def test_capacity_fraction(self):
        p = ElasticPlan(old_dp=8, new_dp=6, old_global_batch=512)
        assert p.capacity_fraction == pytest.approx(0.75)

    def test_shrink_plan_rounds_up_lost_replicas(self):
        # 8 DP replicas over 512 chips -> 64 chips each; losing one
        # 64-chip rack costs exactly one replica
        p = shrink_plan(8, 512, lost_chips=64, total_chips=512)
        assert p.new_dp == 7
        # losing 65 chips straddles two replicas -> ceil to 2
        p = shrink_plan(8, 512, lost_chips=65, total_chips=512)
        assert p.new_dp == 6

    def test_shrink_plan_never_below_one_replica(self):
        p = shrink_plan(2, 512, lost_chips=10_000, total_chips=512)
        assert p.new_dp == 1


class TestCheckpointPartialSave:
    @pytest.fixture()
    def mgr(self, tmp_path):
        jax = pytest.importorskip("jax")  # noqa: F841
        from repro.checkpoint.manager import CheckpointManager

        return CheckpointManager(str(tmp_path), keep=10)

    def test_partial_save_invisible_until_meta_commit(self, mgr, tmp_path):
        import numpy as np

        tree = {"w": np.ones((4,), dtype=np.float32)}
        mgr.save(100, tree, blocking=True)
        # fake a crashed save: arrays on disk, no committed meta.json
        part = tmp_path / "step_00000200"
        part.mkdir()
        (part / "w.npy").write_bytes(b"not a checkpoint")
        (part / "meta.json.tmp").write_text("{\"step\": 200")  # truncated
        assert mgr.steps() == [100]
        assert mgr.latest_step() == 100

    def test_restore_after_partial_save_uses_committed_step(self, mgr, tmp_path):
        import numpy as np

        tree = {"w": np.arange(4, dtype=np.float32)}
        mgr.save(7, tree, blocking=True)
        (tmp_path / "step_00000008").mkdir()    # dir exists, never committed
        out = mgr.restore(mgr.latest_step(), {"w": np.zeros(4, np.float32)})
        assert np.asarray(out["w"]).tolist() == [0.0, 1.0, 2.0, 3.0]

    def test_meta_json_is_valid_after_blocking_save(self, mgr, tmp_path):
        import numpy as np

        mgr.save(3, {"w": np.zeros((2, 2), np.float32)}, blocking=True)
        meta = json.loads((tmp_path / "step_00000003" / "meta.json").read_text())
        assert meta["step"] == 3
        assert meta["keys"] == ["w"]
        assert not (tmp_path / "step_00000003" / "meta.json.tmp").exists()
