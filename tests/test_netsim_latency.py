"""Message-level latency mode + SLO-driven decode serving.

Covers the PR-10 contract end to end:

* ``MessageNetwork`` — store-and-forward pricing: exact closed forms on
  idle links, FIFO queueing behind busy links, ejection-port incast
  serialization, bit-identical determinism;
* ``NetSim(message_level=True)`` — same FlowDAG compiler, per-task
  latency distributions, fluid-divergence on small payloads, and the
  hard mode-off guarantee: ``message_level=False`` is bit-identical to a
  default-constructed sim across a seeded collective corpus;
* ``NetsimPerfModel.latency_profile`` — memoization, persistent-store
  round-trip, width canonicalization, failed-links rejection;
* ``launch.serve`` — the continuous-batching simulator's conservation /
  queueing behavior and the bandwidth-vs-SLO planning divergence.
"""

import pytest

from repro.core.cost_model import (
    LATENCY_SHAPES,
    LatencyStats,
    Routing,
    build_comm_model,
)
from repro.core.topology import ub_mesh_rack
from repro.core.traffic import ParallelSpec, WorkloadSpec
from repro.netsim import EventEngine, MessageNetwork, NetSim
from repro.netsim.collectives import (
    clique_nodes,
    hierarchical_allreduce,
    multipath_all_to_all,
    ring_allreduce,
)

SIZE = 64e3                       # decode-sized payload
X_CAP = 25e9                      # 4-lane passive-electrical X link


def serve_workload() -> WorkloadSpec:
    return WorkloadSpec(
        "dense-70B-serve", 80, 8192, 64, 128, 8,
        seq_len=8192, global_batch=512, params_total=7e10,
    )


# ---------------------------------------------------------------------------
# MessageNetwork: transport-level pricing
# ---------------------------------------------------------------------------


class TestMessageNetwork:
    def _net(self, **kw) -> MessageNetwork:
        return MessageNetwork(ub_mesh_rack(), EventEngine(), **kw)

    def test_single_hop_closed_form(self):
        net = self._net()
        msg = net.send((0, 1), SIZE)
        net.engine.run()
        assert msg.t_end == pytest.approx(SIZE / X_CAP + net.latency_s)

    def test_multi_hop_adds_serialization_and_latency_per_hop(self):
        # X hop then Y hop: store-and-forward pays both hops in full
        net = self._net()
        msg = net.send((0, 1, 9), SIZE)
        net.engine.run()
        assert msg.t_end == pytest.approx(2 * (SIZE / X_CAP + net.latency_s))

    def test_fifo_queueing_behind_busy_link(self):
        # second message on the same directed link waits out the first's
        # serialization; its latency grows by exactly one serialization
        net = self._net()
        m1 = net.send((0, 1), SIZE)
        m2 = net.send((0, 1), SIZE)
        net.engine.run()
        ser = SIZE / X_CAP
        assert m1.t_end == pytest.approx(ser + net.latency_s)
        assert m2.t_end == pytest.approx(2 * ser + net.latency_s)

    def test_reverse_direction_does_not_queue(self):
        # (0,1) and (1,0) are distinct directed links
        net = self._net()
        m1 = net.send((0, 1), SIZE)
        m2 = net.send((1, 0), SIZE)
        net.engine.run()
        assert m1.t_end == pytest.approx(m2.t_end)

    def test_dim_latency_override(self):
        plain = self._net()
        d01 = plain._link_dim[(0, 1)]
        net = self._net(dim_latency_s={d01: 5e-6})
        m_over = net.send((0, 1), SIZE)      # overridden dim
        m_base = net.send((0, 8), SIZE)      # the other dim: default
        net.engine.run()
        assert net._link_dim[(0, 8)] != d01
        assert m_over.t_end - m_base.t_end == pytest.approx(
            5e-6 - net.latency_s
        )

    def test_incast_serializes_at_ejection_port(self):
        # 7 clique peers converge on node 0: with an rx cap the ejection
        # port serializes them; without one they all land together
        free = self._net()
        capped = self._net(rx_gbs=25.0)
        for src in range(1, 8):
            free.send((src, 0), SIZE)
            capped.send((src, 0), SIZE)
        free.engine.run()
        capped.engine.run()
        ser = SIZE / X_CAP
        assert free.engine.now == pytest.approx(ser + 1e-6)
        # cut-through port: the first message is free, the other 6 drain
        # back to back at 25 GB/s behind it
        assert capped.engine.now > free.engine.now
        assert capped.engine.now == pytest.approx(ser + 1e-6 + 6 * ser)

    def test_uncontended_rx_port_is_free(self):
        # cut-through: a single message pays NO extra rx term
        capped = self._net(rx_gbs=25.0)
        msg = capped.send((1, 0), SIZE)
        capped.engine.run()
        assert msg.t_end == pytest.approx(SIZE / X_CAP + 1e-6)

    def test_deterministic_replay(self):
        def run():
            net = self._net(rx_gbs=25.0)
            out = []
            for src in range(1, 8):
                net.send((src, 0), SIZE, on_complete=lambda m: out.append(
                    (m.mid, m.t_end)
                ))
            net.engine.run()
            return out

        assert run() == run()

    def test_rejects_degenerate_path_and_non_links(self):
        net = self._net()
        with pytest.raises(ValueError):
            net.send((3,), SIZE)
        with pytest.raises(KeyError):
            net.send((0, 9), SIZE)      # diagonal: not a physical link
            net.engine.run()


# ---------------------------------------------------------------------------
# NetSim message mode
# ---------------------------------------------------------------------------


class TestMessageMode:
    def test_run_dag_populates_task_latencies(self):
        topo = ub_mesh_rack()
        sim = NetSim(topo, message_level=True)
        dag = ring_allreduce(topo, clique_nodes(topo, 0), SIZE, tag="t")
        res = sim.run_dag(dag)
        assert res.incomplete == 0
        assert set(res.task_latency_s) == set(res.task_end_s)
        assert all(v > 0 for v in res.task_latency_s.values())
        assert res.makespan_s >= max(res.task_latency_s.values())

    def test_message_mode_is_deterministic(self):
        topo = ub_mesh_rack()
        dag = multipath_all_to_all(
            topo, clique_nodes(topo, 0), SIZE / 8, tag="a2a"
        )
        r1 = NetSim(topo, message_level=True).run_dag(dag)
        r2 = NetSim(topo, message_level=True).run_dag(dag)
        assert r1.task_end_s == r2.task_end_s
        assert r1.makespan_s == r2.makespan_s

    def test_diverges_from_fluid_on_small_payloads(self):
        # the whole point of the mode: at decode payloads the fluid
        # model's single flat launch latency misprices the plane-wide
        # collective by a wide margin
        topo = ub_mesh_rack()
        sim_fluid = NetSim(topo)
        sim_msg = NetSim(topo, message_level=True)
        prof = sim_msg.measure_latency_profile(SIZE)
        msg_t = prof.get("model", "allreduce").total_s
        comm = build_comm_model()
        analytic_t = comm.allreduce("model", SIZE)
        assert abs(msg_t - analytic_t) / analytic_t > 0.10

    def test_failure_injection_is_fluid_only(self):
        topo = ub_mesh_rack()
        with pytest.raises(ValueError, match="failed_links"):
            NetSim(topo, message_level=True, failed_links=((0, 1),))
        sim = NetSim(topo, message_level=True)
        dag = ring_allreduce(topo, clique_nodes(topo, 0), SIZE, tag="t")
        with pytest.raises(ValueError, match="fail_link"):
            sim.run_dag(dag, fail_link=(0, 1))

    def test_measure_latency_profile_validates_shapes(self):
        sim = NetSim(ub_mesh_rack(), message_level=True)
        with pytest.raises(ValueError, match="latency profiles"):
            sim.measure_latency_profile(SIZE, shapes=("all_gather",))

    def test_stats_are_internally_consistent(self):
        sim = NetSim(ub_mesh_rack())
        prof = sim.measure_latency_profile(SIZE)
        assert set(s for (_, s) in prof.lat) <= set(LATENCY_SHAPES)
        for st in prof.lat.values():
            assert 0 < st.p50_s <= st.p99_s <= st.total_s
            assert st.n > 0


class TestModeOffParity:
    """``message_level=False`` must be BIT-identical to a sim that never
    heard of the flag — across a seeded corpus of collective DAGs."""

    SCENARIOS = []
    for seed in range(3):
        SCENARIOS.append(("ring", seed))
        SCENARIOS.append(("hier", seed))
        SCENARIOS.append(("a2a", seed))

    @staticmethod
    def _dag(kind: str, seed: int, topo):
        import random

        rng = random.Random(seed)
        if kind == "ring":
            dim = rng.choice((0, 1))
            return ring_allreduce(
                topo, clique_nodes(topo, dim), SIZE * (seed + 1), tag="r"
            )
        if kind == "hier":
            return hierarchical_allreduce(
                topo, (0, 1), SIZE * (seed + 1), tag="h"
            )
        group = clique_nodes(topo, rng.choice((0, 1)))
        return multipath_all_to_all(
            topo, group, SIZE * (seed + 1) / len(group), tag="a"
        )

    @pytest.mark.parametrize("kind,seed", SCENARIOS)
    def test_mode_off_bit_identical(self, kind, seed):
        topo = ub_mesh_rack()
        dag = self._dag(kind, seed, topo)
        base = NetSim(topo, rx_gbs=25.0).run_dag(dag)
        off = NetSim(topo, rx_gbs=25.0, message_level=False).run_dag(dag)
        # exact float equality, not approx: mode off may not perturb the
        # fluid path in any way
        assert off.task_end_s == base.task_end_s
        assert off.makespan_s == base.makespan_s
        assert off.link_utilization == base.link_utilization


# ---------------------------------------------------------------------------
# perf_model threading
# ---------------------------------------------------------------------------


class TestLatencyProfileThreading:
    def _pm(self, cache_dir=None):
        from repro.core.perf_model import NetsimPerfModel

        return NetsimPerfModel(
            base=build_comm_model(),
            topo=ub_mesh_rack(),
            cache_dir=cache_dir,
        )

    def test_memoized_across_calls_and_instances(self):
        from repro.core.perf_model import calibration_stats

        pm = self._pm()
        p = ParallelSpec(tp=8, sp=1, pp=1, dp=8, ep=1)
        prof1 = pm.latency_profile(p)
        before = calibration_stats()
        prof2 = self._pm().latency_profile(p)     # fresh instance, same key
        after = calibration_stats()
        assert prof2.lat == prof1.lat
        assert after["misses"] == before["misses"]
        assert after["hits"] > before["hits"]

    @staticmethod
    def _wipe_latency_memo():
        from repro.core import perf_model as pmod

        for k in [k for k in pmod._LATENCY_CACHE if "latency-mode" in k]:
            del pmod._LATENCY_CACHE[k]

    def test_disk_round_trip(self, tmp_path):
        from repro.core import perf_model as pmod

        # cold memo first, so EVERY key is measured into this tmp store
        self._wipe_latency_memo()
        pm = self._pm(cache_dir=str(tmp_path))
        p = ParallelSpec(tp=4, sp=1, pp=1, dp=16, ep=1)
        prof1 = pm.latency_profile(p)
        # wipe the in-memory memo again: the second resolution must come
        # from the persistent store, stats intact to full precision
        self._wipe_latency_memo()
        before = pmod.calibration_stats()
        prof2 = self._pm(cache_dir=str(tmp_path)).latency_profile(p)
        after = pmod.calibration_stats()
        assert prof2.lat == prof1.lat
        assert after["disk_hits"] - before["disk_hits"] == len(prof1.lat)
        assert isinstance(next(iter(prof2.lat.values())), LatencyStats)

    def test_width_canonicalization_shares_full_plane_key(self):
        from repro.core.perf_model import calibration_stats

        pm = self._pm()
        full = ParallelSpec(tp=64, sp=1, pp=1, dp=1, ep=1)
        pm.latency_profile(full)
        before = calibration_stats()
        # tp*sp = 8*8 also covers the 64-chip plane -> same (None) key
        pm.latency_profile(ParallelSpec(tp=8, sp=8, pp=1, dp=1, ep=1))
        after = calibration_stats()
        assert after["misses"] == before["misses"]

    def test_latency_and_bandwidth_keys_never_alias(self):
        from repro.core import perf_model as pmod

        pm = self._pm()
        p = ParallelSpec(tp=8, sp=1, pp=1, dp=8, ep=1)
        pm.latency_profile(p)
        lat_keys = [k for k in pmod._LATENCY_CACHE if "latency-mode" in k]
        assert lat_keys
        assert not any("latency-mode" in k for k in pmod._CALIBRATION_CACHE)

    def test_failed_links_rejected(self):
        from dataclasses import replace

        pm = replace(self._pm(), failed_links=((0, 1),))
        with pytest.raises(ValueError, match="healthy mesh"):
            pm.latency_profile(ParallelSpec(tp=8, sp=1, pp=1, dp=8, ep=1))

    def test_shapes_restricted_to_latency_set(self):
        pm = self._pm()
        prof = pm.latency_profile(ParallelSpec(tp=8, sp=1, pp=1, dp=8, ep=2))
        assert {s for (_, s) in prof.lat} <= set(LATENCY_SHAPES)
        assert ("model", "allreduce") in prof.lat
        assert ("model", "all_to_all") in prof.lat   # ep=2 has A2A traffic


# ---------------------------------------------------------------------------
# decode serving
# ---------------------------------------------------------------------------


class TestDecodeServing:
    def test_simulator_conserves_tokens(self):
        from repro.launch.serve import simulate_decode_serving

        res = simulate_decode_serving(
            5e-3, qps=10.0, slots=16, gen_tokens=32, duration_s=5.0
        )
        assert res["tokens"] == res["requests"] * 32
        assert res["tokens_per_s"] > 0
        assert 0 < res["utilization"] <= 1

    def test_unloaded_p99_is_one_step(self):
        from repro.launch.serve import simulate_decode_serving

        res = simulate_decode_serving(
            1e-3, qps=1.0, slots=64, gen_tokens=16, duration_s=10.0
        )
        # almost every token is a steady-state inter-token gap
        assert res["p50_s"] == pytest.approx(1e-3)
        assert res["p99_s"] < 3e-3

    def test_overload_shows_queueing_tail(self):
        from repro.launch.serve import simulate_decode_serving

        light = simulate_decode_serving(
            5e-3, qps=2.0, slots=4, gen_tokens=32, duration_s=10.0,
            slo_s=20e-3,
        )
        heavy = simulate_decode_serving(
            5e-3, qps=50.0, slots=4, gen_tokens=32, duration_s=10.0,
            slo_s=20e-3,
        )
        assert heavy["p99_s"] > 10 * light["p99_s"]
        assert heavy["attainment"] < light["attainment"]

    def test_simulator_is_deterministic(self):
        from repro.launch.serve import simulate_decode_serving

        kw = dict(qps=8.0, slots=8, gen_tokens=16, duration_s=5.0, seed=3)
        assert simulate_decode_serving(2e-3, **kw) == simulate_decode_serving(
            2e-3, **kw
        )

    def test_enumerate_decode_specs_memory_filter(self):
        from repro.core.planner import enumerate_decode_specs

        w = serve_workload()              # 140 GB of bf16 weights
        specs = enumerate_decode_specs(w, 64)
        assert specs
        for p in specs:
            assert p.tp * p.dp == 64
            assert p.pp == 1 and p.sp == 1 and p.ep == 1
            # 48 GB HBM: tp < 4 cannot hold the shard
            assert p.tp >= 4

    def test_plan_decode_diverges_from_bandwidth_optimal(self):
        from repro.launch.serve import plan_decode, rack_perf_model

        res = plan_decode(
            serve_workload(), 64, rack_perf_model(cache_dir=None),
            qps=30.0, slo_s=0.012, batch=8, duration_s=5.0,
        )
        bw, slo = res["bandwidth_choice"], res["slo_choice"]
        # bandwidth pricing (spec-invariant latency term) maxes out TP;
        # the measured width-scaled latency makes that the WORST p99
        assert bw["tp"] == 64
        assert slo["tp"] < bw["tp"]
        assert res["diverged"]
        assert slo["meets_slo"] and not bw["meets_slo"]

    def test_latency_pricing_requires_capable_backend(self):
        from repro.core.perf_model import AnalyticPerfModel
        from repro.launch.serve import decode_step_s

        perf = AnalyticPerfModel(base=build_comm_model())
        with pytest.raises(TypeError, match="latency-calibrated"):
            decode_step_s(
                serve_workload(),
                ParallelSpec(tp=8, sp=1, pp=1, dp=8, ep=1),
                perf,
                pricing="latency",
            )
