"""Multi-ring / All2All planner + cost model + simulator tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-shim

from repro.core import alltoall, cost_model, multiring, simulator, traffic
from repro.core.cost_model import Routing
from repro.core.topology import ub_mesh_pod, ub_mesh_rack


class TestMultiRing:
    @given(st.integers(2, 24))
    @settings(max_examples=23, deadline=None)
    def test_clique_decomposition_covers_all_edges(self, n):
        # verify=True asserts hamiltonicity + edge-disjoint + full coverage
        rings, closed = multiring.clique_decomposition(n, verify=True)
        expected = (n - 1) // 2 if n % 2 else n // 2
        if n > 2:
            assert len(rings) == expected

    def test_multiring_beats_single_ring(self):
        pod = ub_mesh_pod()
        for dim in range(4):
            plan = multiring.plan_multiring(pod, dim)
            single = multiring.single_ring_bandwidth_gbs(pod, dim)
            assert plan.effective_bandwidth_gbs() >= single
            assert plan.utilization == 1.0  # every clique link carries traffic

    def test_allreduce_wire_bytes(self):
        pod = ub_mesh_pod()
        plan = multiring.plan_multiring(pod, 0)  # X clique, n=8
        wire = plan.allreduce_wire_bytes_per_chip(1e9)
        assert np.isclose(wire, 2 * 7 / 8 * 1e9)


class TestGridMultiRing:
    """Cross-dim 2D multi-ring: K_n [] K_n into n-1 Hamiltonian cycles."""

    @pytest.mark.parametrize("n", [2, 3, 4, 5, 6, 7, 8])
    def test_square_grid_perfect_decomposition(self, n):
        rings = multiring.grid_ring_decomposition(n, n)
        assert rings is not None
        assert len(rings) == (n - 1 if n > 2 else 1)
        # independent re-verification: Hamiltonian, grid edges only,
        # pairwise edge-disjoint, and full coverage of BOTH cliques' links
        seen = set()
        for r in rings:
            assert sorted(r) == list(range(n * n))
            for t in range(len(r)):
                a, b = r[t], r[(t + 1) % len(r)]
                ai, aj = divmod(a, n)
                bi, bj = divmod(b, n)
                assert (ai == bi) != (aj == bj)
                e = (min(a, b), max(a, b))
                assert e not in seen
                seen.add(e)
        assert len(seen) == n * n * (n - 1)

    def test_rings_cross_dimensions(self):
        # unlike the per-dim hierarchical schedule, every ring must use
        # links of BOTH dimensions (that is the whole point)
        for r in multiring.grid_ring_decomposition(8, 8):
            dims_used = set()
            for t in range(len(r)):
                a, b = r[t], r[(t + 1) % len(r)]
                dims_used.add(0 if a % 8 == b % 8 else 1)
            assert dims_used == {0, 1}

    def test_non_square_raises_structured_error(self):
        for x, y in ((8, 2), (4, 8)):
            with pytest.raises(multiring.UnsupportedGridError) as ei:
                multiring.grid_ring_decomposition(x, y)
            assert (ei.value.x, ei.value.y) == (x, y)
            assert "non-square" in ei.value.reason

    def test_non_square_callers_fall_back_and_log(self, caplog):
        # grid_effective_bandwidth_gbs: rectangular (Z=4, A=2) plane -> None
        from repro.core.topology import ACTIVE_ELECTRICAL, DimSpec, NDFullMesh

        rect = NDFullMesh(
            dims=(
                DimSpec("Z", 4, ACTIVE_ELECTRICAL, 2),
                DimSpec("A", 2, ACTIVE_ELECTRICAL, 2),
            )
        )
        with caplog.at_level("INFO", logger="repro.core.multiring"):
            assert multiring.grid_effective_bandwidth_gbs(rect, (0, 1)) is None
        assert any("unavailable" in r.message for r in caplog.records)
        # netsim's DAG compiler: same plane -> grid compiler declines (the
        # caller then builds the per-dim hierarchical schedule) and logs it
        from repro.netsim.collectives import grid_allreduce

        with caplog.at_level("INFO", logger="repro.netsim.collectives"):
            assert grid_allreduce(rect, (0, 1), 8e6) is None
        assert any("hierarchical" in r.message for r in caplog.records)

    def test_grid_bandwidth_beats_sum_of_chains(self):
        rack = ub_mesh_rack()
        grid_bw = multiring.grid_effective_bandwidth_gbs(rack, (0, 1))
        # 7 closed rings x 25 GB/s = 175: above what the per-dim chain
        # schedule can DELIVER concurrently (one dim's links per phase)
        assert grid_bw == pytest.approx(7 * 25.0)


class TestAllToAll:
    def test_multipath_doubles_pair_bandwidth(self):
        rack = ub_mesh_rack()
        multi = alltoall.permutation_a2a_pair_bandwidth(rack, multipath=True)
        single = alltoall.permutation_a2a_pair_bandwidth(rack, multipath=False)
        assert multi == 2 * single

    def test_uniform_a2a_balanced_one_hop_relay(self):
        rack = ub_mesh_rack()
        rep = alltoall.multipath_a2a_loads(rack, 1.0, split=True)
        assert rep.max_hops <= 2          # at most one relay (Fig. 14-a)
        assert rep.balance < 1.05         # near-perfect balance

    def test_hierarchical_moe_dispatch_saves_long_links(self):
        d, h = alltoall.hierarchical_moe_dispatch(n_cliques=8, topk=4)
        assert h.long_link_bytes_per_token < d.long_link_bytes_per_token
        # savings grow with topk (massive-expert models, paper §7)
        s2 = alltoall.moe_dispatch_savings(8, 2)
        s8 = alltoall.moe_dispatch_savings(8, 8)
        assert s8 > s2 > 1.0


class TestCostModel:
    def test_detour_faster_than_shortest(self):
        short = cost_model.build_comm_model(routing=Routing.SHORTEST)
        detour = cost_model.build_comm_model(routing=Routing.DETOUR)
        borrow = cost_model.build_comm_model(routing=Routing.BORROW)
        size = 1e9
        t_s = short.allreduce("data", size)
        t_d = detour.allreduce("data", size)
        t_b = borrow.allreduce("data", size)
        assert t_b <= t_d <= t_s

    def test_hierarchical_allreduce_cheaper_than_flat_on_slow_axis(self):
        m = cost_model.build_comm_model(multi_pod=True)
        size = 1e9
        flat_slow = m.allreduce("pod", size)
        hier = m.hierarchical_allreduce(["data", "pod"], size)
        assert hier < flat_slow + m.allreduce("data", size)


class TestTraffic:
    def test_table1_locality(self):
        w, p = traffic.moe_2t_workload()
        tab = traffic.analyze_traffic(w, p)
        assert tab.share("TP") + tab.share("SP") > 0.90     # paper: ~97%
        assert tab.share("DP") < 0.02                        # paper: 1.34%
        assert tab.share("PP") < 0.01
        assert tab.local_share() > 0.95

    def test_table1_share_values(self):
        w, p = traffic.moe_2t_workload()
        tab = traffic.analyze_traffic(w, p)
        ref = traffic.PAPER_TABLE1
        assert abs(tab.share("TP") - ref["TP"]["share"]) < 0.05
        assert abs(tab.share("SP") - ref["SP"]["share"]) < 0.05
        assert abs(tab.share("EP") - ref["EP"]["share"]) < 0.02


class TestSimulator:
    def test_intra_rack_ordering_fig17(self):
        w = traffic.WorkloadSpec(
            "GPT3-175B", 96, 12288, 96, 128, 8,
            seq_len=8192, global_batch=2048, params_total=175e9,
        )
        p = traffic.ParallelSpec(tp=8, sp=8, pp=4, dp=256, microbatches=16)
        times = {}
        for variant in ("2D-FM", "1D-FM-A", "1D-FM-B", "Clos"):
            cm = simulator.intra_rack_comm_model(variant)
            times[variant] = simulator.simulate(w, p, cm).iteration_s
        assert times["Clos"] <= times["1D-FM-B"] <= times["1D-FM-A"] <= times["2D-FM"]
        # paper: 2D-FM >= 93% of Clos
        assert times["Clos"] / times["2D-FM"] > 0.90

    def test_linearity_above_95(self):
        w = traffic.WorkloadSpec(
            "GPT4-2T", 96, 12288, 96, 128, 8, seq_len=262144,
            global_batch=64, params_total=2e12, n_experts=16, topk=2,
        )
        lin = simulator.linearity_curve(w, 1024, [1, 4, 16, 64])
        assert all(v > 0.95 for v in lin.values())


class TestPlanner:
    def test_planner_prefers_local_tp_sp(self):
        from repro.core import planner

        w = traffic.WorkloadSpec(
            "LLAMA-70B", 80, 8192, 64, 128, 8,
            seq_len=8192, global_batch=1024, params_total=7e10,
        )
        cm = cost_model.build_comm_model(multi_pod=True)
        best = planner.best_parallel_spec(w, 8192, cm)
        # the high-volume TP*SP footprint should stay near the rack domain
        assert best.tp * best.sp <= 16 * 64
        assert best.dp >= 1
        assert planner.memory_feasible(w, best)
