"""Shared pytest configuration.

Registers a fixed, deadline-free hypothesis profile so the property
suites (``test_netsim_properties.py`` and friends) run reproducibly
inside tier-1 CI: ``derandomize=True`` makes example generation a pure
function of the test body (no flaky seeds across runs/machines) and
``deadline=None`` keeps slow CI workers from killing examples that are
merely scheduled badly.  Override locally with
``HYPOTHESIS_PROFILE=dev`` for wider randomized exploration.

When hypothesis is not installed (it is a dev extra), this is a no-op
and the property tests skip via ``tests/_hypothesis_compat.py``.
"""

import os

import pytest


@pytest.fixture(autouse=True)
def _hermetic_calib_cache(tmp_path, monkeypatch):
    """Point the persistent calibration cache (core/calib_cache.py) at a
    per-test temp dir so test runs never read from or write to the
    developer's real ``~/.cache`` — measured values are content-addressed
    and would be identical, but cold-vs-warm assertions (miss counters,
    file lifecycle) need a cache whose state the test controls."""
    monkeypatch.setenv("CALIB_CACHE_DIR", str(tmp_path / "calib-cache"))


try:
    from hypothesis import settings

    settings.register_profile(
        "ci", deadline=None, derandomize=True, max_examples=25
    )
    settings.register_profile("dev", deadline=None, max_examples=100)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:  # pragma: no cover - hypothesis is a dev extra
    pass
