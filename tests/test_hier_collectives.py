"""Hierarchical collectives: correctness + wire-byte reduction on the slow
axis, on a real 8-device (2x2x2) mesh in a subprocess."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.collectives import (
        flat_allreduce, hierarchical_allreduce, hierarchical_all_to_all,
        multipath_split,
    )
    from repro.launch.hlo_stats import collective_stats

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

    x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)

    with mesh:
        hier = jax.jit(hierarchical_allreduce(mesh, "model", ("data", "pod")))
        flat = jax.jit(flat_allreduce(mesh, ("model", "data", "pod")))
        y_h = hier(x)
        y_f = flat(x)
        np.testing.assert_allclose(np.asarray(y_h), np.asarray(y_f), rtol=1e-6)

        # the payload crossing the SLOW (long-range) links must shrink by
        # the fast-axis size: flat = full tensor through every tier;
        # hierarchical = 1/n_fast of it on the slow-axis all-reduces
        txt_h = hier.lower(x).compile().as_text()
        txt_f = flat.lower(x).compile().as_text()
        s_h = collective_stats(txt_h)
        s_f = collective_stats(txt_f)
        ar_h = max((b for k, b, n in s_h.ops if k == "all-reduce"), default=0)
        ar_f = max((b for k, b, n in s_f.ops if k == "all-reduce"), default=0)
        assert ar_h <= ar_f / 2 + 1, (ar_h, ar_f)

        # multipath split gathers over two axes at once
        mp = jax.jit(multipath_split(mesh, "data", "model"))
        a, b = mp(x)
        assert a.shape[0] * 2 == x.shape[0] * 2  # both halves gathered

        # hierarchical a2a is a permutation (no data lost)
        h2 = jax.jit(hierarchical_all_to_all(mesh, "model", "data"))
        z = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8)
        out = h2(z)
        assert out.shape == z.shape
        assert "all-to-all" in h2.lower(z).compile().as_text()
    print("HIER_OK")
    """
)


@pytest.mark.slow
def test_hierarchical_collectives():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "HIER_OK" in r.stdout
