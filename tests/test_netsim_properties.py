"""Property-based invariant suite for the netsim fluid solvers.

Randomized meshes and flow sets (plain multi-hop flows + aggregate
ring-step flows) must satisfy, under EVERY cap configuration (no caps,
receiver-egress ``rx_gbs``, per-dim ``dim_io_gbs``, both) and under BOTH
solvers (vectorized numpy water-filling and the pure-Python reference):

(a) **capacity** — the summed rate on every constraint (wire link,
    virtual rx port, per-dim IO port) never exceeds its capacity;
(b) **max-min fairness** — every flow has a bottleneck: a saturated
    constraint on its path where no other flow runs faster, i.e. no flow
    can be sped up without slowing a flow that is no faster;
(c) **solver parity** — vectorized and reference allocations agree to
    1e-6 relative on every flow;
(d) **conservation** — running to completion delivers exactly the
    requested bytes per flow, and the per-link byte ledger equals
    sum(size x links crossed) including aggregate multiplicity;
(e) **aggregate equivalence** — a symmetric ring step executed as one
    weighted aggregate flow completes exactly when its member-by-member
    expansion does;
(f) **telemetry parity** — with a ``Telemetry`` recorder attached, the
    vectorized and reference solvers emit identical bottleneck
    attributions (final constraint per flow AND the full dedup'd history),
    and every link's recorded utilization timeline integrates to exactly
    the fluid network's byte ledger for that link.

Two drivers share the same checkers: a seeded corpus that always runs
(``TestSeededInvariants``) and hypothesis-driven exploration
(``TestHypothesisInvariants``) via the ``tests/_hypothesis_compat.py``
shim — with hypothesis installed (the dev extra) the fixed ``ci``
profile from ``tests/conftest.py`` applies (derandomized, no deadline).
"""

import random

import pytest

from _hypothesis_compat import given, settings, st  # hypothesis or skip-shim

from repro.core.topology import (
    ACTIVE_ELECTRICAL,
    DimSpec,
    NDFullMesh,
    PASSIVE_ELECTRICAL,
)
from repro.netsim import FluidNetwork, Telemetry
from repro.netsim.collectives import clique_nodes

CAP_MODES = ("none", "rx", "io", "rx+io")
SEEDS = range(3)
SOLVERS = ("vectorized", "reference")

_REL = 1e-6          # solver freeze tolerance (LEVEL_RTOL) plus fp headroom
_ABS = 1.0           # bytes/s absolute slack on ~1e9-scale capacities


# ---------------------------------------------------------------------------
# randomized scenario generation (shared by the seeded and hypothesis drivers)
# ---------------------------------------------------------------------------


def _random_topo(rng: random.Random) -> NDFullMesh:
    ndim = rng.randint(1, 3)
    dims = tuple(
        DimSpec(
            f"D{i}",
            rng.randint(2, 4),
            PASSIVE_ELECTRICAL if i < 2 else ACTIVE_ELECTRICAL,
            rng.choice((1, 2, 4)),
        )
        for i in range(ndim)
    )
    return NDFullMesh(dims=dims)


def _random_path(topo: NDFullMesh, rng: random.Random) -> tuple[int, ...]:
    """A loop-free dimension-hopping walk of 1-3 direct-link hops."""
    node = rng.randrange(topo.num_nodes)
    path = [node]
    for _ in range(rng.randint(1, 3)):
        c = list(topo.coords(path[-1]))
        d = rng.randrange(topo.ndim)
        c[d] = rng.choice([v for v in range(topo.shape[d]) if v != c[d]])
        nxt = topo.node_id(c)
        if nxt not in path:
            path.append(nxt)
    return tuple(path)


def _scenario(seed: int, caps: str):
    """(topo, rx, dim_io, path flows, aggregate flows) for one case."""
    rng = random.Random(seed * 7919 + len(caps))
    topo = _random_topo(rng)
    rx = None
    dim_io = None
    if "rx" in caps:
        rx = max(d.gbs_total for d in topo.dims) * rng.uniform(0.3, 1.0)
    if "io" in caps:
        d = topo.ndim - 1
        dim_io = {d: topo.dims[d].gbs_per_peer * rng.uniform(0.5, 2.0)}
    paths = []
    for _ in range(rng.randint(3, 10)):
        p = _random_path(topo, rng)
        if len(p) >= 2:
            paths.append((p, rng.uniform(1e6, 1e8)))
    aggs = []
    for _ in range(rng.randint(0, 2)):
        dim = rng.randrange(topo.ndim)
        nodes = clique_nodes(topo, dim)
        if len(nodes) >= 2:
            pairs = tuple(
                (nodes[i], nodes[(i + 1) % len(nodes)])
                for i in range(len(nodes))
            )
            aggs.append((pairs, rng.uniform(1e6, 1e8)))
    return topo, rx, dim_io, paths, aggs


def _build(topo, rx, dim_io, paths, aggs, solver):
    net = FluidNetwork(topo, rx_gbs=rx, dim_io_gbs=dim_io, solver=solver)
    flows = [net.add_flow(p, s) for p, s in paths]
    flows += [net.add_aggregate_flow(pairs, s) for pairs, s in aggs]
    net._recompute()
    return net, flows


# ---------------------------------------------------------------------------
# the invariant checkers
# ---------------------------------------------------------------------------


def _loads(net):
    """Summed rate per constraint key (multiset-aware: a key occurring k
    times in a flow's constraint tuple is consumed k times)."""
    load: dict = {}
    users: dict = {}
    for f in net.flows.values():
        for c in f.constraints:
            load[c] = load.get(c, 0.0) + f.rate
            users.setdefault(c, []).append(f)
    return load, users


def check_capacity(net) -> None:
    load, _ = _loads(net)
    for c, l in load.items():
        cap = net.constraint_capacity(c)
        assert l <= cap * (1 + _REL) + _ABS, (
            f"constraint {c} overloaded: {l} > {cap}"
        )


def check_maxmin(net) -> None:
    load, users = _loads(net)
    for f in net.flows.values():
        assert f.rate > 0.0, f"flow {f.fid} starved with live capacity"
        bottleneck = None
        for c in set(f.constraints):
            cap = net.constraint_capacity(c)
            if load[c] < cap * (1 - _REL) - _ABS:
                continue                      # not saturated
            fastest = max(g.rate for g in users[c])
            if f.rate >= fastest * (1 - _REL):
                bottleneck = c
                break
        assert bottleneck is not None, (
            f"flow {f.fid} (rate {f.rate}) could be increased without "
            f"hurting a slower flow — not max-min fair"
        )


def check_parity(net_a, net_b) -> None:
    assert set(net_a.flows) == set(net_b.flows)
    for fid, fa in net_a.flows.items():
        fb = net_b.flows[fid]
        scale = max(fa.rate, fb.rate, 1.0)
        assert abs(fa.rate - fb.rate) / scale <= 1e-6, (
            f"flow {fid}: vectorized {fa.rate} vs reference {fb.rate}"
        )


def check_conservation(net, flows) -> None:
    net.run()
    assert not net.flows, "flows left hanging after run()"
    expected = sum(f.total_bytes for f in flows)
    assert net.bytes_delivered == pytest.approx(expected, rel=1e-6)
    for f in flows:
        assert f.remaining <= 1e-5
        assert f.end_s is not None
    ledger = sum(net.link_bytes.values())
    wire = sum(f.size * len(f.links) for f in flows)
    assert ledger == pytest.approx(wire, rel=1e-6)


def _check_telemetry_parity(seed: int, caps: str) -> None:
    """Both solvers, recorded end-to-end: identical attributions and
    byte-conserving link timelines."""
    topo, rx, dim_io, paths, aggs = _scenario(seed, caps)
    if not paths and not aggs:
        pytest.skip("degenerate scenario")
    tels: dict[str, Telemetry] = {}
    nets: dict[str, FluidNetwork] = {}
    for solver in SOLVERS:
        tel = Telemetry()
        net = FluidNetwork(
            topo, rx_gbs=rx, dim_io_gbs=dim_io, solver=solver, telemetry=tel
        )
        for p, s in paths:
            net.add_flow(p, s)
        for pairs, s in aggs:
            net.add_aggregate_flow(pairs, s)
        net.run()
        tels[solver], nets[solver] = tel, net
    tv, tr = tels["vectorized"], tels["reference"]
    # identical final attribution per flow (exact key equality: both
    # solvers apply the same canonical min-key-at-freeze-level rule)
    assert tv.flow_bottlenecks() == tr.flow_bottlenecks()
    # ... and the full attribution history (dedup'd key sequence)
    for fid, trace_v in tv.flow_traces.items():
        hist_v = [k for _, k in trace_v.bottlenecks]
        hist_r = [k for _, k in tr.flow_traces[fid].bottlenecks]
        assert hist_v == hist_r, f"flow {fid}: {hist_v} != {hist_r}"
    # timeline integral == byte ledger, per link, per solver
    for solver in SOLVERS:
        net, tel = nets[solver], tels[solver]
        assert set(tel.link_series) <= set(net.link_bytes)
        for link, b in net.link_bytes.items():
            assert tel.link_bytes(link) == pytest.approx(b, rel=1e-6), (
                f"{solver} link {link}: timeline != ledger"
            )


def _run_invariant(seed: int, caps: str, solver: str, which: str) -> None:
    topo, rx, dim_io, paths, aggs = _scenario(seed, caps)
    if not paths and not aggs:
        pytest.skip("degenerate scenario")
    if which == "parity":
        net_v, _ = _build(topo, rx, dim_io, paths, aggs, "vectorized")
        net_r, _ = _build(topo, rx, dim_io, paths, aggs, "reference")
        check_parity(net_v, net_r)
        return
    net, flows = _build(topo, rx, dim_io, paths, aggs, solver)
    if which == "capacity":
        check_capacity(net)
    elif which == "maxmin":
        check_maxmin(net)
    elif which == "conservation":
        check_conservation(net, flows)
    else:  # pragma: no cover
        raise AssertionError(which)


def _check_aggregate_equivalence(seed: int, caps: str, solver: str) -> None:
    """One symmetric ring step: aggregate vs expanded completion parity."""
    rng = random.Random(seed * 104729 + 17)
    topo = _random_topo(rng)
    dim = rng.randrange(topo.ndim)
    nodes = clique_nodes(topo, dim)
    if len(nodes) < 2:
        pytest.skip("degenerate clique")
    pairs = tuple(
        (nodes[i], nodes[(i + 1) % len(nodes)]) for i in range(len(nodes))
    )
    size = rng.uniform(1e6, 1e8)
    rx = None
    dim_io = None
    if "rx" in caps:
        rx = max(d.gbs_total for d in topo.dims) * rng.uniform(0.3, 1.0)
    if "io" in caps:
        dim_io = {dim: topo.dims[dim].gbs_per_peer * rng.uniform(0.5, 2.0)}
    agg = FluidNetwork(topo, rx_gbs=rx, dim_io_gbs=dim_io, solver=solver)
    agg.add_aggregate_flow(pairs, size)
    agg.run()
    exp = FluidNetwork(topo, rx_gbs=rx, dim_io_gbs=dim_io, solver=solver)
    for u, v in pairs:
        exp.add_flow((u, v), size)
    exp.run()
    assert agg.engine.now == pytest.approx(exp.engine.now, rel=1e-9)
    assert agg.bytes_delivered == pytest.approx(
        exp.bytes_delivered, rel=1e-9
    )


# ---------------------------------------------------------------------------
# seeded corpus — always runs (no hypothesis required)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("caps", CAP_MODES)
@pytest.mark.parametrize("seed", SEEDS)
class TestSeededInvariants:
    def test_capacity_respected(self, seed, caps):
        for solver in SOLVERS:
            _run_invariant(seed, caps, solver, "capacity")

    def test_maxmin_bottleneck(self, seed, caps):
        for solver in SOLVERS:
            _run_invariant(seed, caps, solver, "maxmin")

    def test_solver_parity(self, seed, caps):
        _run_invariant(seed, caps, None, "parity")

    def test_conservation(self, seed, caps):
        for solver in SOLVERS:
            _run_invariant(seed, caps, solver, "conservation")

    def test_aggregate_equivalence(self, seed, caps):
        for solver in SOLVERS:
            _check_aggregate_equivalence(seed, caps, solver)

    def test_telemetry_parity(self, seed, caps):
        _check_telemetry_parity(seed, caps)


# ---------------------------------------------------------------------------
# hypothesis exploration — same checkers, generated seeds/cap modes
# ---------------------------------------------------------------------------


class TestHypothesisInvariants:
    @given(seed=st.integers(0, 10**6), caps=st.sampled_from(CAP_MODES))
    @settings(max_examples=20)
    def test_capacity_respected(self, seed, caps):
        for solver in SOLVERS:
            _run_invariant(seed, caps, solver, "capacity")

    @given(seed=st.integers(0, 10**6), caps=st.sampled_from(CAP_MODES))
    @settings(max_examples=20)
    def test_maxmin_bottleneck(self, seed, caps):
        for solver in SOLVERS:
            _run_invariant(seed, caps, solver, "maxmin")

    @given(seed=st.integers(0, 10**6), caps=st.sampled_from(CAP_MODES))
    @settings(max_examples=20)
    def test_solver_parity(self, seed, caps):
        _run_invariant(seed, caps, None, "parity")

    @given(seed=st.integers(0, 10**6), caps=st.sampled_from(CAP_MODES))
    @settings(max_examples=10)
    def test_conservation(self, seed, caps):
        for solver in SOLVERS:
            _run_invariant(seed, caps, solver, "conservation")

    @given(seed=st.integers(0, 10**6), caps=st.sampled_from(CAP_MODES))
    @settings(max_examples=10)
    def test_aggregate_equivalence(self, seed, caps):
        for solver in SOLVERS:
            _check_aggregate_equivalence(seed, caps, solver)

    @given(seed=st.integers(0, 10**6), caps=st.sampled_from(CAP_MODES))
    @settings(max_examples=10)
    def test_telemetry_parity(self, seed, caps):
        _check_telemetry_parity(seed, caps)
