"""Monte-Carlo availability campaign benchmark + campaign-summary CLI
(paper §3.3.2, §6.6, Table 6).

``availability_smoke`` (the ``run.py --suite smoke`` entry, < 30 s):

* **Table 6 head-to-head** — sampling-only UB-Mesh vs Clos campaign at
  8K NPUs over 16 seeds; bar: the measured network-availability gap
  lands on the paper's ≈7.2 pp (±2 pp band).
* **Netsim reroute repricing** — every failure class priced on the
  256-chip smoke pod through ``NetsimPerfModel(failed_links=...)``;
  bars: trunk/LRS failures produce a measurable degraded step (the
  number comes from the flow simulator's APR reroute, not an analytic
  discount) while single intra-rack link failures are fully absorbed by
  detour routing — the paper's graceful-degradation claim.
* **Linearity under failures** — weak-scaled 1K -> 8K per-NPU goodput
  ratio; bar: UB-Mesh >= 95% while the backup-less Clos (full
  checkpoint-restore per NPU failure) lands far below.
* **Determinism** — the same seed replays to the identical SeedResult.

The CLI writes the campaign-summary JSON CI uploads as an artifact::

    PYTHONPATH=src python -m benchmarks.availability_bench --smoke \
        --json campaign_summary.json
    PYTHONPATH=src python -m benchmarks.availability_bench \
        --chips 8192 --seeds 16 --weeks 4 --trace trace.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core.codesign import GeometryCandidate
from repro.runtime.campaign import (
    CampaignConfig,
    DegradedRepricer,
    MESH_CLASSES,
    _default_workload,
    campaign_trace,
    head_to_head,
    linearity_under_failures,
    replay_seed,
    run_campaign,
)

SMOKE_SEEDS = tuple(range(16))

# paper §6.6 / Table 6 reference points
REF = {
    "availability_gap": 0.072,       # "about 7.2% higher availability"
    "ub_availability": 0.987,        # 88.93/yr @ 75 min MTTR
    "clos_availability": 0.917,      # 632.8/yr @ 75 min MTTR
    "linearity": 0.95,               # ">= 95% linearity" under failures
}


def smoke_candidate() -> GeometryCandidate:
    """256-chip (4,4,4,4) pod: every trunk dimension is >= 3 deep, so all
    canonical failure classes keep a detour and reprice measurably."""
    return GeometryCandidate(board=4, boards_per_rack=4)


def availability_smoke():
    t_start = time.perf_counter()
    cand = smoke_candidate()

    # -- Table 6 head-to-head (sampling-only: the gap is an AFR/repair
    # property; repricing doesn't move the availability metric) ---------
    h = head_to_head(chips=8192, seeds=SMOKE_SEEDS, netsim_reprice=False)
    gap = h["availability_gap"]

    # -- netsim reroute repricing on the smoke pod ----------------------
    chips = 256
    perf = cand.perf_model(chips, size_bytes=4e6)
    from repro.core.planner import best_parallel_spec

    w = _default_workload()
    spec = best_parallel_spec(w, chips, perf, rack_size=cand.rack_size)
    rp = DegradedRepricer(
        perf, w, spec,
        rack_size=cand.rack_size,
        hrs_count=cand.superpod(chips).hrs_count(),
    )
    deltas = {cls: rp.delta_s(cls) for cls in MESH_CLASSES}

    # -- one netsim-repriced campaign + replay determinism --------------
    cfg = CampaignConfig(
        candidate=cand, chips=chips, seeds=(0, 1), size_bytes=4e6,
        workload=w,
    )
    camp = run_campaign(cfg)
    r0a = replay_seed(camp.config, 0, None)
    r0b = replay_seed(camp.config, 0, None)
    deterministic = (
        r0a.availability == r0b.availability
        and r0a.goodput == r0b.goodput
        and r0a.timeline == r0b.timeline
    )

    # -- linearity under failures (analytic perf; failure discount from
    # the seeded campaign) ----------------------------------------------
    lin = linearity_under_failures(
        1024, 8192, seeds=tuple(range(8)),
        netsim_reprice=False, perf_backend="analytic",
    )
    lin_clos = linearity_under_failures(
        1024, 8192, seeds=tuple(range(8)), arch="clos",
        netsim_reprice=False,
    )

    wall = time.perf_counter() - t_start
    derived = {
        "ub_availability": round(h["ub"].availability, 5),
        "clos_availability": round(h["clos"].availability, 5),
        "availability_gap": round(gap, 5),
        "gap_within_2pp_of_paper": abs(gap - REF["availability_gap"]) <= 0.02,
        "healthy_step_s": round(rp.healthy_s, 4),
        "delta_a_trunk_s": round(deltas["a_trunk"], 4),
        "delta_lrs_s": round(deltas["lrs"], 4),
        "delta_x_link_s": round(deltas["x_link"], 4),
        "trunk_reprices_measurably": deltas["a_trunk"] > 0
        and deltas["lrs"] > 0,
        "single_link_absorbed_by_detour": deltas["x_link"] == 0.0
        and deltas["y_link"] == 0.0,
        "smoke_goodput": round(camp.goodput, 5),
        "replay_deterministic": deterministic,
        "linearity_ub": round(lin["linearity"], 4),
        "linearity_clos": round(lin_clos["linearity"], 4),
        "ub_linearity_ge_95pct": lin["linearity"] >= 0.95,
        "clos_linearity_below_ub": lin_clos["linearity"] < lin["linearity"],
        "wall_s": round(wall, 2),
        "under_30s": wall <= 30.0,
    }
    return derived, dict(REF)


AVAILABILITY_BENCHMARKS = {"availability_smoke": availability_smoke}


# ---------------------------------------------------------------------------
# CLI: campaign-summary JSON (the CI artifact) + Perfetto timeline
# ---------------------------------------------------------------------------


def full_summary(
    chips: int, seeds: tuple[int, ...], weeks: float, *, reprice: bool
) -> dict:
    h = head_to_head(
        chips=chips, seeds=seeds, horizon_weeks=weeks, netsim_reprice=reprice
    )
    lin = linearity_under_failures(
        min(1024, chips), chips, seeds=seeds, horizon_weeks=weeks,
        netsim_reprice=reprice,
        perf_backend="netsim" if reprice else "analytic",
    )
    lin_clos = linearity_under_failures(
        min(1024, chips), chips, seeds=seeds, horizon_weeks=weeks,
        arch="clos", netsim_reprice=False,
    )
    return {
        "suite": "availability_campaign",
        "chips": chips,
        "seeds": len(seeds),
        "horizon_weeks": weeks,
        "netsim_reprice": reprice,
        "ub": h["ub"].summary(),
        "clos": h["clos"].summary(),
        "availability_gap": round(h["availability_gap"], 5),
        "analytic_gap": round(h["analytic_gap"], 5),
        "goodput_gap": round(h["goodput_gap"], 5),
        "linearity_ub": round(lin["linearity"], 5),
        "linearity_clos": round(lin_clos["linearity"], 5),
        "ref": dict(REF),
        "head_to_head": h,
    }


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--chips", type=int, default=8192)
    ap.add_argument("--seeds", type=int, default=8)
    ap.add_argument("--weeks", type=float, default=4.0)
    ap.add_argument(
        "--smoke", action="store_true",
        help="the < 30 s CI entry (bars + Table 6 gap + linearity)",
    )
    ap.add_argument(
        "--no-reprice", action="store_true",
        help="skip netsim repricing (sampling-only availability)",
    )
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument(
        "--trace", metavar="PATH", default=None,
        help="write seed 0's failure/recovery timeline as a Perfetto trace",
    )
    args = ap.parse_args(argv)

    if args.smoke:
        derived, ref = availability_smoke()
        for k, v in derived.items():
            print(f"{k}={v}")
        doc = {"suite": "availability_smoke", "derived": derived, "ref": ref}
        failures = sum(1 for v in derived.values() if v is False)
    else:
        doc = full_summary(
            args.chips, tuple(range(args.seeds)), args.weeks,
            reprice=not args.no_reprice,
        )
        h = doc.pop("head_to_head")
        print(
            f"UB-Mesh  avail {doc['ub']['availability']:.5f} "
            f"goodput {doc['ub']['goodput']:.5f}"
        )
        print(
            f"Clos     avail {doc['clos']['availability']:.5f} "
            f"goodput {doc['clos']['goodput']:.5f}"
        )
        print(
            f"gap {doc['availability_gap']:.4f} (paper ~0.072, analytic "
            f"{doc['analytic_gap']:.4f}) | linearity UB "
            f"{doc['linearity_ub']:.4f} vs Clos {doc['linearity_clos']:.4f}"
        )
        if args.trace:
            campaign_trace(h["ub"].runs[0], path=args.trace)
            print(f"trace: {args.trace}", file=sys.stderr)
        failures = int(abs(doc["availability_gap"] - REF["availability_gap"]) > 0.02)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2, default=str)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
