"""Roofline report: aggregates results/dryrun/*.json into the §Roofline table.

    PYTHONPATH=src python -m benchmarks.roofline [--markdown]

Per (arch x shape x mesh): the three roofline terms (seconds), the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line "what would move the
dominant term down".
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

ADVICE = {
    ("compute",): "raise useful-FLOPs ratio: lighter remat policy, fuse "
                  "attention (flash kernel), drop redundant weight re-gathers",
    ("memory",): "cut HBM traffic: blocked (flash) attention removes the "
                 "S^2 score materialization; bf16 master copies; fuse "
                 "softmax/loss",
    ("collective",): "cut wire bytes: ZeRO-1 reduce-scatter instead of "
                     "all-reduce, bf16 payloads, hierarchical (multi-ring) "
                     "schedule, batch weight gathers once per layer",
}


def load_records() -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def fmt_row(r: dict) -> dict:
    if r["status"] != "ok":
        return {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": r["status"],
            "note": r.get("reason", r.get("error", ""))[:70],
        }
    roof = r["roofline"]
    dom = roof["bottleneck"]
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "status": "ok",
        "compute_s": roof["compute_s"],
        "memory_s": roof["memory_s"],
        "collective_s": roof["collective_s"],
        "bottleneck": dom,
        "useful_flops": roof["useful_flops_ratio"],
        "mem_gb": r["memory"]["peak_per_device_gb"],
        "advice": ADVICE[(dom,)],
    }


def roofline_fraction(row: dict) -> float:
    """Achievable fraction of the compute roofline: compute term over the
    max term (1.0 = perfectly compute-bound at peak)."""
    terms = [row["compute_s"], row["memory_s"], row["collective_s"]]
    m = max(terms)
    return row["compute_s"] / m if m > 0 else 0.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()

    rows = [fmt_row(r) for r in load_records()]
    if args.mesh:
        rows = [r for r in rows if r.get("mesh") == args.mesh]
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    errored = [r for r in rows if r["status"] == "error"]

    if args.markdown:
        print("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
              "| bottleneck | 6ND/HLO | mem GB/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in sorted(ok, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                  f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
                  f"| {r['collective_s']:.3e} | **{r['bottleneck']}** "
                  f"| {r['useful_flops']:.2f} | {r['mem_gb']:.1f} |")
        for r in sorted(skipped, key=lambda x: (x["arch"], x["shape"])):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — "
                  f"| skipped | — | — |")
    else:
        print(f"{'arch':16s} {'shape':12s} {'mesh':10s} {'comp_s':>10s} "
              f"{'mem_s':>10s} {'coll_s':>10s} {'bottleneck':>11s} "
              f"{'6ND/HLO':>8s} {'GB/dev':>7s}")
        for r in sorted(ok, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
            print(f"{r['arch']:16s} {r['shape']:12s} {r['mesh']:10s} "
                  f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
                  f"{r['collective_s']:10.3e} {r['bottleneck']:>11s} "
                  f"{r['useful_flops']:8.2f} {r['mem_gb']:7.1f}")
        for r in skipped:
            print(f"{r['arch']:16s} {r['shape']:12s} {r['mesh']:10s} "
                  f"SKIPPED: {r['note']}")
        for r in errored:
            print(f"{r['arch']:16s} {r['shape']:12s} {r['mesh']:10s} "
                  f"ERROR: {r['note']}")
    print(f"\n# ok={len(ok)} skipped={len(skipped)} error={len(errored)}")
    if ok:
        worst = min(ok, key=roofline_fraction)
        coll = max(ok, key=lambda r: r["collective_s"] / max(r["compute_s"], 1e-12))
        print(f"# worst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({roofline_fraction(worst):.3f})")
        print(f"# most collective-bound: {coll['arch']}/{coll['shape']}")


if __name__ == "__main__":
    main()
