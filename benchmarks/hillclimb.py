import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing: hypothesis -> change -> measure -> validate.

Runs the three chosen cells (worst roofline fraction / most collective-bound
/ most representative of the paper's technique) through their variant
ladders, measuring the probe-extrapolated roofline terms for each change.

    PYTHONPATH=src python -m benchmarks.hillclimb [--cell granite] [--quick]
    PYTHONPATH=src python -m benchmarks.hillclimb --perf-model netsim

``--perf-model`` re-prices each variant's collective wire bytes on a
``core.perf_model.PerfModel`` backend's UB-Mesh model axis (analytic
idealized bandwidth, or the netsim-calibrated effective bandwidth), shown
as the ``ub_coll`` column — what the variant's collective term would cost
on the paper's fabric instead of the v5e ICI constant.

Writes results/perf/<cell>__<variant>.json; EXPERIMENTS.md §Perf narrates
the hypothesis log.
"""

import argparse
import dataclasses
import json
import pathlib
import time

from repro.configs import load
from repro.launch.dryrun import extrapolated_metrics
from repro.launch.hlo_stats import Roofline
from repro.launch.mesh import make_production_mesh
from repro.models.api import SHAPES
from repro.models.moe import MoEConfig
from repro.train.train_step import build_bundle, lower_bundle

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "perf"


def _dbrx_moe(**kw) -> MoEConfig:
    return MoEConfig(
        n_experts=16, topk=4, d_ff=10752, strategy="expert_parallel", **kw
    )


# variant ladders: (name, hypothesis, cfg overrides)
CELLS = {
    "granite": (
        "granite-8b", "train_4k",
        [
            ("baseline", "paper-faithful: reference attention, full remat", {}),
            ("blocked-attn",
             "H-mem: S^2 score materialization dominates HBM bytes; blocked "
             "online-softmax attention removes it -> memory term down",
             {"attn_impl": "blocked"}),
            ("remat-dots",
             "H-coll: FSDP weight re-gathers run 3x (fwd+bwd+remat); saving "
             "matmul outputs drops the remat re-gather -> wire down, memory up",
             {"remat_policy": "dots"}),
            ("blocked+dots",
             "H-combo: the two compose (different terms)",
             {"attn_impl": "blocked", "remat_policy": "dots"}),
        ],
    ),
    "starcoder2": (
        "starcoder2-7b", "prefill_32k",
        [
            ("baseline", "paper-faithful reference attention", {}),
            ("blocked-attn",
             "H-swa: SWA(4096) computed as full 32K attention wastes 7/8 of "
             "blocks; static block skipping cuts FLOPs ~4x and HBM bytes more",
             {"attn_impl": "blocked"}),
        ],
    ),
    "dbrx": (
        "dbrx-132b", "train_4k",
        [
            ("baseline", "paper-faithful GShard MoE over seq-sharded tokens", {}),
            ("a2a-dispatch",
             "H-a2a: dispatch contracts the model-sharded seq dim -> GSPMD "
             "emits full (E,B,C,D) psums; resharding tokens seq->d_model "
             "turns the expert switch into an A2A (paper's own EP pattern)",
             {"moe": _dbrx_moe(reshard_tokens=True)}),
            ("bf16-dispatch",
             "H-dtype: dispatch/combine collectives carry f32; bf16 payloads "
             "halve wire bytes",
             {"moe": _dbrx_moe(dispatch_dtype="bf16")}),
            ("a2a+bf16+cap1.0",
             "H-combo: A2A lowering + bf16 payloads + capacity 1.0 "
             "(25% fewer dispatched tokens)",
             {"moe": _dbrx_moe(reshard_tokens=True, dispatch_dtype="bf16",
                               capacity_factor=1.0)}),
            ("round2+blocked",
             "round 2 (memory now dominant): add blocked attention to the "
             "best combo -> S^2 scores and mask temporaries gone",
             {"attn_impl": "blocked",
              "moe": _dbrx_moe(reshard_tokens=True, dispatch_dtype="bf16",
                               capacity_factor=1.0)}),
        ],
    ),
}


def measure(arch: str, shape: str, overrides: dict, multi_pod=False) -> dict:
    harness = load(arch)
    if overrides:
        harness = harness.clone(**overrides)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256

    t0 = time.time()
    bundle = build_bundle(harness, cell, mesh, multi_pod=multi_pod)
    compiled = lower_bundle(bundle, mesh).compile()
    mem = compiled.memory_analysis()
    peak_gb = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        + mem.temp_size_in_bytes - mem.alias_size_in_bytes
    ) / 1e9

    metrics = extrapolated_metrics(harness, cell, mesh, multi_pod)
    from repro.launch.dryrun import analytic_model_flops

    roof = Roofline(
        flops=metrics["flops"],
        hbm_bytes=metrics["hbm"],
        wire_bytes=metrics["wire"],
        model_flops=analytic_model_flops(harness, cell) / chips,
    )
    return {
        "arch": arch, "shape": shape,
        "roofline": roof.to_dict(),
        "peak_gb": round(peak_gb, 2),
        "wall_s": round(time.time() - t0, 1),
    }


def ubmesh_model_axis_gbs(backend: str) -> float:
    """Per-chip model-axis bandwidth from a PerfModel backend — the price a
    variant's collective wire bytes would pay on the UB-Mesh fabric."""
    from repro.core.cost_model import Routing, build_comm_model

    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)
    if backend == "netsim":
        from repro.core.perf_model import NetsimPerfModel

        perf = NetsimPerfModel(comm)
    else:
        perf = comm
    return perf.comm_model(None).axes["model"].gbs_per_chip


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=[*CELLS, None])
    ap.add_argument(
        "--perf-model", default=None, choices=("analytic", "netsim"),
        help="also price collective wire bytes on this UB-Mesh PerfModel backend",
    )
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    ub_gbs = ubmesh_model_axis_gbs(args.perf_model) if args.perf_model else None

    cells = [args.cell] if args.cell else list(CELLS)
    for cname in cells:
        arch, shape, ladder = CELLS[cname]
        print(f"=== {cname}: {arch} / {shape} ===", flush=True)
        base_terms = None
        for vname, hypothesis, overrides in ladder:
            out = RESULTS / f"{cname}__{vname}.json"
            if out.exists():
                rec = json.loads(out.read_text())
                print(f"  [cached] {vname}")
            else:
                rec = measure(arch, shape, overrides)
                rec["variant"] = vname
                rec["hypothesis"] = hypothesis
                out.write_text(json.dumps(rec, indent=2))
            r = rec["roofline"]
            terms = (r["compute_s"], r["memory_s"], r["collective_s"])
            if base_terms is None:
                base_terms = terms
            deltas = tuple(
                f"{(t / b - 1) * 100:+.1f}%" if b else "n/a"
                for t, b in zip(terms, base_terms)
            )
            ub = (
                f"ub_coll={r['wire_bytes'] / (ub_gbs * 1e9):.3f}s "
                if ub_gbs
                else ""
            )
            print(f"  {vname:18s} comp={terms[0]:.3f}s ({deltas[0]}) "
                  f"mem={terms[1]:.3f}s ({deltas[1]}) "
                  f"coll={terms[2]:.3f}s ({deltas[2]}) {ub}"
                  f"useful={r['useful_flops_ratio']:.2f} "
                  f"peak={rec['peak_gb']}GB", flush=True)


if __name__ == "__main__":
    main()
