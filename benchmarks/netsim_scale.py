"""SuperPod-scale netsim benchmarks: solver speedup + coarsened multi-pod.

Three claims, each one function (same ``(derived, ref)`` contract as
``paper_tables.py``), run by ``run.py --suite scale`` and recorded in
``BENCH_netsim.json``:

* **pod_calibration_speed** — the ISSUE-4 acceptance bar: the vectorized
  solver + symmetric-flow aggregation must run the existing pod-level
  ``calibrated_axis_gbs`` benchmark >= 5x faster than the reference
  pure-Python configuration while reproducing the measured GB/s within
  1%.  The ``speedup`` ratio is measured *within one process*, so the
  committed baseline transfers across machines — CI fails the suite if
  it regresses more than 25% (see ``REGRESSION_GUARDS``).
* **superpod_coarse** — rack-coarsened multi-pod calibration accuracy:
  cross-pod DP bandwidth within 20% of the analytic DCN model on an
  uncontended config, coarse inter-rack bandwidth within 5% of the exact
  chip-level pod measurement, and a full 8-pod (8192-chip) coarse DP
  hierarchical AllReduce executed end-to-end.
* **superpod_plan** — a 4-pod (4096-chip) coarsened
  ``NetsimPerfModel``-backed ``plan()`` completes within the 60 s budget.
* **planner_throughput** — the ISSUE-7 acceptance bars: an 8-pod
  (8192-chip) ``plan()`` on the fast path (analytic pre-filter + batched
  precalibration + wire-template reuse + disk cache) finishes <= 5 s
  cold and <= 1 s disk-warm, picks the exact same winner as the pre-PR
  per-spec baseline leg, and beats it by >= 3x within one process.
* **mixed_granularity** — the ISSUE-5 acceptance bars: with one rack
  embedded at chip granularity inside the coarse 4-pod mesh
  (``coarsen_superpod(..., detail_racks=(0,))``), zero-background
  "pod"-axis numbers match pure-coarse within 2% and the idle model axis
  matches the chip-level measurement within 2%, while concurrent coarse
  cross-pod DP background traffic degrades the embedded rack's measured
  model-axis bandwidth by >5% (ejection-port + uplink sharing neither
  pure path can see).
* **telemetry_overhead** — the ISSUE-6 acceptance bar: recording full
  telemetry (link timelines + bottleneck attribution + flow traces) on
  the rack-level calibration costs a bounded same-run factor, and the
  disabled path stays free (no recorder, no solver attribution work).
"""

from __future__ import annotations

import time
from dataclasses import replace

from repro.core.cost_model import Routing, build_comm_model
from repro.core.perf_model import NetsimPerfModel
from repro.core.planner import plan
from repro.core.topology import SuperPod, ub_mesh_pod
from repro.core.traffic import moe_2t_workload
from repro.netsim import NetSim
from repro.netsim.coarsen import (
    coarse_calibrated_profile,
    coarse_netsim,
    coarsen_superpod,
    mixed_calibrated_profile,
)

_CAL_BYTES = 16e6


def netsim_pod_calibration_speed():
    """Vectorized+aggregated vs reference pod-level calibration (>= 5x)."""
    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)

    def run(solver: str, aggregate: bool) -> tuple[float, dict]:
        sim = NetSim(
            ub_mesh_pod(),
            routing=Routing.DETOUR,
            solver=solver,
            aggregate=aggregate,
        )
        t0 = time.perf_counter()
        cal = sim.calibrated_axis_gbs(_CAL_BYTES, comm=comm)
        return time.perf_counter() - t0, {k: float(v) for k, v in cal.items()}

    # untimed warmup: the first calibration in a process pays import /
    # allocator cold-start that would otherwise land entirely on the
    # vectorized side (it is timed first) and skew the same-run ratio
    run("vectorized", True)
    fast_s, fast_cal = run("vectorized", True)
    base_s, base_cal = run("reference", False)
    worst_dev = max(
        abs(fast_cal[k] - base_cal[k]) / base_cal[k] for k in base_cal
    )
    derived = {
        "calibrated_s": round(fast_s, 4),
        "reference_s": round(base_s, 4),
        "speedup": round(base_s / fast_s, 2),
        "gbs_rel_dev": round(worst_dev, 6),
        "speedup_ge_5x": base_s / fast_s >= 5.0,
        "gbs_within_1pct": worst_dev <= 0.01,
    }
    derived.update({f"{k}_gbs": round(v, 1) for k, v in sorted(fast_cal.items())})
    ref = {"min_speedup": 5.0, "max_gbs_dev": 0.01}
    return derived, ref


def netsim_superpod_coarse():
    """Rack-coarsened multi-pod calibration: accuracy + 8192-chip run."""
    pod = ub_mesh_pod()
    comm = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
    analytic_pod = comm.axes["pod"].gbs_per_chip

    sp4 = SuperPod(pod=pod, n_pods=4)
    cm4 = coarsen_superpod(sp4)
    t0 = time.perf_counter()
    prof = coarse_calibrated_profile(
        cm4, 64e6, axis_sizes={"pod": 4, "data": 16},
        axes=("pod", "data"), shapes=("allreduce",),
    )
    cal_s = time.perf_counter() - t0
    pod_bw = prof.get("pod", "allreduce")
    pod_err = abs(pod_bw - analytic_pod) / analytic_pod
    exact_data = NetSim(pod, routing=Routing.DETOUR).calibrated_profile(
        _CAL_BYTES, comm=build_comm_model(multi_pod=False, routing=Routing.DETOUR),
        axes=("data",), shapes=("allreduce",),
    ).get("data", "allreduce")
    coarse_data = coarse_calibrated_profile(
        cm4, _CAL_BYTES, axis_sizes={"data": 16}, axes=("data",),
        shapes=("allreduce",), latency_s=1e-6,
    ).get("data", "allreduce")
    data_err = abs(coarse_data - exact_data) / exact_data

    # full 8-pod SuperPod (8192 chips): contended DP AllReduce across the
    # whole coarse mesh (every rack participates, Z+A+HRS dims all busy)
    from repro.netsim.collectives import hierarchical_allreduce

    sp8 = SuperPod(pod=pod, n_pods=8)
    cm8 = coarsen_superpod(sp8)
    dims = tuple(range(cm8.topo.ndim))
    dag = hierarchical_allreduce(
        cm8.topo, dims, 64e6 * cm8.chips_per_node, tag="superpod-dp"
    )
    t0 = time.perf_counter()
    r = coarse_netsim(cm8).run_dag(dag)
    run8_s = time.perf_counter() - t0
    derived = {
        "pod_axis_gbs": round(pod_bw, 2),
        "pod_axis_analytic_gbs": round(analytic_pod, 2),
        "pod_axis_rel_err": round(pod_err, 4),
        "pod_within_20pct": pod_err <= 0.20,
        "data_axis_coarse_gbs": round(coarse_data, 2),
        "data_axis_exact_gbs": round(exact_data, 2),
        "data_axis_rel_err": round(data_err, 4),
        "coarse_cal_s": round(cal_s, 4),
        "superpod8_nodes": cm8.topo.num_nodes,
        "superpod8_chips": cm8.num_chips,
        "superpod8_dp_ms": round(r.makespan_s * 1e3, 3),
        "superpod8_wall_s": round(run8_s, 3),
        "superpod8_complete": r.incomplete == 0,
    }
    ref = {"max_pod_err": 0.20, "note": "analytic DCN pod axis = uplink/chips"}
    return derived, ref


def netsim_superpod_plan():
    """4-pod (4096-chip) coarsened NetsimPerfModel plan() under 60 s."""
    sp = SuperPod(pod=ub_mesh_pod(), n_pods=4)
    base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
    base = base.override_axis("pod", replace(base.axes["pod"], size=4))
    perf = NetsimPerfModel(
        base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=sp
    )
    w, _ = moe_2t_workload()
    t0 = time.perf_counter()
    rep = plan(w, 4096, perf)
    wall = time.perf_counter() - t0
    best = rep[0]
    cm = perf.comm_model(best.spec)
    derived = {
        "plan_wall_s": round(wall, 2),
        "under_60s": wall < 60.0,
        "chips": 4096,
        "n_enumerated": rep.n_enumerated,
        "winner": str(best.spec),
        "iter_s": round(best.iteration_s, 3),
        "pod_axis_gbs": round(cm.axes["pod"].gbs_per_chip, 2),
    }
    ref = {"budget_s": 60.0}
    return derived, ref


def netsim_planner_throughput():
    """ISSUE-7 acceptance bars: planner fast path vs per-spec baseline.

    One 8-pod (8192-chip) coarsened ``NetsimPerfModel`` ``plan()``, three
    legs in one process, each starting from a cleared calibration memo
    (the process-restart boundary the ISSUE's sweep scenario pays — "a
    100-candidate sweep re-pays calibration on every restart"):

    * **baseline** — the pre-PR planner behavior: per-spec sequential
      calibration (no ``precalibrate``), no analytic pre-filter, no wire
      template reuse, no disk cache.  Every restart costs this much.
    * **cold** — the full fast path (pre-filter + batched precalibration +
      wire-template reuse) against an empty ephemeral disk cache: the
      sweep's FIRST call.
    * **warm** — same, after clearing the in-process memo again, so every
      key comes back from disk: every LATER call in the sweep.

    Bars: cold <= 5 s, warm <= 1 s, the three legs agree on the winning
    spec bit-identically, and ``speedup`` >= 3x, defined as the wall-clock
    ratio of a three-restart sweep (3x baseline vs cold + 2x warm — all
    four walls measured in this run, so the ratio transfers across machine
    speeds).  ``cold_speedup`` additionally reports the single-call ratio
    (pre-filter + batching alone, no persistence credit)."""
    import shutil
    import tempfile

    from repro.core import perf_model as _pm
    from repro.core.perf_model import reset_calibration_stats

    sp = SuperPod(pod=ub_mesh_pod(), n_pods=8)
    base = build_comm_model(multi_pod=True, routing=Routing.DETOUR)
    base = base.override_axis("pod", replace(base.axes["pod"], size=8))
    w, _ = moe_2t_workload()

    def leg(perf, **plan_kw):
        _pm._CALIBRATION_CACHE.clear()
        reset_calibration_stats()
        t0 = time.perf_counter()
        rep = plan(w, 8192, perf, **plan_kw)
        return time.perf_counter() - t0, rep

    memo_snapshot = dict(_pm._CALIBRATION_CACHE)
    tmp = tempfile.mkdtemp(prefix="calib-bench-")
    try:
        slow = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=sp,
            cache_dir=None, reuse_wire_template=False,
        )
        # untimed warmup (see pod_calibration_speed): the first plan in a
        # process pays import / allocator cold-start that would otherwise
        # land entirely on the baseline leg and flatter the ratio
        leg(slow, prefilter=None, precalibrate=False)
        base_s, rep_base = leg(slow, prefilter=None, precalibrate=False)
        fast = NetsimPerfModel(
            base, topo=ub_mesh_pod(), size_bytes=64e6, superpod=sp,
            cache_dir=tmp,
        )
        cold_s, rep_cold = leg(fast)
        warm_s, rep_warm = leg(fast)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
        _pm._CALIBRATION_CACHE.clear()
        _pm._CALIBRATION_CACHE.update(memo_snapshot)

    winners = {r[0].spec for r in (rep_base, rep_cold, rep_warm)}
    speedup = 3 * base_s / (cold_s + 2 * warm_s)
    derived = {
        "chips": 8192,
        "n_enumerated": rep_cold.n_enumerated,
        "n_prefiltered": rep_cold.n_prefiltered,
        "baseline_wall_s": round(base_s, 3),
        "cold_wall_s": round(cold_s, 3),
        "warm_wall_s": round(warm_s, 3),
        "speedup": round(speedup, 2),
        "cold_speedup": round(base_s / cold_s, 2),
        "speedup_ge_3x": speedup >= 3.0,
        "cold_under_5s": cold_s <= 5.0,
        "warm_under_1s": warm_s <= 1.0,
        "winner_identical": len(winners) == 1,
        "winner": str(rep_cold[0].spec),
        "iter_s": round(rep_cold[0].iteration_s, 3),
        "warm_disk_hits": rep_warm.calibration.get("disk_hits", 0),
        "baseline_cal_misses": rep_base.calibration.get("misses", 0),
        "cold_cal_misses": rep_cold.calibration.get("misses", 0),
    }
    ref = {"min_speedup": 3.0, "cold_budget_s": 5.0, "warm_budget_s": 1.0}
    return derived, ref


def netsim_mixed_granularity():
    """Mixed-granularity mesh: parity when idle, interference when loaded."""
    pod = ub_mesh_pod()
    sp = SuperPod(pod=pod, n_pods=4)
    cm_coarse = coarsen_superpod(sp)
    cm_mixed = coarsen_superpod(sp, detail_racks=(0,))

    t0 = time.perf_counter()
    coarse_pod = coarse_calibrated_profile(
        cm_coarse, 64e6, axis_sizes={"pod": 4}, axes=("pod",),
        shapes=("allreduce",),
    ).get("pod", "allreduce")
    mixed_pod = mixed_calibrated_profile(
        cm_mixed, 64e6, axis_sizes={"pod": 4}, axes=("pod",),
        shapes=("allreduce",),
    ).get("pod", "allreduce")
    chip_model = NetSim(pod, routing=Routing.DETOUR).calibrated_profile(
        64e6, axis_sizes={"model": 16}, axes=("model",),
        shapes=("allreduce",),
    ).get("model", "allreduce")
    idle_model = mixed_calibrated_profile(
        cm_mixed, 64e6, axis_sizes={"model": 16}, axes=("model",),
        shapes=("allreduce",), latency_s=1e-6,
    ).get("model", "allreduce")
    loaded_model = mixed_calibrated_profile(
        cm_mixed, 64e6, axis_sizes={"model": 16}, axes=("model",),
        shapes=("allreduce",), latency_s=1e-6,
        background_per_chip_bytes=64e6,
    ).get("model", "allreduce")
    wall = time.perf_counter() - t0

    pod_err = abs(mixed_pod - coarse_pod) / coarse_pod
    idle_err = abs(idle_model - chip_model) / chip_model
    degradation = 1 - loaded_model / idle_model
    derived = {
        "pod_axis_mixed_gbs": round(mixed_pod, 2),
        "pod_axis_coarse_gbs": round(coarse_pod, 2),
        "pod_parity_rel_err": round(pod_err, 5),
        "pod_parity_within_2pct": pod_err <= 0.02,
        "model_idle_gbs": round(idle_model, 1),
        "model_chip_level_gbs": round(chip_model, 1),
        "model_idle_rel_err": round(idle_err, 5),
        "model_idle_within_2pct": idle_err <= 0.02,
        "model_loaded_gbs": round(loaded_model, 1),
        "model_degradation_pct": round(100 * degradation, 2),
        "degradation_over_5pct": degradation > 0.05,
        "mixed_wall_s": round(wall, 3),
    }
    ref = {
        "min_degradation_pct": 5.0,
        "note": "coarse cross-pod DP background vs isolated model axis",
    }
    return derived, ref


def netsim_telemetry_overhead():
    """Telemetry-enabled vs -disabled pod calibration, one process.

    The recorder touches every solve (link sampling + attribution
    intervals) so it is NOT free when on — the bar is that the factor
    stays bounded (<= 5x) and the measured bandwidths are identical,
    i.e. observation never perturbs the simulation.  The ``overhead_ratio``
    is a same-run ratio, so the committed baseline transfers across
    machine speeds (guarded in ``REGRESSION_GUARDS``)."""
    comm = build_comm_model(multi_pod=False, routing=Routing.DETOUR)

    def run(telemetry: bool) -> tuple[float, dict]:
        sim = NetSim(
            ub_mesh_pod(), routing=Routing.DETOUR, telemetry=telemetry
        )
        t0 = time.perf_counter()
        cal = sim.calibrated_axis_gbs(_CAL_BYTES, comm=comm)
        return time.perf_counter() - t0, {k: float(v) for k, v in cal.items()}

    run(False)                    # untimed warmup (see pod_calibration_speed)
    off_s, off_cal = run(False)
    on_s, on_cal = run(True)
    ratio = on_s / off_s
    worst_dev = max(
        abs(on_cal[k] - off_cal[k]) / off_cal[k] for k in off_cal
    )
    derived = {
        "telemetry_off_s": round(off_s, 4),
        "telemetry_on_s": round(on_s, 4),
        "overhead_ratio": round(ratio, 3),
        "overhead_le_5x": ratio <= 5.0,
        "gbs_rel_dev": round(worst_dev, 9),
        "gbs_identical": worst_dev <= 1e-9,
    }
    ref = {"max_overhead": 5.0, "note": "observation must not perturb rates"}
    return derived, ref


def netsim_topo_sweep():
    """ISSUE-8 acceptance bars: cross-topology batched calibration vs the
    per-candidate sequential path on the reduced co-design guard set.

    One process, two complete geometry sweeps (pre-filter + calibrate +
    plan) over the 16-candidate reduced grid at 8192 chips, each leg from
    a cold calibration state (cleared memo, ephemeral disk cache — the
    restart cost a real sweep pays).  The **sequential** leg is the
    pre-PR-8 path: one ``NetsimPerfModel.precalibrate`` per candidate, so
    structurally identical measurements across candidates are re-run.
    The **batched** leg routes every candidate through
    ``perf_model.precalibrate_models``: compatible chip-level calibration
    DAGs from *different* candidate topologies share solver sessions on a
    disjoint host mesh, and rack-coarsened pod measurements run once per
    coarse structure instead of once per candidate.

    Bars: identical Pareto frontier and identical per-candidate winning
    specs across the legs (batching must be a pure perf change), batching
    actually shares sessions (keys > sessions), and the same-run
    calibration speedup stays >= 1.5x on the reduced set (the full
    64-candidate sweep, with 4x more uplink variants collapsing onto the
    same coarse structures, is where the >= 3x shows up — see
    ``benchmarks/topo_search.py --mode both``)."""
    from benchmarks.topo_search import (
        _cold_sweep,
        reduced_candidates,
        sweep_workload,
    )

    w = sweep_workload()
    cands = reduced_candidates()
    seq = _cold_sweep(w, 8192, cands, "sequential")
    bat = _cold_sweep(w, 8192, cands, "batched")
    same_frontier = [p.name for p in seq["frontier"]] == [
        p.name for p in bat["frontier"]
    ]
    same_specs = all(
        a.meta["spec"] == b.meta["spec"]
        for a, b in zip(seq["points"], bat["points"])
    )
    cal_speedup = (
        seq["calibrate_s"] / bat["calibrate_s"]
        if bat["calibrate_s"] > 0 else float("inf")
    )
    cal = bat["calibration"]
    derived = {
        "chips": 8192,
        "n_candidates": len(cands),
        "n_culled": bat["n_culled"],
        "sequential_cal_s": round(seq["calibrate_s"], 3),
        "batched_cal_s": round(bat["calibrate_s"], 3),
        "sequential_wall_s": round(seq["wall_s"], 3),
        "batched_wall_s": round(bat["wall_s"], 3),
        "speedup": round(cal_speedup, 2),
        "sweep_speedup": round(seq["wall_s"] / bat["wall_s"], 2),
        "speedup_ge_1_5x": cal_speedup >= 1.5,
        "frontier_identical": same_frontier,
        "winner_specs_identical": same_specs,
        "frontier": ";".join(p.name for p in bat["frontier"]),
        "cal_sessions": cal.get("sessions", 0),
        "cal_session_keys": cal.get("session_keys", 0),
        "sessions_shared": cal.get("session_keys", 0) > cal.get("sessions", 0),
    }
    ref = {"min_cal_speedup": 1.5, "note": "same-run ratio, cold legs"}
    return derived, ref


SCALE_BENCHMARKS = {
    "netsim_pod_calibration_speed": netsim_pod_calibration_speed,
    "netsim_superpod_coarse": netsim_superpod_coarse,
    "netsim_superpod_plan": netsim_superpod_plan,
    "netsim_planner_throughput": netsim_planner_throughput,
    "netsim_topo_sweep": netsim_topo_sweep,
    "netsim_mixed_granularity": netsim_mixed_granularity,
    "netsim_telemetry_overhead": netsim_telemetry_overhead,
}

# (benchmark, derived key, direction): guarded against the committed
# BENCH_netsim.json by ``run.py --baseline``.  Both metrics are same-run
# ratios (vectorized vs reference in one process), so they transfer
# across machine speeds; "higher" means new >= old * (1 - threshold)
# must hold, "lower" means new <= old * (1 + threshold) (+ a tiny
# absolute slack so a 0.0 baseline tolerates fp-accumulation drift).
# Independent of the baseline, ``run.py`` fails the scale suite whenever
# any derived boolean bar (speedup_ge_5x, gbs_within_1pct,
# pod_within_20pct, under_60s, superpod8_complete, ...) comes out False.
REGRESSION_GUARDS = (
    ("netsim_pod_calibration_speed", "speedup", "higher"),
    ("netsim_pod_calibration_speed", "gbs_rel_dev", "lower"),
    # same-run ratio: the priced mixed-granularity interference must not
    # silently vanish.  (Parity is guarded by the boolean
    # pod_parity_within_2pct / model_idle_within_2pct bars instead — a
    # relative guard against their 0.0 baseline would degenerate to the
    # run.py absolute slack, ~2000x tighter than the acceptance bar.)
    ("netsim_mixed_granularity", "model_degradation_pct", "higher"),
    # same-run ratio: fast-path planner (pre-filter + batched
    # precalibration + template reuse) vs the pre-PR per-spec baseline,
    # one process — must not quietly erode below the 3x acceptance bar
    ("netsim_planner_throughput", "speedup", "higher"),
    # same-run ratio: cross-topology batched calibration vs per-candidate
    # sequential precalibration on the reduced co-design guard set — the
    # ISSUE-8 dedup (shared solver sessions + coarse-structure reuse)
    # must not quietly erode below its 1.5x bar
    ("netsim_topo_sweep", "speedup", "higher"),
    # same-run ratio: enabling telemetry must not get quietly more
    # expensive (the disabled path's zero cost is covered by the speedup
    # guard above — a slowed-down disabled path would drag it down)
    ("netsim_telemetry_overhead", "overhead_ratio", "lower"),
)
